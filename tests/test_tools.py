"""Roofline tooling tests: jaxpr cost walker + HLO collective parser."""

import jax
import jax.numpy as jnp

from repro.tools.hlo_collectives import parse_collectives
from repro.tools.jaxpr_cost import trace_cost

jax.config.update("jax_platform_name", "cpu")


def test_jaxpr_cost_counts_scan_trip_counts():
    """The whole point of the walker: scans multiply by length (XLA's
    cost_analysis counts loop bodies once — verified here too)."""
    def body(c, _):
        return c @ c, None

    def with_scan(x):
        return jax.lax.scan(body, x, None, length=8)[0]

    def unrolled(x):
        for _ in range(8):
            x = x @ x
        return x

    spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c_scan = trace_cost(with_scan, spec)
    c_unrolled = trace_cost(unrolled, spec)
    dot = 2 * 64 ** 3
    assert c_scan["flops"] >= 8 * dot
    assert abs(c_scan["flops"] - c_unrolled["flops"]) < 0.01 * dot * 8

    # XLA undercounts the scan version (documents the motivation)
    xla = jax.jit(with_scan).lower(spec).compile().cost_analysis()
    if isinstance(xla, (list, tuple)):
        xla = xla[0]
    assert xla["flops"] <= dot * 1.1        # body counted once


def test_jaxpr_cost_counts_remat_recompute():
    """Backward of a checkpointed fn includes the recompute FLOPs."""
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def loss_plain(x):
        return jnp.sum((x @ x) @ x)

    def loss_remat(x):
        return jnp.sum(jax.checkpoint(lambda y: (y @ y) @ y)(x))

    g_plain = trace_cost(jax.grad(loss_plain), w)["flops"]
    g_remat = trace_cost(jax.grad(loss_remat), w)["flops"]
    dot = 2 * 64 ** 3
    # plain grad = 6 dots; remat grad = 7 (one recomputed fwd dot)
    assert g_remat >= g_plain + 0.9 * dot


def test_jaxpr_cost_nested_scan():
    def inner(c, _):
        return c @ c, None

    def outer(c, _):
        c, _ = jax.lax.scan(inner, c, None, length=3)
        return c, None

    def fn(x):
        return jax.lax.scan(outer, x, None, length=5)[0]

    c = trace_cost(fn, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    dot = 2 * 32 ** 3
    assert c["flops"] >= 15 * dot
    assert c["flops"] < 16 * dot + 1e6


def test_hlo_collective_parser_applies_trip_counts():
    synthetic = """
HloModule test

%body.1 (p: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {
  %ar = f32[16,16]{1,0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[], f32[16,16]) tuple(%i, %ar)
}

%cond.1 (p: (s32[], f32[16,16])) -> pred[] {
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[16,16]) -> f32[16,16] {
  %ag = f32[32,16]{1,0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[16,16]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %r = f32[16,16] get-tuple-element(%w), index=1
}
"""
    out = parse_collectives(synthetic)
    assert out["counts_by_kind"]["all-gather"] == 1
    assert out["counts_by_kind"]["all-reduce"] == 1
    assert out["bytes_by_kind"]["all-gather"] == 32 * 16 * 4
    assert out["bytes_by_kind"]["all-reduce"] == 12 * 16 * 16 * 4


def test_parser_handles_tuple_results_and_start_ops():
    synthetic = """
HloModule t

ENTRY %main (a: f32[8]) -> f32[8] {
  %ars = (f32[8]{0}, f32[8]{0}) all-reduce-start(%a)
  %ard = f32[8]{0} all-reduce-done(%ars)
  ROOT %r = f32[8]{0} copy(%ard)
}
"""
    out = parse_collectives(synthetic)
    assert out["counts_by_kind"]["all-reduce"] == 1     # start counted once
    assert out["bytes_by_kind"]["all-reduce"] == 2 * 8 * 4
