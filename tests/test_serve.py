"""QueryEngine tests (DESIGN.md §11): admission coalescing, singleton
bucket reuse, deadline degradation (and its brute-route bypass), drop
semantics, and mixed filtered/unfiltered admission windows."""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset
from repro.plan import resolve_plan, trace
from repro.serve.engine import QueryEngine

jax.config.update("jax_platform_name", "cpu")

PARAMS = BuildParams(m=6, ef_construction=32, prune_pool=32, chunk=128)


class FakeClock:
    """Manually-advanced monotonic clock for deadline tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@functools.lru_cache(maxsize=1)
def _index():
    base, queries = make_dataset("minilm-surrogate", n=800, queries=12)
    idx = QuIVerIndex.build(jnp.asarray(base), PARAMS)
    rng = np.random.default_rng(0)
    member = np.stack(
        [rng.random(len(base)) < p for p in (0.5, 0.01)], axis=1
    )
    idx.attach_labels(
        [np.nonzero(m)[0].tolist() for m in member], n_labels=2
    )
    idx.build_label_entries(min_count=32)
    return idx, np.asarray(queries, np.float32)


def test_engine_matches_per_call_search():
    idx, queries = _index()
    engine = QueryEngine(idx, default_k=5, default_ef=32)
    ids_e, sc_e = engine.search(queries[:6])
    ids_d, sc_d = idx.search(jnp.asarray(queries[:6]), k=5, ef=32)
    np.testing.assert_array_equal(ids_e, np.asarray(ids_d))
    np.testing.assert_allclose(sc_e, np.asarray(sc_d), rtol=1e-6)


def test_window_coalesces_same_plan_requests():
    idx, queries = _index()
    engine = QueryEngine(idx, default_k=5, default_ef=32)
    t1 = engine.submit(queries[0])
    t2 = engine.submit(queries[1:4])
    t3 = engine.submit(queries[4:6])
    assert engine.pump() == 3
    assert engine.stats.windows == 1
    assert engine.stats.batches == 1           # one plan -> one launch
    ids_d, _ = idx.search(jnp.asarray(queries[:6]), k=5, ef=32)
    ids_d = np.asarray(ids_d)
    np.testing.assert_array_equal(engine.poll(t1)[0], ids_d[:1])
    np.testing.assert_array_equal(engine.poll(t2)[0], ids_d[1:4])
    np.testing.assert_array_equal(engine.poll(t3)[0], ids_d[4:6])


def test_singleton_stream_reuses_smallest_bucket():
    idx, queries = _index()
    engine = QueryEngine(idx, default_k=5, default_ef=32)
    engine.warmup(buckets=(8,))
    with trace.assert_no_retrace(idx.plans.trace_prefix(),
                                 "singleton request stream"):
        for q in queries[:6]:
            engine.search(q)                   # six 1-query requests
    rep = engine.stats_report()
    assert rep["plan_retraces"] == 0
    assert rep["requests"] == 6 and rep["done"] == 6


def test_deadline_degrades_ef_before_dropping():
    idx, queries = _index()
    clock = FakeClock()
    engine = QueryEngine(idx, default_k=10, default_ef=64, clock=clock)
    plan, _ = resolve_plan(idx, k=10, ef=64)
    engine._observe(plan, 10.0)                # plan "measured" at 10 s
    t = engine.submit(queries[:2], deadline_ms=1000)
    engine.pump()
    tk = engine.ticket(t)
    assert tk.status == "done"                 # degraded, not dropped
    assert tk.degraded == 2                    # 64 -> 32 -> 16 (floor: k)
    assert tk.plan.ef == 16 and not tk.plan.adaptive
    assert engine.stats.degraded == 1 and engine.stats.dropped == 0
    # served at the degraded width, not the asked one
    ids_d, _ = idx.search(jnp.asarray(queries[:2]), k=10, ef=16,
                          adaptive=False)
    np.testing.assert_array_equal(engine.poll(t)[0], np.asarray(ids_d))


def test_brute_route_bypasses_degradation():
    idx, queries = _index()
    clock = FakeClock()
    engine = QueryEngine(idx, default_k=5, default_ef=64, clock=clock)
    # label 1 is ~1% selective -> exact brute route; give it a huge
    # observed latency and a tight budget: it must neither degrade
    # (exactness is not negotiable) nor drop (deadline not yet passed)
    plan, _ = resolve_plan(idx, k=5, ef=64, filter=1)
    assert plan.route == "brute"
    engine._observe(plan, 10.0)
    t = engine.submit(queries[:2], filter=1, deadline_ms=50)
    engine.pump()
    tk = engine.ticket(t)
    assert tk.status == "done" and tk.degraded == 0
    assert tk.plan.route == "brute"
    ids_d, _ = idx.search(jnp.asarray(queries[:2]), k=5, ef=64, filter=1)
    np.testing.assert_array_equal(engine.poll(t)[0], np.asarray(ids_d))


def test_expired_request_is_dropped():
    idx, queries = _index()
    clock = FakeClock()
    engine = QueryEngine(idx, default_k=5, default_ef=32, clock=clock)
    t = engine.submit(queries[:2], deadline_ms=5)
    clock.t = 1.0                              # budget long gone
    engine.pump()
    tk = engine.ticket(t)
    assert tk.status == "dropped"
    assert engine.stats.dropped == 1
    ids, scores = engine.result(t)
    assert (ids == -1).all() and np.isneginf(scores).all()


def test_mixed_filtered_unfiltered_window():
    """Regression: one admission window mixing plain, masked-graph and
    brute-routed filtered requests must serve each through its own plan
    group with per-request-correct results — and a second identical
    window must be retrace-free."""
    idx, queries = _index()
    engine = QueryEngine(idx, default_k=5, default_ef=32)

    def window():
        ts = (engine.submit(queries[:3]),
              engine.submit(queries[3:6], filter=0),
              engine.submit(queries[6:9], filter=1),
              engine.submit(queries[9:10], deadline_ms=60_000))
        assert engine.pump() == 4
        return ts

    t_plain, t_graph, t_brute, t_dead = window()
    assert engine.stats.windows == 1
    # three plan groups: the undegraded deadline request coalesces
    # into the plain group (same plan, same filter key)
    assert engine.stats.batches == 3
    assert engine.ticket(t_graph).plan.filtered
    assert engine.ticket(t_brute).plan.route == "brute"
    assert engine.ticket(t_dead).status == "done"

    for t, (qs, kw) in {
        t_plain: (queries[:3], {}),
        t_graph: (queries[3:6], {"filter": 0}),
        t_brute: (queries[6:9], {"filter": 1}),
        t_dead: (queries[9:10], {}),
    }.items():
        ids_d, _ = idx.search(jnp.asarray(qs), k=5, ef=32, **kw)
        np.testing.assert_array_equal(engine.poll(t)[0],
                                      np.asarray(ids_d))

    with trace.assert_no_retrace(idx.plans.trace_prefix(),
                                 "second mixed window"):
        window()
    assert engine.stats_report()["plan_retraces"] == 0
