"""Model-math consistency: chunked/parallel forms vs sequential
references, and serving (prefill+decode) vs training forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import mamba as mamba_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import flash_attention
from repro.models.model import build_model

jax.config.update("jax_platform_name", "cpu")


def test_mlstm_chunkwise_matches_sequential():
    """The chunkwise-parallel mLSTM equals the step recurrence."""
    rng = np.random.default_rng(0)
    b, h, t, d = 2, 2, 48, 16
    q, k, v = (jnp.asarray(rng.standard_normal((b, h, t, d)), jnp.float32)
               for _ in range(3))
    i_gate = jnp.asarray(rng.standard_normal((b, h, t)), jnp.float32)
    f_gate = jnp.asarray(rng.standard_normal((b, h, t)) + 1.0, jnp.float32)

    h_seq, st_seq = xlstm_mod.mlstm_sequential(q, k, v, i_gate, f_gate)
    for chunk in (8, 16, 48):
        h_chk, st_chk = xlstm_mod.mlstm_chunkwise(
            q, k, v, i_gate, f_gate, chunk=chunk
        )
        np.testing.assert_allclose(
            np.asarray(h_chk), np.asarray(h_seq), rtol=2e-4, atol=2e-4
        )
        np.testing.assert_allclose(
            np.asarray(st_chk[0]), np.asarray(st_seq[0]),
            rtol=2e-4, atol=2e-4,
        )


def test_mlstm_chunkwise_state_carry():
    """Splitting a sequence across two chunked calls == one call."""
    rng = np.random.default_rng(1)
    b, h, t, d = 1, 2, 32, 8
    q, k, v = (jnp.asarray(rng.standard_normal((b, h, t, d)), jnp.float32)
               for _ in range(3))
    ig = jnp.asarray(rng.standard_normal((b, h, t)), jnp.float32)
    fg = jnp.asarray(rng.standard_normal((b, h, t)), jnp.float32)

    h_full, _ = xlstm_mod.mlstm_chunkwise(q, k, v, ig, fg, chunk=8)
    h1, st = xlstm_mod.mlstm_chunkwise(
        q[:, :, :16], k[:, :, :16], v[:, :, :16],
        ig[:, :, :16], fg[:, :, :16], chunk=8,
    )
    h2, _ = xlstm_mod.mlstm_chunkwise(
        q[:, :, 16:], k[:, :, 16:], v[:, :, 16:],
        ig[:, :, 16:], fg[:, :, 16:], chunk=8, state=st,
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([h1, h2], axis=2)),
        np.asarray(h_full), rtol=2e-4, atol=2e-4,
    )


def test_mamba_prefill_state_matches_full_scan():
    """Running mamba over [x1;x2] == running x1 then x2 with state."""
    rng = np.random.default_rng(2)
    d_model, t = 32, 24
    p = mamba_mod.init_mamba(jax.random.PRNGKey(0), d_model, d_state=8,
                             expand=2, dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, t, d_model)), jnp.float32)

    conv0, ssm0 = mamba_mod.init_mamba_state(
        1, d_model, d_state=8, expand=2, dtype=jnp.float32
    )
    y_full, _ = mamba_mod.mamba(
        p, x, conv_state=conv0, ssm_state=ssm0, return_state=True
    )
    y1, (c1, s1) = mamba_mod.mamba(
        p, x[:, :12], conv_state=conv0, ssm_state=ssm0, return_state=True
    )
    y2, _ = mamba_mod.mamba(
        p, x[:, 12:], conv_state=c1, ssm_state=s1, return_state=True
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)),
        np.asarray(y_full), rtol=1e-3, atol=1e-3,
    )


def test_flash_attention_matches_naive():
    rng = np.random.default_rng(3)
    b, tq, tk, h, kh, hd = 2, 16, 16, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((b, tq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, tk, kh, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, tk, kh, hd)), jnp.float32)

    out = flash_attention(q, k, v, causal=True, kv_chunk=4)

    # naive reference
    g = h // kh
    qg = q.reshape(b, tq, kh, g, hd)
    scores = jnp.einsum("btkgh,bskh->btkgs", qg, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((tq, tk), bool))
    scores = jnp.where(mask[None, :, None, None, :], scores, -1e30)
    ref = jnp.einsum(
        "btkgs,bskh->btkgh", jax.nn.softmax(scores, -1), v
    ).reshape(b, tq, h, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_sliding_window():
    rng = np.random.default_rng(4)
    b, t, h, hd = 1, 32, 2, 8
    q = jnp.asarray(rng.standard_normal((b, t, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, h, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, h, hd)), jnp.float32)
    out_w = flash_attention(q, k, v, causal=True, sliding_window=8,
                            kv_chunk=8)
    # position 31 must ignore keys < 24: zeroing them changes nothing
    k2 = k.at[:, :20].set(0.0)
    v2 = v.at[:, :20].set(0.0)
    out_w2 = flash_attention(q, k2, v2, causal=True, sliding_window=8,
                             kv_chunk=8)
    np.testing.assert_allclose(
        np.asarray(out_w[:, -1]), np.asarray(out_w2[:, -1]),
        rtol=1e-5, atol=1e-5,
    )


@pytest.mark.parametrize("arch", ["yi-34b", "jamba-v0.1-52b", "xlstm-1.3b"])
def test_prefill_decode_matches_train_forward(arch):
    """Greedy decode logits == teacher-forced forward logits."""
    cfg = get_config(arch).smoke()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    b, s = 1, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)

    # training-style forward logits at every position
    from repro.models import transformer as tf
    x = tf.embed_tokens(params, cfg, tokens)
    hidden, _ = tf.forward_hidden(params, cfg, x)
    full_logits = tf.logits_from_hidden(params, cfg, hidden)

    # serving: prefill s-1 tokens, then decode one
    caches = bundle.init_caches(b, s + 4)
    logits_p, caches = jax.jit(bundle.prefill)(
        params, {"tokens": tokens[:, :-1]}, caches
    )
    logits_d, _ = jax.jit(bundle.decode)(
        params, tokens[:, -1:], caches, jnp.int32(s - 1)
    )
    if cfg.n_experts:
        # MoE capacity drops differ between a T-1 prefill and a T-token
        # forward, so exact logit equality is not guaranteed — require
        # argmax agreement + near-equality on the vast majority.
        for got, want in ((logits_p, full_logits[:, -2]),
                          (logits_d, full_logits[:, -1])):
            close = np.isclose(np.asarray(got), np.asarray(want),
                               rtol=5e-2, atol=5e-2).mean()
            assert close > 0.9, close
    else:
        np.testing.assert_allclose(
            np.asarray(logits_p), np.asarray(full_logits[:, -2]),
            rtol=3e-2, atol=3e-2,
        )
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full_logits[:, -1]),
            rtol=3e-2, atol=3e-2,
        )
