"""Streaming subsystem tests: tombstone-aware beam search, mutable
index freshness (insert/delete/consolidate/freeze), sharded streaming,
the build_sharded tail fix, named-params persistence, and the Retriever
padding-id fix."""

import functools
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import flat_search, recall_at_k
from repro.core.beam import beam_search
from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset
from repro.stream import MutableQuIVerIndex, StreamingShardedIndex

jax.config.update("jax_platform_name", "cpu")

PARAMS = BuildParams(m=6, ef_construction=32, prune_pool=32, chunk=128)


def _run_with_devices(n_dev: int, code: str) -> str:
    import os
    env = {
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_dev}",
        "PYTHONPATH": "src",
        "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "JAX_PLATFORMS": "cpu",
    }
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900, env=env,
        cwd="/root/repo",
    )
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@functools.lru_cache(maxsize=1)
def _data():
    base, queries = make_dataset("minilm-surrogate", n=2000, queries=25)
    return base, queries


# -- tombstone-aware beam search ---------------------------------------------


def _grid():
    n_side = 12
    n = n_side * n_side
    coords = np.stack(
        np.meshgrid(np.arange(n_side), np.arange(n_side), indexing="ij"),
        -1,
    ).reshape(-1, 2).astype(np.float32)
    adj = np.full((n, 4), -1, dtype=np.int32)
    for i, (x, y) in enumerate(coords):
        k = 0
        for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            nx, ny = int(x) + dx, int(y) + dy
            if 0 <= nx < n_side and 0 <= ny < n_side:
                adj[i, k] = nx * n_side + ny
                k += 1
    coords_j = jnp.asarray(coords)

    def dist_fn(query, ids, valid):
        return jnp.linalg.norm(coords_j[ids] - query, axis=-1)

    return n_side, n, jnp.asarray(adj), dist_fn


def test_masked_beam_all_valid_is_bit_identical():
    n_side, n, adj, dist_fn = _grid()
    q = jnp.asarray([8.7, 2.2], dtype=jnp.float32)
    plain = beam_search(q, adj, jnp.int32(0), dist_fn=dist_fn, ef=8, n=n)
    masked = beam_search(
        q, adj, jnp.int32(0), dist_fn=dist_fn, ef=8, n=n,
        node_valid=jnp.ones((n,), jnp.bool_),
    )
    np.testing.assert_array_equal(np.asarray(plain.ids),
                                  np.asarray(masked.ids))
    np.testing.assert_array_equal(np.asarray(plain.dists),
                                  np.asarray(masked.dists))


def test_masked_beam_navigates_through_dead_wall():
    """Kill a full grid column between start and target: the search
    must still cross it (dead nodes route) but never return dead ids."""
    n_side, n, adj, dist_fn = _grid()
    q = jnp.asarray([9.1, 2.1], dtype=jnp.float32)  # nearest: (9, 2)
    node_valid = jnp.ones((n,), jnp.bool_)
    wall = [5 * n_side + y for y in range(n_side)]   # column x == 5
    node_valid = node_valid.at[jnp.asarray(wall)].set(False)
    res = beam_search(
        q, adj, jnp.int32(0), dist_fn=dist_fn, ef=8, n=n,
        node_valid=node_valid,
    )
    ids = np.asarray(res.ids)
    assert int(ids[0]) == 9 * n_side + 2          # found across the wall
    assert not np.isin(ids[ids >= 0], wall).any()  # no dead in results


# -- mutable index lifecycle -------------------------------------------------


def test_freeze_static_corpus_bit_identical():
    """Acceptance: zero-churn freeze() search == the equivalent
    immutable index search, bit for bit."""
    base, queries = _data()
    idx = QuIVerIndex.build(jnp.asarray(base[:1200]), PARAMS)
    mut = MutableQuIVerIndex.from_index(idx)
    frozen = mut.freeze()
    i1, s1 = idx.search(jnp.asarray(queries), k=10, ef=48)
    i2, s2 = frozen.search(jnp.asarray(queries), k=10, ef=48)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(s1, s2)
    # and the mutable index's own masked search agrees too
    i3, _ = mut.search(jnp.asarray(queries), k=10, ef=48)
    np.testing.assert_array_equal(i1, i3)


def test_inserted_vectors_immediately_findable():
    base, queries = _data()
    mut = MutableQuIVerIndex.build(
        jnp.asarray(base[:1500]), PARAMS, capacity=2600
    )
    mut.insert(jnp.asarray(base[1500:2000]))
    assert mut.n_live == 2000
    # recall over the grown corpus
    gt, _ = flat_search(base[:2000], queries, k=10)
    pred, _ = mut.search(jnp.asarray(queries), k=10, ef=48)
    assert recall_at_k(pred, gt) > 0.75
    # the new vectors themselves are their own nearest neighbours
    qnew = base[1500:1550]
    pred1, _ = mut.search(jnp.asarray(qnew), k=1, ef=48)
    hit = (pred1.ravel() == np.arange(1500, 1550)).mean()
    assert hit > 0.9, hit


def test_deleted_ids_never_in_results_and_consolidation_recovers():
    base, queries = _data()
    mut = MutableQuIVerIndex.build(
        jnp.asarray(base[:1500]), PARAMS, capacity=2600
    )
    dead = np.arange(100, 550)          # heavy: 30% of the corpus
    assert mut.delete(dead) == len(dead)

    pred, _ = mut.search(jnp.asarray(queries), k=10, ef=48)
    assert not np.isin(pred, dead).any()

    keep = np.ones(1500, bool)
    keep[dead] = False
    orig = np.nonzero(keep)[0]
    gt_pos, _ = flat_search(base[:1500][keep], queries, k=10)
    gt = orig[gt_pos]
    recall_before = recall_at_k(pred, gt)

    report = mut.consolidate()
    assert report["reclaimed"] == len(dead)
    assert mut.free_slots >= len(dead)
    pred2, _ = mut.search(jnp.asarray(queries), k=10, ef=48)
    assert not np.isin(pred2, dead).any()
    recall_after = recall_at_k(pred2, gt)
    assert recall_after > 0.75, (recall_before, recall_after)
    assert recall_after >= recall_before - 0.02

    # reclaimed slots are reused by the next insert
    new_ids = mut.insert(jnp.asarray(base[1500:1700]))
    assert np.isin(new_ids, dead).all()


def test_freeze_roundtrips_through_save_load(tmp_path):
    base, queries = _data()
    mut = MutableQuIVerIndex.build(
        jnp.asarray(base[:800]), PARAMS, capacity=1200
    )
    mut.delete(np.arange(0, 80))
    mut.insert(jnp.asarray(base[800:900]))
    mut.consolidate()

    # mutable save/load preserves search behaviour exactly
    p = str(tmp_path / "stream.npz")
    mut.save(p)
    mut2 = MutableQuIVerIndex.load(p)
    a, _ = mut.search(jnp.asarray(queries), k=5, ef=32)
    b, _ = mut2.search(jnp.asarray(queries), k=5, ef=32)
    np.testing.assert_array_equal(a, b)
    assert mut2.generation == mut.generation

    # freeze -> immutable save/load roundtrip
    frozen = mut.freeze()
    pf = str(tmp_path / "frozen.npz")
    frozen.save(pf)
    frozen2 = QuIVerIndex.load(pf)
    fa, _ = frozen.search(jnp.asarray(queries), k=5, ef=32)
    fb, _ = frozen2.search(jnp.asarray(queries), k=5, ef=32)
    np.testing.assert_array_equal(fa, fb)
    # frozen ids are compacted: all within [0, n_live)
    assert fa.max() < mut.n_live
    # an immutable archive can be adopted as a mutable index
    mut3 = MutableQuIVerIndex.load(pf)
    assert mut3.n_live == mut.n_live


def test_empty_and_capacity_edges():
    mut = MutableQuIVerIndex.empty(32, 64, PARAMS)
    ids, scores = mut.search(np.ones((3, 32), np.float32), k=5)
    assert (ids == -1).all()
    with pytest.raises(ValueError, match="capacity"):
        mut.insert(np.ones((65, 32), np.float32))
    with pytest.raises(ValueError, match="cannot freeze"):
        mut.freeze()
    rng = np.random.default_rng(0)
    mut.insert(rng.standard_normal((40, 32)).astype(np.float32))
    assert mut.n_live == 40
    ids, _ = mut.search(np.ones((1, 32), np.float32), k=5)
    assert (ids >= 0).all()


# -- sharded streaming -------------------------------------------------------


def test_streaming_sharded_single_device():
    """1-shard fan-out path runs in-process: global ids, tombstone
    exclusion, and the masked merge all exercise the shard_map code."""
    base, queries = _data()
    idx = StreamingShardedIndex.empty(
        base.shape[-1], n_shards=1, capacity_per_shard=1000,
        params=PARAMS,
    )
    gids = idx.insert(base[:600])
    assert len(set(gids.tolist())) == 600
    kill = gids[50:150]
    idx.delete(kill)
    ids, scores = idx.search(queries, ef=48, k=10)
    assert not np.isin(ids, kill).any()
    assert idx.n_live == 500
    idx.consolidate()
    ids2, _ = idx.search(queries, ef=48, k=10)
    assert not np.isin(ids2, kill).any()


@pytest.mark.slow
def test_streaming_sharded_multi_device():
    out = _run_with_devices(4, """
        import numpy as np
        from repro.stream import StreamingShardedIndex
        from repro.core.vamana import BuildParams
        from repro.core.baselines import flat_search, recall_at_k
        from repro.data.datasets import make_dataset

        base, queries = make_dataset("minilm-surrogate", n=2000,
                                     queries=25)
        params = BuildParams(m=6, ef_construction=32, prune_pool=32,
                             chunk=128)
        idx = StreamingShardedIndex.empty(
            base.shape[-1], n_shards=4, capacity_per_shard=700,
            params=params)
        gids = idx.insert(base[:1600])
        assert len(set(gids.tolist())) == 1600
        # round-robin balance
        assert [s.n_live for s in idx.shards] == [400] * 4

        kill = gids[100:260]
        idx.delete(kill)
        idx.consolidate()
        ids, _ = idx.search(queries, ef=48, k=10)
        assert not np.isin(ids, kill).any()

        gid2orig = {int(g): i for i, g in enumerate(gids)}
        keep = np.ones(1600, bool); keep[100:260] = False
        orig = np.nonzero(keep)[0]
        gt_pos, _ = flat_search(base[:1600][keep], queries, k=10)
        gt = orig[gt_pos]
        pred = np.vectorize(lambda g: gid2orig.get(int(g), -1))(ids)
        rec = recall_at_k(pred, gt)
        print("RECALL", rec)
        assert rec > 0.7, rec
    """)
    assert "RECALL" in out


def test_build_sharded_indexes_every_vector_with_indivisible_n():
    out = _run_with_devices(3, """
        import numpy as np
        from repro.core.distributed import build_sharded, search_sharded
        from repro.core.baselines import flat_search
        from repro.core.vamana import BuildParams
        from repro.data.datasets import make_dataset

        base, _ = make_dataset("minilm-surrogate", n=904, queries=4)
        idx = build_sharded(
            base, 3,
            BuildParams(m=4, ef_construction=24, prune_pool=24,
                        chunk=128))
        per = idx.sig_words.shape[1]
        assert per == 302                       # ceil(904 / 3)
        assert int(np.asarray(idx.live).sum()) == 904
        # the tail vectors (would have been dropped before) are found
        tail = base[900:904]
        ids, _ = search_sharded(idx, tail, ef=48, k=1)
        print("TAIL", ids.ravel().tolist())
        assert ids.ravel().tolist() == [900, 901, 902, 903]
        # padded fill slots never surface
        all_ids, _ = search_sharded(idx, base[:100], ef=48, k=10)
        assert (all_ids < 904).all()
    """)
    assert "TAIL" in out


# -- satellite fixes ---------------------------------------------------------


def test_named_params_save_load_with_legacy_compat(tmp_path):
    base, queries = _data()
    params = BuildParams(m=4, ef_construction=24, prune_pool=24,
                         chunk=128, alpha=1.15, beam_expand=2)
    idx = QuIVerIndex.build(jnp.asarray(base[:600]), params)
    p = str(tmp_path / "named.npz")
    idx.save(p)
    z = np.load(p)
    assert "params" not in z                # positional array is gone
    assert int(z["param_m"]) == 4
    idx2 = QuIVerIndex.load(p)
    assert idx2.params == params            # alpha survives exactly

    # legacy positional archive still loads
    legacy = {k: z[k] for k in z.files if not k.startswith("param_")}
    legacy["params"] = np.array(
        [4, 24, 1150, 128, 24, 8, 8, 1, 0, 2], dtype=np.int64
    )
    pl = str(tmp_path / "legacy.npz")
    np.savez(pl, **legacy)
    idx3 = QuIVerIndex.load(pl)
    assert idx3.params == params
    i2, _ = idx2.search(jnp.asarray(queries), k=5, ef=32)
    i3, _ = idx3.search(jnp.asarray(queries), k=5, ef=32)
    np.testing.assert_array_equal(i2, i3)


def test_retriever_augment_handles_missing_hits():
    """-1 padding ids from a sparse index must inject pad tokens, not
    the last document in the store (the old silent-gather bug)."""
    from repro.serve.engine import Retriever

    rng = np.random.default_rng(0)
    docs = rng.standard_normal((5, 16)).astype(np.float32)
    idx = MutableQuIVerIndex.build(
        jnp.asarray(docs),
        BuildParams(m=2, ef_construction=8, prune_pool=8, chunk=128),
        capacity=32,
    )
    doc_tokens = np.arange(5 * 3, dtype=np.int32).reshape(5, 3) + 100

    def embed(tokens):
        return jnp.asarray(docs[:len(tokens)])

    r = Retriever(index=idx, doc_tokens=doc_tokens, embed_fn=embed,
                  k=8, ef=8)       # k=8 > 5 docs -> guaranteed -1 ids
    out = r.augment(np.zeros((2, 4), np.int32))
    assert out.shape == (2, 8 * 3 + 4)
    ctx = out[:, :8 * 3].reshape(2, 8, 3)
    # padded hits are all pad_token, and never equal the last doc's row
    is_pad = (ctx == 0).all(-1)
    assert is_pad.any(axis=1).all()
    last_doc = doc_tokens[-1]
    n_last = (ctx == last_doc).all(-1).sum(axis=1)
    assert (n_last <= 1).all()      # the real hit, not the pad gathers


def test_retriever_add_documents_grows_mutable_corpus():
    from repro.serve.engine import Retriever

    rng = np.random.default_rng(1)
    docs = rng.standard_normal((20, 24)).astype(np.float32)
    docs /= np.linalg.norm(docs, axis=-1, keepdims=True)
    idx = MutableQuIVerIndex.build(
        jnp.asarray(docs[:10]),
        BuildParams(m=2, ef_construction=8, prune_pool=8, chunk=128),
        capacity=64,
    )
    doc_tokens = np.arange(10 * 3, dtype=np.int32).reshape(10, 3)
    store = {}

    def embed(tokens):
        return jnp.asarray(
            np.stack([store[tuple(t)] for t in np.asarray(tokens)])
        )

    r = Retriever(index=idx, doc_tokens=doc_tokens, embed_fn=embed,
                  k=1, ef=16)
    new_tokens = (np.arange(10 * 3, 20 * 3, dtype=np.int32)
                  .reshape(10, 3))
    ids = r.add_documents(new_tokens, embeddings=docs[10:])
    assert len(ids) == 10 and idx.n_live == 20
    # a query at a new doc's embedding retrieves that doc's tokens
    store[tuple(np.zeros(3, np.int32))] = docs[15]
    out = r.augment(np.zeros((1, 3), np.int32))
    np.testing.assert_array_equal(out[0, :3], r.doc_tokens[ids[5]])


def test_streaming_dedup_matches_batch_semantics():
    from repro.data.dedup import streaming_dedup

    rng = np.random.default_rng(0)
    base = rng.standard_normal((260, 48)).astype(np.float32)
    base /= np.linalg.norm(base, axis=-1, keepdims=True)
    dup = base[:15] + 0.001 * rng.standard_normal((15, 48)).astype(
        np.float32
    )
    corpus = np.concatenate([base[:130], dup, base[130:]], axis=0)
    keep = streaming_dedup(corpus, threshold=0.98, ef=48, scan_batch=64)
    dropped = set(range(len(corpus))) - set(keep.tolist())
    planted = set(range(130, 145))
    assert len(dropped & planted) >= 13
    assert len(dropped - planted) <= 4
    # first occurrence wins: the originals are all kept
    assert set(range(15)) <= set(keep.tolist())
