"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs ref.py oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bq
from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")


def _rand_vecs(rng, n, d):
    return jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))


@pytest.mark.parametrize("dim", [64, 100, 384, 768, 1536])
@pytest.mark.parametrize("q,n", [(1, 64), (8, 512), (13, 777)])
def test_bq_distance_kernel_matches_ref(dim, q, n):
    rng = np.random.default_rng(dim + q + n)
    qs = bq.encode(_rand_vecs(rng, q, dim))
    bs = bq.encode(_rand_vecs(rng, n, dim))
    out = ops.bq_distance(qs.words, bs.words, dim, interpret=True)
    expect = ref.bq_distance_ref(qs.words, bs.words, dim)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
    assert out.dtype == jnp.int32


@pytest.mark.parametrize("dim", [32, 384, 768])
@pytest.mark.parametrize("blocks", [(8, 128), (16, 512)])
def test_bq_distance_kernel_block_sweep(dim, blocks):
    bq_, bn = blocks
    rng = np.random.default_rng(99)
    qs = bq.encode(_rand_vecs(rng, 24, dim))
    bs = bq.encode(_rand_vecs(rng, 1000, dim))
    out = ops.bq_distance(
        qs.words, bs.words, dim, block_q=bq_, block_n=bn, interpret=True
    )
    expect = ref.bq_distance_ref(qs.words, bs.words, dim)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("dim", [100, 768])
def test_hamming_kernel_matches_ref(dim):
    rng = np.random.default_rng(5)
    qs = bq.encode(_rand_vecs(rng, 9, dim))
    bs = bq.encode(_rand_vecs(rng, 333, dim))
    out = ops.hamming_distance(qs.pos, bs.pos, interpret=True)
    expect = ref.hamming_distance_ref(qs.pos, bs.pos, dim)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("dim", [64, 100, 384, 768, 1536])
@pytest.mark.parametrize("n", [4, 256, 300])
def test_binarize_kernel_matches_ref(dim, n):
    rng = np.random.default_rng(dim * 7 + n)
    x = _rand_vecs(rng, n, dim)
    sig = ops.binarize(x, interpret=True)
    expect = ref.binarize_ref(x)
    np.testing.assert_array_equal(np.asarray(sig.words), np.asarray(expect))
    assert sig.words.dtype == jnp.uint32
    assert sig.dim == dim


def test_binarize_then_distance_pipeline_consistent():
    """Full hot path: kernel binarize -> kernel distance == pure-jnp path."""
    rng = np.random.default_rng(11)
    base = _rand_vecs(rng, 200, 384)
    q = _rand_vecs(rng, 3, 384)
    sig_b = ops.binarize(base, interpret=True)
    sig_q = ops.binarize(q, interpret=True)
    d_kernel = ops.bq_distance(sig_q.words, sig_b.words, 384, interpret=True)
    d_ref = bq.pairwise_distance(bq.encode(q), bq.encode(base))
    np.testing.assert_array_equal(np.asarray(d_kernel), np.asarray(d_ref))


@pytest.mark.parametrize("shape", [(2, 128, 2, 32), (1, 256, 4, 64),
                                   (1, 100, 2, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_kernel_matches_ref(shape, causal):
    b, t, h, hd = shape
    rng = np.random.default_rng(sum(shape))
    q = jnp.asarray(rng.standard_normal((b, t, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, h, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, h, hd)), jnp.float32)
    out = ops.flash_attention_tpu(
        q, k, v, causal=causal, block_q=64, block_kv=64, interpret=True
    )
    folded = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    expect = ref.flash_attention_ref(
        folded(q), folded(k), folded(v), causal=causal
    ).reshape(b, h, t, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_kernel_bf16():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.bfloat16)
    out = ops.flash_attention_tpu(q, k, v, interpret=True, block_q=64,
                                  block_kv=64)
    assert out.dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(out, dtype=np.float32)).all()
