"""Shadow ground-truth sampling + closed-loop remediation tests
(DESIGN.md §14): deterministic sampling, recall-estimate fidelity,
recall-SLO edge triggering, targeted replan invalidation, and the
remediation ladder's ordering."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset
from repro.obs import (
    MetricsRegistry,
    RemediationPolicy,
    ShadowSampler,
    TenantLedger,
    shadow_hash,
    should_sample,
)
from repro.plan import trace
from repro.probe import probe_corpus
from repro.serve.engine import QueryEngine
from repro.stream.mutable import MutableQuIVerIndex

jax.config.update("jax_platform_name", "cpu")

PARAMS = BuildParams(m=6, ef_construction=32, prune_pool=32, chunk=128)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@functools.lru_cache(maxsize=1)
def _index():
    base, queries = make_dataset("minilm-surrogate", n=800, queries=32)
    idx = QuIVerIndex.build(jnp.asarray(base), PARAMS)
    return idx, np.asarray(queries, np.float32)


def _fresh_index(n=400, queries=8):
    base, qs = make_dataset("minilm-surrogate", n=n, queries=queries)
    return (QuIVerIndex.build(jnp.asarray(base), PARAMS),
            np.asarray(qs, np.float32))


def _red_report():
    """A sampled probe of a sign-collapsed corpus: red verdict."""
    rng = np.random.default_rng(7)
    bad = np.abs(rng.normal(size=(400, 32))).astype(np.float32) + 3.0
    return probe_corpus(jnp.asarray(bad), sample=400)


# -- deterministic sampling -------------------------------------------------


def test_should_sample_is_deterministic_and_stateless():
    rng = np.random.default_rng(0)
    q = rng.normal(size=(32,)).astype(np.float32)
    first = should_sample(q, 16)
    assert all(should_sample(q, 16) == first for _ in range(5))
    # the decision is a pure function of the bytes, not the object
    assert should_sample(q.copy(), 16) == first
    assert shadow_hash(q) == shadow_hash(q.copy())
    assert should_sample(q, 1)           # rate<=1: everything sampled


def test_sampling_rate_is_roughly_honoured():
    rng = np.random.default_rng(1)
    qs = rng.normal(size=(4096, 16)).astype(np.float32)
    frac = np.mean([should_sample(q, 16) for q in qs])
    assert 1 / 32 < frac < 1 / 8         # ~1/16, crc32 is uniform enough


# -- recall-estimate fidelity ----------------------------------------------


def test_shadow_recall_matches_exact_recall_of_served_results():
    from repro.core.baselines import flat_search

    idx, queries = _index()
    engine = QueryEngine(idx, shadow={"rate": 1}, default_ef=64)
    tickets = [engine.submit(queries[i:i + 4]) for i in range(0, 32, 4)]
    while any(engine.poll(t) is None for t in tickets):
        engine.pump()
    # recompute what the shadow lane should have measured
    served = np.concatenate([engine.poll(t)[0] for t in tickets])
    exact, _ = flat_search(idx.vectors, queries, k=10)
    manual = np.mean([
        len(set(s.tolist()) & set(e.tolist())) / 10
        for s, e in zip(served, np.asarray(exact))
    ])
    rep = engine.shadow.report()
    assert rep["seen"] == rep["sampled"] == rep["drained"] == 32
    assert rep["pending"] == 0           # pump drains after the window
    assert rep["recall_mean"] == pytest.approx(manual, abs=1e-4)
    # the fixture is a green corpus: the estimate should be high, and
    # within the ±3pt tolerance of the exact value by construction
    assert rep["recall_mean"] > 0.8


def test_shadow_lane_never_charges_tenant_buckets():
    idx, queries = _index()
    clk = FakeClock()
    engine = QueryEngine(idx, shadow={"rate": 1}, clock=clk)
    engine.set_quota("t0", qps=1.0)      # burst 2: third submit rejects
    tickets = [engine.submit(queries[i], tenant="t0") for i in range(3)]
    while any(engine.poll(t) is None for t in tickets):
        engine.pump()
    acct = engine.tenants.report()["tenants"]["t0"]
    assert acct["admitted"] == 2 and acct["rejected"] == 1
    # only *served* queries reach the shadow lane, and draining their
    # ground truth consumed no admission tokens
    assert engine.shadow.seen == 2
    assert engine.shadow.drained == 2


def test_shadow_sampler_requires_cold_vectors():
    idx, _ = _index()
    bare = QuIVerIndex(
        sigs=idx.sigs, adjacency=idx.adjacency, medoid=idx.medoid,
        params=idx.params, vectors=None,
    )
    with pytest.raises(ValueError, match="vector-free"):
        ShadowSampler(bare)


def test_memory_breakdown_accounts_shadow_state():
    idx, queries = _fresh_index()
    assert idx.memory_breakdown()["host_shadow_bytes"] == 0
    sampler = ShadowSampler(idx, rate=1, registry=MetricsRegistry())
    ids = np.zeros((len(queries), 10), np.int32)
    sampler.offer(queries, ids)
    mem = idx.memory_breakdown()
    assert mem["host_shadow_bytes"] == sampler.memory_bytes()
    assert mem["host_shadow_bytes"] > 0
    assert mem["total_bytes"] >= mem["hot_total_bytes"] + \
        mem["cold_vector_bytes"] + mem["host_shadow_bytes"]


# -- recall-SLO accounting --------------------------------------------------


def test_recall_slo_breach_is_edge_triggered():
    clk = FakeClock()
    ledger = TenantLedger(registry=MetricsRegistry(), clock=clk,
                          recall_min_samples=4)
    ledger.set_quota("t", qps=100.0, recall_slo=0.9)
    events = []
    ledger.subscribe(events.append)
    # below min_samples: no verdict yet
    for _ in range(3):
        assert not ledger.observe_recall("t", 0.2)
    assert not events
    # window p50 drops below the SLO: exactly one breach event
    assert ledger.observe_recall("t", 0.2)
    assert len(events) == 1
    assert events[0]["kind"] == "recall_slo" and events[0]["tenant"] == "t"
    for _ in range(8):                   # still breached: no re-fire
        ledger.observe_recall("t", 0.1)
    assert len(events) == 1
    # recovery clears the flag silently...
    for _ in range(32):
        ledger.observe_recall("t", 1.0)
    assert not ledger.recall_breached("t")
    assert len(events) == 1
    # ...so the next degradation alarms again
    for _ in range(32):
        ledger.observe_recall("t", 0.0)
    assert len(events) == 2


def test_recall_slo_ignored_without_quota():
    ledger = TenantLedger(registry=MetricsRegistry(),
                          recall_min_samples=2)
    for _ in range(8):
        assert not ledger.observe_recall("anon", 0.0)
    assert not ledger.recall_breached("anon")


# -- targeted replan invalidation ------------------------------------------


def test_replan_switches_default_nav_and_keeps_unrelated_plans():
    idx, queries = _fresh_index()
    # compile two plan families: the bq2 default and a forced-float32
    idx.search(jnp.asarray(queries), k=5, ef=32)
    idx.search(jnp.asarray(queries), k=5, ef=32, nav="float32")
    forced = [p for p in idx.plans._programs if p.nav == "float32"]
    survivors = {p: idx.plans._programs[p] for p in forced}
    policy = idx.replan(nav="float32")
    assert policy.nav == "float32" and policy.source == "replan"
    rep = idx.plans.report()
    assert rep["invalidated_plans"] >= 1          # the old bq2 family
    assert all(p.nav != "bq2" for p in idx.plans._programs)
    # unrelated (float32) executables survive by identity: re-running
    # them is retrace-free
    for p, prog in survivors.items():
        assert idx.plans._programs[p] is prog
    with trace.assert_no_retrace(idx.plans.trace_prefix(),
                                 "forced-nav plans survive a replan"):
        idx.search(jnp.asarray(queries), k=5, ef=32, nav="float32")
    assert idx.plans.report()["retraces"] == 0
    # default traffic now navigates the new family
    ids_default, _ = idx.search(jnp.asarray(queries), k=5, ef=32)
    ids_forced, _ = idx.search(jnp.asarray(queries), k=5, ef=32,
                               nav="float32")
    np.testing.assert_array_equal(np.asarray(ids_default),
                                  np.asarray(ids_forced))


def test_replan_validates_tier_requirements():
    idx, _ = _fresh_index()
    with pytest.raises(ValueError, match="partition"):
        idx.replan(nav="ivf")
    bare = QuIVerIndex(
        sigs=idx.sigs, adjacency=idx.adjacency, medoid=idx.medoid,
        params=idx.params, vectors=None,
    )
    with pytest.raises(ValueError, match="vector"):
        bare.replan(nav="float32")


def test_mutable_replan_flips_serving_metric():
    rng = np.random.default_rng(0)
    idx = MutableQuIVerIndex.empty(32, 256, PARAMS)
    idx.insert(rng.normal(size=(128, 32)).astype(np.float32))
    with pytest.raises(ValueError, match="stale"):
        idx.replan(nav="ivf")
    policy = idx.replan(nav="float32", source="remediation")
    assert policy.nav == "float32"
    assert idx.metric_kind == "float32"  # mutable default nav follows
    ids, scores = idx.search(rng.normal(size=(4, 32)).astype(np.float32),
                             k=5)
    assert np.asarray(ids).shape == (4, 5)


# -- the remediation ladder -------------------------------------------------


def test_remediation_ladder_walks_in_order():
    idx, queries = _fresh_index()
    engine = QueryEngine(idx, default_ef=64)
    red = _red_report()
    policy = RemediationPolicy(engine, probe_source=lambda: red,
                               auto=False, ef_cap=2.0,
                               registry=MetricsRegistry())
    trigger = {"kind": "recall_slo", "tenant": "t0"}
    # rung 2: the red re-probe wants the float32 ladder -> replan
    ev1 = policy.step(trigger)
    assert ev1["action"] == "replan"
    assert policy._current_nav() == "float32"
    # rung 3: nav already right -> spend ef (doubled, capped)
    ev2 = policy.step(trigger)
    assert ev2["action"] == "escalate_ef"
    assert engine.default_ef == 128
    # rung 4: ef capped -> red flag
    ev3 = policy.step(trigger)
    assert ev3["action"] == "flag_red"
    assert policy.flagged_red
    # ladder exhausted: further triggers are no-ops
    ev4 = policy.step(trigger)
    assert ev4["note"] == "already red-flagged"
    counts = policy.report()["actions"]
    assert counts["replan"] == 1 and counts["escalate_ef"] == 1
    # every rung re-probed first (except the exhausted no-op)
    assert counts["reprobe"] == 3
    # resolve() re-arms the ladder and restores the ef budget
    policy.resolve()
    assert not policy.flagged_red and engine.default_ef == 64


def test_remediation_green_reprobe_is_false_alarm():
    idx, _ = _fresh_index()
    engine = QueryEngine(idx, default_ef=64)
    green = probe_corpus(idx.vectors, sample=400)
    assert green.verdict == "green"
    policy = RemediationPolicy(engine, probe_source=lambda: green,
                               auto=False, registry=MetricsRegistry())
    ev = policy.step({"kind": "drift", "tenant": "t0", "band": "amber"})
    assert ev["action"] == "reprobe" and ev["note"] == "false alarm"
    assert policy._current_nav() == "bq2"         # no serving change
    assert engine.default_ef == 64


def test_remediation_auto_fires_on_drift_alarm():
    rng = np.random.default_rng(0)
    idx = MutableQuIVerIndex.empty(32, 2048, PARAMS)
    good = idx.insert(rng.normal(size=(128, 32)).astype(np.float32))
    monitor = idx.attach_drift_monitor(tenant="t", min_n=32,
                                       registry=MetricsRegistry())

    class Engine:                        # minimal engine surface
        def __init__(self, index):
            self.index = index
            self.default_ef = 64
            self.tenants = TenantLedger(registry=MetricsRegistry())
            self.obs = None

    policy = RemediationPolicy(Engine(idx), auto=True,
                               registry=MetricsRegistry()).attach(monitor)
    bad = np.abs(rng.normal(size=(512, 32))).astype(np.float32) + 3.0
    idx.insert(bad)
    idx.delete(good)                     # live set crosses to red
    # the alarm fired mid-mutation and the policy acted immediately:
    # live re-probe is red, so the default nav left bq2
    assert policy.action_counts["replan"] == 1
    assert idx.metric_kind == "float32"
    assert policy.last_report.verdict == "red"


def test_remediation_check_coalesces_queued_triggers():
    idx, _ = _fresh_index()
    engine = QueryEngine(idx, default_ef=64)
    red = _red_report()
    policy = RemediationPolicy(engine, probe_source=lambda: red,
                               auto=False, registry=MetricsRegistry())
    for _ in range(5):                   # correlated alarms, one episode
        policy._trigger({"kind": "recall_slo", "tenant": "t"})
    ev = policy.check()
    assert ev["action"] == "replan"
    assert policy.report()["pending_triggers"] == 0
    assert policy.check() is None        # nothing queued


# -- engine lifecycle -------------------------------------------------------


def test_swap_index_rewires_shadow_ground_truth():
    idx, queries = _index()
    engine = QueryEngine(idx, shadow={"rate": 1})
    new_idx, _ = _fresh_index()
    engine.swap_index(new_idx)
    assert engine.shadow.index is new_idx
    assert new_idx.shadow is engine.shadow
    t = engine.submit(queries[:2])
    while engine.poll(t) is None:
        engine.pump()
    assert engine.shadow.drained == 2    # GT ran against the new tier
