"""Applicability-boundary probe tests (DESIGN.md §10): deterministic
diagnostics, report/policy persistence, nav="auto" ladder selection on
both sides of the boundary, incremental probe-stat consistency under
streaming churn, and the adaptive-rerank escalation path."""

import dataclasses
import functools
import math

import jax
import numpy as np
import pytest

from repro.core import bq
from repro.core.beam import INF, beam_margin
from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset
from repro.probe import (
    CompatibilityReport,
    NavPolicy,
    ProbeAccumulator,
    Thresholds,
    merge_reports,
    probe_corpus,
    probe_signatures,
    select_policy,
)
from repro.stream import MutableQuIVerIndex

jax.config.update("jax_platform_name", "cpu")

PARAMS = BuildParams(m=6, ef_construction=32, prune_pool=32, chunk=128)


@functools.lru_cache(maxsize=None)
def _corpus(name: str, n: int = 1200):
    base, queries = make_dataset(name, n=n, queries=20)
    return base, queries


# -- diagnostics -------------------------------------------------------------


def test_probe_deterministic():
    base, _ = _corpus("minilm-surrogate")
    r1 = probe_corpus(base, sample=512, seed=3)
    r2 = probe_corpus(base, sample=512, seed=3)
    assert r1 == r2                       # bit-identical, incl. floats
    r3 = probe_corpus(base, sample=512, seed=4)
    assert r3 != r1                       # the sample actually moves


def test_probe_statistics_ranges():
    base, _ = _corpus("minilm-surrogate")
    r = probe_corpus(base, sample=512)
    assert 0.0 <= r.bq_agreement <= 1.0
    assert 0.0 <= r.sign_entropy <= 1.0
    assert 0.0 <= r.strong_entropy <= 1.0
    assert 0.0 <= r.inter_bit_corr <= 1.0
    assert r.cos_std > 0.0
    assert r.n_sampled == 512
    assert not math.isnan(r.margin_p30)


def test_probe_verdicts_match_paper_tiers():
    """The falsifiable boundary: contrastive -> green, Euclidean-native
    CV (constant sign plane) and the isotropic sphere -> red."""
    green, _ = _corpus("minilm-surrogate")
    assert probe_corpus(green).verdict == "green"
    cv, _ = _corpus("sift-like")
    rcv = probe_corpus(cv)
    assert rcv.verdict == "red"
    assert rcv.sign_entropy < 0.05        # Finding 1: dead sign plane
    sphere, _ = _corpus("random-sphere")
    assert probe_corpus(sphere).verdict == "red"


def test_probe_signatures_only_caps_at_amber():
    base, _ = _corpus("minilm-surrogate")
    sig = bq.encode(np.asarray(base[:500]))
    r = probe_signatures(sig.words, sig.dim, sample=256)
    assert math.isnan(r.bq_agreement)
    assert r.verdict == "amber"           # no falsifiable evidence
    cv, _ = _corpus("sift-like")
    sig2 = bq.encode(np.asarray(cv[:500]))
    assert probe_signatures(sig2.words, sig2.dim).verdict == "red"


def test_merge_reports_weights_by_sample():
    base, _ = _corpus("minilm-surrogate")
    r1 = probe_corpus(base[:600], sample=512, seed=0)
    r2 = probe_corpus(base[600:], sample=512, seed=1)
    m = merge_reports([r1, r2])
    assert m.n_sampled == r1.n_sampled + r2.n_sampled
    lo, hi = sorted([r1.bq_agreement, r2.bq_agreement])
    assert lo <= m.bq_agreement <= hi
    assert m.verdict in ("green", "amber", "red")
    with pytest.raises(ValueError):
        merge_reports([])


def test_thresholds_drive_verdict():
    base, _ = _corpus("minilm-surrogate")
    r = probe_corpus(base)
    strict = dataclasses.replace(
        r, thresholds=Thresholds(agreement_green=1.01)
    )
    assert strict.verdict == "amber"
    impossible = dataclasses.replace(
        r, thresholds=Thresholds(agreement_red=1.01)
    )
    assert impossible.verdict == "red"


# -- policy ------------------------------------------------------------------


def test_select_policy_ladder():
    base, _ = _corpus("minilm-surrogate")
    green = probe_corpus(base)
    assert select_policy(green).nav == "bq2"
    cv, _ = _corpus("sift-like")
    red = probe_corpus(cv)
    assert select_policy(red).nav == "float32"
    assert select_policy(red, have_vectors=False).nav == "adc"
    amber = dataclasses.replace(
        green, thresholds=Thresholds(agreement_green=1.01)
    )
    pol = select_policy(amber)
    assert pol.nav == "bq2" and pol.adaptive and pol.ef_scale == 2
    # the escalation threshold is calibrated from the probe sample
    assert pol.escalate_margin == pytest.approx(amber.margin_p30)


def test_nav_policy_validation():
    with pytest.raises(ValueError):
        NavPolicy(nav="bq1")              # not on the ladder
    with pytest.raises(ValueError):
        NavPolicy(nav="bq2", ef_scale=0)


# -- auto selection on both sides of the boundary ----------------------------


def test_build_auto_cosine_native_picks_bq2():
    base, queries = _corpus("minilm-surrogate")
    idx = QuIVerIndex.build(base, PARAMS, nav="auto", probe_sample=512)
    assert idx.metric_kind == "bq2"
    assert idx.policy is not None and idx.policy.source == "probe"
    assert idx.report is not None and idx.report.verdict == "green"
    ids, _ = idx.search(queries, k=5, ef=32)
    assert (np.asarray(ids) >= 0).all()
    mem = idx.memory_breakdown()
    assert mem["nav_policy"].startswith("bq2")
    assert mem["probe_verdict"] == "green"


def test_build_auto_euclidean_routes_off_bq2():
    """Gaussian-Euclidean (isotropic sphere after L2-norm) must route to
    a non-bq2 rung; with cold vectors that is float32."""
    base, _ = _corpus("random-sphere")
    idx = QuIVerIndex.build(base, PARAMS, nav="auto", probe_sample=512)
    assert idx.metric_kind == "float32"
    assert idx.policy.nav == "float32" and idx.policy.ef_scale > 1
    base_cv, _ = _corpus("sift-like")
    idx_cv = QuIVerIndex.build(
        base_cv, PARAMS, nav="auto", probe_sample=512, keep_vectors=False
    )
    assert idx_cv.metric_kind == "adc"    # no cold tier -> adc rung


def test_auto_probe_uses_rotated_encoding():
    """With rotate_seed the signatures are built from rotated vectors;
    the probe must measure that encoding, not the raw input."""
    import jax.numpy as jnp

    from repro.core.index import _normalize

    base, _ = _corpus("sift-like")
    idx = QuIVerIndex.build(
        base, PARAMS, nav="auto", probe_sample=256, rotate_seed=7
    )
    enc = _normalize(jnp.asarray(base, dtype=jnp.float32)) @ idx.rotation
    assert idx.report == probe_corpus(enc, sample=256)
    # rotation restores sign balance on the non-negative CV corpus
    assert idx.report.sign_entropy > 0.1


def test_auto_report_save_load_roundtrip(tmp_path):
    base, queries = _corpus("minilm-surrogate")
    idx = QuIVerIndex.build(base, PARAMS, nav="auto", probe_sample=512)
    path = str(tmp_path / "auto.npz")
    idx.save(path)
    idx2 = QuIVerIndex.load(path)
    assert idx2.policy == idx.policy
    assert idx2.report == idx.report
    assert idx2.metric_kind == idx.metric_kind
    ids1, _ = idx.search(queries, k=5, ef=32)
    ids2, _ = idx2.search(queries, k=5, ef=32)
    np.testing.assert_array_equal(np.asarray(ids1), np.asarray(ids2))


def test_plain_build_has_no_policy(tmp_path):
    base, _ = _corpus("minilm-surrogate")
    idx = QuIVerIndex.build(base[:400], PARAMS)
    assert idx.policy is None and idx.report is None
    path = str(tmp_path / "plain.npz")
    idx.save(path)
    loaded = QuIVerIndex.load(path)
    assert loaded.policy is None and loaded.report is None
    assert "nav_policy" not in idx.memory_breakdown()


# -- adaptive rerank ---------------------------------------------------------


def test_beam_margin_semantics():
    dists = np.asarray([
        [1.0, 2.0, 3.0, 4.0],            # margin: (10 - 2) / 10
        [9.0, 9.5, float(INF), float(INF)],   # starved at k=2 is fine
        [1.0, float(INF), float(INF), float(INF)],  # starved -> -1
    ], dtype=np.float32)
    m = np.asarray(beam_margin(dists, 2, 10.0))
    assert m[0] == pytest.approx(0.8)
    assert m[1] == pytest.approx((10.0 - 9.5) / 10.0)
    assert m[2] == -1.0


def test_adaptive_escalation_recovers_recall():
    """Amber-style schedule on a corpus where wider pools help: the
    escalated search must not lose recall, and must escalate only the
    tight-margin tail."""
    from repro.core.baselines import flat_search, recall_at_k

    base, queries = _corpus("glove-like")
    gt, _ = flat_search(base, queries, k=10)
    idx = QuIVerIndex.build(base, PARAMS, nav="auto", probe_sample=512)
    plain_ids, _ = idx.search(queries, k=10, ef=64, nav="bq2",
                              adaptive=False)
    auto_ids, _ = idx.search(queries, k=10, ef=64)
    r_plain = recall_at_k(plain_ids, gt)
    r_auto = recall_at_k(auto_ids, gt)
    assert r_auto >= r_plain - 1e-9
    # forcing adaptive on an explicitly-navigated search also works
    forced_ids, _ = idx.search(queries, k=10, ef=64, nav="bq2",
                               adaptive=True)
    assert recall_at_k(forced_ids, gt) >= r_plain - 1e-9


# -- incremental probe stats under churn -------------------------------------


def test_accumulator_matches_recompute_after_churn():
    base, _ = _corpus("minilm-surrogate")
    m = MutableQuIVerIndex.build(
        base[:600], PARAMS, capacity=1500, metric="auto",
    )
    assert m.policy is not None           # adopted from the auto build
    ids = m.insert(base[600:800])
    m.delete(ids[:50])
    m.delete(ids[:10])                    # double-delete must not double-count
    m.consolidate()
    m.insert(base[800:900])
    m.delete(np.arange(25))
    ref = ProbeAccumulator.from_words(
        np.asarray(m.words)[m.live], m.dim
    )
    assert m.probe_acc == ref
    assert m.probe_acc.n == m.n_live


def test_mutable_probe_report_and_save_load(tmp_path):
    base, _ = _corpus("minilm-surrogate")
    m = MutableQuIVerIndex.build(
        base[:600], PARAMS, capacity=1500, metric="auto",
    )
    m.insert(base[600:700])
    m.delete(np.arange(40))
    r = m.probe_report(sample=256)
    assert isinstance(r, CompatibilityReport)
    # entropy fields come from the exact incremental accumulator
    assert r.sign_entropy == pytest.approx(m.probe_acc.sign_entropy)
    path = str(tmp_path / "stream.npz")
    m.save(path)
    m2 = MutableQuIVerIndex.load(path)
    assert m2.policy == m.policy
    assert m2.report == m.report
    assert m2.probe_acc == m.probe_acc    # recomputed == maintained
    frozen = m2.freeze()
    assert frozen.policy == m.policy


def test_mutable_empty_rejects_auto():
    with pytest.raises(ValueError, match="auto"):
        MutableQuIVerIndex.empty(32, 100, PARAMS, metric="auto")
