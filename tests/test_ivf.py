"""IVF-over-BQ tests (DESIGN.md §13): partition determinism + layout
invariants, list-scan kernel parity, the nav="ivf" plan family (recall
parity, cache identity, zero retraces, derived stages), persistence,
construction seeding quality, targeted scatter, and auto-selection."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bq
from repro.core.baselines import flat_search, recall_at_k
from repro.core.index import QuIVerIndex
from repro.core.metric import MetricArrays, make_backend
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset
from repro.ivf import IVFPartition, build_partition, default_n_lists
from repro.kernels import dispatch
from repro.obs.metrics import MetricsRegistry
from repro.plan import QueryPlan, resolve_plan, trace

jax.config.update("jax_platform_name", "cpu")

PARAMS = BuildParams(
    m=6, ef_construction=32, prune_pool=32, chunk=128,
    ivf_candidates=True,
)


@functools.lru_cache(maxsize=1)
def _corpus():
    base, queries = make_dataset("cohere-surrogate", n=1500, queries=24)
    return np.asarray(base), np.asarray(queries, np.float32)


@functools.lru_cache(maxsize=1)
def _index():
    base, _ = _corpus()
    return QuIVerIndex.build(jnp.asarray(base), PARAMS)


# -- partition --------------------------------------------------------------


def test_partition_deterministic_under_seed():
    base, _ = _corpus()
    sigs = bq.encode(jnp.asarray(base))
    a = build_partition(sigs, seed=7)
    b = build_partition(sigs, seed=7)
    np.testing.assert_array_equal(a.assign, b.assign)
    np.testing.assert_array_equal(a.cent_ids, b.cent_ids)
    np.testing.assert_array_equal(
        np.asarray(a.cent_words), np.asarray(b.cent_words)
    )
    c = build_partition(sigs, seed=8)
    assert not np.array_equal(a.assign, c.assign)


def test_partition_layout_invariants():
    base, _ = _corpus()
    n = len(base)
    part = _index().ivf
    assert part.n_lists == default_n_lists(n)
    # member_ids is a permutation of the corpus
    assert sorted(part.member_ids.tolist()) == list(range(n))
    # offsets agree with assign, and each contiguous segment holds
    # exactly the nodes assigned to that list
    counts = np.bincount(part.assign, minlength=part.n_lists)
    np.testing.assert_array_equal(np.diff(part.offsets), counts)
    for lst in range(0, part.n_lists, 7):
        seg = part.member_ids[part.offsets[lst]:part.offsets[lst + 1]]
        assert set(seg.tolist()) == set(
            np.nonzero(part.assign == lst)[0].tolist()
        )
    # padded device view mirrors the layout; cap is lane-aligned
    assert part.cap % 8 == 0 and part.cap >= counts.max()
    lids = np.asarray(part.list_ids)
    assert ((lids >= 0).sum(axis=1) == counts).all()


def test_list_scan_kernel_parity_interpret():
    base, _ = _corpus()
    sigs = bq.encode(jnp.asarray(base[:64]))
    cents = bq.encode(jnp.asarray(base[200:456])).words    # L=256
    ref = dispatch.list_scan_ops(sigs.dim, route="ref")
    expect = np.asarray(ref.scan(sigs.words, cents))
    from repro.kernels.list_scan import list_scan_pallas
    got = np.asarray(list_scan_pallas(
        sigs.words, cents, bq.valid_mask(sigs.dim), dim=sigs.dim,
        interpret=True,
    ))
    np.testing.assert_array_equal(got, expect)


# -- nav="ivf" plan family --------------------------------------------------


def test_ivf_nav_recall_parity():
    base, queries = _corpus()
    idx = _index()
    gt = flat_search(base, queries, k=10)[0]
    ids, _ = idx.search(jnp.asarray(queries), k=10, ef=64, nav="bq2")
    r_graph = recall_at_k(np.asarray(ids), gt)
    part = idx.ivf
    p_wide = -(-3 * part.n_lists // 4)
    ids, _ = idx.search(jnp.asarray(queries), k=10, ef=128, nav="ivf",
                        probes=p_wide)
    r_wide = recall_at_k(np.asarray(ids), gt)
    ids, _ = idx.search(jnp.asarray(queries), k=10, ef=128, nav="ivf")
    r_def = recall_at_k(np.asarray(ids), gt)
    # widened flat scan matches the graph; defaults trade scan
    # fraction for recall (DESIGN.md §13) but stay serviceable
    assert r_wide >= r_graph - 0.02, (r_wide, r_graph)
    assert r_def >= 0.75 * r_graph, (r_def, r_graph)
    # full probe = exact bq2 candidate stage + rerank
    ids, _ = idx.search(jnp.asarray(queries), k=10, ef=256, nav="ivf",
                        probes=part.n_lists)
    assert recall_at_k(np.asarray(ids), gt) >= r_graph - 0.02


def test_ivf_plan_route_and_derived_stages():
    idx = _index()
    plan, ctx = resolve_plan(idx, k=10, ef=64, nav="ivf")
    assert plan.route == "ivf" and plan.probes >= 1
    assert f"p{plan.probes}" in plan.signature()
    up = plan.escalated()
    assert up.route == "ivf" and up.probes > plan.probes
    down = plan.degraded()
    assert down is not None and down.probes <= plan.probes
    # closed set: derived stages are themselves valid hashable plans
    assert isinstance(hash(up), int) and isinstance(hash(down), int)
    with pytest.raises(ValueError):
        QueryPlan(nav="ivf", k=10, ef=64, route="ivf", probes=0)


def test_ivf_plan_cache_hit_and_zero_retrace():
    base, queries = _corpus()
    idx = _index()
    plan, ctx = resolve_plan(idx, k=10, ef=64, nav="ivf")
    assert idx.plans.program(plan) is idx.plans.program(plan)
    idx.plans.warmup(plan, buckets=(8, 32))
    with trace.assert_no_retrace(idx.plans.trace_prefix(),
                                 "steady-state ivf search"):
        for nq in (1, 5, 8, 3, 8, 1):
            idx.plans.run(plan, ctx, jnp.asarray(queries[:nq]))
    assert idx.plans.report()["retraces"] == 0


def test_ivf_requires_partition():
    base, queries = _corpus()
    bare = QuIVerIndex.build(
        jnp.asarray(base[:400]),
        BuildParams(m=6, ef_construction=32, prune_pool=32, chunk=128),
    )
    with pytest.raises(ValueError, match="ivf"):
        bare.search(jnp.asarray(queries[:2]), k=5, ef=16, nav="ivf")


def test_filtered_ivf_returns_only_matches():
    base, queries = _corpus()
    idx = _index()
    if idx.labels is None:
        rng = np.random.default_rng(0)
        member = rng.random(len(base)) < 0.3
        idx.attach_labels(
            [[0] if m else [] for m in member], n_labels=1
        )
    ids, _ = idx.search(jnp.asarray(queries), k=10, ef=64, nav="ivf",
                        filter=0)
    from repro.filter import eval_mask, Label
    mask = np.asarray(eval_mask(idx.labels.words, Label(0)))
    got = np.asarray(ids)
    assert (got >= 0).any()
    assert mask[got[got >= 0]].all()


# -- persistence ------------------------------------------------------------


def test_save_load_roundtrip(tmp_path):
    base, queries = _corpus()
    idx = _index()
    path = str(tmp_path / "ivf_index.npz")
    idx.save(path)
    loaded = QuIVerIndex.load(path)
    assert loaded.ivf is not None
    np.testing.assert_array_equal(loaded.ivf.assign, idx.ivf.assign)
    np.testing.assert_array_equal(
        np.asarray(loaded.ivf.list_ids), np.asarray(idx.ivf.list_ids)
    )
    np.testing.assert_array_equal(
        np.asarray(loaded.ivf.cent_words), np.asarray(idx.ivf.cent_words)
    )
    a, _ = idx.search(jnp.asarray(queries[:8]), k=10, ef=64, nav="ivf")
    b, _ = loaded.search(jnp.asarray(queries[:8]), k=10, ef=64,
                         nav="ivf")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_freeze_rebuilds_partition_and_mutable_rejects_ivf():
    from repro.stream import MutableQuIVerIndex
    base, queries = _corpus()
    m = MutableQuIVerIndex.build(base[:600], PARAMS, capacity=800)
    with pytest.raises(ValueError, match="freeze"):
        m.search(queries[:2], 5, nav="ivf")
    m.delete(np.arange(10))
    frozen = m.freeze()
    assert frozen.ivf is not None
    assert frozen.ivf.assign.shape[0] == 590
    ids, _ = frozen.search(jnp.asarray(queries[:4]), k=5, ef=32,
                           nav="ivf")
    assert (np.asarray(ids) >= 0).any()


def test_memory_breakdown_reports_ivf_hot():
    idx = _index()
    mem = idx.memory_breakdown()
    assert mem["hot_ivf_bytes"] == idx.ivf.memory_bytes() > 0
    assert mem["hot_ivf_bytes"] <= mem["hot_total_bytes"]


# -- construction seeding ---------------------------------------------------


def test_ivf_assisted_build_quality():
    base, queries = _corpus()
    gt = flat_search(base, queries, k=10)[0]
    plain = QuIVerIndex.build(
        jnp.asarray(base),
        BuildParams(m=6, ef_construction=32, prune_pool=32, chunk=128),
    )
    a, _ = plain.search(jnp.asarray(queries), k=10, ef=64)
    b, _ = _index().search(jnp.asarray(queries), k=10, ef=64,
                           nav="bq2")
    r_plain = recall_at_k(np.asarray(a), gt)
    r_ivf = recall_at_k(np.asarray(b), gt)
    assert r_ivf >= r_plain - 0.05, (r_ivf, r_plain)


# -- targeted scatter -------------------------------------------------------


def test_targeted_scatter_matches_broadcast():
    from repro.core.distributed import (
        build_ivf_sharded, search_ivf_sharded,
    )
    base, queries = _corpus()
    idx = build_ivf_sharded(base, 8, seed=0)
    assert sum(s.ids.size for s in idx.shards) == len(base)
    reg = MetricsRegistry()
    p = 2
    ids_t, sc_t = search_ivf_sharded(idx, queries, k=10, ef=64,
                                     probes=p, registry=reg)
    ids_b, sc_b = search_ivf_sharded(idx, queries, k=10, ef=64,
                                     probes=p, broadcast=True,
                                     registry=reg)
    np.testing.assert_array_equal(ids_t, ids_b)
    np.testing.assert_allclose(sc_t, sc_b)
    # per-query fan-out is bounded by min(p, S) — that is the point
    hist = reg.snapshot()["quiver_ivf_scatter_shards"][""]
    assert hist["count"] == 2 * len(queries)
    h = reg.histogram("quiver_ivf_scatter_shards")
    assert h.percentile(100) <= min(p, idx.n_shards)
    # per-list route counters accumulated
    routes = reg.snapshot()["quiver_ivf_list_routes_total"]
    assert sum(routes.values()) == 2 * len(queries) * p


def test_targeted_scatter_recall():
    from repro.core.distributed import (
        build_ivf_sharded, search_ivf_sharded,
    )
    base, queries = _corpus()
    gt = flat_search(base, queries, k=10)[0]
    idx = build_ivf_sharded(base, 4, seed=0)
    ids, _ = search_ivf_sharded(idx, queries, k=10, ef=256,
                                probes=idx.n_lists,
                                registry=MetricsRegistry())
    # full probe == exact bq2 stage + rerank across the fleet
    assert recall_at_k(ids, gt) >= 0.9
    for row in ids:
        v = row[row >= 0]
        assert len(set(v.tolist())) == len(v)


def test_streaming_scatter_routing():
    from repro.stream import MutableQuIVerIndex, StreamingShardedIndex
    base, queries = _corpus()
    fleet = StreamingShardedIndex.empty(
        base.shape[1], n_shards=3, capacity_per_shard=300,
        params=BuildParams(m=6, ef_construction=32, prune_pool=32,
                           chunk=128),
    )
    fleet.insert(base[:720])
    with pytest.raises(ValueError, match="enable_ivf_routing"):
        fleet.search(queries[:2], k=5, scatter=True)
    n_lists = fleet.enable_ivf_routing(seed=0)
    reg = MetricsRegistry()
    ids, sc = fleet.search(queries, k=10, ef=64, scatter=True,
                           probes=n_lists, registry=reg)
    gt = flat_search(base[:720], queries, k=10)[0]
    # gid -> original insert order (round-robin over 3 shards)
    shard = ids // fleet.capacity_per_shard
    slot = ids % fleet.capacity_per_shard
    orig = np.where(ids >= 0, slot * 3 + shard, -1)
    assert recall_at_k(orig, gt) >= 0.6
    assert reg.snapshot()["quiver_ivf_scatter_shards"][""]["count"] \
        == len(queries)
    # churn invalidates the tier lazily: delete then search again
    fleet.delete(ids[0, :3][ids[0, :3] >= 0])
    ids2, _ = fleet.search(queries[:4], k=5, ef=32, scatter=True,
                           registry=reg)
    dead = set(ids[0, :3][ids[0, :3] >= 0].tolist())
    assert not dead & set(ids2.ravel()[ids2.ravel() >= 0].tolist())


# -- auto-selection ---------------------------------------------------------


def test_auto_selection_prefers_ivf_on_green():
    from repro.probe import select_policy
    from repro.probe.report import CompatibilityReport
    idx = _index()
    report = idx.report
    if report is None:
        from repro.probe import probe_corpus
        base, _ = _corpus()
        report = probe_corpus(base)
    assert report.verdict == "green"
    pol = select_policy(report, have_ivf=True)
    assert pol.nav == "ivf" and pol.source == "probe"
    assert select_policy(report, have_ivf=False).nav == "bq2"


def test_metric_ivf_build_sets_policy():
    base, queries = _corpus()
    idx = QuIVerIndex.build(
        jnp.asarray(base[:500]), PARAMS, metric="ivf",
    )
    assert idx.metric_kind == "bq2"
    assert idx.policy is not None and idx.policy.nav == "ivf"
    assert idx.ivf is not None
    # default search rides the policy onto the ivf route
    ids, _ = idx.search(jnp.asarray(queries[:4]), k=5, ef=32)
    assert (np.asarray(ids) >= 0).any()
