"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step + one prefill/decode step on CPU; shape + NaN asserts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import all_configs
from repro.models.model import build_model

jax.config.update("jax_platform_name", "cpu")

ARCHS = sorted(all_configs().keys())


def _make_batch(bundle, rng, b=2, s=32):
    cfg = bundle.cfg
    if cfg.family == "encdec":
        s_dec = max(s // 4, 4)
        return {
            "frames": jnp.asarray(
                rng.standard_normal((b, s, cfg.d_model)), jnp.bfloat16
            ),
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s_dec)), jnp.int32
            ),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s_dec)), jnp.int32
            ),
        }
    n_front = cfg.n_frontend_tokens if cfg.frontend == "patch_stub" else 0
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s - n_front)), jnp.int32
        ),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s - n_front)), jnp.int32
        ),
    }
    if n_front:
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, n_front, cfg.d_model)), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = all_configs()[arch].smoke()
    bundle = build_model(cfg)
    rng = np.random.default_rng(0)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _make_batch(bundle, rng)

    loss, metrics = jax.jit(bundle.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    # one SGD step moves the loss (differentiability end to end)
    grads = jax.grad(lambda p: bundle.loss(p, batch)[0])(params)
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads)
    ))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_then_decode(arch):
    cfg = all_configs()[arch].smoke()
    bundle = build_model(cfg)
    rng = np.random.default_rng(1)
    b, s, max_seq = 2, 16, 32
    params = bundle.init(jax.random.PRNGKey(1))
    batch = _make_batch(bundle, rng, b=b, s=s)
    batch.pop("labels", None)
    caches = bundle.init_caches(b, max_seq)

    logits, caches = jax.jit(bundle.prefill)(params, batch, caches)
    assert logits.shape == (b, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all(), arch
    # padded vocab entries are masked to -inf-ish
    if cfg.padded_vocab != cfg.vocab_size:
        assert (np.asarray(logits)[:, cfg.vocab_size:] < -1e29).all()

    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    prompt_len = batch["tokens"].shape[1] + (
        cfg.n_frontend_tokens if cfg.frontend == "patch_stub" else 0
    )
    pos = jnp.int32(prompt_len if cfg.family != "encdec"
                    else batch["tokens"].shape[1])
    logits2, caches = jax.jit(bundle.decode)(params, tok, caches, pos)
    assert logits2.shape == (b, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2, dtype=np.float32)).all(), arch


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10, ARCHS


def test_param_counts_in_expected_range():
    """Full-config analytic param counts land near the advertised sizes."""
    expect = {
        "yi-34b": (30e9, 40e9),
        "command-r-plus-104b": (90e9, 120e9),
        "nemotron-4-340b": (300e9, 380e9),
        "minicpm-2b": (2e9, 3.5e9),
        "qwen3-moe-30b-a3b": (25e9, 35e9),
        "qwen2-moe-a2.7b": (12e9, 17e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "xlstm-1.3b": (1.0e9, 2.2e9),
        "internvl2-2b": (1.5e9, 3e9),
        "whisper-medium": (0.5e9, 0.9e9),
    }
    for name, (lo, hi) in expect.items():
        n = all_configs()[name].param_count()
        assert lo <= n <= hi, (name, f"{n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]")
