"""Distributed-system tests: sharded QuIVer, compressed psum, dedup,
serve engine.  Multi-device cases run in a subprocess with forced host
devices (the main test process must keep seeing 1 CPU device)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")


def _run_with_devices(n_dev: int, code: str) -> str:
    env = {
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_dev}",
        "PYTHONPATH": "src",
        "PATH": "/usr/bin:/bin",
        "JAX_PLATFORMS": "cpu",
    }
    import os
    env["PATH"] = os.environ.get("PATH", env["PATH"])
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900, env=env,
        cwd="/root/repo",
    )
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.slow
def test_sharded_quiver_search_recall():
    out = _run_with_devices(4, """
        import numpy as np, jax.numpy as jnp
        from repro.core.distributed import build_sharded, search_sharded
        from repro.core.baselines import flat_search, recall_at_k
        from repro.core.vamana import BuildParams
        from repro.data.datasets import make_dataset

        base, queries = make_dataset("minilm-surrogate", n=2000, queries=30)
        idx = build_sharded(
            base, 4,
            BuildParams(m=6, ef_construction=32, prune_pool=32, chunk=128),
        )
        ids, scores = search_sharded(idx, queries, ef=48, k=10)
        gt, _ = flat_search(base[: len(base) // 4 * 4], queries, k=10)
        rec = recall_at_k(ids, gt)
        print("RECALL", rec)
        assert rec > 0.7, rec
        # merged ids are global and unique per query
        for row in ids:
            v = row[row >= 0]
            assert len(set(v.tolist())) == len(v)
    """)
    assert "RECALL" in out


@pytest.mark.slow
def test_compressed_psum_matches_full_precision_direction():
    out = _run_with_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.compat import shard_map
        from repro.optim.compress import compressed_psum

        mesh = jax.make_mesh((4,), ("data",))
        x = jnp.asarray(
            np.random.default_rng(0).standard_normal((4, 256)), jnp.float32
        )

        def f(xs):
            return compressed_psum(xs[0], "data")[None]

        y = jax.jit(shard_map(
            f, mesh=mesh, in_specs=(P("data", None),),
            out_specs=P("data", None), check_vma=False,
        ))(x)
        exact = x.sum(0)
        got = np.asarray(y)[0]
        cos = float(
            (got @ np.asarray(exact))
            / (np.linalg.norm(got) * np.linalg.norm(exact))
        )
        print("COS", cos)
        assert cos > 0.6, cos   # 2-bit quantized sum preserves direction
    """)
    assert "COS" in out


def test_semantic_dedup_drops_duplicates():
    from repro.data.dedup import semantic_dedup
    rng = np.random.default_rng(0)
    base = rng.standard_normal((300, 64)).astype(np.float32)
    base /= np.linalg.norm(base, axis=-1, keepdims=True)
    # plant near-duplicates: rows 100..119 copy rows 0..19
    dup = base[:20] + 0.001 * rng.standard_normal((20, 64)).astype(
        np.float32
    )
    corpus = np.concatenate([base[:100], dup, base[100:]], axis=0)
    keep = semantic_dedup(corpus, threshold=0.98, ef=48)
    dropped = set(range(len(corpus))) - set(keep.tolist())
    # most planted duplicates (indices 100..119) must be dropped
    planted = set(range(100, 120))
    assert len(dropped & planted) >= 15, (len(dropped & planted), dropped)
    # and almost nothing else
    assert len(dropped - planted) <= 5


def test_serve_engine_greedy_deterministic():
    from repro.configs.base import get_config
    from repro.models.model import build_model
    from repro.serve.engine import ServeEngine

    cfg = get_config("yi-34b").smoke()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    engine = ServeEngine(bundle, params, max_seq=64)
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out1 = engine.generate(prompts, max_new=6)
    out2 = engine.generate(prompts, max_new=6)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (2, 6)
    assert (out1 >= 0).all() and (out1 < cfg.vocab_size).all()


def test_rotation_option_preserves_search_api():
    from repro.core.index import QuIVerIndex, random_rotation
    from repro.core.vamana import BuildParams
    from repro.data.datasets import make_dataset

    r = random_rotation(64, seed=3)
    np.testing.assert_allclose(
        np.asarray(r @ r.T), np.eye(64), atol=1e-4
    )
    base, queries = make_dataset("minilm-surrogate", n=600, queries=10)
    base, queries = base[:, :64], queries[:, :64]
    idx = QuIVerIndex.build(
        jnp.asarray(base),
        BuildParams(m=4, ef_construction=24, prune_pool=24, chunk=128),
        rotate_seed=3,
    )
    ids, scores = idx.search(jnp.asarray(queries), k=5, ef=32)
    assert ids.shape == (10, 5)
    assert (scores <= 1.0 + 1e-5).all()
