"""Substrate tests: optimizer, schedules, compression, checkpointing,
pipeline determinism, trainer restart + straggler detection."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim.compress import (
    compress_decompress_tree,
    compression_ratio,
    sm2_dequantize,
    sm2_quantize,
)
from repro.optim.schedule import warmup_cosine, wsd
from repro.train.train_step import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig

jax.config.update("jax_platform_name", "cpu")


# -- optimizer ---------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    cfg = AdamWConfig(weight_decay=0.0)
    state = init_opt_state(params, cfg)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg,
                                        jnp.float32(0.05))
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_bf16_state_roundtrips():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    cfg = AdamWConfig(state_dtype=jnp.bfloat16)
    state = init_opt_state(params, cfg)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    params2, state, _ = adamw_update(params, grads, state, cfg,
                                     jnp.float32(0.01))
    assert jnp.isfinite(params2["w"].astype(jnp.float32)).all()
    assert (params2["w"] != params["w"]).any()


# -- schedules ---------------------------------------------------------------


def test_wsd_schedule_phases():
    lr = lambda s: float(wsd(s, peak_lr=1.0, warmup=10, stable=80, decay=10))
    assert lr(0) == 0.0
    assert lr(5) == pytest.approx(0.5)
    assert lr(50) == pytest.approx(1.0)      # stable phase
    assert lr(89) == pytest.approx(1.0)
    assert lr(95) < 0.2                       # decay tail
    assert lr(100) == pytest.approx(0.01, rel=0.1)


def test_cosine_schedule_monotone_after_peak():
    vals = [float(warmup_cosine(s, peak_lr=1.0, warmup=10, total=100))
            for s in range(10, 100, 10)]
    assert all(a >= b - 1e-6 for a, b in zip(vals, vals[1:]))


# -- 2-bit SM gradient compression -------------------------------------------


def test_sm2_quantize_roundtrip_preserves_sign_and_scale():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1000,)) * 0.1, jnp.float32)
    words, cw, cs = sm2_quantize(x)
    dec = sm2_dequantize(words, cw, cs, x.size, x.shape)
    # signs always preserved
    assert (jnp.sign(dec) == jnp.sign(x)).mean() > 0.999
    # Lloyd-Max levels: decoded norm within 2x of true norm
    ratio = float(jnp.linalg.norm(dec) / jnp.linalg.norm(x))
    assert 0.5 < ratio < 2.0


def test_error_feedback_sgd_converges():
    """EF-compressed gradient descent still reaches the optimum."""
    w = jnp.asarray([4.0, -2.0, 1.0, -0.5] * 16)
    ef = jnp.zeros_like(w)
    lr = 0.05
    for _ in range(400):
        g = 2 * w                           # d/dw ||w||^2
        dec, new_ef = compress_decompress_tree({"w": g}, {"w": ef})
        ef = new_ef["w"]
        w = w - lr * dec["w"]
    assert float(jnp.abs(w).max()) < 0.1


def test_compression_ratio_near_16x():
    params = {"a": jnp.zeros((1024, 1024)), "b": jnp.zeros((4096,))}
    r = compression_ratio(params)
    assert 15.0 < r <= 16.0


# -- checkpointing -----------------------------------------------------------


def test_checkpoint_roundtrip_and_latest(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"count": jnp.int32(7)}}
    checkpoint.save(str(tmp_path / "step_5"), tree, step=5)
    checkpoint.save(str(tmp_path / "step_9"), tree, step=9)
    assert checkpoint.latest_step(str(tmp_path)) == 9
    restored, step = checkpoint.restore(str(tmp_path / "step_9"), tree)
    assert step == 9
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(tree["params"]["w"])
    )


def test_checkpoint_async_write_completes(tmp_path):
    tree = {"w": jnp.ones((128, 128))}
    t = checkpoint.save(str(tmp_path / "step_1"), tree, step=1,
                        async_write=True)
    t.join()
    restored, _ = checkpoint.restore(str(tmp_path / "step_1"), tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.ones((128, 128)))


# -- data pipeline -----------------------------------------------------------


def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4, seed=3)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)
    b1 = p1.batch_at(17)
    b2 = p2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(
        p1.batch_at(3)["tokens"][:, 1:], p1.batch_at(3)["labels"][:, :-1]
    )


def test_pipeline_host_sharding_partitions_batch():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
    hosts = [TokenPipeline(cfg, host_id=i, n_hosts=4) for i in range(4)]
    batches = [h.batch_at(0)["tokens"] for h in hosts]
    assert all(b.shape == (2, 16) for b in batches)
    # different hosts see different data
    assert not np.array_equal(batches[0], batches[1])


# -- trainer: restart + fault tolerance ---------------------------------------


def _tiny_setup(tmp_path, steps, ckpt_every=4, lr=1e-3):
    cfg = get_config("minicpm-2b").smoke()
    bundle = build_model(cfg)
    tc = TrainConfig(n_micro=1, peak_lr=lr, total_steps=steps)
    pipeline = TokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=2
    ))
    trainer = Trainer(
        bundle, tc,
        TrainerConfig(steps=steps, ckpt_dir=str(tmp_path),
                      ckpt_every=ckpt_every, log_every=2,
                      async_ckpt=False),
        pipeline,
    )
    return trainer


@pytest.mark.slow
def test_trainer_checkpoint_restart_resumes(tmp_path):
    t1 = _tiny_setup(tmp_path, steps=6, ckpt_every=3)
    r1 = t1.run()
    assert r1["final_step"] == 6
    assert checkpoint.latest_step(str(tmp_path)) == 6

    # a "restarted job": same config, higher step target
    t2 = _tiny_setup(tmp_path, steps=10, ckpt_every=3)
    r2 = t2.run()
    assert r2["final_step"] == 10
    # it resumed: first logged step is >= 6, not 0
    assert r2["metrics"][0]["step"] >= 6


@pytest.mark.slow
def test_trainer_loss_decreases(tmp_path):
    t = _tiny_setup(tmp_path / "none", steps=200, ckpt_every=10_000,
                    lr=5e-3)
    t.cfg.ckpt_dir = None
    r = t.run()
    first = np.mean([m["loss"] for m in r["metrics"][:3]])
    last = np.mean([m["loss"] for m in r["metrics"][-3:]])
    assert last < first - 0.2, (first, last)


def test_straggler_detection_flags_slow_steps():
    events = []

    class FakeTrainer(Trainer):
        def __init__(self):  # bypass jit setup
            self.cfg = TrainerConfig(straggler_factor=3.0)
            self.straggler_events = events

    # simulate the EWMA logic inline (unit test of the detector math)
    ewma = None
    times = [0.1] * 10 + [1.0] + [0.1] * 5
    flagged = []
    for i, dt in enumerate(times):
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if i > 3 and dt > 3.0 * ewma:
            flagged.append(i)
    assert flagged == [10]
