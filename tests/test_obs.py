"""Telemetry layer tests (DESIGN.md §12): metrics primitives, sinks,
tracing, token-bucket quotas, per-tenant SLO attribution on the engine,
and probe-drift alarms under churn."""

import functools
import json
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset
from repro.obs import (
    JsonlSink,
    MetricsRegistry,
    ObsHub,
    PrometheusServer,
    Ring,
    TenantLedger,
    TenantQuota,
    TokenBucket,
    Tracer,
    render_prometheus,
)
from repro.serve.engine import QueryEngine
from repro.stream.mutable import MutableQuIVerIndex

jax.config.update("jax_platform_name", "cpu")

PARAMS = BuildParams(m=6, ef_construction=32, prune_pool=32, chunk=128)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@functools.lru_cache(maxsize=1)
def _index():
    base, queries = make_dataset("minilm-surrogate", n=800, queries=12)
    idx = QuIVerIndex.build(jnp.asarray(base), PARAMS)
    return idx, np.asarray(queries, np.float32)


# -- metrics primitives -----------------------------------------------------


def test_ring_is_bounded_and_percentile_works():
    r = Ring(4)
    for i in range(10):
        r.append(float(i))
    assert len(r) == 4 and r.maxlen == 4 and r.total == 10
    assert set(r.array()) == {6.0, 7.0, 8.0, 9.0}
    assert r.percentile(50) == pytest.approx(7.5)


def test_counter_gauge_histogram_series():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests", labels=("tenant",))
    c.inc(tenant="a")
    c.inc(2, tenant="b")
    assert c.value(tenant="a") == 1 and c.value(tenant="b") == 2
    g = reg.gauge("queue", "depth")
    g.set(7)
    g.add(-2)
    assert g.value() == 5
    h = reg.histogram("lat", "seconds", buckets=(0.1, 1.0, 10.0))
    h.observe_many([0.05, 0.5, 5.0, 50.0])
    snap = reg.snapshot()
    assert snap["req_total"]["tenant=a"] == 1
    assert snap["lat"][""]["count"] == 4


def test_registry_rejects_type_and_label_mismatch():
    reg = MetricsRegistry()
    reg.counter("x", "d")
    with pytest.raises(ValueError):
        reg.gauge("x", "d")
    reg.counter("y", "d", labels=("a",))
    with pytest.raises(ValueError):
        reg.counter("y", "d", labels=("b",))


def test_prometheus_rendering_and_endpoint():
    reg = MetricsRegistry()
    reg.counter("hits_total", "hits", labels=("route",)).inc(3, route="graph")
    reg.histogram("lat_seconds", "lat", buckets=(0.1, 1.0)).observe(0.5)
    text = render_prometheus(reg)
    assert 'hits_total{route="graph"} 3' in text
    assert 'lat_seconds_bucket{le="1"} 1' in text
    assert "lat_seconds_count 1" in text
    srv = PrometheusServer(reg, port=0)
    try:
        with urllib.request.urlopen(
            f"http://127.0.0.1:{srv.port}/metrics", timeout=5
        ) as resp:
            body = resp.read().decode()
        assert 'hits_total{route="graph"} 3' in body
    finally:
        srv.close()


def test_jsonl_sink_and_hub_emit(tmp_path):
    path = tmp_path / "obs.jsonl"
    reg = MetricsRegistry()
    hub = ObsHub(registry=reg, sinks=[JsonlSink(path)])
    reg.counter("n", "d").inc(5)
    hub.emit({"phase": "test"})
    hub.emit()
    hub.close()
    records = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len(records) == 2
    assert records[0]["phase"] == "test"
    assert records[0]["metrics"]["n"][""] == 5


def test_jsonl_sink_rotates_on_size(tmp_path):
    path = tmp_path / "obs.jsonl"
    # each record is ~40 bytes; cap at ~2 records per generation
    sink = JsonlSink(path, max_bytes=90, keep=2)
    for i in range(7):
        sink.emit({"seq": i, "pad": "x" * 16})
    sink.close()

    def lines(p):
        return [json.loads(ln) for ln in p.read_text().splitlines()]

    live = lines(path)
    gen1 = lines(path.with_name("obs.jsonl.1"))
    gen2 = lines(path.with_name("obs.jsonl.2"))
    # keep=2: no third generation, oldest records dropped
    assert not path.with_name("obs.jsonl.3").exists()
    # every line lands whole in exactly one generation, newest in path
    assert live and live[-1]["seq"] == 6
    seqs = [r["seq"] for r in gen2 + gen1 + live]
    assert seqs == sorted(seqs)                  # oldest -> newest order
    assert len(live) + len(gen1) + len(gen2) < 7  # something rotated out
    # generations respect the size cap
    for p in (path.with_name("obs.jsonl.1"), path.with_name("obs.jsonl.2")):
        assert p.stat().st_size <= 90


def test_jsonl_sink_rotation_disabled_by_default(tmp_path):
    path = tmp_path / "obs.jsonl"
    sink = JsonlSink(path)                       # max_bytes=0: unbounded
    for i in range(50):
        sink.emit({"seq": i})
    sink.close()
    assert len(path.read_text().splitlines()) == 50
    assert not path.with_name("obs.jsonl.1").exists()


def test_jsonl_sink_rejects_bad_keep(tmp_path):
    with pytest.raises(ValueError):
        JsonlSink(tmp_path / "x.jsonl", max_bytes=10, keep=0)


def test_tracer_spans_feed_stage_histogram():
    reg = MetricsRegistry()
    tr = Tracer(reg)
    with tr.span("launch", plan="p"):
        pass
    with tr.span("finalize"):
        pass
    rep = tr.report()
    assert rep["launch"]["count"] == 1
    assert rep["finalize"]["count"] == 1
    assert reg.snapshot()["quiver_stage_seconds"]["stage=launch"]["count"] == 1


# -- quotas and tenant accounting -------------------------------------------


def test_token_bucket_refill_semantics():
    clk = FakeClock()
    b = TokenBucket(TenantQuota(qps=2.0, burst=4), clk())
    assert all(b.take(1, clk()) for _ in range(4))   # burst drains
    assert not b.take(1, clk())                      # empty
    clk.t += 1.0                                     # +2 tokens
    assert b.take(2, clk()) and not b.take(1, clk())


def test_ledger_quota_isolation_and_attribution():
    clk = FakeClock()
    led = TenantLedger(clock=clk)
    led.set_quota("paid", qps=1.0, burst=2)
    # over-budget tenant exhausts only its own bucket
    assert led.admit("paid", 1) and led.admit("paid", 1)
    assert not led.admit("paid", 1)
    # unquota'd tenant is never rejected, regardless of paid's state
    for _ in range(50):
        assert led.admit("free", 1)
    led.observe("paid", status="done", latency=0.01)
    led.observe("free", status="dropped", latency=0.5, degraded=True)
    rep = led.report()
    assert rep["quota_violations"] == 0
    assert rep["tenants"]["paid"]["rejected"] == 1
    assert rep["tenants"]["free"]["rejected"] == 0
    assert rep["tenants"]["free"]["dropped"] == 1
    assert rep["tenants"]["free"]["degraded"] == 1
    assert rep["tenants"]["paid"]["p50_ms"] == pytest.approx(10.0)


# -- engine integration -----------------------------------------------------


def test_engine_quota_rejects_over_budget_without_starving_others():
    idx, queries = _index()
    clk = FakeClock()
    engine = QueryEngine(idx, default_k=5, default_ef=32, clock=clk)
    engine.set_quota("greedy", qps=1.0, burst=2)
    tickets = {"greedy": [], "modest": []}
    for i in range(6):
        tickets["greedy"].append(engine.submit(queries[i % 4],
                                               tenant="greedy"))
        tickets["modest"].append(engine.submit(queries[i % 4],
                                               tenant="modest"))
    engine.pump()
    rep = engine.tenants.report()
    # greedy burned its burst of 2, the rest rejected fast with -1 rows
    assert rep["tenants"]["greedy"]["rejected"] == 4
    assert rep["tenants"]["modest"]["rejected"] == 0
    assert rep["quota_violations"] == 0
    rejected = [t for t in tickets["greedy"]
                if engine.ticket(t).status == "rejected"]
    assert len(rejected) == 4
    ids, scores = engine.result(rejected[0])
    assert (ids == -1).all() and np.isneginf(scores).all()
    # every modest request completed normally
    assert all(engine.ticket(t).status == "done"
               for t in tickets["modest"])


def test_engine_attributes_degrades_and_drops_per_tenant():
    idx, queries = _index()
    clk = FakeClock()
    engine = QueryEngine(idx, default_k=5, default_ef=64,
                         latency_slack=1.0, clock=clk)
    # seed the latency model so the engine predicts 1s/launch
    engine.search(queries[:2])                     # warm + EWMA seed
    for p in list(engine._lat_ewma):
        engine._lat_ewma[p] = 1.0
    # hopeless deadline -> drop, attributed to its submitter
    t_drop = engine.submit(queries[0], tenant="dropper", deadline_ms=0.0)
    clk.t += 1.0
    engine.pump()
    assert engine.ticket(t_drop).status == "dropped"
    # tight-but-feasible deadline -> degraded ef, attributed likewise
    t_deg = engine.submit(queries[1], tenant="degrader", deadline_ms=500.0)
    engine.pump()
    assert engine.ticket(t_deg).status == "done"
    rep = engine.tenants.report()
    assert rep["tenants"]["dropper"]["dropped"] == 1
    assert rep["tenants"]["dropper"]["degraded"] == 0
    assert rep["tenants"]["degrader"]["dropped"] == 0
    assert rep["tenants"]["degrader"]["degraded"] == 1


def test_engine_report_and_span_lifecycle():
    idx, queries = _index()
    engine = QueryEngine(idx, default_k=5, default_ef=32)
    t = engine.submit(queries[:4], tenant="acme")
    engine.pump()
    engine.result(t)
    rep = engine.stats_report()
    assert rep["tenant_report"]["tenants"]["acme"]["done"] == 1
    stages = rep["span_report"]
    for stage in ("admission", "coalesce", "launch", "finalize",
                  "request", "window"):
        assert stages[stage]["count"] >= 1, f"no {stage} span recorded"
    assert rep["rejected"] == 0 and rep["latency_window"] > 0


def test_engine_stats_latencies_bounded():
    idx, queries = _index()
    engine = QueryEngine(idx, default_k=5, default_ef=32,
                         latency_window=8)
    for i in range(12):
        engine.search(queries[i % 8])
    assert len(engine.stats.latencies) == 8
    assert engine.stats.latencies.total == 12


# -- drift alarms -----------------------------------------------------------


def _collapsed(rng, n, dim):
    """Sign-collapsed vectors: every coordinate positive, so bit-plane
    entropy collapses toward 0 as they dominate the live set."""
    return np.abs(rng.normal(size=(n, dim))).astype(np.float32) + 3.0


def test_drift_monitor_quiet_on_green_churn():
    rng = np.random.default_rng(0)
    idx = MutableQuIVerIndex.empty(32, 512, PARAMS)
    mon = idx.attach_drift_monitor(tenant="t", min_n=32)
    for _ in range(4):
        idx.insert(rng.normal(size=(64, 32)).astype(np.float32))
    assert mon.band == "green"
    assert len(mon.events) == 0


def test_drift_monitor_alarms_on_incompatible_churn():
    rng = np.random.default_rng(0)
    reg = MetricsRegistry()
    idx = MutableQuIVerIndex.empty(32, 1024, PARAMS)
    mon = idx.attach_drift_monitor(tenant="drifty", min_n=32,
                                   registry=reg)
    good = idx.insert(rng.normal(size=(128, 32)).astype(np.float32))
    assert mon.band == "green" and not mon.events
    idx.insert(_collapsed(rng, 512, 32))
    idx.delete(good)                      # live set is now all-collapsed
    assert mon.band == "red"
    assert len(mon.events) >= 1
    ev = mon.events[-1]
    assert ev.tenant == "drifty" and ev.band == "red"
    assert "drifty" in ev.message()
    assert reg.counter(
        "quiver_drift_alarms_total", "probe-drift band alarms",
        labels=("tenant", "band"),
    ).value(tenant="drifty", band="red") >= 1


def test_drift_monitor_alarm_fires_once_per_crossing():
    rng = np.random.default_rng(1)
    idx = MutableQuIVerIndex.empty(32, 1024, PARAMS)
    mon = idx.attach_drift_monitor(tenant="t", min_n=32)
    idx.insert(_collapsed(rng, 256, 32))
    n_after_crossing = len(mon.events)
    assert n_after_crossing >= 1
    idx.insert(_collapsed(rng, 64, 32))   # still red: no re-alarm
    assert len(mon.events) == n_after_crossing


def test_mutation_metrics_recorded():
    from repro.obs.metrics import get_default_registry
    rng = np.random.default_rng(2)
    idx = MutableQuIVerIndex.empty(32, 256, PARAMS)
    before = get_default_registry().counter(
        "quiver_stream_mutations_total", "streaming mutations by kind",
        labels=("kind",),
    ).value(kind="insert")
    idx.insert(rng.normal(size=(32, 32)).astype(np.float32))
    after = get_default_registry().counter(
        "quiver_stream_mutations_total", "streaming mutations by kind",
        labels=("kind",),
    ).value(kind="insert")
    assert after - before == 32
