"""Graph X-ray tests (DESIGN.md §15): structural health reports,
medoid-BFS reachability, churn monotonicity, calibrated verdicts on the
surrogate tiers, navigation-path counters vs a host-side reference
walk, and the graph-health rung of the remediation ladder."""

import dataclasses
import functools
import io
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import beam
from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.data.datasets import contrastive_surrogate, make_dataset
from repro.obs import MetricsRegistry, RemediationPolicy
from repro.obs.graph import (
    DEFAULT_GRAPH_THRESHOLDS,
    GraphHealthMonitor,
    GraphHealthReport,
    GraphThresholds,
    graph_health_report,
)
from repro.serve.engine import QueryEngine
from repro.stream.mutable import MutableQuIVerIndex

jax.config.update("jax_platform_name", "cpu")

PARAMS = BuildParams(m=8, ef_construction=48, prune_pool=48, chunk=256)


@functools.lru_cache(maxsize=1)
def _minilm_index():
    base, queries = make_dataset("minilm-surrogate", n=800, queries=8)
    idx = QuIVerIndex.build(jnp.asarray(base), PARAMS)
    return idx, np.asarray(queries, np.float32)


@functools.lru_cache(maxsize=1)
def _siftlike_index():
    base, _ = make_dataset("sift-like", n=800, queries=4)
    return QuIVerIndex.build(jnp.asarray(base), PARAMS)


def _report_fields(rep):
    """to_dict minus NaN pitfalls (NaN != NaN breaks == comparison)."""
    d = rep.to_dict()
    if math.isnan(d["edge_agreement"]):
        d["edge_agreement"] = "nan"
    if isinstance(d.get("health_score"), float) and math.isnan(
            d["health_score"]):
        d["health_score"] = "nan"
    return d


# -- report: determinism + persistence --------------------------------------


def test_report_deterministic_and_npz_roundtrip():
    idx, _ = _minilm_index()
    kw = dict(
        medoid=int(idx.medoid), words=idx.sigs.words, dim=idx.sigs.dim,
        vectors=idx.vectors, sample=64, seed=3,
        registry=MetricsRegistry(),
    )
    r1 = graph_health_report(idx.adjacency, **kw)
    r2 = graph_health_report(idx.adjacency, **kw)
    assert _report_fields(r1) == _report_fields(r2)
    assert not math.isnan(r1.edge_agreement)   # vectors armed the probe

    buf = io.BytesIO()
    np.savez(buf, **r1.to_npz_fields())
    buf.seek(0)
    back = GraphHealthReport.from_npz(np.load(buf))
    assert _report_fields(back) == _report_fields(r1)
    assert back.thresholds == r1.thresholds
    # an archive without the fields reads None, not garbage
    buf2 = io.BytesIO()
    np.savez(buf2, unrelated=np.zeros(3))
    buf2.seek(0)
    assert GraphHealthReport.from_npz(np.load(buf2)) is None


def test_report_persists_through_index_save_load(tmp_path):
    idx, _ = _minilm_index()
    rep = idx.graph_report(sample=64)
    assert idx.graph_health is rep
    idx.save(tmp_path / "idx.npz")
    back = QuIVerIndex.load(tmp_path / "idx.npz")
    assert back.graph_health is not None
    assert back.graph_health.verdict == rep.verdict
    assert back.graph_health.health_score == pytest.approx(
        rep.health_score)
    mem = back.memory_breakdown()
    assert mem["graph_verdict"] == rep.verdict


# -- medoid BFS on a hand-built graph ---------------------------------------


def test_bfs_flags_disconnected_component_as_red():
    # two components: {0,1,2} cycle (holds the medoid) and {3,4}
    adj = np.array(
        [[1, 2], [2, 0], [0, 1], [4, -1], [3, -1]], np.int32)
    rep = graph_health_report(
        jnp.asarray(adj), medoid=0, registry=MetricsRegistry())
    assert rep.n_unreachable == 2
    assert rep.unreachable_frac == pytest.approx(0.4)
    assert rep.hop_max <= 2.0
    assert rep.verdict == "red"
    assert rep.worst_stat()[0] == "unreachable_frac"
    assert math.isnan(rep.edge_agreement)   # no vectors -> structural only

    # fully connected: every live row reached, hop radius == 1
    star = np.array([[1, 2, 3], [0, -1, -1], [0, -1, -1], [0, -1, -1]],
                    np.int32)
    rep2 = graph_health_report(
        jnp.asarray(star), medoid=0, registry=MetricsRegistry())
    assert rep2.n_unreachable == 0
    assert rep2.hop_max == 1.0


# -- churn monotonicity ------------------------------------------------------


def test_tombstone_density_degrades_health_monotonically():
    base = contrastive_surrogate(400, 64, seed=3)
    idx = MutableQuIVerIndex.empty(64, 1024, keep_vectors=True)
    idx.insert(jnp.asarray(base))
    reg = MetricsRegistry()
    reports = [idx.graph_report(sample=64, registry=reg)]
    for stop in (120, 300):           # 30% then 75% tombstones
        start = 0 if len(reports) == 1 else 120
        for i in range(start, stop):
            idx.delete(i)
        reports.append(idx.graph_report(sample=64, registry=reg))
    dens = [r.tombstone_density for r in reports]
    assert dens[0] < dens[1] < dens[2]
    assert dens[2] == pytest.approx(0.75)
    scores = [r.health_score for r in reports]
    assert scores[0] >= scores[1] >= scores[2]
    bands = [("green", "amber", "red").index(r.verdict) for r in reports]
    assert bands == sorted(bands)      # never improves under pure churn
    assert reports[2].verdict == "red"  # 0.75 > tombstone_red
    # heavy churn trips tombstone density, and often medoid
    # reachability with it — either is the honest red stat
    assert reports[2].worst_stat()[0] in (
        "tombstone_density", "unreachable_frac")


# -- calibrated verdicts on the surrogate tiers ------------------------------


def test_verdict_green_on_contrastive_red_on_sign_collapsed():
    idx, _ = _minilm_index()
    rep = idx.graph_report(sample=128)
    assert rep.verdict == "green", rep.summary()
    assert rep.n_unreachable == 0
    assert rep.edge_agreement > 0.65   # BQ ordering tracks f32 cosine
    assert rep.health_score > 0.5

    bad = _siftlike_index().graph_report(sample=128)
    # non-negative data collapses the sign plane: the graph this builds
    # contradicts its own metric space and must not read green
    assert bad.verdict == "red", bad.summary()
    assert bad.health_score < rep.health_score


# -- navigation-path counters vs a host-side reference walk ------------------


def _reference_walk(adj, dist, start, ef):
    """Host-side greedy best-first walk mirroring beam_search(expand=1):
    returns (hops, evals, stalls, best, final_beam_dists)."""
    beam_list = [(dist[start], start)]
    visited = {start}
    expanded = set()
    hops, evals, stalls = 0, 1, 0
    while True:
        frontier = [(d, u) for d, u in beam_list if u not in expanded]
        if not frontier:
            break
        prev_best = beam_list[0][0]
        _, u = min(frontier)
        expanded.add(u)
        for v in adj[u]:
            if v >= 0 and v not in visited:
                visited.add(v)
                evals += 1
                beam_list.append((dist[v], v))
        beam_list = sorted(beam_list)[:ef]
        if not beam_list[0][0] < prev_best:
            stalls += 1
        hops += 1
    return hops, evals, stalls, beam_list


def test_nav_counters_match_reference_walk():
    n, ef, target = 40, 8, 37
    adj = np.full((n, 3), -1, np.int32)
    for i in range(n):
        if i:
            adj[i, 0] = i - 1
        if i < n - 1:
            adj[i, 1] = i + 1
    adj[0, 2] = 7                      # shortcuts off the chain
    adj[10, 2] = 25
    # distinct distances (the id epsilon breaks |i - t| ties) so the
    # device and host walks cannot diverge on tie-breaking
    dist = (np.abs(np.arange(n) - target) +
            0.001 * np.arange(n)).astype(np.float32)

    def dist_fn(q, ids, valid):
        d = jnp.abs(ids.astype(jnp.float32) - q)
        return d + 0.001 * ids.astype(jnp.float32)

    res = beam.beam_search(
        jnp.float32(target), jnp.asarray(adj), jnp.int32(0),
        dist_fn=dist_fn, ef=ef, n=n,
    )
    hops, evals, stalls, ref_beam = _reference_walk(adj, dist, 0, ef)
    assert int(res.hops) == hops
    assert int(res.evals) == evals
    assert int(res.stalls) == stalls
    d0 = dist[0]
    assert float(res.descent) == pytest.approx(d0 - ref_beam[0][0],
                                               abs=1e-4)
    assert int(res.entry_rank) == sum(1 for d, _ in ref_beam if d < d0)
    # the walk actually descended the chain
    assert hops >= 10 and float(res.descent) > 30


def test_nav_traces_flow_into_tenant_report():
    from repro.obs import ObsHub
    idx, queries = _minilm_index()
    reg = MetricsRegistry()
    eng = QueryEngine(idx, default_k=4, default_ef=48,
                      obs=ObsHub(registry=reg))
    for q in queries[:8]:
        eng.submit(q[None])
    while eng.pump():
        pass
    nav = eng.tenants.report()["tenants"]["default"]["nav"]
    assert nav["hops"]["n"] == 8 and nav["hops"]["p50"] > 0
    assert nav["evals"]["p50"] > 0
    assert set(nav) == {"hops", "evals", "descent", "stalls",
                        "entry_rank"}
    # and the fleet histograms saw the same samples
    hist = {m.name: m for m in reg.metrics()}["quiver_nav_hops"]
    assert sum(s.count for s in hist.series().values()) == 8


# -- monitor + remediation ---------------------------------------------------


def _mk_report(**over):
    base = dict(
        n_live=100, n_allocated=100, degree_bound=16,
        out_degree_mean=8.0, in_degree_mean=8.0, saturation=0.1,
        reciprocity=0.2, n_unreachable=0, unreachable_frac=0.0,
        hop_p50=3.0, hop_p99=5.0, hop_max=6.0, tombstone_density=0.0,
        edge_agreement=0.8, n_sampled=64, agreement_k=8, seed=0,
    )
    base.update(over)
    return GraphHealthReport(**base)


def test_monitor_edge_triggers_on_worsening_only():
    mon = GraphHealthMonitor(registry=MetricsRegistry())
    assert mon.band is None
    assert mon.check(_mk_report()) is None            # arming green
    a1 = mon.check(_mk_report(tombstone_density=0.3))  # green -> amber
    assert a1 is not None and a1.band == "amber"
    assert a1.stat == "tombstone_density"
    assert mon.check(_mk_report(tombstone_density=0.35)) is None  # held
    a2 = mon.check(_mk_report(tombstone_density=0.7))  # amber -> red
    assert a2 is not None and a2.band == "red"
    assert mon.check(_mk_report()) is None             # recovery: silent
    a3 = mon.check(_mk_report(tombstone_density=0.3))  # crossing again
    assert a3 is not None and a3.band == "amber"
    assert len(mon.alarms) == 3
    assert mon.report()["band"] == "amber"


def test_remediation_walks_graph_ladder_once_per_crossing():
    base = contrastive_surrogate(200, 64, seed=5)
    idx = MutableQuIVerIndex.empty(64, 512, keep_vectors=True)
    idx.insert(jnp.asarray(base))
    reg = MetricsRegistry()
    eng = QueryEngine(idx, default_k=4, default_ef=16)
    pol = RemediationPolicy(eng, auto=False, registry=reg)
    mon = GraphHealthMonitor(registry=reg)
    pol.attach_graph(mon)

    mon.check(_mk_report())                            # healthy baseline
    mon.check(_mk_report(tombstone_density=0.3))       # -> amber
    mon.check(_mk_report(tombstone_density=0.35))      # held: no retrigger
    assert len(pol.triggers) == 1
    ev = pol.check()
    assert ev["action"] == "consolidate" and ev["trigger"] == "graph_health"
    assert pol.check() is None                         # queue drained

    mon.check(_mk_report(tombstone_density=0.7))       # amber -> red
    assert len(pol.triggers) == 1
    ev = pol.check()
    assert ev["action"] == "flag_red"
    assert ev.get("note") == "rebuild-through-probe"
    assert pol.flagged_red
    # once red-flagged the ladder stays parked at the bottom
    mon.check(_mk_report())                            # recover
    mon.check(_mk_report(tombstone_density=0.3))       # re-cross
    ev = pol.check()
    assert ev["action"] == "flag_red"
    assert ev.get("note") == "already red-flagged"


def test_health_verdicts_and_healthz():
    import json
    import urllib.error
    import urllib.request

    from repro.obs import PrometheusServer, health_snapshot

    idx, _ = _minilm_index()
    rep = idx.graph_report(sample=64)    # cached after first X-ray
    eng = QueryEngine(idx, default_k=4, default_ef=32)
    assert eng.health_verdicts() == {
        "graph": rep.verdict, "recall_slo": "green"}

    record, status = health_snapshot(eng.health_verdicts)
    assert status == 200 and record["verdict"] in ("green", "amber")

    srv = PrometheusServer(MetricsRegistry(), port=0,
                           health_fn=lambda: {"graph": "red"})
    try:
        with pytest.raises(urllib.error.HTTPError) as err:
            urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/healthz")
        assert err.value.code == 503
        assert json.loads(err.value.read())["verdict"] == "red"
    finally:
        srv.close()
