"""Filtered-search subsystem tests (DESIGN.md §9): predicate masks,
two-mask beam composition, selectivity routing, label persistence
across save/load/insert/consolidate/freeze, sharded pushdown, and the
filter=None bit-identity guard."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import flat_search, recall_at_k
from repro.core.beam import beam_search
from repro.core.index import QuIVerIndex, batch_bucket
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset
from repro.filter import (
    All,
    Any,
    LabelStore,
    Not,
    estimate_selectivity,
    eval_mask,
    pack_label_rows,
    route,
    widened_ef,
)
from repro.filter.labels import popcount_rows
from repro.stream import MutableQuIVerIndex, StreamingShardedIndex

jax.config.update("jax_platform_name", "cpu")

PARAMS = BuildParams(m=6, ef_construction=32, prune_pool=32, chunk=128)


@functools.lru_cache(maxsize=1)
def _data():
    base, queries = make_dataset("minilm-surrogate", n=2000, queries=25)
    return base, queries


@functools.lru_cache(maxsize=1)
def _labeled_index():
    """Built index + membership matrix at selectivities ~0.5/0.1/0.01."""
    base, queries = _data()
    rng = np.random.default_rng(0)
    member = np.stack(
        [rng.random(len(base)) < p for p in (0.5, 0.1, 0.01)], axis=1
    )
    rows = [np.nonzero(m)[0].tolist() for m in member]
    # the 5-pt acceptance bar tracks graph quality: the filtered path
    # needs the same build strength an unfiltered 95%-recall graph does
    build = BuildParams(m=8, ef_construction=64, prune_pool=64, chunk=128)
    idx = QuIVerIndex.build(jnp.asarray(base), build)
    idx.attach_labels(rows, n_labels=3)
    idx.build_label_entries(min_count=32)
    return idx, member


def _filtered_gt(base, queries, mask, k=10):
    match = np.nonzero(mask)[0]
    gt_pos, _ = flat_search(base[match], queries, k=k)
    return match[gt_pos]


# -- predicate compilation + selectivity ------------------------------------


def test_pack_and_eval_mask_roundtrip():
    rows = [[0], [1, 33], [], [0, 1, 33]]
    words = pack_label_rows(rows, n_labels=40)
    assert words.shape == (4, 2)
    got = np.asarray(eval_mask(jnp.asarray(words), Any(33)))
    np.testing.assert_array_equal(got, [False, True, False, True])
    got = np.asarray(eval_mask(jnp.asarray(words), All(1, 33)))
    np.testing.assert_array_equal(got, [False, True, False, True])
    got = np.asarray(eval_mask(jnp.asarray(words), Not(0)))
    np.testing.assert_array_equal(got, [False, True, True, False])
    got = np.asarray(
        eval_mask(jnp.asarray(words), All(Any(0, 1), Not(33)))
    )
    np.testing.assert_array_equal(got, [True, False, False, False])


def test_predicate_validation_and_selectivity_bounds():
    with pytest.raises(ValueError, match="outside"):
        LabelStore(8, 4).mask(7)
    with pytest.raises(TypeError):
        from repro.filter import as_predicate
        as_predicate("tenant-a")
    counts = {0: 50, 1: 10, 2: 1}
    cf = counts.get
    assert estimate_selectivity(0, cf, 100) == 0.5
    assert estimate_selectivity(Any(0, 1), cf, 100) == pytest.approx(0.6)
    assert estimate_selectivity(All(0, 1), cf, 100) == pytest.approx(0.1)
    assert estimate_selectivity(Not(0), cf, 100) == pytest.approx(0.5)
    assert route(0.5, 0.05) == "graph"
    assert route(0.01, 0.05) == "brute"
    assert widened_ef(64, 0.1, 0.05, 10_000) == 640
    assert widened_ef(64, 0.01, 0.05, 10_000) == 1280   # clamped @ floor
    # quantized to integer multiples of ef: continuous widening would
    # retrace the statically-keyed beam on every selectivity drift
    assert widened_ef(64, 0.9, 0.05, 10_000) == 128
    assert widened_ef(64, 0.34, 0.05, 10_000) == 192
    assert widened_ef(64, 0.1, 0.05, 300) == 300        # capped at n
    assert widened_ef(64, 1.0, 0.05, 8) == 64           # never below ef


def test_label_store_attach_modes_and_counts():
    store = LabelStore(16, 5)
    store.set(np.arange(8), 2)                     # categorical broadcast
    assert store.count(2) == 8
    store.add([0, 1], [[3], [3, 4]])               # multi-tag OR
    assert store.labels_of(0) == [2, 3]
    assert store.labels_of(1) == [2, 3, 4]
    store.set([0], [1])                            # overwrite
    assert store.labels_of(0) == [1]
    store.clear([1])
    assert store.labels_of(1) == []
    assert store.count(2) == 6
    # duplicate ids in one batch OR together, not last-one-wins
    store.add([5, 5], [[3], [4]])
    assert store.labels_of(5) == [2, 3, 4]
    # incremental counts stay exact through every mutation mode
    fresh = popcount_rows(np.asarray(store.words), store.n_labels)
    np.testing.assert_array_equal(store.counts, fresh)


# -- two-mask beam composition ----------------------------------------------


def test_beam_result_valid_all_true_is_bit_identical():
    base, queries = _data()
    idx, _ = _labeled_index()
    n = idx.sigs.words.shape[0]
    backend = idx.backend()
    q = backend.encode_queries(jnp.asarray(queries[:1]))[0]
    plain = beam_search(
        q, idx.adjacency, jnp.int32(idx.medoid),
        dist_fn=backend.dist_fn, ef=16, n=n,
    )
    masked = beam_search(
        q, idx.adjacency, jnp.int32(idx.medoid),
        dist_fn=backend.dist_fn, ef=16, n=n,
        result_valid=jnp.ones((n,), jnp.bool_),
    )
    np.testing.assert_array_equal(np.asarray(plain.ids),
                                  np.asarray(masked.ids))
    np.testing.assert_array_equal(np.asarray(plain.dists),
                                  np.asarray(masked.dists))


def test_beam_two_masks_conjoin():
    """node_valid ∧ result_valid: a node failing either never surfaces,
    but both kinds of masked nodes still route navigation."""
    idx, member = _labeled_index()
    n = idx.sigs.words.shape[0]
    backend = idx.backend()
    _, queries = _data()
    q = backend.encode_queries(jnp.asarray(queries[:1]))[0]
    rng = np.random.default_rng(3)
    node_valid = jnp.asarray(rng.random(n) > 0.3)
    result_valid = jnp.asarray(member[:, 0])
    res = beam_search(
        q, idx.adjacency, jnp.int32(idx.medoid),
        dist_fn=backend.dist_fn, ef=32, n=n,
        node_valid=node_valid, result_valid=result_valid,
    )
    ids = np.asarray(res.ids)
    ids = ids[ids >= 0]
    both = np.asarray(node_valid & result_valid)
    assert ids.size > 0
    assert both[ids].all()


# -- frozen-index filtered search -------------------------------------------


@pytest.mark.parametrize("label,floor_recall", [(0, 0.95), (1, 0.95)])
def test_filtered_recall_within_5pts(label, floor_recall):
    """Acceptance: filtered recall@10 within 5 points of exact filtered
    ground truth at selectivity ~0.5 and ~0.1 (graph route)."""
    base, queries = _data()
    idx, member = _labeled_index()
    gt = _filtered_gt(base, queries, member[:, label])
    pred, scores = idx.search(jnp.asarray(queries), k=10, ef=64,
                              filter=label)
    rec = recall_at_k(pred, gt)
    assert rec >= floor_recall, (label, rec)
    # every returned id matches the predicate
    ok = pred[pred >= 0]
    assert member[ok, label].all()
    # reranked scores are cosine similarities
    assert (scores[np.isfinite(scores)] <= 1.0 + 1e-5).all()


def test_filtered_brute_route_is_exact():
    """Below the selectivity floor the match set is brute-forced:
    recall is exactly 1 against filtered ground truth."""
    base, queries = _data()
    idx, member = _labeled_index()
    mask = member[:, 2]                     # ~1% selectivity
    k = min(10, int(mask.sum()))
    gt = _filtered_gt(base, queries, mask, k=k)
    pred, _ = idx.search(jnp.asarray(queries), k=k, ef=64, filter=2)
    assert recall_at_k(pred[:, :k], gt) == 1.0
    assert member[pred[pred >= 0], 2].all()


def test_filtered_brute_route_k_larger_than_match_set():
    """k above the match count (and above the pad width) must return
    -1/-inf tails, not crash top_k (regression)."""
    base, queries = _data()
    idx, member = _labeled_index()
    n_match = int(member[:, 2].sum())
    pred, scores = idx.search(jnp.asarray(queries), k=100, ef=64,
                              filter=2)
    assert pred.shape == (len(queries), 100)
    valid = pred >= 0
    assert valid.sum(axis=1).max() <= n_match
    assert (pred[~valid] == -1).all()
    assert np.isneginf(scores[~valid]).all()


def test_not_of_union_estimate_cannot_force_giant_brute_scan():
    """Not(Any(a, b)) over overlapping popular labels *estimates* below
    the floor (complement of a union bound) but truly matches ~half the
    corpus: the exact-popcount guard must reroute it to graph search
    (regression — the old code materialized the huge match set)."""
    base, queries = _data()
    n = len(base)
    rng = np.random.default_rng(8)
    both = rng.random(n) < 0.5                   # a and b coincide
    rows = [[0, 1] if b else [] for b in both]
    idx = QuIVerIndex.build(jnp.asarray(base), PARAMS)
    idx.attach_labels(rows, n_labels=2)
    expr = Not(Any(0, 1))
    cf = idx.labels.count_fn()
    assert estimate_selectivity(expr, cf, n) < 0.05   # the bad bound
    pred, _ = idx.search(jnp.asarray(queries), k=10, ef=48, filter=expr)
    ok = pred[pred >= 0]
    assert ok.size > 0
    assert (~both[ok]).all()


def test_filtered_search_small_live_set_does_not_shrink_beam():
    """A filtered search over fewer live docs than ef/k must not clamp
    the beam below k (regression: widened_ef returned n_live=8 and
    top_k crashed)."""
    rng = np.random.default_rng(11)
    docs = rng.standard_normal((8, 24)).astype(np.float32)
    mut = MutableQuIVerIndex.empty(
        24, 64,
        BuildParams(m=2, ef_construction=8, prune_pool=8, chunk=128),
        n_labels=2,
    )
    mut.insert(jnp.asarray(docs), labels=[0] * 8)
    ids, scores = mut.search(jnp.asarray(docs[:2]), k=10, ef=64,
                             filter=0)       # used to crash in top_k
    assert ids.shape == (2, 10)
    valid = ids >= 0
    assert valid[:, 0].all()                 # found live matches
    assert valid.sum(axis=1).max() <= 8      # never more than live
    assert np.isneginf(scores[~valid]).all()


def test_delete_clears_label_bits_for_routing():
    """Deleting most of a label's members must drop its popcount so
    selectivity routing sees live counts, not dead-inflated ones
    (regression)."""
    base, _ = _data()
    mut = MutableQuIVerIndex.empty(base.shape[-1], 800, PARAMS,
                                   n_labels=2)
    ids = mut.insert(jnp.asarray(base[:500]),
                     labels=[1] * 100 + [0] * 400)
    assert mut.labels.count(1) == 100
    mut.delete(ids[:95])                          # kill 95% of label 1
    assert mut.labels.count(1) == 5
    cf = mut.labels.count_fn()
    assert estimate_selectivity(1, cf, mut.n_live) < 0.05


def test_filtered_composite_predicates_only_match():
    base, queries = _data()
    idx, member = _labeled_index()
    expr = All(0, Not(1))
    want = member[:, 0] & ~member[:, 1]
    pred, _ = idx.search(jnp.asarray(queries), k=10, ef=64, filter=expr)
    ok = pred[pred >= 0]
    assert ok.size > 0
    assert want[ok].all()


def test_filter_none_matches_all_true_predicate_and_per_query():
    """filter=None takes the unmasked beam path; an all-matching
    predicate and per-query batching must agree with it exactly."""
    base, queries = _data()
    idx, member = _labeled_index()
    i0, s0 = idx.search(jnp.asarray(queries), k=10, ef=48)
    # tail padding: searching in odd-sized slices hits different pad
    # buckets but must return identical per-query results
    i1a, s1a = idx.search(jnp.asarray(queries[:7]), k=10, ef=48)
    i1b, s1b = idx.search(jnp.asarray(queries[7:]), k=10, ef=48)
    np.testing.assert_array_equal(i0, np.concatenate([i1a, i1b]))
    np.testing.assert_array_equal(s0, np.concatenate([s1a, s1b]))
    # an always-true predicate returns the same ids: estimated
    # selectivity 1.0 keeps ef unwidened, and with per-label entries
    # disabled the start is the medoid, so the only difference is the
    # all-valid masked beam — bit-identical by construction
    saved_entries = idx.labels.entries.copy()
    idx.labels.entries[:] = -1
    try:
        i2, _ = idx.search(jnp.asarray(queries), k=10, ef=48,
                           filter=Any(0, Not(0)))
        np.testing.assert_array_equal(i0, i2)
    finally:
        idx.labels.entries[:] = saved_entries


def test_batch_bucket_ladder():
    assert batch_bucket(1, 256) == 8
    assert batch_bucket(8, 256) == 8
    assert batch_bucket(25, 256) == 32
    assert batch_bucket(129, 256) == 256
    assert batch_bucket(256, 256) == 256
    assert batch_bucket(40, 32) == 32     # never exceeds query_batch


def test_label_entries_route_start_into_region():
    idx, member = _labeled_index()
    assert (idx.labels.entries[:2] >= 0).all()   # frequent labels
    assert idx.labels.entries[2] == -1           # rare label: none
    for lb in (0, 1):
        assert member[idx.labels.entries[lb], lb]


def test_labels_survive_index_save_load(tmp_path):
    base, queries = _data()
    idx, member = _labeled_index()
    p = str(tmp_path / "labeled.npz")
    idx.save(p)
    idx2 = QuIVerIndex.load(p)
    assert idx2.labels is not None
    assert idx2.labels.n_labels == 3
    np.testing.assert_array_equal(idx2.labels.entries, idx.labels.entries)
    a, _ = idx.search(jnp.asarray(queries), k=10, ef=48, filter=Any(0, 1))
    b, _ = idx2.search(jnp.asarray(queries), k=10, ef=48,
                       filter=Any(0, 1))
    np.testing.assert_array_equal(a, b)
    mem = idx2.memory_breakdown()
    assert mem["hot_label_bytes"] > 0
    assert mem["hot_label_bytes"] <= mem["hot_total_bytes"]


# -- mutable index: streaming labels + tombstone composition ----------------


def test_streaming_insert_labels_and_tombstone_composition():
    base, queries = _data()
    rng = np.random.default_rng(1)
    labels = rng.integers(0, 4, 1200)
    mut = MutableQuIVerIndex.empty(
        base.shape[-1], 2000, PARAMS, n_labels=4
    )
    mut.insert(jnp.asarray(base[:1200]), labels=list(labels))
    mask0 = np.asarray(mut.labels.mask(0)) & mut.live
    kill = np.nonzero(mask0)[0][:120]
    mut.delete(kill)

    pred, _ = mut.search(jnp.asarray(queries), k=10, ef=48, filter=0)
    ok = pred[pred >= 0]
    assert ok.size > 0
    assert not np.isin(ok, kill).any()           # no tombstones
    live_match = np.asarray(mut.labels.mask(0)) & mut.live
    assert live_match[ok].all()                  # only live matches

    # recall against live filtered ground truth (an insert-built m=6
    # graph is weaker than a batch build — this guards composition
    # correctness, not peak recall, which test_filtered_recall_within_
    # 5pts pins on the batch-built index)
    match = np.nonzero(live_match)[0]
    gt_pos, _ = flat_search(base[match], queries, k=10)
    gt = match[gt_pos]
    assert recall_at_k(pred, gt) >= 0.75


def test_streaming_labels_survive_consolidate_and_reuse():
    base, _ = _data()
    rng = np.random.default_rng(2)
    labels = rng.integers(0, 3, 600)
    mut = MutableQuIVerIndex.empty(
        base.shape[-1], 1000, PARAMS, n_labels=3
    )
    ids = mut.insert(jnp.asarray(base[:600]), labels=list(labels))
    dead = ids[100:200]
    mut.delete(dead)
    mut.consolidate()
    # reclaimed slots lost their labels...
    assert all(mut.labels.labels_of(int(i)) == [] for i in dead[:10])
    # ...and a label-less reinsert into them stays clean
    new_ids = mut.insert(jnp.asarray(base[600:700]))
    assert np.isin(new_ids, dead).all()
    assert all(mut.labels.labels_of(int(i)) == [] for i in new_ids[:10])
    # live nodes kept their labels
    keep = ids[:100]
    for i in keep[:10]:
        assert mut.labels.labels_of(int(i)) == [int(labels[int(i)])]


def test_streaming_labels_save_load_and_freeze(tmp_path):
    base, queries = _data()
    rng = np.random.default_rng(3)
    labels = rng.integers(0, 3, 500)
    mut = MutableQuIVerIndex.empty(
        base.shape[-1], 800, PARAMS, n_labels=3
    )
    mut.insert(jnp.asarray(base[:500]), labels=list(labels))
    mut.delete(np.arange(0, 50))
    mut.build_label_entries(min_count=16)

    p = str(tmp_path / "stream_labeled.npz")
    mut.save(p)
    mut2 = MutableQuIVerIndex.load(p)
    assert mut2.labels is not None
    a, _ = mut.search(jnp.asarray(queries), k=5, ef=32, filter=1)
    b, _ = mut2.search(jnp.asarray(queries), k=5, ef=32, filter=1)
    np.testing.assert_array_equal(a, b)

    # freeze compacts the store and keeps filtered search consistent
    frozen = mut.freeze()
    assert frozen.labels.words.shape[0] == mut.n_live
    fi, _ = frozen.search(jnp.asarray(queries), k=5, ef=32, filter=1)
    fmask = np.asarray(frozen.labels.mask(1))
    ok = fi[fi >= 0]
    assert ok.size > 0 and fmask[ok].all()
    # adoption keeps labels too
    mut3 = MutableQuIVerIndex.from_index(frozen)
    assert mut3.labels is not None and mut3.labels.n_labels == 3
    c, _ = mut3.search(jnp.asarray(queries), k=5, ef=32, filter=1)
    ok3 = c[c >= 0]
    assert ok3.size > 0 and fmask[ok3].all()


def test_insert_labels_without_store_raises():
    mut = MutableQuIVerIndex.empty(32, 64, PARAMS)
    with pytest.raises(ValueError, match="enable_labels"):
        mut.insert(np.ones((2, 32), np.float32), labels=[0, 1])
    with pytest.raises(ValueError, match="filtered search"):
        mut.insert(np.ones((2, 32), np.float32))
        mut.search(np.ones((1, 32), np.float32), k=2, filter=0)


# -- sharded: predicate pushdown --------------------------------------------


def test_sharded_streaming_filter_pushdown_single_device():
    base, queries = _data()
    rng = np.random.default_rng(4)
    labels = rng.integers(0, 3, 800)
    idx = StreamingShardedIndex.empty(
        base.shape[-1], n_shards=1, capacity_per_shard=1200,
        params=PARAMS, n_labels=3,
    )
    gids = idx.insert(base[:800], labels=list(labels))
    kill = gids[:100]
    idx.delete(kill)
    idx.build_label_entries(min_count=16)

    ids, _ = idx.search(queries, ef=48, k=10, filter=Any(0, 2))
    ok = ids[ids >= 0]
    assert ok.size > 0
    assert not np.isin(ok, kill).any()
    glab = {int(g): int(labels[i]) for i, g in enumerate(gids)}
    assert all(glab[int(g)] in (0, 2) for g in ok)

    # unfiltered search on the same snapshot still works
    ids_u, _ = idx.search(queries, ef=48, k=10)
    assert not np.isin(ids_u[ids_u >= 0], kill).any()


def test_build_sharded_with_labels_filtered_search():
    from repro.core.distributed import build_sharded, search_sharded

    base, queries = _data()
    rng = np.random.default_rng(5)
    labels = rng.integers(0, 2, 900)
    idx = build_sharded(
        base[:900], 1,
        BuildParams(m=4, ef_construction=24, prune_pool=24, chunk=128),
        labels=list(labels), label_entry_min=16,
    )
    assert idx.label_words is not None and idx.n_labels == 2
    ids, _ = search_sharded(idx, queries, ef=48, k=10, filter=1)
    ok = ids[ids >= 0]
    assert ok.size > 0
    assert (labels[ok] == 1).all()
    gt = _filtered_gt(base[:900], queries, labels == 1)
    assert recall_at_k(ids, gt) >= 0.85
    with pytest.raises(ValueError, match="label_words"):
        search_sharded(
            build_sharded(base[:300], 1, PARAMS), queries, k=5, filter=0
        )


# -- retriever: metadata-filtered RAG ---------------------------------------


def test_retriever_filtered_rag():
    from repro.serve.engine import Retriever

    rng = np.random.default_rng(6)
    docs = rng.standard_normal((40, 24)).astype(np.float32)
    docs /= np.linalg.norm(docs, axis=-1, keepdims=True)
    lang = rng.integers(0, 2, 40)                # 0 = "en", 1 = "de"
    idx = MutableQuIVerIndex.empty(
        24, 64,
        BuildParams(m=2, ef_construction=8, prune_pool=8, chunk=128),
        n_labels=2,
    )
    idx.insert(jnp.asarray(docs), labels=list(lang))
    doc_tokens = (
        np.arange(40 * 3, dtype=np.int32).reshape(40, 3) + 100
    )
    store = {}

    def embed(tokens):
        return jnp.asarray(
            np.stack([store[tuple(t)] for t in np.asarray(tokens)])
        )

    r = Retriever(index=idx, doc_tokens=doc_tokens, embed_fn=embed,
                  k=3, ef=32, filter=1)
    probe = np.zeros((1, 3), np.int32)
    store[tuple(probe[0])] = docs[int(np.nonzero(lang == 0)[0][0])]
    out = r.augment(probe)
    ctx = out[0, : 3 * 3].reshape(3, 3)
    # every retrieved document is language 1, even though the probe
    # embedding sits on a language-0 document
    for row in ctx:
        if (row == 0).all():
            continue                             # pad slot
        doc_id = int(row[0] - 100) // 3
        assert lang[doc_id] == 1
    # per-call override beats the configured filter
    out0 = r.augment(probe, filter=0)
    row0 = out0[0, :3]
    assert lang[int(row0[0] - 100) // 3] == 0

    # add_documents carries labels through
    new_tokens = np.arange(300, 306, dtype=np.int32).reshape(2, 3)
    new_ids = r.add_documents(
        new_tokens, embeddings=docs[:2] * -1.0, labels=[1, 1]
    )
    assert all(idx.labels.labels_of(int(i)) == [1] for i in new_ids)
