"""Compiled query-plan tests (DESIGN.md §11): QueryPlan key semantics,
PlanCache compile-once identity, derived escalation/degradation stages,
steady-state zero-retrace, and plan stability across save/load/freeze."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset
from repro.plan import QueryPlan, resolve_plan, trace
from repro.plan.plan import PlanContext
from repro.stream import MutableQuIVerIndex

jax.config.update("jax_platform_name", "cpu")

PARAMS = BuildParams(m=6, ef_construction=32, prune_pool=32, chunk=128)


@functools.lru_cache(maxsize=1)
def _index():
    base, queries = make_dataset("minilm-surrogate", n=800, queries=12)
    idx = QuIVerIndex.build(jnp.asarray(base), PARAMS)
    rng = np.random.default_rng(0)
    member = np.stack(
        [rng.random(len(base)) < p for p in (0.5, 0.01)], axis=1
    )
    idx.attach_labels(
        [np.nonzero(m)[0].tolist() for m in member], n_labels=2
    )
    idx.build_label_entries(min_count=32)
    return idx, np.asarray(queries, np.float32)


# -- plan key semantics -----------------------------------------------------


def test_plan_equality_hash_roundtrip():
    a = QueryPlan(nav="bq2", k=10, ef=64)
    b = QueryPlan(nav="bq2", k=10, ef=64)
    assert a == b and hash(a) == hash(b)
    assert {a: "prog"}[b] == "prog"
    assert a != QueryPlan(nav="bq2", k=10, ef=128)
    assert a != QueryPlan(nav="adc", k=10, ef=64)
    assert a.signature() == b.signature()
    assert a.signature() != QueryPlan(nav="bq2", k=10, ef=128).signature()


def test_plan_validation():
    with pytest.raises(ValueError):
        QueryPlan(nav="bq2", k=10, ef=64, route="teleport")
    with pytest.raises(ValueError):
        QueryPlan(nav="bq2", k=10, ef=4)           # graph needs ef >= k
    with pytest.raises(ValueError):
        QueryPlan(nav="bq2", k=10, ef=64, expand=65)
    # brute plans don't constrain ef
    QueryPlan(nav="bq2", k=10, ef=4, route="brute")


def test_derived_stages_closed_set():
    p = QueryPlan(nav="bq2", k=10, ef=64, adaptive=True, escalate_mult=4)
    esc = p.escalated()
    assert esc.ef == 256 and not esc.adaptive
    assert esc == p.escalated()                    # derived plans re-key
    ladder = [p]
    while ladder[-1].can_degrade():
        ladder.append(ladder[-1].degraded())
    assert [q.ef for q in ladder] == [64, 32, 16]
    assert ladder[-1].ef >= ladder[-1].min_ef
    assert not ladder[-1].can_degrade()
    assert ladder[-1].degraded() == ladder[-1]     # floor is a fixpoint
    brute = QueryPlan(nav="bq2", k=10, ef=64, route="brute")
    assert not brute.can_degrade()                 # exact: nothing to give


# -- resolve + cache identity -----------------------------------------------


def test_same_config_same_cached_executable():
    idx, _ = _index()
    p1, _ = resolve_plan(idx, k=10, ef=64)
    p2, _ = resolve_plan(idx, k=10, ef=64)
    assert p1 == p2
    assert idx.plans.program(p1) is idx.plans.program(p2)
    p3, _ = resolve_plan(idx, k=10, ef=48)
    assert idx.plans.program(p3) is not idx.plans.program(p1)


def test_same_selectivity_band_same_plan():
    idx, _ = _index()
    # label 0 (selectivity ~0.5, graph route): two resolutions land on
    # the same quantized widening -> hash-identical plan
    pa, ca = resolve_plan(idx, k=10, ef=64, filter=0)
    pb, cb = resolve_plan(idx, k=10, ef=64, filter=0)
    assert pa.route == "graph" and pa.filtered
    assert pa == pb and hash(pa) == hash(pb)
    assert idx.plans.program(pa) is idx.plans.program(pb)
    assert ca.start == cb.start
    # label 1 (selectivity ~0.01): routes to brute with the exact
    # match set materialized in the context
    pc, cc = resolve_plan(idx, k=10, ef=64, filter=1)
    assert pc.route == "brute" and not pc.filtered
    assert cc.match_ids is not None and len(cc.match_ids) > 0
    assert cc.selectivity < 0.05


def test_search_lowers_to_plan_run():
    idx, queries = _index()
    ids_a, sc_a = idx.search(jnp.asarray(queries), k=10, ef=48)
    plan, ctx = resolve_plan(idx, k=10, ef=48)
    ids_b, sc_b = idx.plans.run(plan, ctx, jnp.asarray(queries))
    np.testing.assert_array_equal(np.asarray(ids_a), ids_b)
    np.testing.assert_allclose(np.asarray(sc_a), sc_b, rtol=1e-6)


# -- steady-state retraces --------------------------------------------------


def test_steady_state_zero_retraces():
    idx, queries = _index()
    plan, ctx = resolve_plan(idx, k=10, ef=64)
    idx.plans.warmup(plan, buckets=(8, 32))
    misses_before = idx.plans.misses
    # warmed shapes: repeated traffic at any size inside the warmed
    # buckets must never re-lower (and never count as a cache miss —
    # warmup itself is excluded from the hit/miss stats)
    with trace.assert_no_retrace(idx.plans.trace_prefix(),
                                 "steady-state search"):
        for nq in (1, 3, 8, 12, 5, 1, 12):
            idx.plans.run(plan, ctx, jnp.asarray(queries[:nq]))
    assert idx.plans.report()["retraces"] == 0
    assert idx.plans.misses == misses_before


def test_warmup_compiles_escalation_stage():
    idx, queries = _index()
    plan, ctx = resolve_plan(idx, k=10, ef=16, adaptive=True)
    assert plan.adaptive
    idx.plans.warmup(plan, buckets=(8, 32))
    assert plan.escalated() in idx.plans._programs
    with trace.assert_no_retrace(idx.plans.trace_prefix(),
                                 "adaptive two-stage search"):
        idx.plans.run(plan, ctx, jnp.asarray(queries))


# -- persistence ------------------------------------------------------------


def test_plan_stable_across_save_load_freeze(tmp_path):
    idx, _ = _index()
    plan, ctx = resolve_plan(idx, k=10, ef=64, filter=0)

    path = str(tmp_path / "planned.npz")
    idx.save(path)
    loaded = QuIVerIndex.load(path)
    plan_l, ctx_l = resolve_plan(loaded, k=10, ef=64, filter=0)
    assert plan_l == plan and hash(plan_l) == hash(plan)
    assert ctx_l.start == ctx.start

    frozen = MutableQuIVerIndex.from_index(idx).freeze()
    plan_f, ctx_f = resolve_plan(frozen, k=10, ef=64, filter=0)
    assert plan_f == plan
    assert ctx_f.start == ctx.start
    # each index owns its own cache (compiled executables never
    # persist; plans re-derive and recompile on first use)
    assert loaded.plans is not idx.plans
    ids_a, _ = idx.plans.run(plan, ctx, jnp.zeros((2, idx.sigs.dim)))
    ids_b, _ = loaded.plans.run(plan_l, ctx_l,
                                jnp.zeros((2, idx.sigs.dim)))
    np.testing.assert_array_equal(ids_a, ids_b)


def test_plan_context_defaults():
    ctx = PlanContext()
    assert ctx.start == 0
    assert ctx.result_valid is None and ctx.match_ids is None
