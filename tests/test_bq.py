"""Unit + property tests for the 2-bit Sign-Magnitude BQ core.

``hypothesis`` is an optional test dependency: when it is installed the
property tests fuzz their (dim, seed) inputs; without it they fall back
to a deterministic sample of draws so the suite still runs everywhere.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, no hypothesis installed
    def settings(**_kw):
        return lambda f: f

    class st:  # noqa: N801 - mimics hypothesis.strategies
        @staticmethod
        def integers(min_value=0, max_value=0):
            return (min_value, max_value)

    def given(**strategies):
        def deco(f):
            # plain zero-arg wrapper (no functools.wraps: pytest would
            # read the wrapped signature and hunt for fixtures)
            def run():
                rng = np.random.default_rng(0)
                for _ in range(10):
                    f(**{
                        k: int(rng.integers(lo, hi + 1))
                        for k, (lo, hi) in strategies.items()
                    })
            run.__name__ = f.__name__
            run.__doc__ = f.__doc__
            return run
        return deco

from repro.core import bq

jax.config.update("jax_platform_name", "cpu")


def _semantic_similarity(a: np.ndarray, b: np.ndarray) -> int:
    """Straight-from-Table-1 similarity computed dimension by dimension."""
    ta, tb = np.abs(a).mean(), np.abs(b).mean()
    sim = 0
    for x, y in zip(a, b):
        same = (x > 0) == (y > 0)
        sa, sb = abs(x) > ta, abs(y) > tb
        if sa and sb:
            w = 4
        elif sa or sb:
            w = 2
        else:
            w = 1
        sim += w if same else -w
    return sim


@pytest.mark.parametrize("dim", [7, 32, 100, 384, 768, 1536])
def test_pack_unpack_roundtrip(dim):
    rng = np.random.default_rng(0)
    bits = jnp.asarray(rng.random((5, dim)) > 0.5)
    words = bq.pack_bits(bits)
    assert words.shape == (5, bq.n_words(dim))
    out = bq.unpack_bits(words, dim)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(bits))


@pytest.mark.parametrize("dim", [16, 33, 100, 384])
def test_symmetric_distance_matches_semantic_oracle(dim):
    rng = np.random.default_rng(1)
    a = rng.standard_normal((4, dim)).astype(np.float32)
    b = rng.standard_normal((6, dim)).astype(np.float32)
    sig_a, sig_b = bq.encode(jnp.asarray(a)), bq.encode(jnp.asarray(b))
    d = np.asarray(bq.pairwise_distance(sig_a, sig_b))
    for i in range(4):
        for j in range(6):
            assert d[i, j] == -_semantic_similarity(a[i], b[j]), (i, j)


def test_distance_symmetry_and_self_similarity():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((8, 96)).astype(np.float32))
    sig = bq.encode(x)
    d = np.asarray(bq.pairwise_distance(sig, sig))
    np.testing.assert_array_equal(d, d.T)
    # self-distance is the (negated) max self-similarity for that vector
    # and must be the row minimum (no other vector can agree better).
    assert (np.diag(d)[:, None] <= d).all()


def test_signature_memory_is_d_over_4_bytes():
    # 12:1 compression vs float32 when D % 32 == 0 (paper §3.1).
    for d in (384, 768, 1536):
        assert bq.signature_bytes(1, d) == d // 4
        assert 4 * d / bq.signature_bytes(1, d) == 16.0  # vs f32: 16x bytes
    # paper's "12:1" counts the 2-bit code vs 24 bits effective — our
    # physical layout is exactly 2 bits/dim:
    assert bq.signature_bytes(1_000_000, 768) == 192_000_000  # 192 MB (Table 2)


def test_hamming_1bit_matches_sign_disagreement():
    rng = np.random.default_rng(3)
    a = rng.standard_normal((3, 130)).astype(np.float32)
    b = rng.standard_normal((5, 130)).astype(np.float32)
    sa, sb = bq.encode(jnp.asarray(a)), bq.encode(jnp.asarray(b))
    d = np.asarray(bq.pairwise_hamming_1bit(sa, sb))
    expect = ((a[:, None, :] > 0) != (b[None, :, :] > 0)).sum(-1)
    np.testing.assert_array_equal(d, expect)


def test_adc_distance_orders_by_decoded_dot():
    rng = np.random.default_rng(4)
    base = rng.standard_normal((32, 64)).astype(np.float32)
    q = rng.standard_normal((2, 64)).astype(np.float32)
    sig = bq.encode(jnp.asarray(base))
    d = np.asarray(bq.adc_distance(jnp.asarray(q), sig))
    levels = np.asarray(bq.decode_levels(sig))
    np.testing.assert_allclose(d, -(q @ levels.T), rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(
    dim=st.integers(min_value=2, max_value=256),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_distance_bounds_and_triangle_of_expectation(dim, seed):
    """|d| <= 4*dim always; encode/pack never crashes on any dim."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((3, dim)).astype(np.float32))
    sig = bq.encode(x)
    d = np.asarray(bq.pairwise_distance(sig, sig))
    assert (np.abs(d) <= bq.distance_upper_bound(dim)).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_property_gw_concentration(seed):
    """Thm 1: E[hamming]/D ~ theta/pi, within Chernoff eps for D=768."""
    rng = np.random.default_rng(seed)
    u = rng.standard_normal(768).astype(np.float32)
    v = rng.standard_normal(768).astype(np.float32)
    theta = np.arccos(
        np.clip(u @ v / (np.linalg.norm(u) * np.linalg.norm(v)), -1, 1)
    )
    su = bq.encode(jnp.asarray(u[None]))
    sv = bq.encode(jnp.asarray(v[None]))
    dh = int(np.asarray(bq.pairwise_hamming_1bit(su, sv))[0, 0])
    # eps = 0.08 -> failure prob < 2 exp(-2*768*0.0064) ~ 1e-4 per draw
    assert abs(dh / 768 - theta / np.pi) < 0.08


def test_misranking_decreases_with_angular_gap():
    """Prop. 2 qualitative check: larger gaps are misranked less often."""
    rng = np.random.default_rng(7)
    d, trials = 768, 200
    rates = []
    for gap in (0.1, 0.5, 1.0):
        bad = 0
        for _ in range(trials):
            u = rng.standard_normal(d)
            u /= np.linalg.norm(u)
            r1, r2 = rng.standard_normal(d), rng.standard_normal(d)
            v = np.cos(0.4) * u + np.sin(0.4) * _orth(r1, u)
            w = np.cos(0.4 + gap) * u + np.sin(0.4 + gap) * _orth(r2, u)
            sigs = bq.encode(jnp.asarray(np.stack([u, v, w]), dtype=jnp.float32))
            dm = np.asarray(bq.pairwise_distance(sigs, sigs))
            if dm[0, 1] >= dm[0, 2]:
                bad += 1
        rates.append(bad / trials)
    assert rates[0] >= rates[1] >= rates[2]
    assert rates[2] < 0.05


def _orth(r, u):
    r = r - (r @ u) * u
    return r / np.linalg.norm(r)
