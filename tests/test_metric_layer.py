"""Tests for the kernel-dispatched metric layer.

Covers the registry contract, dispatch-route equivalence, the ADC
pairwise implementation against a brute-force oracle, multi-expansion
beam search (L=1 must be bit-for-bit the pre-refactor greedy search),
metric_kind persistence, and the single-owner grep invariant: no module
outside the metric/dispatch layer computes a BQ distance by hand.
"""

import functools
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bq, metric
from repro.core.baselines import flat_search, recall_at_k
from repro.core.beam import INF, batched_beam_search
from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset
from repro.kernels import dispatch

jax.config.update("jax_platform_name", "cpu")

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"


# -- registry ----------------------------------------------------------------


def test_registry_lists_all_paper_metrics():
    assert set(metric.registered_kinds()) >= {"bq2", "bq1", "adc",
                                              "float32"}


def test_registry_unknown_kind_raises_with_candidates():
    with pytest.raises(ValueError, match="bq2"):
        metric.resolve("no-such-metric")


@pytest.mark.parametrize("kind", ["bq2", "bq1", "adc", "float32"])
def test_make_backend_constructs_each_kind(kind):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 96)), jnp.float32)
    arrays = metric.MetricArrays(sigs=bq.encode(x), vectors=x)
    b = metric.make_backend(kind, arrays)
    assert b.kind == kind
    assert b.n == 64
    ids = jnp.arange(8, dtype=jnp.int32)
    d = b.dist_fn(b.encode_queries(x[:1])[0], ids,
                  jnp.ones((8,), jnp.bool_))
    assert d.shape == (8,)
    assert (np.asarray(d) >= -1e-4).all()            # calibrated >= 0
    pw = b.pairwise(ids)
    assert pw.shape == (8, 8)
    assert (np.asarray(pw) >= -1e-4).all()


def test_backend_dist_many_matches_dist_fn():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((50, 64)), jnp.float32)
    arrays = metric.MetricArrays(sigs=bq.encode(x), vectors=x)
    ids = jnp.asarray(rng.integers(0, 50, (4, 7)), jnp.int32)
    valid = jnp.ones((4, 7), jnp.bool_)
    for kind in metric.registered_kinds():
        b = metric.make_backend(kind, arrays)
        qs = b.query_repr(jnp.arange(4, dtype=jnp.int32))
        batched = np.asarray(b.dist_many(qs, ids, valid))
        for i in range(4):
            single = np.asarray(b.dist_fn(qs[i], ids[i], valid[i]))
            np.testing.assert_allclose(batched[i], single, rtol=1e-5,
                                       atol=1e-5)


# -- kernel dispatch ---------------------------------------------------------


def test_dispatch_auto_routes_ref_off_tpu():
    assert dispatch.resolve_route(None) == (
        "pallas" if jax.default_backend() == "tpu" else "ref"
    )
    with pytest.raises(ValueError):
        dispatch.resolve_route("cuda")


@pytest.mark.parametrize("dim", [64, 100, 384])
def test_dispatch_pallas_route_matches_ref_route(dim):
    """Both routes must agree exactly (Pallas runs interpreted off-TPU)."""
    rng = np.random.default_rng(dim)
    sigs = bq.encode(jnp.asarray(rng.standard_normal((40, dim)),
                                 jnp.float32))
    q = sigs.words[:3]
    rows = sigs.words[jnp.asarray(rng.integers(0, 40, (3, 9)))]
    ref = dispatch.bq2_ops(dim, route="ref")
    pal = dispatch.bq2_ops(dim, route="pallas")
    np.testing.assert_array_equal(
        np.asarray(ref.dist_rows(q, rows)),
        np.asarray(pal.dist_rows(q, rows)),
    )
    np.testing.assert_array_equal(
        np.asarray(ref.pairwise(rows)), np.asarray(pal.pairwise(rows))
    )


def test_dispatch_hamming_routes_agree():
    rng = np.random.default_rng(3)
    sigs = bq.encode(jnp.asarray(rng.standard_normal((30, 100)),
                                 jnp.float32))
    pos = sigs.pos
    rows = pos[jnp.asarray(rng.integers(0, 30, (2, 11)))]
    ref = dispatch.bq1_ops(100, route="ref")
    pal = dispatch.bq1_ops(100, route="pallas")
    np.testing.assert_array_equal(
        np.asarray(ref.dist_rows(pos[:2], rows)),
        np.asarray(pal.dist_rows(pos[:2], rows)),
    )
    np.testing.assert_array_equal(
        np.asarray(ref.pairwise(rows)), np.asarray(pal.pairwise(rows))
    )


# -- ADC pairwise vs brute force ---------------------------------------------


def test_adc_pairwise_matches_bruteforce_oracle():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((40, 48)), jnp.float32)
    sigs = bq.encode(x)
    b = metric.make_backend("adc", metric.MetricArrays(sigs=sigs))
    ids = jnp.asarray(rng.integers(0, 40, (12,)), jnp.int32)
    got = np.asarray(b.pairwise(ids))

    levels = np.asarray(bq.decode_levels(sigs))       # (N, D)
    offset = 2.0 * np.sqrt(48.0)
    want = np.zeros((12, 12), np.float32)
    for i, a in enumerate(np.asarray(ids)):
        qa = levels[a] / max(np.linalg.norm(levels[a]), 1e-12)
        for j, c in enumerate(np.asarray(ids)):
            want[i, j] = offset - qa @ levels[c]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert (got >= -1e-4).all()


def test_adc_built_graph_searches():
    """The point of ADC pairwise: construction in ADC space works."""
    base, queries = make_dataset("minilm-surrogate", n=600, queries=8)
    base, queries = base[:, :64], queries[:, :64]
    idx = QuIVerIndex.build(
        jnp.asarray(base),
        BuildParams(m=4, ef_construction=24, prune_pool=24, chunk=128),
        metric="adc",
    )
    assert idx.metric_kind == "adc"
    ids, scores = idx.search(jnp.asarray(queries), k=5, ef=32)
    assert ids.shape == (8, 5)
    assert (ids >= 0).all()


# -- multi-expansion beam search ---------------------------------------------


def _greedy_beam_search_oracle(query, adjacency, start, *, dist_fn, ef, n,
                               max_hops=0):
    """Verbatim pre-refactor greedy traversal (the L=1 ground truth)."""
    r = adjacency.shape[1]
    max_hops = max_hops or (4 * ef + 128)

    d0 = dist_fn(query, start[None], jnp.ones((1,), jnp.bool_))[0]
    ids = jnp.full((ef,), -1, dtype=jnp.int32).at[0].set(start)
    dists = jnp.full((ef,), INF, dtype=jnp.float32).at[0].set(d0)
    expanded = jnp.ones((ef,), dtype=jnp.bool_).at[0].set(False)
    visited = jnp.zeros((n,), dtype=jnp.bool_).at[start].set(True)

    def cond(state):
        ids, dists, expanded, visited, hops = state
        frontier = (~expanded) & (ids >= 0)
        return frontier.any() & (hops < max_hops)

    def body(state):
        ids, dists, expanded, visited, hops = state
        pick = jnp.argmin(jnp.where(expanded, INF, dists))
        node = ids[pick]
        expanded = expanded.at[pick].set(True)

        nbrs = adjacency[node]
        valid = nbrs >= 0
        nbrs_safe = jnp.where(valid, nbrs, 0)
        fresh = valid & ~visited[nbrs_safe]
        dedup_key = jnp.where(valid, nbrs, -(jnp.arange(r) + 1))
        first_occurrence = (
            dedup_key[None, :] == dedup_key[:, None]
        ).argmax(axis=1) == jnp.arange(r)
        fresh = fresh & first_occurrence
        visited = visited.at[nbrs_safe].max(valid)

        nd = dist_fn(query, nbrs_safe, fresh)
        nd = jnp.where(fresh, nd, INF)
        new_ids = jnp.where(fresh, nbrs_safe, -1).astype(jnp.int32)
        cat_ids = jnp.concatenate([ids, new_ids])
        cat_dists = jnp.concatenate([dists, nd])
        cat_exp = jnp.concatenate(
            [expanded, jnp.zeros(new_ids.shape, dtype=jnp.bool_)]
        )
        order = jnp.argsort(cat_dists)[:ef]
        return (cat_ids[order], cat_dists[order], cat_exp[order],
                visited, hops + 1)

    ids, dists, expanded, visited, hops = jax.lax.while_loop(
        cond, body, (ids, dists, expanded, visited, jnp.int32(0))
    )
    return ids, dists, hops


@functools.lru_cache(maxsize=1)
def _fixed_index():
    base, queries = make_dataset("minilm-surrogate", n=2000, queries=16)
    idx = QuIVerIndex.build(
        jnp.asarray(base),
        BuildParams(m=6, ef_construction=32, prune_pool=32, chunk=128,
                    seed=0),
    )
    return idx, jnp.asarray(queries)


def test_beam_expand1_identical_to_pre_refactor_greedy():
    """Acceptance: L=1 reproduces the old greedy search bit-for-bit on a
    fixed-seed 2k-vector index."""
    idx, queries = _fixed_index()
    backend = idx.backend()
    reprs = backend.encode_queries(queries)
    n = idx.sigs.words.shape[0]

    new = batched_beam_search(
        reprs, idx.adjacency, jnp.int32(idx.medoid),
        dist_fn=backend.dist_fn, ef=48, n=n, expand=1,
    )
    oracle = jax.vmap(
        lambda q: _greedy_beam_search_oracle(
            q, idx.adjacency, jnp.int32(idx.medoid),
            dist_fn=backend.dist_fn, ef=48, n=n,
        )
    )(reprs)
    np.testing.assert_array_equal(np.asarray(new.ids),
                                  np.asarray(oracle[0]))
    np.testing.assert_array_equal(np.asarray(new.dists),
                                  np.asarray(oracle[1]))
    np.testing.assert_array_equal(np.asarray(new.hops),
                                  np.asarray(oracle[2]))


@pytest.mark.parametrize("expand", [2, 4])
def test_beam_expandL_converges_in_fewer_hops(expand):
    """Wider expansion covers at least the greedy result set at equal or
    better hop count (each hop is one (L*R,) distance batch)."""
    idx, queries = _fixed_index()
    backend = idx.backend()
    reprs = backend.encode_queries(queries)
    n = idx.sigs.words.shape[0]

    greedy = batched_beam_search(
        reprs, idx.adjacency, jnp.int32(idx.medoid),
        dist_fn=backend.dist_fn, ef=48, n=n, expand=1,
    )
    wide = batched_beam_search(
        reprs, idx.adjacency, jnp.int32(idx.medoid),
        dist_fn=backend.dist_fn, ef=48, n=n, expand=expand,
    )
    # same metric space: the wide beam's best-found distance can't be
    # worse than greedy's (both explore supersets of the start region)
    assert float(np.asarray(wide.dists)[:, 0].mean()) <= \
        float(np.asarray(greedy.dists)[:, 0].mean()) + 1e-3
    # and it must take measurably fewer expansion rounds
    assert float(np.asarray(wide.hops).mean()) < \
        float(np.asarray(greedy.hops).mean())


@pytest.mark.parametrize("nav", ["bq2", "bq1", "adc", "float32"])
def test_rotated_index_search_every_nav_kind(nav):
    """Rotation x nav-kind coverage: a rotated build must encode
    queries in rotated space for sig-based navigation (bq2/bq1/adc)
    but keep the float32 backend unrotated (it holds the unrotated
    cold vectors) — every kind must stay a working, sane search."""
    base, queries = make_dataset("minilm-surrogate", n=800, queries=12)
    base, queries = base[:, :64], queries[:, :64]
    idx = QuIVerIndex.build(
        jnp.asarray(base),
        BuildParams(m=6, ef_construction=48, prune_pool=48, chunk=128),
        rotate_seed=11,
    )
    gt, _ = flat_search(base, queries, k=5)
    ids, scores = idx.search(jnp.asarray(queries), k=5, ef=48, nav=nav)
    assert ids.shape == (12, 5)
    assert (ids >= 0).all() and (ids < 800).all()
    # reranked scores are cosine regardless of nav kind
    assert (scores <= 1.0 + 1e-5).all()
    rec = recall_at_k(ids, gt)
    # adc/bq1 are ablation navigators; they still must clearly beat
    # chance, while bq2/float32 should be strong
    floor = 0.6 if nav in ("bq1", "adc") else 0.8
    assert rec >= floor, (nav, rec)
    # query-side rotation really is what makes sig-based navigation
    # work: rerank=False exposes raw navigation quality, which would
    # collapse if queries were encoded unrotated
    ids_raw, raw_scores = idx.search(
        jnp.asarray(queries), k=5, ef=48, nav=nav, rerank=False
    )
    # 1-bit raw navigation is the paper's weak ablation — lowest floor
    assert recall_at_k(ids_raw, gt) >= (0.3 if nav == "bq1" else 0.4), nav
    # rerank=False scores are negated navigation distances, not cosine
    if nav == "bq2":
        assert (raw_scores <= 0.0).all()


def test_index_search_accepts_expand():
    idx, queries = _fixed_index()
    ids1, _ = idx.search(queries, k=5, ef=32, expand=1)
    ids2, _ = idx.search(queries, k=5, ef=32, expand=2)
    assert ids1.shape == ids2.shape == (16, 5)
    # both are searches of the same graph: heavy overlap expected
    overlap = np.mean([
        len(set(a) & set(b)) / 5 for a, b in zip(ids1, ids2)
    ])
    assert overlap > 0.6, overlap


# -- persistence -------------------------------------------------------------


def test_save_load_roundtrips_metric_kind(tmp_path):
    base, queries = make_dataset("minilm-surrogate", n=600, queries=6)
    base = base[:, :64]
    idx = QuIVerIndex.build(
        jnp.asarray(base),
        BuildParams(m=4, ef_construction=24, prune_pool=24, chunk=128,
                    beam_expand=2),
        metric="bq1",
    )
    p = str(tmp_path / "index.npz")
    idx.save(p)
    idx2 = QuIVerIndex.load(p)
    assert idx2.metric_kind == "bq1"
    assert idx2.params.beam_expand == 2
    # nav defaults to the loaded metric kind on both sides
    ids1, _ = idx.search(jnp.asarray(queries[:, :64]), k=5, ef=32)
    ids2, _ = idx2.search(jnp.asarray(queries[:, :64]), k=5, ef=32)
    np.testing.assert_array_equal(ids1, ids2)


# -- single-owner invariant --------------------------------------------------


@pytest.mark.parametrize("module", ["core/distributed.py", "core/index.py"])
def test_bq2_distance_has_one_owner(module):
    """Acceptance: the BQ2 distance lives in the registered backend over
    kernels/dispatch.py — no hand-rolled copies in the serving stack."""
    text = (SRC / module).read_text()
    assert "symmetric_similarity_words" not in text, module


def test_metric_backends_route_through_dispatch():
    text = (SRC / "core" / "metric.py").read_text()
    assert "symmetric_similarity_words" not in text
    assert "dispatch" in text
