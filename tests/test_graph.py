"""Beam search + alpha-prune + Vamana build + end-to-end recall tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import flat_search, recall_at_k
from repro.core.beam import batched_beam_search, beam_search
from repro.core.index import QuIVerIndex
from repro.core.prune import alpha_prune
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset

jax.config.update("jax_platform_name", "cpu")


def test_alpha_prune_keeps_nearest_and_respects_r():
    # line of points: target at 0, candidates at 1,2,3,...  with alpha=1.2
    # candidate i is covered by candidate j<i when d(i,0) > 1.2*d(i,j).
    ids = jnp.arange(1, 9, dtype=jnp.int32)
    dists = jnp.arange(1, 9, dtype=jnp.float32)
    pos = jnp.arange(1, 9, dtype=jnp.float32)
    pw = jnp.abs(pos[:, None] - pos[None, :])
    out_ids, out_dists = alpha_prune(ids, dists, pw, r=4, alpha=1.2)
    assert int(out_ids[0]) == 1                      # nearest always kept
    valid = np.asarray(out_ids) >= 0
    assert valid.sum() <= 4
    # selected dists are sorted ascending
    sel = np.asarray(out_dists)[valid]
    assert (np.diff(sel) >= 0).all()


def test_alpha_prune_alpha_one_keeps_diverse_only():
    # two clusters of candidates: close pair + far pair in opposite dirs
    ids = jnp.asarray([0, 1, 2, 3], dtype=jnp.int32)
    dists = jnp.asarray([1.0, 1.1, 5.0, 5.05], dtype=jnp.float32)
    # 0 and 1 are near each other; 2 and 3 near each other; clusters far
    pw = jnp.asarray(
        [[0.0, 0.2, 6.0, 6.0],
         [0.2, 0.0, 6.0, 6.0],
         [6.0, 6.0, 0.0, 0.1],
         [6.0, 6.0, 0.1, 0.0]], dtype=jnp.float32)
    out_ids, _ = alpha_prune(ids, dists, pw, r=4, alpha=1.0)
    kept = set(np.asarray(out_ids)[np.asarray(out_ids) >= 0].tolist())
    assert 0 in kept and 2 in kept       # one representative per direction
    assert 1 not in kept                  # covered by 0 (d(1,t)=1.1 > d(1,0)=0.2)
    assert 3 not in kept


def test_alpha_prune_handles_invalid_padding():
    ids = jnp.asarray([5, -1, 7, -1], dtype=jnp.int32)
    dists = jnp.asarray([2.0, 1e30, 3.0, 1e30], dtype=jnp.float32)
    pw = jnp.full((4, 4), 10.0, dtype=jnp.float32)
    out_ids, _ = alpha_prune(ids, dists, pw, r=3, alpha=1.2)
    kept = np.asarray(out_ids)
    assert set(kept[kept >= 0].tolist()) == {5, 7}


def _grid_graph(n_side):
    """2D grid of points with 4-neighbour adjacency — known topology."""
    n = n_side * n_side
    coords = np.stack(
        np.meshgrid(np.arange(n_side), np.arange(n_side), indexing="ij"),
        -1,
    ).reshape(-1, 2).astype(np.float32)
    adj = np.full((n, 4), -1, dtype=np.int32)
    for i, (x, y) in enumerate(coords):
        k = 0
        for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            nx, ny = int(x) + dx, int(y) + dy
            if 0 <= nx < n_side and 0 <= ny < n_side:
                adj[i, k] = nx * n_side + ny
                k += 1
    return coords, jnp.asarray(adj)


def test_beam_search_finds_nearest_on_grid():
    coords, adj = _grid_graph(16)
    coords_j = jnp.asarray(coords)

    def dist_fn(query, ids, valid):
        return jnp.linalg.norm(coords_j[ids] - query, axis=-1)

    query = jnp.asarray([13.2, 2.9], dtype=jnp.float32)
    res = beam_search(
        query, adj, jnp.int32(0), dist_fn=dist_fn, ef=8, n=256
    )
    # true nearest grid point to (13.2, 2.9) is (13, 3) -> id 13*16+3
    assert int(res.ids[0]) == 13 * 16 + 3
    assert int(res.hops) > 10   # actually had to walk across the grid


def test_beam_search_batched_matches_single():
    coords, adj = _grid_graph(8)
    coords_j = jnp.asarray(coords)

    def dist_fn(query, ids, valid):
        return jnp.linalg.norm(coords_j[ids] - query, axis=-1)

    queries = jnp.asarray([[1.1, 6.8], [6.2, 0.3]], dtype=jnp.float32)
    bres = batched_beam_search(
        queries, adj, jnp.int32(0), dist_fn=dist_fn, ef=6, n=64
    )
    for i in range(2):
        sres = beam_search(
            queries[i], adj, jnp.int32(0), dist_fn=dist_fn, ef=6, n=64
        )
        np.testing.assert_array_equal(
            np.asarray(bres.ids[i]), np.asarray(sres.ids)
        )


@pytest.mark.slow
def test_end_to_end_recall_contrastive():
    """The paper's core claim at test scale: BQ-native graph + rerank
    reaches high recall on contrastive-like data."""
    base, queries = make_dataset("minilm-surrogate", n=4000, queries=50)
    params = BuildParams(m=8, ef_construction=48, prune_pool=48, chunk=128)
    idx = QuIVerIndex.build(jnp.asarray(base), params)
    true_ids, _ = flat_search(base, queries, k=10)
    pred_ids, _ = idx.search(jnp.asarray(queries), k=10, ef=64)
    rec = recall_at_k(pred_ids, true_ids)
    assert rec > 0.80, rec


@pytest.mark.slow
def test_monotone_recall_in_ef():
    """Lemma 3 / Finding 2: recall rises monotonically with ef."""
    base, queries = make_dataset("minilm-surrogate", n=2000, queries=40)
    params = BuildParams(m=6, ef_construction=32, prune_pool=32, chunk=128)
    idx = QuIVerIndex.build(jnp.asarray(base), params)
    true_ids, _ = flat_search(base, queries, k=10)
    recalls = []
    for ef in (16, 64, 256):
        pred_ids, _ = idx.search(jnp.asarray(queries), k=10, ef=ef)
        recalls.append(recall_at_k(pred_ids, true_ids))
    assert recalls[0] <= recalls[1] + 0.02
    assert recalls[1] <= recalls[2] + 0.02
    assert recalls[-1] > 0.85


def test_graph_degree_bound_and_no_self_edges():
    base, _ = make_dataset("minilm-surrogate", n=1200, queries=10)
    params = BuildParams(m=6, ef_construction=32, prune_pool=32, chunk=128)
    idx = QuIVerIndex.build(jnp.asarray(base), params)
    adj = np.asarray(idx.adjacency)
    deg = (adj >= 0).sum(-1)
    assert deg.max() <= params.r_total
    n = adj.shape[0]
    ids = np.arange(n)[:, None]
    assert not (adj == ids).any()            # no self edges
    assert (adj < n).all() and (adj >= -1).all()


def test_index_save_load_roundtrip(tmp_path):
    base, queries = make_dataset("minilm-surrogate", n=800, queries=8)
    params = BuildParams(m=4, ef_construction=24, prune_pool=24, chunk=128)
    idx = QuIVerIndex.build(jnp.asarray(base), params)
    p = str(tmp_path / "index.npz")
    idx.save(p)
    idx2 = QuIVerIndex.load(p)
    ids1, _ = idx.search(jnp.asarray(queries), k=5, ef=32)
    ids2, _ = idx2.search(jnp.asarray(queries), k=5, ef=32)
    np.testing.assert_array_equal(ids1, ids2)


def test_memory_breakdown_matches_table2_model():
    base, _ = make_dataset("cohere-surrogate", n=1000, queries=8)
    idx = QuIVerIndex.build(
        jnp.asarray(base),
        BuildParams(m=4, ef_construction=24, prune_pool=24, chunk=128),
    )
    mem = idx.memory_breakdown()
    # signatures: N * 2 * ceil(768/32) * 4 = N * 192 bytes (Table 2: 192MB @ 1M)
    assert mem["hot_signature_bytes"] == 1000 * 192
    assert mem["cold_vector_bytes"] == 1000 * 768 * 4
    assert mem["hot_total_bytes"] < mem["cold_vector_bytes"]
