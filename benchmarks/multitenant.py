"""Multi-tenant chaos benchmark: SLOs, quotas and drift under churn.

The scenario ISSUE 7 caps the telemetry layer with — three tenants on
one :class:`~repro.serve.engine.QueryEngine` while a streaming corpus
churns and (deliberately) drifts:

* **acme** — the well-behaved tenant: a generous quota, a mix of
  filtered and unfiltered requests.  Must never be rejected and never
  be charged for anyone else's trouble.
* **burst** — the over-budget tenant: a tight token-bucket quota
  (``qps=2`` sustained, small burst) hammered every round.  Its
  rejections must land on *its* account only — quota buckets are
  independent, so starving acme/drifty through burst's excess is
  structurally impossible (``quota_violations`` audits this).
* **drifty** — queries the shared index like everyone else, but also
  owns a :class:`~repro.stream.mutable.MutableQuIVerIndex` under
  churn.  A green phase streams in-distribution vectors (no alarm);
  the drift phase replaces the live set with sign-collapsed vectors,
  collapsing the accumulator's bit-plane entropy across the calibrated
  band thresholds — the armed :class:`~repro.obs.DriftMonitor` must
  raise.

A deadline-pressure segment forces the ef-degradation ladder so
degrades/drops show up attributed per tenant, and a paired
obs-vs-bare run on the identical workload measures the telemetry tax.

Knobs (all env):

* ``REPRO_MT_CLIENTS`` (8) — closed-loop concurrency;
* ``REPRO_MT_ROUNDS`` (12) — rounds per phase;
* ``REPRO_MT_ASSERT`` (0) — enable the CI smoke assertions (nonzero
  QPS, metrics JSONL parseable, drift alarm in the drift phase only,
  zero cross-tenant quota violations);
* ``REPRO_MT_OVERHEAD_PCT`` (5.0) — telemetry overhead gate, checked
  only under ``REPRO_MT_ASSERT``.
"""

from __future__ import annotations

import json
import os
import pathlib
import time

import numpy as np

from benchmarks.common import BENCH_Q, dataset, index_for
from repro.obs import JsonlSink, ObsHub, render_prometheus
from repro.obs.metrics import get_default_registry
from repro.serve.engine import QueryEngine
from repro.stream.mutable import MutableQuIVerIndex

CLIENTS = int(os.environ.get("REPRO_MT_CLIENTS", 8))
ROUNDS = int(os.environ.get("REPRO_MT_ROUNDS", 12))
ASSERT = os.environ.get("REPRO_MT_ASSERT", "0") == "1"
OVERHEAD_PCT = float(os.environ.get("REPRO_MT_OVERHEAD_PCT", 5.0))

DATASET = "minilm-surrogate"
N_LABELS = 4
FILTER_LABEL = 1
EF = 64
K = 10

JSONL_PATH = (
    pathlib.Path(__file__).resolve().parents[1]
    / "experiments" / "obs" / "multitenant.jsonl"
)

# churn sizing: per-round insert batch for the drifty tenant's corpus
CHURN = 48


def _request_mix(queries, rng):
    """One round of (tenant, queries, kwargs) triples: acme gets the
    serve benchmark's mixed shape, drifty small batches, burst
    singletons (the cheapest way to drain its bucket fast)."""
    out = []
    for c in range(CLIENTS):
        if c % 4 < 2:
            tenant, size = "acme", [2, 4][c % 2]
        elif c % 4 == 2:
            tenant, size = "drifty", 2
        else:
            # the over-budget tenant fires a salvo of singletons every
            # round — far above its sustained qps, so its bucket drains
            # no matter how slowly the rounds tick
            for _ in range(4):
                row = rng.integers(0, len(queries), 1)
                out.append(("burst", queries[row], {"ef": EF, "k": K}))
            continue
        rows = rng.integers(0, len(queries), size)
        kwargs = {"ef": EF, "k": K}
        if tenant == "acme" and c % 2 == 0:
            kwargs["filter"] = FILTER_LABEL
        out.append((tenant, queries[rows], kwargs))
    return out


def _rounds(engine, n, queries, rng, deadline_ms=None):
    """Closed-loop rounds; returns (queries_admitted, wall_seconds)."""
    nq = 0
    t0 = time.perf_counter()
    for _ in range(n):
        tickets = []
        for tenant, q, kw in _request_mix(queries, rng):
            if deadline_ms is not None:
                kw = dict(kw, deadline_ms=deadline_ms)
            tickets.append(engine.submit(q, tenant=tenant, **kw))
            nq += len(q)
        engine.pump()
        for t in tickets:
            engine.result(t)
    return nq, time.perf_counter() - t0


def _warm(engine, queries):
    engine.warmup(buckets=(8, 32), configs=({}, {"filter": FILTER_LABEL}))
    _rounds(engine, 2, queries, np.random.default_rng(3))


def run():
    rng = np.random.default_rng(11)
    base, queries = dataset(DATASET)
    base = np.asarray(base, dtype=np.float32)
    queries = np.asarray(queries, dtype=np.float32)[:BENCH_Q]
    idx, _ = index_for(DATASET)
    if idx.labels is None:
        labels = np.random.default_rng(0).integers(0, N_LABELS, len(base))
        idx.attach_labels(list(labels), n_labels=N_LABELS)
        idx.build_label_entries(min_count=32)

    JSONL_PATH.unlink(missing_ok=True)
    hub = ObsHub(sinks=[JsonlSink(JSONL_PATH)])
    engine = QueryEngine(idx, default_k=K, default_ef=EF, obs=hub)
    engine.set_quota("acme", qps=1e6)
    engine.set_quota("burst", qps=2.0, burst=6)
    _warm(engine, queries)

    # the drifty tenant's own streaming corpus, drift alarms armed
    dim = base.shape[1]
    churn_idx = MutableQuIVerIndex.empty(dim, capacity=4 * ROUNDS * CHURN)
    monitor = churn_idx.attach_drift_monitor(tenant="drifty")

    rows = []

    # -- phase 1: green churn (in-distribution inserts, no alarm) ----------
    green_ids = []
    nq_g, wall_g = 0, 0.0
    for r in range(ROUNDS):
        lo = (r * CHURN) % max(len(base) - CHURN, 1)
        green_ids.append(churn_idx.insert(base[lo:lo + CHURN]))
        nq, w = _rounds(engine, 1, queries, rng)
        nq_g, wall_g = nq_g + nq, wall_g + w
    alarms_green = len(monitor.events)
    engine.emit_report()
    rows.append({
        "name": "mt_green_phase",
        "us_per_call": wall_g / nq_g * 1e6,
        "queries": nq_g, "churn_inserts": ROUNDS * CHURN,
        "drift_band": monitor.band, "alarms": alarms_green,
    })

    # -- phase 2: drift (sign-collapsed inserts + churn out the green
    # live set, collapsing bit-plane entropy across the red band) ----------
    drift_rng = np.random.default_rng(13)
    nq_d, wall_d = 0, 0.0
    for r in range(ROUNDS):
        bad = np.abs(
            drift_rng.normal(size=(CHURN, dim))
        ).astype(np.float32) + 3.0
        churn_idx.insert(bad)
        if r < len(green_ids):
            churn_idx.delete(green_ids[r])
        nq, w = _rounds(engine, 1, queries, rng)
        nq_d, wall_d = nq_d + nq, wall_d + w
    alarms_drift = len(monitor.events) - alarms_green
    engine.emit_report()
    rows.append({
        "name": "mt_drift_phase",
        "us_per_call": wall_d / nq_d * 1e6,
        "queries": nq_d, "churn_inserts": ROUNDS * CHURN,
        "drift_band": monitor.band, "alarms": alarms_drift,
    })

    # -- phase 3: deadline pressure (degrades/drops, attributed) -----------
    rep = engine.stats_report()
    p50 = rep["p50_ms"] or 1.0
    nq_p, wall_p = _rounds(engine, ROUNDS, queries, rng,
                           deadline_ms=max(0.5 * p50, 0.2))
    engine.emit_report()
    rows.append({
        "name": "mt_deadline_phase",
        "us_per_call": wall_p / nq_p * 1e6,
        "queries": nq_p,
        "degraded": engine.stats.degraded,
        "dropped": engine.stats.dropped,
    })

    # -- per-tenant SLO accounts -------------------------------------------
    tenant_report = engine.tenants.report()
    for name, t in tenant_report["tenants"].items():
        rows.append({"name": f"mt_tenant_{name}", **t})

    # -- telemetry overhead: identical workload, obs vs bare engine --------
    obs_engine = QueryEngine(idx, default_k=K, default_ef=EF)
    bare_engine = QueryEngine(idx, default_k=K, default_ef=EF, obs=False)
    _warm(obs_engine, queries)
    _warm(bare_engine, queries)
    nq_o, wall_o = _rounds(obs_engine, ROUNDS,
                           queries, np.random.default_rng(5))
    nq_b, wall_b = _rounds(bare_engine, ROUNDS,
                           queries, np.random.default_rng(5))
    qps_obs, qps_bare = nq_o / wall_o, nq_b / wall_b
    overhead_pct = (qps_bare - qps_obs) / qps_bare * 100.0
    rows.append({
        "name": "mt_overhead",
        "qps_obs": round(qps_obs, 1),
        "qps_bare": round(qps_bare, 1),
        "overhead_pct": round(overhead_pct, 2),
    })

    # -- sink + scrape sanity ----------------------------------------------
    records = [
        json.loads(line)
        for line in JSONL_PATH.read_text().splitlines() if line
    ]
    prom_text = render_prometheus(get_default_registry())
    quota_violations = tenant_report["quota_violations"]
    qps_total = (nq_g + nq_d) / (wall_g + wall_d)
    rows.append({
        "name": "mt_summary",
        "qps": round(qps_total, 1),
        "quota_violations": quota_violations,
        "alarms_green": alarms_green,
        "alarms_drift": alarms_drift,
        "drift_band_final": monitor.band,
        "jsonl_records": len(records),
        "prometheus_lines": len(prom_text.splitlines()),
    })

    hub.close()

    if ASSERT:
        assert qps_total > 0, "multitenant QPS must be nonzero"
        assert len(records) >= 3 and all(
            "metrics" in r for r in records
        ), "metrics JSONL missing or unparseable"
        assert alarms_green == 0, (
            f"{alarms_green} drift alarms during in-distribution churn"
        )
        assert alarms_drift >= 1, "no drift alarm in the drift phase"
        assert quota_violations == 0, (
            f"{quota_violations} cross-tenant quota violations"
        )
        t = tenant_report["tenants"]
        assert t["burst"]["rejected"] > 0, (
            "over-budget tenant was never rejected"
        )
        assert t["acme"]["rejected"] == 0 and t["drifty"]["rejected"] == 0, (
            "quota rejections leaked onto in-budget tenants"
        )
        assert overhead_pct <= OVERHEAD_PCT, (
            f"telemetry overhead {overhead_pct:.1f}% > {OVERHEAD_PCT}%"
        )

    extra = {
        "tenant_report": tenant_report,
        "drift": monitor.report(),
    }
    return rows, extra
