"""Graph X-ray benchmark: churn-to-collapse early warning + probe cost
+ nav-tracing tax (DESIGN.md §15).

The claim the X-ray has to earn: **structural health degrades before
shadow recall does**.  Shadow sampling (§14) tells you recall already
cratered; the topology probes are supposed to fire while the damage is
still building.  The scenario:

* **build + probe cost** — build the green snapshot, then time the full
  probe suite (structure + BFS + edge agreement) cold (with compiles)
  and warm.  The warm suite — the operational per-cycle cadence — must
  cost < ``PROBE_PCT`` of the build it is guarding.
* **churn-to-collapse** — an embedding-model rollover applied in
  slices: each cycle replaces a tranche of the contrastive corpus with
  SIFT-style non-negative rows (the paper's Finding-1 sign-collapse),
  X-rays the streaming graph, lets the operator-paced
  :class:`~repro.obs.RemediationPolicy` act on any band crossing, then
  swaps the frozen snapshot under a shadow-sampled engine and serves.
  The gate: the health band leaves green at least one cycle before the
  tenant's recall SLO breaches — amber while recall is still inside
  SLO is exactly the early warning §15 promises.
* **nav-tracing tax** — paired engines over the identical green
  snapshot and workload: obs-armed (per-query nav counters transferred
  + histogrammed) vs obs-off (counters ride the compiled program but
  never leave device).  Gate is a QPS ratio (never wall-clock — the CI
  runner is a 1-core box) plus zero steady-state retraces.

Knobs (all env):

* ``REPRO_GRAPHHEALTH_CYCLES`` (6) — rollover tranches;
* ``REPRO_GRAPHHEALTH_ROUNDS`` (4) — serving rounds per cycle;
* ``REPRO_GRAPHHEALTH_SAMPLE`` (128) — edge-agreement sample rows;
* ``REPRO_GRAPHHEALTH_ASSERT`` (0) — enable the CI smoke gates;
* ``REPRO_GRAPHHEALTH_PROBE_PCT`` (5.0) — warm probe suite as % of
  build wall;
* ``REPRO_GRAPHHEALTH_NAV_OVERHEAD_PCT`` (5.0) — nav-tracing QPS tax;
* ``REPRO_GRAPHHEALTH_SLO`` (0.80) — the tenant recall SLO.
"""

from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import BENCH_Q, dataset
from repro.core.baselines import flat_search
from repro.core.vamana import BuildParams
from repro.data.datasets import euclidean_cv_surrogate
from repro.obs import GraphHealthMonitor, RemediationPolicy
from repro.plan import trace
from repro.serve.engine import QueryEngine
from repro.stream.mutable import MutableQuIVerIndex

CYCLES = int(os.environ.get("REPRO_GRAPHHEALTH_CYCLES", 6))
ROUNDS = int(os.environ.get("REPRO_GRAPHHEALTH_ROUNDS", 4))
SAMPLE = int(os.environ.get("REPRO_GRAPHHEALTH_SAMPLE", 128))
ASSERT = os.environ.get("REPRO_GRAPHHEALTH_ASSERT", "0") == "1"
PROBE_PCT = float(os.environ.get("REPRO_GRAPHHEALTH_PROBE_PCT", 5.0))
NAV_OVERHEAD_PCT = float(
    os.environ.get("REPRO_GRAPHHEALTH_NAV_OVERHEAD_PCT", 5.0))
RECALL_SLO = float(os.environ.get("REPRO_GRAPHHEALTH_SLO", 0.80))

DATASET = "minilm-surrogate"
TENANT = "prod"
EF = 64
K = 10
BANDS = ("green", "amber", "red")

PARAMS = BuildParams(m=12, ef_construction=64, prune_pool=64, chunk=256)


def _serve_rounds(engine, queries, rounds, *, tenant=TENANT):
    nq, t0, served = 0, time.perf_counter(), None
    for _ in range(rounds):
        tickets = [
            engine.submit(queries[i:i + 8], tenant=tenant)
            for i in range(0, len(queries), 8)
        ]
        engine.pump()
        served = np.concatenate(
            [engine.result(t)[0] for t in tickets]
        )
        nq += len(queries)
    return nq, time.perf_counter() - t0, served


def _probe(churn, **kw):
    t0 = time.perf_counter()
    rep = churn.graph_report(sample=SAMPLE, **kw)
    return rep, time.perf_counter() - t0


def run():
    base, queries = dataset(DATASET)
    base = np.asarray(base, dtype=np.float32)
    queries = np.asarray(queries, dtype=np.float32)[:BENCH_Q]
    dim = base.shape[1]
    rows = []

    # -- build + probe cost -------------------------------------------------
    t0 = time.perf_counter()
    churn = MutableQuIVerIndex.build(
        base, PARAMS, capacity=3 * len(base))
    build_s = time.perf_counter() - t0
    rep0, probe_cold_s = _probe(churn)      # includes the jit compiles
    _, probe_warm_s = _probe(churn)         # the operational cadence
    probe_pct = probe_warm_s / build_s * 100.0
    rows.append({
        "name": "graphhealth_build_probe",
        "build_s": round(build_s, 2),
        "probe_cold_s": round(probe_cold_s, 3),
        "probe_warm_s": round(probe_warm_s, 3),
        "probe_pct_of_build": round(probe_pct, 2),
        "verdict": rep0.verdict,
        "health_score": rep0.health_score,
        "edge_agreement": round(rep0.edge_agreement, 4),
    })

    # -- churn-to-collapse: amber must lead the SLO breach ------------------
    monitor = GraphHealthMonitor(tenant=TENANT)
    monitor.check(rep0)                     # arm on the green baseline
    engine = QueryEngine(churn.freeze(), default_k=K, default_ef=EF,
                         shadow={"rate": 1})
    engine.tenants.recall_window = 32
    engine.tenants.recall_min_samples = 8
    engine.set_quota(TENANT, qps=1e9, recall_slo=RECALL_SLO)
    policy = RemediationPolicy(engine, auto=False)
    policy.attach_graph(monitor)
    engine.warmup(buckets=(8,))

    # the rollover corpus: Finding-1 sign-collapse rows, sliced into
    # per-cycle tranches replacing the original contrastive rows
    bad = euclidean_cv_surrogate(len(base), d=dim)
    green_ids = np.nonzero(np.asarray(churn.live))[0]
    tranche = -(-len(base) // CYCLES)       # ceil: all rolled by the end

    amber_cycle = breach_cycle = None
    for cycle in range(1, CYCLES + 1):
        lo, hi = (cycle - 1) * tranche, min(cycle * tranche, len(base))
        if lo < hi:
            churn.insert(bad[lo:hi])
            churn.delete(green_ids[lo:hi])
        rep, probe_s = _probe(churn)
        monitor.check(rep)
        act = policy.check()                # operator-paced ladder step
        engine.swap_index(churn.freeze())
        nq, wall, served = _serve_rounds(engine, queries, ROUNDS)
        window = engine.tenants.stats(TENANT).recalls
        shadow_recall = (
            float(window.array().mean()) if len(window) else float("nan")
        )
        breached = engine.tenants.recall_breached(TENANT)
        if amber_cycle is None and rep.verdict != "green":
            amber_cycle = cycle
        if breach_cycle is None and breached:
            breach_cycle = cycle
        rows.append({
            "name": f"graphhealth_cycle{cycle}",
            "us_per_call": wall / nq * 1e6,
            "rolled_frac": round(hi / len(base), 2),
            "health_score": rep.health_score,
            "band": rep.verdict,
            "worst_stat": rep.worst_stat()[0],
            "edge_agreement": round(rep.edge_agreement, 4),
            "tombstones": round(rep.tombstone_density, 3),
            "probe_s": round(probe_s, 3),
            "action": act["action"] if act else None,
            "shadow_recall": round(shadow_recall, 4),
            "slo_breached": breached,
        })

    lead = (
        breach_cycle - amber_cycle
        if amber_cycle is not None and breach_cycle is not None else None
    )
    rows.append({
        "name": "graphhealth_early_warning",
        "amber_cycle": amber_cycle,
        "breach_cycle": breach_cycle,
        "lead_cycles": lead,
        "final_band": monitor.band,
        "alarms": len(monitor.alarms),
        "actions": dict(policy.action_counts),
    })

    # -- nav-tracing tax: paired obs-on / obs-off engines -------------------
    snap = MutableQuIVerIndex.build(
        base, PARAMS, capacity=len(base) + 1).freeze()
    traced = QueryEngine(snap, default_k=K, default_ef=EF)   # obs armed
    bare = QueryEngine(snap, default_k=K, default_ef=EF, obs=False)
    traced.warmup(buckets=(8,))
    bare.warmup(buckets=(8,))
    _serve_rounds(traced, queries, 2)
    _serve_rounds(bare, queries, 2)
    with trace.assert_no_retrace(what="nav-traced steady state"):
        nq_t, wall_t, _ = _serve_rounds(traced, queries, ROUNDS)
    nq_b, wall_b, _ = _serve_rounds(bare, queries, ROUNDS)
    qps_traced, qps_bare = nq_t / wall_t, nq_b / wall_b
    nav_overhead_pct = (qps_bare - qps_traced) / qps_bare * 100.0
    nav = engine.tenants.report()["tenants"][TENANT]["nav"]
    rows.append({
        "name": "graphhealth_nav_overhead",
        "qps_traced": round(qps_traced, 1),
        "qps_bare": round(qps_bare, 1),
        "overhead_pct": round(nav_overhead_pct, 2),
        "hops_p50": nav.get("hops", {}).get("p50"),
        "evals_p50": nav.get("evals", {}).get("p50"),
    })

    if ASSERT:
        assert rep0.verdict == "green", (
            f"green baseline read {rep0.verdict}: {rep0.summary()}"
        )
        assert probe_pct < PROBE_PCT, (
            f"warm probe suite {probe_pct:.2f}% of build > {PROBE_PCT}%"
        )
        assert amber_cycle is not None, "health never left green"
        assert breach_cycle is not None, (
            "recall SLO never breached: the collapse scenario is broken"
        )
        assert amber_cycle < breach_cycle, (
            f"no early warning: amber at cycle {amber_cycle}, SLO "
            f"breach at cycle {breach_cycle}"
        )
        assert monitor.band == "red", (
            f"full sign-collapse rollover should X-ray red, got "
            f"{monitor.band}"
        )
        assert sum(policy.action_counts.values()) >= 1, (
            "band crossings never reached the remediation ladder"
        )
        assert nav_overhead_pct <= NAV_OVERHEAD_PCT, (
            f"nav-tracing tax {nav_overhead_pct:.1f}% > "
            f"{NAV_OVERHEAD_PCT}% QPS"
        )
        assert nav.get("hops", {}).get("p50", 0) > 0, (
            "nav counters never reached the tenant ledger"
        )

    extra = {
        "graph_monitor": monitor.report(),
        "remediation": policy.report(),
        "slo": RECALL_SLO,
    }
    return rows, extra
