"""Quality chaos benchmark: shadow recall tracking + closed-loop
remediation (DESIGN.md §14).

The scenario ISSUE 9 caps the quality layer with — one tenant on a
:class:`~repro.serve.engine.QueryEngine` whose corpus drifts
mid-stream, with the shadow ground-truth lane armed:

* **stable phase** — a green snapshot serves in bq2.  The shadow
  sampler's rolling recall estimate (a hash-sampled fraction of live
  traffic, re-answered exactly) must track the true exact recall of
  the served results within ``EST_TOL_PT`` points.
* **drift phase** — an embedding-model rollover: the streaming corpus
  (and the live queries) churn to SIFT-style non-negative features —
  the paper's Finding-1 collapse case, constant sign plane — and the
  engine swaps in the drifted ``freeze()`` snapshot, still navigating
  bq2: recall collapses.  The estimate must *track the collapse*
  (same tolerance — an estimator that only works when quality is good
  is not an estimator), the armed :class:`~repro.obs.DriftMonitor`
  and the tenant's recall SLO must both raise, and the
  :class:`~repro.obs.RemediationPolicy` (operator-paced here, so the
  fidelity measurement is clean) must fire **exactly once** — its
  re-probe reads red and replans the default nav to the float32
  ladder.
* **mitigated phase** — the replanned engine serves on.  The graph
  itself was linked in collapsed bq space, so the float32 rung over
  the damaged topology is a *stopgap*: recall improves but does not
  recover, and the estimator must say so (it keeps tracking exact).
* **post phase** — the red flag's runbook completes: the live corpus
  is rebuilt through the applicability probe (which reads red and
  builds the float32 ladder) and swapped in.  recall@10 must recover
  to within ``RECOVER_PT`` points of the pre-drift value.

A paired shadow-vs-bare run on the identical workload measures the
shadow-lane tax at the *default* sampling rate (~1/256) as a QPS
ratio — a wall-clock latency gate is meaningless on a 1-core CI box,
a throughput ratio on a paired workload is not.

Knobs (all env):

* ``REPRO_QUALITY_ROUNDS`` (8) — serving rounds per phase;
* ``REPRO_QUALITY_RATE`` (1) — shadow sampling rate for the fidelity
  phases (1/rate of traffic gets ground truth; the overhead pair
  always runs the production default).  Defaults to 1 deliberately:
  the hash lane samples a *fixed* subset, so at bench scale (~100
  queries) a 1/4 subset carries ±4-5pt of irreducible
  subset-vs-population noise in the mid-recall regime — rate 1 makes
  the estimate-vs-exact gates isolate pipeline correctness (sampling
  unbiasedness is covered by tests/test_quality.py, the production
  rate's cost by the overhead pair);
* ``REPRO_QUALITY_ASSERT`` (0) — enable the CI smoke assertions;
* ``REPRO_QUALITY_EST_TOL_PT`` (3.0) — estimate-vs-exact tolerance;
* ``REPRO_QUALITY_RECOVER_PT`` (5.0) — post-remediation recovery gate;
* ``REPRO_QUALITY_OVERHEAD_PCT`` (5.0) — shadow-lane QPS tax gate,
  checked only under ``REPRO_QUALITY_ASSERT``.
"""

from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import BENCH_Q, dataset
from repro.core.baselines import flat_search
from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.data.datasets import euclidean_cv_surrogate
from repro.obs import DEFAULT_RATE, RemediationPolicy, Ring
from repro.obs.metrics import get_default_registry
from repro.serve.engine import QueryEngine
from repro.stream.mutable import MutableQuIVerIndex

ROUNDS = int(os.environ.get("REPRO_QUALITY_ROUNDS", 8))
RATE = int(os.environ.get("REPRO_QUALITY_RATE", 1))
ASSERT = os.environ.get("REPRO_QUALITY_ASSERT", "0") == "1"
EST_TOL_PT = float(os.environ.get("REPRO_QUALITY_EST_TOL_PT", 3.0))
RECOVER_PT = float(os.environ.get("REPRO_QUALITY_RECOVER_PT", 5.0))
OVERHEAD_PCT = float(os.environ.get("REPRO_QUALITY_OVERHEAD_PCT", 5.0))

DATASET = "minilm-surrogate"
TENANT = "prod"
EF = 64
K = 10
RECALL_SLO = 0.80

PARAMS = BuildParams(m=12, ef_construction=64, prune_pool=64, chunk=256)
# the remediation rebuild spends more on construction: a red corpus
# has weaker neighborhood structure, and the rebuild is a one-off
# operator action, not the steady-state build budget
REBUILD_PARAMS = BuildParams(
    m=32, ef_construction=160, prune_pool=160, chunk=256
)


def _serve_rounds(engine, queries, rounds, *, tenant=TENANT):
    """Closed-loop rounds of the full query set; returns
    (queries_served, wall_seconds, last_round_served_ids)."""
    nq, t0, served = 0, time.perf_counter(), None
    for _ in range(rounds):
        tickets = [
            engine.submit(queries[i:i + 8], tenant=tenant)
            for i in range(0, len(queries), 8)
        ]
        engine.pump()
        served = np.concatenate(
            [engine.result(t)[0] for t in tickets]
        )
        nq += len(queries)
    return nq, time.perf_counter() - t0, served


def _exact_recall(index, queries, served):
    truth, _ = flat_search(index.vectors, queries, k=K)
    truth = np.asarray(truth)
    return float(np.mean([
        len(set(s.tolist()) & set(t.tolist())) / K
        for s, t in zip(served, truth)
    ]))


def _phase(engine, queries, *, name):
    """Serve ROUNDS rounds and measure both sides of the estimator:
    the shadow lane's rolling estimate (reset per phase) and the exact
    recall of what was actually served."""
    engine.shadow.recalls = Ring(engine.shadow.recalls.maxlen)
    d0 = engine.shadow.drained
    nq, wall, served = _serve_rounds(engine, queries, ROUNDS)
    window = engine.shadow.recalls
    estimate = (
        float(window.array().mean()) if len(window) else float("nan")
    )
    exact = _exact_recall(engine.index, queries, served)
    return {
        "name": name,
        "us_per_call": wall / nq * 1e6,
        "queries": nq,
        "shadow_samples": engine.shadow.drained - d0,
        "recall_estimate": round(estimate, 4),
        "recall_exact": round(exact, 4),
        "estimate_err_pt": round(abs(estimate - exact) * 100, 2),
    }


def run():
    base, queries = dataset(DATASET)
    base = np.asarray(base, dtype=np.float32)
    queries = np.asarray(queries, dtype=np.float32)[:BENCH_Q]

    # the streaming corpus the snapshots come from, drift alarms armed
    churn = MutableQuIVerIndex.build(base, PARAMS, capacity=4 * len(base))
    monitor = churn.attach_drift_monitor(tenant=TENANT)

    engine = QueryEngine(
        churn.freeze(), default_k=K, default_ef=EF,
        shadow={"rate": RATE},
    )
    # a small breach window so the drift phase's own samples decide the
    # SLO verdict (the default 256-sample window would still be half
    # full of stable-phase measurements)
    engine.tenants.recall_window = 32
    engine.tenants.recall_min_samples = 8
    engine.set_quota(TENANT, qps=1e9, recall_slo=RECALL_SLO)
    # operator-paced remediation: triggers queue; check() acts — so the
    # drift phase measures estimator fidelity on the *unremediated*
    # collapse, then remediates exactly once at the phase boundary
    policy = RemediationPolicy(engine, auto=False).attach(monitor)
    engine.warmup(buckets=(8,))

    rows = []

    # -- phase 1: stable (green snapshot, estimate tracks exact) -----------
    stable = _phase(engine, queries, name="quality_stable")
    rows.append(stable)

    # -- phase 2: drift (collapsed corpus served in bq2, no remediation
    # yet: the estimator must track the collapse) --------------------------
    # an embedding-model rollover to SIFT-style non-negative features
    # (euclidean_cv_surrogate at the index's dim): the sign plane goes
    # constant — the paper's Finding-1 red zone — while the float32
    # geometry stays healthy; live queries re-embed under the new
    # model too, so phases 2/3 serve and score the drifted query set
    dim = base.shape[1]
    rolled = euclidean_cv_surrogate(len(base) + len(queries), d=dim)
    drift_rng = np.random.default_rng(1234)
    qidx = drift_rng.choice(len(rolled), size=len(queries), replace=False)
    mask = np.ones(len(rolled), dtype=bool)
    mask[qidx] = False
    bad = rolled[mask][: len(base)]
    dq = rolled[qidx] + 0.02 * drift_rng.standard_normal(
        (len(queries), dim)
    ).astype(np.float32)
    drift_queries = (
        dq / np.linalg.norm(dq, axis=1, keepdims=True)
    ).astype(np.float32)

    green_rows = np.nonzero(churn.live)[0]
    churn.insert(bad)
    churn.delete(green_rows)              # live set is now all-collapsed
    engine.swap_index(churn.freeze())
    drift = _phase(engine, drift_queries, name="quality_drift")
    drift["drift_band"] = monitor.band
    drift["slo_breached"] = engine.tenants.recall_breached(TENANT)
    rows.append(drift)

    # -- remediation: all queued triggers coalesce into one action --------
    fired = policy.check()
    actions = dict(policy.action_counts)
    rows.append({
        "name": "quality_remediation",
        "action": fired["action"] if fired else None,
        "reprobe_verdict": (
            policy.last_report.verdict if policy.last_report else None
        ),
        "nav_after": policy._current_nav(),
        "replans": actions["replan"],
        "flag_red": actions["flag_red"],
        "pending_triggers": policy.report()["pending_triggers"],
    })

    # -- phase 3a: stopgap serving on the replanned engine -----------------
    # the drifted rows were *linked* in collapsed bq space, so the
    # float32 rung over the damaged topology mitigates but cannot fully
    # recover — and the estimator has to keep tracking exactly that
    mitigated = _phase(engine, drift_queries, name="quality_mitigated")
    rows.append(mitigated)

    # -- phase 3b: the red flag's runbook — rebuild through the probe ------
    # a red corpus invalidates the bq-built graph, not just the serving
    # nav: rebuild the live corpus with metric="auto" (the probe reads
    # red and builds the float32 ladder) and swap the snapshot in
    rebuilt = QuIVerIndex.build(
        np.asarray(engine.index.vectors), REBUILD_PARAMS, metric="auto"
    )
    engine.swap_index(rebuilt)
    post = _phase(engine, drift_queries, name="quality_post_remediation")
    post["rebuild_verdict"] = (
        rebuilt.report.verdict if rebuilt.report else None
    )
    post["rebuild_nav"] = rebuilt.policy.nav if rebuilt.policy else None
    post["recovered_to_pt"] = round(
        (stable["recall_exact"] - post["recall_exact"]) * 100, 2
    )
    rows.append(post)

    # -- shadow-lane tax: paired runs at the production sampling rate ------
    snap = MutableQuIVerIndex.build(
        base, PARAMS, capacity=len(base) + 1
    ).freeze()
    shadow_engine = QueryEngine(snap, default_k=K, default_ef=EF,
                                shadow={"rate": DEFAULT_RATE})
    bare_engine = QueryEngine(snap, default_k=K, default_ef=EF)
    shadow_engine.warmup(buckets=(8,))
    bare_engine.warmup(buckets=(8,))
    _serve_rounds(shadow_engine, queries, 2)          # warm both paths
    _serve_rounds(bare_engine, queries, 2)
    nq_s, wall_s, _ = _serve_rounds(shadow_engine, queries, ROUNDS)
    nq_b, wall_b, _ = _serve_rounds(bare_engine, queries, ROUNDS)
    qps_shadow, qps_bare = nq_s / wall_s, nq_b / wall_b
    overhead_pct = (qps_bare - qps_shadow) / qps_bare * 100.0
    rows.append({
        "name": "quality_shadow_overhead",
        "rate": DEFAULT_RATE,
        "qps_shadow": round(qps_shadow, 1),
        "qps_bare": round(qps_bare, 1),
        "overhead_pct": round(overhead_pct, 2),
        "sampled": shadow_engine.shadow.sampled,
    })

    reg = get_default_registry()
    remediation_counter = reg.counter(
        "quiver_remediation_actions_total",
        "remediation-ladder actions by trigger",
        labels=("action", "trigger"),
    )
    span_rep = engine.obs.tracer.report() if engine.obs else {}

    if ASSERT:
        assert stable["estimate_err_pt"] <= EST_TOL_PT, (
            f"stable-phase estimate off by {stable['estimate_err_pt']}pt"
            f" > {EST_TOL_PT}pt"
        )
        assert drift["estimate_err_pt"] <= EST_TOL_PT, (
            f"drift-phase estimate off by {drift['estimate_err_pt']}pt"
            f" > {EST_TOL_PT}pt"
        )
        assert mitigated["estimate_err_pt"] <= EST_TOL_PT, (
            f"mitigated-phase estimate off by "
            f"{mitigated['estimate_err_pt']}pt > {EST_TOL_PT}pt"
        )
        assert drift["recall_exact"] < stable["recall_exact"] - 0.2, (
            "drift phase did not actually collapse recall"
        )
        assert mitigated["recall_exact"] > drift["recall_exact"], (
            "float32 stopgap did not improve on the collapsed bq2 serve"
        )
        assert drift["slo_breached"], "recall SLO never breached"
        assert monitor.band == "red", "drift monitor missed the collapse"
        assert actions["replan"] == 1 and sum(
            actions[a] for a in ("replan", "escalate_ef", "flag_red")
        ) == 1, f"remediation fired other than exactly once: {actions}"
        assert post["recovered_to_pt"] <= RECOVER_PT, (
            f"post-remediation recall {post['recall_exact']} is "
            f"{post['recovered_to_pt']}pt below pre-drift"
        )
        assert remediation_counter.value(
            action="replan", trigger=fired["trigger"]
        ) >= 1, "remediation action not visible as a counter"
        assert span_rep.get("remediate", {}).get("count", 0) >= 1, (
            "remediation action not visible as a span"
        )
        assert overhead_pct <= OVERHEAD_PCT, (
            f"shadow-lane overhead {overhead_pct:.1f}% > {OVERHEAD_PCT}%"
        )

    extra = {
        "remediation": policy.report(),
        "drift": monitor.report(),
        "tenant_report": engine.tenants.report(),
        "shadow_report": engine.shadow.report(),
    }
    return rows, extra
