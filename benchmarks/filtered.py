"""Filtered search: recall/QPS across the selectivity sweep (DESIGN §9).

Labels are planted at target selectivities {0.5, 0.1, 0.01}; for each,
we measure Recall@10 against exact *filtered* ground truth and time
three strategies:

* ``quiver``      — the integrated path: predicate pushed into the beam
  as a result mask, selectivity-routed (widened-``ef`` graph search
  above the floor, brute force over matches below), per-label entry
  points;
* ``postfilter``  — the classic baseline: unfiltered search fetching
  ``k / selectivity`` candidates, then dropping non-matches;
* ``exact``       — brute force over the match set (the recall ceiling,
  and the QPS floor the graph path must beat at high selectivity).

The acceptance bar (tests/test_filtered.py) is recall within 5 points
of exact filtered ground truth at selectivities 0.5 and 0.1.

Scale knobs: REPRO_FILTER_N (corpus, default min(BENCH_N, 4000)),
REPRO_BENCH_Q (queries).
"""

from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.baselines import flat_search, recall_at_k
from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset
from repro.filter import brute_force_topk

from benchmarks.common import BENCH_N, BENCH_Q

NAME = "minilm-surrogate"
FILTER_N = int(os.environ.get("REPRO_FILTER_N", min(BENCH_N, 4000)))
SELECTIVITIES = (0.5, 0.1, 0.01)
PARAMS = BuildParams(m=8, ef_construction=64, prune_pool=64, chunk=256)
EF, K = 64, 10


def _timed(fn, repeats: int = 2):
    out = fn()                                   # warm / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn()
    return out, (time.perf_counter() - t0) / repeats


def run() -> list[dict]:
    base, queries = make_dataset(NAME, n=FILTER_N, queries=BENCH_Q)
    rng = np.random.default_rng(7)
    # label i is planted independently at selectivity SELECTIVITIES[i]
    member = np.stack(
        [rng.random(FILTER_N) < p for p in SELECTIVITIES], axis=1
    )
    # label-less nodes are fine: their bitset rows are zero and they
    # simply never match — exactly the unlabeled-document case
    rows = [np.nonzero(m)[0].tolist() for m in member]

    idx = QuIVerIndex.build(jnp.asarray(base), PARAMS)
    idx.attach_labels(rows, n_labels=len(SELECTIVITIES))
    idx.build_label_entries(min_count=16)
    qj = jnp.asarray(queries)

    rows_out = []
    nq = len(queries)
    for label, target in enumerate(SELECTIVITIES):
        mask = member[:, label]
        match = np.nonzero(mask)[0]
        sel = mask.mean()
        k = min(K, len(match))       # toy scales: < K matches at 1%
        if k == 0:
            continue
        gt_pos, _ = flat_search(base[match], queries, k=k)
        gt = match[gt_pos]

        # integrated filtered search (selectivity-routed)
        (pred, _), dt = _timed(
            lambda: idx.search(qj, k=k, ef=EF, filter=label)
        )
        rows_out.append({
            "name": f"filtered/quiver_sel{target}",
            "us_per_call": round(dt * 1e6 / nq, 1),
            "recall": round(recall_at_k(pred, gt), 4),
            "qps": round(nq / dt, 1),
            "selectivity": round(float(sel), 4),
        })

        # post-filter baseline: over-fetch then drop non-matches
        kf = min(FILTER_N, int(np.ceil(k / max(sel, 1e-9))))
        def _postfilter():
            ids, _ = idx.search(qj, k=kf, ef=max(EF, kf))
            out = np.full((nq, k), -1, np.int64)
            for i, row in enumerate(ids):
                hits = row[(row >= 0) & mask[np.clip(row, 0, None)]][:k]
                out[i, : len(hits)] = hits
            return out
        pf, dt_pf = _timed(_postfilter)
        rows_out.append({
            "name": f"filtered/postfilter_sel{target}",
            "us_per_call": round(dt_pf * 1e6 / nq, 1),
            "recall": round(recall_at_k(pf, gt), 4),
            "qps": round(nq / dt_pf, 1),
            "overfetch_k": kf,
        })

        # exact brute force over matches (ceiling)
        (ex, _), dt_ex = _timed(
            lambda: brute_force_topk(
                jnp.asarray(
                    queries / np.linalg.norm(
                        queries, axis=-1, keepdims=True
                    )
                ),
                match, k, vectors=idx.vectors,
            )
        )
        rows_out.append({
            "name": f"filtered/exact_sel{target}",
            "us_per_call": round(dt_ex * 1e6 / nq, 1),
            "recall": round(recall_at_k(ex, gt), 4),
            "qps": round(nq / dt_ex, 1),
            "n_matches": int(len(match)),
        })
    return rows_out


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run(), "filtered")
