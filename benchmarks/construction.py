"""Paper §4.1 + DESIGN.md §13: batch construction — scaling, IVF
candidate seeding, and the chunk ablation.

Claims to validate:

* build time scales ~linearly in N for the IVF-assisted build: with
  ``ivf_candidates=True`` each chunk's candidate pool comes from a
  top-p coarse-list scan (O(L + p·cap) per node) instead of a beam
  traversal of the whole current graph, so the per-node cost stops
  growing with N;
* seeding from coarse lists does not cost graph quality: recall@10 of
  a graph built with ``ivf_candidates=True`` stays within a point of
  the plain beam-seeded build at the same search settings;
* the ``nav="ivf"`` plan family rides the same partition: flat top-p
  list scan + rerank reaches graph-level recall when p is widened
  (coarse routing trades scan fraction for recall — DESIGN.md §13);
* chunk size trades per-chunk dispatch overhead against staleness
  (recall impact small).

Env knobs: ``REPRO_BENCH_N`` (sweep tops out here; the sweep is
N/4, N/2, N), ``REPRO_CONS_ASSERT=1`` enables the CI gates (IVF build
speedup ≥ 3x at the largest N, build-recall parity within 1pt,
widened nav="ivf" within 2pt of graph nav).
"""

from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.baselines import flat_search, recall_at_k
from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams

from benchmarks.common import BENCH_N, dataset, emit, timed_search

NAME = "cohere-surrogate"
ASSERT = os.environ.get("REPRO_CONS_ASSERT", "0") == "1"

# gates (see module docstring); the speedup gate applies at the
# largest swept N, where the O(N) beam-seeded chunk cost dominates.
# Defaults are the full-scale (N >= ~8k) acceptance bars; the CI toy
# smoke relaxes them via env (at N=600 the partition is a bigger
# fraction of the build and sub-pt recall deltas are sample noise).
SPEEDUP_MIN = float(os.environ.get("REPRO_CONS_SPEEDUP_MIN", "3.0"))
BUILD_RECALL_PT = float(os.environ.get("REPRO_CONS_RECALL_PT", "0.01"))
IVF_NAV_RECALL_PT = float(
    os.environ.get("REPRO_CONS_IVF_NAV_PT", "0.02")
)


def _build(base, *, ivf: bool, chunk: int = 256):
    params = BuildParams(
        m=16, ef_construction=96, prune_pool=96, chunk=chunk,
        ivf_candidates=ivf,
    )
    t0 = time.perf_counter()
    idx = QuIVerIndex.build(jnp.asarray(base), params)
    return idx, time.perf_counter() - t0


def run():
    rows = []
    base, queries = dataset(NAME)
    sweep = sorted({max(512, BENCH_N // 4), max(512, BENCH_N // 2),
                    BENCH_N})
    summary = {}

    for n in sweep:
        sub = np.asarray(base[:n])
        gt = flat_search(sub, queries, k=10)[0]

        idx_plain, t_plain = _build(sub, ivf=False)
        pred, _ = timed_search(idx_plain, queries, ef=64, repeats=1)
        r_plain = recall_at_k(pred, gt)
        rows.append({
            "name": f"construction/plain_n{n}",
            "us_per_call": round(t_plain * 1e6 / n, 1),  # per node
            "build_s": round(t_plain, 1),
            "recall_ef64": round(r_plain, 4),
        })

        idx_ivf, t_ivf = _build(sub, ivf=True)
        pred, _ = timed_search(idx_ivf, queries, ef=64, nav="bq2",
                               repeats=1)
        r_ivf_build = recall_at_k(pred, gt)
        part = idx_ivf.ivf
        rows.append({
            "name": f"construction/ivf_n{n}",
            "us_per_call": round(t_ivf * 1e6 / n, 1),
            "build_s": round(t_ivf, 1),
            "recall_ef64": round(r_ivf_build, 4),
            "speedup_vs_plain": round(t_plain / t_ivf, 2),
            "n_lists": part.n_lists,
        })

        # the nav="ivf" plan family on the same partition: defaults
        # (p ~ L/3) and the widened setting the parity gate uses
        p_wide = -(-3 * part.n_lists // 4)
        pred, _ = timed_search(idx_ivf, queries, ef=128, nav="ivf",
                               repeats=1)
        r_nav_def = recall_at_k(pred, gt)
        ids, _ = idx_ivf.search(jnp.asarray(queries), k=10, ef=128,
                                nav="ivf", probes=p_wide)
        r_nav_wide = recall_at_k(np.asarray(ids), gt)
        rows.append({
            "name": f"construction/ivf_nav_n{n}",
            "us_per_call": "",
            "recall_ivf_default": round(r_nav_def, 4),
            "recall_ivf_wide": round(r_nav_wide, 4),
            "probes_wide": p_wide,
        })

        summary[n] = {
            "t_plain": t_plain, "t_ivf": t_ivf,
            "r_plain": r_plain, "r_ivf_build": r_ivf_build,
            "r_nav_wide": r_nav_wide,
        }

    for chunk in (128, 512):
        idx, dt = _build(np.asarray(base), ivf=True, chunk=chunk)
        gt = flat_search(np.asarray(base), queries, k=10)[0]
        pred, _ = timed_search(idx, queries, ef=64, nav="bq2",
                               repeats=1)
        rows.append({
            "name": f"construction/ivf_chunk{chunk}",
            "us_per_call": round(dt * 1e6 / len(base), 1),
            "build_s": round(dt, 1),
            "recall_ef64": round(recall_at_k(pred, gt), 4),
        })

    top = summary[sweep[-1]]
    speedup = top["t_plain"] / top["t_ivf"]
    extra = {
        "ivf_speedup_at_max_n": round(speedup, 2),
        "build_recall_delta": round(
            top["r_plain"] - top["r_ivf_build"], 4
        ),
        "ivf_nav_wide_delta": round(
            top["r_plain"] - top["r_nav_wide"], 4
        ),
    }
    if ASSERT:
        assert speedup >= SPEEDUP_MIN, (
            f"ivf_candidates build speedup {speedup:.2f}x < "
            f"{SPEEDUP_MIN}x at N={sweep[-1]}"
        )
        assert top["r_ivf_build"] >= top["r_plain"] - BUILD_RECALL_PT, (
            f"ivf-seeded build recall {top['r_ivf_build']:.4f} more "
            f"than {BUILD_RECALL_PT} below plain {top['r_plain']:.4f}"
        )
        assert top["r_nav_wide"] >= top["r_plain"] - IVF_NAV_RECALL_PT, (
            f"widened nav='ivf' recall {top['r_nav_wide']:.4f} more "
            f"than {IVF_NAV_RECALL_PT} below graph {top['r_plain']:.4f}"
        )
    return rows, extra


if __name__ == "__main__":
    rows, extra = run()
    emit(rows, "construction")
    from benchmarks.common import write_bench_json
    write_bench_json(rows, "construction", extra)
