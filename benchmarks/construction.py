"""Paper §4.1: batch concurrent construction — scaling + chunk ablation.

Claims to validate: build time scales ~linearly in N (each chunk does
bounded work), chunk size trades per-chunk dispatch overhead against
graph staleness (recall impact small), and construction never touches
float32 vectors (asserted structurally: the build path only consumes
packed signatures).
"""

from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core.baselines import recall_at_k
from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams

from benchmarks.common import dataset, emit, ground_truth, timed_search

NAME = "cohere-surrogate"


def run() -> list[dict]:
    rows = []
    base, queries = dataset(NAME)
    gt = ground_truth(NAME)

    for n in (2500, 5000, 10000):
        sub = base[:n]
        t0 = time.perf_counter()
        QuIVerIndex.build(
            jnp.asarray(sub),
            BuildParams(m=16, ef_construction=96, prune_pool=96,
                        chunk=256),
        )
        dt = time.perf_counter() - t0
        rows.append({
            "name": f"construction/scale_n{n}",
            "us_per_call": round(dt * 1e6 / n, 1),   # per inserted node
            "build_s": round(dt, 1),
        })

    for chunk in (128, 512):
        t0 = time.perf_counter()
        idx = QuIVerIndex.build(
            jnp.asarray(base),
            BuildParams(m=16, ef_construction=96, prune_pool=96,
                        chunk=chunk),
        )
        dt = time.perf_counter() - t0
        pred, _ = timed_search(idx, queries, ef=64, repeats=1)
        rows.append({
            "name": f"construction/chunk{chunk}",
            "us_per_call": round(dt * 1e6 / len(base), 1),
            "build_s": round(dt, 1),
            "recall_ef64": round(recall_at_k(pred, gt), 4),
        })
    return rows


if __name__ == "__main__":
    emit(run(), "construction")
