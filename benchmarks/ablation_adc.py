"""Paper §3.3 ablation: symmetric BQ navigation vs ADC navigation.

Claim to validate: ADC costs far more per hop (decode + float mac vs
XOR/popcount) for a small recall gain — "symmetric + rerank achieves a
strictly better Pareto trade-off" (paper: 9.4x QPS drop for +3.2%
recall; constants differ off-SIMD, ordering should hold).
"""

from __future__ import annotations

from repro.core.baselines import recall_at_k

from benchmarks.common import dataset, emit, ground_truth, index_for, \
    timed_search

NAME = "cohere-surrogate"
EF = 64


def run() -> list[dict]:
    rows = []
    idx, _ = index_for(NAME)
    _, queries = dataset(NAME)
    gt = ground_truth(NAME)
    out = {}
    for nav in ("bq2", "adc"):
        pred, spq = timed_search(idx, queries, ef=EF, nav=nav)
        out[nav] = (recall_at_k(pred, gt), spq)
        rows.append({
            "name": f"ablation_adc/{nav}",
            "us_per_call": round(spq * 1e6, 1),
            "recall_at_10": round(out[nav][0], 4),
            "qps": round(1.0 / spq, 1),
        })
    rows.append({
        "name": "ablation_adc/summary",
        "us_per_call": "",
        "qps_ratio_sym_over_adc": round(out["adc"][1] / out["bq2"][1], 2),
        "recall_delta_adc_minus_sym": round(out["adc"][0] - out["bq2"][0],
                                            4),
    })
    return rows


if __name__ == "__main__":
    emit(run(), "ablation_adc")
