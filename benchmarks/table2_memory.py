"""Paper Table 2: hot/cold memory breakdown + dimensionality invariance.

Measured at bench scale and extrapolated analytically to the paper's
1M-vector setting; claims to validate: hot = signatures (N*D/4 B) +
adjacency (dimension-independent), cold = 4*N*D B, hot grows ~1.46x
over a 4x dimensionality range while cold grows 4x.
"""

from __future__ import annotations

from repro.core import bq

from benchmarks.common import BENCH_N, emit, index_for

DIMS = {"minilm-surrogate": 384, "cohere-surrogate": 768,
        "dbpedia-surrogate": 1536}


def analytic_1m(dim: int, m: int = 32, slack: int = 8) -> dict:
    n = 1_000_000
    sig = bq.signature_bytes(n, dim)
    adj = n * (2 * m + slack) * 4 + n * 4
    cold = n * dim * 4
    return {"sig_mb": sig / 2**20, "adj_mb": adj / 2**20,
            "hot_mb": (sig + adj) / 2**20, "cold_mb": cold / 2**20}


def run() -> list[dict]:
    rows = []
    hot = {}
    for name, dim in DIMS.items():
        idx, _ = index_for(name)
        mem = idx.memory_breakdown()
        a = analytic_1m(dim)
        hot[dim] = a["hot_mb"]
        rows.append({
            "name": f"table2/{name}",
            "us_per_call": "",
            "dim": dim,
            "measured_hot_mb": round(mem["hot_total_bytes"] / 2**20, 1),
            "measured_cold_mb": round(mem["cold_vector_bytes"] / 2**20, 1),
            "analytic_1m_hot_mb": round(a["hot_mb"], 0),
            "analytic_1m_cold_mb": round(a["cold_mb"], 0),
            "n": BENCH_N,
        })
    rows.append({
        "name": "table2/dim-invariance",
        "us_per_call": "",
        "hot_growth_384_to_1536": round(hot[1536] / hot[384], 2),
        "cold_growth_384_to_1536": 4.0,
        "paper_hot_growth": 1.46,
    })
    return rows


if __name__ == "__main__":
    emit(run(), "table2")
