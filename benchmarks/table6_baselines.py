"""Paper Table 6: QuIVer vs full-precision graph baselines.

The hnswlib/USearch roles are played by the same Vamana builder run in
*float32 metric space* (the paradigm the paper challenges: topology
decided at full precision) plus the exact flat scan.  Claims to
validate: BQ-native construction is faster to build and faster to
search at comparable recall (exact speedup constants are Rust/AVX-512
artifacts; the *ordering* and build-time ratio are the architecture-
level claims).
"""

from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core.baselines import flat_search, recall_at_k
from repro.core.index import QuIVerIndex

from benchmarks.common import (
    DEFAULT_PARAMS, dataset, emit, ground_truth, index_for, timed_search,
)

NAME = "cohere-surrogate"
EFS = [64, 128, 256]


def run() -> list[dict]:
    rows = []
    base, queries = dataset(NAME)
    gt = ground_truth(NAME)

    # QuIVer (BQ-native topology)
    idx, build_bq = index_for(NAME)
    for ef in EFS:
        pred, spq = timed_search(idx, queries, ef=ef)
        rows.append({
            "name": f"table6/quiver/ef{ef}",
            "us_per_call": round(spq * 1e6, 1),
            "recall_at_10": round(recall_at_k(pred, gt), 4),
            "qps": round(1.0 / spq, 1),
            "build_s": round(build_bq, 1),
        })

    # float32-metric Vamana (the "full-precision topology" baseline)
    t0 = time.perf_counter()
    idx_f = QuIVerIndex.build(jnp.asarray(base), DEFAULT_PARAMS,
                              metric="float32")
    build_f = time.perf_counter() - t0
    for ef in EFS:
        pred, spq = timed_search(idx_f, queries, ef=ef, nav="float32")
        rows.append({
            "name": f"table6/f32-vamana/ef{ef}",
            "us_per_call": round(spq * 1e6, 1),
            "recall_at_10": round(recall_at_k(pred, gt), 4),
            "qps": round(1.0 / spq, 1),
            "build_s": round(build_f, 1),
        })

    # exact flat scan
    t0 = time.perf_counter()
    pred, _ = flat_search(base, queries, k=10)
    spq = (time.perf_counter() - t0) / len(queries)
    rows.append({
        "name": "table6/flat-exact",
        "us_per_call": round(spq * 1e6, 1),
        "recall_at_10": 1.0,
        "qps": round(1.0 / spq, 1),
        "build_s": 0.0,
    })
    return rows


if __name__ == "__main__":
    emit(run(), "table6")
