"""Paper §2.1/§3.1 ablation: 1-bit SimHash vs 2-bit Sign-Magnitude.

Claims to validate:
  * SQNR: ~4.4 dB (1-bit) vs ~10.5 dB (2-bit) on a unit Gaussian, i.e.
    quantization variance reduced to ~25% ("~70% reduction");
  * graph recall: the 2-bit index beats a 1-bit index built and
    navigated identically (same Vamana machinery, metric backend is the
    only change).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.baselines import recall_at_k
from repro.core.index import QuIVerIndex

from benchmarks.common import (
    DEFAULT_PARAMS, dataset, emit, ground_truth, index_for, timed_search,
)

NAME = "cohere-surrogate"
EF = 64


def sqnr_db(levels: np.ndarray, x: np.ndarray) -> float:
    mse = float(np.mean((x - levels) ** 2))
    return 10 * np.log10(float(np.mean(x ** 2)) / mse)


def measure_sqnr() -> dict:
    rng = np.random.default_rng(0)
    x = rng.standard_normal(1_000_000).astype(np.float32)
    # optimal 1-bit: +-sqrt(2/pi) (paper footnote 1)
    lvl1 = np.sign(x) * np.sqrt(2 / np.pi)
    # 2-bit SM with tau = mean|x| and Lloyd-Max conditional-mean levels
    tau = np.abs(x).mean()
    strong = np.abs(x) > tau
    c_weak = np.abs(x)[~strong].mean()
    c_strong = np.abs(x)[strong].mean()
    lvl2 = np.sign(x) * np.where(strong, c_strong, c_weak)
    return {"sqnr_1bit_db": sqnr_db(lvl1, x), "sqnr_2bit_db": sqnr_db(lvl2, x)}


def run() -> list[dict]:
    rows = []
    s = measure_sqnr()
    var_ratio = 10 ** (-(s["sqnr_2bit_db"] - s["sqnr_1bit_db"]) / 10)
    rows.append({
        "name": "ablation_bits/sqnr",
        "us_per_call": "",
        "sqnr_1bit_db": round(s["sqnr_1bit_db"], 2),
        "sqnr_2bit_db": round(s["sqnr_2bit_db"], 2),
        "variance_ratio_2bit_over_1bit": round(var_ratio, 3),
        "paper_1bit_db": 4.4, "paper_2bit_db": 10.5,
    })

    base, queries = dataset(NAME)
    gt = ground_truth(NAME)
    idx2, _ = index_for(NAME)
    pred2, spq2 = timed_search(idx2, queries, ef=EF)
    idx1 = QuIVerIndex.build(jnp.asarray(base), DEFAULT_PARAMS,
                             metric="bq1")
    pred1, spq1 = timed_search(idx1, queries, ef=EF, nav="bq1")
    rows.append({
        "name": "ablation_bits/recall",
        "us_per_call": round(spq2 * 1e6, 1),
        "recall_2bit": round(recall_at_k(pred2, gt), 4),
        "recall_1bit": round(recall_at_k(pred1, gt), 4),
    })
    return rows


if __name__ == "__main__":
    emit(run(), "ablation_bits")
