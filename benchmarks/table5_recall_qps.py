"""Paper Table 5: recall-throughput trade-off on LLM-embedding datasets.

Surrogate datasets stand in for MiniLM/Cohere/DBpedia (see
repro/data/datasets.py); paper claims to validate: >=91% R@10 at ef=64
on every dataset, monotone recall in ef, hot memory << cold memory,
hot-memory growth sub-linear in D.
"""

from __future__ import annotations

from repro.core.baselines import recall_at_k

from benchmarks.common import (
    dataset, emit, ground_truth, index_for, timed_search,
)

DATASETS = ["minilm-surrogate", "cohere-surrogate", "dbpedia-surrogate"]
EFS = [16, 64, 256, 1024]


def run() -> list[dict]:
    rows = []
    for name in DATASETS:
        idx, build_s = index_for(name)
        _, queries = dataset(name)
        gt = ground_truth(name)
        mem = idx.memory_breakdown()
        for ef in EFS:
            pred, spq = timed_search(idx, queries, ef=ef)
            rows.append({
                "name": f"table5/{name}/ef{ef}",
                "us_per_call": round(spq * 1e6, 1),
                "recall_at_10": round(recall_at_k(pred, gt), 4),
                "qps": round(1.0 / spq, 1),
                "build_s": round(build_s, 1),
                "hot_mb": round(mem["hot_total_bytes"] / 2**20, 1),
                "cold_mb": round(mem["cold_vector_bytes"] / 2**20, 1),
            })
    return rows


if __name__ == "__main__":
    emit(run(), "table5")
