"""Serve-path load generator: the engine's QPS/p99 headline.

Closed- and open-loop traffic over the continuous-batching
:class:`~repro.serve.engine.QueryEngine` vs the per-call
``index.search`` baseline, on the same request mix (a blend of
singleton, small-batch and filtered requests — the shape RAG and
multi-tenant serving actually produce).  Records p50/p99 request
latency, QPS, plan-cache hit rate and the steady-state retrace count
into ``BENCH_serve.json`` via ``benchmarks/run.py``.

Knobs (all env):

* ``REPRO_SERVE_CLIENTS`` (8) — closed-loop concurrency;
* ``REPRO_SERVE_ROUNDS`` (20) — measured admission windows per phase;
* ``REPRO_SERVE_P99_MS`` (5000) — assertion threshold (toy scale);
* ``REPRO_SERVE_ASSERT`` (0) — enable the CI smoke assertions
  (nonzero QPS, p99 under threshold, zero steady-state retraces,
  plan-cache hit rate >= 0.95).
"""

from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import BENCH_Q, dataset, index_for
from repro.obs import ObsHub, autostart
from repro.plan import trace
from repro.serve.engine import QueryEngine

CLIENTS = int(os.environ.get("REPRO_SERVE_CLIENTS", 8))
ROUNDS = int(os.environ.get("REPRO_SERVE_ROUNDS", 20))
P99_MS = float(os.environ.get("REPRO_SERVE_P99_MS", 5000))
ASSERT = os.environ.get("REPRO_SERVE_ASSERT", "0") == "1"

DATASET = "minilm-surrogate"
N_LABELS = 4
FILTER_LABEL = 1
EF = 64
K = 10


def _request_mix(queries: np.ndarray, rng: np.random.Generator):
    """One closed-loop round of requests: per client a (queries, kwargs)
    pair — mostly small unfiltered batches, some singletons, some
    filtered — drawn from the query pool."""
    out = []
    for c in range(CLIENTS):
        size = [1, 2, 4, 4][c % 4]
        rows = rng.integers(0, len(queries), size)
        kwargs = {"ef": EF, "k": K}
        if c % 4 == 3:
            kwargs["filter"] = FILTER_LABEL
        out.append((queries[rows], kwargs))
    return out


def _percentiles(lat_s: list[float]) -> tuple[float, float]:
    a = np.asarray(lat_s, dtype=np.float64) * 1e3
    return float(np.percentile(a, 50)), float(np.percentile(a, 99))


def _closed_loop_engine(engine, rounds, queries, rng):
    """Every client keeps exactly one request in flight: submit all,
    pump one admission window, repeat."""
    lat, nq = [], 0
    t0 = time.perf_counter()
    for _ in range(rounds):
        tickets = []
        for q, kw in _request_mix(queries, rng):
            tickets.append(engine.submit(q, **kw))
            nq += len(q)
        engine.pump()
        for t in tickets:
            engine.result(t)
            lat.append(engine.ticket(t).latency)
    wall = time.perf_counter() - t0
    return lat, nq, wall


def _closed_loop_percall(index, rounds, queries, rng):
    """The pre-engine serving shape: one ``index.search`` per request."""
    lat, nq = [], 0
    t0 = time.perf_counter()
    for _ in range(rounds):
        for q, kw in _request_mix(queries, rng):
            t1 = time.perf_counter()
            index.search(q, **kw)
            lat.append(time.perf_counter() - t1)
            nq += len(q)
    wall = time.perf_counter() - t0
    return lat, nq, wall


def _open_loop_engine(engine, queries, rng, *, rate_qps, n_requests,
                      deadline_ms=None):
    """Fixed-rate arrivals: requests are submitted on their schedule
    regardless of completions (queueing shows up as latency), pumping
    one admission window per arrival step."""
    mix = [_request_mix(queries, rng)[i % CLIENTS]
           for i in range(n_requests)]
    mean_q = np.mean([len(q) for q, _ in mix])
    interval = mean_q / rate_qps
    tickets = []
    t0 = time.perf_counter()
    next_due = 0.0
    for q, kw in mix:
        # busy-wait to the arrival slot (intervals are sub-ms at toy
        # scale; sleep() granularity would distort the schedule)
        while time.perf_counter() - t0 < next_due:
            pass
        if deadline_ms is not None:
            kw = dict(kw, deadline_ms=deadline_ms)
        tickets.append(engine.submit(q, **kw))
        engine.pump()
        next_due += interval
    for t in tickets:
        if engine.poll(t) is None:
            engine.pump()
    wall = time.perf_counter() - t0
    lat = [engine.ticket(t).latency for t in tickets]
    nq = int(sum(len(q) for q, _ in mix))
    return lat, nq, wall


def run() -> list[dict]:
    rng = np.random.default_rng(7)
    base, queries = dataset(DATASET)
    queries = np.asarray(queries, dtype=np.float32)[:BENCH_Q]
    idx, _ = index_for(DATASET)
    if idx.labels is None:
        labels = np.random.default_rng(0).integers(0, N_LABELS, len(base))
        idx.attach_labels(list(labels), n_labels=N_LABELS)
        idx.build_label_entries(min_count=32)

    # telemetry (DESIGN.md §12): hub over the env-staged sinks
    # (launch/serve.py sets REPRO_OBS_JSONL/REPRO_OBS_INTERVAL_S), with
    # the periodic reporter pushing live stats_report snapshots and an
    # optional Prometheus endpoint on REPRO_METRICS_PORT
    engine = QueryEngine(idx, default_k=K, default_ef=EF,
                         obs=ObsHub.from_env())
    reporter, server = autostart(engine.obs, extra_fn=engine.stats_report,
                                 health_fn=engine.health_verdicts)
    # warm the closed plan set: unfiltered + filtered, singleton bucket
    # through the coalesced-round bucket
    buckets = (8, 32)
    engine.warmup(buckets=buckets,
                  configs=({}, {"filter": FILTER_LABEL}))
    # one throwaway round so every (plan, coalesced-bucket) pair the
    # workload produces is compiled before measurement starts
    _closed_loop_engine(engine, 2, queries, np.random.default_rng(7))

    rows = []
    steady = trace.snapshot(idx.plans.trace_prefix())

    lat, nq, wall = _closed_loop_engine(engine, ROUNDS, queries, rng)
    p50, p99 = _percentiles(lat)
    retraces = steady.delta()
    rep = engine.stats_report()
    rows.append({
        "name": "serve_closed_engine",
        "us_per_call": wall / nq * 1e6,
        "p50_ms": round(p50, 3), "p99_ms": round(p99, 3),
        "requests": len(lat), "queries": nq,
        "plan_hit_rate": round(rep["plan_hit_rate"], 4),
        "retraces_steady": retraces,
        "windows": rep["windows"], "batches": rep["batches"],
    })
    engine_qps = nq / wall

    lat_b, nq_b, wall_b = _closed_loop_percall(
        idx, ROUNDS, queries, np.random.default_rng(7)
    )
    p50_b, p99_b = _percentiles(lat_b)
    rows.append({
        "name": "serve_closed_percall",
        "us_per_call": wall_b / nq_b * 1e6,
        "p50_ms": round(p50_b, 3), "p99_ms": round(p99_b, 3),
        "requests": len(lat_b), "queries": nq_b,
    })
    baseline_qps = nq_b / wall_b

    # open loop at ~70% of measured closed-loop capacity
    lat_o, nq_o, wall_o = _open_loop_engine(
        engine, queries, rng, rate_qps=0.7 * engine_qps,
        n_requests=max(CLIENTS * ROUNDS // 2, 8),
    )
    p50_o, p99_o = _percentiles(lat_o)
    rows.append({
        "name": "serve_open_engine",
        "us_per_call": wall_o / nq_o * 1e6,
        "p50_ms": round(p50_o, 3), "p99_ms": round(p99_o, 3),
        "offered_qps": round(0.7 * engine_qps, 1),
        "requests": len(lat_o), "queries": nq_o,
    })

    # deadline pressure: budgets near the observed per-request p50
    # force the engine onto the ef-degradation ladder instead of
    # dropping (the heavy widened-ef filtered plan degrades first)
    deadline_ms = max(2.0 * p50_o, 1.0)
    pre_drop, pre_deg = engine.stats.dropped, engine.stats.degraded
    lat_d, nq_d, wall_d = _open_loop_engine(
        engine, queries, rng, rate_qps=1.5 * engine_qps,
        n_requests=max(CLIENTS * ROUNDS // 2, 8),
        deadline_ms=deadline_ms,
    )
    p50_d, p99_d = _percentiles(lat_d)
    rows.append({
        "name": "serve_deadline_mix",
        "us_per_call": wall_d / nq_d * 1e6,
        "p50_ms": round(p50_d, 3), "p99_ms": round(p99_d, 3),
        "deadline_ms": round(deadline_ms, 3),
        "degraded": engine.stats.degraded - pre_deg,
        "dropped": engine.stats.dropped - pre_drop,
        "requests": len(lat_d), "queries": nq_d,
    })

    rows.append({
        "name": "serve_summary",
        "engine_qps": round(engine_qps, 1),
        "percall_qps": round(baseline_qps, 1),
        "speedup": round(engine_qps / max(baseline_qps, 1e-9), 2),
        "plan_hit_rate": round(rep["plan_hit_rate"], 4),
        "retraces_steady": retraces,
        "plans_compiled": rep["plan_plans_compiled"],
    })

    if reporter is not None:
        reporter.stop()
    if server is not None:
        server.close()
    engine.obs.close()

    if ASSERT:
        assert engine_qps > 0, "engine QPS must be nonzero"
        assert p99 < P99_MS, f"closed-loop p99 {p99:.1f}ms >= {P99_MS}ms"
        assert retraces == 0, (
            f"steady-state serving retraced {retraces}x: "
            f"{steady.delta_by_program()}"
        )
        assert rep["plan_hit_rate"] >= 0.95, (
            f"plan-cache hit rate {rep['plan_hit_rate']:.3f} < 0.95"
        )
    return rows
