"""Paper Table 7 / Fig 3: the applicability boundary across nine
distribution tiers — now with the probe's *prediction* next to the
measured recall, so the boundary criterion is directly falsifiable
from one run (``run``), plus the auto-selection demonstration
(``run_boundary``, registered as the ``boundary`` suite).

Claims to validate: four-tier gradient (contrastive SOTA > multimodal
CLIP > cosine-native non-contrastive ~ low-rank synthetic > Euclidean-
native/random collapse), Finding 2 (recall monotone in ef everywhere),
Finding 4 (Synthetic-LR sits strictly between Random-Sphere and the
contrastive tier with everything else held fixed) — and, beyond the
paper, that the training-free probe *predicts* each tier's verdict and
that ``nav="auto"`` turns the red tiers from a collapse into a served
workload (DESIGN.md §10).
"""

from __future__ import annotations

from repro.core.baselines import recall_at_k
from repro.probe import probe_corpus

from benchmarks.common import (
    dataset, emit, ground_truth, index_for, timed_search,
)

DATASETS = [
    "random-sphere", "gist-like", "sift-like", "synthetic-lr",
    "glove-like", "redcaps-surrogate", "minilm-surrogate",
    "cohere-surrogate", "dbpedia-surrogate",
]

# the auto-selection demonstration: one corpus per side of the boundary
# (cosine-native contrastive vs Euclidean-native CV vs isotropic)
BOUNDARY_DATASETS = ["minilm-surrogate", "sift-like", "random-sphere"]


def run() -> list[dict]:
    rows = []
    for name in DATASETS:
        idx, build_s = index_for(name)
        base, queries = dataset(name)
        gt = ground_truth(name)
        report = probe_corpus(base, seed=0)
        r_by_ef = {}
        for ef in (64, 256):
            pred, spq = timed_search(idx, queries, ef=ef)
            r_by_ef[ef] = recall_at_k(pred, gt)
        rows.append({
            "name": f"table7/{name}",
            "us_per_call": round(spq * 1e6, 1),
            "recall_ef64": round(r_by_ef[64], 4),
            "recall_ef256": round(r_by_ef[256], 4),
            "monotone": r_by_ef[256] >= r_by_ef[64] - 0.02,
            "build_s": round(build_s, 1),
            # probe prediction vs measurement: red must line up with
            # the collapse tiers, green with the contrastive tiers
            "probe_verdict": report.verdict,
            "probe_agreement": round(report.bq_agreement, 4),
            "probe_sign_entropy": round(report.sign_entropy, 4),
            "probe_cos_std": round(report.cos_std, 4),
        })
    return rows


def run_boundary() -> list[dict]:
    """Auto-selection across the boundary: for each side, the probe
    verdict, the nav kind ``nav="auto"`` picked, recall/QPS/memory
    under the auto policy, and the same corpus forced onto bq2
    navigation — the paper's collapse, now routed around."""
    rows = []
    for name in BOUNDARY_DATASETS:
        base, queries = dataset(name)
        gt = ground_truth(name)
        auto_idx, build_s = index_for(name, metric="auto")
        forced_idx, _ = index_for(name)          # plain bq2 build
        pred_auto, spq_auto = timed_search(auto_idx, queries, ef=64)
        pred_bq2, spq_bq2 = timed_search(forced_idx, queries, ef=64)
        mem = auto_idx.memory_breakdown()
        report = auto_idx.report
        rows.append({
            "name": f"boundary/{name}",
            "us_per_call": round(spq_auto * 1e6, 1),
            "probe_verdict": report.verdict,
            "probe_agreement": round(report.bq_agreement, 4),
            "selected_nav": auto_idx.metric_kind,
            "nav_policy": auto_idx.policy.describe(),
            "recall_auto": round(recall_at_k(pred_auto, gt), 4),
            "recall_forced_bq2": round(recall_at_k(pred_bq2, gt), 4),
            "us_per_call_bq2": round(spq_bq2 * 1e6, 1),
            "hot_bytes": mem["hot_total_bytes"],
            "total_bytes": mem["total_bytes"],
            "build_s": round(build_s, 1),
        })
    return rows


if __name__ == "__main__":
    emit(run(), "table7")
    emit(run_boundary(), "boundary")
