"""Paper Table 7 / Fig 3: the applicability boundary across nine
distribution tiers.

Claims to validate: four-tier gradient (contrastive SOTA > multimodal
CLIP > cosine-native non-contrastive ~ low-rank synthetic > Euclidean-
native/random collapse), Finding 2 (recall monotone in ef everywhere),
Finding 4 (Synthetic-LR sits strictly between Random-Sphere and the
contrastive tier with everything else held fixed).
"""

from __future__ import annotations

from repro.core.baselines import recall_at_k

from benchmarks.common import (
    dataset, emit, ground_truth, index_for, timed_search,
)

DATASETS = [
    "random-sphere", "gist-like", "sift-like", "synthetic-lr",
    "glove-like", "redcaps-surrogate", "minilm-surrogate",
    "cohere-surrogate", "dbpedia-surrogate",
]


def run() -> list[dict]:
    rows = []
    for name in DATASETS:
        idx, build_s = index_for(name)
        _, queries = dataset(name)
        gt = ground_truth(name)
        r_by_ef = {}
        for ef in (64, 256):
            pred, spq = timed_search(idx, queries, ef=ef)
            r_by_ef[ef] = recall_at_k(pred, gt)
        rows.append({
            "name": f"table7/{name}",
            "us_per_call": round(spq * 1e6, 1),
            "recall_ef64": round(r_by_ef[64], 4),
            "recall_ef256": round(r_by_ef[256], 4),
            "monotone": r_by_ef[256] >= r_by_ef[64] - 0.02,
            "build_s": round(build_s, 1),
        })
    return rows


if __name__ == "__main__":
    emit(run(), "table7")
