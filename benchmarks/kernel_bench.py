"""Hot-kernel microbenchmarks + TPU roofline projection.

The Pallas kernels execute in interpret mode on CPU (correctness, not
speed), so wall-clock here times the pure-jnp hot path; the ``derived``
column projects TPU v5e performance from first principles:

    symmetric BQ distance streams (2W words x 4 B) per base row
      -> pairs/s at HBM roofline = 819 GB/s / (D/4 B)
    vs float32 dot: 4D B per row -> 16x fewer pairs/s at the same
    bandwidth — the TPU restatement of the paper's "20x cheaper per
    hop" claim (theirs is compute-bound AVX-512; ours is bandwidth-
    bound VPU, and the 16x is exactly the compression ratio).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bq
from repro.core.baselines import recall_at_k
from repro.core.beam import batched_beam_search

from benchmarks.common import dataset, emit, ground_truth, index_for

HBM_BW = 819e9


def beam_width_sweep(ef: int = 64, k: int = 10) -> list[dict]:
    """Multi-expansion beam search: recall at equal distance-eval budget.

    The beam expansion width L turns the per-hop distance batch from
    (R,) into (L*R,) — the shape a Pallas/VPU kernel wants.  Budget is
    held constant across L by capping hops at ceil(H1 / L), where H1 is
    the greedy (L=1) run's natural mean hop count, so every row spends
    ~H1*R distance evaluations per query.
    """
    idx, _ = index_for("minilm-surrogate")
    _, queries = dataset("minilm-surrogate")
    gt = ground_truth("minilm-surrogate", k=k)
    backend = idx.backend()
    q = jnp.asarray(queries, jnp.float32)
    q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    reprs = backend.encode_queries(q)
    n = idx.sigs.words.shape[0]
    r = idx.adjacency.shape[1]

    def rerank(res):
        from repro.core.index import _rerank_f32
        ids, _ = _rerank_f32(res.ids, q, idx.vectors, k)
        return np.asarray(ids)

    # greedy reference: its natural fresh-evaluation count defines the
    # shared budget; every L (including 1) then runs under the same
    # max_evals cap, so no width gets free extra distance evaluations.
    # (Fresh evals — not hop slots — are the hardware-honest budget:
    # each fresh eval is one popcount row regardless of batch shape.)
    res1 = batched_beam_search(
        reprs, idx.adjacency, jnp.int32(idx.medoid),
        dist_fn=backend.dist_fn, ef=ef, n=n, expand=1,
    )
    budget = int(round(float(np.asarray(res1.evals).mean())))

    rows = []
    for expand in (1, 2, 4):
        run = jax.jit(lambda rep: batched_beam_search(
            rep, idx.adjacency, jnp.int32(idx.medoid),
            dist_fn=backend.dist_fn, ef=ef, n=n, expand=expand,
            max_evals=budget,
        ))
        res = run(reprs)
        jax.block_until_ready(res)
        t0 = time.perf_counter()
        res = run(reprs)
        jax.block_until_ready(res)
        dt = time.perf_counter() - t0
        rows.append({
            "name": f"kernel/beam_expand_L{expand}",
            "us_per_call": round(dt * 1e6 / len(queries), 1),
            "recall_at_10": round(recall_at_k(rerank(res), gt), 4),
            "mean_hops": round(float(np.asarray(res.hops).mean()), 1),
            "dist_evals_per_query": round(
                float(np.asarray(res.evals).mean()), 1),
            "eval_budget": budget,
            "dist_batch_width": expand * r,
        })
    return rows


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    for dim in (384, 768, 1536):
        n = 100_000
        base = jnp.asarray(rng.standard_normal((n, dim)), jnp.float32)
        sigs = bq.encode(base)
        q = bq.encode(base[:8])

        fn = jax.jit(lambda a, b: bq.pairwise_distance(
            bq.Signature(a, dim), bq.Signature(b, dim)))
        fn(q.words, sigs.words).block_until_ready()
        t0 = time.perf_counter()
        fn(q.words, sigs.words).block_until_ready()
        dt = time.perf_counter() - t0
        pairs = 8 * n
        bytes_per_row = sigs.words.shape[-1] * 4
        tpu_pairs_per_s = HBM_BW / bytes_per_row
        f32_pairs_per_s = HBM_BW / (4 * dim)
        rows.append({
            "name": f"kernel/bq_distance_d{dim}",
            "us_per_call": round(dt * 1e6, 1),
            "cpu_mpairs_per_s": round(pairs / dt / 1e6, 1),
            "tpu_roofline_mpairs_per_s": round(tpu_pairs_per_s / 1e6, 1),
            "tpu_f32_roofline_mpairs_per_s": round(f32_pairs_per_s / 1e6,
                                                   1),
            "bq_vs_f32_bandwidth_advantage": round(
                tpu_pairs_per_s / f32_pairs_per_s, 1),
        })

        # binarize throughput (stage-0 bulk pre-installation)
        x32 = base[:20_000]
        enc = jax.jit(lambda v: bq.encode(v).words)
        enc(x32).block_until_ready()
        t0 = time.perf_counter()
        enc(x32).block_until_ready()
        dt = time.perf_counter() - t0
        rows.append({
            "name": f"kernel/binarize_d{dim}",
            "us_per_call": round(dt * 1e6 / len(x32), 2),
            "cpu_mvecs_per_s": round(len(x32) / dt / 1e6, 2),
            "tpu_roofline_mvecs_per_s": round(
                HBM_BW / (4 * dim) / 1e6, 1),
        })
    rows.extend(beam_width_sweep())
    return rows


if __name__ == "__main__":
    emit(run(), "kernel_bench")
