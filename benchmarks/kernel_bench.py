"""Hot-kernel microbenchmarks + TPU roofline projection.

The Pallas kernels execute in interpret mode on CPU (correctness, not
speed), so wall-clock here times the pure-jnp hot path; the ``derived``
column projects TPU v5e performance from first principles:

    symmetric BQ distance streams (2W words x 4 B) per base row
      -> pairs/s at HBM roofline = 819 GB/s / (D/4 B)
    vs float32 dot: 4D B per row -> 16x fewer pairs/s at the same
    bandwidth — the TPU restatement of the paper's "20x cheaper per
    hop" claim (theirs is compute-bound AVX-512; ours is bandwidth-
    bound VPU, and the 16x is exactly the compression ratio).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bq
from repro.kernels import ops

from benchmarks.common import emit

HBM_BW = 819e9


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    for dim in (384, 768, 1536):
        n = 100_000
        base = jnp.asarray(rng.standard_normal((n, dim)), jnp.float32)
        sigs = bq.encode(base)
        q = bq.encode(base[:8])

        fn = jax.jit(lambda a, b: bq.pairwise_distance(
            bq.Signature(a, dim), bq.Signature(b, dim)))
        fn(q.words, sigs.words).block_until_ready()
        t0 = time.perf_counter()
        fn(q.words, sigs.words).block_until_ready()
        dt = time.perf_counter() - t0
        pairs = 8 * n
        bytes_per_row = sigs.words.shape[-1] * 4
        tpu_pairs_per_s = HBM_BW / bytes_per_row
        f32_pairs_per_s = HBM_BW / (4 * dim)
        rows.append({
            "name": f"kernel/bq_distance_d{dim}",
            "us_per_call": round(dt * 1e6, 1),
            "cpu_mpairs_per_s": round(pairs / dt / 1e6, 1),
            "tpu_roofline_mpairs_per_s": round(tpu_pairs_per_s / 1e6, 1),
            "tpu_f32_roofline_mpairs_per_s": round(f32_pairs_per_s / 1e6,
                                                   1),
            "bq_vs_f32_bandwidth_advantage": round(
                tpu_pairs_per_s / f32_pairs_per_s, 1),
        })

        # binarize throughput (stage-0 bulk pre-installation)
        x32 = base[:20_000]
        enc = jax.jit(lambda v: bq.encode(v).words)
        enc(x32).block_until_ready()
        t0 = time.perf_counter()
        enc(x32).block_until_ready()
        dt = time.perf_counter() - t0
        rows.append({
            "name": f"kernel/binarize_d{dim}",
            "us_per_call": round(dt * 1e6 / len(x32), 2),
            "cpu_mvecs_per_s": round(len(x32) / dt / 1e6, 2),
            "tpu_roofline_mvecs_per_s": round(
                HBM_BW / (4 * dim) / 1e6, 1),
        })
    return rows


if __name__ == "__main__":
    emit(run(), "kernel_bench")
