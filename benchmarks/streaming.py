"""Streaming churn: the freshness/recall curve the paper never measures.

A mutable index starts from a batch build, then survives churn cycles
(default 10) of delete-5% / insert-5%.  After every cycle we measure
Recall@10 against exact ground truth over the *current* live corpus —
once with consolidation after each cycle and once without — plus
insert/delete/consolidate throughput.  The last row compares the
churned index against a from-scratch rebuild of the final corpus: the
acceptance bar is recall within 3 points of the rebuild (consolidated
path).

Scale knobs: REPRO_STREAM_N (initial corpus, default min(BENCH_N,
4000)), REPRO_STREAM_ROUNDS (default 10), REPRO_STREAM_CHURN (fraction
per cycle, default 0.05).
"""

from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.baselines import flat_search, recall_at_k
from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset
from repro.stream import MutableQuIVerIndex

from benchmarks.common import BENCH_N, BENCH_Q

NAME = "minilm-surrogate"
STREAM_N = int(os.environ.get("REPRO_STREAM_N", min(BENCH_N, 4000)))
ROUNDS = int(os.environ.get("REPRO_STREAM_ROUNDS", 10))
CHURN = float(os.environ.get("REPRO_STREAM_CHURN", 0.05))

PARAMS = BuildParams(m=8, ef_construction=64, prune_pool=64, chunk=256)
EF, K = 64, 10


class _Corpus:
    """Host-side mirror of the live set: slot id <-> vector."""

    def __init__(self, vectors: np.ndarray, slots: np.ndarray):
        self.vectors = list(vectors)
        self.slots = list(int(s) for s in slots)

    def delete(self, rng: np.random.Generator, frac: float) -> np.ndarray:
        n_kill = max(1, int(len(self.slots) * frac))
        pick = rng.choice(len(self.slots), size=n_kill, replace=False)
        killed = np.asarray([self.slots[i] for i in pick])
        keep = np.ones(len(self.slots), dtype=bool)
        keep[pick] = False
        self.vectors = [v for v, m in zip(self.vectors, keep) if m]
        self.slots = [s for s, m in zip(self.slots, keep) if m]
        return killed

    def insert(self, vectors: np.ndarray, slots: np.ndarray) -> None:
        self.vectors.extend(vectors)
        self.slots.extend(int(s) for s in slots)

    def ground_truth(self, queries: np.ndarray, k: int) -> np.ndarray:
        mat = np.stack(self.vectors)
        gt_pos, _ = flat_search(mat, queries, k=k)
        return np.asarray(self.slots)[gt_pos]


def _churn_run(base, fresh_pool, queries, *, consolidate: bool):
    """One full churn experiment; returns (rows, final corpus, index)."""
    rng = np.random.default_rng(0)
    capacity = int(len(base) * (1 + CHURN * (ROUNDS + 1)) + 512)
    idx = MutableQuIVerIndex.build(
        jnp.asarray(base), PARAMS, capacity=capacity
    )
    corpus = _Corpus(base, np.arange(len(base)))
    tag = "consol" if consolidate else "noconsol"
    rows, pool_pos = [], 0

    for rnd in range(1, ROUNDS + 1):
        kill = corpus.delete(rng, CHURN)
        t0 = time.perf_counter()
        idx.delete(kill)
        t_del = time.perf_counter() - t0

        n_new = len(kill)
        new_vecs = fresh_pool[pool_pos:pool_pos + n_new]
        pool_pos += n_new
        t0 = time.perf_counter()
        slots = idx.insert(jnp.asarray(new_vecs))
        t_ins = time.perf_counter() - t0
        corpus.insert(new_vecs, slots)

        t_con = 0.0
        if consolidate:
            t0 = time.perf_counter()
            idx.consolidate()
            t_con = time.perf_counter() - t0

        gt = corpus.ground_truth(queries, K)
        pred, _ = idx.search(jnp.asarray(queries), k=K, ef=EF)
        rows.append({
            "name": f"streaming/{tag}_round{rnd}",
            "us_per_call": round(t_ins * 1e6 / n_new, 1),  # per insert
            "recall": round(recall_at_k(pred, gt), 4),
            "n_live": idx.n_live,
            "insert_per_s": round(n_new / t_ins, 1),
            "delete_per_s": round(n_new / t_del, 1),
            "consolidate_s": round(t_con, 3),
        })
    return rows, corpus, idx


def run() -> list[dict]:
    total = int(STREAM_N * (1 + CHURN * (ROUNDS + 1))) + 64
    allvecs, queries = make_dataset(NAME, n=total, queries=BENCH_Q)
    base, fresh_pool = allvecs[:STREAM_N], allvecs[STREAM_N:]

    rows_c, corpus, idx = _churn_run(
        base, fresh_pool, queries, consolidate=True
    )
    rows_n, _, _ = _churn_run(
        base, fresh_pool, queries, consolidate=False
    )
    rows = rows_c + rows_n

    # from-scratch rebuild of the final (consolidated-path) corpus
    mat = np.stack(corpus.vectors)
    t0 = time.perf_counter()
    rebuilt = QuIVerIndex.build(jnp.asarray(mat), PARAMS)
    t_build = time.perf_counter() - t0
    gt_pos, _ = flat_search(mat, queries, k=K)
    pred_pos, _ = rebuilt.search(jnp.asarray(queries), k=K, ef=EF)
    rebuild_recall = recall_at_k(pred_pos, gt_pos)

    gt = np.asarray(corpus.slots)[gt_pos]
    pred, _ = idx.search(jnp.asarray(queries), k=K, ef=EF)
    churned_recall = recall_at_k(pred, gt)

    rows.append({
        "name": "streaming/final_vs_rebuild",
        "us_per_call": round(t_build * 1e6 / len(mat), 1),
        "churned_recall": round(churned_recall, 4),
        "rebuild_recall": round(rebuild_recall, 4),
        "delta_points": round(100 * (rebuild_recall - churned_recall), 2),
        "rounds": ROUNDS,
        "churn": CHURN,
    })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run(), "streaming")
