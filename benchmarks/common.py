"""Shared benchmark plumbing: timing, dataset/index caches, CSV rows."""

from __future__ import annotations

import json
import os
import pathlib
import time

import jax.numpy as jnp

from repro.core.baselines import flat_search
from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset

OUT_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "bench"

# benchmark scale (1M in the paper; reduced for the CPU container —
# override with REPRO_BENCH_N)
BENCH_N = int(os.environ.get("REPRO_BENCH_N", 10_000))
BENCH_Q = int(os.environ.get("REPRO_BENCH_Q", 200))

DEFAULT_PARAMS = BuildParams(
    m=16, ef_construction=96, prune_pool=96, chunk=256
)

_dataset_cache: dict = {}
_index_cache: dict = {}
_gt_cache: dict = {}


def dataset(name: str, n: int = None, q: int = None):
    n, q = n or BENCH_N, q or BENCH_Q
    key = (name, n, q)
    if key not in _dataset_cache:
        _dataset_cache[key] = make_dataset(name, n=n, queries=q)
    return _dataset_cache[key]


def index_for(name: str, params: BuildParams = None, **build_kw):
    params = params or DEFAULT_PARAMS
    key = (name, params, tuple(sorted(build_kw.items())))
    if key not in _index_cache:
        base, _ = dataset(name)
        t0 = time.perf_counter()
        idx = QuIVerIndex.build(jnp.asarray(base), params, **build_kw)
        bt = time.perf_counter() - t0
        _index_cache[key] = (idx, bt)
    return _index_cache[key]


def ground_truth(name: str, k: int = 10):
    key = (name, k)
    if key not in _gt_cache:
        base, queries = dataset(name)
        _gt_cache[key] = flat_search(base, queries, k=k)[0]
    return _gt_cache[key]


def timed_search(idx, queries, *, ef: int, k: int = 10, nav="bq2",
                 expand: int = 1, repeats: int = 2):
    """Returns (pred_ids, seconds_per_query)."""
    q = jnp.asarray(queries)
    pred, _ = idx.search(q, k=k, ef=ef, nav=nav, expand=expand)  # warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        pred, _ = idx.search(q, k=k, ef=ef, nav=nav, expand=expand)
    dt = (time.perf_counter() - t0) / repeats / len(queries)
    return pred, dt


def emit(rows: list[dict], table: str):
    """Print the harness CSV and persist the JSON artifact."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / f"{table}.json").write_text(json.dumps(rows, indent=2))
    for r in rows:
        us = r.get("us_per_call", "")
        derived = ";".join(
            f"{k}={v}" for k, v in r.items()
            if k not in ("name", "us_per_call")
        )
        print(f"{r['name']},{us},{derived}")
