"""Shared benchmark plumbing: timing, dataset/index caches, CSV rows."""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import time

import jax.numpy as jnp

from repro.core.baselines import flat_search
from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset

OUT_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "bench"

# benchmark scale (1M in the paper; reduced for the CPU container —
# override with REPRO_BENCH_N)
BENCH_N = int(os.environ.get("REPRO_BENCH_N", 10_000))
BENCH_Q = int(os.environ.get("REPRO_BENCH_Q", 200))

DEFAULT_PARAMS = BuildParams(
    m=16, ef_construction=96, prune_pool=96, chunk=256
)

_dataset_cache: dict = {}
_index_cache: dict = {}
_gt_cache: dict = {}


def dataset(name: str, n: int = None, q: int = None):
    n, q = n or BENCH_N, q or BENCH_Q
    key = (name, n, q)
    if key not in _dataset_cache:
        _dataset_cache[key] = make_dataset(name, n=n, queries=q)
    return _dataset_cache[key]


def index_for(name: str, params: BuildParams = None, **build_kw):
    params = params or DEFAULT_PARAMS
    key = (name, params, tuple(sorted(build_kw.items())))
    if key not in _index_cache:
        base, _ = dataset(name)
        t0 = time.perf_counter()
        idx = QuIVerIndex.build(jnp.asarray(base), params, **build_kw)
        bt = time.perf_counter() - t0
        _index_cache[key] = (idx, bt)
    return _index_cache[key]


def ground_truth(name: str, k: int = 10):
    key = (name, k)
    if key not in _gt_cache:
        base, queries = dataset(name)
        _gt_cache[key] = flat_search(base, queries, k=k)[0]
    return _gt_cache[key]


def timed_search(idx, queries, *, ef: int, k: int = 10, nav=None,
                 expand: int = 1, repeats: int = 2):
    """Returns (pred_ids, seconds_per_query).

    ``nav=None`` searches in the index's own metric (and applies its
    NavPolicy schedule when it was built with ``nav="auto"``); pass a
    kind explicitly to force a navigation space.
    """
    q = jnp.asarray(queries)
    pred, _ = idx.search(q, k=k, ef=ef, nav=nav, expand=expand)  # warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        pred, _ = idx.search(q, k=k, ef=ef, nav=nav, expand=expand)
    dt = (time.perf_counter() - t0) / repeats / len(queries)
    return pred, dt


def emit(rows: list[dict], table: str):
    """Print the harness CSV and persist the JSON artifact."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / f"{table}.json").write_text(json.dumps(rows, indent=2))
    for r in rows:
        us = r.get("us_per_call", "")
        derived = ";".join(
            f"{k}={v}" for k, v in r.items()
            if k not in ("name", "us_per_call")
        )
        print(f"{r['name']},{us},{derived}")


# bump when the BENCH_*.json payload shape changes incompatibly
BENCH_SCHEMA_VERSION = 2


def _git_sha() -> str | None:
    """Current commit SHA for artifact provenance (None outside git)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=OUT_DIR.parents[1], capture_output=True, text=True,
            timeout=10,
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def provenance() -> dict:
    """Who/what produced this artifact: git SHA, schema version, and an
    echo of every ``REPRO_*`` env knob that shaped the run — so a
    BENCH_*.json from six months ago answers "what exactly ran?" by
    itself instead of via archaeology on CI logs."""
    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "git_sha": _git_sha(),
        "config": {
            k: v for k, v in sorted(os.environ.items())
            if k.startswith("REPRO_")
        },
    }


def write_bench_json(rows: list[dict], table: str, extra: dict = None) -> str:
    """Record the suite's results as ``BENCH_<table>.json`` at the repo
    root — the machine-readable perf-trajectory artifact (one file per
    suite, overwritten per run; the git history is the trajectory).

    Each row keeps whatever the suite measured (recall/memory/...);
    ``qps`` is derived from ``us_per_call`` where present.  Every
    payload is stamped with :func:`provenance`; ``extra`` merges
    suite-specific top-level fields (e.g. per-tenant summaries).
    """
    out_rows = []
    for r in rows:
        row = dict(r)
        us = row.get("us_per_call")
        if us:
            row["qps"] = round(1e6 / us, 1)
        out_rows.append(row)
    payload = {
        "table": table,
        "bench_n": BENCH_N,
        "bench_q": BENCH_Q,
        "generated_unix": round(time.time(), 1),
        "provenance": provenance(),
        "rows": out_rows,
    }
    if extra:
        payload.update(extra)
    path = OUT_DIR.parents[1] / f"BENCH_{table}.json"
    path.write_text(json.dumps(payload, indent=2) + "\n")
    return str(path)
