"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [table5 table7 ...]

Prints ``name,us_per_call,derived`` CSV rows (the harness contract) and
writes JSON artifacts to experiments/bench/.  Scale via REPRO_BENCH_N
(default 10k vectors; the paper uses 1M — constants scale, orderings
don't).
"""

from __future__ import annotations

import sys
import time

from benchmarks import (
    ablation_adc,
    ablation_bits,
    construction,
    filtered,
    kernel_bench,
    streaming,
    table2_memory,
    table5_recall_qps,
    table6_baselines,
    table7_boundary,
)
from benchmarks.common import emit

TABLES = {
    "kernel_bench": kernel_bench,
    "table2": table2_memory,
    "table5": table5_recall_qps,
    "table6": table6_baselines,
    "table7": table7_boundary,
    "ablation_adc": ablation_adc,
    "ablation_bits": ablation_bits,
    "construction": construction,
    "streaming": streaming,
    "filtered": filtered,
}


def main() -> None:
    names = sys.argv[1:] or list(TABLES)
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.perf_counter()
        rows = TABLES[name].run()
        emit(rows, name)
        print(f"# {name} done in {time.perf_counter()-t0:.0f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
