"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [table5 table7 ...]

Prints ``name,us_per_call,derived`` CSV rows (the harness contract),
writes JSON artifacts to experiments/bench/, and records each suite as
a machine-readable ``BENCH_<name>.json`` at the repo root (the perf
trajectory: recall/QPS/memory per config, one artifact per suite).
Scale via REPRO_BENCH_N (default 10k vectors; the paper uses 1M —
constants scale, orderings don't).
"""

from __future__ import annotations

import sys
import time

from benchmarks import (
    ablation_adc,
    ablation_bits,
    construction,
    filtered,
    graphhealth,
    kernel_bench,
    multitenant,
    quality,
    serve,
    streaming,
    table2_memory,
    table5_recall_qps,
    table6_baselines,
    table7_boundary,
)
from benchmarks.common import emit, write_bench_json

TABLES = {
    "kernel_bench": kernel_bench.run,
    "table2": table2_memory.run,
    "table5": table5_recall_qps.run,
    "table6": table6_baselines.run,
    "table7": table7_boundary.run,
    "boundary": table7_boundary.run_boundary,
    "ablation_adc": ablation_adc.run,
    "ablation_bits": ablation_bits.run,
    "construction": construction.run,
    "streaming": streaming.run,
    "filtered": filtered.run,
    "serve": serve.run,
    "multitenant": multitenant.run,
    "quality": quality.run,
    "graphhealth": graphhealth.run,
}


def main() -> None:
    names = sys.argv[1:] or list(TABLES)
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.perf_counter()
        rows = TABLES[name]()
        # a suite may return (rows, extra) to stamp suite-level fields
        # (e.g. the multitenant tenant/drift reports) into its artifact
        extra = None
        if isinstance(rows, tuple):
            rows, extra = rows
        emit(rows, name)
        path = write_bench_json(rows, name, extra)
        print(f"# {name} done in {time.perf_counter()-t0:.0f}s "
              f"-> {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
