#!/usr/bin/env python3
"""Runtime-tuned launcher for the serve benchmark / QueryEngine.

    python launch/serve.py [benchmarks.run args...]   # default: serve

Allocator and XLA runtime knobs must be in place *before* the process
that imports jax starts — LD_PRELOAD is read by the dynamic linker and
XLA_FLAGS at backend init — so this script sets up the environment and
``exec``s a fresh interpreter running ``benchmarks.run`` rather than
importing anything heavy itself.

What it applies (the SNIPPETS.md 1-2 serving recipe):

* **tcmalloc preload** — glibc malloc fragments badly under the serve
  engine's steady stream of short-lived numpy result buffers; tcmalloc's
  thread caches keep host-side staging allocations cheap.  Preloaded
  when a system copy exists, otherwise launch proceeds with a pointer to
  the package that provides it (we never install anything ourselves).
* ``TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD`` — silence tcmalloc's
  large-alloc warnings for corpus-sized arrays.
* ``TF_CPP_MIN_LOG_LEVEL=4`` — mute the XLA/TSL C++ log spew that
  otherwise interleaves with benchmark CSV output.
* ``XLA_FLAGS --xla_force_host_platform_device_count=1`` — pin the CPU
  backend to ONE host device.  The engine already owns batching (the
  admission queue coalesces into the bucket ladder); letting XLA split
  the host into N virtual devices would shard those carefully-shaped
  batches and retrace per shard.  An existing value in ``XLA_FLAGS`` is
  respected (appended, not replaced).
* ``JAX_ENABLE_X64=0`` — keep everything in 32-bit; the hot path is
  2-bit signatures and float32 rerank, fp64 would double rerank traffic.
"""

from __future__ import annotations

import os
import pathlib
import sys

TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
    "/usr/lib/aarch64-linux-gnu/libtcmalloc_minimal.so.4",
)

PIN_FLAG = "--xla_force_host_platform_device_count=1"


def tuned_env() -> dict:
    env = dict(os.environ)

    tcmalloc = next(
        (p for p in TCMALLOC_CANDIDATES if pathlib.Path(p).exists()), None
    )
    if tcmalloc:
        preload = env.get("LD_PRELOAD", "")
        if tcmalloc not in preload:
            env["LD_PRELOAD"] = f"{preload}:{tcmalloc}".strip(":")
        env.setdefault(
            "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD", "60000000000"
        )
        print(f"[launch/serve] tcmalloc: {tcmalloc}", file=sys.stderr)
    else:
        print(
            "[launch/serve] tcmalloc not found; running with glibc "
            "malloc (install libgoogle-perftools4 / gperftools for the "
            "preload path)",
            file=sys.stderr,
        )

    env.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    env.setdefault("JAX_ENABLE_X64", "0")

    # observability defaults (DESIGN.md §12): the serve/multitenant
    # benchmarks build an ObsHub from these — periodic reports land as
    # JSONL under experiments/obs/, and REPRO_METRICS_PORT (opt-in, no
    # default: it opens a listening socket) serves the same registry as
    # a Prometheus text endpoint at /metrics.
    repo = pathlib.Path(__file__).resolve().parents[1]
    env.setdefault(
        "REPRO_OBS_JSONL", str(repo / "experiments" / "obs" / "serve.jsonl")
    )
    env.setdefault("REPRO_OBS_INTERVAL_S", "5")

    xla_flags = env.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in xla_flags:
        env["XLA_FLAGS"] = f"{xla_flags} {PIN_FLAG}".strip()

    src = str(repo / "src")
    pypath = env.get("PYTHONPATH", "")
    if src not in pypath.split(os.pathsep):
        env["PYTHONPATH"] = os.pathsep.join(p for p in (src, pypath) if p)
    return env


def main() -> None:
    env = tuned_env()
    tables = sys.argv[1:] or ["serve"]
    argv = [sys.executable, "-m", "benchmarks.run", *tables]
    print(f"[launch/serve] exec: {' '.join(argv)}", file=sys.stderr)
    repo = pathlib.Path(__file__).resolve().parents[1]
    os.chdir(repo)
    os.execve(sys.executable, argv, env)


if __name__ == "__main__":
    main()
