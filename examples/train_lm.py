"""End-to-end training driver: a ~100M-param LM for a few hundred steps.

Exercises the full substrate — data pipeline, microbatch accumulation,
AdamW + cosine schedule, checkpointing, straggler monitor — at a scale a
CPU can run.  (Full-size configs go through repro.launch.train /
repro.launch.dryrun.)

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses


from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: a scaled-down yi-34b family member
    cfg = dataclasses.replace(
        get_config("yi-34b"),
        name="yi-100m",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=8192, remat=False, kv_chunk=256,
    )
    print(f"{cfg.name}: {cfg.param_count()/1e6:.0f}M params")
    bundle = build_model(cfg)

    tc = TrainConfig(
        n_micro=2, peak_lr=1e-3, warmup=50, total_steps=args.steps,
        schedule="cosine", adamw=AdamWConfig(),
    )
    pipeline = TokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=256, global_batch=8
    ))
    trainer = Trainer(
        bundle, tc,
        TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=100, log_every=20),
        pipeline,
    )
    result = trainer.run()
    losses = [m["loss"] for m in result["metrics"]]
    for m in result["metrics"]:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"{m['seconds']*1e3:.0f} ms")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'OK: decreased' if losses[-1] < losses[0] else 'FLAT'})")


if __name__ == "__main__":
    main()
