"""Telemetry walkthrough: metrics, spans, tenants, drift, recall SLOs
(DESIGN.md §12, §14).

A compressed tour of the observability layer: a QueryEngine serving two
tenants (one quota'd) with its metrics streamed to a JSONL sink and
scrapable as Prometheus text, a drifting streaming corpus raising a
probe-drift alarm, and the shadow ground-truth lane turning that drift
into a recall-SLO breach the remediation ladder answers.

    PYTHONPATH=src python examples/telemetry.py
"""

import json
import pathlib
import urllib.request

import jax.numpy as jnp
import numpy as np

from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset
from repro.obs import (
    JsonlSink,
    ObsHub,
    PrometheusServer,
    RemediationPolicy,
    health_snapshot,
)
from repro.serve.engine import QueryEngine
from repro.stream.mutable import MutableQuIVerIndex


def main():
    base, queries = make_dataset("minilm-surrogate", n=4000, queries=32)
    queries = np.asarray(queries, np.float32)
    index = QuIVerIndex.build(
        jnp.asarray(base),
        BuildParams(m=16, ef_construction=96, prune_pool=96, chunk=256),
    )

    # 1. an engine with a JSONL sink: every emit_report() appends one
    # self-contained snapshot record (metrics + spans + stats_report)
    out = pathlib.Path("experiments/obs/telemetry_example.jsonl")
    out.unlink(missing_ok=True)
    hub = ObsHub(sinks=[JsonlSink(out)])
    engine = QueryEngine(index, default_k=5, default_ef=64, obs=hub)

    # 2. two tenants: "paid" is unconstrained, "free" gets a token
    # bucket of 2 sustained qps with burst 4 — its fifth-in-a-burst
    # request is rejected instantly, without touching paid's traffic
    engine.set_quota("free", qps=2.0, burst=4)
    for i in range(8):
        engine.submit(queries[i % 4], tenant="paid")
        engine.submit(queries[i % 4], tenant="free")
    engine.pump()
    rep = engine.stats_report()
    for name, t in rep["tenant_report"]["tenants"].items():
        print(f"tenant {name}: admitted={t['admitted']} "
              f"rejected={t['rejected']} p50={t['p50_ms']}ms")
    counts = {k: v["count"] for k, v in rep["span_report"].items()}
    print(f"lifecycle spans: {counts}")

    # 3. push one record through the sink and read it back
    engine.emit_report()
    record = json.loads(out.read_text().splitlines()[-1])
    print(f"JSONL record keys: {sorted(record)[:6]}... "
          f"({len(record['metrics'])} metric families)")

    # 4. the same registry as a Prometheus scrape (ephemeral port)
    srv = PrometheusServer(hub.registry, port=0)
    body = urllib.request.urlopen(
        f"http://127.0.0.1:{srv.port}/metrics", timeout=5
    ).read().decode()
    wanted = [ln for ln in body.splitlines()
              if ln.startswith("quiver_tenant_requests_total")]
    print("scrape excerpt:", *wanted[:4], sep="\n  ")
    srv.close()

    # 5. probe-drift alarm: a streaming corpus whose live set slides
    # from healthy embeddings to sign-collapsed features crosses the
    # calibrated green/amber/red boundary and the armed monitor raises
    rng = np.random.default_rng(0)
    stream = MutableQuIVerIndex.empty(64, 2048)
    monitor = stream.attach_drift_monitor(tenant="drifty")
    good = stream.insert(rng.normal(size=(256, 64)).astype(np.float32))
    print(f"after healthy churn: band={monitor.band}, "
          f"alarms={len(monitor.alarms)}")
    stream.insert(
        np.abs(rng.normal(size=(512, 64))).astype(np.float32) + 3.0
    )
    stream.delete(good)
    print(f"after drift churn:   band={monitor.band}, "
          f"alarms={len(monitor.alarms)}")
    for a in monitor.alarms:
        print(" ", a.message())

    # 6. recall SLO + closed-loop remediation (DESIGN.md §14): serve
    # the drifted corpus with the shadow ground-truth lane armed — a
    # hash-sampled slice of traffic is re-answered exactly off the hot
    # path, the tenant's rolling recall p50 breaches its SLO, and the
    # remediation ladder re-probes (red) and replans the nav family
    drifted = QueryEngine(
        stream.freeze(), default_k=5, default_ef=64,
        shadow={"rate": 1},            # sample everything for the demo
    )
    drifted.tenants.recall_window = 64
    drifted.tenants.recall_min_samples = 8
    drifted.set_quota("drifty", qps=1e9, recall_slo=0.95)
    policy = RemediationPolicy(drifted, auto=False).attach(monitor)
    dq = rng.normal(size=(32, 64)).astype(np.float32)
    t = drifted.submit(dq, tenant="drifty")
    drifted.pump()
    drifted.result(t)
    shadow = drifted.shadow.report()
    ledger = drifted.tenants.report()["tenants"]["drifty"]
    print(f"shadow lane: sampled={shadow['sampled']} "
          f"recall_mean={shadow['recall_mean']}")
    print(f"tenant drifty: recall_p50={ledger['recall_p50']} "
          f"slo={ledger['recall_slo']} "
          f"breached={ledger['recall_breached']}")
    fired = policy.check()
    if fired:
        print(f"remediation: action={fired['action']} "
              f"trigger={fired['trigger']} "
              f"nav now {policy._current_nav()}")

    # 7. the graph X-ray (DESIGN.md §15): structural health before and
    # after forced churn.  The contrastive build reads green; the
    # stream that rolled its live set over to sign-collapsed rows
    # reads degraded — the early warning fires from topology, before
    # shadow recall has finished collecting evidence.  The same
    # verdicts back GET /healthz (200 green / 503 red) so a load
    # balancer can evict a structurally collapsed replica.
    healthy = index.graph_report(sample=128)
    print(f"green build X-ray:    {healthy.summary()}")
    churned = stream.graph_report(sample=128)
    print(f"drifted stream X-ray: {churned.summary()}")
    drifted.swap_index(stream.freeze())    # snapshot carries the report
    record, status = health_snapshot(drifted.health_verdicts)
    print(f"GET /healthz -> {status}: {json.dumps(record)}")

    hub.close()


if __name__ == "__main__":
    main()
