"""Quickstart: build a QuIVer index, search it, inspect the hot path.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax.numpy as jnp

from repro.core.baselines import flat_search, recall_at_k
from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.data.datasets import make_dataset
from repro.serve.engine import QueryEngine


def main():
    # 1. data: a contrastive-embedding surrogate (the paper's sweet spot)
    base, queries = make_dataset("cohere-surrogate", n=5000, queries=50)
    print(f"base {base.shape}, queries {queries.shape}")

    # 2. build with nav="auto": the training-free applicability probe
    # (DESIGN.md §10) checks the corpus is BQ-compatible and picks the
    # navigation ladder rung — bq2 here (contrastive-style data); an
    # incompatible corpus would route to adc/float32 instead of
    # silently collapsing.
    t0 = time.perf_counter()
    index = QuIVerIndex.build(
        jnp.asarray(base),
        BuildParams(m=16, ef_construction=96, prune_pool=96, chunk=256),
        nav="auto",
    )
    print(f"built in {time.perf_counter()-t0:.1f}s "
          f"({index.build_stats.chunks} chunks, "
          f"mean {index.build_stats.mean_hops:.1f} hops/insert)")
    print(f"probe: {index.report.summary()}")
    print(f"policy: {index.policy.describe()}")

    # 3. hot/cold memory split (paper Table 2)
    mem = index.memory_breakdown()
    print(f"hot {mem['hot_total_bytes']/2**20:.1f} MB "
          f"(sigs {mem['hot_signature_bytes']/2**20:.1f} MB + adjacency) "
          f"vs cold {mem['cold_vector_bytes']/2**20:.1f} MB float32")

    # 4. search: symmetric BQ beam + float32 rerank
    for ef in (16, 64, 256):
        t0 = time.perf_counter()
        ids, scores = index.search(jnp.asarray(queries), k=10, ef=ef)
        dt = (time.perf_counter() - t0) / len(queries)
        gt, _ = flat_search(base, queries, k=10)
        print(f"ef={ef:4d}: recall@10={recall_at_k(ids, gt):.3f} "
              f"{dt*1e3:.1f} ms/query")

    # 5. persistence
    index.save("/tmp/quiver_index.npz")
    loaded = QuIVerIndex.load("/tmp/quiver_index.npz")
    ids2, _ = loaded.search(jnp.asarray(queries), k=10, ef=64)
    print("save/load roundtrip OK:",
          bool((ids2 == index.search(jnp.asarray(queries), k=10, ef=64)[0])
               .all()))

    # 6. IVF-over-BQ (DESIGN.md §13): a training-free coarse partition
    # in signature space.  ivf_candidates=True seeds each build chunk's
    # prune pool from top-p coarse lists instead of a whole-graph beam
    # — near-linear build, same graph quality — and the partition also
    # serves as a second navigation family: nav="ivf" is a flat top-p
    # list scan + rerank, widened via probes= (recall grows with the
    # scanned fraction; the graph stays the recall champion, the
    # partition is the build/scatter lever).
    t0 = time.perf_counter()
    ivf_index = QuIVerIndex.build(
        jnp.asarray(base),
        BuildParams(m=16, ef_construction=96, prune_pool=96, chunk=256,
                    ivf_candidates=True),
    )
    print(f"ivf-assisted build in {time.perf_counter()-t0:.1f}s "
          f"({ivf_index.ivf.n_lists} lists)")
    gt, _ = flat_search(base, queries, k=10)
    p_wide = -(-3 * ivf_index.ivf.n_lists // 4)
    for probes in (None, p_wide):
        ids, _ = ivf_index.search(jnp.asarray(queries), k=10, ef=128,
                                  nav="ivf", probes=probes)
        tag = probes or ivf_index.ivf.default_probes
        print(f"nav='ivf' p={tag}: recall@10={recall_at_k(ids, gt):.3f}")

    # 7. serving: every search() above lowered to a compiled QueryPlan
    # (DESIGN.md §11) — resolved once, jit-compiled once, reused.  For
    # request traffic, the continuous-batching engine coalesces pending
    # requests by plan; singletons share the smallest ladder bucket, so
    # a stream of 1-query calls never retraces.
    engine = QueryEngine(index, default_k=10, default_ef=64)
    engine.warmup()
    for q in queries[:20]:
        engine.search(q)                      # 20 singleton requests
    rep = engine.stats_report()
    print(f"engine: {rep['requests']} requests, "
          f"plans compiled={rep['plan_plans_compiled']}, "
          f"hit rate={rep['plan_hit_rate']:.2f}, "
          f"steady retraces={rep['plan_retraces']}")


if __name__ == "__main__":
    main()
