"""Retrieval-augmented serving: a smoke-scale LM + QuIVer as its memory.

End-to-end driver (deliverable (b)): the LM embeds a corpus, QuIVer
indexes the embeddings (2-bit hot path), and generation prepends the
retrieved documents' tokens to each prompt before prefill.  The second
half demos *filtered* retrieval (DESIGN.md §9): the corpus is tagged
with language labels and the retriever is pinned to one language — the
predicate runs as packed bitset ops inside the BQ beam search.

    PYTHONPATH=src python examples/rag_serve.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.filter import Any
from repro.models.model import build_model
from repro.serve.engine import (
    QueryEngine,
    Retriever,
    ServeEngine,
    mean_pool_embedder,
)


def main():
    rng = np.random.default_rng(0)
    cfg = get_config("minicpm-2b").smoke()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))

    # 1. a toy corpus of 512 "documents" (token sequences)
    n_docs, doc_len = 512, 8
    corpus = rng.integers(0, cfg.vocab_size, (n_docs, doc_len)).astype(
        np.int32
    )

    # 2. embed the corpus with the LM itself, index with QuIVer
    embed_fn = mean_pool_embedder(bundle, params)
    doc_emb = np.asarray(embed_fn(jnp.asarray(corpus)))
    index = QuIVerIndex.build(
        jnp.asarray(doc_emb),
        BuildParams(m=4, ef_construction=24, prune_pool=24, chunk=128),
    )
    print(f"indexed {n_docs} docs; "
          f"hot={index.memory_breakdown()['hot_total_bytes']/1024:.0f} KB")

    # 3. serve with and without retrieval.  The retriever routes its
    # searches through a QueryEngine (DESIGN.md §11): lookups enter the
    # admission queue, coalesce with any other in-flight request, and
    # reuse one compiled plan per (k, ef, filter) config — a stream of
    # single-prompt RAG calls never retraces.
    engine = ServeEngine(bundle, params, max_seq=128)
    prompts = rng.integers(0, cfg.vocab_size, (4, 12)).astype(np.int32)

    query_engine = QueryEngine(index, default_k=2, default_ef=32)
    query_engine.warmup(configs=({"k": 2, "ef": 32},))
    plain = engine.generate(prompts, max_new=8)
    retriever = Retriever(index=index, doc_tokens=corpus,
                          embed_fn=embed_fn, k=2, ef=32,
                          engine=query_engine)
    augmented = engine.generate(prompts, max_new=8, retriever=retriever)

    print("plain generation     :", plain[0].tolist())
    print("retrieval-augmented  :", augmented[0].tolist())
    print("context per prompt   :",
          retriever.augment(prompts).shape[1] - prompts.shape[1], "tokens")

    # 4. filtered retrieval: tag each document with a language and pin
    # the retriever to German — every retrieved context document now
    # matches the predicate, enforced inside the beam search itself
    LANGS = {"en": 0, "de": 1, "fr": 2}
    doc_lang = rng.integers(0, len(LANGS), n_docs)
    index.attach_labels(list(doc_lang), n_labels=len(LANGS))
    index.build_label_entries(min_count=16)

    de_retriever = Retriever(index=index, doc_tokens=corpus,
                             embed_fn=embed_fn, k=2, ef=32,
                             filter=LANGS["de"], engine=query_engine)
    de_out = engine.generate(prompts, max_new=8, retriever=de_retriever)
    hits, _ = index.search(jnp.asarray(doc_emb[:4]), k=2, ef=32,
                           filter=LANGS["de"])
    print("german-only generation:", de_out[0].tolist())
    print("german-only hits      :", hits.tolist(),
          "(labels:", [doc_lang[h] for h in hits.ravel() if h >= 0], ")")
    # predicates compose: Any(en, fr) == "anything but German"
    hits_ef, _ = index.search(jnp.asarray(doc_emb[:4]), k=2, ef=32,
                              filter=Any(LANGS["en"], LANGS["fr"]))
    assert all(doc_lang[h] != LANGS["de"] for h in hits_ef.ravel()
               if h >= 0)
    print("en|fr hits            :", hits_ef.tolist())

    # 5. the serving ledger: every retrieval above went through the
    # admission queue — distinct (k, ef, filter) configs each compiled
    # exactly once, then reused
    rep = query_engine.stats_report()
    print(f"query engine          : {rep['requests']} requests, "
          f"{rep['plan_plans_compiled']} plans compiled, "
          f"steady retraces={rep['plan_retraces']}")


if __name__ == "__main__":
    main()
