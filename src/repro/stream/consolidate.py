"""Streaming graph surgery: live-masked linking and FreshDiskANN repair.

Pure device-side functions, meant to be called *inside* a jit whose
arguments are the mutable index's preallocated arrays (see
``repro.stream.mutable``).  Everything routes through the registered
metric backend that the caller constructed from those arrays — the
repair never leaves the metric space the graph was built in, so no
float topology creeps back after consolidation.

``repair_rows`` is the FreshDiskANN delete-consolidation step: for a
row that points at tombstones, the candidate pool becomes

    (live out-neighbours of the row)
  ∪ (live out-neighbours of each dead out-neighbour)

— the dead node's edges are spliced across it — and the pool is
alpha-pruned with the backend's own ``dist_many``/``pairwise``,
exactly the criterion used at build time (Vamana Alg. 1).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import linking
from repro.core.metric import MetricSpace
from repro.core.prune import alpha_prune_batch

BIG = jnp.float32(3.0e38)


def link_chunk(
    backend: MetricSpace,
    adj,
    deg,
    live,
    chunk_ids,               # (B,) int32, -1 padded
    medoid,
    *,
    ef: int,
    pool: int,
    r: int,
    alpha: float,
    n: int,
    expand: int,
    r_total: int,
):
    """Insert one chunk of freshly-binarized nodes into the live graph.

    The paper's chunked concurrent linking (§4.1) with a live mask:
    beam-search candidates are restricted to live nodes, so new edges
    never target tombstones, then forward rows are installed and
    reverse edges scatter-appended — the shared batch-build primitives.
    """
    fwd_ids, _, _, _, _ = linking.chunk_forward(
        backend, adj, chunk_ids, medoid,
        ef=ef, pool=pool, r=r, alpha=alpha, n=n, expand=expand,
        node_valid=live,
    )
    adj, deg = linking.apply_forward(
        adj, deg, chunk_ids, fwd_ids, r_total=r_total
    )
    adj, deg, added = linking.reverse_append(
        adj, deg, chunk_ids, fwd_ids, r_total=r_total
    )
    return adj, deg, added


def overflow_rows(
    backend: MetricSpace, adj, deg, live, row_ids, *,
    r: int, alpha: float, r_total: int,
):
    """Live-masked re-prune of degree-overflowed rows."""
    return linking.consolidate_rows(
        backend, adj, deg, row_ids,
        r=r, alpha=alpha, r_total=r_total, node_valid=live,
    )


def _dedup_rows(cands: jnp.ndarray) -> jnp.ndarray:
    """Per-row candidate dedup: repeats of an id collapse to -1."""
    b = cands.shape[0]
    order = jnp.argsort(cands, axis=1)
    s = jnp.take_along_axis(cands, order, axis=1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros((b, 1), dtype=jnp.bool_),
         (s[:, 1:] == s[:, :-1]) & (s[:, 1:] >= 0)],
        axis=1,
    )
    dup = jnp.zeros_like(dup_sorted).at[
        jnp.arange(b)[:, None], order
    ].set(dup_sorted)
    return jnp.where(dup, -1, cands)


def repair_rows(
    backend: MetricSpace,
    adj,
    deg,
    live,
    row_ids,                 # (B,) int32, -1 padded
    *,
    r: int,
    alpha: float,
    r_total: int,
    pool: int,
):
    """Splice dead out-neighbours' edges into ``row_ids``' pools and
    alpha-prune in the backend's metric space (delete consolidation)."""
    safe_row = jnp.maximum(row_ids, 0)
    rows = adj[safe_row]                                 # (B, T)
    nbr_safe = jnp.maximum(rows, 0)
    nbr_ok = rows >= 0
    nbr_live = nbr_ok & live[nbr_safe]
    nbr_dead = nbr_ok & ~live[nbr_safe]

    # one hop through each dead neighbour: its own live out-edges
    second = adj[jnp.where(nbr_dead, rows, 0)]           # (B, T, T)
    sec_ok = nbr_dead[:, :, None] & (second >= 0)
    sec_ok = sec_ok & live[jnp.maximum(second, 0)]

    b = rows.shape[0]
    cands = jnp.concatenate(
        [jnp.where(nbr_live, rows, -1),
         jnp.where(sec_ok, second, -1).reshape(b, -1)],
        axis=1,
    )                                                    # (B, T + T*T)
    cands = jnp.where(cands == row_ids[:, None], -1, cands)
    cands = _dedup_rows(cands)

    valid = cands >= 0
    safe = jnp.maximum(cands, 0)
    target_repr = backend.query_repr(safe_row)
    d = backend.dist_many(target_repr, safe, valid)
    d = jnp.where(valid, d, BIG)
    order = jnp.argsort(d, axis=-1)[:, :pool]
    cids = jnp.take_along_axis(cands, order, axis=-1)
    cdists = jnp.take_along_axis(d, order, axis=-1)

    pw = backend.pairwise(jnp.maximum(cids, 0))
    new_ids, _ = alpha_prune_batch(cids, cdists, pw, r=r, alpha=alpha)
    return linking.scatter_rows(adj, deg, row_ids, new_ids,
                                r_total=r_total)
