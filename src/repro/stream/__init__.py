"""Streaming index subsystem: live insert/delete/consolidate in BQ space.

The paper builds QuIVer once and serves it frozen; this package gives
the same BQ-native graph a mutable lifecycle (DESIGN.md §8):

* :class:`~repro.stream.mutable.MutableQuIVerIndex` — live insert
  (chunk-linked with the shared Vamana primitives), tombstone delete,
  FreshDiskANN-style consolidation, ``freeze()`` snapshots and
  persistence, all over capacity-preallocated accelerator arrays.
* :class:`~repro.stream.sharded.StreamingShardedIndex` — round-robin
  insert routing over per-shard mutable indexes with tombstone-masked
  fan-out search.
"""

from repro.stream.mutable import MutableQuIVerIndex, StreamStats
from repro.stream.sharded import StreamingShardedIndex

__all__ = [
    "MutableQuIVerIndex",
    "StreamStats",
    "StreamingShardedIndex",
]
