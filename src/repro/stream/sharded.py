"""Shard-local streaming: mutable QuIVer shards behind one fan-out API.

Fleet layout (DESIGN.md §3/§8): each shard owns a
:class:`MutableQuIVerIndex` over its own capacity-preallocated arrays.
Inserts are routed round-robin so shards stay balanced under churn;
deletes route by the shard encoded in the global id; searches snapshot
the per-shard arrays into a :class:`ShardedIndex` (stacked, leading dim
= n_shards) whose ``live`` mask carries every shard's tombstones into
the ``shard_map`` fan-out — dead nodes are filtered from each local
top-k *before* the all-gather merge, so the collective stays one
(k ids, k scores) pair per shard.

Global id scheme: ``gid = shard * capacity_per_shard + slot``.  Slots
are reclaimed by consolidation, so a gid is unique among *live* ids at
any instant but may be reused after its document is deleted and the
shard consolidated — the usual semantics of a slotted streaming store.
"""

from __future__ import annotations

import numpy as np

from repro.core.distributed import ShardedIndex, search_sharded
from repro.core.vamana import BuildParams
from repro.probe import CompatibilityReport, merge_reports
from repro.stream.mutable import MutableQuIVerIndex

import jax.numpy as jnp


class StreamingShardedIndex:
    """Round-robin streaming over per-shard mutable indexes."""

    def __init__(self, shards: list[MutableQuIVerIndex]):
        if not shards:
            raise ValueError("need at least one shard")
        caps = {s.capacity for s in shards}
        dims = {s.dim for s in shards}
        kinds = {s.metric_kind for s in shards}
        if len(caps) != 1 or len(dims) != 1 or len(kinds) != 1:
            raise ValueError(
                "shards must share capacity/dim/metric "
                f"(got {caps}/{dims}/{kinds})"
            )
        self.shards = shards
        self.capacity_per_shard = caps.pop()
        self.dim = dims.pop()
        self.metric_kind = kinds.pop()
        self._rr = 0                      # round-robin insert cursor
        self._snapshot: ShardedIndex | None = None
        self._snapshot_gens: tuple[int, ...] | None = None
        # IVF routing tier (enable_ivf_routing): (cent_words, owners,
        # default_probes, generations it was built at)
        self._ivf_route = None
        self._ivf_route_seed = 0

    @classmethod
    def empty(
        cls,
        dim: int,
        *,
        n_shards: int,
        capacity_per_shard: int,
        params: BuildParams | None = None,
        metric: str = "bq2",
        keep_vectors: bool = True,
        n_labels: int | None = None,
    ) -> "StreamingShardedIndex":
        return cls([
            MutableQuIVerIndex.empty(
                dim, capacity_per_shard, params, metric=metric,
                keep_vectors=keep_vectors, n_labels=n_labels,
            )
            for _ in range(n_shards)
        ])

    def enable_labels(self, n_labels: int) -> None:
        """Enable filtered search on every shard."""
        for s in self.shards:
            s.enable_labels(n_labels)

    def build_label_entries(self, *, min_count: int = 32) -> int:
        """Per-shard per-label entry points; returns total built."""
        return sum(
            s.build_label_entries(min_count=min_count)
            for s in self.shards
        )

    # -- id scheme ---------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def n_live(self) -> int:
        return sum(s.n_live for s in self.shards)

    def _to_global(self, shard: int, slots: np.ndarray) -> np.ndarray:
        return shard * self.capacity_per_shard + np.asarray(slots)

    def _to_local(self, gids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        gids = np.asarray(gids, dtype=np.int64)
        return gids // self.capacity_per_shard, \
            gids % self.capacity_per_shard

    # -- mutation ----------------------------------------------------------

    def insert(self, vectors, labels=None) -> np.ndarray:
        """Round-robin insert; returns global ids in input order.

        All-or-nothing: capacity is checked across every target shard
        *before* any shard mutates, so a full shard can never leave the
        fleet with untracked live vectors.

        ``labels`` (optional): one int / iterable of ints per vector,
        routed to each owning shard's label store alongside the vector
        (see ``MutableQuIVerIndex.insert``)."""
        v = np.asarray(vectors, dtype=np.float32)
        if v.ndim == 1:
            v = v[None]
        if labels is not None:
            labels = list(labels)
            if len(labels) != len(v):
                raise ValueError(
                    f"{len(labels)} label rows for {len(v)} vectors"
                )
        owner = (self._rr + np.arange(len(v))) % self.n_shards
        counts = np.bincount(owner, minlength=self.n_shards)
        for s, need in enumerate(counts):
            if need > self.shards[s].free_slots:
                raise ValueError(
                    f"shard {s} needs {need} slots but has "
                    f"{self.shards[s].free_slots} free of "
                    f"{self.shards[s].capacity} "
                    f"(consolidate() reclaims tombstoned slots)"
                )
        self._rr = int((self._rr + len(v)) % self.n_shards)
        gids = np.empty((len(v),), dtype=np.int64)
        for s in range(self.n_shards):
            take = np.nonzero(owner == s)[0]
            if take.size == 0:
                continue
            slots = self.shards[s].insert(
                v[take],
                labels=(
                    [labels[i] for i in take] if labels is not None
                    else None
                ),
            )
            gids[take] = self._to_global(s, slots)
        return gids

    def delete(self, gids) -> int:
        """Tombstone global ids; returns how many were live."""
        shard, slot = self._to_local(np.atleast_1d(gids))
        if len(shard) and (shard.min() < 0 or shard.max() >= self.n_shards):
            raise ValueError("global id out of range")
        removed = 0
        for s in range(self.n_shards):
            take = shard == s
            if take.any():
                removed += self.shards[s].delete(slot[take])
        return removed

    def consolidate(self) -> list[dict]:
        """Per-shard repair + reclamation (embarrassingly parallel)."""
        return [s.consolidate() for s in self.shards]

    def attach_drift_monitors(self, *, tenant="default", registry=None,
                              **monitor_kw) -> list:
        """Arm per-shard probe-drift alarms (DESIGN.md §12).  Each shard
        gets its own monitor over its own live-set accumulator, labelled
        ``{tenant}/shard{i}`` so a single drifting shard is attributable
        on the fleet scrape.  Returns the monitors in shard order."""
        return [
            s.attach_drift_monitor(
                tenant=f"{tenant}/shard{i}", registry=registry,
                **monitor_kw,
            )
            for i, s in enumerate(self.shards)
        ]

    def replan(self, *, nav: str, **replan_kw) -> list:
        """Fan a nav replan out to every shard (DESIGN.md §14): each
        shard's default nav + schedule flips together, so the fleet
        serves one consistent policy.  Returns the per-shard policies
        in shard order; same validation as ``MutableQuIVerIndex.replan``
        (``nav="ivf"`` rejected — the routing tier is a scatter overlay,
        not a per-shard nav family)."""
        return [s.replan(nav=nav, **replan_kw) for s in self.shards]

    # -- applicability probe (DESIGN.md §10) -------------------------------

    def probe_report(self, **probe_kw) -> CompatibilityReport:
        """Fleet-wide compatibility report: per-shard live-set probes
        (incremental entropies + sampled stats, see
        ``MutableQuIVerIndex.probe_report``) merged sample-weighted —
        the streaming analogue of ``build_sharded(metric="auto")``'s
        build-time merge.  Empty shards contribute nothing."""
        reports = [
            s.probe_report(**probe_kw) for s in self.shards if s.n_live
        ]
        if not reports:
            raise ValueError("cannot probe an empty fleet")
        return merge_reports(reports)

    # -- IVF routing tier (DESIGN.md §13) ----------------------------------

    def enable_ivf_routing(self, *, n_lists: int | None = None,
                           seed: int = 0) -> int:
        """Build the coarse routing tier over the fleet's live set.

        Streaming placement is round-robin (lists cannot be the shard
        unit under churn), so the tier is an *ownership overlay*: one
        global partition over the live signatures plus a (S, L) matrix
        of which shards hold members of each list.  ``search(...,
        scatter=True)`` then contacts only the shards owning a query's
        top-p lists.  The tier is rebuilt lazily whenever any shard's
        generation counter moves.  Returns the number of lists.
        """
        from repro.core import bq
        from repro.ivf import build_partition

        self._ivf_route_seed = seed
        live_words, shard_of = [], []
        for i, s in enumerate(self.shards):
            rows = np.nonzero(s.live)[0]
            if rows.size:
                live_words.append(np.asarray(s.words)[rows])
                shard_of.append(np.full(rows.size, i, np.int32))
        if not live_words:
            raise ValueError("cannot route an empty fleet")
        sigs = bq.Signature(
            words=jnp.asarray(np.concatenate(live_words)), dim=self.dim
        )
        part = build_partition(sigs, n_lists=n_lists, seed=seed)
        shard_of = np.concatenate(shard_of)
        owners = np.zeros((self.n_shards, part.n_lists), dtype=bool)
        owners[shard_of, part.assign] = True
        self._ivf_route = (
            part.cent_words, owners, part.default_probes,
            tuple(s.generation for s in self.shards),
        )
        return part.n_lists

    def _scatter_search(self, queries, *, ef, k, probes, filter,
                        registry):
        from repro.core.metric import encode_queries_for
        from repro.ivf import record_routes, top_lists
        from repro.kernels import dispatch

        if self._ivf_route is None:
            raise ValueError(
                "scatter search needs enable_ivf_routing() first"
            )
        gens = tuple(s.generation for s in self.shards)
        if gens != self._ivf_route[3]:    # stale under churn: rebuild
            self.enable_ivf_routing(
                n_lists=self._ivf_route[0].shape[0],
                seed=self._ivf_route_seed,
            )
        cent_words, owners, default_probes, _ = self._ivf_route
        q = jnp.asarray(queries, jnp.float32)
        if q.ndim == 1:
            q = q[None]
        nq = q.shape[0]
        p = max(1, min(probes or default_probes, cent_words.shape[0]))
        reprs = encode_queries_for("bq2", q / jnp.maximum(
            jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12
        ))
        ops = dispatch.list_scan_ops(self.dim)
        top = np.asarray(top_lists(ops.scan, reprs, cent_words, p))
        contact = owners.T[top].any(axis=1)            # (Q, S)
        record_routes(top, contact.sum(axis=-1), registry=registry)

        all_ids = np.full((nq, self.n_shards, k), -1, dtype=np.int64)
        all_scores = np.full((nq, self.n_shards, k), -np.inf,
                             dtype=np.float32)
        qn = np.asarray(q)
        for s in range(self.n_shards):
            rows = np.nonzero(contact[:, s])[0]
            if rows.size == 0:
                continue
            ids, scores = self.shards[s].search(
                qn[rows], k, ef=ef, filter=filter,
            )
            ok = ids >= 0
            all_ids[rows, s] = np.where(
                ok, self._to_global(s, np.maximum(ids, 0)), -1
            )
            all_scores[rows, s] = np.where(ok, scores, -np.inf)
        flat_ids = all_ids.reshape(nq, -1)
        flat_scores = all_scores.reshape(nq, -1)
        order = np.argsort(-flat_scores, axis=-1)[:, :k]
        out_scores = np.take_along_axis(flat_scores, order, axis=-1)
        out_ids = np.take_along_axis(flat_ids, order, axis=-1)
        out_ids[~np.isfinite(out_scores)] = -1
        return out_ids, out_scores

    # -- search ------------------------------------------------------------

    def snapshot(self) -> ShardedIndex:
        """Stack the per-shard mutable arrays into a ShardedIndex whose
        ``live`` mask carries tombstones into the fan-out search.

        Cached on the shard generation counters: an unchanged index
        serves every search from the same stacked arrays instead of
        re-copying the fleet per request.
        """
        if any(s.vectors is None for s in self.shards):
            raise ValueError("sharded streaming search needs cold vectors")
        gens = tuple(s.generation for s in self.shards)
        if self._snapshot is not None and gens == self._snapshot_gens:
            return self._snapshot
        labeled = all(s.labels is not None for s in self.shards)
        self._snapshot = ShardedIndex(
            sig_words=jnp.stack([s.words for s in self.shards]),
            adjacency=jnp.stack([s.adjacency for s in self.shards]),
            medoids=jnp.asarray(
                [max(s.medoid, 0) for s in self.shards], dtype=jnp.int32
            ),
            vectors=jnp.stack([s.vectors for s in self.shards]),
            dim=self.dim,
            metric=self.metric_kind,
            live=jnp.asarray(
                np.stack([s.live for s in self.shards])
            ),
            label_words=(
                jnp.stack([s.labels.words for s in self.shards])
                if labeled else None
            ),
            n_labels=(
                self.shards[0].labels.n_labels if labeled else 0
            ),
            label_entries=(
                jnp.asarray(
                    np.stack([s.labels.entries for s in self.shards])
                )
                if labeled else None
            ),
            # live-accurate fleet popcounts (delete clears label bits)
            label_counts=(
                np.sum([s.labels.counts for s in self.shards], axis=0)
                if labeled else None
            ),
            # one fleet schedule only when every shard agrees (shards
            # adopted from differently-probed indexes get no schedule)
            policy=(
                self.shards[0].policy
                if len({s.policy for s in self.shards}) == 1 else None
            ),
            report=(
                self.shards[0].report
                if len({s.report for s in self.shards}) == 1 else None
            ),
        )
        self._snapshot_gens = gens
        return self._snapshot

    def search(self, queries, *, ef: int = 64, k: int = 10,
               nav: str | None = None, expand: int = 1,
               mesh=None, filter=None, scatter: bool = False,
               probes: int | None = None, registry=None):
        """Fan-out/merge search over all shards (global ids).

        ``filter`` is pushed down per shard: every shard's label bitset
        mask joins its tombstone mask in the fan-out, so only live
        matching ids reach the top-k merge (``search_sharded``).

        ``scatter=True`` routes on the IVF tier instead
        (``enable_ivf_routing`` first): only shards owning the query's
        top-``probes`` lists are contacted — each runs its normal local
        graph search — and their reranked top-k merge by score.  At
        ``probes = n_lists`` every member-owning shard is contacted, so
        results coincide with the full fan-out."""
        if scatter:
            return self._scatter_search(
                queries, ef=ef, k=k, probes=probes, filter=filter,
                registry=registry,
            )
        return search_sharded(
            self.snapshot(), queries, mesh=mesh, ef=ef, k=k,
            nav=nav, expand=expand, filter=filter,
        )
