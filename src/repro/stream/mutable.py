"""MutableQuIVerIndex — the paper's index with a live mutation lifecycle.

Layout (DESIGN.md §8): every array is preallocated at ``capacity`` and
lives on the accelerator for its whole life — the IVF-RaBitQ lesson
(PAPERS.md) that build and search should share device-resident arrays,
extended to a full mutable lifecycle:

    words      (capacity, 2W) uint32   packed 2-bit SM signatures (hot)
    adjacency  (capacity, R+slack) int32
    deg        (capacity,) int32       degree counters
    vectors    (capacity, D) float32   cold rerank tier (optional)
    live       (capacity,) bool        tombstone mask (host-owned)

``insert`` binarizes the new vectors and chunk-links them against the
*live* graph with exactly the shared Vamana primitives the batch
builder uses (``repro.core.linking``) — the paper's chunked concurrent
linking (§4.1) run against a non-frozen graph.  ``delete`` only flips
tombstones: dead nodes keep routing beam searches (FreshDiskANN
semantics) but never surface in results, courtesy of the ``node_valid``
path in ``repro.core.beam``.  ``consolidate`` repairs the topology —
each dead node's out-edges are spliced into its in-neighbours'
candidate pools and alpha-pruned in the index's own registered metric
space — then reclaims the dead slots for reuse.  ``freeze`` compacts
the live set into an immutable :class:`QuIVerIndex`.

Jit discipline: every device op here takes the mutable arrays as
*traced* arguments and constructs the registered metric backend inside
the trace (the ``repro.core.distributed`` pattern).  Cache keys are
(shapes, static params) only — shapes are pinned by ``capacity``, so
mutations never retrace.  Partial chunks are padded to a small set of
bucket sizes to bound the number of traces.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bq
from repro.core.beam import (
    batch_bucket,
    batched_beam_search,
    beam_margin,
    escalated_search,
    pad_rows,
)
from repro.core.index import (
    QuIVerIndex,
    params_from_npz,
    params_to_npz,
    rerank_f32,
    topk_by_dist,
)
from repro.core.linking import medoid_scan
from repro.core.metric import MetricArrays, encode_queries_for, make_backend
from repro.core.vamana import BuildParams
from repro.filter import (
    DEFAULT_SELECTIVITY_FLOOR,
    LabelStore,
    brute_force_topk,
    build_label_entries,
    entry_label,
    estimate_selectivity,
    route,
    validate,
    widened_ef,
)
from repro.probe import (
    CompatibilityReport,
    NavPolicy,
    ProbeAccumulator,
    probe_corpus,
    probe_signatures,
    resolve_schedule,
)
from repro.stream.consolidate import link_chunk, overflow_rows, repair_rows

_BUCKETS = (16, 64, 256)


def _normalize(x: jnp.ndarray) -> jnp.ndarray:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def _pad_ids(ids: np.ndarray, size: int) -> jnp.ndarray:
    out = np.full((size,), -1, dtype=np.int32)
    out[: len(ids)] = ids
    return jnp.asarray(out)


def _bucket(n: int, chunk: int) -> int:
    """Smallest padding bucket >= n (bounds the jit trace count)."""
    for b in sorted(set(_BUCKETS) | {chunk}):
        if b >= n:
            return b
    return chunk


def _mk_backend(kind, dim, words, vectors):
    return make_backend(
        kind, MetricArrays(sigs=bq.Signature(words=words, dim=dim),
                           vectors=vectors)
    )


# ---------------------------------------------------------------------------
# device ops — arrays traced, backend constructed inside the trace
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("kind", "dim", "ef", "pool", "r", "alpha", "n",
                     "expand", "r_total"),
)
def _link_op(words, vectors, adj, deg, live, chunk_ids, medoid, *,
             kind, dim, ef, pool, r, alpha, n, expand, r_total):
    backend = _mk_backend(kind, dim, words, vectors)
    return link_chunk(
        backend, adj, deg, live, chunk_ids, medoid,
        ef=ef, pool=pool, r=r, alpha=alpha, n=n, expand=expand,
        r_total=r_total,
    )


@functools.partial(
    jax.jit,
    static_argnames=("kind", "dim", "r", "alpha", "r_total", "pool"),
)
def _repair_op(words, vectors, adj, deg, live, row_ids, *,
               kind, dim, r, alpha, r_total, pool):
    backend = _mk_backend(kind, dim, words, vectors)
    return repair_rows(
        backend, adj, deg, live, row_ids,
        r=r, alpha=alpha, r_total=r_total, pool=pool,
    )


@functools.partial(
    jax.jit,
    static_argnames=("kind", "dim", "r", "alpha", "r_total"),
)
def _overflow_op(words, vectors, adj, deg, live, row_ids, *,
                 kind, dim, r, alpha, r_total):
    backend = _mk_backend(kind, dim, words, vectors)
    return overflow_rows(
        backend, adj, deg, live, row_ids,
        r=r, alpha=alpha, r_total=r_total,
    )


@functools.partial(
    jax.jit,
    static_argnames=("kind", "dim", "ef", "n", "expand", "k",
                     "use_rerank"),
)
def _search_op(words, vectors, adj, live, result_valid, medoid, reprs,
               queries, *, kind, dim, ef, n, expand, k, use_rerank):
    backend = _mk_backend(kind, dim, words, vectors)
    res = batched_beam_search(
        reprs, adj, medoid, dist_fn=backend.dist_fn, ef=ef, n=n,
        expand=expand, node_valid=live, result_valid=result_valid,
    )
    margin = beam_margin(res.dists, k, backend.neutral_dist)
    if use_rerank and vectors is not None:
        ids, scores = rerank_f32(res.ids, queries, vectors, k)
    else:
        ids, scores = topk_by_dist(res.ids, res.dists, k)
    return ids, scores, margin


@functools.partial(jax.jit, static_argnames=("kind", "dim", "chunk"))
def _medoid_op(words, vectors, live, *, kind, dim, chunk):
    backend = _mk_backend(kind, dim, words, vectors)
    live_f = live.astype(jnp.float32)
    denom = jnp.maximum(live_f.sum(), 1.0)
    if vectors is not None:
        c = (vectors * live_f[:, None]).sum(0) / denom
    else:
        levels = bq.decode_levels(bq.Signature(words=words, dim=dim))
        c = (levels * live_f[:, None]).sum(0) / denom
    centroid = backend.encode_queries(c[None])[0]
    return medoid_scan(backend, centroid, chunk=chunk, node_valid=live)


# ---------------------------------------------------------------------------
# the mutable index
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StreamStats:
    """Cumulative mutation accounting (since construction or load)."""

    inserts: int = 0
    deletes: int = 0
    consolidations: int = 0
    slots_reclaimed: int = 0
    rows_repaired: int = 0
    reverse_edges_added: int = 0


class MutableQuIVerIndex:
    """A QuIVer index that supports live insert/delete/consolidate.

    Construct with :meth:`empty` (streaming from scratch),
    :meth:`build` (batch build + headroom) or :meth:`from_index`
    (adopt an existing immutable index).
    """

    def __init__(
        self,
        *,
        capacity: int,
        dim: int,
        params: BuildParams,
        metric_kind: str = "bq2",
        keep_vectors: bool = True,
        rotation: jnp.ndarray | None = None,
        n_labels: int | None = None,
        policy: NavPolicy | None = None,
        report: CompatibilityReport | None = None,
    ):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        if metric_kind == "auto":
            raise ValueError(
                "metric='auto' needs a corpus to probe; use build() "
                "(or probe_report() + select_policy after inserting)"
            )
        w2 = 2 * bq.n_words(dim)
        self.capacity = int(capacity)
        self.dim = int(dim)
        self.params = params
        self.metric_kind = metric_kind
        self.rotation = rotation
        self.words = jnp.zeros((capacity, w2), dtype=jnp.uint32)
        self.adjacency = jnp.full(
            (capacity, params.r_total), -1, dtype=jnp.int32
        )
        self.deg = jnp.zeros((capacity,), dtype=jnp.int32)
        self.vectors = (
            jnp.zeros((capacity, dim), dtype=jnp.float32)
            if keep_vectors else None
        )
        self.labels = (
            LabelStore(capacity, n_labels) if n_labels else None
        )
        self.live = np.zeros((capacity,), dtype=bool)
        self.allocated = np.zeros((capacity,), dtype=bool)
        self.size = 0                    # allocation high-water mark
        self.medoid = -1                 # -1 until the first insert
        self.generation = 0              # bumped on every mutation
        self.stats = StreamStats()
        self._free: list[int] = []       # reclaimed slots, reused first
        # applicability-boundary state (DESIGN.md §10): the nav policy /
        # probe report travel with the index; the accumulator keeps the
        # live set's exact bit-plane statistics current under churn
        self.policy = policy
        self.report = report
        self.probe_acc = ProbeAccumulator(dim)
        # optional probe-drift monitor (DESIGN.md §12): re-scores the
        # accumulator against the calibrated bands after every mutation
        self.drift_monitor = None
        # structural X-ray (DESIGN.md §15): the last GraphHealthReport
        # plus the optional band-crossing monitor that consolidate()
        # re-checks every cycle
        self.graph_health = None
        self.graph_monitor = None

    # -- constructors ------------------------------------------------------

    @classmethod
    def from_index(
        cls, index: QuIVerIndex, *, capacity: int | None = None
    ) -> "MutableQuIVerIndex":
        """Adopt a built :class:`QuIVerIndex` (default headroom: 2x)."""
        n = index.sigs.words.shape[0]
        capacity = capacity or 2 * n
        if capacity < n:
            raise ValueError(f"capacity {capacity} < index size {n}")
        out = cls(
            capacity=capacity,
            dim=index.sigs.dim,
            params=index.params,
            metric_kind=index.metric_kind,
            keep_vectors=index.vectors is not None,
            rotation=index.rotation,
            policy=index.policy,
            report=index.report,
        )
        out.probe_acc.add(np.asarray(index.sigs.words))
        out.words = out.words.at[:n].set(index.sigs.words)
        out.adjacency = out.adjacency.at[:n].set(index.adjacency)
        out.deg = out.deg.at[:n].set(
            (index.adjacency >= 0).sum(-1).astype(jnp.int32)
        )
        if out.vectors is not None:
            out.vectors = out.vectors.at[:n].set(index.vectors)
        out.live[:n] = True
        out.allocated[:n] = True
        out.size = n
        out.medoid = int(index.medoid)
        out.graph_health = index.graph_health
        if index.labels is not None:
            out.labels = index.labels.padded_to(capacity)
        return out

    @classmethod
    def build(
        cls,
        vectors: jnp.ndarray,
        params: BuildParams | None = None,
        *,
        capacity: int | None = None,
        metric: str = "bq2",
        **build_kw,
    ) -> "MutableQuIVerIndex":
        """Batch-build (two-stage Vamana) then adopt with headroom."""
        idx = QuIVerIndex.build(
            jnp.asarray(vectors), params, metric=metric, **build_kw
        )
        return cls.from_index(idx, capacity=capacity)

    @classmethod
    def empty(
        cls,
        dim: int,
        capacity: int,
        params: BuildParams | None = None,
        *,
        metric: str = "bq2",
        keep_vectors: bool = True,
        rotation: jnp.ndarray | None = None,
        n_labels: int | None = None,
    ) -> "MutableQuIVerIndex":
        return cls(
            capacity=capacity,
            dim=dim,
            params=params or BuildParams(),
            metric_kind=metric,
            keep_vectors=keep_vectors,
            rotation=rotation,
            n_labels=n_labels,
        )

    def enable_labels(self, n_labels: int) -> LabelStore:
        """Create (or return) the label store for filtered search."""
        if self.labels is None:
            self.labels = LabelStore(self.capacity, n_labels)
        elif self.labels.n_labels != n_labels:
            raise ValueError(
                f"labels already enabled with n_labels="
                f"{self.labels.n_labels}"
            )
        return self.labels

    def build_label_entries(self, *, min_count: int = 32) -> int:
        """Per-label entry points over the *live* member sets."""
        if self.labels is None:
            raise ValueError("no labels enabled")
        backend = _mk_backend(
            self.metric_kind, self.dim, self.words, self.vectors
        )
        return build_label_entries(
            self.labels, backend, vectors=self.vectors,
            node_valid=self._live_dev(), min_count=min_count,
        )

    # -- applicability probe (DESIGN.md §10) -------------------------------

    def probe_report(
        self,
        *,
        sample: int = 1024,
        queries: int = 64,
        k: int = 10,
        seed: int = 0,
    ) -> CompatibilityReport:
        """Probe the *live* set: sampled statistics plus the exact
        incremental bit-plane entropies from :class:`ProbeAccumulator`.

        The sampled stats (cosine spread, BQ agreement, margins) are
        recomputed from a live sample on demand; the entropy fields are
        taken from the accumulator, which covers every live row exactly
        and costs nothing here.  Vector-free indexes degrade to
        signature-only probes (agreement NaN, verdict capped at amber).
        """
        if self.n_live == 0:
            raise ValueError("cannot probe an empty index")
        live_idx = jnp.asarray(
            np.nonzero(self.live)[0].astype(np.int32)
        )
        if self.vectors is not None:
            # probe the served encoding: signatures were built from
            # rotated vectors, so the sampled stats must be too (the
            # accumulator's words are already rotated)
            v = self.vectors[live_idx]
            if self.rotation is not None:
                v = v @ self.rotation
            r = probe_corpus(
                v, sample=sample, queries=queries, k=k, seed=seed,
            )
        else:
            r = probe_signatures(
                self.words[live_idx], self.dim, sample=sample, k=k,
                seed=seed,
            )
        return dataclasses.replace(
            r,
            sign_entropy=self.probe_acc.sign_entropy,
            strong_entropy=self.probe_acc.strong_entropy,
        )

    # -- drift alarms (DESIGN.md §12) --------------------------------------

    def attach_drift_monitor(self, monitor=None, *, tenant="default",
                             registry=None, **monitor_kw):
        """Arm probe-drift alarms: after every insert/delete/consolidate
        batch the accumulator's exact bit-plane stats are re-scored
        against the calibrated green/amber/red thresholds
        (:class:`repro.obs.DriftMonitor`) and band crossings raise
        alarms through the metrics layer.

        Pass a prebuilt monitor, or kwargs to build one over this
        index's accumulator (thresholds default to the build-time probe
        report's, keeping the live banding consistent with the verdict
        that chose the nav policy).  Returns the armed monitor.
        """
        if monitor is None:
            from repro.obs import DriftMonitor
            if "thresholds" not in monitor_kw and self.report is not None:
                monitor_kw["thresholds"] = self.report.thresholds
            monitor = DriftMonitor(
                self.probe_acc, tenant=tenant, registry=registry,
                **monitor_kw,
            )
        self.drift_monitor = monitor
        monitor.check()                     # establish the current band
        return monitor

    # -- structural health (graph X-ray, DESIGN.md §15) --------------------

    def graph_report(
        self,
        *,
        sample: int = 256,
        agreement_k: int = 8,
        max_hops: int = 64,
        seed: int = 0,
        thresholds=None,
        registry=None,
    ):
        """Compute (and cache as ``graph_health``) the structural
        :class:`~repro.obs.graph.GraphHealthReport` over the live set:
        tombstoned rows route in the BFS but never count as unreachable,
        and tombstone density itself is one of the banded statistics."""
        if self.n_live == 0:
            raise ValueError("cannot X-ray an empty index")
        from repro.obs.graph import (
            DEFAULT_GRAPH_THRESHOLDS,
            graph_health_report,
        )
        self.graph_health = graph_health_report(
            self.adjacency,
            medoid=max(self.medoid, 0),
            words=self.words if self.vectors is not None else None,
            dim=self.dim,
            vectors=self.vectors,
            live=self.live,
            allocated=self.allocated,
            sample=sample,
            agreement_k=agreement_k,
            max_hops=max_hops,
            seed=seed,
            thresholds=thresholds or DEFAULT_GRAPH_THRESHOLDS,
            registry=registry,
        )
        return self.graph_health

    def attach_graph_monitor(self, monitor=None, *, tenant="default",
                             registry=None, **monitor_kw):
        """Arm graph-health banding: every :meth:`consolidate` cycle
        re-X-rays the live graph and band *worsenings* raise
        :class:`~repro.obs.graph.GraphHealthAlarm`s (the trigger class
        :class:`~repro.obs.remediate.RemediationPolicy.attach_graph`
        subscribes to).  The first check runs now, so arming an already
        degraded graph alarms immediately.  Returns the monitor."""
        if monitor is None:
            from repro.obs.graph import GraphHealthMonitor
            monitor = GraphHealthMonitor(
                tenant=tenant, registry=registry, **monitor_kw,
            )
        self.graph_monitor = monitor
        if self.n_live:
            monitor.check(self.graph_report(registry=registry))
        return monitor

    def replan(
        self,
        *,
        nav: str,
        ef_scale: int | None = None,
        adaptive: bool | None = None,
        source: str = "replan",
    ) -> NavPolicy:
        """Switch the live index's default nav at serve time (the
        remediation path, DESIGN.md §14).  Same contract as
        ``QuIVerIndex.replan`` except ``nav="ivf"`` is rejected for the
        same reason ``search(nav="ivf")`` is: coarse partitions go
        stale under churn — freeze() first.

        A mutable index resolves its default nav from ``metric_kind``
        (the policy carries only the ef/escalation schedule), so both
        are updated together.
        """
        if nav == "ivf":
            raise ValueError(
                "replan(nav='ivf') is not available on a mutable index "
                "(partitions go stale under churn); freeze() first"
            )
        if nav == "float32" and self.vectors is None:
            raise ValueError(
                "replan(nav='float32') needs the cold vector tier; "
                "this index is vector-free"
            )
        if self.policy is not None:
            kw = {"nav": nav, "source": source}
            if ef_scale is not None:
                kw["ef_scale"] = int(ef_scale)
            if adaptive is not None:
                kw["adaptive"] = bool(adaptive)
            self.policy = dataclasses.replace(self.policy, **kw)
        else:
            self.policy = NavPolicy(
                nav=nav, source=source,
                **({} if ef_scale is None else {"ef_scale": int(ef_scale)}),
                **({} if adaptive is None else {"adaptive": bool(adaptive)}),
            )
        self.metric_kind = nav
        return self.policy

    def _note_mutation(self, kind: str, count: int):
        """Mutation telemetry + drift re-score (one owner: insert,
        delete and consolidate all funnel through here)."""
        from repro.obs.metrics import get_default_registry
        reg = get_default_registry()
        reg.counter(
            "quiver_stream_mutations_total",
            "streaming mutations by kind", labels=("kind",),
        ).inc(count, kind=kind)
        reg.gauge(
            "quiver_stream_live_rows", "live rows in mutable indexes",
        ).set(self.n_live)
        if self.drift_monitor is not None:
            return self.drift_monitor.check()
        return None

    # -- introspection -----------------------------------------------------

    @property
    def n_live(self) -> int:
        return int(self.live.sum())

    @property
    def n_dead(self) -> int:
        return int((self.allocated & ~self.live).sum())

    @property
    def free_slots(self) -> int:
        return self.capacity - self.size + len(self._free)

    def __len__(self) -> int:
        return self.n_live

    def memory_breakdown(self) -> dict[str, int]:
        sig_bytes = self.words.size * 4
        adj_bytes = self.adjacency.size * 4 + self.deg.size * 4
        mask_bytes = 2 * self.capacity  # live + allocated, host-side
        label_bytes = (
            self.labels.memory_bytes() if self.labels is not None else 0
        )
        cold = self.vectors.size * 4 if self.vectors is not None else 0
        shadow = getattr(self, "shadow", None)
        shadow_bytes = shadow.memory_bytes() if shadow is not None else 0
        hot = sig_bytes + adj_bytes + mask_bytes + label_bytes
        out = {
            "hot_signature_bytes": int(sig_bytes),
            "hot_adjacency_bytes": int(adj_bytes),
            "hot_mask_bytes": int(mask_bytes),
            "hot_label_bytes": int(label_bytes),
            "hot_total_bytes": int(hot),
            "cold_vector_bytes": int(cold),
            "host_shadow_bytes": int(shadow_bytes),
            "total_bytes": int(hot + cold + shadow_bytes),
        }
        if self.policy is not None:
            out["nav_policy"] = self.policy.describe()
            out["probe_verdict"] = (
                self.report.verdict if self.report is not None else "n/a"
            )
        if self.graph_health is not None:
            out["graph_verdict"] = self.graph_health.verdict
            out["graph_health_score"] = round(
                self.graph_health.health_score, 4
            )
        return out

    def _live_dev(self) -> jnp.ndarray:
        return jnp.asarray(self.live)

    # -- mutation ----------------------------------------------------------

    def _allocate(self, count: int) -> np.ndarray:
        take = min(count, len(self._free))
        ids = self._free[:take]
        fresh = count - take
        if self.size + fresh > self.capacity:
            raise ValueError(
                f"insert of {count} exceeds capacity: "
                f"{self.free_slots} slots free of {self.capacity} "
                f"(consolidate() reclaims tombstoned slots)"
            )
        del self._free[:take]
        ids = ids + list(range(self.size, self.size + fresh))
        self.size += fresh
        return np.asarray(ids, dtype=np.int32)

    def insert(self, vectors: jnp.ndarray, labels=None) -> np.ndarray:
        """Insert a batch of float32 vectors; returns their slot ids.

        Vectors are L2-normalized and binarized, then chunk-linked
        against the live graph: beam search from the medoid, alpha-prune
        in the index's metric space, forward + reverse edge install —
        the shared primitives of ``repro.core.linking``.

        ``labels`` (optional) assigns filter labels on the way in: one
        int or iterable of ints per vector (or a single int for the
        whole batch), written into the :class:`LabelStore` before the
        new nodes become searchable.  Requires ``enable_labels``.
        """
        v = _normalize(jnp.asarray(vectors, dtype=jnp.float32))
        if v.ndim == 1:
            v = v[None]
        if v.shape[-1] != self.dim:
            raise ValueError(f"dim mismatch: {v.shape[-1]} != {self.dim}")
        if labels is not None and self.labels is None:
            raise ValueError(
                "insert(labels=...) needs enable_labels(n_labels) first"
            )
        if v.shape[0] == 0:
            return np.empty((0,), dtype=np.int32)
        ids = self._allocate(v.shape[0])
        pre_live = self.n_live
        if labels is not None:
            self.labels.set(ids, labels)
        elif self.labels is not None:
            self.labels.clear(ids)     # reused slots must start clean

        enc = v @ self.rotation if self.rotation is not None else v
        sig_words = bq.encode(enc).words
        self.probe_acc.add(np.asarray(sig_words))
        dev_ids = jnp.asarray(ids)
        self.words = self.words.at[dev_ids].set(sig_words)
        if self.vectors is not None:
            self.vectors = self.vectors.at[dev_ids].set(v)
        self.live[ids] = True
        self.allocated[ids] = True
        if self.medoid < 0 or pre_live == 0:
            # empty (or fully-tombstoned) graph: a dead medoid inside an
            # all-dead component could strand the new nodes — re-anchor
            self.medoid = int(ids[0])

        p = self.params
        live_before = 0
        pos = 0
        while pos < len(ids):
            # adapt the chunk to the current graph size: a chunk links
            # against a frozen snapshot, so never link more nodes at
            # once than the graph already holds (bootstrap quality)
            live_before = self.n_live - (len(ids) - pos)
            take = min(p.chunk, max(16, live_before), len(ids) - pos)
            block = ids[pos:pos + take]
            pos += take
            padded = _pad_ids(block, _bucket(take, p.chunk))
            self.adjacency, self.deg, added = _link_op(
                self.words, self.vectors, self.adjacency, self.deg,
                self._live_dev(), padded, jnp.int32(self.medoid),
                kind=self.metric_kind, dim=self.dim,
                ef=p.ef_construction, pool=p.prune_pool, r=p.r,
                alpha=p.alpha, n=self.capacity, expand=p.beam_expand,
                r_total=p.r_total,
            )
            self.stats.reverse_edges_added += int(added)
        self._consolidate_overflow()
        self.stats.inserts += len(ids)
        self.generation += 1
        self._note_mutation("insert", len(ids))
        return ids

    def delete(self, ids) -> int:
        """Tombstone ``ids``; returns how many were live.

        Dead nodes keep routing beam searches until :meth:`consolidate`
        splices them out and reclaims their slots.  Their label bits
        are cleared *now*: popcounts drive selectivity routing, and
        dead-inflated counts would keep a mostly-deleted label on the
        graph route long after brute force became the right answer.
        """
        ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
        if len(ids) and (ids.min() < 0 or ids.max() >= self.capacity):
            raise ValueError(f"ids out of range [0, {self.capacity})")
        was_live = self.live[ids].sum()
        gone = np.unique(ids[self.live[ids]])
        if gone.size:
            # un-count exactly the rows leaving the live set (duplicate
            # and already-dead ids must not decrement twice)
            self.probe_acc.remove(np.asarray(self.words[jnp.asarray(gone)]))
        self.live[ids] = False
        if self.labels is not None:
            self.labels.clear(ids)
        self.stats.deletes += int(was_live)
        self.generation += 1
        self._note_mutation("delete", int(was_live))
        return int(was_live)

    def _batched_rows(self, rows: np.ndarray, op) -> None:
        """Run a row-repair device op over bucketed batches of rows."""
        chunk = self.params.chunk
        for s in range(0, len(rows), chunk):
            block = rows[s:s + chunk]
            padded = _pad_ids(block, _bucket(len(block), chunk))
            self.adjacency, self.deg = op(padded)

    def _consolidate_overflow(self) -> None:
        """Re-prune rows whose degree overflowed r (build-time analogue)."""
        deg_host = np.asarray(self.deg)
        overflow = np.nonzero(deg_host > self.params.r)[0].astype(np.int32)
        if overflow.size == 0:
            return
        p = self.params
        self._batched_rows(
            overflow,
            lambda row_ids: _overflow_op(
                self.words, self.vectors, self.adjacency, self.deg,
                self._live_dev(), row_ids,
                kind=self.metric_kind, dim=self.dim, r=p.r,
                alpha=p.alpha, r_total=p.r_total,
            ),
        )

    def consolidate(self) -> dict[str, int]:
        """FreshDiskANN-style repair + slot reclamation.

        For every live row that points at a tombstone, splice the dead
        neighbours' own live out-edges into the row's candidate pool
        and alpha-prune it in the registered metric space.  Then clear
        the dead rows, reclaim their slots for reuse, and re-elect the
        medoid if it died.
        """
        dead_mask = self.allocated & ~self.live
        dead = np.nonzero(dead_mask)[0]
        report = {"dead": int(dead.size), "repaired_rows": 0,
                  "reclaimed": int(dead.size)}
        if dead.size == 0:
            return report

        # compute the points-at-dead mask on device: only a (capacity,)
        # bool comes back, never the full adjacency matrix
        adj = self.adjacency
        dead_mask_dev = jnp.asarray(dead_mask)
        points_at_dead = np.asarray(
            ((adj >= 0) & dead_mask_dev[jnp.clip(adj, 0, None)]).any(axis=1)
        )
        affected = np.nonzero(self.live & points_at_dead)[0].astype(
            np.int32
        )
        report["repaired_rows"] = int(affected.size)

        p = self.params
        if affected.size:
            self._batched_rows(
                affected,
                lambda row_ids: _repair_op(
                    self.words, self.vectors, self.adjacency, self.deg,
                    self._live_dev(), row_ids,
                    kind=self.metric_kind, dim=self.dim, r=p.r,
                    alpha=p.alpha, r_total=p.r_total, pool=p.prune_pool,
                ),
            )

        # clear + reclaim the dead slots (labels too: a reclaimed slot
        # must not inherit its previous occupant's filter labels)
        dead_dev = jnp.asarray(dead.astype(np.int32))
        self.adjacency = self.adjacency.at[dead_dev].set(-1)
        self.deg = self.deg.at[dead_dev].set(0)
        if self.labels is not None:
            self.labels.clear(dead)
        self.allocated[dead] = False
        self._free.extend(int(i) for i in dead)

        # re-elect the medoid if it died (or was never set)
        if self.n_live and (self.medoid < 0 or not self.live[self.medoid]):
            self.medoid = int(_medoid_op(
                self.words, self.vectors, self._live_dev(),
                kind=self.metric_kind, dim=self.dim, chunk=4096,
            ))
        elif self.n_live == 0:
            self.medoid = -1

        self.stats.consolidations += 1
        self.stats.rows_repaired += report["repaired_rows"]
        self.stats.slots_reclaimed += report["reclaimed"]
        self.generation += 1
        self._note_mutation("consolidate", 1)
        # per-cycle health delta: re-X-ray the repaired graph so the
        # monitor's delta gauge tracks what each consolidation bought
        # (or failed to buy) and band worsenings reach the remediation
        # ladder before shadow recall moves
        if self.graph_monitor is not None and self.n_live:
            prev = self.graph_monitor.last_score
            rep = self.graph_report()
            self.graph_monitor.check(rep)
            report["health_score"] = rep.health_score
            report["health_band"] = rep.verdict
            if prev is not None:
                report["health_delta"] = rep.health_score - prev
        return report

    # -- search ------------------------------------------------------------

    def search(
        self,
        queries: jnp.ndarray,
        k: int = 10,
        *,
        ef: int = 64,
        rerank: bool = True,
        nav: str | None = None,
        expand: int = 1,
        query_batch: int = 256,
        filter=None,
        selectivity_floor: float = DEFAULT_SELECTIVITY_FLOOR,
        adaptive: bool | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Tombstone-aware search: same contract as ``QuIVerIndex.search``
        (including the score scale: cosine with ``rerank=True``, negated
        navigation distances with ``rerank=False``, and the
        :class:`NavPolicy` schedule — ef scaling plus per-query
        adaptive escalation — when the index carries one) but dead or
        never-inserted slots cannot appear in the results.

        ``filter`` composes with tombstones through the beam's two-mask
        design: the predicate mask and the live mask each restrict only
        what may be *returned* while navigation traverses everything —
        so results are exactly live ∧ matching.
        """
        queries = _normalize(jnp.asarray(queries, dtype=jnp.float32))
        if queries.ndim == 1:
            queries = queries[None]
        nq = queries.shape[0]
        if self.n_live == 0:
            return (np.full((nq, k), -1, np.int32),
                    np.full((nq, k), -np.inf, np.float32))
        if nav == "ivf":
            raise ValueError(
                "nav='ivf' serves from a frozen coarse partition, which "
                "would go stale under churn — freeze() this index first "
                "(with BuildParams(ivf_candidates=True) the frozen "
                "snapshot carries a fresh partition)"
            )
        ef, adaptive, sched = resolve_schedule(self.policy, nav, ef,
                                               adaptive)
        kind = nav or self.metric_kind
        enc_in = queries
        if self.rotation is not None and kind != "float32":
            enc_in = queries @ self.rotation
        reprs = encode_queries_for(kind, enc_in)
        live = self._live_dev()

        result_valid = live          # live & live == live: no-op AND
        start = jnp.int32(max(self.medoid, 0))
        ef_run = ef
        if filter is not None:
            if self.labels is None:
                raise ValueError(
                    "filtered search needs enable_labels() / "
                    "insert(labels=...) first"
                )
            expr = validate(filter, self.labels.n_labels)
            count_fn = self.labels.count_fn()
            sel = estimate_selectivity(expr, count_fn, self.n_live)
            mask = self.labels.mask(expr)
            if route(sel, selectivity_floor) == "brute":
                # estimate is a bound — verify against the exact live
                # match count before materializing the match set
                match = np.nonzero(np.asarray(mask) & self.live)[0]
                sel = len(match) / max(self.n_live, 1)
                if route(sel, selectivity_floor) == "brute":
                    if rerank and self.vectors is not None:
                        return brute_force_topk(
                            queries, match, k, vectors=self.vectors
                        )
                    backend = _mk_backend(
                        kind, self.dim, self.words, self.vectors
                    )
                    return brute_force_topk(
                        queries, match, k, vectors=None, backend=backend,
                        reprs=reprs,
                    )
            result_valid = mask
            ef_run = widened_ef(ef, sel, selectivity_floor, self.n_live)
            lbl = entry_label(expr, count_fn)
            if lbl is not None:
                ent = int(self.labels.entries[lbl])
                if ent >= 0 and self.live[ent]:
                    start = jnp.int32(ent)

        def run(reprs_r, queries_r, ef_r, want_margin):
            # margins are computed inside the jitted _search_op either
            # way (fused, ~free); want_margin only gates the host copy
            out_ids, out_scores, out_margin = [], [], []
            for s in range(0, reprs_r.shape[0], query_batch):
                rep = reprs_r[s:s + query_batch]
                q = queries_r[s:s + query_batch]
                real = rep.shape[0]
                bucket = batch_bucket(real, query_batch)
                ids, scores, margin = _search_op(
                    self.words, self.vectors, self.adjacency, live,
                    result_valid, start,
                    pad_rows(rep, bucket), pad_rows(q, bucket),
                    kind=kind, dim=self.dim, ef=ef_r, n=self.capacity,
                    expand=expand, k=k, use_rerank=rerank,
                )
                out_ids.append(np.asarray(ids[:real]))
                out_scores.append(np.asarray(scores[:real]))
                if want_margin:
                    out_margin.append(np.asarray(margin[:real]))
            return (np.concatenate(out_ids), np.concatenate(out_scores),
                    np.concatenate(out_margin) if want_margin else None)

        return escalated_search(
            run, reprs, queries, ef_run, adaptive=adaptive,
            margin_thr=sched.escalate_margin, mult=sched.escalate_mult,
        )

    # -- snapshots ---------------------------------------------------------

    def freeze(self) -> QuIVerIndex:
        """Compact the live set into an immutable :class:`QuIVerIndex`.

        Live slots keep their relative order; edges to tombstones are
        dropped (they are already absent after :meth:`consolidate`).
        With zero churn this is exactly the arrays the index was built
        with, so searches are bit-identical to the source index.

        When the index was configured with
        ``BuildParams(ivf_candidates=True)`` the snapshot also carries
        a freshly built coarse partition over the compacted live set,
        so ``nav="ivf"`` works on the frozen index (it is rejected on
        the mutable one — the partition would go stale under churn).
        """
        if self.n_live == 0:
            raise ValueError("cannot freeze an empty index")
        live_idx = np.nonzero(self.live)[0]
        remap = np.full((self.capacity + 1,), -1, dtype=np.int32)
        remap[live_idx] = np.arange(live_idx.size, dtype=np.int32)

        sel = jnp.asarray(live_idx.astype(np.int32))
        words = self.words[sel]
        vectors = self.vectors[sel] if self.vectors is not None else None
        adj_host = np.asarray(self.adjacency)[live_idx]
        adj_new = remap[np.clip(adj_host, 0, None)]
        adj_new[adj_host < 0] = -1

        medoid = self.medoid
        if medoid < 0 or not self.live[medoid]:
            medoid = int(_medoid_op(
                self.words, self.vectors, self._live_dev(),
                kind=self.metric_kind, dim=self.dim, chunk=4096,
            ))
        sigs = bq.Signature(words=words, dim=self.dim)
        ivf = None
        if getattr(self.params, "ivf_candidates", False):
            from repro.ivf import build_partition
            ivf = build_partition(sigs, seed=self.params.seed)
        return QuIVerIndex(
            sigs=sigs,
            adjacency=jnp.asarray(adj_new),
            medoid=int(remap[medoid]),
            params=self.params,
            vectors=vectors,
            rotation=self.rotation,
            metric_kind=self.metric_kind,
            labels=(
                self.labels.compact(live_idx)
                if self.labels is not None else None
            ),
            policy=self.policy,
            report=self.report,
            ivf=ivf,
            graph_health=self.graph_health,
        )

    # -- persistence -------------------------------------------------------

    def save(self, path: str) -> None:
        label_fields = (
            self.labels.to_npz_fields() if self.labels is not None else {}
        )
        probe_fields = {}
        if self.policy is not None:
            probe_fields.update(self.policy.to_npz_fields())
        if self.report is not None:
            probe_fields.update(self.report.to_npz_fields())
        if self.graph_health is not None:
            probe_fields.update(self.graph_health.to_npz_fields())
        np.savez_compressed(
            path,
            stream_format=np.int64(1),
            **label_fields,
            **probe_fields,
            words=np.asarray(self.words),
            dim=np.int64(self.dim),
            adjacency=np.asarray(self.adjacency),
            deg=np.asarray(self.deg),
            vectors=(
                np.asarray(self.vectors)
                if self.vectors is not None else np.zeros((0,))
            ),
            rotation=(
                np.asarray(self.rotation)
                if self.rotation is not None else np.zeros((0,))
            ),
            live=self.live,
            allocated=self.allocated,
            free=np.asarray(self._free, dtype=np.int64),
            size=np.int64(self.size),
            medoid=np.int64(self.medoid),
            generation=np.int64(self.generation),
            metric_kind=np.array(self.metric_kind),
            **params_to_npz(self.params),
        )

    @classmethod
    def load(cls, path: str) -> "MutableQuIVerIndex":
        z = np.load(path)
        if "stream_format" not in z:
            # an immutable QuIVerIndex archive: adopt it
            return cls.from_index(QuIVerIndex.load(path))
        params = params_from_npz(z)
        dim = int(z["dim"])
        vectors = z["vectors"]
        rotation = z["rotation"]
        out = cls(
            capacity=z["words"].shape[0],
            dim=dim,
            params=params,
            metric_kind=str(z["metric_kind"]),
            keep_vectors=bool(vectors.size),
            rotation=jnp.asarray(rotation) if rotation.size else None,
        )
        out.words = jnp.asarray(z["words"])
        out.adjacency = jnp.asarray(z["adjacency"])
        out.deg = jnp.asarray(z["deg"])
        if vectors.size:
            out.vectors = jnp.asarray(vectors)
        out.live = z["live"].astype(bool)
        out.allocated = z["allocated"].astype(bool)
        out.labels = LabelStore.from_npz(z)
        out._free = [int(i) for i in z["free"]]
        out.size = int(z["size"])
        out.medoid = int(z["medoid"])
        out.generation = int(z["generation"])
        out.policy = NavPolicy.from_npz(z)
        out.report = CompatibilityReport.from_npz(z)
        from repro.obs.graph import GraphHealthReport
        out.graph_health = GraphHealthReport.from_npz(z)
        # the accumulator is derived state: recompute from the live rows
        # (exactly what the incremental path would have maintained)
        out.probe_acc = ProbeAccumulator.from_words(
            np.asarray(out.words)[out.live], dim
        )
        return out
