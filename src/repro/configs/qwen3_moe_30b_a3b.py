"""Qwen3-30B-A3B — 128-expert top-8 MoE. [hf:Qwen/Qwen3-30B-A3B; hf]
48L d_model=2048 32H (GQA kv=4) d_ff=768 (per expert) vocab=151936.
head_dim=128 per the HF config (q/k/v project to 4096, not d_model)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    n_experts=128,
    top_k=8,
    moe_every=1,
    moe_offset=0,
    rope_theta=1e6,
))
