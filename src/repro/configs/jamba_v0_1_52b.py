"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536.  Attention sits at position 4 of each 8-layer block; MoE on
every second layer (per the paper's e=16 top-2, 1-in-2 MoE frequency).
Runs long_500k: the Mamba layers give O(1) state and the 4 attention
layers carry a (sharded) 500k KV cache.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=4,
    d_state=16,
    d_conv=4,
    ssm_expand=2,
    supports_long_context=True,
))
