"""Nemotron-4 340B — dense GQA with squared-ReLU FFN.
[arXiv:2402.16819; unverified] 96L d_model=18432 96H (GQA kv=8)
d_ff=73728 vocab=256000."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    activation="squared_relu",
    rope_theta=1e4,
))
