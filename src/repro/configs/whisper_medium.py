"""Whisper-medium backbone — encoder-decoder, conv frontend stubbed.
[arXiv:2212.04356; unverified] 24L (x2: 24 enc + 24 dec) d_model=1024
16H (kv=16) d_ff=4096 vocab=51865.  ``input_specs`` provides precomputed
frame embeddings (B, S, d); decoder length = seq_len // 4 for training
shapes (audio-to-text compression); decode shapes exercise the decoder
with self-KV of seq_len and cross-KV over the encoder output."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    n_dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    activation="gelu",
    frontend="audio_stub",
))
