"""xLSTM-1.3B — sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM).
[arXiv:2405.04517; unverified] 48L d_model=2048 4H d_ff=0 (cells carry
their own projections) vocab=50304.  Pure recurrence -> runs long_500k
with O(1) decode state."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    slstm_offset=7,
    xlstm_expand=2.0,
    supports_long_context=True,
))
