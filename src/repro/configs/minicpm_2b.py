"""MiniCPM-2B — llama-like dense MHA (kv=36), WSD LR schedule.
[arXiv:2404.06395; hf] 40L d_model=2304 36H (kv=36) d_ff=5760
vocab=122753 (padded to 122880 for the 16-way model axis).
The WSD (warmup-stable-decay) schedule lives in repro/optim/schedule.py
and is this arch's default."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    rope_theta=1e4,
    note="WSD schedule arch; 36 heads do not divide the 16-way model "
         "axis -> head sharding falls back to fused-dim sharding",
))
