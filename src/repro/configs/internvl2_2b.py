"""InternVL2-2B backbone — InternLM2-1.8B decoder + InternViT stub.
[arXiv:2404.16821; hf] 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 (padded).  The ViT frontend is a stub: ``input_specs``
provides 256 precomputed patch embeddings per example which are
prepended to the token sequence; loss only on token positions."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    frontend="patch_stub",
    n_frontend_tokens=256,
    rope_theta=1e6,
))
