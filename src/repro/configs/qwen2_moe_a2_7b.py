"""Qwen1.5-MoE-A2.7B — 4 shared + 60 routed experts top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf] 24L d_model=2048 16H (kv=16)
d_ff=1408 (per expert) vocab=151936.  60 experts do not divide any mesh
axis — expert weights shard on their matrix dims instead (DESIGN.md §4)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    n_experts=60,
    n_shared_experts=4,
    top_k=4,
    moe_every=1,
    moe_offset=0,
    rope_theta=1e6,
))
