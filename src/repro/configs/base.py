"""Architecture + shape configuration schema and registry.

Every assigned architecture is an ``ArchConfig`` in its own module under
``repro/configs``; ``get_config(name)`` resolves it.  ``smoke()``
derives a reduced same-family config for CPU tests; the full config is
only ever lowered via the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal


Family = Literal["dense", "moe", "hybrid", "ssm", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_every: int = 1
    moe_offset: int = 1
    # hybrid (attention-every-k, rest mamba)
    attn_every: int = 1
    attn_offset: int = 0
    # SSM / mamba
    d_state: int = 16
    d_conv: int = 4
    ssm_expand: int = 2
    # xLSTM
    slstm_every: int = 0
    slstm_offset: int = 0
    xlstm_expand: float = 2.0
    # misc
    activation: str = "swiglu"
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    sliding_window: int = 0
    n_dec_layers: int = 0           # encdec only
    frontend: str | None = None     # "patch_stub" | "audio_stub"
    n_frontend_tokens: int = 256
    supports_long_context: bool = False
    vocab_pad_to: int = 512
    kv_chunk: int = 1024
    remat: bool = True
    note: str = ""

    # -- derived -------------------------------------------------------------

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def seq_sharded_residual(self) -> bool:
        """Megatron-style sequence-parallel residual stream: right for
        attention-dominant stacks; wrong for recurrent mixers (mamba/
        xlstm time-scans need the full sequence per device, so their
        residual shards d_model over tp instead)."""
        return self.family in ("dense", "moe", "vlm", "encdec")

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    def pattern(self) -> tuple[int, int]:
        """(period, n_groups) for the scan-over-layers grouping."""
        period = 1
        if self.family == "hybrid":
            period = math.lcm(period, self.attn_every)
        if self.n_experts:
            period = math.lcm(period, self.moe_every)
        if self.slstm_every:
            period = math.lcm(period, self.slstm_every)
        assert self.n_layers % period == 0, (self.n_layers, period)
        return period, self.n_layers // period

    def layer_kind(self, pos: int) -> str:
        if self.family == "ssm":
            if self.slstm_every and pos % self.slstm_every == self.slstm_offset:
                return "slstm"
            return "mlstm"
        if self.family == "hybrid":
            if pos % self.attn_every == self.attn_offset:
                return "attn"
            return "mamba"
        return "attn"

    def layer_has_moe(self, pos: int) -> bool:
        return bool(self.n_experts) and pos % self.moe_every == self.moe_offset

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.head_dim_
        total = self.padded_vocab * d * 2          # embed + lm_head
        period, groups = self.pattern()
        for pos in range(period):
            kind = self.layer_kind(pos)
            n = groups
            if kind == "attn":
                total += n * d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
            elif kind == "mamba":
                di = self.ssm_expand * d
                total += n * (
                    d * 2 * di + di * d            # in/out proj
                    + di * (self.d_conv + 2 * self.d_state + d // 16 + 2)
                    + (d // 16) * di + di * self.d_state
                )
            elif kind == "mlstm":
                di = int(self.xlstm_expand * d)
                hd_x = di // self.n_heads
                total += n * (2 * d * di + 3 * self.n_heads * hd_x * hd_x
                              + di * 2 * self.n_heads + di * d)
                continue
            elif kind == "slstm":
                total += n * (4 * d * d + 4 * d * (d // self.n_heads)
                              + 4 * d * d)
                continue
            if self.layer_has_moe(pos):
                total += n * self.n_experts * 3 * d * self.d_ff
                total += n * self.n_shared_experts * 3 * d * self.d_ff
                total += n * d * self.n_experts
            else:
                mats = 3 if self.activation == "swiglu" else 2
                total += n * mats * d * self.d_ff
        if self.family == "encdec":
            # decoder self+cross attention and FFN
            total += self.n_dec_layers * (
                d * hd * (self.n_heads * 2 + self.n_kv_heads * 2) * 2
                + 2 * d * self.d_ff
            )
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE top-k counting)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        period, groups = self.pattern()
        moe_layers = sum(
            groups for pos in range(period) if self.layer_has_moe(pos)
        )
        dense_expert = self.param_count() - moe_layers * (
            self.n_experts * 3 * d * self.d_ff
        )
        active = dense_expert + moe_layers * (
            self.top_k * 3 * d * self.d_ff
        )
        return active

    # -- reduced config for CPU smoke tests ----------------------------------

    def smoke(self) -> "ArchConfig":
        period, _ = self.pattern()
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=period * 2 if period > 1 else 2,
            d_model=64,
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2)
            if self.n_kv_heads < self.n_heads else min(self.n_heads, 4),
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=503,                      # odd on purpose: pad path
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 2),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            d_state=8,
            n_dec_layers=2 if self.n_dec_layers else 0,
            n_frontend_tokens=8 if self.frontend else 0,
            kv_chunk=64,
            remat=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_configs() -> dict[str, ArchConfig]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all():
    # import side effect registers each config
    from repro.configs import (  # noqa: F401
        command_r_plus_104b,
        internvl2_2b,
        jamba_v0_1_52b,
        minicpm_2b,
        nemotron_4_340b,
        qwen2_moe_a2_7b,
        qwen3_moe_30b_a3b,
        whisper_medium,
        xlstm_1_3b,
        yi_34b,
    )


def cell_is_supported(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether this (arch x shape) cell runs or is a documented skip."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "skip(full-attn): quadratic attention at 500k"
    return True, ""
