"""QuIVer-backed semantic deduplication for the data pipeline.

Technique integration #2 (DESIGN.md §4): before documents enter the
token pipeline, their embeddings are indexed with QuIVer and near-
duplicates — BQ beam-search hit whose *float32-reranked* cosine exceeds
``threshold`` — are dropped.  The whole scan runs in the 2-bit hot path
(build + search never touch float32 except at rerank), which is what
makes corpus-scale dedup cheap: the paper's 12:1 hot-memory compression
applies to the dedup working set too.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams


def semantic_dedup(
    embeddings: np.ndarray,
    *,
    threshold: float = 0.97,
    params: BuildParams | None = None,
    ef: int = 32,
    query_batch: int = 256,
) -> np.ndarray:
    """Returns indices of the documents to KEEP (first occurrence wins).

    Greedy order-preserving dedup: build the index once over all docs,
    then for each doc query its neighbourhood; doc i is dropped iff some
    kept doc j < i has cosine(q_i, v_j) >= threshold.
    """
    params = params or BuildParams(
        m=8, ef_construction=48, prune_pool=48, chunk=256
    )
    x = np.asarray(embeddings, dtype=np.float32)
    idx = QuIVerIndex.build(jnp.asarray(x), params)
    ids, scores = idx.search(
        jnp.asarray(x), k=min(16, ef), ef=ef, query_batch=query_batch
    )

    keep_mask = np.ones(len(x), dtype=bool)
    for i in range(len(x)):
        for j, s in zip(ids[i], scores[i]):
            if j < 0 or j == i:
                continue
            if s >= threshold and j < i and keep_mask[j]:
                keep_mask[i] = False
                break
    return np.nonzero(keep_mask)[0]
