"""QuIVer-backed semantic deduplication for the data pipeline.

Technique integration #2 (DESIGN.md §4): before documents enter the
token pipeline, their embeddings are indexed with QuIVer and near-
duplicates — BQ beam-search hit whose *float32-reranked* cosine exceeds
``threshold`` — are dropped.  The whole scan runs in the 2-bit hot path
(build + search never touch float32 except at rerank), which is what
makes corpus-scale dedup cheap: the paper's 12:1 hot-memory compression
applies to the dedup working set too.

Two modes:

* :func:`semantic_dedup` — batch: build once over all docs, then scan.
* :func:`streaming_dedup` — insert-as-you-scan over a mutable index
  (DESIGN.md §8): each batch is searched against only the *kept* docs
  so far, survivors are inserted immediately.  Same keep semantics
  (first occurrence wins), but single-pass — the natural shape for a
  pipeline that deduplicates while ingesting.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.index import QuIVerIndex
from repro.core.vamana import BuildParams
from repro.stream import MutableQuIVerIndex

_DEFAULT_PARAMS = dict(m=8, ef_construction=48, prune_pool=48, chunk=256)


def semantic_dedup(
    embeddings: np.ndarray,
    *,
    threshold: float = 0.97,
    params: BuildParams | None = None,
    ef: int = 32,
    query_batch: int = 256,
) -> np.ndarray:
    """Returns indices of the documents to KEEP (first occurrence wins).

    Greedy order-preserving dedup: build the index once over all docs,
    then for each doc query its neighbourhood; doc i is dropped iff some
    kept doc j < i has cosine(q_i, v_j) >= threshold.
    """
    params = params or BuildParams(**_DEFAULT_PARAMS)
    x = np.asarray(embeddings, dtype=np.float32)
    idx = QuIVerIndex.build(jnp.asarray(x), params)
    ids, scores = idx.search(
        jnp.asarray(x), k=min(16, ef), ef=ef, query_batch=query_batch
    )

    keep_mask = np.ones(len(x), dtype=bool)
    for i in range(len(x)):
        for j, s in zip(ids[i], scores[i]):
            if j < 0 or j == i:
                continue
            if s >= threshold and j < i and keep_mask[j]:
                keep_mask[i] = False
                break
    return np.nonzero(keep_mask)[0]


def streaming_dedup(
    embeddings: np.ndarray,
    *,
    threshold: float = 0.97,
    params: BuildParams | None = None,
    ef: int = 32,
    scan_batch: int = 256,
    k: int = 16,
    index: MutableQuIVerIndex | None = None,
) -> np.ndarray:
    """Insert-as-you-scan dedup; returns indices of documents to KEEP.

    Each batch is (1) searched against the index of previously-kept
    docs — a reranked-cosine hit >= ``threshold`` drops the doc — then
    (2) checked for exact-cosine duplicates *within* the batch (the
    index cannot see docs that have not been inserted yet), and (3) the
    survivors are inserted before the next batch is scanned.

    Pass ``index`` to continue an earlier scan (e.g. deduplicating an
    hourly feed against everything already ingested); by default a
    fresh mutable index sized to ``len(embeddings)`` is used.
    """
    params = params or BuildParams(**_DEFAULT_PARAMS)
    x = np.asarray(embeddings, dtype=np.float32)
    x = x / np.maximum(
        np.linalg.norm(x, axis=-1, keepdims=True), 1e-12
    )
    if index is None:
        index = MutableQuIVerIndex.empty(
            x.shape[-1], len(x), params
        )
    if index.vectors is None:
        # without the cold tier, search scores are negative BQ
        # distances and the >= threshold test would never fire
        raise ValueError(
            "streaming_dedup needs an index with cold vectors "
            "(keep_vectors=True) — thresholds are reranked cosines"
        )
    keep: list[int] = []
    for s in range(0, len(x), scan_batch):
        batch = x[s:s + scan_batch]
        if index.n_live:
            ids, scores = index.search(
                jnp.asarray(batch), k=k, ef=ef
            )
            dup = ((np.asarray(ids) >= 0)
                   & (np.asarray(scores) >= threshold)).any(axis=1)
        else:
            dup = np.zeros(len(batch), dtype=bool)
        # within-batch: exact cosine against earlier survivors
        sims = batch @ batch.T
        survivors: list[int] = []
        for i in range(len(batch)):
            if dup[i]:
                continue
            if survivors and (sims[i, survivors] >= threshold).any():
                continue
            survivors.append(i)
        if survivors:
            index.insert(jnp.asarray(batch[survivors]))
            keep.extend(s + i for i in survivors)
    return np.asarray(keep, dtype=np.int64)
