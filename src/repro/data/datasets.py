"""Evaluation dataset generators (paper Table 4, offline-container edition).

The container has no network access, so the paper's two *synthetic*
datasets are generated exactly per its recipes, and the real-embedding
tiers are emulated by distribution surrogates with the structural
properties the paper identifies as causal (§5.4, §6):

* ``random_sphere``        — uniform unit vectors, seed 42 (paper's
  structureless lower bound; predicted recall ~0).
* ``synthetic_lr``         — 256 Zipf-weighted clusters in a 64-d
  subspace -> 768-d via random orthogonal basis, eps=0.05 full-rank
  noise, L2-norm (paper's causal probe; predicted recall ~50%).
* ``contrastive_surrogate``— hierarchical anisotropic clusters on the
  sphere with low effective dimensionality: a stand-in for the
  MiniLM/Cohere/DBpedia tier (predicted recall >91% at matching dims).
* ``clip_surrogate``       — two contrastive sub-distributions (image/
  text "modalities") sharing a space with a modality-gap offset: the
  RedCaps tier (predicted recall between GloVe and MiniLM tiers).
* ``euclidean_cv_surrogate``— non-negative, concentrated-positive
  features (SIFT/GIST-like); after L2-norm the sign bits carry ~no
  information -> predicted collapse (<6%).
* ``glove_like``           — cosine-native but non-contrastive: moderate
  rank, heavy-tailed cluster sizes (predicted ~50%).

Real-corpus loaders (``load_fvecs``) are provided for hosts that have the
actual datasets on disk.
"""

from __future__ import annotations

import numpy as np


def _l2norm(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def random_sphere(n: int = 10_000, d: int = 768, seed: int = 42) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return _l2norm(rng.standard_normal((n, d)).astype(np.float32))


def synthetic_lr(
    n: int = 10_000,
    d: int = 768,
    intrinsic: int = 64,
    clusters: int = 256,
    eps: float = 0.05,
    zipf_a: float = 1.2,
    seed: int = 0,
) -> np.ndarray:
    """Paper §5.1 Synthetic-LR: low-rank Zipf clusters + eps noise."""
    rng = np.random.default_rng(seed)
    # Zipf cluster weights
    w = 1.0 / np.arange(1, clusters + 1) ** zipf_a
    w /= w.sum()
    assign = rng.choice(clusters, size=n, p=w)
    centers = rng.standard_normal((clusters, intrinsic)).astype(np.float32)
    centers = _l2norm(centers)
    within = 0.35 * rng.standard_normal((n, intrinsic)).astype(np.float32)
    low_rank = centers[assign] + within
    # random orthogonal basis into ambient dims
    basis, _ = np.linalg.qr(rng.standard_normal((d, intrinsic)))
    x = low_rank @ basis.T.astype(np.float32)
    x += eps * rng.standard_normal((n, d)).astype(np.float32)
    return _l2norm(x.astype(np.float32))


def contrastive_surrogate(
    n: int = 10_000,
    d: int = 384,
    n_topics: int = 64,
    subclusters: int = 16,
    intrinsic: int | None = None,
    seed: int = 1,
) -> np.ndarray:
    """Single-modality contrastive-embedding surrogate (MiniLM tier).

    Hierarchical semantic clustering + low effective dimensionality +
    anisotropic within-cluster spread — the three properties §5.4 names.
    """
    rng = np.random.default_rng(seed)
    intrinsic = intrinsic or max(48, d // 8)
    topics = _l2norm(rng.standard_normal((n_topics, intrinsic)))
    sub = topics[:, None, :] + 0.45 * rng.standard_normal(
        (n_topics, subclusters, intrinsic)
    )
    sub = _l2norm(sub.reshape(-1, intrinsic))
    assign = rng.integers(0, sub.shape[0], size=n)
    # anisotropic within-cluster noise (decaying spectrum)
    spectrum = 1.0 / np.sqrt(1.0 + np.arange(intrinsic))
    within = rng.standard_normal((n, intrinsic)) * spectrum * 0.35
    low = sub[assign] + within
    basis, _ = np.linalg.qr(rng.standard_normal((d, intrinsic)))
    x = low @ basis.T
    x += 0.02 * rng.standard_normal((n, d))
    return _l2norm(x.astype(np.float32))


def clip_surrogate(
    n: int = 10_000, d: int = 512, seed: int = 2
) -> np.ndarray:
    """Multimodal (RedCaps/CLIP) surrogate: two modalities, shared space,
    modality-gap offset + per-modality covariance mismatch."""
    rng = np.random.default_rng(seed)
    half = n // 2
    base_img = contrastive_surrogate(half, d, seed=seed + 10)
    base_txt = contrastive_surrogate(n - half, d, seed=seed + 11)
    gap = _l2norm(rng.standard_normal((1, d)).astype(np.float32))
    # CLIP's measured modality gap is moderate (|mu_img - mu_txt| ~ 0.8
    # of unit norm pre-normalization); 0.3 reproduces the paper's
    # "high but sub-SOTA" RedCaps tier rather than a bimodal collapse.
    img = _l2norm(base_img + 0.3 * gap)
    txt = _l2norm(base_txt - 0.3 * gap)
    x = np.concatenate([img, txt], axis=0)
    perm = rng.permutation(n)
    return x[perm].astype(np.float32)


def glove_like(n: int = 10_000, d: int = 100, seed: int = 3) -> np.ndarray:
    """Cosine-native, non-contrastive word-vector surrogate (GloVe tier)."""
    rng = np.random.default_rng(seed)
    intrinsic = d // 2
    clusters = 512
    w = 1.0 / np.arange(1, clusters + 1) ** 1.05   # heavy-tailed sizes
    w /= w.sum()
    assign = rng.choice(clusters, size=n, p=w)
    centers = rng.standard_normal((clusters, intrinsic))
    low = centers[assign] + 0.9 * rng.standard_normal((n, intrinsic))
    basis, _ = np.linalg.qr(rng.standard_normal((d, intrinsic)))
    x = low @ basis.T + 0.15 * rng.standard_normal((n, d))
    return _l2norm(x.astype(np.float32))


def euclidean_cv_surrogate(
    n: int = 10_000, d: int = 128, seed: int = 4
) -> np.ndarray:
    """SIFT/GIST-like: non-negative concentrated histograms; after
    L2-norm the sign plane is constant -> BQ collapse (paper Finding 1)."""
    rng = np.random.default_rng(seed)
    clusters = 128
    assign = rng.integers(0, clusters, size=n)
    centers = np.abs(rng.standard_normal((clusters, d))) + 0.5
    x = centers[assign] + 0.3 * np.abs(rng.standard_normal((n, d)))
    return _l2norm(x.astype(np.float32))


DATASET_REGISTRY = {
    # name: (factory, default_dim, paper tier)
    "random-sphere": (random_sphere, 768, "collapse"),
    "synthetic-lr": (synthetic_lr, 768, "usable"),
    "minilm-surrogate": (
        lambda n, d=384, seed=1: contrastive_surrogate(n, d, seed=seed),
        384, "sota",
    ),
    "cohere-surrogate": (
        lambda n, d=768, seed=5: contrastive_surrogate(n, d, seed=seed),
        768, "sota",
    ),
    "dbpedia-surrogate": (
        lambda n, d=1536, seed=6: contrastive_surrogate(n, d, seed=seed),
        1536, "sota",
    ),
    "redcaps-surrogate": (clip_surrogate, 512, "high"),
    "glove-like": (glove_like, 100, "usable"),
    "sift-like": (euclidean_cv_surrogate, 128, "collapse"),
    "gist-like": (
        lambda n, d=960, seed=8: euclidean_cv_surrogate(n, d, seed=seed),
        960, "collapse",
    ),
}


def make_dataset(name: str, n: int, queries: int = 100, seed: int = 1234):
    """Returns (base (n, d), queries (q, d)) float32, unit-norm."""
    factory, d, _tier = DATASET_REGISTRY[name]
    base = factory(n + queries)
    rng = np.random.default_rng(seed)
    qidx = rng.choice(len(base), size=queries, replace=False)
    mask = np.ones(len(base), dtype=bool)
    mask[qidx] = False
    q = base[qidx] + 0.02 * rng.standard_normal(
        (queries, base.shape[1])
    ).astype(np.float32)
    q = q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    return base[mask][:n], q


def load_fvecs(path: str, max_n: int | None = None) -> np.ndarray:
    """Loader for standard .fvecs corpora when present on the host."""
    raw = np.fromfile(path, dtype=np.int32)
    d = raw[0]
    raw = raw.reshape(-1, d + 1)
    if max_n:
        raw = raw[:max_n]
    return raw[:, 1:].view(np.float32).copy()
