"""Deterministic, resumable, shard-indexed token pipeline.

Design constraints at fleet scale:
  * **Deterministic**: batch t is a pure function of (seed, step, host),
    so a restarted job resumes mid-epoch with no pipeline state beyond
    the step counter (pairs with the checkpoint design).
  * **Host-sharded**: each host materializes only its slice of the
    global batch (``host_slice``).
  * **Prefetch**: a background thread keeps ``prefetch`` batches ready.

Sources: a synthetic LM stream (n-gram-ish mixture, good enough for
loss-goes-down validation) or a memory-mapped token file.  The QuIVer
integration — semantic dedup of documents before batching — lives in
``repro/data/dedup.py``.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    token_file: str | None = None
    prefetch: int = 2


class TokenPipeline:
    def __init__(self, cfg: DataConfig, *, host_id: int = 0,
                 n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        self._tokens = None
        if cfg.token_file:
            self._tokens = np.memmap(cfg.token_file, dtype=np.int32,
                                     mode="r")

    # -- deterministic batch construction ---------------------------------

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """The batch for a given step — pure function, resumable."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4099 + self.host_id
        )
        if self._tokens is not None:
            n = len(self._tokens) - cfg.seq_len - 1
            starts = rng.integers(0, n, size=self.local_batch)
            toks = np.stack(
                [self._tokens[s:s + cfg.seq_len + 1] for s in starts]
            )
        else:
            toks = self._synthetic(rng)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def _synthetic(self, rng) -> np.ndarray:
        """Markov-ish synthetic stream with learnable structure."""
        cfg = self.cfg
        b, s, v = self.local_batch, cfg.seq_len + 1, cfg.vocab_size
        # mixture: repeated motifs + skew-Zipf unigrams
        motif_len = 16
        n_motifs = 64
        motif_rng = np.random.default_rng(cfg.seed)   # fixed across steps
        motifs = motif_rng.integers(0, v, size=(n_motifs, motif_len))
        out = np.empty((b, s), dtype=np.int64)
        for i in range(b):
            pos = 0
            while pos < s:
                if rng.random() < 0.7:
                    m = motifs[rng.integers(0, n_motifs)]
                    take = min(motif_len, s - pos)
                    out[i, pos:pos + take] = m[:take]
                    pos += take
                else:
                    take = min(int(rng.integers(4, 16)), s - pos)
                    out[i, pos:pos + take] = (
                        rng.zipf(1.4, size=take).clip(1, v) - 1
                    )
                    pos += take
        return out

    # -- prefetching iterator ------------------------------------------------

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        q: queue.Queue = queue.Queue(maxsize=self.cfg.prefetch)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                q.put(self.batch_at(step))
                step += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
