"""Training step: microbatch gradient accumulation + AdamW + schedules.

The global batch is split into ``n_micro`` microbatches accumulated in a
``lax.scan`` with fp32 gradient accumulators — per-device activation
memory is bounded by one microbatch regardless of global batch size
(this is what fits nemotron-340b's 1M-token steps on 16 GB chips).
Gradient compression (2-bit Sign-Magnitude with error feedback — the
paper's encoder reused on the DP axis) hooks in between accumulation and
the optimizer; see ``repro/optim/compress.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim.schedule import SCHEDULES


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    n_micro: int = 1
    peak_lr: float = 3e-4
    warmup: int = 200
    total_steps: int = 10_000
    schedule: str = "cosine"
    adamw: AdamWConfig = AdamWConfig()
    compress_grads: bool = False


def suggest_n_micro(cfg: ArchConfig, shape: ShapeConfig, dp: int) -> int:
    """Fewest microbatches whose activations fit (FSDP weight-gather
    traffic scales linearly with n_micro: 190.8s -> 129.3s collective on
    nemotron train_4k going 16 -> 4, EXPERIMENTS.md §Perf b.2).

    Napkin model: saved group-boundary residuals per device
      = n_layers * (B/dp/n_micro) * S/tp_or_1 * d_model * 2 B
    budget ~4 GB next to params+optimizer (~11 GB at 340B/bf16-Adam).
    """
    per_dev = max(1, shape.global_batch // dp)
    seq_shard = 16 if cfg.seq_sharded_residual else 1
    budget = 4e9
    for n_micro in (1, 2, 4, 8, 16, 32):
        if n_micro > per_dev:
            break
        act = (cfg.n_layers * (per_dev / n_micro)
               * shape.seq_len / seq_shard * cfg.d_model * 2)
        if act <= budget:
            return n_micro
    return per_dev


def _lr(tc: TrainConfig, step):
    sched = SCHEDULES[tc.schedule]
    if tc.schedule == "wsd":
        return sched(step, peak_lr=tc.peak_lr, warmup=tc.warmup,
                     stable=int(0.8 * tc.total_steps),
                     decay=int(0.1 * tc.total_steps))
    return sched(step, peak_lr=tc.peak_lr, warmup=tc.warmup,
                 total=tc.total_steps)


def make_train_step(bundle, tc: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``batch`` leaves have leading dim = global_batch."""

    grad_fn = jax.value_and_grad(bundle.loss, has_aux=True)

    def train_step(params, opt_state, batch):
        step = opt_state["count"]

        if tc.n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(
                    tc.n_micro, x.shape[0] // tc.n_micro, *x.shape[1:]
                ),
                batch,
            )
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def body(carry, mb):
                acc, loss_acc = carry
                (loss, metrics), grads = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads
                )
                return (acc, loss_acc + loss), metrics

            (grads, loss_sum), metrics = jax.lax.scan(
                body, (zero, jnp.float32(0.0)), micro
            )
            grads = jax.tree.map(lambda g: g / tc.n_micro, grads)
            loss = loss_sum / tc.n_micro
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        if tc.compress_grads:
            from repro.optim.compress import compress_decompress_tree
            grads, new_ef = compress_decompress_tree(
                grads, opt_state["ef"]
            )
            opt_state = {**opt_state, "ef": new_ef}

        lr = _lr(tc, step)
        params, new_opt, opt_metrics = adamw_update(
            params, grads, opt_state, tc.adamw, lr
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, new_opt, metrics

    return train_step


def init_train_state(bundle, tc: TrainConfig, key):
    params = bundle.init(key)
    opt_state = init_opt_state(params, tc.adamw)
    if tc.compress_grads:
        from repro.optim.compress import init_error_feedback
        opt_state["ef"] = init_error_feedback(params)
    return params, opt_state
