"""Training loop with fault tolerance: checkpoint/restart, preemption
safety, straggler detection, elastic resume.

Fleet-scale behaviors validated here at CPU scale (the logic is
host-side and device-count agnostic):

  * **checkpoint/restart** — periodic async saves; on startup the loop
    scans the checkpoint root and resumes from the newest complete
    manifest (a killed job restarts exactly where it left off, and the
    data pipeline is a pure function of the step so batches line up).
  * **preemption safety** — SIGTERM triggers a final synchronous save
    before exit.
  * **straggler detection** — per-step wall times feed an EWMA; steps
    slower than ``straggler_factor`` x EWMA are logged with the step
    index (on a fleet this feeds the rebalancer; here it feeds tests).
  * **elastic resume** — restore() re-shards onto whatever mesh is
    active, so a 512-chip checkpoint restarts on 256 chips.
"""

from __future__ import annotations

import dataclasses
import signal
import time
import jax

from repro.ckpt import checkpoint
from repro.train.train_step import TrainConfig, init_train_state, \
    make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0
    async_ckpt: bool = True


class Trainer:
    def __init__(self, bundle, train_cfg: TrainConfig,
                 trainer_cfg: TrainerConfig, pipeline, *, key=None):
        self.bundle = bundle
        self.tc = train_cfg
        self.cfg = trainer_cfg
        self.pipeline = pipeline
        self.step_fn = jax.jit(make_train_step(bundle, train_cfg),
                               donate_argnums=(0, 1))
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.metrics_log: list[dict] = []
        self.straggler_events: list[dict] = []
        self._preempted = False
        self._writer = None

    # -- lifecycle -----------------------------------------------------------

    def _install_signal_handler(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass   # non-main thread (tests)

    def init_or_restore(self):
        params, opt_state = init_train_state(self.bundle, self.tc, self.key)
        start = 0
        if self.cfg.ckpt_dir:
            latest = checkpoint.latest_step(self.cfg.ckpt_dir)
            if latest is not None:
                state_like = {"params": params, "opt": opt_state}
                restored, start = checkpoint.restore(
                    f"{self.cfg.ckpt_dir}/step_{latest}", state_like
                )
                params, opt_state = restored["params"], restored["opt"]
        return params, opt_state, start

    def _save(self, params, opt_state, step, *, sync=False):
        if not self.cfg.ckpt_dir:
            return
        if self._writer is not None:
            self._writer.join()   # never two writers in flight
        self._writer = checkpoint.save(
            f"{self.cfg.ckpt_dir}/step_{step}",
            {"params": params, "opt": opt_state},
            step=step,
            async_write=self.cfg.async_ckpt and not sync,
        )

    # -- main loop --------------------------------------------------------

    def run(self) -> dict:
        self._install_signal_handler()
        params, opt_state, start = self.init_or_restore()
        ewma = None
        it = self.pipeline.iterate(start_step=start)

        step = start
        for step in range(start, self.cfg.steps):
            batch = {k: jax.numpy.asarray(v)
                     for k, v in next(it).items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0

            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > self.cfg.straggler_factor * ewma and step > start + 3:
                self.straggler_events.append({"step": step, "dt": dt,
                                              "ewma": ewma})
            if step % self.cfg.log_every == 0 or step == self.cfg.steps - 1:
                self.metrics_log.append(
                    {"step": step,
                     "loss": float(metrics["loss"]),
                     "grad_norm": float(metrics["grad_norm"]),
                     "seconds": dt}
                )
            if self.cfg.ckpt_dir and (step + 1) % self.cfg.ckpt_every == 0:
                self._save(params, opt_state, step + 1)
            if self._preempted:
                self._save(params, opt_state, step + 1, sync=True)
                break

        if self._writer is not None:
            self._writer.join()
        return {
            "params": params,
            "opt_state": opt_state,
            "final_step": step + 1,
            "metrics": self.metrics_log,
            "stragglers": self.straggler_events,
        }
