"""Batched serving engine: prefill/decode loop + QuIVer retrieval (RAG).

The engine drives any decoder-family ``ModelBundle``:

    engine = ServeEngine(bundle, params, max_seq=...)
    out = engine.generate(prompts)                   # batched greedy
    out = engine.generate(prompts, retriever=quiver) # retrieval-augmented

Retrieval integration (DESIGN.md §4): the prompt's mean-pooled embedding
queries a QuIVer index; the top-k neighbour *token prefixes* are
prepended to the prompt before prefill — the hot path of retrieval is
the paper's XOR/popcount beam search, so augmentation adds microseconds
of index time, not model FLOPs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Retriever:
    """QuIVer index + token store for RAG.

    ``index`` may be an immutable :class:`QuIVerIndex` or a streaming
    :class:`repro.stream.MutableQuIVerIndex` — with the latter the
    corpus can grow *while serving* via :meth:`add_documents` (the hot
    path stays the BQ beam search either way, DESIGN.md §8).

    ``nav=None`` navigates in the metric the index was built in;
    ``expand`` is the beam expansion width L (DESIGN.md §4).
    ``pad_token`` fills the context slots of missing hits (search
    returns -1 ids when the beam finds fewer than k live documents).

    ``filter`` (optional) is a label predicate (``repro.filter``):
    retrieval only surfaces documents matching it — metadata-filtered
    RAG (language, tenant, source tags), evaluated as packed bitset
    ops inside the BQ hot path (DESIGN.md §9).  The index needs labels
    attached (``attach_labels`` / ``insert(labels=...)``).

    ``adaptive`` (default None) follows the index's own
    :class:`~repro.probe.NavPolicy` (auto-built indexes escalate
    tight-margin retrievals per query, DESIGN.md §10); pass True/False
    to force it per retriever.
    """
    index: Any                      # QuIVerIndex | MutableQuIVerIndex
    doc_tokens: np.ndarray          # (n_docs, doc_len) int32
    embed_fn: Callable              # (B, S) tokens -> (B, D) embeddings
    k: int = 2
    ef: int = 64
    nav: str | None = None
    expand: int = 1
    pad_token: int = 0
    filter: Any = None              # label predicate (repro.filter)
    adaptive: bool | None = None    # None: the index policy decides

    def augment(
        self, tokens: np.ndarray, *, filter=None
    ) -> np.ndarray:
        emb = np.asarray(self.embed_fn(jnp.asarray(tokens)))
        ids, _ = self.index.search(
            jnp.asarray(emb), k=self.k, ef=self.ef, nav=self.nav,
            expand=self.expand, adaptive=self.adaptive,
            filter=filter if filter is not None else self.filter,
        )
        ids = np.asarray(ids).reshape(len(tokens), -1)
        # ids outside the token store — -1 padding (beam found < k live
        # docs) or slots beyond a lagging doc_tokens — must not gather a
        # real document; clamp for the gather, then blank out
        in_store = (ids >= 0) & (ids < len(self.doc_tokens))
        safe = np.clip(ids, 0, len(self.doc_tokens) - 1)
        ctx = np.asarray(self.doc_tokens)[safe]
        ctx = np.where(in_store[..., None], ctx, self.pad_token)
        ctx = ctx.reshape(len(tokens), -1)
        return np.concatenate([ctx, tokens], axis=1)

    def add_documents(
        self,
        doc_tokens: np.ndarray,
        embeddings: np.ndarray | None = None,
        *,
        labels=None,
    ) -> np.ndarray:
        """Insert documents into a *mutable* index while serving.

        Returns the slot ids the index assigned.  The token store is
        slot-addressed: it is grown to the index capacity on first use
        so reclaimed slots (delete + consolidate) overwrite in place.
        ``labels`` tags the new documents for filtered retrieval (one
        int / iterable of ints per document).
        """
        if not hasattr(self.index, "insert"):
            raise TypeError(
                "add_documents needs a mutable index (repro.stream); "
                f"got {type(self.index).__name__}"
            )
        doc_tokens = np.atleast_2d(np.asarray(doc_tokens, dtype=np.int32))
        if embeddings is None:
            embeddings = np.asarray(
                self.embed_fn(jnp.asarray(doc_tokens))
            )
        ids = np.asarray(
            self.index.insert(jnp.asarray(embeddings), labels=labels)
        )
        cap = self.index.capacity
        if len(self.doc_tokens) < cap:
            pad = np.full(
                (cap - len(self.doc_tokens), self.doc_tokens.shape[1]),
                self.pad_token, dtype=self.doc_tokens.dtype,
            )
            self.doc_tokens = np.concatenate([self.doc_tokens, pad])
        self.doc_tokens[ids] = doc_tokens
        return ids


class ServeEngine:
    def __init__(self, bundle, params, *, max_seq: int = 512):
        self.bundle = bundle
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(bundle.prefill)
        self._decode = jax.jit(bundle.decode, donate_argnums=(2,))

    def generate(
        self,
        tokens: np.ndarray,              # (B, S) int32 prompts
        *,
        max_new: int = 32,
        retriever: Retriever | None = None,
        temperature: float = 0.0,
        seed: int = 0,
        extra_batch: dict | None = None,
    ) -> np.ndarray:
        if retriever is not None:
            tokens = retriever.augment(tokens)
        tokens = np.asarray(tokens, dtype=np.int32)
        b, s = tokens.shape
        assert s + max_new <= self.max_seq

        batch = {"tokens": jnp.asarray(tokens)}
        if extra_batch:
            batch.update(extra_batch)
        caches = self.bundle.init_caches(b, self.max_seq)
        logits, caches = self._prefill(self.params, batch, caches)

        prompt_len = s
        cfg = self.bundle.cfg
        if cfg.frontend == "patch_stub" and extra_batch:
            prompt_len += extra_batch["patches"].shape[1]

        key = jax.random.PRNGKey(seed)
        out = []
        pos = prompt_len
        for i in range(max_new):
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / temperature)
            else:
                tok = jnp.argmax(logits, axis=-1)
            tok = tok.astype(jnp.int32)[:, None]
            out.append(np.asarray(tok))
            logits, caches = self._decode(
                self.params, tok, caches, jnp.int32(pos)
            )
            pos += 1
        return np.concatenate(out, axis=1)


def mean_pool_embedder(bundle, params):
    """(B, S) tokens -> (B, d_model) embeddings from the final hidden
    state (the LM as its own embedding model for RAG)."""
    from repro.models import transformer as tf

    def embed(tokens):
        x = tf.embed_tokens(params, bundle.cfg, tokens)
        h, _ = tf.forward_hidden(params, bundle.cfg, x)
        return h.mean(axis=1).astype(jnp.float32)

    return jax.jit(embed)
