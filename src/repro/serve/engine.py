"""Batched serving engine: prefill/decode loop + QuIVer retrieval (RAG).

The engine drives any decoder-family ``ModelBundle``:

    engine = ServeEngine(bundle, params, max_seq=...)
    out = engine.generate(prompts)                   # batched greedy
    out = engine.generate(prompts, retriever=quiver) # retrieval-augmented

Retrieval integration (DESIGN.md §4): the prompt's mean-pooled embedding
queries a QuIVer index; the top-k neighbour *token prefixes* are
prepended to the prompt before prefill — the hot path of retrieval is
the paper's XOR/popcount beam search, so augmentation adds microseconds
of index time, not model FLOPs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Retriever:
    """QuIVer index + token store for RAG.

    ``nav=None`` navigates in the metric the index was built in;
    ``expand`` is the beam expansion width L (DESIGN.md §4).
    """
    index: Any                      # QuIVerIndex
    doc_tokens: np.ndarray          # (n_docs, doc_len) int32
    embed_fn: Callable              # (B, S) tokens -> (B, D) embeddings
    k: int = 2
    ef: int = 64
    nav: str | None = None
    expand: int = 1

    def augment(self, tokens: np.ndarray) -> np.ndarray:
        emb = np.asarray(self.embed_fn(jnp.asarray(tokens)))
        ids, _ = self.index.search(
            jnp.asarray(emb), k=self.k, ef=self.ef, nav=self.nav,
            expand=self.expand,
        )
        ctx = self.doc_tokens[ids.reshape(len(tokens), -1)]
        ctx = ctx.reshape(len(tokens), -1)
        return np.concatenate([ctx, tokens], axis=1)


class ServeEngine:
    def __init__(self, bundle, params, *, max_seq: int = 512):
        self.bundle = bundle
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(bundle.prefill)
        self._decode = jax.jit(bundle.decode, donate_argnums=(2,))

    def generate(
        self,
        tokens: np.ndarray,              # (B, S) int32 prompts
        *,
        max_new: int = 32,
        retriever: Retriever | None = None,
        temperature: float = 0.0,
        seed: int = 0,
        extra_batch: dict | None = None,
    ) -> np.ndarray:
        if retriever is not None:
            tokens = retriever.augment(tokens)
        tokens = np.asarray(tokens, dtype=np.int32)
        b, s = tokens.shape
        assert s + max_new <= self.max_seq

        batch = {"tokens": jnp.asarray(tokens)}
        if extra_batch:
            batch.update(extra_batch)
        caches = self.bundle.init_caches(b, self.max_seq)
        logits, caches = self._prefill(self.params, batch, caches)

        prompt_len = s
        cfg = self.bundle.cfg
        if cfg.frontend == "patch_stub" and extra_batch:
            prompt_len += extra_batch["patches"].shape[1]

        key = jax.random.PRNGKey(seed)
        out = []
        pos = prompt_len
        for i in range(max_new):
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / temperature)
            else:
                tok = jnp.argmax(logits, axis=-1)
            tok = tok.astype(jnp.int32)[:, None]
            out.append(np.asarray(tok))
            logits, caches = self._decode(
                self.params, tok, caches, jnp.int32(pos)
            )
            pos += 1
        return np.concatenate(out, axis=1)


def mean_pool_embedder(bundle, params):
    """(B, S) tokens -> (B, d_model) embeddings from the final hidden
    state (the LM as its own embedding model for RAG)."""
    from repro.models import transformer as tf

    def embed(tokens):
        x = tf.embed_tokens(params, bundle.cfg, tokens)
        h, _ = tf.forward_hidden(params, bundle.cfg, x)
        return h.mean(axis=1).astype(jnp.float32)

    return jax.jit(embed)
