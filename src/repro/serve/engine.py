"""Serving engines: continuous-batching QuIVer search + LM generation.

Two engines live here:

* :class:`QueryEngine` — the retrieval serving path (DESIGN.md §11).
  A continuous-batching request pipeline over a built index: an
  admission queue coalesces pending requests by *compiled query plan*
  (``repro.plan``), pads the merged batch up the bucket ladder, overlaps
  host→device transfer of the next group with compute of the current
  one (jax async dispatch double-buffering), and maps per-request
  deadline budgets onto the plan's ef schedule — degrading ef down the
  plan ladder before ever dropping a request.  A warmed engine serves
  from a closed set of compiled programs: steady-state retraces == 0.

      engine = QueryEngine(index)
      engine.warmup()
      t = engine.submit(queries, k=10, ef=64, deadline_ms=50)
      engine.pump()                    # one admission window
      ids, scores = engine.result(t)
      ids, scores = engine.search(q)   # submit+pump+wait convenience

* :class:`ServeEngine` — batched LM generation (prefill/decode loop),
  optionally retrieval-augmented through a :class:`Retriever`.

Retrieval integration (DESIGN.md §4): the prompt's mean-pooled embedding
queries a QuIVer index; the top-k neighbour *token prefixes* are
prepended to the prompt before prefill — the hot path of retrieval is
the paper's XOR/popcount beam search, so augmentation adds microseconds
of index time, not model FLOPs.  A Retriever given an ``engine`` routes
its searches through the admission queue, so RAG lookups coalesce with
every other in-flight request (and singleton prompts ride the smallest
ladder bucket instead of retracing per call shape).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import DEFAULT_TENANT, ObsHub, Ring, Span, TenantLedger
from repro.obs.metrics import latency_summary
from repro.obs.quality import ShadowSampler
from repro.plan import resolve_plan, trace
from repro.plan.cache import NAV_STATS
from repro.plan.plan import PlanContext, QueryPlan


@dataclasses.dataclass
class QueryTicket:
    """One admitted search request (a row range of a coalesced batch)."""

    id: int
    queries: np.ndarray                # (q, D) float32
    kwargs: dict                       # resolve_plan arguments
    filter_key: Any                    # hashable grouping key for filter
    submitted: float                   # clock() at submit
    deadline: float | None             # absolute clock() budget, or None
    tenant: str = DEFAULT_TENANT       # SLO accounting bucket
    trace_id: int = 0                  # span context carried end to end
    status: str = "pending"            # pending | done | dropped | rejected
    degraded: int = 0                  # deadline rungs walked down
    plan: QueryPlan | None = None      # the plan that actually served it
    latency: float | None = None       # seconds, admission -> completion


# window for the engine-wide latency ring: long-running engines keep
# the last this-many request latencies (per-tenant windows live in the
# TenantLedger); percentiles are over the window, memory is O(window)
DEFAULT_LATENCY_WINDOW = 4096


@dataclasses.dataclass
class EngineStats:
    requests: int = 0
    queries: int = 0
    windows: int = 0                   # pump() calls that did work
    batches: int = 0                   # coalesced plan-group launches
    done: int = 0
    dropped: int = 0
    degraded: int = 0                  # requests served below asked ef
    rejected: int = 0                  # quota-refused at admission
    latencies: Ring = dataclasses.field(
        default_factory=lambda: Ring(DEFAULT_LATENCY_WINDOW)
    )


class QueryEngine:
    """Continuous-batching search serving over a ``QuIVerIndex``.

    The engine is deliberately synchronous-pumped: callers ``submit``
    requests and ``pump`` admission windows (a thread, an asyncio task
    or a benchmark's load loop can drive it).  Each window:

    1. resolves every pending request to its :class:`QueryPlan` (the
       ahead-of-time decision point — nav ladder, filter route,
       escalation schedule);
    2. walks deadline-pressed requests down the plan's ef-degradation
       ladder (brute-route plans are exact and never degrade; requests
       already past deadline are dropped);
    3. coalesces requests group-by-plan into one batch each, padded up
       the bucket ladder — singletons land in the smallest bucket, so
       repeated 1-query traffic reuses one executable;
    4. launches all groups before finalizing any (jax async dispatch:
       group i+1's host→device transfer and compute overlap group i's
       result sync — the double-buffering);
    5. scatters results back to tickets and records latencies.

    ``latency_slack``: a request is degraded when its remaining budget
    is under ``latency_slack`` × the EWMA batch latency of its plan.

    Telemetry (DESIGN.md §12): ``obs`` is the engine's
    :class:`~repro.obs.ObsHub` — per-tenant counters and latency
    histograms land in ``obs.registry``, lifecycle spans (admission →
    coalesce → launch → finalize) in ``obs.tracer``, and the same hub
    is handed to the index's :class:`~repro.plan.cache.PlanCache` so
    per-plan stage timings and escalations are attributed too.  Pass
    ``obs=False`` to serve bare (the telemetry-overhead baseline).

    Multi-tenancy: ``submit(tenant=...)`` threads a tenant id through
    the ticket; :meth:`set_quota` arms a token-bucket admission cap
    (queries/s) for that tenant — over-budget requests are *rejected*
    at submit (status ``"rejected"``, -1/-inf results, accounted to the
    tenant) and never reach the batch queue, so one tenant's overload
    cannot starve another's window.

    Shadow lane (DESIGN.md §14): ``shadow=True`` (or a config dict /
    prebuilt :class:`~repro.obs.quality.ShadowSampler`) re-answers a
    deterministic ~1/``rate`` of live queries as exact float32 brute
    force.  Sampled rows are *offered* at result-scatter time (a copy,
    nothing more) and *drained* only after every live request of the
    window is delivered and accounted — the shadow lane never passes
    admission, never charges a token bucket, and never delays a live
    result.  Drained recall@k feeds the tenant ledger's recall-SLO
    windows (:meth:`set_quota` ``recall_slo=``).
    """

    def __init__(
        self,
        index,
        *,
        query_batch: int = 256,
        default_k: int = 10,
        default_ef: int = 64,
        latency_slack: float = 1.0,
        ewma_alpha: float = 0.3,
        latency_window: int = DEFAULT_LATENCY_WINDOW,
        obs: ObsHub | bool | None = None,
        shadow: ShadowSampler | dict | bool | None = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.index = index
        self.query_batch = query_batch
        self.default_k = default_k
        self.default_ef = default_ef
        self.latency_slack = latency_slack
        self.ewma_alpha = ewma_alpha
        self.clock = clock
        self.stats = EngineStats(latencies=Ring(latency_window))
        self._pending: list[QueryTicket] = []
        self._tickets: dict[int, QueryTicket] = {}
        self._results: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        self._next_id = 0
        self._lat_ewma: dict[QueryPlan, float] = {}
        if obs is False:
            self.obs = None
        elif obs is None or obs is True:
            self.obs = ObsHub()
        else:
            self.obs = obs
        self.tenants = TenantLedger(
            registry=self.obs.registry if self.obs else None,
            latency_window=latency_window,
            clock=clock,
        )
        if not shadow:
            self.shadow = None
        elif isinstance(shadow, ShadowSampler):
            self.shadow = shadow
            self.shadow.ledger = self.tenants
        else:
            kw = dict(shadow) if isinstance(shadow, dict) else {}
            kw.setdefault("k", default_k)
            self.shadow = ShadowSampler(
                index,
                registry=self.obs.registry if self.obs else None,
                ledger=self.tenants, **kw,
            )
        if self.obs is not None:
            reg = self.obs.registry
            self._m_requests = reg.counter(
                "quiver_engine_requests_total",
                "terminal request outcomes",
                labels=("tenant", "status"),
            )
            self._m_degraded = reg.counter(
                "quiver_engine_degraded_total",
                "requests served below the asked ef", labels=("tenant",),
            )
            self._m_windows = reg.counter(
                "quiver_engine_windows_total", "admission windows pumped"
            )
            self._m_batches = reg.counter(
                "quiver_engine_batches_total",
                "coalesced plan-group launches",
            )
            self._m_queue = reg.gauge(
                "quiver_engine_pending_requests",
                "requests awaiting an admission window",
            )
            # plan-stage timings ride the same hub (PlanCache checks
            # its ``obs`` on every launch/finalize)
            if hasattr(index, "plans"):
                index.plans.obs = self.obs

    # -- admission ---------------------------------------------------------

    def set_quota(self, tenant: str, qps: float,
                  burst: float | None = None,
                  recall_slo: float | None = None) -> None:
        """Arm a token-bucket admission quota (queries/second with
        ``burst`` headroom) for ``tenant``.  Requests beyond the budget
        are rejected at submit; other tenants are unaffected (each
        bucket is independent).

        ``recall_slo`` adds the quality dimension: the tenant's rolling
        shadow-recall p50 must stay at or above it.  Breaches are
        edge-triggered events the ledger's subscribers (the remediation
        policy) receive — they never reject traffic.  Needs the shadow
        lane armed (``shadow=`` at construction) to get measurements.
        """
        self.tenants.set_quota(tenant, qps, burst=burst,
                               recall_slo=recall_slo)

    def submit(
        self,
        queries,
        *,
        k: int | None = None,
        ef: int | None = None,
        rerank: bool = True,
        nav: str | None = None,
        expand: int = 1,
        filter=None,
        adaptive: bool | None = None,
        deadline_ms: float | None = None,
        tenant: str = DEFAULT_TENANT,
    ) -> int:
        """Queue a request; returns a ticket id for :meth:`result`.

        ``tenant`` selects the SLO account (and quota bucket, if one is
        armed).  A quota-rejected request completes immediately with
        status ``"rejected"`` and -1/-inf results — the ticket id is
        still valid for :meth:`result`, so callers observe rejection as
        a fast, attributed failure rather than an exception.
        """
        q = np.atleast_2d(np.asarray(queries, dtype=np.float32))
        now = self.clock()
        t = QueryTicket(
            id=self._next_id,
            queries=q,
            kwargs=dict(
                k=k if k is not None else self.default_k,
                ef=ef if ef is not None else self.default_ef,
                rerank=rerank, nav=nav, expand=expand, filter=filter,
                adaptive=adaptive, query_batch=self.query_batch,
            ),
            filter_key=filter,
            submitted=now,
            deadline=(now + deadline_ms / 1e3
                      if deadline_ms is not None else None),
            tenant=tenant,
            trace_id=(self.obs.tracer.new_trace()
                      if self.obs is not None else 0),
        )
        self._next_id += 1
        self._tickets[t.id] = t
        self.stats.requests += 1
        self.stats.queries += len(q)
        if not self.tenants.admit(tenant, len(q), now):
            self._finish_rejected(t)
            return t.id
        self._pending.append(t)
        if self.obs is not None:
            self._m_queue.set(len(self._pending))
        return t.id

    def _finish_rejected(self, t: QueryTicket) -> None:
        k = t.kwargs["k"]
        nq = len(t.queries)
        self._results[t.id] = (
            np.full((nq, k), -1, np.int32),
            np.full((nq, k), -np.inf, np.float32),
        )
        t.status = "rejected"
        t.latency = 0.0
        self.stats.rejected += 1
        if self.obs is not None:
            self._m_requests.inc(tenant=t.tenant, status="rejected")

    # -- one admission window ----------------------------------------------

    def pump(self) -> int:
        """Serve every pending request in one admission window; returns
        how many requests completed (dropped requests count)."""
        if not self._pending:
            return 0
        admitted, self._pending = self._pending, []
        self.stats.windows += 1
        now = self.clock()
        tracer = self.obs.tracer if self.obs is not None else None
        window_t0 = tracer.clock() if tracer is not None else 0.0

        # 1+2: plan resolution + deadline degradation, group by plan
        groups: dict[tuple, list] = {}
        ctxs: dict[tuple, PlanContext] = {}
        completed = 0
        coalesce_t0 = tracer.clock() if tracer is not None else 0.0
        for t in admitted:
            if tracer is not None:
                # admission span: submit -> window start (queue wait,
                # on the engine clock — same clock as ``latency``)
                tracer.record(Span(
                    "admission", t.trace_id, t.submitted, end=now,
                    attrs={"tenant": t.tenant},
                ))
            if t.deadline is not None and now > t.deadline:
                self._finish_dropped(t)
                completed += 1
                continue
            plan, ctx = resolve_plan(self.index, **t.kwargs)
            if t.deadline is not None:
                budget = t.deadline - now
                while (plan.can_degrade()
                       and self._estimate(plan)
                       * self.latency_slack > budget):
                    plan = plan.degraded()
                    t.degraded += 1
                if t.degraded:
                    self.stats.degraded += 1
                    if self.obs is not None:
                        self._m_degraded.inc(tenant=t.tenant)
                        mark = tracer.clock()
                        tracer.record(Span(
                            "degrade", t.trace_id, mark, end=mark,
                            attrs={"rungs": t.degraded,
                                   "ef": plan.ef,
                                   "tenant": t.tenant},
                        ))
            t.plan = plan
            key = (plan, t.filter_key)
            groups.setdefault(key, []).append(t)
            ctxs.setdefault(key, ctx)
        if tracer is not None:
            tracer.record(Span(
                "coalesce", 0, coalesce_t0,
                attrs={"requests": len(admitted), "groups": len(groups)},
            ))

        # 3+4: coalesce each group and launch all before finalizing any
        # (async dispatch overlaps group i+1's transfer with group i)
        launches = []
        for key, tickets in groups.items():
            plan = key[0]
            qcat = np.concatenate([t.queries for t in tickets])
            t0 = self.clock()
            if tracer is not None:
                with tracer.span("launch", tickets[0].trace_id,
                                 plan=plan.signature(),
                                 queries=len(qcat)):
                    pending = self.index.plans.launch(
                        plan, ctxs[key], qcat
                    )
            else:
                pending = self.index.plans.launch(plan, ctxs[key], qcat)
            launches.append((plan, tickets, pending, t0))
            self.stats.batches += 1
            if self.obs is not None:
                self._m_batches.inc()

        # 5: sync, scatter, account
        for plan, tickets, pending, t0 in launches:
            if tracer is not None:
                with tracer.span("finalize", tickets[0].trace_id,
                                 plan=plan.signature()):
                    ids, scores = self.index.plans.finalize(pending)
            else:
                ids, scores = self.index.plans.finalize(pending)
            t_done = self.clock()
            self._observe(plan, t_done - t0)
            # nav traces (graph plans only; the cache populates them at
            # finalize when obs is armed) scatter to the same per-ticket
            # row ranges as the results
            nav = getattr(pending, "nav", None)
            row = 0
            for t in tickets:
                nq = len(t.queries)
                self._results[t.id] = (ids[row:row + nq],
                                       scores[row:row + nq])
                if nav is not None:
                    self.tenants.observe_nav(t.tenant, {
                        stat: nav[row:row + nq, col]
                        for col, (stat, _) in enumerate(NAV_STATS)
                    })
                row += nq
                if self.shadow is not None:
                    # offer only: a copy of the sampled rows; ground
                    # truth runs after the whole window is accounted
                    stage = ("degraded" if t.degraded
                             else "adaptive" if plan.adaptive
                             else "base")
                    self.shadow.offer(
                        t.queries, self._results[t.id][0],
                        tenant=t.tenant, nav=plan.nav, stage=stage,
                    )
                t.status = "done"
                t.latency = t_done - t.submitted
                self.stats.done += 1
                self.stats.latencies.append(t.latency)
                self.tenants.observe(
                    t.tenant, status="done", latency=t.latency,
                    degraded=bool(t.degraded),
                )
                if self.obs is not None:
                    self._m_requests.inc(tenant=t.tenant, status="done")
                    tracer.record(Span(
                        "request", t.trace_id, t.submitted,
                        end=t.submitted + t.latency,
                        attrs={"tenant": t.tenant,
                               "plan": plan.signature(),
                               "status": "done"},
                    ))
                completed += 1
        if self.obs is not None:
            self._m_windows.inc()
            self._m_queue.set(len(self._pending))
            tracer.record(Span(
                "window", 0, window_t0,
                attrs={"requests": len(admitted),
                       "batches": len(launches)},
            ))
        # shadow drain: every live result above is already delivered and
        # its latency recorded — the exact brute force happens strictly
        # off the serving path and outside tenant accounting
        if self.shadow is not None and self.shadow.pending:
            if tracer is not None:
                with tracer.span("shadow", 0,
                                 pending=len(self.shadow.pending)):
                    self.shadow.drain()
            else:
                self.shadow.drain()
        return completed

    def _finish_dropped(self, t: QueryTicket) -> None:
        k = t.kwargs["k"]
        nq = len(t.queries)
        self._results[t.id] = (
            np.full((nq, k), -1, np.int32),
            np.full((nq, k), -np.inf, np.float32),
        )
        t.status = "dropped"
        t.latency = self.clock() - t.submitted
        self.stats.dropped += 1
        self.tenants.observe(t.tenant, status="dropped",
                             latency=t.latency)
        if self.obs is not None:
            self._m_requests.inc(tenant=t.tenant, status="dropped")

    def _estimate(self, plan: QueryPlan) -> float:
        """EWMA batch latency for ``plan`` (0.0 until first observed —
        no degradation before the engine has evidence)."""
        if plan in self._lat_ewma:
            return self._lat_ewma[plan]
        # unmeasured degraded rungs inherit the parent's estimate scaled
        # by the beam ratio (latency is ~linear in ef)
        for parent, lat in self._lat_ewma.items():
            if (parent.nav == plan.nav and parent.route == plan.route
                    and parent.filtered == plan.filtered
                    and parent.k == plan.k):
                return lat * plan.ef / max(parent.ef, 1)
        return 0.0

    def _observe(self, plan: QueryPlan, seconds: float) -> None:
        prev = self._lat_ewma.get(plan)
        a = self.ewma_alpha
        self._lat_ewma[plan] = (
            seconds if prev is None else a * seconds + (1 - a) * prev
        )

    # -- results -----------------------------------------------------------

    def poll(self, ticket: int):
        """(ids, scores) if the ticket completed, else None."""
        return self._results.get(ticket)

    def result(self, ticket: int):
        """Block (pumping the queue) until ``ticket`` completes."""
        while ticket not in self._results:
            if not self.pump():
                raise KeyError(f"unknown or lost ticket {ticket}")
        return self._results.pop(ticket)

    def ticket(self, ticket: int) -> QueryTicket:
        return self._tickets[ticket]

    def search(self, queries, **kwargs):
        """Per-call convenience: submit + pump + wait.  Single queries
        still ride the admission path, so they share the smallest
        ladder bucket with every other singleton."""
        return self.result(self.submit(queries, **kwargs))

    # -- index lifecycle ---------------------------------------------------

    def swap_index(self, index, *, warmup: bool = False) -> None:
        """Re-point the engine at a new index snapshot.

        Streaming serves swap in ``freeze()`` snapshots at consolidation
        or phase boundaries; the engine re-wires plan-cache telemetry
        and the shadow sampler's ground-truth tier to the new index.
        Plan latency EWMAs carry over (plan keys are index-independent
        and the new snapshot serves comparable shapes).
        """
        self.index = index
        if self.obs is not None and hasattr(index, "plans"):
            index.plans.obs = self.obs
        if self.shadow is not None:
            self.shadow.index = index
            index.shadow = self.shadow
        if warmup:
            self.warmup()

    # -- warmup & reporting ------------------------------------------------

    def warmup(
        self,
        *,
        buckets: tuple[int, ...] = (8,),
        configs: tuple[dict, ...] = ({},),
    ) -> int:
        """Precompile the plans the engine expects to serve (default:
        its default k/ef on the smallest bucket, escalation stage
        included).  ``configs`` are extra submit-kwarg dicts to warm
        (e.g. ``{"filter": 3}`` or ``{"ef": 32}``)."""
        warmed = 0
        for cfg in configs:
            kw = dict(
                k=self.default_k, ef=self.default_ef, rerank=True,
                nav=None, expand=1, filter=None, adaptive=None,
                query_batch=self.query_batch,
            )
            kw.update(cfg)
            plan, ctx = resolve_plan(self.index, **kw)
            warmed += self.index.plans.warmup(
                plan, ctx if plan.filtered or plan.route == "brute"
                else None, buckets=buckets,
            )
        return warmed

    def stats_report(self) -> dict:
        """``memory_breakdown``-style serving report: request counters,
        window latency percentiles, per-tenant SLO accounts, lifecycle
        span aggregates, plan-cache behaviour, retraces.

        Percentiles are over the bounded latency ring (the last
        ``latency_window`` requests), so a long-running engine reports
        its *current* tail, not its lifetime-averaged one.
        """
        lat = self.stats.latencies
        out = {
            "requests": self.stats.requests,
            "queries": self.stats.queries,
            "windows": self.stats.windows,
            "batches": self.stats.batches,
            "done": self.stats.done,
            "dropped": self.stats.dropped,
            "degraded": self.stats.degraded,
            "rejected": self.stats.rejected,
            "latency_window": lat.maxlen,
            **latency_summary(lat),
        }
        out["tenant_report"] = self.tenants.report()
        if self.shadow is not None:
            out["shadow_report"] = self.shadow.report()
        if self.obs is not None:
            out["span_report"] = self.obs.tracer.report()
        out.update(
            {f"plan_{k}": v for k, v in self.index.plans.report().items()}
        )
        out["trace_report"] = trace.trace_report(
            self.index.plans.trace_prefix()
        )
        return out

    def health_verdicts(self) -> dict:
        """Per-component liveness bands for ``GET /healthz``: the
        graph's last structural X-ray, the probe-drift monitor's band,
        and the recall SLO (red while any tenant is breaching).  A
        component with no monitor attached is simply absent — absence
        reads green, so a bare engine stays servable."""
        out = {}
        gh = getattr(self.index, "graph_health", None)
        if gh is not None:
            out["graph"] = gh.verdict
        gm = getattr(self.index, "graph_monitor", None)
        if gm is not None and gm.band is not None:
            out["graph"] = gm.band
        dm = getattr(self.index, "drift_monitor", None)
        if dm is not None and dm.band is not None:
            out["drift"] = dm.band
        breached = [
            t for t in self.tenants.tenants()
            if self.tenants.recall_breached(t)
        ]
        out["recall_slo"] = "red" if breached else "green"
        return out

    def emit_report(self) -> dict:
        """Push one ``stats_report`` snapshot through the hub's sinks
        (the :class:`~repro.obs.PeriodicReporter` calls this)."""
        report = self.stats_report()
        if self.obs is not None:
            return self.obs.emit({"stats_report": report})
        return report

    def shutdown(self) -> dict:
        """Flush the final telemetry window and close the hub.

        Emits one last ``stats_report`` through the sinks, then stops
        the hub's reporters and closes its sinks (idempotent — the
        hub's own ``atexit`` hook makes a second call a no-op).  Call
        this at the end of short-lived benchmark/CLI processes so the
        final window is never dropped.
        """
        report = self.emit_report()
        if self.obs is not None:
            self.obs.close()
        return report


@dataclasses.dataclass
class Retriever:
    """QuIVer index + token store for RAG.

    ``index`` may be an immutable :class:`QuIVerIndex` or a streaming
    :class:`repro.stream.MutableQuIVerIndex` — with the latter the
    corpus can grow *while serving* via :meth:`add_documents` (the hot
    path stays the BQ beam search either way, DESIGN.md §8).

    ``nav=None`` navigates in the metric the index was built in;
    ``expand`` is the beam expansion width L (DESIGN.md §4).
    ``pad_token`` fills the context slots of missing hits (search
    returns -1 ids when the beam finds fewer than k live documents).

    ``filter`` (optional) is a label predicate (``repro.filter``):
    retrieval only surfaces documents matching it — metadata-filtered
    RAG (language, tenant, source tags), evaluated as packed bitset
    ops inside the BQ hot path (DESIGN.md §9).  The index needs labels
    attached (``attach_labels`` / ``insert(labels=...)``).

    ``adaptive`` (default None) follows the index's own
    :class:`~repro.probe.NavPolicy` (auto-built indexes escalate
    tight-margin retrievals per query, DESIGN.md §10); pass True/False
    to force it per retriever.

    ``engine`` (optional) is a :class:`QueryEngine` over the same
    index: retrievals then go through the admission queue — coalescing
    with concurrent requests, always padded up the bucket ladder — so a
    stream of single-prompt RAG calls reuses one compiled plan instead
    of retracing per call-site kwargs.
    """
    index: Any                      # QuIVerIndex | MutableQuIVerIndex
    doc_tokens: np.ndarray          # (n_docs, doc_len) int32
    embed_fn: Callable              # (B, S) tokens -> (B, D) embeddings
    k: int = 2
    ef: int = 64
    nav: str | None = None
    expand: int = 1
    pad_token: int = 0
    filter: Any = None              # label predicate (repro.filter)
    adaptive: bool | None = None    # None: the index policy decides
    engine: Any = None              # QueryEngine routing (optional)

    def augment(
        self, tokens: np.ndarray, *, filter=None
    ) -> np.ndarray:
        emb = np.asarray(self.embed_fn(jnp.asarray(tokens)))
        search = self.engine.search if self.engine is not None \
            else self.index.search
        ids, _ = search(
            jnp.asarray(emb), k=self.k, ef=self.ef, nav=self.nav,
            expand=self.expand, adaptive=self.adaptive,
            filter=filter if filter is not None else self.filter,
        )
        ids = np.asarray(ids).reshape(len(tokens), -1)
        # ids outside the token store — -1 padding (beam found < k live
        # docs) or slots beyond a lagging doc_tokens — must not gather a
        # real document; clamp for the gather, then blank out
        in_store = (ids >= 0) & (ids < len(self.doc_tokens))
        safe = np.clip(ids, 0, len(self.doc_tokens) - 1)
        ctx = np.asarray(self.doc_tokens)[safe]
        ctx = np.where(in_store[..., None], ctx, self.pad_token)
        ctx = ctx.reshape(len(tokens), -1)
        return np.concatenate([ctx, tokens], axis=1)

    def add_documents(
        self,
        doc_tokens: np.ndarray,
        embeddings: np.ndarray | None = None,
        *,
        labels=None,
    ) -> np.ndarray:
        """Insert documents into a *mutable* index while serving.

        Returns the slot ids the index assigned.  The token store is
        slot-addressed: it is grown to the index capacity on first use
        so reclaimed slots (delete + consolidate) overwrite in place.
        ``labels`` tags the new documents for filtered retrieval (one
        int / iterable of ints per document).
        """
        if not hasattr(self.index, "insert"):
            raise TypeError(
                "add_documents needs a mutable index (repro.stream); "
                f"got {type(self.index).__name__}"
            )
        doc_tokens = np.atleast_2d(np.asarray(doc_tokens, dtype=np.int32))
        if embeddings is None:
            embeddings = np.asarray(
                self.embed_fn(jnp.asarray(doc_tokens))
            )
        ids = np.asarray(
            self.index.insert(jnp.asarray(embeddings), labels=labels)
        )
        cap = self.index.capacity
        if len(self.doc_tokens) < cap:
            pad = np.full(
                (cap - len(self.doc_tokens), self.doc_tokens.shape[1]),
                self.pad_token, dtype=self.doc_tokens.dtype,
            )
            self.doc_tokens = np.concatenate([self.doc_tokens, pad])
        self.doc_tokens[ids] = doc_tokens
        return ids


class ServeEngine:
    def __init__(self, bundle, params, *, max_seq: int = 512):
        self.bundle = bundle
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(bundle.prefill)
        self._decode = jax.jit(bundle.decode, donate_argnums=(2,))

    def generate(
        self,
        tokens: np.ndarray,              # (B, S) int32 prompts
        *,
        max_new: int = 32,
        retriever: Retriever | None = None,
        temperature: float = 0.0,
        seed: int = 0,
        extra_batch: dict | None = None,
    ) -> np.ndarray:
        if retriever is not None:
            tokens = retriever.augment(tokens)
        tokens = np.asarray(tokens, dtype=np.int32)
        b, s = tokens.shape
        assert s + max_new <= self.max_seq

        batch = {"tokens": jnp.asarray(tokens)}
        if extra_batch:
            batch.update(extra_batch)
        caches = self.bundle.init_caches(b, self.max_seq)
        logits, caches = self._prefill(self.params, batch, caches)

        prompt_len = s
        cfg = self.bundle.cfg
        if cfg.frontend == "patch_stub" and extra_batch:
            prompt_len += extra_batch["patches"].shape[1]

        key = jax.random.PRNGKey(seed)
        out = []
        pos = prompt_len
        for i in range(max_new):
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / temperature)
            else:
                tok = jnp.argmax(logits, axis=-1)
            tok = tok.astype(jnp.int32)[:, None]
            out.append(np.asarray(tok))
            logits, caches = self._decode(
                self.params, tok, caches, jnp.int32(pos)
            )
            pos += 1
        return np.concatenate(out, axis=1)


def mean_pool_embedder(bundle, params):
    """(B, S) tokens -> (B, d_model) embeddings from the final hidden
    state (the LM as its own embedding model for RAG)."""
    from repro.models import transformer as tf

    def embed(tokens):
        x = tf.embed_tokens(params, bundle.cfg, tokens)
        h, _ = tf.forward_hidden(params, bundle.cfg, x)
        return h.mean(axis=1).astype(jnp.float32)

    return jax.jit(embed)
