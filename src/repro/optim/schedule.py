"""LR schedules: linear-warmup cosine and MiniCPM's WSD (warmup-stable-decay).

WSD [arXiv:2404.06395 §4]: warmup to peak, hold constant for the stable
phase, then a short exponential/linear decay tail — the schedule that
lets MiniCPM resume the stable phase from any checkpoint (continuous
pretraining), which pairs naturally with this framework's elastic
checkpoint/restore.
"""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    progress = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * progress)
    )
    return jnp.where(step < warmup, warm, peak_lr * cos)


def wsd(step, *, peak_lr: float, warmup: int, stable: int, decay: int,
        final_frac: float = 0.01):
    """Warmup-Stable-Decay (MiniCPM)."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    decay_progress = jnp.clip(
        (step - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0
    )
    # exponential-style decay tail
    decayed = peak_lr * jnp.power(final_frac, decay_progress)
    out = jnp.where(step < warmup, warm, peak_lr)
    return jnp.where(step > warmup + stable, decayed, out)


SCHEDULES = {"cosine": warmup_cosine, "wsd": wsd}
