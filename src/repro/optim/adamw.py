"""AdamW on parameter pytrees, with dtype-configurable state.

Optimizer state dtype is a first-class memory knob at fleet scale:
fp32 m+v costs 8 bytes/param — more than bf16 params+grads combined.
``state_dtype=bf16`` halves that (the standard large-model trade-off;
update math still runs in fp32).  State sharding mirrors parameter
sharding exactly (handled at the jit boundary by
``repro.dist.sharding.param_shardings``).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32


def init_opt_state(params, cfg: AdamWConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, dtype=cfg.state_dtype)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    ))


def adamw_update(
    params,
    grads,
    state: dict,
    cfg: AdamWConfig,
    lr: jnp.ndarray,
):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def leaf(p, g, mu, nu):
        g32 = g.astype(jnp.float32) * scale
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g32
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        update = (mu32 / c1) / (jnp.sqrt(nu32 / c2) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * update
        return (
            new_p.astype(p.dtype),
            mu32.astype(mu.dtype),
            nu32.astype(nu.dtype),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [leaf(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return (
        new_params,
        {**state, "mu": new_mu, "nu": new_nu, "count": count},
        {"grad_norm": gnorm, "lr": lr},
    )
