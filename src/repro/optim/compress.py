"""2-bit Sign-Magnitude gradient compression with error feedback.

Beyond-paper integration: QuIVer's training-free 2-bit encoder (§3.1)
applied to *gradients* on the data-parallel axis.  Exactly the paper's
code construction — per-tensor threshold tau = mean|g|, sign plane +
magnitude plane — plus two per-tensor reconstruction levels (the
conditional means of the weak/strong buckets, i.e. the 1-D Lloyd-Max
update for the paper's 4-level quantizer), and EF-SGD-style error
feedback so quantization noise is fed back instead of lost.

16x compression vs fp32 on the wire (2 bits + 2 scalars per tensor).
``compressed_psum`` demonstrates the collective itself under
``shard_map`` (quantize -> all-gather words -> decode+sum), used on the
'pod' (DCN) axis where bandwidth is scarcest.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import bq


def sm2_quantize(x: jnp.ndarray):
    """Flat fp32 -> (packed words (2W,) uint32, c_weak, c_strong)."""
    flat = x.reshape(-1).astype(jnp.float32)
    absx = jnp.abs(flat)
    tau = absx.mean()
    pos = flat > 0
    strong = absx > tau
    words = jnp.concatenate(
        [bq.pack_bits(pos), bq.pack_bits(strong)], axis=-1
    )
    # Lloyd-Max level update: conditional mean |x| per bucket
    n_strong = jnp.maximum(strong.sum(), 1)
    n_weak = jnp.maximum((~strong).sum(), 1)
    c_strong = jnp.where(strong, absx, 0.0).sum() / n_strong
    c_weak = jnp.where(strong, 0.0, absx).sum() / n_weak
    return words, c_weak, c_strong


def sm2_dequantize(words, c_weak, c_strong, size: int, shape) -> jnp.ndarray:
    w = words.shape[-1] // 2
    pos = bq.unpack_bits(words[..., :w], size)
    strong = bq.unpack_bits(words[..., w:], size)
    mag = jnp.where(strong, c_strong, c_weak)
    out = jnp.where(pos, mag, -mag)
    return out.reshape(shape)


def compress_decompress_tree(grads: Any, ef: Any) -> tuple[Any, Any]:
    """Quantize+dequantize each leaf with error feedback.

    Models the numerical effect of the compressed all-reduce exactly
    (the collective itself is ``compressed_psum``); returns
    (decoded grads, new error-feedback state).
    """
    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        size = g32.size
        words, cw, cs = sm2_quantize(g32)
        dec = sm2_dequantize(words, cw, cs, size, g32.shape)
        return dec.astype(g.dtype), (g32 - dec).astype(e.dtype)

    out = jax.tree.map(leaf, grads, ef)
    dec = jax.tree.map(lambda o: o[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return dec, new_ef


def init_error_feedback(params: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, dtype=dtype), params
    )


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """2-bit compressed all-reduce (inside shard_map).

    Wire bytes: 2 bits/element instead of 32 — each member quantizes
    its local shard, all-gathers the packed words + two scalars, then
    decodes and sums all contributions locally.
    """
    shape = x.shape
    size = x.size
    words, cw, cs = sm2_quantize(x)
    aw = jax.lax.all_gather(words, axis_name)        # (N, 2W) uint32
    acw = jax.lax.all_gather(cw, axis_name)
    acs = jax.lax.all_gather(cs, axis_name)
    decoded = jax.vmap(
        lambda w, a, b: sm2_dequantize(w, a, b, size, shape)
    )(aw, acw, acs)
    return decoded.sum(axis=0)


def compression_ratio(params: Any) -> float:
    """Wire-byte ratio fp32 : compressed for one gradient exchange."""
    total = sum(p.size for p in jax.tree.leaves(params))
    n_leaves = len(jax.tree.leaves(params))
    fp32 = 4 * total
    comp = total / 4 + 8 * n_leaves      # 2 bits/elem + 2 fp32 scalars
    return fp32 / comp
