"""QueryPlan — a frozen, hashable spec of one search configuration.

The serving stack used to make three ad-hoc decisions per request —
nav-ladder rung + ef/rerank schedule (``core/index.py``), filter
routing (``filter/search.py``) and adaptive escalation
(``core/beam.py``) — each of which could steer a call onto a jit
program the process had never traced.  A :class:`QueryPlan` freezes all
of them into one hashable value resolved *once* per request shape
(``repro.plan.planner.resolve_plan``), so the set of compiled programs
a process can ever need is the closed set of distinct plans
(``repro.plan.cache.PlanCache`` compiles each exactly once).

Everything in the plan is static-with-respect-to-jit: nav kind, beam
width, expansion, rerank depth, route, whether a predicate mask rides
the beam, and the escalation schedule.  Dynamic per-request arrays —
the entry point, the predicate mask, the brute-route match set — live
in the companion :class:`PlanContext` and never key a compilation.

Derived stages are plans too: ``escalated()`` is the tight-margin
second stage (same program shape, ``escalate_mult``-times wider beam)
and ``degraded()`` walks the deadline ladder (halve ef, floor at k) —
both land back in the same closed plan set.
"""

from __future__ import annotations

import dataclasses

ROUTES = ("graph", "brute", "ivf")


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """One compiled search configuration (see module docstring).

    ``ef`` is the *effective* beam width: the caller's ef after the
    NavPolicy ``ef_scale`` and — on the filtered graph route — the
    quantized selectivity widening, so equal plans really do share a
    program.  ``filtered`` marks whether a predicate mask rides the
    beam (a masked beam is a structurally different program than an
    unmasked one).  ``rerank`` means float32 cosine rerank (requires
    cold vectors; the planner clears it when they are absent).
    """

    nav: str
    k: int
    ef: int
    expand: int = 1
    rerank: bool = True
    route: str = "graph"            # "graph" | "brute" | "ivf"
    filtered: bool = False          # result_valid mask on the beam
    adaptive: bool = False          # tight-margin second stage enabled
    escalate_margin: float = 0.15
    escalate_mult: int = 4
    query_batch: int = 256          # chunk ceiling of the bucket ladder
    probes: int = 0                 # ivf route: top-p lists scanned

    def __post_init__(self):
        if self.route not in ROUTES:
            raise ValueError(f"route {self.route!r} not in {ROUTES}")
        if self.route == "graph":
            if self.ef < self.k:
                raise ValueError(
                    f"graph plan needs ef >= k, got ef={self.ef} k={self.k}"
                )
            if not 1 <= self.expand <= self.ef:
                raise ValueError(
                    f"expand must be in [1, ef], got {self.expand}"
                )
        if self.route == "ivf":
            if self.ef < self.k:
                raise ValueError(
                    f"ivf plan needs ef >= k, got ef={self.ef} k={self.k}"
                )
            if self.probes < 1:
                raise ValueError(
                    f"ivf plan needs probes >= 1, got {self.probes}"
                )
        if self.k < 1 or self.query_batch < 1 or self.escalate_mult < 1:
            raise ValueError("k / query_batch / escalate_mult must be >= 1")

    # -- derived stages ----------------------------------------------------

    def escalated(self) -> "QueryPlan":
        """Stage 2 of an adaptive plan: same program shape, wider pool,
        no further escalation.  The ivf route widens its list fan-in
        (``probes``) along with ef — starved pools escalate by scanning
        more lists, not just keeping more of the same candidates."""
        probes = self.probes
        if self.route == "ivf":
            probes = self.probes * self.escalate_mult
        return dataclasses.replace(
            self, ef=self.ef * self.escalate_mult, probes=probes,
            adaptive=False,
        )

    @property
    def min_ef(self) -> int:
        return max(self.k, self.expand)

    def can_degrade(self) -> bool:
        """Brute plans are already exact (ef plays no role) and plans at
        the ef floor have nothing left to give."""
        if self.route == "ivf":
            return self.ef // 2 >= self.min_ef or self.probes > 1
        return self.route == "graph" and self.ef // 2 >= self.min_ef

    def degraded(self) -> "QueryPlan":
        """One rung down the deadline ladder: halve the beam (floor at
        ``max(k, expand)``) and drop escalation — under deadline
        pressure the adaptive second stage is the first thing to go.
        Halving keeps the degraded plans inside a closed set (no fresh
        compilations under load spikes).  The ivf route halves its
        probed lists in step (floor 1)."""
        if not self.can_degrade():
            return self
        probes = self.probes
        if self.route == "ivf":
            probes = max(1, self.probes // 2)
        return dataclasses.replace(
            self, ef=max(self.min_ef, self.ef // 2), probes=probes,
            adaptive=False,
        )

    def signature(self) -> str:
        """Short stable id for logs and trace-counter names."""
        bits = [self.nav, f"k{self.k}", f"ef{self.ef}", f"L{self.expand}",
                self.route]
        if self.route == "ivf":
            bits.append(f"p{self.probes}")
        if self.filtered:
            bits.append("masked")
        if self.rerank:
            bits.append("rr")
        if self.adaptive:
            bits.append(f"esc{self.escalate_mult}")
        return "-".join(bits)


@dataclasses.dataclass
class PlanContext:
    """The dynamic companions of a plan: per-request arrays that feed a
    compiled program but never key a compilation.

    ``start`` is the traversal entry point (global or per-label medoid);
    ``result_valid`` the predicate mask of a filtered graph plan;
    ``match_ids`` the materialized match set of a brute plan;
    ``selectivity`` the (exact-verified where brute) match fraction, for
    reporting.
    """

    start: int = 0
    result_valid: object | None = None     # (n,) bool device mask
    match_ids: object | None = None        # (M,) int32 host match set
    selectivity: float | None = None
