"""resolve_plan — collapse the three serve-time decision points.

Before the plan refactor, every search call re-decided, inline and
independently:

1. the **nav ladder** — which metric rung and ef/rerank schedule the
   index's :class:`~repro.probe.NavPolicy` prescribes
   (``core/index.py``);
2. the **filter route** — widened-ef graph traversal vs exact brute
   force over the match set, from the predicate's estimated
   selectivity (``filter/search.py``);
3. the **escalation schedule** — whether tight-margin queries re-run
   with a wider beam (``core/beam.py::escalated_search``).

:func:`resolve_plan` makes them one decision with one output: a frozen
:class:`~repro.plan.plan.QueryPlan` (everything jit-static) plus a
:class:`~repro.plan.plan.PlanContext` (the dynamic arrays — entry
point, predicate mask, brute match set).  The routing *policies* stay
where they live today (``resolve_schedule``, ``route``/``widened_ef``/
``entry_label``) — this module only owns their composition, so a plan
is always exactly what the legacy inline path would have decided.

Selectivity enters the plan only through ``widened_ef``'s quantized
widening multiple, so predicate drift moves the plan key in bounded
steps (a "selectivity band"), not per-popcount.
"""

from __future__ import annotations

import numpy as np

from repro.filter import (
    DEFAULT_SELECTIVITY_FLOOR,
    entry_label,
    estimate_selectivity,
    route,
    validate,
    widened_ef,
)
from repro.obs.metrics import get_default_registry
from repro.plan.plan import PlanContext, QueryPlan
from repro.probe import resolve_schedule


def _note_resolution(plan: QueryPlan, selectivity: float | None) -> None:
    """Route-decision telemetry (DESIGN.md §12): every resolution lands
    in the process registry so fleet dashboards see the filter-route
    mix and the selectivity distribution driving it."""
    reg = get_default_registry()
    reg.counter(
        "quiver_plan_resolutions_total",
        "resolve_plan outcomes by route",
        labels=("route", "filtered", "nav"),
    ).inc(route=plan.route, filtered=str(plan.filtered).lower(),
          nav=plan.nav)
    if selectivity is not None:
        reg.histogram(
            "quiver_filter_selectivity",
            "match fraction of filtered requests",
            buckets=(0.001, 0.005, 0.01, 0.05, 0.1, 0.25, 0.5, 1.0),
            window=0,
        ).observe(selectivity)


def resolve_plan(
    index,
    *,
    k: int = 10,
    ef: int = 64,
    rerank: bool = True,
    nav: str | None = None,
    expand: int = 1,
    query_batch: int = 256,
    filter=None,
    selectivity_floor: float = DEFAULT_SELECTIVITY_FLOOR,
    adaptive: bool | None = None,
    probes: int | None = None,
) -> tuple[QueryPlan, PlanContext]:
    """Resolve one search call to (plan, context) for ``index``.

    ``index`` is any immutable-index-shaped object: ``sigs``,
    ``medoid``, ``vectors``, ``labels``, ``policy``, ``metric_kind``.
    Same (policy, filter selectivity band, ef, k, nav, expand, probes)
    in → equal (hash-identical) plan out: the PlanCache key.

    ``kind`` defaults through the index's :class:`NavPolicy` before its
    build metric: the policy may prescribe a navigation *family* the
    graph was not built in (``nav="ivf"`` navigates coarse lists over a
    bq2-built index).  ``probes`` is the ivf route's list fan-in
    (default: the partition's √L).
    """
    n = index.sigs.words.shape[0]
    policy = getattr(index, "policy", None)
    ef, adaptive, sched = resolve_schedule(policy, nav, ef, adaptive)
    kind = nav or (policy.nav if policy is not None else index.metric_kind)
    do_rerank = rerank and index.vectors is not None

    part = None
    if kind == "ivf":
        part = getattr(index, "ivf", None)
        if part is None:
            raise ValueError(
                "nav='ivf' needs a coarse partition: build with "
                "BuildParams(ivf_candidates=True) or call build_ivf()"
            )
        probes = probes or part.default_probes
        # enough lists to fill k even if every probed list is sparse
        probes = max(min(probes, part.n_lists),
                     min(part.n_lists, -(-k // part.cap)))
        expand = 1                  # no traversal: expansion is meaningless

    ctx = PlanContext(start=int(index.medoid))
    filtered = False
    ef_run = ef
    if filter is not None:
        if index.labels is None:
            raise ValueError(
                "filtered search needs labels: attach_labels() first"
            )
        expr = validate(filter, index.labels.n_labels)
        count_fn = index.labels.count_fn()
        sel = estimate_selectivity(expr, count_fn, n)
        mask = index.labels.mask(expr)
        if route(sel, selectivity_floor) == "brute":
            # the popcount estimate is a bound, not a measurement
            # (Not() of a union bound can underestimate badly); verify
            # with the exact mask popcount before committing to
            # materializing the match set
            match = np.nonzero(np.asarray(mask))[0]
            sel = len(match) / max(n, 1)
            if route(sel, selectivity_floor) == "brute":
                ctx.match_ids = match.astype(np.int32)
                ctx.selectivity = sel
                plan = QueryPlan(
                    nav=kind, k=k, ef=max(ef, k), expand=expand,
                    rerank=do_rerank, route="brute",
                    query_batch=query_batch,
                )
                _note_resolution(plan, sel)
                return plan, ctx
        filtered = True
        ctx.result_valid = mask
        ctx.selectivity = sel
        ef_run = widened_ef(ef, sel, selectivity_floor, n)
        if part is not None and ef_run > ef:
            # the ivf route widens its list fan-in by the same
            # quantized multiple the graph route widens its beam: the
            # predicate thins every probed list uniformly in expectation
            probes = min(part.n_lists, -(-(probes * ef_run) // ef))
        lbl = entry_label(expr, count_fn)
        if lbl is not None and index.labels.entries[lbl] >= 0:
            ctx.start = int(index.labels.entries[lbl])

    plan = QueryPlan(
        nav=kind, k=k, ef=ef_run, expand=expand, rerank=do_rerank,
        route="ivf" if kind == "ivf" else "graph",
        filtered=filtered, adaptive=adaptive,
        escalate_margin=sched.escalate_margin,
        escalate_mult=sched.escalate_mult, query_batch=query_batch,
        probes=probes if kind == "ivf" else 0,
    )
    _note_resolution(plan, ctx.selectivity)
    return plan, ctx
