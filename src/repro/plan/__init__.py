"""Compiled query plans (DESIGN.md §11).

The serving stack's three per-request decision points — nav ladder,
filter routing, adaptive escalation — collapse into one ahead-of-time
resolved :class:`QueryPlan`:

* :func:`resolve_plan` — (policy, predicate selectivity band, caller
  args) -> frozen, hashable plan + dynamic :class:`PlanContext`;
* :class:`PlanCache` — jit-compiles each distinct plan exactly once
  (escalation is the same plan's second stage) and reuses it across
  requests;
* ``repro.plan.trace`` — jit lowering counters behind the
  "steady-state retraces == 0" serving guarantee.
"""

from repro.plan import trace
from repro.plan.plan import PlanContext, QueryPlan
from repro.plan.planner import resolve_plan
from repro.plan.cache import PendingResult, PlanCache

__all__ = [
    "PendingResult",
    "PlanCache",
    "PlanContext",
    "QueryPlan",
    "resolve_plan",
    "trace",
]
