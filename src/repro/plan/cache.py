"""PlanCache — compile each distinct QueryPlan exactly once, then feed it.

One cache per index.  For every :class:`~repro.plan.plan.QueryPlan` the
cache builds a single fused program — beam search + rerank + margin in
one ``jit`` (per-query-bucket shapes handled by jax's own shape
caching, bounded by the bucket ladder) — and every later request with
the same plan reuses it.  Adaptive escalation is the *second stage of
the same compiled plan*: ``plan.escalated()`` is just another plan in
the cache, precompiled by :meth:`warmup`, so the tight-margin re-run
dispatches a cached executable instead of retracing a fresh call-site
combination the way the legacy ``escalated_search`` driver could.

Trace accounting rides ``repro.plan.trace``: each program is a
``counting_jit`` under this cache's prefix, so
``report()["retraces"]`` is exactly "trace events beyond the first per
(plan, bucket)" — the number the serve benchmark pins to zero in
steady state.
"""

from __future__ import annotations

import itertools

import jax.numpy as jnp
import numpy as np

from repro.core.beam import (
    batch_bucket,
    batched_beam_search,
    beam_margin,
    pad_rows,
)
from repro.plan import trace
from repro.plan.plan import PlanContext, QueryPlan

_CACHE_IDS = itertools.count()

# navigation-path trace statistics (DESIGN.md §15): column order of the
# (Q, 5) nav array the graph programs return, with the fixed histogram
# buckets each lands in (windowless — hot-path observes stay vectorized)
NAV_STATS = (
    ("hops", (1, 2, 4, 8, 16, 32, 64, 128, 256)),
    ("evals", (32, 64, 128, 256, 512, 1024, 2048, 4096, 8192)),
    ("descent", (0.0, 1.0, 4.0, 16.0, 64.0, 256.0, 1024.0, 4096.0,
                 16384.0)),
    ("stalls", (0, 1, 2, 4, 8, 16, 32, 64)),
    ("entry_rank", (0, 1, 2, 4, 8, 16, 32, 64, 128)),
)


def _nav_trace(res) -> jnp.ndarray:
    """Stack a batched BeamResult's per-query counters into the (Q, 5)
    nav-trace array (float32: one dtype, one transfer)."""
    return jnp.stack([
        res.hops.astype(jnp.float32),
        res.evals.astype(jnp.float32),
        res.descent.astype(jnp.float32),
        res.stalls.astype(jnp.float32),
        res.entry_rank.astype(jnp.float32),
    ], axis=-1)


def _normalize(x: jnp.ndarray) -> jnp.ndarray:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


class PendingResult:
    """In-flight device results of one launched plan: per-chunk device
    arrays plus splice metadata.  ``PlanCache.finalize`` syncs them to
    host and runs the escalation stage if the plan asks for one.  The
    split exists so the serve engine can overlap the next batch's
    host→device transfer with this batch's compute (double buffering).
    """

    __slots__ = ("plan", "ctx", "queries", "reprs", "chunks", "nav")

    def __init__(self, plan, ctx, queries, reprs, chunks):
        self.plan = plan
        self.ctx = ctx
        self.queries = queries       # (Q, D) normalized, device
        self.reprs = reprs           # encoded queries, device
        self.chunks = chunks         # [(ids, scores, margins, nav, real)]
        # (Q, 5) host float32 nav-trace rows [hops, evals, descent,
        # stalls, entry_rank] — populated by finalize() when the cache
        # has an obs hub and the plan traverses the graph; None otherwise
        self.nav = None


class PlanCache:
    """Compiled-executable cache keyed by :class:`QueryPlan`."""

    def __init__(self, index):
        self._index = index
        self._programs: dict[QueryPlan, object] = {}
        # (plan, bucket) pairs that have executed at least once — the
        # closed set of compiled shapes; misses == first-time pairs
        self._seen: set[tuple[QueryPlan, int]] = set()
        self._tag = f"plan[{next(_CACHE_IDS)}]:"
        self.hits = 0
        self.misses = 0
        self.executions = 0
        self.invalidated_plans = 0
        # (plan, bucket) shapes evicted by invalidate(): their historic
        # trace events stay in the counters, so retrace accounting
        # subtracts them — a post-replan recompile is intended work, not
        # an accounting anomaly
        self.invalidated_shapes = 0
        # telemetry hub (repro.obs.ObsHub, DESIGN.md §12): set by the
        # serve engine (or any owner) to land per-plan stage timings in
        # ``quiver_plan_seconds{stage,plan}`` and escalation counts in
        # ``quiver_escalated_queries_total{plan}``.  None: zero overhead.
        self.obs = None

    # -- program construction ---------------------------------------------

    def program(self, plan: QueryPlan):
        """The compiled program for ``plan`` (built exactly once)."""
        if plan not in self._programs:
            self._programs[plan] = self._build(plan)
        return self._programs[plan]

    def _nav_backend(self, nav: str):
        """The metric backend a plan's nav family scores with — the
        ivf family navigates coarse lists but scores candidates in
        plain bq2 space (the partition lives there)."""
        return self._index.backend("bq2" if nav == "ivf" else nav)

    def _build(self, plan: QueryPlan):
        if plan.route == "brute":
            raise ValueError("brute plans run through "
                             "filter.brute_force_topk, not a program")
        if plan.route == "ivf":
            return self._build_ivf(plan)
        index = self._index
        backend = self._nav_backend(plan.nav)
        dist_fn = backend.dist_fn
        neutral = backend.neutral_dist
        n = index.sigs.words.shape[0]
        # lazy: core.index imports this module at its own top level
        from repro.core.index import rerank

        if plan.filtered:
            def program(reprs, queries, adjacency, vectors, start,
                        result_valid):
                res = batched_beam_search(
                    reprs, adjacency, start, dist_fn=dist_fn, ef=plan.ef,
                    n=n, expand=plan.expand, result_valid=result_valid,
                )
                ids, scores = rerank(res.ids, res.dists, queries,
                                     vectors, plan.k)
                margins = beam_margin(res.dists, plan.k, neutral)
                return ids, scores, margins, _nav_trace(res)
        else:
            def program(reprs, queries, adjacency, vectors, start):
                res = batched_beam_search(
                    reprs, adjacency, start, dist_fn=dist_fn, ef=plan.ef,
                    n=n, expand=plan.expand,
                )
                ids, scores = rerank(res.ids, res.dists, queries,
                                     vectors, plan.k)
                margins = beam_margin(res.dists, plan.k, neutral)
                return ids, scores, margins, _nav_trace(res)

        return trace.counting_jit(
            program, name=self._tag + plan.signature()
        )

    def _build_ivf(self, plan: QueryPlan):
        """One fused ivf program: list scan -> top-p gather -> metric
        top-ef -> rerank -> margin.  ``cent_words``/``list_ids`` enter
        as program arguments (like ``adjacency`` on the graph route) so
        the executable never bakes index arrays in as constants."""
        index = self._index
        part = index.ivf
        if part is None:
            raise ValueError("ivf plan on an index without a partition")
        backend = self._nav_backend(plan.nav)
        neutral = backend.neutral_dist
        from repro.core.index import rerank
        from repro.ivf.search import scan_search
        from repro.kernels import dispatch

        scan = dispatch.list_scan_ops(
            index.sigs.dim, route=getattr(backend, "route", None)
        ).scan
        # clamp to the partition, but never below the fan-in that can
        # fill k (degraded plans halve probes with floor 1)
        p_eff = max(min(plan.probes, part.n_lists),
                    min(part.n_lists, -(-plan.k // part.cap)))

        if plan.filtered:
            def program(reprs, queries, cent_words, list_ids, vectors,
                        result_valid):
                ids, dists = scan_search(
                    backend, scan, reprs, cent_words, list_ids,
                    probes=p_eff, ef=plan.ef, result_valid=result_valid,
                )
                out_ids, scores = rerank(ids, dists, queries, vectors,
                                         plan.k)
                margins = beam_margin(dists, plan.k, neutral)
                return out_ids, scores, margins
        else:
            def program(reprs, queries, cent_words, list_ids, vectors):
                ids, dists = scan_search(
                    backend, scan, reprs, cent_words, list_ids,
                    probes=p_eff, ef=plan.ef,
                )
                out_ids, scores = rerank(ids, dists, queries, vectors,
                                         plan.k)
                margins = beam_margin(dists, plan.k, neutral)
                return out_ids, scores, margins

        return trace.counting_jit(
            program, name=self._tag + plan.signature()
        )

    # -- query encoding ----------------------------------------------------

    def encode(self, plan: QueryPlan, queries: jnp.ndarray) -> jnp.ndarray:
        """Normalized float32 queries -> the plan's beam representation
        (rotation applied for signature-space navigation)."""
        index = self._index
        backend = self._nav_backend(plan.nav)
        enc_in = queries
        if index.rotation is not None and backend.kind != "float32":
            enc_in = queries @ index.rotation
        return backend.encode_queries(enc_in)

    # -- execution ---------------------------------------------------------

    def launch(
        self,
        plan: QueryPlan,
        ctx: PlanContext,
        queries: jnp.ndarray,
        *,
        record: bool = True,
    ) -> PendingResult:
        """Dispatch ``queries`` through ``plan`` without waiting.

        Queries are normalized here; chunks follow the bucket ladder
        (``batch_bucket``) so tail and singleton batches land on the
        small closed set of padded shapes.  Returns device-side results
        (jax async dispatch: compute proceeds while the host goes on to
        stage the next batch).
        """
        obs = self.obs
        t0 = obs.tracer.clock() if obs is not None else 0.0
        queries = _normalize(jnp.asarray(queries, dtype=jnp.float32))
        if queries.ndim == 1:
            queries = queries[None]
        if plan.route == "brute":
            return PendingResult(plan, ctx, queries, None, None)
        index = self._index
        prog = self.program(plan)
        reprs = self.encode(plan, queries)
        vectors = index.vectors if plan.rerank else None
        start = jnp.int32(ctx.start)
        chunks = []
        for s in range(0, queries.shape[0], plan.query_batch):
            rep = reprs[s:s + plan.query_batch]
            q = queries[s:s + plan.query_batch]
            real = rep.shape[0]
            bucket = batch_bucket(real, plan.query_batch)
            if record:
                self.executions += 1
                if (plan, bucket) in self._seen:
                    self.hits += 1
                else:
                    self.misses += 1
            self._seen.add((plan, bucket))
            if plan.route == "ivf":
                args = (pad_rows(rep, bucket), pad_rows(q, bucket),
                        index.ivf.cent_words, index.ivf.list_ids, vectors)
            else:
                args = (pad_rows(rep, bucket), pad_rows(q, bucket),
                        index.adjacency, vectors, start)
            if plan.filtered:
                args += (ctx.result_valid,)
            out = prog(*args)
            # graph programs return a 4th (nav-trace) array; the ivf
            # route has no traversal to trace
            nav = out[3] if len(out) > 3 else None
            chunks.append((out[0], out[1], out[2], nav, real))
        if obs is not None:
            self._stage_hist(obs).observe(
                obs.tracer.clock() - t0,
                stage="launch", plan=plan.signature(),
            )
        return PendingResult(plan, ctx, queries, reprs, chunks)

    def _stage_hist(self, obs):
        return obs.registry.histogram(
            "quiver_plan_seconds",
            "per-plan stage wall time (launch dispatch / finalize sync)",
            labels=("stage", "plan"),
        )

    def finalize(
        self, pending: PendingResult
    ) -> tuple[np.ndarray, np.ndarray]:
        """Sync a launched plan to host and run its second (escalation)
        stage where margins demand one."""
        plan, ctx = pending.plan, pending.ctx
        obs = self.obs
        t0 = obs.tracer.clock() if obs is not None else 0.0
        if plan.route == "brute":
            return self._run_brute(plan, ctx, pending.queries)
        out_ids, out_scores, out_margin, out_nav = [], [], [], []
        for ids, scores, margins, nav, real in pending.chunks:
            out_ids.append(np.asarray(ids[:real]))
            out_scores.append(np.asarray(scores[:real]))
            out_margin.append(np.asarray(margins[:real]))
            if obs is not None and nav is not None:
                out_nav.append(np.asarray(nav[:real]))
        all_ids = np.concatenate(out_ids)
        all_scores = np.concatenate(out_scores)
        if out_nav:
            # nav-path tracing (DESIGN.md §15): the counters ride the
            # compiled program either way; host transfer + histogram
            # observes only happen with an obs hub attached
            pending.nav = np.concatenate(out_nav)
            for col, (stat, buckets) in enumerate(NAV_STATS):
                obs.registry.histogram(
                    f"quiver_nav_{stat}",
                    f"per-query beam {stat} by nav family and plan",
                    labels=("nav", "plan"), buckets=buckets, window=0,
                ).observe_many(
                    pending.nav[:, col],
                    nav=plan.nav, plan=plan.signature(),
                )
        if obs is not None:
            self._stage_hist(obs).observe(
                obs.tracer.clock() - t0,
                stage="finalize", plan=plan.signature(),
            )
        if plan.adaptive:
            margins = np.concatenate(out_margin)
            esc = np.nonzero(margins < plan.escalate_margin)[0]
            if esc.size:
                take = jnp.asarray(esc.astype(np.int32))
                if obs is not None:
                    obs.registry.counter(
                        "quiver_escalated_queries_total",
                        "tight-margin queries re-run at the escalated "
                        "stage", labels=("plan",),
                    ).inc(int(esc.size), plan=plan.signature())
                    with obs.tracer.span("escalate",
                                         plan=plan.signature(),
                                         queries=int(esc.size)):
                        esc_ids, esc_scores = self.finalize(self.launch(
                            plan.escalated(), ctx, pending.queries[take]
                        ))
                else:
                    esc_ids, esc_scores = self.finalize(self.launch(
                        plan.escalated(), ctx, pending.queries[take]
                    ))
                all_ids[esc] = esc_ids
                all_scores[esc] = esc_scores
        return all_ids, all_scores

    def run(
        self, plan: QueryPlan, ctx: PlanContext, queries
    ) -> tuple[np.ndarray, np.ndarray]:
        """launch + finalize: the synchronous per-call entry
        (``QuIVerIndex.search`` lowers to exactly this)."""
        return self.finalize(self.launch(plan, ctx, queries))

    def _run_brute(self, plan, ctx, queries):
        # exact top-k over the materialized match set; already a
        # shape-bounded jit (match lists pad to powers of two)
        from repro.filter.search import brute_force_topk

        index = self._index
        if plan.rerank:
            return brute_force_topk(
                queries, ctx.match_ids, plan.k, vectors=index.vectors
            )
        backend = self._nav_backend(plan.nav)
        return brute_force_topk(
            queries, ctx.match_ids, plan.k, vectors=None,
            backend=backend, reprs=self.encode(plan, queries),
        )

    # -- invalidation ------------------------------------------------------

    def invalidate(self, *, nav: str) -> int:
        """Evict every compiled program and shape record whose plan
        navigates in ``nav``; returns the number of plans evicted.

        This is the surgical half of :meth:`QuIVerIndex.replan`: when
        remediation swaps the default nav policy, only the plans of the
        *abandoned* family are dropped — every other plan (forced-nav
        traffic, other k/ef shapes) keeps its program object, so their
        steady-state serve sees zero retraces.  Evicted plans recompile
        on next use (counted as misses, compensated out of the retrace
        audit).
        """
        victims = {p for p in self._programs if p.nav == nav}
        victims |= {p for p, _ in self._seen if p.nav == nav}
        for p in victims:
            self._programs.pop(p, None)
        evicted = {pb for pb in self._seen if pb[0].nav == nav}
        self._seen -= evicted
        self.invalidated_shapes += len(evicted)
        self.invalidated_plans += len(victims)
        return len(victims)

    # -- warmup & accounting ----------------------------------------------

    def warmup(
        self,
        plan: QueryPlan,
        ctx: PlanContext | None = None,
        *,
        buckets: tuple[int, ...] = (8,),
        with_escalation: bool = True,
    ) -> int:
        """Precompile ``plan`` (and its escalation stage) for the given
        query buckets; returns how many programs were exercised.
        Warmup traffic is excluded from hit/miss stats."""
        if plan.route == "brute":
            return 0
        if ctx is None:
            ctx = PlanContext(start=int(self._index.medoid))
            if plan.filtered:
                n = self._index.sigs.words.shape[0]
                ctx.result_valid = jnp.ones((n,), dtype=jnp.bool_)
        dim = self._index.sigs.dim
        ran = 0
        stages = [plan]
        if with_escalation and plan.adaptive:
            stages.append(plan.escalated())
        for stage in stages:
            for b in buckets:
                q = jnp.zeros((min(b, stage.query_batch), dim),
                              dtype=jnp.float32)
                self.finalize(self.launch(stage, ctx, q, record=False))
                ran += 1
        return ran

    def report(self) -> dict:
        """``memory_breakdown``-style serving-compilation report."""
        tr = trace.trace_report(self._tag)
        lookups = self.hits + self.misses
        return {
            "plans_compiled": len(self._programs),
            "plan_shapes": len(self._seen),
            "executions": self.executions,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / lookups if lookups else 1.0,
            "invalidated_plans": self.invalidated_plans,
            "trace_events": tr["total_traces"],
            "retraces": (tr["total_traces"] - len(self._seen)
                         - self.invalidated_shapes),
        }

    def trace_prefix(self) -> str:
        """This cache's trace-counter namespace (for snapshots)."""
        return self._tag
