"""Retrace accounting: count ``jax.jit`` trace events per named program.

A jitted function's Python body only executes when jax *traces* it — a
cache miss on the (function, abstract-shapes, static-args) key.  So a
counter bumped inside the body is exactly a lowering counter: it moves
on first compilation and on every retrace, and stays flat on cache
hits.  ``counting_jit`` builds instrumented jits; ``note_trace`` is the
raw hook for already-jitted functions (``repro.core.beam.beam_search``
notes itself).

Steady-state serving must not retrace (ROADMAP: the serving process
compiles a small closed set of plans once and then only feeds them), so
the serve benchmark and tier-1 tests pin that down with
:func:`assert_no_retrace` / :func:`snapshot` deltas, and
:func:`trace_report` exposes the counters ``memory_breakdown``-style.

This module is import-cycle-free on purpose (its only ``repro.*``
import is the leaf ``repro.obs.metrics``, itself stdlib+numpy-only):
anything — core, filter, serve — may note traces into it.  Each trace
event is mirrored into the process metrics registry
(``quiver_jit_traces_total{program=...}``) so compilation storms are
visible on the same scrape as everything else.
"""

from __future__ import annotations

import contextlib
import functools
import threading

import jax

_LOCK = threading.Lock()
_COUNTS: dict[str, int] = {}


def note_trace(name: str) -> None:
    """Record one trace event for program ``name`` (call this from
    *inside* a jitted function's Python body)."""
    with _LOCK:
        _COUNTS[name] = _COUNTS.get(name, 0) + 1
    # mirror into the metrics layer (trace events are rare — only at
    # compile time — so the extra counter bump costs nothing steady-state)
    from repro.obs.metrics import get_default_registry
    get_default_registry().counter(
        "quiver_jit_traces_total",
        "jit trace (compilation) events per program",
        labels=("program",),
    ).inc(program=name)


def counting_jit(fun, *, name: str | None = None, **jit_kwargs):
    """``jax.jit(fun)`` whose trace events are counted under ``name``
    (default: the function's ``__name__``)."""
    tag = name or getattr(fun, "__name__", "anonymous")

    @functools.wraps(fun)
    def noted(*args, **kwargs):
        note_trace(tag)
        return fun(*args, **kwargs)

    return jax.jit(noted, **jit_kwargs)


def trace_counts(prefix: str = "") -> dict[str, int]:
    """Per-program trace counts (filtered to names under ``prefix``)."""
    with _LOCK:
        return {k: v for k, v in _COUNTS.items() if k.startswith(prefix)}


def total_traces(prefix: str = "") -> int:
    return sum(trace_counts(prefix).values())


def reset(prefix: str = "") -> None:
    with _LOCK:
        for k in [k for k in _COUNTS if k.startswith(prefix)]:
            del _COUNTS[k]


def trace_report(prefix: str = "") -> dict:
    """``memory_breakdown``-style report: per-program trace counts plus
    the total — diff two of these across a serving window to get the
    window's retrace count."""
    counts = trace_counts(prefix)
    return {
        "programs": dict(sorted(counts.items())),
        "distinct_programs": len(counts),
        "total_traces": sum(counts.values()),
    }


class TraceSnapshot:
    """Point-in-time counter snapshot; ``delta()`` is the traces since."""

    def __init__(self, prefix: str = ""):
        self.prefix = prefix
        self._base = trace_counts(prefix)

    def delta(self) -> int:
        now = trace_counts(self.prefix)
        return sum(now.values()) - sum(self._base.values())

    def delta_by_program(self) -> dict[str, int]:
        now = trace_counts(self.prefix)
        out = {}
        for k, v in now.items():
            d = v - self._base.get(k, 0)
            if d:
                out[k] = d
        return out


def snapshot(prefix: str = "") -> TraceSnapshot:
    return TraceSnapshot(prefix)


@contextlib.contextmanager
def assert_no_retrace(prefix: str = "", what: str = "steady state"):
    """Context manager asserting zero trace events inside the block —
    the serve benchmark's and tier-1's "steady-state retraces == 0"."""
    snap = snapshot(prefix)
    yield snap
    d = snap.delta()
    if d:
        raise AssertionError(
            f"{what}: expected 0 retraces, got {d}: "
            f"{snap.delta_by_program()}"
        )
