"""jax version compatibility for the SPMD substrate.

``shard_map`` moved from ``jax.experimental.shard_map`` to the ``jax``
namespace, and its replication-check kwarg was renamed
(``check_rep`` -> ``check_vma``) along the way.  This wrapper accepts
either spelling and forwards whichever the installed jax understands, so
every caller in this repo can target the modern signature.
"""

from __future__ import annotations

import inspect

try:
    from jax import shard_map as _shard_map          # jax >= 0.6
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = set(inspect.signature(_shard_map).parameters)
_CHECK_KW = "check_vma" if "check_vma" in _PARAMS else (
    "check_rep" if "check_rep" in _PARAMS else None
)


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma=None, check_rep=None, **kwargs):
    """``jax.shard_map`` with version-portable replication-check kwarg."""
    flag = check_vma if check_vma is not None else check_rep
    if flag is not None and _CHECK_KW is not None:
        kwargs[_CHECK_KW] = flag
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )
