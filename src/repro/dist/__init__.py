"""Distributed substrate: logical-axis sharding helpers for the model
stack (``repro.models``) and the launch/dry-run drivers.

``sharding``       — mesh context, activation constraints, param layouts.
``cache_sharding`` — batch and KV-cache layouts for serving.
"""
