"""Logical-axis sharding: 'dp'/'tp' names over whatever mesh is active.

The model code never mentions physical mesh axes.  It says
``shard(x, "dp", None, "tp")`` and this module maps the logical names to
the active mesh's physical axes:

    dp (data/FSDP) -> ("pod", "data")   (whichever exist on the mesh)
    tp (tensor)    -> ("model",)

Outside a ``use_mesh`` context everything degrades to a no-op, which is
what the single-device smoke tests and local runs rely on: the same
model code runs unmodified on 1 CPU device and on a 2x16x16 fleet.

Param layouts (DESIGN.md §6):
  * training: FSDP on the dp axes over the weight's first big dim +
    TP on its last dim ("w2"-style down-projections transpose this, so
    the contraction stays TP-sharded and the psum count stays at one).
  * serving: TP-only when the params fit per chip — replicating the dp
    dim removes the per-layer all-gathers from the decode path.

Every leaf rule checks divisibility; a dim that does not divide the axis
size stays replicated rather than erroring, so reduced smoke configs
lower on any mesh.
"""

from __future__ import annotations

import contextlib
import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# stack of (mesh, logical_map) — innermost context wins
_ACTIVE: list = []

_DP_AXES = ("pod", "data")
_TP_AXES = ("model",)


def logical_map(mesh: Mesh) -> dict:
    """{'dp': physical axes, 'tp': physical axes} present on ``mesh``."""
    names = set(mesh.axis_names)
    return {
        "dp": tuple(a for a in _DP_AXES if a in names),
        "tp": tuple(a for a in _TP_AXES if a in names),
    }


@contextlib.contextmanager
def use_mesh(mesh: Mesh, lmap: dict | None = None):
    """Activate ``mesh`` for :func:`shard` / :func:`active_ctx`."""
    _ACTIVE.append((mesh, lmap or logical_map(mesh)))
    try:
        yield mesh
    finally:
        _ACTIVE.pop()


def active_ctx():
    """(mesh, logical_map) of the innermost ``use_mesh``, or None."""
    return _ACTIVE[-1] if _ACTIVE else None


def _axis_size(mesh: Mesh, axes: tuple) -> int:
    return math.prod(int(mesh.shape[a]) for a in axes) if axes else 1


def _entry(axes: tuple):
    """PartitionSpec entry for a physical-axes tuple."""
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _spec_for(mesh: Mesh, lmap: dict, shape: tuple, dims: tuple) -> P:
    """Map per-dim logical names ('dp'/'tp'/None) to a PartitionSpec,
    dropping any assignment that does not divide the dim."""
    entries = []
    for size, name in zip(shape, dims):
        axes = tuple(lmap.get(name, ())) if name else ()
        if axes and size % _axis_size(mesh, axes) == 0:
            entries.append(_entry(axes))
        else:
            entries.append(None)
    return P(*entries)


def shard(x, *dims):
    """Constrain ``x``'s sharding by logical dim names; no-op outside a
    ``use_mesh`` context.  ``dims`` has one 'dp'/'tp'/None per array dim."""
    ctx = active_ctx()
    if ctx is None:
        return x
    mesh, lmap = ctx
    if len(dims) != x.ndim:
        raise ValueError(f"shard: {len(dims)} dims for rank-{x.ndim} array")
    spec = _spec_for(mesh, lmap, x.shape, dims)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter layouts
# ---------------------------------------------------------------------------


def _leaf_name(path) -> str:
    """Last string key on a tree path ('w1', 'router', ...)."""
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
    return ""


def _param_dims(name: str, ndim: int) -> tuple:
    """Logical dim assignment for one weight leaf.

    Rank-2+ weights shard (dp, tp) over their last two dims; 'w2'-style
    down-projections transpose to (tp, dp) so the d_ff contraction dim
    stays TP-sharded; routers and rank<2 leaves replicate.
    """
    if ndim < 2 or name == "router":
        return (None,) * ndim
    lead = (None,) * (ndim - 2)
    if name == "w2":
        return lead + ("tp", "dp")
    return lead + ("dp", "tp")


def param_pspecs(mesh: Mesh, params, lmap: dict) -> "params-like":
    """PartitionSpecs for a param pytree under an explicit logical map
    (the ``shard_map`` in_specs path: MoE passes a reduced map when it
    skips the FSDP gathers)."""
    def leaf(path, p):
        dims = _param_dims(_leaf_name(path), p.ndim)
        return _spec_for(mesh, lmap, p.shape, dims)

    return jax.tree_util.tree_map_with_path(leaf, params)


def param_shardings(mesh: Mesh, p_shapes) -> "p_shapes-like":
    """Training layout: FSDP(dp) x TP NamedShardings for the param tree."""
    lmap = logical_map(mesh)
    specs = param_pspecs(mesh, p_shapes, lmap)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def serve_param_shardings(
    mesh: Mesh, p_shapes, param_count: float,
    *, hbm_budget_bytes: float = 12e9,
) -> "p_shapes-like":
    """Serving layout: TP-only when bf16 params fit per chip, else the
    training FSDP layout (no per-layer dp gathers on the decode path
    when we can afford to replicate)."""
    lmap = logical_map(mesh)
    tp_bytes = 2.0 * param_count / max(_axis_size(mesh, lmap["tp"]), 1)
    if tp_bytes > hbm_budget_bytes:
        return param_shardings(mesh, p_shapes)
    tp_only = {"dp": (), "tp": lmap["tp"]}
    specs = param_pspecs(mesh, p_shapes, tp_only)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
