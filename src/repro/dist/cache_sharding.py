"""Batch and serving-cache layouts (DESIGN.md §6).

Activations and KV caches are data-parallel over their batch dim; KV
caches additionally TP-shard the head dim (axis 2 of the canonical
(B, S, H, hd) layout) so decode-time attention reads stay local to the
tensor-parallel shard.  All rules are divisibility-guarded: a dim that
does not divide its axis group stays replicated, so reduced smoke
configs lower on any mesh.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import _axis_size, _entry, logical_map


def _batch_spec(mesh: Mesh, lmap: dict, shape: tuple) -> P:
    dp = lmap["dp"]
    if shape and dp and shape[0] % _axis_size(mesh, dp) == 0:
        return P(_entry(dp), *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def batch_shardings(mesh: Mesh, specs) -> "specs-like":
    """Input-batch layout: axis 0 over dp, everything else replicated."""
    lmap = logical_map(mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _batch_spec(mesh, lmap, s.shape)),
        specs,
    )


def _cache_spec(mesh: Mesh, lmap: dict, shape: tuple,
                global_batch: int) -> P:
    dp, tp = lmap["dp"], lmap["tp"]
    entries = [None] * len(shape)
    if (shape and shape[0] == global_batch and dp
            and shape[0] % _axis_size(mesh, dp) == 0):
        entries[0] = _entry(dp)
    # canonical KV layout (B, S, H, hd): heads on tp
    if (len(shape) >= 4 and tp
            and shape[2] % _axis_size(mesh, tp) == 0):
        entries[2] = _entry(tp)
    return P(*entries)


def cache_shardings(mesh: Mesh, c_shapes, global_batch: int):
    """Serving-cache layout: batch over dp, KV heads over tp."""
    lmap = logical_map(mesh)
    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, _cache_spec(mesh, lmap, s.shape, global_batch)
        ),
        c_shapes,
    )
