"""Sharded checkpointing with elastic restore.

Layout: one ``.npz`` per host-shard plus a msgpack-free JSON manifest
(leaf paths, shapes, dtypes, step).  Leaves are saved *unsharded
logically* but written by shard slices, so a checkpoint written from an
N-host mesh restores onto an M-host mesh (elastic scaling: the restore
path re-shards to whatever mesh is active) — the mechanism behind both
fault recovery (restart on fewer hosts) and WSD-style continuous
pretraining.

Async save: the device->host copy happens at the step boundary; file
writes run on a background thread so training continues.
"""

from __future__ import annotations

import json
import os
import pathlib
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

# numpy's savez cannot represent bfloat16; store as uint16 + manifest tag
_BF16 = np.dtype(ml_dtypes.bfloat16)


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any, *, step: int = 0,
         async_write: bool = False) -> threading.Thread | None:
    """Write a checkpoint. Returns the writer thread if async."""
    p = pathlib.Path(path)
    p.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)   # device->host copy happens here, synchronously

    manifest = {
        "step": step,
        "leaves": {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in flat.items()
        },
    }

    def write():
        storable = {
            k.replace("/", "__"): (
                v.view(np.uint16) if v.dtype == _BF16 else v
            )
            for k, v in flat.items()
        }
        np.savez(p / "shard0.npz", **storable)
        tmp = p / "manifest.json.tmp"
        tmp.write_text(json.dumps(manifest))
        os.replace(tmp, p / "manifest.json")   # atomic commit

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(root: str) -> int | None:
    """Scan a checkpoint root for the newest complete checkpoint."""
    r = pathlib.Path(root)
    if not r.exists():
        return None
    steps = []
    for d in r.iterdir():
        if (d / "manifest.json").exists():
            try:
                steps.append(json.loads((d / "manifest.json").read_text())
                             ["step"])
            except Exception:
                continue
    return max(steps) if steps else None


def restore(path: str, like: Any, *, mesh=None, shardings: Any = None
            ) -> tuple[Any, int]:
    """Restore into the structure of ``like``; re-shard if asked.

    ``like`` may be a pytree of arrays or ShapeDtypeStructs.  When
    ``shardings`` (matching pytree of NamedSharding) is given the leaves
    are device_put to the *current* mesh — elastic restore onto a
    different host/device count.
    """
    p = pathlib.Path(path)
    manifest = json.loads((p / "manifest.json").read_text())
    data = np.load(p / "shard0.npz")
    flat = {k.replace("__", "/"): data[k] for k in data.files}

    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_like:
        key = "/".join(
            str(getattr(q, "key", getattr(q, "idx", getattr(q, "name", q))))
            for q in path_k
        )
        arr = flat[key]
        if manifest["leaves"][key]["dtype"] == "bfloat16":
            arr = arr.view(_BF16)
        expect = tuple(leaf.shape)
        assert tuple(arr.shape) == expect, (key, arr.shape, expect)
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out
    )
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, manifest["step"]
