"""Roofline-term extraction from compiled XLA artifacts.

Three terms per (arch x shape x mesh), all in seconds (per-step):

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

``cost_analysis()`` on an SPMD-partitioned module reports *per-device*
flops/bytes, so the "chips x" in the roofline denominators is already
applied.  Collective bytes are not in cost_analysis: we parse the
optimized HLO and sum the result-shape bytes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute op
(per-device, one-shot convention; ring-factor 2(n-1)/n refinements are
noted in EXPERIMENTS.md where they matter).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment's constants).
"""

from __future__ import annotations

import re
from typing import Any

V5E = {
    "peak_flops": 197e12,     # bf16 / chip
    "hbm_bw": 819e9,          # bytes/s / chip
    "ici_bw": 50e9,           # bytes/s / link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    size = _DTYPE_BYTES.get(dtype)
    if size is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * size


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, Any]:
    """Sum result bytes per collective kind from optimized HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result type(s) appear between '=' and the op name
        for kind in _COLLECTIVES:
            marker = f" {kind}("
            alt = f" {kind}-start("
            if marker in stripped or alt in stripped:
                eq = stripped.find("=")
                op_at = stripped.find(marker)
                if op_at < 0:
                    op_at = stripped.find(alt)
                if eq < 0 or op_at < eq:
                    continue
                result_sig = stripped[eq + 1: op_at]
                total = sum(
                    _shape_bytes(d, s)
                    for d, s in _SHAPE_RE.findall(result_sig)
                )
                out[kind] += total
                counts[kind] += 1
                break
    return {
        "bytes_by_kind": out,
        "counts_by_kind": counts,
        "total_bytes": sum(out.values()),
        "total_count": sum(counts.values()),
    }


def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca) if ca else {}


def analyze(compiled, *, n_chips: int, model_flops: float,
            jaxpr_costs: dict | None = None,
            hw: dict | None = None) -> dict:
    """Roofline report dict for one compiled executable.

    ``jaxpr_costs`` (from ``repro.tools.jaxpr_cost``) provides the
    scan-corrected global FLOPs/bytes; XLA's cost_analysis (which counts
    loop bodies once) is retained for cross-reference only.  Collective
    bytes come from the optimized HLO with while-trip-count correction
    (``repro.tools.hlo_collectives``).
    """
    hw = hw or V5E
    cost = _cost_dict(compiled)
    xla_flops_dev = float(cost.get("flops", 0.0))
    xla_bytes_dev = float(cost.get("bytes accessed", 0.0))

    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    from repro.tools.hlo_collectives import parse_collectives
    coll = parse_collectives(hlo)

    if jaxpr_costs is not None:
        flops_dev = jaxpr_costs["flops"] / n_chips
        bytes_dev = jaxpr_costs["bytes"] / n_chips
    else:
        flops_dev = xla_flops_dev
        bytes_dev = xla_bytes_dev

    compute_s = flops_dev / hw["peak_flops"]
    memory_s = bytes_dev / hw["hbm_bw"]
    collective_s = coll["total_bytes"] / hw["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    total_hlo_flops = flops_dev * n_chips

    mem_an = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem_an = {
                k: int(getattr(ma, k))
                for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                )
                if hasattr(ma, k)
            }
    except Exception:
        pass

    return {
        "n_chips": n_chips,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "xla_flops_per_device": xla_flops_dev,
        "xla_bytes_per_device": xla_bytes_dev,
        "collectives": coll,
        "terms_seconds": terms,
        "dominant": dominant,
        "bound_seconds": bound_s,
        "model_flops": model_flops,
        "useful_flops_ratio": (
            model_flops / total_hlo_flops if total_hlo_flops else 0.0
        ),
        "mfu_at_bound": (
            model_flops / (n_chips * hw["peak_flops"] * bound_s)
            if bound_s else 0.0
        ),
        "memory_analysis": mem_an,
    }


def model_flops_for(cfg, shape) -> float:
    """6·N_active·tokens for training, 2·N_active·tokens for inference."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one new token per sequence
    return 2.0 * n * shape.global_batch
