"""Scan-aware FLOP/byte accounting from jaxprs.

XLA's ``HloCostAnalysis`` counts while-loop bodies exactly once, which
under-reports any scan-over-layers / microbatch-accumulation /
kv-chunked program by the product of trip counts (verified empirically
in tests/test_roofline.py).  This walker computes costs from the jaxpr,
where every ``scan`` carries its ``length`` and remat recompute appears
explicitly in the backward scan body, so FLOPs are exact for
matmul-dominated programs.

Byte accounting is a *pre-fusion upper bound*: every eqn contributes
(operands + outputs), except indexed ops (gather / dynamic-slice /
scatter / dynamic-update-slice) which contribute only the slices they
actually touch.  XLA fusion removes elementwise intermediate traffic,
so the true HBM traffic lies between ``params+IO`` and this bound; the
roofline table reports the bound and flags memory terms accordingly.
"""

from __future__ import annotations

import math

import jax
import numpy as np

_CONTAINER_PARAM_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")

# trip-count multiplier for while loops with data-dependent exit: callers
# can override per call-site via `while_trip_hint`.
DEFAULT_WHILE_TRIPS = 1


def _aval_bytes(aval) -> int:
    if not hasattr(aval, "shape"):
        return 0
    return int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize \
        if aval.shape else aval.dtype.itemsize


def _aval_elems(aval) -> int:
    if not hasattr(aval, "shape"):
        return 0
    return int(np.prod(aval.shape, dtype=np.int64)) if aval.shape else 1


def _dot_flops(eqn) -> int:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = math.prod(lhs.shape[i] for i in lb) if lb else 1
    k = math.prod(lhs.shape[i] for i in lc) if lc else 1
    m = math.prod(
        d for i, d in enumerate(lhs.shape) if i not in lc and i not in lb
    )
    n = math.prod(
        d for i, d in enumerate(rhs.shape) if i not in rc and i not in rb
    )
    return 2 * batch * m * n * k


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    kernel_elems = int(np.prod(rhs.shape, dtype=np.int64))
    out_spatial = int(np.prod(out.shape, dtype=np.int64))
    # rough: 2 * out_elems * (kernel per output element)
    return 2 * out_spatial * kernel_elems // max(rhs.shape[-1], 1)


def jaxpr_cost(jaxpr, *, while_trip_hint: int = DEFAULT_WHILE_TRIPS) -> dict:
    """Returns {"flops": float, "bytes": float, "by_prim": {...}}."""
    by_prim: dict[str, float] = {}

    def add(prim: str, f: float):
        by_prim[prim] = by_prim.get(prim, 0.0) + f

    def walk(jx, mult: float) -> tuple[float, float]:
        flops = 0.0
        byts = 0.0
        for eqn in jx.eqns:
            name = eqn.primitive.name
            in_bytes = sum(_aval_bytes(v.aval) for v in eqn.invars)
            out_bytes = sum(_aval_bytes(v.aval) for v in eqn.outvars)

            if name == "dot_general":
                f = _dot_flops(eqn) * mult
                flops += f
                byts += (in_bytes + out_bytes) * mult
                add("dot_general", f)
            elif name in ("conv_general_dilated",):
                f = _conv_flops(eqn) * mult
                flops += f
                byts += (in_bytes + out_bytes) * mult
                add("conv", f)
            elif name == "scan":
                length = eqn.params["length"]
                sub_f, sub_b = walk(eqn.params["jaxpr"].jaxpr,
                                    mult * length)
                flops += sub_f
                byts += sub_b
            elif name == "while":
                sub_f, sub_b = walk(eqn.params["body_jaxpr"].jaxpr,
                                    mult * while_trip_hint)
                flops += sub_f
                byts += sub_b
            elif name == "cond":
                branch_costs = [
                    walk(b.jaxpr, mult) for b in eqn.params["branches"]
                ]
                fmax = max(c[0] for c in branch_costs)
                bmax = max(c[1] for c in branch_costs)
                flops += fmax
                byts += bmax
            elif any(k in eqn.params for k in _CONTAINER_PARAM_KEYS):
                key = next(
                    k for k in _CONTAINER_PARAM_KEYS if k in eqn.params
                )
                sub = eqn.params[key]
                sub_jx = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                sub_f, sub_b = walk(sub_jx, mult)
                flops += sub_f
                byts += sub_b
            elif name in ("gather", "dynamic_slice"):
                # touches only the gathered slice
                idx_bytes = sum(
                    _aval_bytes(v.aval) for v in eqn.invars[1:]
                )
                byts += (2 * out_bytes + idx_bytes) * mult
            elif name in ("dynamic_update_slice",):
                upd = _aval_bytes(eqn.invars[1].aval)
                byts += 2 * upd * mult
            elif name in ("scatter", "scatter-add", "scatter_add"):
                upd = sum(_aval_bytes(v.aval) for v in eqn.invars[1:])
                byts += 2 * upd * mult
            else:
                # elementwise / reduction / layout: 1 flop per output elem
                f = sum(_aval_elems(v.aval) for v in eqn.outvars) * mult
                flops += f
                byts += (in_bytes + out_bytes) * mult
                add("elementwise", f)
        return flops, byts

    core = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    flops, byts = walk(core, 1.0)
    return {"flops": flops, "bytes": byts, "by_prim": by_prim}


def trace_cost(fn, *args, while_trip_hint: int = 1, **kwargs) -> dict:
    """Trace ``fn`` with ShapeDtypeStructs and account its jaxpr."""
    jx = jax.make_jaxpr(fn)(*args, **kwargs)
    return jaxpr_cost(jx, while_trip_hint=while_trip_hint)
