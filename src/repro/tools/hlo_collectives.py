"""Collective-byte accounting from optimized HLO with loop correction.

GSPMD inserts collectives during compilation, so they are only visible
in ``compiled.as_text()`` — but collectives inside while-loop bodies
(our scan-over-layers / microbatch loops) execute trip-count-many times
while appearing once in the text.  XLA annotates most loops with
``backend_config={"known_trip_count":{"n":...}}``; this parser builds
the computation call graph (while bodies/conditions, fusions, calls),
propagates multipliers from ENTRY, and sums result-shape bytes of every
collective op weighted by its computation's multiplier.
"""

from __future__ import annotations

import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(sig):
        sz = _DTYPE_BYTES.get(dtype)
        if sz is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * sz
    return total


def parse_collectives(hlo_text: str,
                      default_while_trips: int = 1) -> dict[str, Any]:
    """Loop-corrected per-device collective bytes by kind."""
    # 1. split into computations and find ENTRY
    comp_lines: dict[str, list[str]] = {}
    entry = None
    current = None
    for raw in hlo_text.splitlines():
        stripped = raw.strip()
        # computation headers: "[ENTRY ]%name (args...) -> result {"
        # (args may contain nested parens, so match by structure not regex)
        if stripped.endswith("{") and "->" in stripped and (
            stripped.startswith("%") or stripped.startswith("ENTRY")
        ):
            toks = stripped.split()
            name_tok = toks[1] if toks[0] == "ENTRY" else toks[0]
            current = name_tok.lstrip("%")
            comp_lines[current] = []
            if stripped.startswith("ENTRY"):
                entry = current
            continue
        if current is not None:
            comp_lines[current].append(stripped)

    # 2. edges: computation -> [(callee, multiplier_factor)]
    edges: dict[str, list[tuple[str, int]]] = {c: [] for c in comp_lines}
    for comp, lines in comp_lines.items():
        for ln in lines:
            if " while(" in ln:
                trip = _TRIP_RE.search(ln)
                n = int(trip.group(1)) if trip else default_while_trips
                b = _BODY_RE.search(ln)
                c = _COND_RE.search(ln)
                if b:
                    edges[comp].append((b.group(1), n))
                if c:
                    edges[comp].append((c.group(1), n + 1))
            else:
                cm = _CALLS_RE.search(ln)
                if cm:
                    edges[comp].append((cm.group(1), 1))

    # 3. propagate multipliers from ENTRY (call graph is a DAG)
    mult: dict[str, float] = {c: 0.0 for c in comp_lines}
    if entry is None and comp_lines:
        entry = next(iter(comp_lines))
    if entry is not None:
        mult[entry] = 1.0
        # simple fixpoint (DAG depth is small)
        for _ in range(64):
            changed = False
            for comp, outs in edges.items():
                for callee, factor in outs:
                    if callee not in mult:
                        continue
                    cand = mult[comp] * factor
                    if cand > mult[callee]:
                        mult[callee] = cand
                        changed = True
            if not changed:
                break

    # 4. sum collective result bytes x multiplier
    # (computations the propagation failed to reach count with mult 1:
    # undercounting silently would hide collective cost)
    bytes_by_kind = {k: 0.0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    unreached = 0
    for comp, lines in comp_lines.items():
        m = mult.get(comp, 1.0)
        if m == 0.0:
            m = 1.0
            unreached += 1
        for ln in lines:
            for kind in COLLECTIVES:
                if f" {kind}(" in ln or f" {kind}-start(" in ln:
                    eq = ln.find("=")
                    at = ln.find(f" {kind}")
                    if eq < 0 or at < eq:
                        continue
                    b = _shape_bytes(ln[eq + 1: at])
                    bytes_by_kind[kind] += b * m
                    counts[kind] += 1
                    break

    return {
        "bytes_by_kind": bytes_by_kind,
        "counts_by_kind": counts,
        "total_bytes": sum(bytes_by_kind.values()),
        "total_count": sum(counts.values()),
        "unreached_computations": unreached,
    }
