"""Render the dry-run JSON artifacts into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.tools.report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import json
import pathlib


def load_cells(d: pathlib.Path) -> list[dict]:
    cells = []
    for f in sorted(d.glob("*.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(cells: list[dict], mesh: str = "16x16") -> str:
    rows = [
        "| cell | mode | compute | memory | collective | dominant | "
        "useful FLOPs | MFU@bound |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if "skipped" in c:
            rows.append(
                f"| {c['cell']} | — | — | — | — | — | — | {c['skipped']} |"
            )
            continue
        t = c["terms_seconds"]
        rows.append(
            f"| {c['arch']} × {c['shape']} | {c['mode']} "
            f"| {fmt_s(t['compute'])} | {fmt_s(t['memory'])} "
            f"| {fmt_s(t['collective'])} | **{c['dominant']}** "
            f"| {c['useful_flops_ratio']*100:.0f}% "
            f"| {c['mfu_at_bound']*100:.1f}% |"
        )
    return "\n".join(rows)


def dryrun_table(cells: list[dict]) -> str:
    rows = [
        "| cell | mesh | status | compile (s) | per-dev FLOPs | "
        "per-dev bytes | collective bytes | arg GB (global) | temp GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if "skipped" in c:
            rows.append(
                f"| {c['cell']} | {c.get('mesh','')} | SKIP: {c['skipped']}"
                " | | | | | | |"
            )
            continue
        ma = c.get("memory_analysis") or {}
        rows.append(
            f"| {c['arch']} × {c['shape']} | {c['mesh']} | OK "
            f"| {c['compile_seconds']} "
            f"| {c['flops_per_device']:.2e} | {c['bytes_per_device']:.2e} "
            f"| {c['collectives']['total_bytes']:.2e} "
            f"| {ma.get('argument_size_in_bytes', 0)/2**30:.0f} "
            f"| {ma.get('temp_size_in_bytes', 0)/2**30:.0f} |"
        )
    return "\n".join(rows)


def summary(cells: list[dict]) -> dict:
    ok = [c for c in cells if "terms_seconds" in c]
    skip = [c for c in cells if "skipped" in c]
    worst = sorted(
        (c for c in ok if c["mesh"] == "16x16"),
        key=lambda c: c["mfu_at_bound"],
    )
    most_coll = sorted(
        (c for c in ok if c["mesh"] == "16x16"),
        key=lambda c: -c["terms_seconds"]["collective"],
    )
    return {
        "n_ok": len(ok), "n_skip": len(skip),
        "worst_mfu": [c["cell"] for c in worst[:5]],
        "most_collective": [c["cell"] for c in most_coll[:5]],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    cells = load_cells(pathlib.Path(args.dir))
    print("## Dry-run\n")
    print(dryrun_table(cells))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(cells, args.mesh))
    print("\n## Summary\n")
    print(json.dumps(summary(cells), indent=2))


if __name__ == "__main__":
    main()
