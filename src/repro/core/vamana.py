"""BQ-native Vamana graph construction (QuIVer §3.2 / §4.1).

Two-stage batch construction, adapted from the paper's lock-based
concurrency to pure-functional SPMD:

* **Stage 0 — bulk pre-installation**: all signatures are computed in one
  embarrassingly-parallel pass (``repro.kernels.binarize``) and the flat
  adjacency table is allocated once (``(N, R + slack)`` int32).
* **Stage 1 — chunked concurrent linking**: nodes are processed in chunks
  of ~256.  Each chunk runs `vmap`-batched beam searches against the
  frozen current graph, alpha-prunes its candidate pools *in BQ space*,
  writes forward edges, and scatter-appends reverse edges.  Rows that
  overflow the degree bound R are re-pruned (batched) during periodic
  consolidation — the functional analogue of the paper's per-node
  spin-locked re-prune, amortized exactly like DiskANN's.

The device-side chunk ops are jitted once per (shape, param) signature;
the host driver is a plain Python loop (this is how real accelerator
fleets drive construction too — host orchestrates, device crunches).

The chunk-level graph surgery itself lives in ``repro.core.linking`` —
shared, mask-aware primitives with one owner, so the streaming
subsystem (``repro.stream``) inserts against a live graph with exactly
the operations this batch builder uses.  The wrappers here jit with a
*static* backend (arrays are frozen for the whole build); streaming
jits its own wrappers over traced arrays.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bq, linking
from repro.core.metric import MetricBackend

BIG = jnp.float32(3.0e38)


@dataclasses.dataclass(frozen=True)
class BuildParams:
    m: int = 32                  # paper: max degree 2m
    ef_construction: int = 128
    alpha: float = 1.2
    chunk: int = 256
    prune_pool: int = 128        # candidates entering alpha-prune
    reverse_slack: int = 8       # adjacency headroom for reverse appends
    consolidate_every: int = 8   # chunks between overflow re-prunes
    passes: int = 1              # full insertion passes over the data
    seed: int = 0
    beam_expand: int = 1         # beam expansion width L during build

    @property
    def r(self) -> int:          # out-degree bound
        return 2 * self.m

    @property
    def r_total(self) -> int:    # adjacency row width incl. slack
        return self.r + self.reverse_slack


# ---------------------------------------------------------------------------
# device-side chunk ops
# ---------------------------------------------------------------------------


def _init_graph(n: int, params: BuildParams, seed: int):
    key = jax.random.PRNGKey(seed)
    rand = jax.random.randint(key, (n, params.r), 0, n, dtype=jnp.int32)
    ids = jnp.arange(n, dtype=jnp.int32)[:, None]
    rand = jnp.where(rand == ids, (rand + 1) % n, rand)
    pad = jnp.full((n, params.reverse_slack), -1, dtype=jnp.int32)
    adj = jnp.concatenate([rand, pad], axis=1)
    deg = jnp.full((n,), params.r, dtype=jnp.int32)
    return adj, deg


@functools.partial(
    jax.jit,
    static_argnames=("backend", "ef", "pool", "r", "alpha", "n", "expand"),
)
def _chunk_forward(
    adj, chunk_ids, medoid, *,
    backend: MetricBackend, ef, pool, r, alpha, n, expand=1,
):
    """Beam-search a chunk of nodes and alpha-prune their candidates."""
    return linking.chunk_forward(
        backend, adj, chunk_ids, medoid,
        ef=ef, pool=pool, r=r, alpha=alpha, n=n, expand=expand,
    )


@functools.partial(jax.jit, static_argnames=("r_total",))
def _apply_forward(adj, deg, chunk_ids, fwd_ids, *, r_total):
    return linking.apply_forward(adj, deg, chunk_ids, fwd_ids,
                                 r_total=r_total)


@functools.partial(jax.jit, static_argnames=("r_total",))
def _reverse_append(adj, deg, chunk_ids, fwd_ids, *, r_total):
    """Scatter-append reverse edges src -> tgt with capacity drop."""
    return linking.reverse_append(adj, deg, chunk_ids, fwd_ids,
                                  r_total=r_total)


@functools.partial(
    jax.jit, static_argnames=("backend", "r", "alpha", "r_total")
)
def _consolidate_rows(
    adj, deg, row_ids, *, backend: MetricBackend, r, alpha, r_total
):
    """Re-prune overflowing rows (deg > r) back down to <= r edges."""
    return linking.consolidate_rows(
        backend, adj, deg, row_ids, r=r, alpha=alpha, r_total=r_total
    )


@functools.partial(jax.jit, static_argnames=("backend", "chunk"))
def _medoid(backend: MetricBackend, centroid_repr, *, chunk: int):
    return linking.medoid_scan(backend, centroid_repr, chunk=chunk)


# ---------------------------------------------------------------------------
# host driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BuildStats:
    seconds: float = 0.0
    chunks: int = 0
    consolidations: int = 0
    reverse_edges_added: int = 0
    mean_hops: float = 0.0


def build_graph(
    backend: MetricBackend,
    params: BuildParams,
    *,
    medoid: int | None = None,
    verbose: bool = False,
) -> tuple[jnp.ndarray, int, BuildStats]:
    """Construct a Vamana graph in ``backend``'s metric space.

    Returns (adjacency (N, R+slack) int32, medoid id, stats).
    """
    t0 = time.perf_counter()
    n = backend.n
    stats = BuildStats()
    adj, deg = _init_graph(n, params, params.seed)

    if medoid is None:
        # centroid representation: encode the float mean when available,
        # else use node 0 as the entry point.
        centroid = _centroid_repr(backend)
        medoid = int(_medoid(backend, centroid, chunk=4096)) \
            if centroid is not None else 0
    medoid_arr = jnp.int32(medoid)

    rng = np.random.default_rng(params.seed)
    chunk = params.chunk
    hops_acc = []

    for pass_idx in range(params.passes):
        order = rng.permutation(n).astype(np.int32)
        pad = (-len(order)) % chunk
        if pad:
            order = np.concatenate([order, order[:pad]])
        n_chunks = len(order) // chunk

        for ci in range(n_chunks):
            chunk_ids = jnp.asarray(order[ci * chunk:(ci + 1) * chunk])
            fwd_ids, fwd_dists, hops = _chunk_forward(
                adj, chunk_ids, medoid_arr,
                backend=backend,
                ef=params.ef_construction,
                pool=params.prune_pool,
                r=params.r,
                alpha=params.alpha,
                n=n,
                expand=params.beam_expand,
            )
            adj, deg = _apply_forward(
                adj, deg, chunk_ids, fwd_ids, r_total=params.r_total
            )
            adj, deg, added = _reverse_append(
                adj, deg, chunk_ids, fwd_ids, r_total=params.r_total
            )
            stats.chunks += 1
            stats.reverse_edges_added += int(added)
            hops_acc.append(float(hops.mean()))

            if (ci + 1) % params.consolidate_every == 0:
                adj, deg, did = _consolidate_overflow(
                    adj, deg, backend, params, chunk
                )
                stats.consolidations += did
            if verbose and ci % 16 == 0:
                print(
                    f"[vamana] pass {pass_idx} chunk {ci}/{n_chunks} "
                    f"hops={hops_acc[-1]:.1f}"
                )

    adj, deg, did = _consolidate_overflow(adj, deg, backend, params, chunk)
    stats.consolidations += did
    stats.seconds = time.perf_counter() - t0
    stats.mean_hops = float(np.mean(hops_acc)) if hops_acc else 0.0
    return adj, int(medoid), stats


def _centroid_repr(backend) -> Any:
    """Best-effort centroid query representation for medoid selection."""
    if hasattr(backend, "vectors"):
        c = backend.vectors.mean(axis=0, keepdims=True)
        return backend.encode_queries(c)[0]
    if hasattr(backend, "sigs"):
        # decode to ±1/±2 levels, average, re-encode
        levels = bq.decode_levels(backend.sigs)
        c = levels.mean(axis=0, keepdims=True)
        return backend.encode_queries(c)[0]
    return None


def _consolidate_overflow(adj, deg, backend, params, batch):
    """Host-side: find rows with deg > R, prune them in fixed batches."""
    deg_host = np.asarray(deg)
    overflow = np.nonzero(deg_host > params.r)[0].astype(np.int32)
    if overflow.size == 0:
        return adj, deg, 0
    pad = (-overflow.size) % batch
    if pad:
        overflow = np.concatenate([overflow, overflow[:pad]])
    for i in range(0, overflow.size, batch):
        rows = jnp.asarray(overflow[i:i + batch])
        adj, deg = _consolidate_rows(
            adj, deg, rows,
            backend=backend,
            r=params.r,
            alpha=params.alpha,
            r_total=params.r_total,
        )
    return adj, deg, 1
