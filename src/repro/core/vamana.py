"""BQ-native Vamana graph construction (QuIVer §3.2 / §4.1).

Two-stage batch construction, adapted from the paper's lock-based
concurrency to pure-functional SPMD:

* **Stage 0 — bulk pre-installation**: all signatures are computed in one
  embarrassingly-parallel pass (``repro.kernels.binarize``) and the flat
  adjacency table is allocated once (``(N, R + slack)`` int32).
* **Stage 1 — chunked concurrent linking**: nodes are processed in chunks
  of ~256.  Each chunk runs `vmap`-batched beam searches against the
  frozen current graph, alpha-prunes its candidate pools *in BQ space*,
  writes forward edges, and scatter-appends reverse edges.  Rows that
  overflow the degree bound R are re-pruned (batched) during periodic
  consolidation — the functional analogue of the paper's per-node
  spin-locked re-prune, amortized exactly like DiskANN's.

The device-side chunk ops are jitted once per (shape, param) signature;
the host driver is a plain Python loop (this is how real accelerator
fleets drive construction too — host orchestrates, device crunches).

The chunk-level graph surgery itself lives in ``repro.core.linking`` —
shared, mask-aware primitives with one owner, so the streaming
subsystem (``repro.stream``) inserts against a live graph with exactly
the operations this batch builder uses.  The wrappers here jit with a
*static* backend (arrays are frozen for the whole build); streaming
jits its own wrappers over traced arrays.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bq, linking
from repro.core.metric import MetricBackend

BIG = jnp.float32(3.0e38)


@dataclasses.dataclass(frozen=True)
class BuildParams:
    m: int = 32                  # paper: max degree 2m
    ef_construction: int = 128
    alpha: float = 1.2
    chunk: int = 256
    prune_pool: int = 128        # candidates entering alpha-prune
    reverse_slack: int = 8       # adjacency headroom for reverse appends
    consolidate_every: int = 8   # chunks between overflow re-prunes
    passes: int = 1              # full insertion passes over the data
    seed: int = 0
    beam_expand: int = 1         # beam expansion width L during build
    # IVF-seeded construction (DESIGN.md §13): seed each chunk's prune
    # pool from the node's top-p coarse lists instead of a full-graph
    # beam search — the dominant per-chunk cost drops from
    # O(hops·ef·R) graph traversal to one list scan + one gather,
    # making build time near-linear in N.  ``ivf_lists=0`` means the
    # partition's own √N default.
    ivf_candidates: bool = False
    ivf_lists: int = 0

    @property
    def r(self) -> int:          # out-degree bound
        return 2 * self.m

    @property
    def r_total(self) -> int:    # adjacency row width incl. slack
        return self.r + self.reverse_slack


# ---------------------------------------------------------------------------
# device-side chunk ops
# ---------------------------------------------------------------------------


def _init_graph(n: int, params: BuildParams, seed: int):
    key = jax.random.PRNGKey(seed)
    rand = jax.random.randint(key, (n, params.r), 0, n, dtype=jnp.int32)
    ids = jnp.arange(n, dtype=jnp.int32)[:, None]
    rand = jnp.where(rand == ids, (rand + 1) % n, rand)
    pad = jnp.full((n, params.reverse_slack), -1, dtype=jnp.int32)
    adj = jnp.concatenate([rand, pad], axis=1)
    deg = jnp.full((n,), params.r, dtype=jnp.int32)
    return adj, deg


@functools.partial(
    jax.jit,
    static_argnames=("backend", "ef", "pool", "r", "alpha", "n", "expand"),
)
def _chunk_forward(
    adj, chunk_ids, medoid, *,
    backend: MetricBackend, ef, pool, r, alpha, n, expand=1,
):
    """Beam-search a chunk of nodes and alpha-prune their candidates."""
    return linking.chunk_forward(
        backend, adj, chunk_ids, medoid,
        ef=ef, pool=pool, r=r, alpha=alpha, n=n, expand=expand,
    )


@functools.partial(
    jax.jit,
    static_argnames=("backend", "scan", "pool", "r", "alpha", "probes"),
)
def _chunk_forward_ivf(
    chunk_ids, rand_ids, sig_words, cent_words, list_ids, *,
    backend: MetricBackend, scan, pool, r, alpha, probes,
):
    """IVF-seeded chunk linking: top-p lists feed the prune pool.

    Replaces the beam search of :func:`_chunk_forward`: each chunk
    node's candidates are the members of its ``probes`` nearest coarse
    lists (scored in the build metric), topped up with ``rand_ids`` —
    random far candidates whose long edges the alpha-criterion can
    keep, preserving navigability that purely local list members would
    lose.  Duplicates between the two pools die in the prune (a
    duplicate is distance-0 from its selected twin).  Hops are 0 by
    construction — there is no traversal.
    """
    from repro.ivf import search as ivf_search

    pad_row = (chunk_ids < 0)[:, None]
    safe_chunk = jnp.maximum(chunk_ids, 0)
    reprs = backend.query_repr(safe_chunk)
    top = ivf_search.top_lists(scan, sig_words[safe_chunk], cent_words,
                               probes)
    mem, d = ivf_search.list_candidates(backend, reprs, list_ids, top)
    drop = (mem == chunk_ids[:, None]) | pad_row
    mem = jnp.where(drop, -1, mem)
    d = jnp.where(drop, BIG, d)
    n_rand = rand_ids.shape[1]
    neg, pos = jax.lax.top_k(-d, max(pool - n_rand, 1))
    local_ids = jnp.take_along_axis(mem, pos, axis=-1)
    local_dists = -neg

    rand_ok = (rand_ids >= 0) & (rand_ids != chunk_ids[:, None]) & ~pad_row
    rd = backend.dist_many(reprs, jnp.maximum(rand_ids, 0), rand_ok)
    cids = jnp.concatenate(
        [local_ids, jnp.where(rand_ok, rand_ids, -1)], axis=-1
    )
    cdists = jnp.concatenate(
        [local_dists, jnp.where(rand_ok, rd, BIG)], axis=-1
    )
    pw = backend.pairwise(jnp.maximum(cids, 0))
    fwd_ids, fwd_dists, pool_sizes, occluded = (
        linking.alpha_prune_stats_batch(
            cids, cdists, pw, r=r, alpha=alpha
        )
    )
    hops = jnp.zeros(chunk_ids.shape, dtype=jnp.int32)
    return fwd_ids, fwd_dists, hops, pool_sizes, occluded


@functools.partial(jax.jit, static_argnames=("r_total",))
def _apply_forward(adj, deg, chunk_ids, fwd_ids, *, r_total):
    return linking.apply_forward(adj, deg, chunk_ids, fwd_ids,
                                 r_total=r_total)


@functools.partial(jax.jit, static_argnames=("r_total",))
def _reverse_append(adj, deg, chunk_ids, fwd_ids, *, r_total):
    """Scatter-append reverse edges src -> tgt with capacity drop."""
    return linking.reverse_append(adj, deg, chunk_ids, fwd_ids,
                                  r_total=r_total)


@functools.partial(
    jax.jit, static_argnames=("backend", "r", "alpha", "r_total")
)
def _consolidate_rows(
    adj, deg, row_ids, *, backend: MetricBackend, r, alpha, r_total
):
    """Re-prune overflowing rows (deg > r) back down to <= r edges."""
    return linking.consolidate_rows(
        backend, adj, deg, row_ids, r=r, alpha=alpha, r_total=r_total
    )


@functools.partial(jax.jit, static_argnames=("backend", "chunk"))
def _medoid(backend: MetricBackend, centroid_repr, *, chunk: int):
    return linking.medoid_scan(backend, centroid_repr, chunk=chunk)


# ---------------------------------------------------------------------------
# host driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BuildStats:
    seconds: float = 0.0
    chunks: int = 0
    consolidations: int = 0
    reverse_edges_added: int = 0
    mean_hops: float = 0.0
    # build telemetry (DESIGN.md §15): per-chunk means, averaged over
    # the whole build; occluded is the total candidate count the
    # alpha-criterion covered away
    pool_occupancy: float = 0.0    # mean pool fill / prune_pool
    survivor_ratio: float = 0.0    # mean survivors / pool
    occluded_total: int = 0


def build_graph(
    backend: MetricBackend,
    params: BuildParams,
    *,
    medoid: int | None = None,
    ivf=None,
    verbose: bool = False,
) -> tuple[jnp.ndarray, int, BuildStats]:
    """Construct a Vamana graph in ``backend``'s metric space.

    With ``params.ivf_candidates`` each chunk's prune pool is seeded
    from the node's top-p coarse lists (:mod:`repro.ivf`) instead of a
    full-graph beam search — near-linear build.  ``ivf`` is the
    :class:`~repro.ivf.IVFPartition` to seed from; when None it is
    built here from the backend's signatures (requires a
    signature-bearing build metric).

    Build stats accumulate **on device** (one lazy add per chunk) and
    materialize once at the end — the host loop never blocks on a
    device→host sync per chunk.

    Returns (adjacency (N, R+slack) int32, medoid id, stats).
    """
    t0 = time.perf_counter()
    n = backend.n
    stats = BuildStats()
    adj, deg = _init_graph(n, params, params.seed)

    if medoid is None:
        # centroid representation: encode the float mean when available,
        # else use node 0 as the entry point.
        centroid = _centroid_repr(backend)
        medoid = int(_medoid(backend, centroid, chunk=4096)) \
            if centroid is not None else 0
    medoid_arr = jnp.int32(medoid)

    scan = sig_words = probes = n_rand = None
    if params.ivf_candidates:
        if not hasattr(backend, "sigs"):
            raise ValueError(
                "ivf_candidates needs a signature-bearing build metric "
                "(bq2/bq1/adc); float32 builds must beam-search"
            )
        if ivf is None:
            from repro.ivf import build_partition
            ivf = build_partition(
                backend.sigs, n_lists=params.ivf_lists or None,
                seed=params.seed,
            )
        from repro.kernels import dispatch
        route = getattr(backend, "route", None)
        scan = dispatch.list_scan_ops(backend.sigs.dim, route=route).scan
        sig_words = backend.sigs.words
        probes = ivf.build_probes
        n_rand = max(1, min(params.prune_pool // 4, params.r))

    rng = np.random.default_rng(params.seed)
    chunk = params.chunk
    # device-side accumulators: eager jnp adds are async-dispatched, so
    # the loop enqueues work without a per-chunk host round trip
    added_acc = jnp.int32(0)
    hops_sum = jnp.float32(0.0)
    n_hop_chunks = 0
    occl_acc = jnp.int32(0)
    # per-chunk device scalars (async-dispatched; one stack+sync at the
    # end feeds the quiver_build_* histograms without blocking the loop)
    pool_occ_chunks: list = []
    surv_chunks: list = []
    occl_chunks: list = []

    for pass_idx in range(params.passes):
        order = rng.permutation(n).astype(np.int32)
        pad = (-len(order)) % chunk
        if pad:
            order = np.concatenate([order, order[:pad]])
        n_chunks = len(order) // chunk

        for ci in range(n_chunks):
            chunk_ids = jnp.asarray(order[ci * chunk:(ci + 1) * chunk])
            if params.ivf_candidates:
                rand_ids = jnp.asarray(rng.integers(
                    0, n, size=(chunk, n_rand), dtype=np.int32
                ))
                fwd_ids, fwd_dists, hops, pool_sizes, occluded = \
                    _chunk_forward_ivf(
                    chunk_ids, rand_ids, sig_words,
                    ivf.cent_words, ivf.list_ids,
                    backend=backend,
                    scan=scan,
                    pool=params.prune_pool,
                    r=params.r,
                    alpha=params.alpha,
                    probes=probes,
                )
            else:
                fwd_ids, fwd_dists, hops, pool_sizes, occluded = \
                    _chunk_forward(
                    adj, chunk_ids, medoid_arr,
                    backend=backend,
                    ef=params.ef_construction,
                    pool=params.prune_pool,
                    r=params.r,
                    alpha=params.alpha,
                    n=n,
                    expand=params.beam_expand,
                )
            adj, deg = _apply_forward(
                adj, deg, chunk_ids, fwd_ids, r_total=params.r_total
            )
            adj, deg, added = _reverse_append(
                adj, deg, chunk_ids, fwd_ids, r_total=params.r_total
            )
            stats.chunks += 1
            added_acc = added_acc + added
            hops_sum = hops_sum + hops.mean()
            n_hop_chunks += 1
            real = chunk_ids >= 0
            denom = jnp.maximum(real.sum(), 1).astype(jnp.float32)
            pool_mean = jnp.where(real, pool_sizes, 0).sum() / denom
            surv = jnp.where(
                real, (fwd_ids >= 0).sum(-1), 0
            ).sum() / jnp.maximum(
                jnp.where(real, pool_sizes, 0).sum(), 1
            ).astype(jnp.float32)
            occl = jnp.where(real, occluded, 0).sum()
            occl_acc = occl_acc + occl
            pool_occ_chunks.append(pool_mean / params.prune_pool)
            surv_chunks.append(surv)
            occl_chunks.append(occl)

            if (ci + 1) % params.consolidate_every == 0:
                adj, deg, did = _consolidate_overflow(
                    adj, deg, backend, params, chunk
                )
                stats.consolidations += did
            if verbose and ci % 16 == 0:
                # verbose is the debug path: the sync it forces is the
                # point (live numbers), so it is allowed to block
                print(
                    f"[vamana] pass {pass_idx} chunk {ci}/{n_chunks} "
                    f"hops={float(hops.mean()):.1f}"
                )

    adj, deg, did = _consolidate_overflow(adj, deg, backend, params, chunk)
    stats.consolidations += did
    # single materialization of the device accumulators
    stats.reverse_edges_added = int(added_acc)
    stats.mean_hops = (
        float(hops_sum) / n_hop_chunks if n_hop_chunks else 0.0
    )
    stats.occluded_total = int(occl_acc)
    if pool_occ_chunks:
        pool_occ = np.asarray(jnp.stack(pool_occ_chunks))
        surv = np.asarray(jnp.stack(surv_chunks))
        occl = np.asarray(jnp.stack(occl_chunks))
        stats.pool_occupancy = float(pool_occ.mean())
        stats.survivor_ratio = float(surv.mean())
        # per-chunk distributions land in the default registry (core
        # imports obs lazily — same discipline as the index reports)
        from repro.obs.metrics import get_default_registry
        reg = get_default_registry()
        reg.histogram(
            "quiver_build_pool_occupancy",
            "per-chunk prune-pool fill ratio at alpha-prune entry",
            buckets=(0.1, 0.25, 0.5, 0.75, 0.9, 1.0), window=0,
        ).observe_many(pool_occ)
        reg.histogram(
            "quiver_build_survivor_ratio",
            "per-chunk alpha-prune survivors / pool",
            buckets=(0.05, 0.1, 0.25, 0.5, 0.75, 1.0), window=0,
        ).observe_many(surv)
        reg.histogram(
            "quiver_build_occluded",
            "per-chunk candidates occluded by the alpha-criterion",
            buckets=(1.0, 1e1, 1e2, 1e3, 1e4, 1e5), window=0,
        ).observe_many(occl)
    stats.seconds = time.perf_counter() - t0
    return adj, int(medoid), stats


def _centroid_repr(backend) -> Any:
    """Best-effort centroid query representation for medoid selection."""
    if hasattr(backend, "vectors"):
        c = backend.vectors.mean(axis=0, keepdims=True)
        return backend.encode_queries(c)[0]
    if hasattr(backend, "sigs"):
        # decode to ±1/±2 levels, average, re-encode
        levels = bq.decode_levels(backend.sigs)
        c = levels.mean(axis=0, keepdims=True)
        return backend.encode_queries(c)[0]
    return None


def _consolidate_overflow(adj, deg, backend, params, batch):
    """Host-side: find rows with deg > R, prune them in fixed batches."""
    deg_host = np.asarray(deg)
    overflow = np.nonzero(deg_host > params.r)[0].astype(np.int32)
    if overflow.size == 0:
        return adj, deg, 0
    pad = (-overflow.size) % batch
    if pad:
        overflow = np.concatenate([overflow, overflow[:pad]])
    for i in range(0, overflow.size, batch):
        rows = jnp.asarray(overflow[i:i + batch])
        adj, deg = _consolidate_rows(
            adj, deg, rows,
            backend=backend,
            r=params.r,
            alpha=params.alpha,
            r_total=params.r_total,
        )
    return adj, deg, 1
