"""BQ-native Vamana graph construction (QuIVer §3.2 / §4.1).

Two-stage batch construction, adapted from the paper's lock-based
concurrency to pure-functional SPMD:

* **Stage 0 — bulk pre-installation**: all signatures are computed in one
  embarrassingly-parallel pass (``repro.kernels.binarize``) and the flat
  adjacency table is allocated once (``(N, R + slack)`` int32).
* **Stage 1 — chunked concurrent linking**: nodes are processed in chunks
  of ~256.  Each chunk runs `vmap`-batched beam searches against the
  frozen current graph, alpha-prunes its candidate pools *in BQ space*,
  writes forward edges, and scatter-appends reverse edges.  Rows that
  overflow the degree bound R are re-pruned (batched) during periodic
  consolidation — the functional analogue of the paper's per-node
  spin-locked re-prune, amortized exactly like DiskANN's.

The device-side chunk ops are jitted once per (shape, param) signature;
the host driver is a plain Python loop (this is how real accelerator
fleets drive construction too — host orchestrates, device crunches).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bq
from repro.core.beam import INF, batched_beam_search
from repro.core.metric import MetricBackend
from repro.core.prune import alpha_prune_batch

BIG = jnp.float32(3.0e38)


@dataclasses.dataclass(frozen=True)
class BuildParams:
    m: int = 32                  # paper: max degree 2m
    ef_construction: int = 128
    alpha: float = 1.2
    chunk: int = 256
    prune_pool: int = 128        # candidates entering alpha-prune
    reverse_slack: int = 8       # adjacency headroom for reverse appends
    consolidate_every: int = 8   # chunks between overflow re-prunes
    passes: int = 1              # full insertion passes over the data
    seed: int = 0
    beam_expand: int = 1         # beam expansion width L during build

    @property
    def r(self) -> int:          # out-degree bound
        return 2 * self.m

    @property
    def r_total(self) -> int:    # adjacency row width incl. slack
        return self.r + self.reverse_slack


# ---------------------------------------------------------------------------
# device-side chunk ops
# ---------------------------------------------------------------------------


def _init_graph(n: int, params: BuildParams, seed: int):
    key = jax.random.PRNGKey(seed)
    rand = jax.random.randint(key, (n, params.r), 0, n, dtype=jnp.int32)
    ids = jnp.arange(n, dtype=jnp.int32)[:, None]
    rand = jnp.where(rand == ids, (rand + 1) % n, rand)
    pad = jnp.full((n, params.reverse_slack), -1, dtype=jnp.int32)
    adj = jnp.concatenate([rand, pad], axis=1)
    deg = jnp.full((n,), params.r, dtype=jnp.int32)
    return adj, deg


@functools.partial(
    jax.jit,
    static_argnames=("backend", "ef", "pool", "r", "alpha", "n", "expand"),
)
def _chunk_forward(
    adj, chunk_ids, medoid, *,
    backend: MetricBackend, ef, pool, r, alpha, n, expand=1,
):
    """Beam-search a chunk of nodes and alpha-prune their candidates."""
    queries = backend.query_repr(chunk_ids)
    res = batched_beam_search(
        queries, adj, medoid, dist_fn=backend.dist_fn, ef=ef, n=n,
        expand=expand,
    )
    # remove self from each candidate list, keep the best ``pool``
    is_self = res.ids == chunk_ids[:, None]
    cids = jnp.where(is_self, -1, res.ids)
    cdists = jnp.where(is_self, BIG, res.dists)
    order = jnp.argsort(cdists, axis=-1)[:, :pool]
    cids = jnp.take_along_axis(cids, order, axis=-1)
    cdists = jnp.take_along_axis(cdists, order, axis=-1)

    safe = jnp.maximum(cids, 0)
    pw = backend.pairwise(safe)
    fwd_ids, fwd_dists = alpha_prune_batch(
        cids, cdists, pw, r=r, alpha=alpha
    )
    return fwd_ids, fwd_dists, res.hops


@functools.partial(jax.jit, static_argnames=("r_total",))
def _apply_forward(adj, deg, chunk_ids, fwd_ids, *, r_total):
    rows = jnp.full(
        (fwd_ids.shape[0], r_total), -1, dtype=jnp.int32
    ).at[:, : fwd_ids.shape[1]].set(fwd_ids)
    adj = adj.at[chunk_ids].set(rows)
    deg = deg.at[chunk_ids].set((fwd_ids >= 0).sum(-1).astype(jnp.int32))
    return adj, deg


@functools.partial(jax.jit, static_argnames=("r_total",))
def _reverse_append(adj, deg, chunk_ids, fwd_ids, *, r_total):
    """Scatter-append reverse edges src -> tgt with capacity drop."""
    n = adj.shape[0]
    b, r = fwd_ids.shape
    tgt = fwd_ids.reshape(-1)                                   # (B*R,)
    src = jnp.repeat(chunk_ids, r)                              # (B*R,)
    valid = tgt >= 0
    tgt_safe = jnp.where(valid, tgt, 0)

    # skip proposals whose edge already exists
    exists = (adj[tgt_safe] == src[:, None]).any(-1)
    valid = valid & ~exists

    # rank of each proposal within its target group (sorted by target)
    key_sort = jnp.where(valid, tgt, n + 1)
    order = jnp.argsort(key_sort)
    tgt_s, src_s, valid_s = key_sort[order], src[order], valid[order]
    idx = jnp.arange(tgt_s.shape[0])
    boundary = jnp.concatenate(
        [jnp.array([True]), tgt_s[1:] != tgt_s[:-1]]
    )
    seg_start = jax.lax.cummax(jnp.where(boundary, idx, 0))
    rank = idx - seg_start

    tgt_w = jnp.where(valid_s, tgt_s, n)       # n == trash row
    slot = deg[jnp.minimum(tgt_w, n - 1)] + rank
    ok = valid_s & (slot < r_total)
    tgt_w = jnp.where(ok, tgt_w, n)
    slot_w = jnp.where(ok, slot, r_total)      # r_total == trash col

    adj_pad = jnp.full((n + 1, r_total + 1), -1, dtype=jnp.int32)
    adj_pad = adj_pad.at[:n, :r_total].set(adj)
    adj_pad = adj_pad.at[tgt_w, slot_w].set(
        jnp.where(ok, src_s, -1).astype(jnp.int32)
    )
    adj = adj_pad[:n, :r_total]
    deg = deg.at[jnp.minimum(tgt_w, n - 1)].add(
        ok.astype(jnp.int32) * (tgt_w < n)
    )
    return adj, deg, ok.sum()


@functools.partial(
    jax.jit, static_argnames=("backend", "r", "alpha", "r_total")
)
def _consolidate_rows(
    adj, deg, row_ids, *, backend: MetricBackend, r, alpha, r_total
):
    """Re-prune overflowing rows (deg > r) back down to <= r edges."""
    rows = adj[row_ids]                                  # (B, r_total)
    safe = jnp.maximum(rows, 0)
    # distance of each neighbour to the row's own node
    target_repr = backend.query_repr(row_ids)
    dists = backend.dist_many(target_repr, safe, rows >= 0)
    dists = jnp.where(rows >= 0, dists, BIG)
    pw = backend.pairwise(safe)
    new_ids, _ = alpha_prune_batch(rows, dists, pw, r=r, alpha=alpha)
    new_rows = jnp.full(
        (rows.shape[0], r_total), -1, dtype=jnp.int32
    ).at[:, :r].set(new_ids)
    adj = adj.at[row_ids].set(new_rows)
    deg = deg.at[row_ids].set((new_ids >= 0).sum(-1).astype(jnp.int32))
    return adj, deg


@functools.partial(jax.jit, static_argnames=("backend", "chunk"))
def _medoid(backend: MetricBackend, centroid_repr, *, chunk: int):
    n = backend.n
    n_pad = ((n + chunk - 1) // chunk) * chunk
    ids = jnp.arange(n_pad, dtype=jnp.int32) % n

    def scan_fn(best, block_ids):
        d = backend.dist_fn(
            centroid_repr, block_ids, jnp.ones_like(block_ids, jnp.bool_)
        )
        i = jnp.argmin(d)
        cand = (d[i], block_ids[i])
        better = cand[0] < best[0]
        return (
            jnp.where(better, cand[0], best[0]),
            jnp.where(better, cand[1], best[1]),
        ), None

    (best_d, best_i), _ = jax.lax.scan(
        scan_fn,
        (BIG, jnp.int32(0)),
        ids.reshape(-1, chunk),
    )
    return best_i


# ---------------------------------------------------------------------------
# host driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BuildStats:
    seconds: float = 0.0
    chunks: int = 0
    consolidations: int = 0
    reverse_edges_added: int = 0
    mean_hops: float = 0.0


def build_graph(
    backend: MetricBackend,
    params: BuildParams,
    *,
    medoid: int | None = None,
    verbose: bool = False,
) -> tuple[jnp.ndarray, int, BuildStats]:
    """Construct a Vamana graph in ``backend``'s metric space.

    Returns (adjacency (N, R+slack) int32, medoid id, stats).
    """
    t0 = time.perf_counter()
    n = backend.n
    stats = BuildStats()
    adj, deg = _init_graph(n, params, params.seed)

    if medoid is None:
        # centroid representation: encode the float mean when available,
        # else use node 0 as the entry point.
        centroid = _centroid_repr(backend)
        medoid = int(_medoid(backend, centroid, chunk=4096)) \
            if centroid is not None else 0
    medoid_arr = jnp.int32(medoid)

    rng = np.random.default_rng(params.seed)
    chunk = params.chunk
    hops_acc = []

    for pass_idx in range(params.passes):
        order = rng.permutation(n).astype(np.int32)
        pad = (-len(order)) % chunk
        if pad:
            order = np.concatenate([order, order[:pad]])
        n_chunks = len(order) // chunk

        for ci in range(n_chunks):
            chunk_ids = jnp.asarray(order[ci * chunk:(ci + 1) * chunk])
            fwd_ids, fwd_dists, hops = _chunk_forward(
                adj, chunk_ids, medoid_arr,
                backend=backend,
                ef=params.ef_construction,
                pool=params.prune_pool,
                r=params.r,
                alpha=params.alpha,
                n=n,
                expand=params.beam_expand,
            )
            adj, deg = _apply_forward(
                adj, deg, chunk_ids, fwd_ids, r_total=params.r_total
            )
            adj, deg, added = _reverse_append(
                adj, deg, chunk_ids, fwd_ids, r_total=params.r_total
            )
            stats.chunks += 1
            stats.reverse_edges_added += int(added)
            hops_acc.append(float(hops.mean()))

            if (ci + 1) % params.consolidate_every == 0:
                adj, deg, did = _consolidate_overflow(
                    adj, deg, backend, params, chunk
                )
                stats.consolidations += did
            if verbose and ci % 16 == 0:
                print(
                    f"[vamana] pass {pass_idx} chunk {ci}/{n_chunks} "
                    f"hops={hops_acc[-1]:.1f}"
                )

    adj, deg, did = _consolidate_overflow(adj, deg, backend, params, chunk)
    stats.consolidations += did
    stats.seconds = time.perf_counter() - t0
    stats.mean_hops = float(np.mean(hops_acc)) if hops_acc else 0.0
    return adj, int(medoid), stats


def _centroid_repr(backend) -> Any:
    """Best-effort centroid query representation for medoid selection."""
    if hasattr(backend, "vectors"):
        c = backend.vectors.mean(axis=0, keepdims=True)
        return backend.encode_queries(c)[0]
    if hasattr(backend, "sigs"):
        # decode to ±1/±2 levels, average, re-encode
        levels = bq.decode_levels(backend.sigs)
        c = levels.mean(axis=0, keepdims=True)
        return backend.encode_queries(c)[0]
    return None


def _consolidate_overflow(adj, deg, backend, params, batch):
    """Host-side: find rows with deg > R, prune them in fixed batches."""
    deg_host = np.asarray(deg)
    overflow = np.nonzero(deg_host > params.r)[0].astype(np.int32)
    if overflow.size == 0:
        return adj, deg, 0
    pad = (-overflow.size) % batch
    if pad:
        overflow = np.concatenate([overflow, overflow[:pad]])
    for i in range(0, overflow.size, batch):
        rows = jnp.asarray(overflow[i:i + batch])
        adj, deg = _consolidate_rows(
            adj, deg, rows,
            backend=backend,
            r=params.r,
            alpha=params.alpha,
            r_total=params.r_total,
        )
    return adj, deg, 1
