"""Non-graph baselines + ground truth (paper §5.1 "Exact Flat baselines")."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _normalize(x):
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


@functools.partial(jax.jit, static_argnames=("k",))
def _flat_block(queries, base, k):
    sims = queries @ base.T
    scores, ids = jax.lax.top_k(sims, k)
    return ids, scores


def flat_search(
    vectors: jnp.ndarray,
    queries: jnp.ndarray,
    k: int = 10,
    *,
    query_batch: int = 128,
) -> tuple[np.ndarray, np.ndarray]:
    """Exact brute-force cosine top-k (ground truth / Flat baseline)."""
    base = _normalize(jnp.asarray(vectors, jnp.float32))
    queries = _normalize(jnp.asarray(queries, jnp.float32))
    all_ids, all_scores = [], []
    for s in range(0, queries.shape[0], query_batch):
        ids, scores = _flat_block(queries[s:s + query_batch], base, k)
        all_ids.append(np.asarray(ids))
        all_scores.append(np.asarray(scores))
    return np.concatenate(all_ids), np.concatenate(all_scores)


def recall_at_k(pred_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Mean |pred ∩ true| / k over queries (Recall@k, the paper's metric)."""
    k = true_ids.shape[1]
    hits = 0
    for p, t in zip(pred_ids, true_ids):
        hits += len(set(p[:k].tolist()) & set(t.tolist()))
    return hits / (k * len(true_ids))
