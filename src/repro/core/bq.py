"""2-bit Sign-Magnitude binary quantization (QuIVer §3.1).

Encoding (training-free, codebook-free):
    tau_v      = mean(|v_1| ... |v_D|)            (per-vector threshold)
    pos_i      = 1[v_i > 0]                        (sign bit)
    strong_i   = 1[|v_i| > tau_v]                  (magnitude bit)

Signatures are bit-packed into uint32 words, struct-of-arrays: a packed
signature matrix has shape (N, 2*W) where W = ceil(D/32); columns [0, W)
hold the sign words and [W, 2W) the magnitude words.  Padding bits beyond
D are zero in both planes and are masked out of every distance term, so
distances are exactly the Table-1 weighted sums over the D real dims.

Symmetric distance (QuIVer Table 1): classify each dim by sign agreement
and magnitude strength:

    category              same sign   diff sign
    both strong              +4          -4
    one strong one weak      +2          -2
    both weak                +1          -1

similarity = sum of category weights; distance = -similarity (ordering-
equivalent to the paper's weighted Hamming form, kept in int32).

Everything here is pure jnp and doubles as the oracle for the Pallas
kernels in ``repro.kernels``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32
_U32 = jnp.uint32


def n_words(dim: int) -> int:
    """Words per bit-plane for a ``dim``-dimensional vector."""
    return (dim + WORD_BITS - 1) // WORD_BITS


def valid_mask(dim: int) -> jnp.ndarray:
    """(W,) uint32 mask with ones at bit positions < dim."""
    w = n_words(dim)
    bit_index = np.arange(w * WORD_BITS).reshape(w, WORD_BITS)
    mask_bits = (bit_index < dim).astype(np.uint64)
    weights = (1 << np.arange(WORD_BITS, dtype=np.uint64))
    words = (mask_bits * weights).sum(axis=1).astype(np.uint32)
    return jnp.asarray(words)


class Signature(NamedTuple):
    """Packed 2-bit Sign-Magnitude signatures (struct-of-arrays)."""

    words: jnp.ndarray  # (..., 2*W) uint32 — [pos words | strong words]
    dim: int            # original float dimensionality D

    @property
    def w(self) -> int:
        return self.words.shape[-1] // 2

    @property
    def pos(self) -> jnp.ndarray:
        return self.words[..., : self.w]

    @property
    def strong(self) -> jnp.ndarray:
        return self.words[..., self.w:]

    @property
    def nbytes_per_vector(self) -> int:
        return 2 * self.w * 4


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack a (..., D) boolean array into (..., ceil(D/32)) uint32 words.

    Bit d of the vector lands at bit (d % 32) of word (d // 32)
    (little-endian bit order within each word).
    """
    *lead, d = bits.shape
    w = n_words(d)
    pad = w * WORD_BITS - d
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros((*lead, pad), dtype=bits.dtype)], axis=-1
        )
    grouped = bits.reshape(*lead, w, WORD_BITS).astype(_U32)
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=_U32))
    return (grouped * weights).sum(axis=-1).astype(_U32)


def unpack_bits(words: jnp.ndarray, dim: int) -> jnp.ndarray:
    """Inverse of :func:`pack_bits` → (..., dim) bool."""
    shifts = jnp.arange(WORD_BITS, dtype=_U32)
    bits = (words[..., None] >> shifts) & _U32(1)
    bits = bits.reshape(*words.shape[:-1], words.shape[-1] * WORD_BITS)
    return bits[..., :dim].astype(jnp.bool_)


def sign_magnitude_bits(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Float vectors → (pos, strong) boolean planes, each (..., D)."""
    absx = jnp.abs(x)
    tau = jnp.mean(absx, axis=-1, keepdims=True)
    pos = x > 0
    strong = absx > tau
    return pos, strong


@functools.partial(jax.jit, static_argnames=())
def _encode_words(x: jnp.ndarray) -> jnp.ndarray:
    pos, strong = sign_magnitude_bits(x)
    return jnp.concatenate([pack_bits(pos), pack_bits(strong)], axis=-1)


def encode(x: jnp.ndarray) -> Signature:
    """Encode float vectors (..., D) → packed 2-bit SM :class:`Signature`."""
    return Signature(words=_encode_words(x), dim=x.shape[-1])


def decode_levels(sig: Signature) -> jnp.ndarray:
    """Reconstruction levels ±1 / ±2 (weak/strong), (..., D) float32.

    Used by the ADC baseline: the absolute scale is irrelevant for
    ranking, only the 1:2 weak:strong ratio matters.
    """
    pos = unpack_bits(sig.pos, sig.dim).astype(jnp.float32)
    strong = unpack_bits(sig.strong, sig.dim).astype(jnp.float32)
    return (2.0 * pos - 1.0) * (1.0 + strong)


def _popcount(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.population_count(x)


def symmetric_similarity_words(
    pa: jnp.ndarray,
    sa: jnp.ndarray,
    pb: jnp.ndarray,
    sb: jnp.ndarray,
    mask: jnp.ndarray,
) -> jnp.ndarray:
    """Table-1 weighted similarity from word arrays.

    All four word arrays broadcast against each other over leading dims;
    last dim is W words. ``mask`` is the (W,) valid-bit mask. Returns an
    int32 similarity with shape = broadcast(leading dims).
    """
    same = (~(pa ^ pb)) & mask
    diff = pa ^ pb  # padding bits are 0 in both planes -> diff pad bits = 0
    both_strong = sa & sb
    one_strong = sa ^ sb
    both_weak = (~(sa | sb)) & mask

    def pc(v):
        return _popcount(v).astype(jnp.int32).sum(axis=-1)

    sim = (
        4 * pc(same & both_strong)
        + 2 * pc(same & one_strong)
        + pc(same & both_weak)
        - 4 * pc(diff & both_strong)
        - 2 * pc(diff & one_strong)
        - pc(diff & both_weak)
    )
    return sim


def symmetric_distance(a: Signature, b: Signature) -> jnp.ndarray:
    """Symmetric 2-bit SM distance = -similarity, int32.

    Broadcasts over leading dims: e.g. a=(Q, 2W) vs b=(N, 2W) requires the
    caller to expand dims; see :func:`pairwise_distance` for the batched
    (Q, N) form.
    """
    assert a.dim == b.dim
    mask = valid_mask(a.dim)
    sim = symmetric_similarity_words(a.pos, a.strong, b.pos, b.strong, mask)
    return -sim


def pairwise_distance(queries: Signature, base: Signature) -> jnp.ndarray:
    """(Q, 2W) signatures vs (N, 2W) signatures → (Q, N) int32 distances."""
    assert queries.dim == base.dim
    mask = valid_mask(queries.dim)
    qp = queries.pos[..., :, None, :]
    qs = queries.strong[..., :, None, :]
    bp = base.pos[..., None, :, :]
    bs = base.strong[..., None, :, :]
    return -symmetric_similarity_words(qp, qs, bp, bs, mask)


def hamming_distance_1bit(a: Signature, b: Signature) -> jnp.ndarray:
    """1-bit SimHash Hamming distance (sign plane only), int32."""
    assert a.dim == b.dim
    x = a.pos ^ b.pos
    return _popcount(x).astype(jnp.int32).sum(axis=-1)


def pairwise_hamming_1bit(queries: Signature, base: Signature) -> jnp.ndarray:
    x = queries.pos[..., :, None, :] ^ base.pos[..., None, :, :]
    return _popcount(x).astype(jnp.int32).sum(axis=-1)


def adc_distance(query_f32: jnp.ndarray, base: Signature) -> jnp.ndarray:
    """Asymmetric distance: full-precision query vs signatures.

    dist = -<q, decode(sig)> ; (Q, D) x (N, 2W) -> (Q, N) float32.
    The §3.3 ablation baseline ("why not ADC for navigation").
    """
    levels = decode_levels(base)  # (N, D)
    return -(query_f32 @ levels.T)


def distance_upper_bound(dim: int) -> int:
    """Max possible |distance| value: every dim both-strong mismatched."""
    return 4 * dim


def signature_bytes(n: int, dim: int) -> int:
    """Hot-path signature memory for n vectors (paper Table 2 accounting)."""
    return n * 2 * n_words(dim) * 4
