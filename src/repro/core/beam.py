"""Symmetric BQ beam search over a fixed-degree graph (QuIVer §3.3, stage 1).

Pure ``jax.lax`` control flow: a ``while_loop`` maintaining a sorted beam
of ``ef`` candidates, a per-query visited array, and an expanded mask.
Each iteration expands the ``expand`` nearest unexpanded beam entries and
folds their <= expand*R neighbours into the beam with **one** batched
distance evaluation — the TPU-friendly formulation of the paper's
per-hop XOR+popcount loop.  ``expand=1`` is the classic greedy
best-first traversal (bit-for-bit identical to the pre-refactor code);
wider ``expand`` trades hops for batch width, which is what a Pallas/VPU
distance kernel wants: an ``(L*R,)`` distance batch per hop amortizes
kernel launch and HBM streaming far better than ``(R,)``.

The distance function is pluggable so the same traversal serves the
paper's symmetric 2-bit navigation, the 1-bit Hamming baseline, the ADC
ablation and the float32 Vamana reference build — any backend registered
in ``repro.core.metric``.

Two-mask semantics (DESIGN.md §8/§9): the beam splits *navigation*
from *results* under two independent, composable masks —

* ``node_valid`` (tombstones, streaming subsystem): dead nodes are
  still traversed — their edges keep the graph connected between
  deletions and consolidation, exactly as in FreshDiskANN — but never
  returned;
* ``result_valid`` (filtered search, ``repro.filter``): non-matching
  nodes are traversed freely — the predicate restricts what may be
  *returned*, never where the beam may *walk* — so filtered search
  over a mutable index composes with deletes for free.

Either mask alone, or their conjunction, drives one parallel
valid-only result list maintained inside the traversal; with both
``None`` the loop carries no result list at all and is bit-for-bit the
unmasked search.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

INF = jnp.float32(3.0e38)

# dist_fn(query_repr, ids (k,), valid (k,) bool) -> (k,) float32
DistFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]


def batch_bucket(n: int, query_batch: int) -> int:
    """Padded size for a (possibly partial) query batch.

    Tail batches are padded up a small fixed ladder (8, 32, 128, ...,
    ``query_batch``) instead of tracing :func:`batched_beam_search` once
    per distinct tail size: the trace count is bounded by the ladder
    length while tiny batches never pay a full ``query_batch`` of
    padding.  The one owner of the ladder — every search surface
    (core, streaming, filtered, adaptive escalation) pads through it.
    """
    b = 8
    while b < n and b < query_batch:
        b *= 4
    return min(b, query_batch)


def pad_rows(arr: jnp.ndarray, size: int) -> jnp.ndarray:
    """Pad axis 0 to ``size`` rows by repeating the last row (the
    padded rows run real searches whose outputs are sliced away)."""
    pad = size - arr.shape[0]
    if pad <= 0:
        return arr
    return jnp.concatenate(
        [arr, jnp.repeat(arr[-1:], pad, axis=0)], axis=0
    )


@functools.partial(jax.jit, static_argnames=("k", "neutral"))
def beam_margin(dists, k: int, neutral: float) -> jnp.ndarray:
    """Per-query top-k score margin of a beam result.

    ``dists`` is the ``(Q, ef)`` sorted (ascending, INF-padded) distance
    list of a :class:`BeamResult`; ``neutral`` is the navigation
    metric's zero-similarity distance (``MetricSpace.neutral_dist`` —
    e.g. ``4D`` for bq2, ``1.0`` for float32 cosine).  The margin is
    the k-th candidate's normalized score margin over that floor:

        margin = (neutral - d[k-1]) / neutral

    A query whose top candidates all score near the indifference point
    has *tight* margins — the quantized metric barely distinguishes its
    rerank pool from arbitrary points, which is the dominant per-query
    failure mode (margin-vs-recall correlation ~-0.9 on the amber-tier
    surrogates, DESIGN.md §10) — and its rerank pool should widen.
    Beams that found fewer than ``k`` valid candidates report -1
    (starved: escalation is the only way to fill the pool).  The
    escalation threshold is corpus-dependent; ``build(nav="auto")``
    calibrates it from the probe sample
    (``CompatibilityReport.margin_p30``).
    """
    dk = dists[..., k - 1]
    margin = (neutral - dk) / neutral
    return jnp.where(dk < INF / 2, margin, -1.0)


def escalated_search(run, reprs, queries, ef: int, *,
                     adaptive: bool, margin_thr: float, mult: int):
    """The adaptive-escalation driver shared by every search surface
    (one owner — ``QuIVerIndex.search`` and ``MutableQuIVerIndex.search``
    both delegate here; DESIGN.md §10).

    ``run(reprs, queries, ef, want_margin) -> (ids, scores, margins)``
    is the surface's batched base search (margins may be None when
    ``want_margin`` is False).  With ``adaptive``, queries whose
    :func:`beam_margin` falls below ``margin_thr`` re-run once with an
    ``mult``-times wider beam — widening the rerank candidate pool
    exactly for the tight-margin tail — and their rows are spliced
    back in place.
    """
    all_ids, all_scores, margins = run(reprs, queries, ef, adaptive)
    if adaptive and margins is not None:
        # margin telemetry (DESIGN.md §12): the per-query margin
        # distribution is the live recall-health signal, and the
        # escalated fraction is the cost it buys.  Lazy import — obs is
        # a leaf module, but core must stay importable without it warm.
        from repro.obs.metrics import get_default_registry
        reg = get_default_registry()
        reg.histogram(
            "quiver_beam_margin",
            "per-query normalized k-th-neighbor score margin",
            buckets=(-1.0, 0.0, 0.05, 0.1, 0.15, 0.2, 0.3, 0.5, 1.0),
            window=0,
        ).observe_many(np.asarray(margins, dtype=np.float64))
        esc = np.nonzero(margins < margin_thr)[0]
        if esc.size:
            reg.counter(
                "quiver_escalated_queries_total",
                "tight-margin queries re-run at the escalated stage",
                labels=("plan",),
            ).inc(int(esc.size), plan=f"legacy-ef{ef}x{mult}")
            take = jnp.asarray(esc.astype(np.int32))
            esc_ids, esc_scores, _ = run(
                reprs[take], queries[take], ef * mult, False
            )
            all_ids[esc] = esc_ids
            all_scores[esc] = esc_scores
    return all_ids, all_scores


class BeamResult(NamedTuple):
    ids: jnp.ndarray     # (ef,) int32, -1 padded, sorted by distance
    dists: jnp.ndarray   # (ef,) float32, INF padded
    hops: jnp.ndarray    # () int32 — number of expansion rounds performed
    evals: jnp.ndarray   # () int32 — fresh distance evaluations performed
    # navigation-path trace (DESIGN.md §15): live descent diagnostics
    # carried out of the jitted loop for the quiver_nav_* histograms
    descent: jnp.ndarray = jnp.float32(0.0)   # () entry dist - best nav dist
    stalls: jnp.ndarray = jnp.int32(0)        # () rounds w/o beam-best gain
    entry_rank: jnp.ndarray = jnp.int32(0)    # () nav dists beating entry


def _conjoin(node_valid, result_valid):
    """Combine the tombstone and predicate result masks (None == all
    valid); the single owner of the two-mask conjunction semantics."""
    if node_valid is not None and result_valid is not None:
        return node_valid & result_valid
    return node_valid if node_valid is not None else result_valid


def _merge_beam(ids, dists, expanded, new_ids, new_dists, ef):
    """Merge new candidates into the sorted beam, keep best ``ef``."""
    cat_ids = jnp.concatenate([ids, new_ids])
    cat_dists = jnp.concatenate([dists, new_dists])
    cat_exp = jnp.concatenate(
        [expanded, jnp.zeros(new_ids.shape, dtype=jnp.bool_)]
    )
    order = jnp.argsort(cat_dists)[:ef]
    return cat_ids[order], cat_dists[order], cat_exp[order]


def _merge_results(ids, dists, new_ids, new_dists, ef):
    """Merge live candidates into the sorted result list, keep best ``ef``."""
    cat_ids = jnp.concatenate([ids, new_ids])
    cat_dists = jnp.concatenate([dists, new_dists])
    order = jnp.argsort(cat_dists)[:ef]
    return cat_ids[order], cat_dists[order]


@functools.partial(
    jax.jit,
    static_argnames=(
        "dist_fn", "ef", "max_hops", "n", "expand", "max_evals"
    ),
)
def beam_search(
    query,
    adjacency: jnp.ndarray,   # (N, R) int32, -1 padded
    start: jnp.ndarray,       # () int32 entry point (medoid)
    *,
    dist_fn: DistFn,
    ef: int,
    n: int,
    max_hops: int = 0,
    expand: int = 1,
    max_evals: int = 0,
    node_valid: jnp.ndarray | None = None,     # (n,) bool live mask
    result_valid: jnp.ndarray | None = None,   # (n,) bool predicate mask
) -> BeamResult:
    """Best-first beam search from ``start`` toward ``query``.

    ``expand`` (the beam expansion width L) controls how many unexpanded
    beam entries are expanded per hop; each hop issues a single
    ``(expand * R,)`` distance batch.  ``expand=1`` reproduces greedy
    best-first search exactly.

    ``max_evals`` (0 = unlimited) stops expanding once that many fresh
    distance evaluations have been spent — the budget knob for
    recall-per-distance-evaluation comparisons across expansion widths.

    ``node_valid`` (optional) is the tombstone mask of a mutable index;
    ``result_valid`` (optional) is a filter-predicate match mask
    (``repro.filter``).  Under either (or both — they conjoin), beam
    *navigation* is unchanged: masked-out nodes are still expanded and
    their edges still route.  Only the returned ids/dists are drawn
    from a parallel valid-only result list, so tombstoned and
    non-matching nodes never surface.
    """
    # lowering counter (repro.plan.trace): this body only runs when jax
    # traces it, so the bump counts compilations, not calls.  Imported
    # lazily — trace time is after import time, and beam must not pull
    # the plan package in at module scope.
    from repro.plan.trace import note_trace
    note_trace("beam_search")
    r = adjacency.shape[1]
    max_hops = max_hops or (4 * ef + 128)
    assert 1 <= expand <= ef, (expand, ef)
    lr = expand * r
    res_valid = _conjoin(node_valid, result_valid)
    masked = res_valid is not None

    d0 = dist_fn(query, start[None], jnp.ones((1,), jnp.bool_))[0]
    ids = jnp.full((ef,), -1, dtype=jnp.int32).at[0].set(start)
    dists = jnp.full((ef,), INF, dtype=jnp.float32).at[0].set(d0)
    # padding entries are marked expanded so they are never picked
    expanded = jnp.ones((ef,), dtype=jnp.bool_).at[0].set(False)
    visited = jnp.zeros((n,), dtype=jnp.bool_).at[start].set(True)
    if masked:
        ok0 = res_valid[start]
        res_ids = jnp.full((ef,), -1, dtype=jnp.int32).at[0].set(
            jnp.where(ok0, start, -1)
        )
        res_dists = jnp.full((ef,), INF, dtype=jnp.float32).at[0].set(
            jnp.where(ok0, d0, INF)
        )
    else:
        res_ids = res_dists = None

    def cond(state):
        ids, dists, expanded, *_rest, hops, evals = state
        frontier = (~expanded) & (ids >= 0)
        go = frontier.any() & (hops < max_hops)
        if max_evals:
            go = go & (evals < max_evals)
        return go

    def body(state):
        if masked:
            ids, dists, expanded, res_ids, res_dists, visited, stalls, \
                hops, evals = state
        else:
            ids, dists, expanded, visited, stalls, hops, evals = state
        prev_best = dists[0]
        frontier = (~expanded) & (ids >= 0)
        # stable sort => tie-break by beam position, matching argmin at L=1
        picks = jnp.argsort(jnp.where(frontier, dists, INF))[:expand]
        valid_pick = frontier[picks]
        nodes = jnp.where(valid_pick, ids[picks], 0)
        expanded = expanded.at[picks].max(valid_pick)

        nbrs = adjacency[nodes].reshape(lr)          # (L*R,)
        valid = (nbrs >= 0) & jnp.repeat(valid_pick, r)
        nbrs_safe = jnp.where(valid, nbrs, 0)
        fresh = valid & ~visited[nbrs_safe]
        # duplicate neighbours within one batch: keep first occurrence only
        # (invalid slots get unique sentinels so they never alias node 0)
        dedup_key = jnp.where(valid, nbrs, -(jnp.arange(lr) + 1))
        first_occurrence = (
            dedup_key[None, :] == dedup_key[:, None]
        ).argmax(axis=1) == jnp.arange(lr)
        fresh = fresh & first_occurrence
        visited = visited.at[nbrs_safe].max(valid)

        nd = dist_fn(query, nbrs_safe, fresh)
        nd = jnp.where(fresh, nd, INF)
        new_ids = jnp.where(fresh, nbrs_safe, -1).astype(jnp.int32)
        ids, dists, expanded = _merge_beam(
            ids, dists, expanded, new_ids, nd, ef
        )
        evals = evals + fresh.sum().astype(jnp.int32)
        # a round that fails to improve the nav-beam best is a stall:
        # the walk is circling (or backtracking through worse frontier
        # entries) rather than descending — see DESIGN.md §15
        stalls = stalls + (~(dists[0] < prev_best)).astype(jnp.int32)
        if masked:
            live = fresh & res_valid[nbrs_safe]
            res_ids, res_dists = _merge_results(
                res_ids, res_dists,
                jnp.where(live, nbrs_safe, -1).astype(jnp.int32),
                jnp.where(live, nd, INF), ef,
            )
            return (ids, dists, expanded, res_ids, res_dists, visited,
                    stalls, hops + 1, evals)
        return ids, dists, expanded, visited, stalls, hops + 1, evals

    if masked:
        state = jax.lax.while_loop(
            cond, body,
            (ids, dists, expanded, res_ids, res_dists, visited,
             jnp.int32(0), jnp.int32(0), jnp.int32(1)),
        )
        _, nav_dists, _, res_ids, res_dists, _, stalls, hops, \
            evals = state
        return BeamResult(
            ids=res_ids, dists=res_dists, hops=hops, evals=evals,
            descent=d0 - nav_dists[0], stalls=stalls,
            entry_rank=(nav_dists < d0).sum().astype(jnp.int32),
        )

    ids, dists, expanded, visited, stalls, hops, evals = \
        jax.lax.while_loop(
            cond, body,
            (ids, dists, expanded, visited, jnp.int32(0), jnp.int32(0),
             jnp.int32(1)),
        )
    return BeamResult(
        ids=ids, dists=dists, hops=hops, evals=evals,
        descent=d0 - dists[0], stalls=stalls,
        entry_rank=(dists < d0).sum().astype(jnp.int32),
    )


def batched_beam_search(
    queries,
    adjacency: jnp.ndarray,
    start: jnp.ndarray,
    *,
    dist_fn: DistFn,
    ef: int,
    n: int,
    max_hops: int = 0,
    expand: int = 1,
    max_evals: int = 0,
    node_valid: jnp.ndarray | None = None,
    result_valid: jnp.ndarray | None = None,
) -> BeamResult:
    """vmap of :func:`beam_search` over a batch of queries.

    ``queries`` is whatever representation ``dist_fn`` consumes, batched on
    axis 0 (packed signature words for BQ navigation, float vectors for
    ADC / float32 navigation).  ``node_valid`` (tombstones) and
    ``result_valid`` (filter predicate), both shared across the batch,
    are the two result masks of :func:`beam_search`.
    """
    fn = functools.partial(
        beam_search,
        dist_fn=dist_fn,
        ef=ef,
        n=n,
        max_hops=max_hops,
        expand=expand,
        max_evals=max_evals,
    )
    res_valid = _conjoin(node_valid, result_valid)
    if res_valid is None:
        return jax.vmap(fn, in_axes=(0, None, None))(
            queries, adjacency, start
        )
    return jax.vmap(
        lambda q, adj, s, nv: fn(q, adj, s, node_valid=nv),
        in_axes=(0, None, None, None),
    )(queries, adjacency, start, res_valid)
