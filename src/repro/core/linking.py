"""Shared mask-aware Vamana linking primitives (QuIVer §4.1 + streaming).

One owner for the chunk-level graph surgery that both the batch builder
(``repro.core.vamana``) and the streaming subsystem (``repro.stream``)
perform: beam-search a chunk of nodes, alpha-prune their candidate
pools, install forward edges, scatter-append reverse edges, and re-prune
overflowing rows.  The batch builder wraps these in jitted functions
whose cache keys on a *static* backend (arrays frozen for the whole
build); the streaming subsystem jits its own wrappers that take the
mutable arrays as traced arguments and construct the registered backend
inside the trace — same primitives, no retrace per mutation.

Two forms of masking make the primitives streaming-safe while staying
bit-identical on the batch path:

* ``node_valid`` — the live/tombstone mask of a mutable index.  When
  given, beam-search candidates, re-prune pools and medoid scans are
  restricted to live nodes (dead nodes are still *traversed*, see
  ``repro.core.beam``).  ``None`` (the batch build) means all nodes.
* ``chunk_ids`` / ``row_ids`` may contain ``-1`` padding — streaming
  insert batches rarely fill a whole chunk, and padded entries must not
  touch the graph.  Scatters route padded rows to a trash row.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.beam import batched_beam_search
from repro.core.metric import MetricSpace
from repro.core.prune import alpha_prune_batch, alpha_prune_stats_batch

BIG = jnp.float32(3.0e38)


def chunk_forward(
    backend: MetricSpace,
    adj: jnp.ndarray,
    chunk_ids: jnp.ndarray,       # (B,) int32, -1 padded
    medoid: jnp.ndarray,
    *,
    ef: int,
    pool: int,
    r: int,
    alpha: float,
    n: int,
    expand: int = 1,
    node_valid: jnp.ndarray | None = None,
):
    """Beam-search a chunk of nodes and alpha-prune their candidates.

    Returns ((B, r) forward ids, (B, r) dists, (B,) hops, (B,) prune
    pool sizes, (B,) occlusion counts).  The last two are the build
    telemetry DESIGN.md §15 aggregates — how full each node's candidate
    pool was when it entered the alpha-prune and how many candidates
    the diversity criterion occluded (same trace; reductions over masks
    the prune already computes).  Rows whose ``chunk_ids`` entry is -1
    come back all -1 / 0.
    """
    pad_row = (chunk_ids < 0)[:, None]
    queries = backend.query_repr(jnp.maximum(chunk_ids, 0))
    res = batched_beam_search(
        queries, adj, medoid, dist_fn=backend.dist_fn, ef=ef, n=n,
        expand=expand, node_valid=node_valid,
    )
    # remove self from each candidate list, keep the best ``pool``
    is_self = res.ids == chunk_ids[:, None]
    drop = is_self | pad_row
    cids = jnp.where(drop, -1, res.ids)
    cdists = jnp.where(drop, BIG, res.dists)
    order = jnp.argsort(cdists, axis=-1)[:, :pool]
    cids = jnp.take_along_axis(cids, order, axis=-1)
    cdists = jnp.take_along_axis(cdists, order, axis=-1)

    safe = jnp.maximum(cids, 0)
    pw = backend.pairwise(safe)
    fwd_ids, fwd_dists, pool_sizes, occluded = alpha_prune_stats_batch(
        cids, cdists, pw, r=r, alpha=alpha
    )
    return fwd_ids, fwd_dists, res.hops, pool_sizes, occluded


def scatter_rows(adj, deg, row_ids, edge_ids, *, r_total):
    """Overwrite ``row_ids``' adjacency rows with ``edge_ids``.

    ``edge_ids`` (B, <= r_total) is right-padded to the full row width;
    degree counters are reset to the count of valid edges.  ``row_ids``
    entries of -1 (chunk padding) scatter into a trash row and leave
    the graph untouched.
    """
    n = adj.shape[0]
    rows = jnp.full(
        (edge_ids.shape[0], r_total), -1, dtype=jnp.int32
    ).at[:, : edge_ids.shape[1]].set(edge_ids)
    tgt = jnp.where(row_ids >= 0, row_ids, n)
    adj_pad = jnp.concatenate(
        [adj, jnp.full((1, r_total), -1, dtype=jnp.int32)], axis=0
    ).at[tgt].set(rows)
    deg_pad = jnp.concatenate(
        [deg, jnp.zeros((1,), dtype=jnp.int32)]
    ).at[tgt].set((edge_ids >= 0).sum(-1).astype(jnp.int32))
    return adj_pad[:n], deg_pad[:n]


def apply_forward(adj, deg, chunk_ids, fwd_ids, *, r_total):
    """Install forward-edge rows for a chunk (padded ids -> trash row)."""
    return scatter_rows(adj, deg, chunk_ids, fwd_ids, r_total=r_total)


def reverse_append(adj, deg, chunk_ids, fwd_ids, *, r_total):
    """Scatter-append reverse edges src -> tgt with capacity drop."""
    n = adj.shape[0]
    b, r = fwd_ids.shape
    tgt = fwd_ids.reshape(-1)                                   # (B*R,)
    src = jnp.repeat(chunk_ids, r)                              # (B*R,)
    valid = (tgt >= 0) & (src >= 0)
    tgt_safe = jnp.where(valid, tgt, 0)

    # skip proposals whose edge already exists
    exists = (adj[tgt_safe] == src[:, None]).any(-1)
    valid = valid & ~exists

    # rank of each proposal within its target group (sorted by target)
    key_sort = jnp.where(valid, tgt, n + 1)
    order = jnp.argsort(key_sort)
    tgt_s, src_s, valid_s = key_sort[order], src[order], valid[order]
    idx = jnp.arange(tgt_s.shape[0])
    boundary = jnp.concatenate(
        [jnp.array([True]), tgt_s[1:] != tgt_s[:-1]]
    )
    seg_start = jax.lax.cummax(jnp.where(boundary, idx, 0))
    rank = idx - seg_start

    tgt_w = jnp.where(valid_s, tgt_s, n)       # n == trash row
    slot = deg[jnp.minimum(tgt_w, n - 1)] + rank
    ok = valid_s & (slot < r_total)
    tgt_w = jnp.where(ok, tgt_w, n)
    slot_w = jnp.where(ok, slot, r_total)      # r_total == trash col

    adj_pad = jnp.full((n + 1, r_total + 1), -1, dtype=jnp.int32)
    adj_pad = adj_pad.at[:n, :r_total].set(adj)
    adj_pad = adj_pad.at[tgt_w, slot_w].set(
        jnp.where(ok, src_s, -1).astype(jnp.int32)
    )
    adj = adj_pad[:n, :r_total]
    deg = deg.at[jnp.minimum(tgt_w, n - 1)].add(
        ok.astype(jnp.int32) * (tgt_w < n)
    )
    return adj, deg, ok.sum()


def consolidate_rows(
    backend: MetricSpace,
    adj,
    deg,
    row_ids,                      # (B,) int32, -1 padded
    *,
    r: int,
    alpha: float,
    r_total: int,
    node_valid: jnp.ndarray | None = None,
):
    """Re-prune rows back down to <= r edges (deg overflow / repair).

    With ``node_valid``, dead neighbours are dropped from the pool
    before pruning.  Padded ``row_ids`` entries leave the graph alone.
    """
    safe_row_ids = jnp.maximum(row_ids, 0)
    rows = adj[safe_row_ids]                             # (B, r_total)
    ok = rows >= 0
    if node_valid is not None:
        ok = ok & node_valid[jnp.maximum(rows, 0)]
    rows = jnp.where(ok, rows, -1)
    safe = jnp.maximum(rows, 0)
    # distance of each neighbour to the row's own node
    target_repr = backend.query_repr(safe_row_ids)
    dists = backend.dist_many(target_repr, safe, ok)
    dists = jnp.where(ok, dists, BIG)
    pw = backend.pairwise(safe)
    new_ids, _ = alpha_prune_batch(rows, dists, pw, r=r, alpha=alpha)
    return scatter_rows(adj, deg, row_ids, new_ids, r_total=r_total)


def shard_medoids(
    backend: MetricSpace,
    cent_reprs,                   # (L, ...) query representations
    shard_ids,                    # (L, S) int32, -1 padded
):
    """Batched shard-restricted medoid selection.

    The vectorized form of :func:`medoid_scan`: for each of L random
    shards, pick the member nearest its shard centroid representation.
    One ``dist_many`` call scores all (L, S) members at once — this is
    how the IVF layer picks k-means-free centroids (DESIGN.md §13).
    Returns (L,) int32 medoid node ids.
    """
    valid = shard_ids >= 0
    d = backend.dist_many(cent_reprs, jnp.maximum(shard_ids, 0), valid)
    d = jnp.where(valid, d, BIG)
    best = jnp.argmin(d, axis=-1)
    return jnp.take_along_axis(shard_ids, best[:, None], axis=-1)[:, 0]


def medoid_scan(
    backend: MetricSpace,
    centroid_repr,
    *,
    chunk: int,
    node_valid: jnp.ndarray | None = None,
):
    """Blockwise argmin of distance-to-centroid (restricted to live)."""
    n = backend.n
    n_pad = ((n + chunk - 1) // chunk) * chunk
    ids = jnp.arange(n_pad, dtype=jnp.int32) % n

    def scan_fn(best, block_ids):
        d = backend.dist_fn(
            centroid_repr, block_ids, jnp.ones_like(block_ids, jnp.bool_)
        )
        if node_valid is not None:
            d = jnp.where(node_valid[block_ids], d, BIG)
        i = jnp.argmin(d)
        cand = (d[i], block_ids[i])
        better = cand[0] < best[0]
        return (
            jnp.where(better, cand[0], best[0]),
            jnp.where(better, cand[1], best[1]),
        ), None

    (best_d, best_i), _ = jax.lax.scan(
        scan_fn,
        (BIG, jnp.int32(0)),
        ids.reshape(-1, chunk),
    )
    return best_i
