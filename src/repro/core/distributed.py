"""Sharded QuIVer: the paper's index distributed over the 'data' axis.

Fleet layout (DESIGN.md §3):
  * base vectors are range-partitioned into one shard per device;
  * each shard builds its own BQ-native Vamana graph — construction is
    embarrassingly parallel (the cluster-scale analogue of the paper's
    chunked concurrent linking: zero cross-shard dependencies);
  * a query fans out to all shards (`shard_map`), runs the local
    beam search + local float32 rerank, and the per-shard top-k are
    all-gathered and merged — one collective of k ids/scores per shard,
    the classic scatter-gather serving pattern.

The shard-local traversal distance is NOT hand-rolled here: each shard
constructs the registered metric backend (``repro.core.metric``) from
its local arrays, so sharded serving navigates in exactly the metric
space the graph was built in — any registered nav kind (``bq2``,
``bq1``, ``adc``, ``float32``), with kernel dispatch decided once at
backend construction (DESIGN.md §2).

Per-chip hot set = (N/S) signatures + adjacency: at 1M x 768 over 256
chips that is ~3 MB/chip — the paper's DDR5-bandwidth-bound hot loop
becomes VMEM/HBM-resident on TPU.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.compat import shard_map

from repro.core import bq
from repro.core.beam import batched_beam_search
from repro.core.index import QuIVerIndex, rerank_f32
from repro.core.metric import (
    MetricArrays,
    encode_queries_for,
    make_backend,
)
from repro.core.vamana import BuildParams
from repro.filter import (
    DEFAULT_SELECTIVITY_FLOOR,
    Label,
    entry_label,
    estimate_selectivity,
    eval_mask,
    validate,
    widened_ef,
)
from repro.probe import (
    CompatibilityReport,
    NavPolicy,
    merge_reports,
    probe_corpus,
    select_policy,
)


class ShardedIndex(NamedTuple):
    """Stacked per-shard index arrays (leading dim = n_shards).

    ``live`` is the per-shard validity mask: padding fill from an
    indivisible partition and streaming tombstones are both False and
    are excluded from search results *before* the all-gather merge.

    ``label_words`` / ``label_entries`` (optional) carry the per-shard
    filtered-search state (DESIGN.md §9): packed label bitsets stacked
    shard-major, and per-(shard, label) entry points.  A filtered
    query's predicate is evaluated per shard and pushed down into the
    fan-out as the beam's ``result_valid`` mask, so every shard merges
    only matching live ids — the top-k collective never widens.
    """
    sig_words: jnp.ndarray    # (S, n, 2W) uint32
    adjacency: jnp.ndarray    # (S, n, R+slack) int32
    medoids: jnp.ndarray      # (S,) int32
    vectors: jnp.ndarray      # (S, n, D) float32 (cold)
    dim: int
    metric: str = "bq2"       # metric kind the shards were built in
    live: jnp.ndarray | None = None   # (S, n) bool; None == all live
    label_words: jnp.ndarray | None = None   # (S, n, W_l) uint32
    n_labels: int = 0
    label_entries: jnp.ndarray | None = None  # (S, n_labels) int32, -1
    label_counts: np.ndarray | None = None    # (n_labels,) fleet-wide
    # applicability boundary (DESIGN.md §10): the fleet-wide merged
    # probe report and the nav policy every shard was built under
    policy: NavPolicy | None = None
    report: CompatibilityReport | None = None


def build_sharded(vectors: np.ndarray, n_shards: int,
                  params: BuildParams | None = None,
                  *, metric: str = "bq2",
                  labels=None, n_labels: int | None = None,
                  label_entry_min: int = 32) -> ShardedIndex:
    """Partition + per-shard build (host loop; on a fleet each host
    builds its own shard independently).

    Indivisible N is handled by padding the last shard with repeats of
    the leading vectors; the fill nodes participate in their shard's
    graph (they are real points, so navigation quality is unaffected)
    but are masked out of every search result, so all N input vectors
    — and only those — are retrievable.

    ``labels`` (optional, one int or iterable of ints per vector)
    attaches filter labels: each shard packs its slice into a
    :class:`~repro.filter.labels.LabelStore` and builds per-label
    entry points (``label_entry_min`` member floor), enabling
    ``search_sharded(filter=...)`` predicate pushdown.  Padding fill
    rows inherit the repeated vectors' labels but stay masked by
    ``live``, so they never surface.

    ``metric="auto"`` runs the applicability probe per shard slice,
    merges the shard reports fleet-wide (``repro.probe.merge_reports``)
    and builds every shard under the single policy the *merged* verdict
    selects — one serving schedule for the whole fleet, chosen from
    evidence pooled across all partitions.
    """
    params = params or BuildParams()
    n = len(vectors)
    per = -(-n // n_shards)                      # ceil division
    pad = per * n_shards - n
    arr = np.asarray(vectors)
    if pad:
        arr = np.concatenate([arr, arr[:pad]], axis=0)
    parts = arr.reshape(n_shards, per, arr.shape[-1])
    live = (np.arange(n_shards * per) < n).reshape(n_shards, per)
    policy = report = None
    if metric == "auto":
        # per-shard probes (each host probes only its own slice; the
        # last shard's pad fill repeats leading vectors — a < 1-shard
        # bias on fleet statistics, same as the label popcounts below)
        shard_reports = [
            probe_corpus(parts[s], seed=s) for s in range(n_shards)
        ]
        report = merge_reports(shard_reports)
        policy = select_policy(report)
        metric = policy.nav
    label_parts = None
    if labels is not None:
        if len(labels) != n:
            raise ValueError(f"{len(labels)} label rows for {n} vectors")
        labels = list(labels)
        if n_labels is None:
            flat = [x for item in labels for x in (
                (item,) if np.isscalar(item) else tuple(item))]
            n_labels = int(max(flat)) + 1 if flat else 1
        label_parts = [
            (labels + labels[:pad])[s * per:(s + 1) * per]
            for s in range(n_shards)
        ]
    words, adjs, meds, vecs = [], [], [], []
    lwords, lentries, lcounts = [], [], []
    for s in range(n_shards):
        idx = QuIVerIndex.build(jnp.asarray(parts[s]), params, metric=metric)
        if label_parts is not None:
            store = idx.attach_labels(label_parts[s], n_labels=n_labels)
            idx.build_label_entries(min_count=label_entry_min)
            lwords.append(store.words)
            lentries.append(store.entries)
            lcounts.append(store.counts)
        words.append(idx.sigs.words)
        adjs.append(idx.adjacency)
        meds.append(idx.medoid)
        vecs.append(idx.vectors)
    return ShardedIndex(
        sig_words=jnp.stack(words),
        adjacency=jnp.stack(adjs),
        medoids=jnp.asarray(meds, dtype=jnp.int32),
        vectors=jnp.stack(vecs),
        dim=vectors.shape[-1],
        metric=metric,
        live=jnp.asarray(live),
        label_words=jnp.stack(lwords) if lwords else None,
        n_labels=n_labels or 0,
        label_entries=(
            jnp.asarray(np.stack(lentries)) if lentries else None
        ),
        # fleet-wide popcounts for selectivity routing (pad fill rows
        # inflate these by < 1 shard's worth — estimates, not truth)
        label_counts=np.sum(lcounts, axis=0) if lcounts else None,
        policy=policy,
        report=report,
    )


def make_sharded_search(mesh: Mesh, *, dim: int, ef: int, k: int,
                        n_per_shard: int,
                        axis: str | tuple = "data",
                        nav: str = "bq2",
                        expand: int = 1):
    """Compile a fan-out/merge search step over ``mesh[axis]``.

    Returns search(index arrays..., result_valid (S, n), q_repr
    (Q, ...), queries (Q, D)) -> (global_ids (Q, k) int32, scores
    (Q, k) f32), replicated.  ``q_repr`` is the ``nav`` backend's query
    representation (use :func:`repro.core.metric.encode_queries_for`).
    ``live`` is the per-shard tombstone/padding mask and
    ``result_valid`` the per-shard filter-predicate mask (all-True when
    unfiltered): dead and non-matching nodes still route the local beam
    (FreshDiskANN navigation semantics, see ``repro.core.beam``) but
    are masked out of the local top-k *before* the all-gather, so one
    collective of k already-filtered ids/scores per shard is merged.
    """

    def local_search(sig_words, adj, medoid, vectors, live,
                     result_valid, q_repr, queries):
        # shard-local arrays arrive with the leading shard dim stripped
        sig_words = sig_words[0]
        adj = adj[0]
        medoid = medoid[0]
        vectors = vectors[0]
        live = live[0]
        result_valid = result_valid[0]
        # one backend per shard, same registry as everything else — the
        # sharded path owns no private distance function.
        backend = make_backend(nav, MetricArrays(
            sigs=bq.Signature(words=sig_words, dim=dim), vectors=vectors,
        ))

        res = batched_beam_search(
            q_repr, adj, medoid, dist_fn=backend.dist_fn, ef=ef,
            n=n_per_shard, expand=expand, node_valid=live,
            result_valid=result_valid,
        )
        # local cold-path rerank to top-k (res.ids are live-only) —
        # the single shared rerank, not a private copy
        ids, scores = rerank_f32(res.ids, queries, vectors, k)
        # globalize ids with the shard offset
        shard_id = jax.lax.axis_index(axis)
        gids = jnp.where(ids >= 0, ids + shard_id * n_per_shard, -1)

        # merge across shards: gather (S, Q, k) and take global top-k
        all_ids = jax.lax.all_gather(gids, axis)
        all_scores = jax.lax.all_gather(scores, axis)
        s = all_ids.shape[0]
        flat_ids = all_ids.transpose(1, 0, 2).reshape(-1, s * k)
        flat_scores = all_scores.transpose(1, 0, 2).reshape(-1, s * k)
        top_scores, top_pos = jax.lax.top_k(flat_scores, k)
        top_ids = jnp.take_along_axis(flat_ids, top_pos, axis=-1)
        return top_ids, top_scores

    spec_shard = P(axis)
    return shard_map(
        local_search,
        mesh=mesh,
        in_specs=(spec_shard, spec_shard, spec_shard, spec_shard,
                  spec_shard, spec_shard, P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )


def sharded_count_fn(index: ShardedIndex):
    """``label -> member popcount`` across all shards.

    Uses the precomputed ``label_counts`` carried by the index (kept
    fresh by ``build_sharded`` / ``StreamingShardedIndex.snapshot``);
    falls back to a per-label device popcount for hand-assembled
    indexes, cached for the lifetime of the returned closure.
    """
    if index.label_counts is not None:
        counts = index.label_counts
        return lambda label: int(counts[label])
    live = index.live
    cache: dict[int, int] = {}

    def count(label: int) -> int:
        if label not in cache:
            member = eval_mask(index.label_words, Label(label))
            if live is not None:
                member = member & live
            cache[label] = int(member.sum())
        return cache[label]

    return count


def search_sharded(index: ShardedIndex, queries: np.ndarray, *,
                   mesh: Mesh | None = None, ef: int = 64, k: int = 10,
                   axis: str = "data", nav: str | None = None,
                   expand: int = 1, filter=None,
                   selectivity_floor: float = DEFAULT_SELECTIVITY_FLOOR):
    """Convenience wrapper: encode queries, fan out, merge.

    ``nav`` defaults to the metric the shards were built in, mirroring
    ``QuIVerIndex.search``.

    ``filter`` (optional label predicate) is pushed down per shard: the
    predicate mask is evaluated against each shard's packed label
    bitsets and rides the fan-out as the local beam's ``result_valid``,
    with ``ef`` widened by the popcount-estimated selectivity and each
    shard starting from its own per-label entry point when one exists.
    Every shard therefore contributes only matching live ids to the
    merge — the collective stays one (k ids, k scores) pair per shard.
    (There is no per-shard brute-force route: a shard's match set is
    already 1/S of the corpus, and the masked merge is exact.)

    An auto-built fleet (``build_sharded(metric="auto")``) applies its
    :class:`NavPolicy` ef/rerank schedule when ``nav`` is left default.
    Per-query adaptive escalation is a single-index feature: at fleet
    scale the static ``ef_scale`` rides the one fan-out collective,
    while a second escalated collective per tight query would double
    the serving critical path (DESIGN.md §10).
    """
    sched = index.policy if nav is None else None
    if sched is not None:
        ef = ef * sched.ef_scale
    nav = nav or index.metric
    if mesh is None:
        n_dev = index.sig_words.shape[0]
        mesh = jax.make_mesh((n_dev,), (axis,))
    q = jnp.asarray(queries, jnp.float32)
    q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    q_repr = encode_queries_for(nav, q)
    live = index.live
    if live is None:
        live = jnp.ones(index.sig_words.shape[:2], dtype=jnp.bool_)

    result_valid = jnp.ones(index.sig_words.shape[:2], dtype=jnp.bool_)
    medoids = index.medoids
    ef_run = ef
    if filter is not None:
        if index.label_words is None:
            raise ValueError(
                "filtered sharded search needs label_words (build with "
                "labels= or snapshot a labeled streaming index)"
            )
        expr = validate(filter, index.n_labels)
        count_fn = sharded_count_fn(index)
        n_live = int(live.sum())
        sel = estimate_selectivity(expr, count_fn, n_live)
        # (S, n) predicate mask, evaluated shard-major on device
        result_valid = eval_mask(index.label_words, expr)
        ef_run = widened_ef(
            ef, sel, selectivity_floor, index.sig_words.shape[1]
        )
        lbl = entry_label(expr, count_fn)
        if lbl is not None and index.label_entries is not None:
            ent = index.label_entries[:, lbl]
            medoids = jnp.where(ent >= 0, ent, medoids).astype(jnp.int32)
    # cache the compiled fan-out: make_sharded_search returns a fresh
    # closure per call, so without this every search retraces (a
    # serving loop would recompile per request)
    key = (mesh, index.dim, ef_run, k, index.sig_words.shape[1], axis,
           nav, expand)
    fn = _SEARCH_CACHE.get(key)
    if fn is None:
        fn = jax.jit(make_sharded_search(
            mesh, dim=index.dim, ef=ef_run, k=k,
            n_per_shard=index.sig_words.shape[1], axis=axis, nav=nav,
            expand=expand,
        ))
        _SEARCH_CACHE[key] = fn
    ids, scores = fn(
        index.sig_words, index.adjacency, medoids, index.vectors,
        live, result_valid, q_repr, q,
    )
    return np.asarray(ids), np.asarray(scores)


_SEARCH_CACHE: dict = {}
