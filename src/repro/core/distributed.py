"""Sharded QuIVer: the paper's index distributed over the 'data' axis.

Fleet layout (DESIGN.md §3):
  * base vectors are range-partitioned into one shard per device;
  * each shard builds its own BQ-native Vamana graph — construction is
    embarrassingly parallel (the cluster-scale analogue of the paper's
    chunked concurrent linking: zero cross-shard dependencies);
  * a query fans out to all shards (`shard_map`), runs the local
    beam search + local float32 rerank, and the per-shard top-k are
    all-gathered and merged — one collective of k ids/scores per shard,
    the classic scatter-gather serving pattern.

The shard-local traversal distance is NOT hand-rolled here: each shard
constructs the registered metric backend (``repro.core.metric``) from
its local arrays, so sharded serving navigates in exactly the metric
space the graph was built in — any registered nav kind (``bq2``,
``bq1``, ``adc``, ``float32``), with kernel dispatch decided once at
backend construction (DESIGN.md §2).

Per-chip hot set = (N/S) signatures + adjacency: at 1M x 768 over 256
chips that is ~3 MB/chip — the paper's DDR5-bandwidth-bound hot loop
becomes VMEM/HBM-resident on TPU.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.compat import shard_map

from repro.core import bq
from repro.core.beam import batched_beam_search
from repro.core.index import QuIVerIndex, rerank_f32
from repro.core.metric import (
    MetricArrays,
    encode_queries_for,
    make_backend,
)
from repro.core.vamana import BuildParams
from repro.filter import (
    DEFAULT_SELECTIVITY_FLOOR,
    Label,
    entry_label,
    estimate_selectivity,
    eval_mask,
    validate,
    widened_ef,
)
from repro.probe import (
    CompatibilityReport,
    NavPolicy,
    merge_reports,
    probe_corpus,
    select_policy,
)


class ShardedIndex(NamedTuple):
    """Stacked per-shard index arrays (leading dim = n_shards).

    ``live`` is the per-shard validity mask: padding fill from an
    indivisible partition and streaming tombstones are both False and
    are excluded from search results *before* the all-gather merge.

    ``label_words`` / ``label_entries`` (optional) carry the per-shard
    filtered-search state (DESIGN.md §9): packed label bitsets stacked
    shard-major, and per-(shard, label) entry points.  A filtered
    query's predicate is evaluated per shard and pushed down into the
    fan-out as the beam's ``result_valid`` mask, so every shard merges
    only matching live ids — the top-k collective never widens.
    """
    sig_words: jnp.ndarray    # (S, n, 2W) uint32
    adjacency: jnp.ndarray    # (S, n, R+slack) int32
    medoids: jnp.ndarray      # (S,) int32
    vectors: jnp.ndarray      # (S, n, D) float32 (cold)
    dim: int
    metric: str = "bq2"       # metric kind the shards were built in
    live: jnp.ndarray | None = None   # (S, n) bool; None == all live
    label_words: jnp.ndarray | None = None   # (S, n, W_l) uint32
    n_labels: int = 0
    label_entries: jnp.ndarray | None = None  # (S, n_labels) int32, -1
    label_counts: np.ndarray | None = None    # (n_labels,) fleet-wide
    # applicability boundary (DESIGN.md §10): the fleet-wide merged
    # probe report and the nav policy every shard was built under
    policy: NavPolicy | None = None
    report: CompatibilityReport | None = None


def build_sharded(vectors: np.ndarray, n_shards: int,
                  params: BuildParams | None = None,
                  *, metric: str = "bq2",
                  labels=None, n_labels: int | None = None,
                  label_entry_min: int = 32) -> ShardedIndex:
    """Partition + per-shard build (host loop; on a fleet each host
    builds its own shard independently).

    Indivisible N is handled by padding the last shard with repeats of
    the leading vectors; the fill nodes participate in their shard's
    graph (they are real points, so navigation quality is unaffected)
    but are masked out of every search result, so all N input vectors
    — and only those — are retrievable.

    ``labels`` (optional, one int or iterable of ints per vector)
    attaches filter labels: each shard packs its slice into a
    :class:`~repro.filter.labels.LabelStore` and builds per-label
    entry points (``label_entry_min`` member floor), enabling
    ``search_sharded(filter=...)`` predicate pushdown.  Padding fill
    rows inherit the repeated vectors' labels but stay masked by
    ``live``, so they never surface.

    ``metric="auto"`` runs the applicability probe per shard slice,
    merges the shard reports fleet-wide (``repro.probe.merge_reports``)
    and builds every shard under the single policy the *merged* verdict
    selects — one serving schedule for the whole fleet, chosen from
    evidence pooled across all partitions.
    """
    params = params or BuildParams()
    n = len(vectors)
    per = -(-n // n_shards)                      # ceil division
    pad = per * n_shards - n
    arr = np.asarray(vectors)
    if pad:
        arr = np.concatenate([arr, arr[:pad]], axis=0)
    parts = arr.reshape(n_shards, per, arr.shape[-1])
    live = (np.arange(n_shards * per) < n).reshape(n_shards, per)
    policy = report = None
    if metric == "auto":
        # per-shard probes (each host probes only its own slice; the
        # last shard's pad fill repeats leading vectors — a < 1-shard
        # bias on fleet statistics, same as the label popcounts below)
        shard_reports = [
            probe_corpus(parts[s], seed=s) for s in range(n_shards)
        ]
        report = merge_reports(shard_reports)
        policy = select_policy(report)
        metric = policy.nav
    label_parts = None
    if labels is not None:
        if len(labels) != n:
            raise ValueError(f"{len(labels)} label rows for {n} vectors")
        labels = list(labels)
        if n_labels is None:
            flat = [x for item in labels for x in (
                (item,) if np.isscalar(item) else tuple(item))]
            n_labels = int(max(flat)) + 1 if flat else 1
        label_parts = [
            (labels + labels[:pad])[s * per:(s + 1) * per]
            for s in range(n_shards)
        ]
    words, adjs, meds, vecs = [], [], [], []
    lwords, lentries, lcounts = [], [], []
    for s in range(n_shards):
        idx = QuIVerIndex.build(jnp.asarray(parts[s]), params, metric=metric)
        if label_parts is not None:
            store = idx.attach_labels(label_parts[s], n_labels=n_labels)
            idx.build_label_entries(min_count=label_entry_min)
            lwords.append(store.words)
            lentries.append(store.entries)
            lcounts.append(store.counts)
        words.append(idx.sigs.words)
        adjs.append(idx.adjacency)
        meds.append(idx.medoid)
        vecs.append(idx.vectors)
    return ShardedIndex(
        sig_words=jnp.stack(words),
        adjacency=jnp.stack(adjs),
        medoids=jnp.asarray(meds, dtype=jnp.int32),
        vectors=jnp.stack(vecs),
        dim=vectors.shape[-1],
        metric=metric,
        live=jnp.asarray(live),
        label_words=jnp.stack(lwords) if lwords else None,
        n_labels=n_labels or 0,
        label_entries=(
            jnp.asarray(np.stack(lentries)) if lentries else None
        ),
        # fleet-wide popcounts for selectivity routing (pad fill rows
        # inflate these by < 1 shard's worth — estimates, not truth)
        label_counts=np.sum(lcounts, axis=0) if lcounts else None,
        policy=policy,
        report=report,
    )


def make_sharded_search(mesh: Mesh, *, dim: int, ef: int, k: int,
                        n_per_shard: int,
                        axis: str | tuple = "data",
                        nav: str = "bq2",
                        expand: int = 1):
    """Compile a fan-out/merge search step over ``mesh[axis]``.

    Returns search(index arrays..., result_valid (S, n), q_repr
    (Q, ...), queries (Q, D)) -> (global_ids (Q, k) int32, scores
    (Q, k) f32), replicated.  ``q_repr`` is the ``nav`` backend's query
    representation (use :func:`repro.core.metric.encode_queries_for`).
    ``live`` is the per-shard tombstone/padding mask and
    ``result_valid`` the per-shard filter-predicate mask (all-True when
    unfiltered): dead and non-matching nodes still route the local beam
    (FreshDiskANN navigation semantics, see ``repro.core.beam``) but
    are masked out of the local top-k *before* the all-gather, so one
    collective of k already-filtered ids/scores per shard is merged.
    """

    def local_search(sig_words, adj, medoid, vectors, live,
                     result_valid, q_repr, queries):
        # shard-local arrays arrive with the leading shard dim stripped
        sig_words = sig_words[0]
        adj = adj[0]
        medoid = medoid[0]
        vectors = vectors[0]
        live = live[0]
        result_valid = result_valid[0]
        # one backend per shard, same registry as everything else — the
        # sharded path owns no private distance function.
        backend = make_backend(nav, MetricArrays(
            sigs=bq.Signature(words=sig_words, dim=dim), vectors=vectors,
        ))

        res = batched_beam_search(
            q_repr, adj, medoid, dist_fn=backend.dist_fn, ef=ef,
            n=n_per_shard, expand=expand, node_valid=live,
            result_valid=result_valid,
        )
        # local cold-path rerank to top-k (res.ids are live-only) —
        # the single shared rerank, not a private copy
        ids, scores = rerank_f32(res.ids, queries, vectors, k)
        # globalize ids with the shard offset
        shard_id = jax.lax.axis_index(axis)
        gids = jnp.where(ids >= 0, ids + shard_id * n_per_shard, -1)

        # merge across shards: gather (S, Q, k) and take global top-k
        all_ids = jax.lax.all_gather(gids, axis)
        all_scores = jax.lax.all_gather(scores, axis)
        s = all_ids.shape[0]
        flat_ids = all_ids.transpose(1, 0, 2).reshape(-1, s * k)
        flat_scores = all_scores.transpose(1, 0, 2).reshape(-1, s * k)
        top_scores, top_pos = jax.lax.top_k(flat_scores, k)
        top_ids = jnp.take_along_axis(flat_ids, top_pos, axis=-1)
        return top_ids, top_scores

    spec_shard = P(axis)
    return shard_map(
        local_search,
        mesh=mesh,
        in_specs=(spec_shard, spec_shard, spec_shard, spec_shard,
                  spec_shard, spec_shard, P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )


# -- IVF targeted scatter (DESIGN.md §13) ---------------------------------
#
# The coarse lists double as the fleet's shard unit: whole inverted
# lists are placed on shards (greedy balance by population), the tiny
# centroid tier is replicated everywhere, and a query is scattered only
# to the shards owning its top-p lists — at most min(p, S) of them —
# instead of the all-shard fan-out above.  The scatter is host-driven
# (each contacted shard runs one compiled fused list-scan + rerank
# step over its own arrays), which is exactly the multi-host serving
# shape: routing on the frontend, one RPC per contacted shard.


class IVFShard(NamedTuple):
    """One shard's slice of a list-partitioned corpus."""

    sig_words: jnp.ndarray    # (n_s, 2W) uint32
    vectors: jnp.ndarray      # (n_s, D) float32 (cold/rerank tier)
    ids: np.ndarray           # (n_s,) int32 global corpus ids
    list_ids: jnp.ndarray     # (L_s, cap_s) int32 LOCAL slots, -1 pad
    lists: np.ndarray         # (L_s,) int32 global list ids owned


class IVFShardedIndex(NamedTuple):
    """List-partitioned fleet: replicated routing tier + per-shard
    member slices.  ``list_shard``/``list_local`` map a global list id
    to (owning shard, local list index) — the scatter's routing table.
    """

    cent_words: jnp.ndarray   # (L, 2W) uint32, replicated
    list_shard: np.ndarray    # (L,) int32 owning shard per list
    list_local: np.ndarray    # (L,) int32 local index within owner
    shards: tuple             # tuple[IVFShard, ...]
    dim: int
    default_probes: int

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def n_lists(self) -> int:
        return int(self.cent_words.shape[0])


def build_ivf_sharded(vectors: np.ndarray, n_shards: int, *,
                      n_lists: int | None = None,
                      seed: int = 0) -> IVFShardedIndex:
    """Partition by coarse list, then place whole lists on shards.

    One global :func:`repro.ivf.build_partition` over the corpus
    signatures, then greedy balance: lists in descending population
    order each go to the currently lightest shard, so shard loads stay
    within one max-list of each other without splitting any list (a
    list never spans shards — that is what makes the scatter targeted).
    """
    from repro.ivf import build_partition

    v = np.asarray(vectors, dtype=np.float32)
    v = v / np.maximum(
        np.linalg.norm(v, axis=-1, keepdims=True), 1e-12
    )
    sigs = bq.encode(jnp.asarray(v))
    part = build_partition(sigs, n_lists=n_lists, seed=seed)
    L = part.n_lists
    n_shards = max(1, min(n_shards, L))
    counts = np.diff(part.offsets)

    # greedy balance by population, descending
    order = np.argsort(-counts, kind="stable")
    load = np.zeros(n_shards, dtype=np.int64)
    list_shard = np.empty(L, dtype=np.int32)
    for lst in order:
        s = int(np.argmin(load))
        list_shard[lst] = s
        load[s] += counts[lst]
    list_local = np.empty(L, dtype=np.int32)

    shards = []
    for s in range(n_shards):
        owned = np.nonzero(list_shard == s)[0].astype(np.int32)
        list_local[owned] = np.arange(owned.size, dtype=np.int32)
        member_chunks = [
            part.member_ids[part.offsets[l]:part.offsets[l + 1]]
            for l in owned
        ]
        ids = (np.concatenate(member_chunks) if member_chunks
               else np.empty((0,), np.int32)).astype(np.int32)
        slot_of = {}
        cap = max(8, int(-(-max(
            (len(c) for c in member_chunks), default=1) // 8) * 8))
        local = np.full((max(owned.size, 1), cap), -1, dtype=np.int32)
        pos = 0
        for i, chunk_ids in enumerate(member_chunks):
            local[i, :len(chunk_ids)] = np.arange(
                pos, pos + len(chunk_ids), dtype=np.int32
            )
            pos += len(chunk_ids)
        del slot_of
        shards.append(IVFShard(
            sig_words=sigs.words[jnp.asarray(
                ids if ids.size else np.zeros((1,), np.int32)
            )],
            vectors=jnp.asarray(
                v[ids] if ids.size else v[:1] * 0.0
            ),
            ids=ids,
            list_ids=jnp.asarray(local),
            lists=owned,
        ))
    return IVFShardedIndex(
        cent_words=part.cent_words,
        list_shard=list_shard,
        list_local=list_local,
        shards=tuple(shards),
        dim=vectors.shape[-1],
        default_probes=part.default_probes,
    )


def _ivf_shard_step(dim: int, ef: int, k: int):
    """Compiled per-shard scatter step: fused local list scan + rerank."""

    def step(sig_words, vectors, list_ids, probe_local, reprs, queries):
        backend = make_backend("bq2", MetricArrays(
            sigs=bq.Signature(words=sig_words, dim=dim),
            vectors=vectors,
        ))
        q = probe_local.shape[0]
        mem = list_ids[jnp.maximum(probe_local, 0)].reshape(q, -1)
        valid = (
            (probe_local >= 0).repeat(list_ids.shape[1], axis=-1)
            & (mem >= 0)
        )
        d = backend.dist_many(reprs, jnp.maximum(mem, 0), valid)
        d = jnp.where(valid, d, _INF)
        ef_eff = min(ef, mem.shape[1])
        neg, pos = jax.lax.top_k(-d, ef_eff)
        ids = jnp.take_along_axis(mem, pos, axis=-1)
        ids = jnp.where(-neg < _INF / 2, ids, -1)
        return rerank_f32(ids, queries, vectors, k)

    return jax.jit(step)


_INF = jnp.float32(3.0e38)
_IVF_STEP_CACHE: dict = {}


def search_ivf_sharded(index: IVFShardedIndex, queries: np.ndarray, *,
                       k: int = 10, ef: int = 64,
                       probes: int | None = None,
                       broadcast: bool = False,
                       registry=None):
    """Targeted scatter over the list-partitioned fleet.

    Routes each query on the replicated centroid tier, contacts only
    the shards owning its top-p lists (≤ min(p, S) of them; shards a
    query does not route to never see it), and merges the per-shard
    reranked top-k by cosine score — the IVF analogue of
    :func:`search_sharded`'s all-shard fan-out.

    ``broadcast=True`` sends every query to every shard (non-probed
    lists stay masked out) — the all-shard baseline the targeted path
    is equivalence-tested against.  Per-list route counters and the
    shards-contacted histogram land on ``registry`` (default process
    registry).  Returns (global ids (Q, k), cosine scores (Q, k)).
    """
    from repro.core.beam import batch_bucket, pad_rows
    from repro.ivf import record_routes, top_lists
    from repro.kernels import dispatch

    q = jnp.asarray(queries, jnp.float32)
    if q.ndim == 1:
        q = q[None]
    q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    nq = q.shape[0]
    reprs = encode_queries_for("bq2", q)
    p = probes or index.default_probes
    p = max(1, min(p, index.n_lists))

    ops = dispatch.list_scan_ops(index.dim)
    top = np.asarray(top_lists(ops.scan, reprs, index.cent_words, p))
    shard_of = index.list_shard[top]                       # (Q, p)
    contacted_per_q = np.array([
        len(np.unique(row)) for row in shard_of
    ])
    record_routes(top, contacted_per_q, registry=registry)

    all_ids = np.full((nq, index.n_shards, k), -1, dtype=np.int64)
    all_scores = np.full((nq, index.n_shards, k), -np.inf,
                         dtype=np.float32)
    for s, shard in enumerate(index.shards):
        if broadcast:
            rows = np.arange(nq)
        else:
            rows = np.nonzero((shard_of == s).any(axis=-1))[0]
        if rows.size == 0 or shard.ids.size == 0:
            continue                     # targeted: shard never contacted
        # local probe table: this shard's local index for each probed
        # list it owns, -1 elsewhere (masked inside the fused step)
        sub = top[rows]
        probe_local = np.where(
            index.list_shard[sub] == s, index.list_local[sub], -1
        ).astype(np.int32)
        bucket = batch_bucket(rows.size, 256)
        key = (s, shard.sig_words.shape, shard.list_ids.shape,
               bucket, p, ef, k, index.dim)
        step = _IVF_STEP_CACHE.get(key)
        if step is None:
            step = _ivf_shard_step(index.dim, ef, k)
            _IVF_STEP_CACHE[key] = step
        ids, scores = step(
            shard.sig_words, shard.vectors, shard.list_ids,
            pad_rows(jnp.asarray(probe_local), bucket),
            pad_rows(reprs[jnp.asarray(rows)], bucket),
            pad_rows(q[jnp.asarray(rows)], bucket),
        )
        ids = np.asarray(ids[:rows.size])
        scores = np.asarray(scores[:rows.size])
        gids = np.where(ids >= 0, shard.ids[np.maximum(ids, 0)], -1)
        all_ids[rows, s] = gids
        all_scores[rows, s] = np.where(ids >= 0, scores, -np.inf)

    flat_ids = all_ids.reshape(nq, -1)
    flat_scores = all_scores.reshape(nq, -1)
    order = np.argsort(-flat_scores, axis=-1)[:, :k]
    out_scores = np.take_along_axis(flat_scores, order, axis=-1)
    out_ids = np.take_along_axis(flat_ids, order, axis=-1)
    out_ids[~np.isfinite(out_scores)] = -1
    return out_ids, out_scores


def sharded_count_fn(index: ShardedIndex):
    """``label -> member popcount`` across all shards.

    Uses the precomputed ``label_counts`` carried by the index (kept
    fresh by ``build_sharded`` / ``StreamingShardedIndex.snapshot``);
    falls back to a per-label device popcount for hand-assembled
    indexes, cached for the lifetime of the returned closure.
    """
    if index.label_counts is not None:
        counts = index.label_counts
        return lambda label: int(counts[label])
    live = index.live
    cache: dict[int, int] = {}

    def count(label: int) -> int:
        if label not in cache:
            member = eval_mask(index.label_words, Label(label))
            if live is not None:
                member = member & live
            cache[label] = int(member.sum())
        return cache[label]

    return count


def search_sharded(index: ShardedIndex, queries: np.ndarray, *,
                   mesh: Mesh | None = None, ef: int = 64, k: int = 10,
                   axis: str = "data", nav: str | None = None,
                   expand: int = 1, filter=None,
                   selectivity_floor: float = DEFAULT_SELECTIVITY_FLOOR):
    """Convenience wrapper: encode queries, fan out, merge.

    ``nav`` defaults to the metric the shards were built in, mirroring
    ``QuIVerIndex.search``.

    ``filter`` (optional label predicate) is pushed down per shard: the
    predicate mask is evaluated against each shard's packed label
    bitsets and rides the fan-out as the local beam's ``result_valid``,
    with ``ef`` widened by the popcount-estimated selectivity and each
    shard starting from its own per-label entry point when one exists.
    Every shard therefore contributes only matching live ids to the
    merge — the collective stays one (k ids, k scores) pair per shard.
    (There is no per-shard brute-force route: a shard's match set is
    already 1/S of the corpus, and the masked merge is exact.)

    An auto-built fleet (``build_sharded(metric="auto")``) applies its
    :class:`NavPolicy` ef/rerank schedule when ``nav`` is left default.
    Per-query adaptive escalation is a single-index feature: at fleet
    scale the static ``ef_scale`` rides the one fan-out collective,
    while a second escalated collective per tight query would double
    the serving critical path (DESIGN.md §10).
    """
    sched = index.policy if nav is None else None
    if sched is not None:
        ef = ef * sched.ef_scale
    nav = nav or index.metric
    if mesh is None:
        n_dev = index.sig_words.shape[0]
        mesh = jax.make_mesh((n_dev,), (axis,))
    q = jnp.asarray(queries, jnp.float32)
    q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    q_repr = encode_queries_for(nav, q)
    live = index.live
    if live is None:
        live = jnp.ones(index.sig_words.shape[:2], dtype=jnp.bool_)

    result_valid = jnp.ones(index.sig_words.shape[:2], dtype=jnp.bool_)
    medoids = index.medoids
    ef_run = ef
    if filter is not None:
        if index.label_words is None:
            raise ValueError(
                "filtered sharded search needs label_words (build with "
                "labels= or snapshot a labeled streaming index)"
            )
        expr = validate(filter, index.n_labels)
        count_fn = sharded_count_fn(index)
        n_live = int(live.sum())
        sel = estimate_selectivity(expr, count_fn, n_live)
        # (S, n) predicate mask, evaluated shard-major on device
        result_valid = eval_mask(index.label_words, expr)
        ef_run = widened_ef(
            ef, sel, selectivity_floor, index.sig_words.shape[1]
        )
        lbl = entry_label(expr, count_fn)
        if lbl is not None and index.label_entries is not None:
            ent = index.label_entries[:, lbl]
            medoids = jnp.where(ent >= 0, ent, medoids).astype(jnp.int32)
    # cache the compiled fan-out: make_sharded_search returns a fresh
    # closure per call, so without this every search retraces (a
    # serving loop would recompile per request)
    key = (mesh, index.dim, ef_run, k, index.sig_words.shape[1], axis,
           nav, expand)
    fn = _SEARCH_CACHE.get(key)
    if fn is None:
        fn = jax.jit(make_sharded_search(
            mesh, dim=index.dim, ef=ef_run, k=k,
            n_per_shard=index.sig_words.shape[1], axis=axis, nav=nav,
            expand=expand,
        ))
        _SEARCH_CACHE[key] = fn
    ids, scores = fn(
        index.sig_words, index.adjacency, medoids, index.vectors,
        live, result_valid, q_repr, q,
    )
    return np.asarray(ids), np.asarray(scores)


_SEARCH_CACHE: dict = {}
