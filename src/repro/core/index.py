"""QuIVerIndex — the paper's system as a composable public API.

Pipeline (paper Fig. 1):

    float32 vectors ──binarize──▶ 2-bit SM signatures      (hot)
                                   │
                         BQ-native Vamana build             (hot)
                                   │
    query ──encode──▶ symmetric BQ beam search              (hot)
                                   │ top-ef candidates
                      float32 cosine rerank                 (cold)

Hot path = signatures + adjacency; float32 vectors are only touched at
rerank (and may live in host memory / another tier on a real fleet).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bq

# bucket-ladder + escalation-driver re-exports: pre-plan callers import
# these from here (the beam module stays the one owner)
from repro.core.beam import (  # noqa: F401
    batch_bucket,
    batched_beam_search,
    beam_margin,
    escalated_search,
    pad_rows,
)
from repro.core.metric import MetricArrays, MetricSpace, make_backend
from repro.core.vamana import BuildParams, BuildStats, build_graph
from repro.filter import (
    DEFAULT_SELECTIVITY_FLOOR,
    LabelStore,
    build_label_entries,
)
from repro.ivf import IVFPartition, build_partition
from repro.plan.cache import PlanCache
from repro.plan.planner import resolve_plan
from repro.probe import (
    CompatibilityReport,
    NavPolicy,
    probe_corpus,
    select_policy,
)

# "ivf" is a navigation *family*, not a build metric: the graph (and
# the partition) live in bq2 space; serving scans top-p coarse lists
# instead of traversing (DESIGN.md §13)
NavKind = Literal["bq2", "bq1", "adc", "float32", "ivf"]

# BuildParams persistence: one named npz field per dataclass field (the
# old format was a positional int64 array — brittle, and alpha had to be
# smuggled as milli-units).  ``params_from_npz`` still reads it.
_PARAM_PREFIX = "param_"


def params_to_npz(params: BuildParams) -> dict:
    """BuildParams -> named npz fields (``param_<name>``)."""
    return {
        _PARAM_PREFIX + f.name: np.asarray(getattr(params, f.name))
        for f in dataclasses.fields(BuildParams)
    }


def params_from_npz(z) -> BuildParams:
    """Named npz fields -> BuildParams, with the legacy positional
    int64 ``params`` array as the backward-compat path."""
    names = {f.name for f in dataclasses.fields(BuildParams)}
    if _PARAM_PREFIX + "m" in z:
        kw = {}
        for name in names:
            key = _PARAM_PREFIX + name
            if key in z:
                val = z[key][()]
                kw[name] = float(val) if name == "alpha" else int(val)
        return BuildParams(**kw)
    p = z["params"]                      # legacy positional archive
    return BuildParams(
        m=int(p[0]), ef_construction=int(p[1]), alpha=p[2] / 1000.0,
        chunk=int(p[3]), prune_pool=int(p[4]), reverse_slack=int(p[5]),
        consolidate_every=int(p[6]), passes=int(p[7]), seed=int(p[8]),
        beam_expand=int(p[9]) if len(p) > 9 else 1,
    )


def _normalize(x: jnp.ndarray) -> jnp.ndarray:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def random_rotation(dim: int, seed: int) -> jnp.ndarray:
    """Random orthogonal matrix (RaBitQ-style preprocessing; beyond-paper)."""
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (dim, dim), dtype=jnp.float32)
    q, r = jnp.linalg.qr(g)
    # fix signs for a uniform Haar rotation
    return q * jnp.sign(jnp.diag(r))[None, :]


@dataclasses.dataclass
class QuIVerIndex:
    """A built index. ``vectors`` is the cold path; everything else hot."""

    sigs: bq.Signature               # (N, 2W) packed — hot
    adjacency: jnp.ndarray           # (N, R+slack) int32 — hot
    medoid: int
    params: BuildParams
    vectors: jnp.ndarray | None      # (N, D) float32, L2-normalized — cold
    rotation: jnp.ndarray | None = None
    build_stats: BuildStats | None = None
    metric_kind: NavKind = "bq2"
    labels: LabelStore | None = None     # packed label bitsets — hot
    # applicability-boundary state (repro.probe, DESIGN.md §10): the
    # probe report and nav policy chosen by ``build(nav="auto")``; both
    # persist through save/load so a loaded index keeps its schedule.
    policy: NavPolicy | None = None
    report: CompatibilityReport | None = None
    # IVF-over-BQ coarse partition (repro.ivf, DESIGN.md §13): present
    # when built with ``ivf_candidates`` or attached via ``build_ivf``;
    # enables the ``nav="ivf"`` plan family and targeted scatter
    ivf: IVFPartition | None = None
    # structural X-ray (repro.obs.graph, DESIGN.md §15): the last
    # computed GraphHealthReport; persists through save/load (and
    # freeze, on the streaming side) so a loaded index remembers the
    # topology verdict it shipped with
    graph_health: "object | None" = None
    # backends are constructed once per nav kind and cached: kernel
    # dispatch happens at construction, and beam-search jit caches key on
    # the backend instance, so reusing it avoids re-trace per query batch.
    _backends: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    # compiled query plans (repro.plan, DESIGN.md §11): one cache per
    # index; every distinct plan jit-compiles exactly once and serving
    # only feeds the compiled set
    _plan_cache: PlanCache | None = dataclasses.field(
        default=None, repr=False, compare=False
    )

    def backend(self, kind: NavKind | None = None) -> MetricSpace:
        """The metric backend for ``kind`` (default: the index's own)."""
        kind = kind or self.metric_kind
        if kind not in self._backends:
            self._backends[kind] = make_backend(
                kind, MetricArrays(sigs=self.sigs, vectors=self.vectors)
            )
        return self._backends[kind]

    @property
    def plans(self) -> PlanCache:
        """The index's compiled-plan cache (created on first use)."""
        if self._plan_cache is None:
            self._plan_cache = PlanCache(self)
        return self._plan_cache

    # -- construction ------------------------------------------------------

    @classmethod
    def build(
        cls,
        vectors: jnp.ndarray,
        params: BuildParams | None = None,
        *,
        metric: NavKind | Literal["auto"] = "bq2",
        nav: NavKind | Literal["auto"] | None = None,
        probe_sample: int = 1024,
        probe_seed: int = 0,
        rotate_seed: int | None = None,
        keep_vectors: bool = True,
        verbose: bool = False,
    ) -> "QuIVerIndex":
        """Build the index; ``metric`` (alias ``nav``) picks the space.

        ``metric="auto"`` runs the training-free applicability probe
        (``repro.probe``, DESIGN.md §10) on a ``probe_sample``-row
        slice and selects the nav ladder rung + ef/rerank schedule
        from the verdict: green -> ``bq2``, amber -> ``bq2`` with
        adaptive escalation, red -> ``float32`` (or ``adc`` without
        cold vectors) — so incompatible corpora route around the BQ
        collapse instead of silently serving <15% recall.  The chosen
        :class:`NavPolicy` and :class:`CompatibilityReport` ride the
        index through save/load and drive ``search`` defaults.
        """
        if nav is not None:
            metric = nav
        params = params or BuildParams()
        assert params.prune_pool <= params.ef_construction
        vectors = _normalize(jnp.asarray(vectors, dtype=jnp.float32))
        rotation = None
        encoded = vectors
        if rotate_seed is not None:
            rotation = random_rotation(vectors.shape[-1], rotate_seed)
            encoded = vectors @ rotation
        policy = report = None
        if metric == "auto":
            # probe the encoding the index will actually serve: the
            # bit-plane statistics and BQ agreement are properties of
            # the (possibly rotated) signatures, not the raw vectors
            # (cosine moments are rotation-invariant either way)
            report = probe_corpus(
                encoded, sample=probe_sample, seed=probe_seed
            )
            policy = select_policy(
                report, have_vectors=keep_vectors,
                have_ivf=params.ivf_candidates,
            )
            metric = policy.nav
            if verbose:
                print(f"[probe] {report.summary()} -> {policy.describe()}")
        if metric == "ivf":
            # "ivf" is a nav family over a bq2-built graph + partition,
            # not a construction metric; the policy carries the default
            if policy is None:
                policy = NavPolicy(nav="ivf", source="manual")
            metric = "bq2"
        sigs = bq.encode(encoded)
        ivf = None
        if params.ivf_candidates or (
            policy is not None and policy.nav == "ivf"
        ):
            ivf = build_partition(
                sigs, n_lists=params.ivf_lists or None, seed=params.seed
            )
        backend = make_backend(
            metric, MetricArrays(sigs=sigs, vectors=vectors)
        )
        adj, medoid, stats = build_graph(
            backend, params, ivf=ivf, verbose=verbose
        )
        return cls(
            sigs=sigs,
            adjacency=adj,
            medoid=medoid,
            params=params,
            vectors=vectors if keep_vectors else None,
            rotation=rotation,
            build_stats=stats,
            metric_kind=metric,
            policy=policy,
            report=report,
            ivf=ivf,
        )

    def build_ivf(
        self, *, n_lists: int | None = None, seed: int | None = None
    ) -> IVFPartition:
        """Attach a coarse partition post-hoc (enables ``nav="ivf"``
        and targeted scatter on an index built without one).
        Deterministic under the build seed unless ``seed`` overrides."""
        self.ivf = build_partition(
            self.sigs, n_lists=n_lists,
            seed=self.params.seed if seed is None else seed,
        )
        return self.ivf

    # -- replanning (closed-loop remediation, DESIGN.md §14) ---------------

    def replan(
        self,
        *,
        nav: NavKind,
        ef_scale: int | None = None,
        adaptive: bool | None = None,
        source: str = "replan",
    ) -> NavPolicy:
        """Switch the index's default nav policy at serve time.

        The remediation path (``repro.obs.remediate``) calls this when
        live recall evidence contradicts the build-time verdict: the
        new :class:`NavPolicy` becomes the default for every search
        that leaves ``nav`` unset, and the *old* default's compiled
        plans are invalidated from the :class:`PlanCache` — targeted,
        so every other nav family's executables survive untouched
        (zero retraces for unaffected traffic).

        ``ef_scale`` / ``adaptive`` default to the current policy's
        values (or the :class:`NavPolicy` defaults when none is set).
        """
        if nav == "ivf" and self.ivf is None:
            raise ValueError(
                "replan(nav='ivf') needs a coarse partition; call "
                "build_ivf() first"
            )
        if nav == "float32" and self.vectors is None:
            raise ValueError(
                "replan(nav='float32') needs the cold vector tier; "
                "this index is vector-free"
            )
        old_nav = (
            self.policy.nav if self.policy is not None else self.metric_kind
        )
        if self.policy is not None:
            kw = {"nav": nav, "source": source}
            if ef_scale is not None:
                kw["ef_scale"] = int(ef_scale)
            if adaptive is not None:
                kw["adaptive"] = bool(adaptive)
            self.policy = dataclasses.replace(self.policy, **kw)
        else:
            self.policy = NavPolicy(
                nav=nav, source=source,
                **({} if ef_scale is None else {"ef_scale": int(ef_scale)}),
                **({} if adaptive is None else {"adaptive": bool(adaptive)}),
            )
        if nav != old_nav and self._plan_cache is not None:
            self._plan_cache.invalidate(nav=old_nav)
        return self.policy

    # -- labels (filtered search, DESIGN.md §9) ----------------------------

    def attach_labels(
        self, labels, *, n_labels: int | None = None
    ) -> LabelStore:
        """Attach per-node labels: one int (categorical) or iterable of
        ints (multi-tag) per node, length N.  Returns the store."""
        n = self.sigs.words.shape[0]
        if len(labels) != n:
            raise ValueError(f"{len(labels)} label rows for {n} nodes")
        self.labels = LabelStore.from_rows(labels, n_labels=n_labels)
        return self.labels

    def build_label_entries(self, *, min_count: int = 32) -> int:
        """Per-label entry points (member medoids) for frequent labels;
        returns how many were built."""
        if self.labels is None:
            raise ValueError("no labels attached")
        return build_label_entries(
            self.labels, self.backend(), vectors=self.vectors,
            min_count=min_count,
        )

    # -- search ------------------------------------------------------------

    def search(
        self,
        queries: jnp.ndarray,
        k: int = 10,
        *,
        ef: int = 64,
        rerank: bool = True,
        nav: NavKind | None = None,
        expand: int = 1,
        query_batch: int = 256,
        filter=None,
        selectivity_floor: float = DEFAULT_SELECTIVITY_FLOOR,
        adaptive: bool | None = None,
        probes: int | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """(Q, D) float32 queries -> ((Q, k) ids, (Q, k) scores).

        ``nav="ivf"`` (or an ivf :class:`NavPolicy` default) routes
        through the coarse-list family (DESIGN.md §13): scan the
        centroid signatures, gather the members of the ``probes``
        nearest lists (default: the partition's √L), keep the best
        ``ef`` in bq2 space and rerank — no graph traversal.
        Escalation widens ``probes``; all other knobs compose as on
        the graph route.

        Score scale: with ``rerank=True`` (and cold vectors present)
        scores are exact float32 **cosine similarity** in [-1, 1]; with
        ``rerank=False`` they are **negated navigation distances** on
        the ``nav`` backend's own scale (e.g. ``sim - 4D`` for ``bq2``)
        — larger is still better, but the two scales are not comparable
        (see :func:`rerank`).

        ``nav`` defaults to the metric the index was built in; ``expand``
        is the beam expansion width L (one (L*R,) distance batch/hop).

        An auto-built index (``build(nav="auto")``) applies its
        :class:`NavPolicy` schedule when ``nav`` is left default: ``ef``
        is multiplied by ``policy.ef_scale``, and ``adaptive`` defaults
        to the policy's setting.  ``adaptive=True`` enables per-query
        escalation (DESIGN.md §10): queries whose top-k navigation
        margins are tight (:func:`repro.core.beam.beam_margin` below
        the policy's ``escalate_margin`` — the quantized scores cannot
        separate the rerank pool boundary) re-run with an
        ``escalate_mult``-times wider beam, widening the rerank
        candidate pool exactly where it is needed.

        ``filter`` (optional) is a label predicate — ``repro.filter``'s
        ``Any``/``All``/``Not`` or a bare label id — evaluated against
        the attached :class:`LabelStore`.  Estimated selectivity picks
        the route: above ``selectivity_floor`` the graph is traversed
        with a widened ``ef`` and the predicate as the beam's
        ``result_valid`` mask (non-matching nodes route but never
        surface), starting from the best per-label entry point; below
        the floor the match set is brute-forced exactly.  Adaptive
        escalation composes with the graph route (the escalated pass
        keeps the predicate mask); the brute route is already exact.

        The whole call lowers to a compiled :class:`~repro.plan.QueryPlan`
        (DESIGN.md §11): the nav ladder, the filter route and the
        escalation schedule are resolved *once* into a frozen plan, and
        the index's :class:`~repro.plan.PlanCache` compiles each
        distinct plan exactly once — repeated calls with the same
        configuration only feed cached executables.
        """
        plan, ctx = resolve_plan(
            self, k=k, ef=ef, rerank=rerank, nav=nav, expand=expand,
            query_batch=query_batch, filter=filter,
            selectivity_floor=selectivity_floor, adaptive=adaptive,
            probes=probes,
        )
        return self.plans.run(plan, ctx, queries)

    # -- structural health (graph X-ray, DESIGN.md §15) --------------------

    def graph_report(
        self,
        *,
        sample: int = 256,
        agreement_k: int = 8,
        max_hops: int = 64,
        seed: int = 0,
        thresholds=None,
        registry=None,
    ):
        """Compute (and cache as ``graph_health``) the structural
        :class:`~repro.obs.graph.GraphHealthReport`: degree structure,
        reciprocity, medoid reachability, and — when cold vectors are
        present — the sampled BQ↔float32 edge-agreement score.  The
        cached report persists through :meth:`save`/:meth:`load`."""
        from repro.obs.graph import (
            DEFAULT_GRAPH_THRESHOLDS,
            graph_health_report,
        )
        self.graph_health = graph_health_report(
            self.adjacency,
            medoid=self.medoid,
            words=self.sigs.words if self.vectors is not None else None,
            dim=self.sigs.dim,
            vectors=self.vectors,
            sample=sample,
            agreement_k=agreement_k,
            max_hops=max_hops,
            seed=seed,
            thresholds=thresholds or DEFAULT_GRAPH_THRESHOLDS,
            registry=registry,
        )
        return self.graph_health

    # -- accounting (paper Table 2) -----------------------------------------

    def memory_breakdown(self) -> dict:
        n = self.sigs.words.shape[0]
        sig_bytes = self.sigs.words.size * 4
        adj_bytes = self.adjacency.size * 4 + n * 4  # + degree counters
        label_bytes = (
            self.labels.memory_bytes() if self.labels is not None else 0
        )
        # the IVF tier (centroid signatures + padded list layout) rides
        # the hot path: every ivf plan gathers from it per query
        ivf_bytes = self.ivf.memory_bytes() if self.ivf is not None else 0
        cold = self.vectors.size * 4 if self.vectors is not None else 0
        # shadow-sampler host state (pending ground-truth copies + the
        # recall window) — attached by repro.obs.quality.ShadowSampler
        shadow = getattr(self, "shadow", None)
        shadow_bytes = shadow.memory_bytes() if shadow is not None else 0
        hot = sig_bytes + adj_bytes + label_bytes + ivf_bytes
        out = {
            "hot_signature_bytes": int(sig_bytes),
            "hot_adjacency_bytes": int(adj_bytes),
            "hot_label_bytes": int(label_bytes),
            "hot_ivf_bytes": int(ivf_bytes),
            "hot_total_bytes": int(hot),
            "cold_vector_bytes": int(cold),
            "host_shadow_bytes": int(shadow_bytes),
            "total_bytes": int(hot + cold + shadow_bytes),
        }
        if self.policy is not None:
            # auto-built indexes report the serving policy next to the
            # bytes it costs: a red-zone float32 ladder means the "cold"
            # tier is actually on the hot path
            out["nav_policy"] = self.policy.describe()
            out["probe_verdict"] = (
                self.report.verdict if self.report is not None else "n/a"
            )
        if self.graph_health is not None:
            out["graph_verdict"] = self.graph_health.verdict
            out["graph_health_score"] = round(
                self.graph_health.health_score, 4
            )
        return out

    # -- persistence ---------------------------------------------------------

    def save(self, path: str) -> None:
        label_fields = (
            self.labels.to_npz_fields() if self.labels is not None else {}
        )
        probe_fields = {}
        if self.policy is not None:
            probe_fields.update(self.policy.to_npz_fields())
        if self.report is not None:
            probe_fields.update(self.report.to_npz_fields())
        if self.ivf is not None:
            probe_fields.update(self.ivf.to_npz_fields())
        if self.graph_health is not None:
            probe_fields.update(self.graph_health.to_npz_fields())
        np.savez_compressed(
            path,
            words=np.asarray(self.sigs.words),
            dim=self.sigs.dim,
            adjacency=np.asarray(self.adjacency),
            medoid=self.medoid,
            vectors=(
                np.asarray(self.vectors)
                if self.vectors is not None else np.zeros((0,))
            ),
            rotation=(
                np.asarray(self.rotation)
                if self.rotation is not None else np.zeros((0,))
            ),
            metric_kind=np.array(self.metric_kind),
            **label_fields,
            **probe_fields,
            **params_to_npz(self.params),
        )

    @classmethod
    def load(cls, path: str) -> "QuIVerIndex":
        z = np.load(path)
        if "stream_format" in z:
            raise ValueError(
                "this is a streaming archive; load it with "
                "repro.stream.MutableQuIVerIndex.load (freeze() it for "
                "an immutable QuIVerIndex)"
            )
        from repro.obs.graph import GraphHealthReport
        params = params_from_npz(z)
        vectors = z["vectors"]
        rotation = z["rotation"]
        # pre-refactor archives carried no metric_kind (always bq2)
        metric_kind = str(z["metric_kind"]) if "metric_kind" in z else "bq2"
        return cls(
            sigs=bq.Signature(
                words=jnp.asarray(z["words"]), dim=int(z["dim"])
            ),
            adjacency=jnp.asarray(z["adjacency"]),
            medoid=int(z["medoid"]),
            params=params,
            vectors=jnp.asarray(vectors) if vectors.size else None,
            rotation=jnp.asarray(rotation) if rotation.size else None,
            metric_kind=metric_kind,
            labels=LabelStore.from_npz(z),
            policy=NavPolicy.from_npz(z),
            report=CompatibilityReport.from_npz(z),
            ivf=IVFPartition.from_npz(z),
            graph_health=GraphHealthReport.from_npz(z),
        )


@functools.partial(jax.jit, static_argnames=("k",))
def rerank_f32(beam_ids, queries, vectors, k):
    """Cold-path rerank: exact cosine over the ef candidates (§3.3).

    ``beam_ids`` entries < 0 (padding / masked tombstones) are excluded
    — their similarity is -inf, so they can only surface as trailing -1
    ids when fewer than k valid candidates exist.
    """
    from repro.plan.trace import note_trace
    note_trace("rerank_f32")
    safe = jnp.maximum(beam_ids, 0)
    cand = vectors[safe]                                # (Q, ef, D)
    sims = jnp.einsum("qd,qed->qe", queries, cand)
    sims = jnp.where(beam_ids >= 0, sims, -jnp.inf)
    scores, pos = jax.lax.top_k(sims, k)
    ids = jnp.take_along_axis(beam_ids, pos, axis=-1)
    ids = jnp.where(jnp.isfinite(scores), ids, -1)
    return ids, scores


@functools.partial(jax.jit, static_argnames=("k",))
def topk_by_dist(beam_ids, beam_dists, k):
    """Hot-path-only top-k: scores are **negated navigation distances**
    (the beam backend's own scale — e.g. ``sim - 4D`` in [-8D, 0] for
    ``bq2``, negated Hamming for ``bq1``), NOT cosine.  Larger is
    better, but the scale is not comparable to :func:`rerank_f32`."""
    from repro.plan.trace import note_trace
    note_trace("topk_by_dist")
    scores, pos = jax.lax.top_k(-beam_dists, k)
    ids = jnp.take_along_axis(beam_ids, pos, axis=-1)
    return ids, scores


def rerank(beam_ids, beam_dists, queries, vectors, k):
    """Shared rerank entry — the score-convention boundary.

    With cold ``vectors`` present, candidates are re-scored exactly and
    the returned scores are **float32 cosine similarity** in [-1, 1]
    (:func:`rerank_f32`).  With ``vectors=None`` (``rerank=False``
    searches, vector-free indexes) the beam order is kept and the
    scores are **negated navigation distances** on the metric backend's
    own scale (:func:`topk_by_dist`).  Both exclude invalid (-1) beam
    ids; callers comparing scores across searches must hold the
    convention fixed — the two scales are not interchangeable.
    """
    if vectors is None:
        return topk_by_dist(beam_ids, beam_dists, k)
    return rerank_f32(beam_ids, queries, vectors, k)


# pre-streaming private names, kept for any out-of-tree callers
_rerank, _rerank_f32, _topk_by_dist = rerank, rerank_f32, topk_by_dist
