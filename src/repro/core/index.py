"""QuIVerIndex — the paper's system as a composable public API.

Pipeline (paper Fig. 1):

    float32 vectors ──binarize──▶ 2-bit SM signatures      (hot)
                                   │
                         BQ-native Vamana build             (hot)
                                   │
    query ──encode──▶ symmetric BQ beam search              (hot)
                                   │ top-ef candidates
                      float32 cosine rerank                 (cold)

Hot path = signatures + adjacency; float32 vectors are only touched at
rerank (and may live in host memory / another tier on a real fleet).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bq
from repro.core.beam import batched_beam_search
from repro.core.metric import MetricArrays, MetricSpace, make_backend
from repro.core.vamana import BuildParams, BuildStats, build_graph

NavKind = Literal["bq2", "bq1", "adc", "float32"]

# BuildParams persistence: one named npz field per dataclass field (the
# old format was a positional int64 array — brittle, and alpha had to be
# smuggled as milli-units).  ``params_from_npz`` still reads it.
_PARAM_PREFIX = "param_"


def params_to_npz(params: BuildParams) -> dict:
    """BuildParams -> named npz fields (``param_<name>``)."""
    return {
        _PARAM_PREFIX + f.name: np.asarray(getattr(params, f.name))
        for f in dataclasses.fields(BuildParams)
    }


def params_from_npz(z) -> BuildParams:
    """Named npz fields -> BuildParams, with the legacy positional
    int64 ``params`` array as the backward-compat path."""
    names = {f.name for f in dataclasses.fields(BuildParams)}
    if _PARAM_PREFIX + "m" in z:
        kw = {}
        for name in names:
            key = _PARAM_PREFIX + name
            if key in z:
                val = z[key][()]
                kw[name] = float(val) if name == "alpha" else int(val)
        return BuildParams(**kw)
    p = z["params"]                      # legacy positional archive
    return BuildParams(
        m=int(p[0]), ef_construction=int(p[1]), alpha=p[2] / 1000.0,
        chunk=int(p[3]), prune_pool=int(p[4]), reverse_slack=int(p[5]),
        consolidate_every=int(p[6]), passes=int(p[7]), seed=int(p[8]),
        beam_expand=int(p[9]) if len(p) > 9 else 1,
    )


def _normalize(x: jnp.ndarray) -> jnp.ndarray:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def random_rotation(dim: int, seed: int) -> jnp.ndarray:
    """Random orthogonal matrix (RaBitQ-style preprocessing; beyond-paper)."""
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (dim, dim), dtype=jnp.float32)
    q, r = jnp.linalg.qr(g)
    # fix signs for a uniform Haar rotation
    return q * jnp.sign(jnp.diag(r))[None, :]


@dataclasses.dataclass
class QuIVerIndex:
    """A built index. ``vectors`` is the cold path; everything else hot."""

    sigs: bq.Signature               # (N, 2W) packed — hot
    adjacency: jnp.ndarray           # (N, R+slack) int32 — hot
    medoid: int
    params: BuildParams
    vectors: jnp.ndarray | None      # (N, D) float32, L2-normalized — cold
    rotation: jnp.ndarray | None = None
    build_stats: BuildStats | None = None
    metric_kind: NavKind = "bq2"
    # backends are constructed once per nav kind and cached: kernel
    # dispatch happens at construction, and beam-search jit caches key on
    # the backend instance, so reusing it avoids re-trace per query batch.
    _backends: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )

    def backend(self, kind: NavKind | None = None) -> MetricSpace:
        """The metric backend for ``kind`` (default: the index's own)."""
        kind = kind or self.metric_kind
        if kind not in self._backends:
            self._backends[kind] = make_backend(
                kind, MetricArrays(sigs=self.sigs, vectors=self.vectors)
            )
        return self._backends[kind]

    # -- construction ------------------------------------------------------

    @classmethod
    def build(
        cls,
        vectors: jnp.ndarray,
        params: BuildParams | None = None,
        *,
        metric: NavKind = "bq2",
        rotate_seed: int | None = None,
        keep_vectors: bool = True,
        verbose: bool = False,
    ) -> "QuIVerIndex":
        params = params or BuildParams()
        assert params.prune_pool <= params.ef_construction
        vectors = _normalize(jnp.asarray(vectors, dtype=jnp.float32))
        rotation = None
        encoded = vectors
        if rotate_seed is not None:
            rotation = random_rotation(vectors.shape[-1], rotate_seed)
            encoded = vectors @ rotation
        sigs = bq.encode(encoded)
        backend = make_backend(
            metric, MetricArrays(sigs=sigs, vectors=vectors)
        )
        adj, medoid, stats = build_graph(backend, params, verbose=verbose)
        return cls(
            sigs=sigs,
            adjacency=adj,
            medoid=medoid,
            params=params,
            vectors=vectors if keep_vectors else None,
            rotation=rotation,
            build_stats=stats,
            metric_kind=metric,
        )

    # -- search ------------------------------------------------------------

    def search(
        self,
        queries: jnp.ndarray,
        k: int = 10,
        *,
        ef: int = 64,
        rerank: bool = True,
        nav: NavKind | None = None,
        expand: int = 1,
        query_batch: int = 256,
    ) -> tuple[np.ndarray, np.ndarray]:
        """(Q, D) float32 queries -> ((Q, k) ids, (Q, k) cosine scores).

        ``nav`` defaults to the metric the index was built in; ``expand``
        is the beam expansion width L (one (L*R,) distance batch/hop).
        """
        queries = _normalize(jnp.asarray(queries, dtype=jnp.float32))
        backend = self.backend(nav)
        # signatures were encoded from rotated vectors, so sig-based
        # backends need rotated queries; the float32 backend holds the
        # unrotated cold vectors and must see the queries unrotated too.
        enc_in = queries
        if self.rotation is not None and backend.kind != "float32":
            enc_in = queries @ self.rotation
        reprs = backend.encode_queries(enc_in)
        n = self.sigs.words.shape[0]

        out_ids, out_scores = [], []
        for s in range(0, queries.shape[0], query_batch):
            rep = reprs[s:s + query_batch]
            res = batched_beam_search(
                rep, self.adjacency, jnp.int32(self.medoid),
                dist_fn=backend.dist_fn, ef=ef, n=n, expand=expand,
            )
            ids, scores = _rerank(
                res.ids, res.dists, queries[s:s + query_batch],
                self.vectors if rerank else None, k,
            )
            out_ids.append(np.asarray(ids))
            out_scores.append(np.asarray(scores))
        return np.concatenate(out_ids), np.concatenate(out_scores)

    # -- accounting (paper Table 2) -----------------------------------------

    def memory_breakdown(self) -> dict[str, int]:
        n = self.sigs.words.shape[0]
        sig_bytes = self.sigs.words.size * 4
        adj_bytes = self.adjacency.size * 4 + n * 4  # + degree counters
        cold = self.vectors.size * 4 if self.vectors is not None else 0
        return {
            "hot_signature_bytes": int(sig_bytes),
            "hot_adjacency_bytes": int(adj_bytes),
            "hot_total_bytes": int(sig_bytes + adj_bytes),
            "cold_vector_bytes": int(cold),
            "total_bytes": int(sig_bytes + adj_bytes + cold),
        }

    # -- persistence ---------------------------------------------------------

    def save(self, path: str) -> None:
        np.savez_compressed(
            path,
            words=np.asarray(self.sigs.words),
            dim=self.sigs.dim,
            adjacency=np.asarray(self.adjacency),
            medoid=self.medoid,
            vectors=(
                np.asarray(self.vectors)
                if self.vectors is not None else np.zeros((0,))
            ),
            rotation=(
                np.asarray(self.rotation)
                if self.rotation is not None else np.zeros((0,))
            ),
            metric_kind=np.array(self.metric_kind),
            **params_to_npz(self.params),
        )

    @classmethod
    def load(cls, path: str) -> "QuIVerIndex":
        z = np.load(path)
        if "stream_format" in z:
            raise ValueError(
                "this is a streaming archive; load it with "
                "repro.stream.MutableQuIVerIndex.load (freeze() it for "
                "an immutable QuIVerIndex)"
            )
        params = params_from_npz(z)
        vectors = z["vectors"]
        rotation = z["rotation"]
        # pre-refactor archives carried no metric_kind (always bq2)
        metric_kind = str(z["metric_kind"]) if "metric_kind" in z else "bq2"
        return cls(
            sigs=bq.Signature(
                words=jnp.asarray(z["words"]), dim=int(z["dim"])
            ),
            adjacency=jnp.asarray(z["adjacency"]),
            medoid=int(z["medoid"]),
            params=params,
            vectors=jnp.asarray(vectors) if vectors.size else None,
            rotation=jnp.asarray(rotation) if rotation.size else None,
            metric_kind=metric_kind,
        )


@functools.partial(jax.jit, static_argnames=("k",))
def rerank_f32(beam_ids, queries, vectors, k):
    """Cold-path rerank: exact cosine over the ef candidates (§3.3).

    ``beam_ids`` entries < 0 (padding / masked tombstones) are excluded
    — their similarity is -inf, so they can only surface as trailing -1
    ids when fewer than k valid candidates exist.
    """
    safe = jnp.maximum(beam_ids, 0)
    cand = vectors[safe]                                # (Q, ef, D)
    sims = jnp.einsum("qd,qed->qe", queries, cand)
    sims = jnp.where(beam_ids >= 0, sims, -jnp.inf)
    scores, pos = jax.lax.top_k(sims, k)
    ids = jnp.take_along_axis(beam_ids, pos, axis=-1)
    ids = jnp.where(jnp.isfinite(scores), ids, -1)
    return ids, scores


@functools.partial(jax.jit, static_argnames=("k",))
def topk_by_dist(beam_ids, beam_dists, k):
    scores, pos = jax.lax.top_k(-beam_dists, k)
    ids = jnp.take_along_axis(beam_ids, pos, axis=-1)
    return ids, scores


def rerank(beam_ids, beam_dists, queries, vectors, k):
    """Shared rerank entry: float32 cosine when cold vectors exist,
    else BQ-distance top-k.  Both exclude invalid (-1) beam ids."""
    if vectors is None:
        return topk_by_dist(beam_ids, beam_dists, k)
    return rerank_f32(beam_ids, queries, vectors, k)


# pre-streaming private names, kept for any out-of-tree callers
_rerank, _rerank_f32, _topk_by_dist = rerank, rerank_f32, topk_by_dist
