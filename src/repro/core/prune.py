"""Vamana alpha-diversity pruning on BQ distances (QuIVer Alg. 1).

Vectorized greedy selection: a ``fori_loop`` over the R output slots; at
each step the nearest not-yet-pruned candidate is selected and every
candidate it "covers" (``dist(c, t) > alpha * dist(c, s)``) is pruned.
All distances are the *calibrated non-negative* BQ distances
``d = 4D - similarity`` (see ``repro.core.index`` for why the Table-1
signed similarity needs an offset before the multiplicative alpha
criterion is meaningful).

The pairwise candidate-candidate distance matrix is computed once up
front — the batched analogue of the paper's per-candidate popcount calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

BIG = jnp.float32(3.0e38)


def _greedy_select(cand_ids, cand_dists, pairwise, *, r, alpha):
    """Distance-sort + greedy cover loop; the shared core of the prune.

    Returns (sorted ids, sorted dists, selected mask, pruned mask) over
    the sorted candidate order.
    """
    c = cand_ids.shape[0]
    valid = cand_ids >= 0
    order = jnp.argsort(jnp.where(valid, cand_dists, BIG))
    ids = cand_ids[order]
    dists = cand_dists[order]
    pw = pairwise[order][:, order]
    valid = ids >= 0

    def step(_, state):
        selected, pruned = state
        avail = valid & ~selected & ~pruned
        # candidates are sorted by distance: first available == nearest
        pick = jnp.argmax(avail)           # first True (all-False handled below)
        any_avail = avail.any()
        selected = selected.at[pick].set(selected[pick] | any_avail)
        # prune everything covered by the new pivot
        covered = dists > alpha * pw[pick]
        covered = covered & ~selected & any_avail
        pruned = pruned | covered
        return selected, pruned

    selected, pruned = jax.lax.fori_loop(
        0,
        r,
        step,
        (jnp.zeros((c,), jnp.bool_), jnp.zeros((c,), jnp.bool_)),
    )
    return ids, dists, selected, pruned


def _compact(ids, dists, selected, r):
    """Compact the <= r selected entries (sorted by distance) into (r,)."""
    rank = jnp.cumsum(selected) - 1        # in-order rank among selected
    slot = jnp.where(selected, rank, r)    # r == overflow bucket for the rest
    out_ids = (
        jnp.full((r + 1,), -1, jnp.int32)
        .at[slot]
        .set(jnp.where(selected, ids, -1))[:r]
    )
    out_dists = (
        jnp.full((r + 1,), BIG, jnp.float32)
        .at[slot]
        .set(jnp.where(selected, dists, BIG))[:r]
    )
    return out_ids, out_dists


@functools.partial(jax.jit, static_argnames=("r", "alpha"))
def alpha_prune(
    cand_ids: jnp.ndarray,    # (C,) int32, -1 padded
    cand_dists: jnp.ndarray,  # (C,) float32, distance to target, INF padded
    pairwise: jnp.ndarray,    # (C, C) float32 candidate-candidate distances
    *,
    r: int,
    alpha: float,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Greedy alpha-diversity selection -> ((r,) ids, (r,) dists)."""
    ids, dists, selected, _ = _greedy_select(
        cand_ids, cand_dists, pairwise, r=r, alpha=alpha
    )
    return _compact(ids, dists, selected, r)


@functools.partial(jax.jit, static_argnames=("r", "alpha"))
def alpha_prune_stats(
    cand_ids: jnp.ndarray,
    cand_dists: jnp.ndarray,
    pairwise: jnp.ndarray,
    *,
    r: int,
    alpha: float,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """:func:`alpha_prune` plus the build-telemetry counts.

    Returns ((r,) ids, (r,) dists, () pool size, () occluded count):
    *pool* is how many valid candidates entered the prune, *occluded*
    how many the alpha-criterion covered away (survivors = pool −
    occluded, bounded by r).  Same trace as ``alpha_prune`` — the
    counts are reductions over masks the loop already computes.
    """
    ids, dists, selected, pruned = _greedy_select(
        cand_ids, cand_dists, pairwise, r=r, alpha=alpha
    )
    out_ids, out_dists = _compact(ids, dists, selected, r)
    pool = (ids >= 0).sum().astype(jnp.int32)
    occluded = pruned.sum().astype(jnp.int32)
    return out_ids, out_dists, pool, occluded


def alpha_prune_batch(cand_ids, cand_dists, pairwise, *, r, alpha):
    """vmap over a chunk of targets: (B, C) / (B, C, C) -> (B, r)."""
    return jax.vmap(
        functools.partial(alpha_prune, r=r, alpha=alpha)
    )(cand_ids, cand_dists, pairwise)


def alpha_prune_stats_batch(cand_ids, cand_dists, pairwise, *, r, alpha):
    """vmap of :func:`alpha_prune_stats`: adds (B,) pool / occluded."""
    return jax.vmap(
        functools.partial(alpha_prune_stats, r=r, alpha=alpha)
    )(cand_ids, cand_dists, pairwise)
