"""Pluggable metric backends for graph construction and navigation.

QuIVer's whole thesis is *which metric space the graph lives in*; making
the metric a first-class backend lets the same Vamana builder + beam
search produce:

* ``BQ2Backend``   — the paper: symmetric 2-bit Sign-Magnitude distance,
  calibrated non-negative as ``d = 4D - similarity`` (Table 1 weights are
  signed; the multiplicative alpha-criterion of Algorithm 1 needs d >= 0,
  and this shift is the unique order-preserving calibration with
  ``d(x, x) = 0`` when every dim of x is strong-matched).
* ``BQ1Backend``   — 1-bit SimHash Hamming (the §2.1/§5 ablation).
* ``Float32Backend`` — exact cosine distance (the hnswlib/USearch-like
  full-precision reference build, paper Table 6).

A backend exposes a query representation per node, a gather-based
distance function for beam search, and batched pairwise distances for
alpha-pruning.
"""

from __future__ import annotations

import functools
from typing import Protocol

import jax
import jax.numpy as jnp

from repro.core import bq


class MetricBackend(Protocol):
    n: int

    def query_repr(self, ids: jnp.ndarray) -> jnp.ndarray:
        """Representation handed to beam search for these node ids."""

    def encode_queries(self, x: jnp.ndarray) -> jnp.ndarray:
        """External float32 queries (Q, D) -> beam-search representation."""

    def dist_fn(self, query, ids, valid) -> jnp.ndarray:
        """(k,) distances from ``query`` to nodes ``ids``; >= 0."""

    def pairwise(self, ids: jnp.ndarray) -> jnp.ndarray:
        """(..., C) ids -> (..., C, C) pairwise distances; >= 0."""


class BQ2Backend:
    """Symmetric 2-bit Sign-Magnitude metric space (the paper's hot path)."""

    def __init__(self, sigs: bq.Signature):
        self.sigs = sigs
        self.n = sigs.words.shape[0]
        self.dim = sigs.dim
        self._w = sigs.w
        self._mask = bq.valid_mask(sigs.dim)
        self._offset = jnp.float32(4 * sigs.dim)

    def query_repr(self, ids):
        return self.sigs.words[ids]

    def encode_queries(self, x):
        return bq.encode(x).words

    def dist_fn(self, query, ids, valid):
        w = self._w
        rows = self.sigs.words[ids]
        sim = bq.symmetric_similarity_words(
            query[..., :w], query[..., w:],
            rows[..., :w], rows[..., w:],
            self._mask,
        )
        return self._offset - sim.astype(jnp.float32)

    def pairwise(self, ids):
        w = self._w
        rows = self.sigs.words[ids]                      # (..., C, 2W)
        a = rows[..., :, None, :]
        b = rows[..., None, :, :]
        sim = bq.symmetric_similarity_words(
            a[..., :w], a[..., w:], b[..., :w], b[..., w:], self._mask
        )
        return self._offset - sim.astype(jnp.float32)


class BQ1Backend:
    """1-bit SimHash Hamming metric space (ablation baseline)."""

    def __init__(self, sigs: bq.Signature):
        self.sigs = sigs
        self.n = sigs.words.shape[0]
        self.dim = sigs.dim
        self._w = sigs.w

    def query_repr(self, ids):
        return self.sigs.pos[ids]

    def encode_queries(self, x):
        return bq.encode(x).words[..., : self._w]

    def dist_fn(self, query, ids, valid):
        rows = self.sigs.pos[ids]
        x = query ^ rows
        return (
            jax.lax.population_count(x).astype(jnp.int32).sum(-1)
        ).astype(jnp.float32)

    def pairwise(self, ids):
        rows = self.sigs.pos[ids]
        x = rows[..., :, None, :] ^ rows[..., None, :, :]
        return (
            jax.lax.population_count(x).astype(jnp.int32).sum(-1)
        ).astype(jnp.float32)


class Float32Backend:
    """Exact cosine metric space (full-precision reference build)."""

    def __init__(self, vectors: jnp.ndarray):
        norms = jnp.linalg.norm(vectors, axis=-1, keepdims=True)
        self.vectors = vectors / jnp.maximum(norms, 1e-12)
        self.n = vectors.shape[0]
        self.dim = vectors.shape[-1]

    def query_repr(self, ids):
        return self.vectors[ids]

    def encode_queries(self, x):
        norms = jnp.linalg.norm(x, axis=-1, keepdims=True)
        return x / jnp.maximum(norms, 1e-12)

    def dist_fn(self, query, ids, valid):
        rows = self.vectors[ids]
        return 1.0 - rows @ query

    def pairwise(self, ids):
        rows = self.vectors[ids]
        sims = jnp.einsum("...cd,...ed->...ce", rows, rows)
        return 1.0 - sims


class ADCBackend:
    """Asymmetric navigation: float32 query vs decoded 2-bit signatures.

    Search-time-only ablation (§3.3 "Why not ADC for navigation?"):
    construction still uses the symmetric backend; this backend is used
    for the traversal distance in the ADC experiment.
    """

    def __init__(self, sigs: bq.Signature):
        self.sigs = sigs
        self.n = sigs.words.shape[0]
        self.dim = sigs.dim

    def query_repr(self, ids):  # pragma: no cover - ADC is query-side only
        raise NotImplementedError("ADC is an asymmetric, query-side metric")

    def encode_queries(self, x):
        norms = jnp.linalg.norm(x, axis=-1, keepdims=True)
        return x / jnp.maximum(norms, 1e-12)

    def dist_fn(self, query, ids, valid):
        rows = bq.Signature(words=self.sigs.words[ids], dim=self.dim)
        levels = bq.decode_levels(rows)              # (k, D)
        # non-negative calibration: max |<q, levels>| <= 2*sqrt(D) for
        # unit q; offset keeps the alpha-criterion well-defined.
        offset = 2.0 * jnp.sqrt(jnp.float32(self.dim))
        return offset - levels @ query

    def pairwise(self, ids):  # pragma: no cover - not used for pruning
        raise NotImplementedError
