"""The metric layer: registry-driven metric spaces for the whole index.

QuIVer's whole thesis is *which metric space the graph lives in*; this
module makes that space a first-class, registered object so Vamana
construction, beam search, sharded serving and the benchmarks all pull
the same distance from the same place.  Backends are registered by name
and constructed from a shared :class:`MetricArrays` bundle:

    backend = make_backend("bq2", MetricArrays(sigs=sigs))

* ``bq2``     — the paper: symmetric 2-bit Sign-Magnitude distance,
  calibrated non-negative as ``d = 4D - similarity`` (Table 1 weights are
  signed; the multiplicative alpha-criterion of Algorithm 1 needs d >= 0,
  and this shift is the unique order-preserving calibration with
  ``d(x, x) = 0`` when every dim of x is strong-matched).
* ``bq1``     — 1-bit SimHash Hamming (the §2.1/§5 ablation).
* ``adc``     — asymmetric float-query-vs-decoded-levels navigation
  (§3.3 "Why not ADC for navigation?"), now with a decoded-levels
  ``pairwise`` so ADC-built graphs work too.
* ``float32`` — exact cosine distance (the hnswlib/USearch-like
  full-precision reference build, paper Table 6).

Every BQ distance evaluation routes through ``repro.kernels.dispatch``,
bound once per backend at construction: compiled Pallas kernels on TPU,
the ``bq.py`` jnp reference elsewhere.  No caller outside this module
computes a BQ distance by hand (grep-enforced in the tests).

A backend exposes a query representation per node, a gather-based
distance function for beam search (``dist_fn`` single query,
``dist_many`` batched queries), and batched pairwise distances for
alpha-pruning.  See DESIGN.md §2 for the registry contract.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol

import jax.numpy as jnp

from repro.core import bq
from repro.kernels import dispatch


@dataclasses.dataclass(frozen=True)
class MetricArrays:
    """Shared array bundle every backend is constructed from.

    ``sigs`` is the hot path (packed 2-bit SM signatures); ``vectors``
    the cold path (float32, L2-normalized) — only ``float32`` needs it.
    """

    sigs: bq.Signature | None = None
    vectors: jnp.ndarray | None = None


class MetricSpace(Protocol):
    """What construction, search and serving require of a metric space."""

    kind: str
    n: int
    neutral_dist: float   # zero-similarity distance (beam_margin scale)

    def query_repr(self, ids: jnp.ndarray) -> jnp.ndarray:
        """Representation handed to beam search for these node ids."""

    def encode_queries(self, x: jnp.ndarray) -> jnp.ndarray:
        """External float32 queries (Q, D) -> beam-search representation."""

    def dist_fn(self, query, ids, valid) -> jnp.ndarray:
        """(K,) distances from one ``query`` to nodes ``ids``; >= 0."""

    def dist_many(self, queries, ids, valid) -> jnp.ndarray:
        """(..., K) distances for a leading batch of queries; >= 0."""

    def pairwise(self, ids: jnp.ndarray) -> jnp.ndarray:
        """(..., C) ids -> (..., C, C) pairwise distances; >= 0."""


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type] = {}


def register(name: str):
    """Class decorator: register a backend under ``name``."""

    def deco(cls):
        cls.kind = name
        _REGISTRY[name] = cls
        return cls

    return deco


def registered_kinds() -> list[str]:
    return sorted(_REGISTRY)


def resolve(kind: str) -> type:
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise ValueError(
            f"unknown metric kind {kind!r}; registered: {registered_kinds()}"
        ) from None


def make_backend(
    kind: str, arrays: MetricArrays, *, route: str | None = None
) -> MetricSpace:
    """Construct the registered backend ``kind`` from ``arrays``.

    ``route`` forces the kernel dispatch route (``pallas``/``ref``);
    default auto-selects by platform (see ``repro.kernels.dispatch``).
    """
    return resolve(kind).from_arrays(arrays, route=route)


def encode_queries_for(kind: str, x: jnp.ndarray) -> jnp.ndarray:
    """Instance-free query encoding (sharded serving encodes on the host
    side, before any shard-local backend exists)."""
    return resolve(kind).encode(x)


def _unit(x: jnp.ndarray) -> jnp.ndarray:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


@register("bq2")
class BQ2Backend:
    """Symmetric 2-bit Sign-Magnitude metric space (the paper's hot path)."""

    def __init__(self, sigs: bq.Signature, *, route: str | None = None):
        self.sigs = sigs
        self.n = sigs.words.shape[0]
        self.dim = sigs.dim
        self._ops = dispatch.bq2_ops(sigs.dim, route=route)
        self._offset = jnp.float32(4 * sigs.dim)
        # an orthogonal pair scores similarity ~0 -> distance ~offset
        self.neutral_dist = float(4 * sigs.dim)

    @classmethod
    def from_arrays(cls, arrays: MetricArrays, *, route: str | None = None):
        assert arrays.sigs is not None, "bq2 needs packed signatures"
        return cls(arrays.sigs, route=route)

    @classmethod
    def encode(cls, x):
        return bq.encode(x).words

    @property
    def route(self) -> str:
        return self._ops.route

    def query_repr(self, ids):
        return self.sigs.words[ids]

    def encode_queries(self, x):
        return self.encode(x)

    def dist_fn(self, query, ids, valid):
        rows = self.sigs.words[ids]
        sim = self._ops.dist_rows(query, rows)
        return self._offset - sim.astype(jnp.float32)

    dist_many = dist_fn   # dist_rows broadcasts over leading query dims

    def pairwise(self, ids):
        rows = self.sigs.words[ids]
        sim = self._ops.pairwise(rows)
        return self._offset - sim.astype(jnp.float32)


@register("bq1")
class BQ1Backend:
    """1-bit SimHash Hamming metric space (ablation baseline)."""

    def __init__(self, sigs: bq.Signature, *, route: str | None = None):
        self.sigs = sigs
        self.n = sigs.words.shape[0]
        self.dim = sigs.dim
        self._ops = dispatch.bq1_ops(sigs.dim, route=route)
        # expected Hamming distance of independent sign planes
        self.neutral_dist = float(sigs.dim) / 2.0

    @classmethod
    def from_arrays(cls, arrays: MetricArrays, *, route: str | None = None):
        assert arrays.sigs is not None, "bq1 needs packed signatures"
        return cls(arrays.sigs, route=route)

    @classmethod
    def encode(cls, x):
        sig = bq.encode(x)
        return sig.words[..., : sig.w]

    @property
    def route(self) -> str:
        return self._ops.route

    def query_repr(self, ids):
        return self.sigs.pos[ids]

    def encode_queries(self, x):
        return self.encode(x)

    def dist_fn(self, query, ids, valid):
        rows = self.sigs.pos[ids]
        sim = self._ops.dist_rows(query, rows)   # negated Hamming
        return -sim.astype(jnp.float32)

    dist_many = dist_fn

    def pairwise(self, ids):
        rows = self.sigs.pos[ids]
        return -self._ops.pairwise(rows).astype(jnp.float32)


@register("float32")
class Float32Backend:
    """Exact cosine metric space (full-precision reference build)."""

    def __init__(self, vectors: jnp.ndarray, *, route: str | None = None):
        self.vectors = _unit(vectors)
        self.n = vectors.shape[0]
        self.dim = vectors.shape[-1]
        self.neutral_dist = 1.0          # cos 0 -> distance 1

    @classmethod
    def from_arrays(cls, arrays: MetricArrays, *, route: str | None = None):
        assert arrays.vectors is not None, "float32 needs cold vectors"
        return cls(arrays.vectors)

    @classmethod
    def encode(cls, x):
        return _unit(x)

    def query_repr(self, ids):
        return self.vectors[ids]

    def encode_queries(self, x):
        return self.encode(x)

    def dist_fn(self, query, ids, valid):
        rows = self.vectors[ids]
        return 1.0 - rows @ query

    def dist_many(self, queries, ids, valid):
        rows = self.vectors[ids]
        return 1.0 - jnp.einsum("...d,...kd->...k", queries, rows)

    def pairwise(self, ids):
        rows = self.vectors[ids]
        sims = jnp.einsum("...cd,...ed->...ce", rows, rows)
        return 1.0 - sims


@register("adc")
class ADCBackend:
    """Asymmetric navigation: float32 query vs decoded 2-bit signatures.

    Search-time ablation (§3.3 "Why not ADC for navigation?").  A node's
    own query representation is its unit-normalized decoded levels, and
    ``pairwise`` is decoded-levels inner products with the same
    calibration — so ADC-built graphs (construction in ADC space) work,
    not just ADC traversal of a symmetric-built graph.
    """

    def __init__(self, sigs: bq.Signature, *, route: str | None = None):
        self.sigs = sigs
        self.n = sigs.words.shape[0]
        self.dim = sigs.dim
        # non-negative calibration: |<q, levels>| <= ||levels|| <= 2*sqrt(D)
        # for unit q; the offset keeps the alpha-criterion well-defined.
        self._offset = 2.0 * jnp.sqrt(jnp.float32(sigs.dim))
        self.neutral_dist = float(self._offset)   # zero inner product

    @classmethod
    def from_arrays(cls, arrays: MetricArrays, *, route: str | None = None):
        assert arrays.sigs is not None, "adc needs packed signatures"
        return cls(arrays.sigs, route=route)

    @classmethod
    def encode(cls, x):
        return _unit(x)

    def _levels(self, ids):
        rows = bq.Signature(words=self.sigs.words[ids], dim=self.dim)
        return bq.decode_levels(rows)                # (..., K, D)

    def query_repr(self, ids):
        return _unit(self._levels(ids))

    def encode_queries(self, x):
        return self.encode(x)

    def dist_fn(self, query, ids, valid):
        return self._offset - self._levels(ids) @ query

    def dist_many(self, queries, ids, valid):
        levels = self._levels(ids)
        return self._offset - jnp.einsum("...d,...kd->...k", queries, levels)

    def pairwise(self, ids):
        levels = self._levels(ids)                   # (..., C, D)
        q = _unit(levels)
        sims = jnp.einsum("...cd,...ed->...ce", q, levels)
        return self._offset - sims


# legacy alias kept for external callers of the old protocol name
MetricBackend = MetricSpace
