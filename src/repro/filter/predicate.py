"""Label predicates: Any/All/Not expressions compiled to bitset masks.

A predicate is a small immutable expression tree over integer label ids
(``Any``/``All``/``Not``, leaves are labels).  Compilation produces a
jitted ``(n,)`` bool mask from the packed ``(n, W)`` uint32 label words
of a :class:`repro.filter.labels.LabelStore` — the predicate is a
*static* jit argument (frozen dataclasses hash structurally), so each
distinct expression shape traces once and every evaluation is packed
word ops (shift/AND/OR) right next to the XOR/popcount distances on the
hot path.

Selectivity estimation (``estimate_selectivity``) never touches the
mask: it works from per-label popcounts via the classic bounds —
union bound for ``Any``, min for ``All``, complement for ``Not`` — and
drives the graph-vs-brute-force routing in the search surfaces.
``entry_label`` picks the label whose per-label entry point (see
DESIGN.md §9) a filtered traversal should start from.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Union

import jax
import jax.numpy as jnp


class Predicate:
    """Base class for label expressions (see ``Any``/``All``/``Not``)."""

    __slots__ = ()


PredicateLike = Union[Predicate, int]


@dataclasses.dataclass(frozen=True)
class Label(Predicate):
    """Leaf: node carries label ``label``."""

    label: int


def as_predicate(expr: PredicateLike) -> Predicate:
    """Coerce a bare label id to a :class:`Label` leaf."""
    if isinstance(expr, Predicate):
        return expr
    if isinstance(expr, (int,)) and not isinstance(expr, bool):
        return Label(int(expr))
    raise TypeError(
        f"predicate must be Any/All/Not/Label or an int label id, "
        f"got {type(expr).__name__}"
    )


@dataclasses.dataclass(frozen=True, init=False)
class Any(Predicate):
    """Union: node carries at least one of the given labels/sub-exprs."""

    items: tuple[Predicate, ...]

    def __init__(self, *items: PredicateLike):
        if not items:
            raise ValueError("Any() needs at least one label")
        object.__setattr__(
            self, "items", tuple(as_predicate(i) for i in items)
        )


@dataclasses.dataclass(frozen=True, init=False)
class All(Predicate):
    """Intersection: node carries every given label/sub-expr."""

    items: tuple[Predicate, ...]

    def __init__(self, *items: PredicateLike):
        if not items:
            raise ValueError("All() needs at least one label")
        object.__setattr__(
            self, "items", tuple(as_predicate(i) for i in items)
        )


@dataclasses.dataclass(frozen=True, init=False)
class Not(Predicate):
    """Complement of a single label/sub-expr."""

    expr: Predicate

    def __init__(self, expr: PredicateLike):
        object.__setattr__(self, "expr", as_predicate(expr))


def labels_in(expr: PredicateLike) -> set[int]:
    """All label ids referenced anywhere in ``expr``."""
    expr = as_predicate(expr)
    if isinstance(expr, Label):
        return {expr.label}
    if isinstance(expr, (Any, All)):
        out: set[int] = set()
        for item in expr.items:
            out |= labels_in(item)
        return out
    assert isinstance(expr, Not)
    return labels_in(expr.expr)


# ---------------------------------------------------------------------------
# compilation: expression -> jitted (n,) bool mask over packed words
# ---------------------------------------------------------------------------


def _member_bits(words: jnp.ndarray, label: int) -> jnp.ndarray:
    w, b = divmod(label, 32)
    return ((words[..., w] >> jnp.uint32(b)) & jnp.uint32(1)) != 0


def _eval(words: jnp.ndarray, expr: Predicate) -> jnp.ndarray:
    if isinstance(expr, Label):
        return _member_bits(words, expr.label)
    if isinstance(expr, Any):
        return functools.reduce(
            jnp.logical_or, (_eval(words, i) for i in expr.items)
        )
    if isinstance(expr, All):
        return functools.reduce(
            jnp.logical_and, (_eval(words, i) for i in expr.items)
        )
    assert isinstance(expr, Not)
    return ~_eval(words, expr.expr)


@functools.partial(jax.jit, static_argnames=("expr",))
def eval_mask(words: jnp.ndarray, expr: Predicate) -> jnp.ndarray:
    """Packed label words ``(..., W)`` -> ``(...,)`` bool match mask.

    ``expr`` is static: one trace per expression structure, after which
    every evaluation is a handful of fused word ops.
    """
    return _eval(words, as_predicate(expr))


def validate(expr: PredicateLike, n_labels: int) -> Predicate:
    """Coerce + bounds-check every referenced label id."""
    expr = as_predicate(expr)
    bad = [lb for lb in labels_in(expr) if not 0 <= lb < n_labels]
    if bad:
        raise ValueError(
            f"predicate references labels {sorted(bad)} outside "
            f"[0, {n_labels})"
        )
    return expr


# ---------------------------------------------------------------------------
# selectivity estimation + entry-point routing (from label popcounts)
# ---------------------------------------------------------------------------

CountFn = Callable[[int], int]


def estimate_selectivity(
    expr: PredicateLike, count_fn: CountFn, n: int
) -> float:
    """Estimated match fraction of ``expr`` over ``n`` nodes.

    Pure popcount arithmetic (no mask evaluation): union bound for
    ``Any``, min for ``All``, complement for ``Not``.  Estimates are
    upper bounds under independence-free worst cases, which is the safe
    direction for routing: overestimating selectivity widens ``ef``
    less, underestimating never sends a huge match set to brute force.
    """
    if n <= 0:
        return 0.0
    expr = as_predicate(expr)
    if isinstance(expr, Label):
        return min(1.0, count_fn(expr.label) / n)
    if isinstance(expr, Any):
        return min(
            1.0,
            sum(estimate_selectivity(i, count_fn, n) for i in expr.items),
        )
    if isinstance(expr, All):
        return min(
            estimate_selectivity(i, count_fn, n) for i in expr.items
        )
    assert isinstance(expr, Not)
    return 1.0 - estimate_selectivity(expr.expr, count_fn, n)


def entry_label(expr: PredicateLike, count_fn: CountFn) -> int | None:
    """The label whose per-label entry point a filtered search should
    start from, or ``None`` when the predicate carries no positive
    label information (e.g. a bare ``Not``).

    ``All``: the most selective positively-required label — its region
    is the tightest superset of the match set.  ``Any``: the most
    populous branch — the largest reachable slice of the union.
    """
    expr = as_predicate(expr)
    if isinstance(expr, Label):
        return expr.label
    if isinstance(expr, Not):
        return None
    cands = [entry_label(i, count_fn) for i in expr.items]
    cands = [c for c in cands if c is not None]
    if not cands:
        return None
    if isinstance(expr, All):
        return min(cands, key=count_fn)
    return max(cands, key=count_fn)
