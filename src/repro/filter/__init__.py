"""Filtered search: predicate-aware BQ navigation (DESIGN.md §9).

Public surface:

* :class:`LabelStore` — packed per-node label bitsets (device-resident
  hot path) with per-label entry points;
* :class:`Any` / :class:`All` / :class:`Not` — label predicates,
  compiled to jitted packed-bitset masks;
* selectivity routing helpers (``estimate_selectivity``, ``route``,
  ``widened_ef``, ``brute_force_topk``, ``build_label_entries``).

Every search surface threads a ``filter=`` predicate down to the
two-mask beam search in ``repro.core.beam``: tombstones keep their
traverse-but-never-return semantics (``node_valid``) while the
predicate mask (``result_valid``) restricts what may be *returned*,
never what may be *traversed* — so filtered search over a mutable index
composes with deletes for free.
"""

from repro.filter.labels import (
    LabelStore,
    n_label_words,
    pack_label_rows,
)
from repro.filter.predicate import (
    All,
    Any,
    Label,
    Not,
    Predicate,
    as_predicate,
    entry_label,
    estimate_selectivity,
    eval_mask,
    labels_in,
    validate,
)
from repro.filter.search import (
    DEFAULT_SELECTIVITY_FLOOR,
    brute_force_topk,
    build_label_entries,
    route,
    widened_ef,
)

__all__ = [
    "All",
    "Any",
    "DEFAULT_SELECTIVITY_FLOOR",
    "Label",
    "LabelStore",
    "Not",
    "Predicate",
    "as_predicate",
    "brute_force_topk",
    "build_label_entries",
    "entry_label",
    "estimate_selectivity",
    "eval_mask",
    "labels_in",
    "n_label_words",
    "pack_label_rows",
    "route",
    "validate",
    "widened_ef",
]
