"""Filtered-search routing: widened graph search vs brute force.

The classic filtered-ANN cliff: graph traversal with a result mask
degrades as selectivity drops (ever more of the beam is spent on
non-matching nodes), while brute force over the match set gets *cheaper*
— at selectivity 0.01 a scan over matches touches 1% of the corpus with
perfect recall.  ``route`` picks the side of the cliff from the
popcount-estimated selectivity; ``widened_ef`` scales the beam so the
graph side keeps ~``ef`` *matching* candidates in flight; and
``brute_force_topk`` is the under-the-floor fallback (exact cosine when
cold vectors exist, backend distances otherwise — the same score
conventions as ``repro.core.index.rerank``).

``build_label_entries`` computes Filtered-Vamana-style per-label entry
points: the member-set medoid of every frequent label, stored alongside
the global medoid in the :class:`~repro.filter.labels.LabelStore`, so a
low-selectivity query starts *inside* its label region instead of
navigating to it from the global medoid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bq
from repro.core.linking import medoid_scan
from repro.core.metric import MetricSpace
from repro.filter.labels import LabelStore

# below this estimated selectivity, graph navigation falls off the
# filtered-ANN cliff and brute force over the match set wins
DEFAULT_SELECTIVITY_FLOOR = 0.05


def route(selectivity: float, floor: float) -> str:
    """``"graph"`` above the selectivity floor, ``"brute"`` below."""
    return "graph" if selectivity >= floor else "brute"


def widened_ef(ef: int, selectivity: float, floor: float, n: int) -> int:
    """Scale ``ef`` so ~``ef`` *matching* candidates stay in the beam.

    A result mask at selectivity s thins the live result list by ~s, so
    the beam widens by 1/s — clamped at 1/floor (below the floor the
    router brute-forces instead) and at ``n``.  The widening factor is
    quantized to an integer multiple of ``ef``: ``ef`` is a static jit
    argument, so a continuous-valued widening would retrace the beam on
    every selectivity drift under streaming churn; quantization bounds
    the distinct compile keys at ceil(1/floor) per base ``ef``.

    ``n`` caps only the *widening* — the result never drops below the
    caller's ``ef`` (a beam wider than a small live set just carries
    padding, while an ef below the rerank ``k`` would break top-k).
    """
    widen = min(1.0 / max(selectivity, 1e-9), 1.0 / floor)
    return max(ef, min(n, ef * int(np.ceil(widen))))


def _pad_pow2(ids: np.ndarray, lo: int = 64) -> np.ndarray:
    """-1-pad a match-id list to a power-of-two length (bounded traces)."""
    size = lo
    while size < len(ids):
        size *= 2
    out = np.full((size,), -1, dtype=np.int32)
    out[: len(ids)] = ids
    return out


@functools.partial(jax.jit, static_argnames=("k",))
def _brute_cosine(queries, vectors, match_ids, k):
    """Exact cosine top-k over a -1-padded match-id list."""
    from repro.plan.trace import note_trace
    note_trace("brute_cosine")
    safe = jnp.maximum(match_ids, 0)
    cand = vectors[safe]                               # (M, D)
    sims = queries @ cand.T                            # (Q, M)
    sims = jnp.where(match_ids[None, :] >= 0, sims, -jnp.inf)
    scores, pos = jax.lax.top_k(sims, k)
    ids = jnp.take_along_axis(
        jnp.broadcast_to(match_ids[None, :], sims.shape), pos, axis=-1
    )
    ids = jnp.where(jnp.isfinite(scores), ids, -1)
    return ids, scores


def brute_force_topk(
    queries: jnp.ndarray,          # (Q, D) float32, L2-normalized
    match_ids: np.ndarray,         # (M,) int32 matching node ids
    k: int,
    *,
    vectors: jnp.ndarray | None,
    backend: MetricSpace | None = None,
    reprs: jnp.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Exact top-k over the match set (the sub-floor fallback).

    With cold ``vectors`` the scores are cosine similarity (identical
    scale to the reranked graph path).  Without, ``backend``/``reprs``
    compute negated backend distances — the ``rerank=False`` scale of
    ``repro.core.index.topk_by_dist``.
    """
    nq = int(queries.shape[0])
    # route telemetry: brute-side query volume, next to the graph side's
    # plan counters (lazy leaf import, same pattern as note_trace)
    from repro.obs.metrics import get_default_registry
    get_default_registry().counter(
        "quiver_brute_queries_total",
        "queries served by the exact brute-force route",
    ).inc(nq)
    if len(match_ids) == 0:
        return (np.full((nq, k), -1, np.int32),
                np.full((nq, k), -np.inf, np.float32))
    # pad to >= k as well: top_k's k may not exceed the candidate axis
    # (missing hits come back as -1/-inf, same as the graph path)
    padded = jnp.asarray(
        _pad_pow2(np.asarray(match_ids, np.int32), lo=max(64, k))
    )
    if vectors is not None:
        ids, scores = _brute_cosine(queries, vectors, padded, k)
        return np.asarray(ids), np.asarray(scores)
    assert backend is not None and reprs is not None, (
        "brute force without cold vectors needs the metric backend"
    )
    valid = padded >= 0
    dists = jax.vmap(
        lambda q: backend.dist_fn(q, jnp.maximum(padded, 0), valid)
    )(reprs)
    dists = jnp.where(valid[None, :], dists, jnp.inf)
    scores, pos = jax.lax.top_k(-dists, k)
    ids = jnp.take_along_axis(
        jnp.broadcast_to(padded[None, :], dists.shape), pos, axis=-1
    )
    ids = jnp.where(jnp.isfinite(scores), ids, -1)
    return np.asarray(ids), np.asarray(scores)


def build_label_entries(
    store: LabelStore,
    backend: MetricSpace,
    *,
    vectors: jnp.ndarray | None = None,
    node_valid: jnp.ndarray | None = None,
    min_count: int = 32,
    chunk: int = 4096,
) -> int:
    """Fill ``store.entries`` with per-label medoids; returns how many.

    For every label whose member count is >= ``min_count`` (frequent
    labels — rare ones route to brute force anyway), the member set's
    centroid is encoded into the backend's query representation and a
    masked medoid scan picks the closest member.  ``node_valid``
    restricts members to live nodes (streaming).
    """
    built = 0
    counts = store.counts
    for label in range(store.n_labels):
        if counts[label] < min_count:
            store.entries[label] = -1
            continue
        member = store.member_mask(label)
        if node_valid is not None:
            member = member & node_valid
        member_f = member.astype(jnp.float32)
        denom = jnp.maximum(member_f.sum(), 1.0)
        if vectors is not None:
            c = (vectors * member_f[:, None]).sum(0) / denom
        else:
            levels = bq.decode_levels(backend.sigs)
            c = (levels * member_f[:, None]).sum(0) / denom
        centroid = backend.encode_queries(c[None])[0]
        store.entries[label] = int(
            medoid_scan(backend, centroid, chunk=chunk, node_valid=member)
        )
        built += 1
    return built
