"""LabelStore — per-node labels as packed device-resident bitsets.

One uint32 word row per node, ``W = ceil(n_labels / 32)`` words wide:
bit ``b`` of word ``w`` in row ``i`` means node ``i`` carries label
``w * 32 + b``.  The words array lives on the accelerator next to the
signature words — predicate evaluation is shift/AND/OR over the same
(n,)-shaped hot arrays the XOR/popcount distances stream, and it is
accounted as hot memory in every ``memory_breakdown``.

Attach modes (both host-driven, scatter-applied on device):

* **categorical** — one label id per node (``set``), the tenant /
  language / partition-key case;
* **multi-tag**   — a sequence of label ids per node (``set`` with
  lists, or ``add`` to OR tags into existing rows).

Per-label popcounts (``count`` / ``count_fn``) feed selectivity
estimation; ``entries`` holds the per-label entry points (medoid of
each frequent label's member set, Filtered-Vamana style — built by
:func:`repro.filter.search.build_label_entries`).  ``compact`` remaps
both through a freeze, and ``clear`` wipes reclaimed slots when the
streaming index consolidates.
"""

from __future__ import annotations

from typing import Iterable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.filter.predicate import PredicateLike, eval_mask, validate

WORD_BITS = 32


def n_label_words(n_labels: int) -> int:
    return (n_labels + WORD_BITS - 1) // WORD_BITS


def pack_label_rows(
    labels: Sequence, n_labels: int
) -> np.ndarray:
    """Per-node labels -> packed ``(B, W)`` uint32 rows (host side).

    ``labels`` is one entry per node: an int (categorical) or an
    iterable of ints (multi-tag).  Out-of-range ids raise.
    """
    w = n_label_words(n_labels)
    rows = np.zeros((len(labels), w), dtype=np.uint32)
    for i, item in enumerate(labels):
        ids = (item,) if np.isscalar(item) else tuple(item)
        for lb in ids:
            lb = int(lb)
            if not 0 <= lb < n_labels:
                raise ValueError(
                    f"label {lb} outside [0, {n_labels}) at row {i}"
                )
            rows[i, lb // WORD_BITS] |= np.uint32(1 << (lb % WORD_BITS))
    return rows


def popcount_rows(words: np.ndarray, n_labels: int) -> np.ndarray:
    """Packed ``(n, W)`` rows -> ``(n_labels,)`` per-label popcounts."""
    bits = np.unpackbits(
        words.view(np.uint8), axis=-1, bitorder="little"
    )                                            # (n, W*32)
    return bits[:, :n_labels].sum(axis=0).astype(np.int64)


class LabelStore:
    """Packed per-node label bitsets + per-label entry points."""

    def __init__(self, capacity: int, n_labels: int):
        if n_labels <= 0:
            raise ValueError(f"n_labels must be positive, got {n_labels}")
        self.capacity = int(capacity)
        self.n_labels = int(n_labels)
        self.n_words = n_label_words(n_labels)
        self.words = jnp.zeros(
            (self.capacity, self.n_words), dtype=jnp.uint32
        )
        # per-label entry points (Filtered-Vamana medoids); -1 == none
        self.entries = np.full((self.n_labels,), -1, dtype=np.int32)
        self._counts: np.ndarray | None = np.zeros(
            (self.n_labels,), dtype=np.int64
        )

    # -- constructors ------------------------------------------------------

    @classmethod
    def from_rows(
        cls,
        labels: Sequence,
        *,
        n_labels: int | None = None,
        capacity: int | None = None,
    ) -> "LabelStore":
        """Build a store from one label (or label list) per node."""
        if n_labels is None:
            flat: list[int] = []
            for item in labels:
                flat.extend(
                    (int(item),) if np.isscalar(item)
                    else (int(x) for x in item)
                )
            if not flat:
                raise ValueError(
                    "cannot infer n_labels from empty labels; pass "
                    "n_labels explicitly"
                )
            n_labels = max(flat) + 1
        out = cls(capacity or len(labels), n_labels)
        out.set(np.arange(len(labels), dtype=np.int32), labels)
        return out

    # -- mutation ----------------------------------------------------------

    def _rows_for(self, ids: np.ndarray, labels) -> np.ndarray:
        if np.isscalar(labels):
            labels = [labels] * len(ids)
        if len(labels) != len(ids):
            raise ValueError(
                f"{len(ids)} ids but {len(labels)} label entries"
            )
        return pack_label_rows(labels, self.n_labels)

    def _old_rows(self, dev_ids: jnp.ndarray) -> np.ndarray:
        return np.asarray(self.words[dev_ids])

    def _count_delta(self, old: np.ndarray, new: np.ndarray) -> None:
        """Incremental popcount update from the mutated rows only —
        never a full-store rescan on the mutation path."""
        if self._counts is None:
            return
        self._counts = (
            self._counts
            + popcount_rows(new, self.n_labels)
            - popcount_rows(old, self.n_labels)
        )

    @staticmethod
    def _dedup_or(ids: np.ndarray, rows: np.ndarray):
        """Collapse duplicate ids by OR-ing their rows: a scatter with
        duplicate indices keeps an arbitrary one, silently dropping
        tags."""
        uniq, inv = np.unique(ids, return_inverse=True)
        if len(uniq) == len(ids):
            return ids, rows
        combined = np.zeros((len(uniq), rows.shape[1]), dtype=np.uint32)
        np.bitwise_or.at(combined, inv, rows)
        return uniq.astype(np.int32), combined

    def set(self, ids, labels) -> None:
        """Overwrite the label rows of ``ids`` (categorical attach).

        ``labels``: one int / iterable-of-ints per id, or a single int
        applied to every id.  Duplicate ids within one batch OR their
        rows together (the batch is one logical assignment per node).
        """
        ids = np.atleast_1d(np.asarray(ids, dtype=np.int32))
        if len(ids) == 0:
            return
        rows = self._rows_for(ids, labels)
        ids, rows = self._dedup_or(ids, rows)
        dev = jnp.asarray(ids)
        old = self._old_rows(dev)
        self.words = self.words.at[dev].set(jnp.asarray(rows))
        self._count_delta(old, rows)

    def add(self, ids, labels) -> None:
        """OR labels into the existing rows of ``ids`` (multi-tag)."""
        ids = np.atleast_1d(np.asarray(ids, dtype=np.int32))
        if len(ids) == 0:
            return
        rows = self._rows_for(ids, labels)
        ids, rows = self._dedup_or(ids, rows)
        dev = jnp.asarray(ids)
        old = self._old_rows(dev)
        new = old | rows
        self.words = self.words.at[dev].set(jnp.asarray(new))
        self._count_delta(old, new)

    def clear(self, ids) -> None:
        """Zero the rows of ``ids`` (reclaimed streaming slots)."""
        ids = np.unique(np.atleast_1d(np.asarray(ids, dtype=np.int32)))
        if len(ids) == 0:
            return
        dev = jnp.asarray(ids)
        old = self._old_rows(dev)
        self.words = self.words.at[dev].set(jnp.uint32(0))
        self.entries[np.isin(self.entries, ids)] = -1
        self._count_delta(old, np.zeros_like(old))

    # -- queries -----------------------------------------------------------

    @property
    def counts(self) -> np.ndarray:
        """(n_labels,) per-label popcounts (cached between mutations)."""
        if self._counts is None:
            self._counts = popcount_rows(
                np.asarray(self.words), self.n_labels
            )
        return self._counts

    def count(self, label: int) -> int:
        return int(self.counts[label])

    def count_fn(self):
        """``label -> popcount`` callable for selectivity estimation."""
        counts = self.counts
        return lambda lb: int(counts[lb])

    def mask(self, expr: PredicateLike) -> jnp.ndarray:
        """Compiled predicate mask: ``(capacity,)`` bool on device."""
        return eval_mask(self.words, validate(expr, self.n_labels))

    def member_mask(self, label: int) -> jnp.ndarray:
        return self.mask(label)

    def labels_of(self, node: int) -> list[int]:
        """The label ids carried by ``node`` (host-side, for debugging)."""
        row = np.asarray(self.words[node])[None, :]
        bits = np.unpackbits(
            row.view(np.uint8), axis=-1, bitorder="little"
        )[0, : self.n_labels]
        return np.nonzero(bits)[0].tolist()

    def memory_bytes(self) -> int:
        return int(self.words.size * 4 + self.entries.size * 4)

    # -- lifecycle ---------------------------------------------------------

    def padded_to(self, capacity: int) -> "LabelStore":
        """A copy grown to ``capacity`` rows (mutable-index adoption)."""
        if capacity < self.capacity:
            raise ValueError(
                f"capacity {capacity} < store size {self.capacity}"
            )
        out = LabelStore(capacity, self.n_labels)
        out.words = out.words.at[: self.capacity].set(self.words)
        out.entries = self.entries.copy()
        out._counts = None
        return out

    def compact(self, live_idx: np.ndarray) -> "LabelStore":
        """Select rows ``live_idx`` and remap entries (freeze path)."""
        live_idx = np.asarray(live_idx)
        out = LabelStore(len(live_idx), self.n_labels)
        out.words = self.words[jnp.asarray(live_idx.astype(np.int32))]
        remap = np.full((self.capacity,), -1, dtype=np.int32)
        remap[live_idx] = np.arange(len(live_idx), dtype=np.int32)
        ent = self.entries.copy()
        ok = ent >= 0
        ent[ok] = remap[ent[ok]]
        out.entries = ent
        out._counts = None
        return out

    # -- persistence -------------------------------------------------------

    def to_npz_fields(self) -> dict:
        """Named npz fields (merged into the index archive)."""
        return {
            "label_words": np.asarray(self.words),
            "label_n": np.int64(self.n_labels),
            "label_entries": self.entries,
        }

    @classmethod
    def from_npz(cls, z) -> "LabelStore | None":
        """Rebuild from an index archive; None when it has no labels."""
        if "label_words" not in z:
            return None
        words = z["label_words"]
        out = cls(words.shape[0], int(z["label_n"]))
        out.words = jnp.asarray(words)
        out.entries = np.asarray(z["label_entries"], dtype=np.int32)
        out._counts = None
        return out


def iter_label_lists(labels: Sequence) -> Iterable[tuple[int, ...]]:
    """Normalize a per-node label column to tuples (test/bench helper)."""
    for item in labels:
        yield (int(item),) if np.isscalar(item) else tuple(
            int(x) for x in item
        )
