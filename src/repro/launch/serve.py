"""Serving entry point: batched generation, optionally QuIVer-RAG.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-34b --smoke \
        --batch 4 --max-new 16 [--rag]

Full-size configs require a production mesh (>=256 devices); locally use
``--smoke``. The dry-run path for serving shapes is
``repro.launch.dryrun --shape decode_32k``.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.serve.engine import Retriever, ServeEngine, mean_pool_embedder


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--rag", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    elif len(jax.devices()) < 256:
        print(f"[serve] full config {cfg.name} needs a production mesh; "
              f"found {len(jax.devices())} devices. Use --smoke locally.")
        return
    if cfg.family == "encdec":
        print("[serve] use examples/ for enc-dec serving "
              "(needs frame inputs); decoder-family archs only here.")
        return

    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(bundle, params, max_seq=args.max_seq)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)
    ).astype(np.int32)
    extra = None
    if cfg.frontend == "patch_stub":
        extra = {"patches": jax.numpy.asarray(
            rng.standard_normal(
                (args.batch, cfg.n_frontend_tokens, cfg.d_model)
            ), jax.numpy.bfloat16)}

    retriever = None
    if args.rag:
        from repro.core.index import QuIVerIndex
        from repro.core.vamana import BuildParams
        embed_fn = mean_pool_embedder(bundle, params)
        corpus = rng.integers(0, cfg.vocab_size, (256, 8)).astype(np.int32)
        emb = np.asarray(embed_fn(jax.numpy.asarray(corpus)))
        index = QuIVerIndex.build(
            jax.numpy.asarray(emb),
            BuildParams(m=4, ef_construction=24, prune_pool=24, chunk=128),
        )
        retriever = Retriever(index=index, doc_tokens=corpus,
                              embed_fn=embed_fn, k=2, ef=32)
        print(f"[serve] RAG enabled over {len(corpus)} docs")

    t0 = time.perf_counter()
    out = engine.generate(
        prompts, max_new=args.max_new, retriever=retriever,
        temperature=args.temperature, seed=args.seed, extra_batch=extra,
    )
    dt = time.perf_counter() - t0
    for i, row in enumerate(out):
        print(f"[serve] seq {i}: {row.tolist()}")
    print(f"[serve] {out.size} tokens in {dt:.2f}s "
          f"({out.size/dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
