"""Training entry point.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
        --smoke --steps 50 --ckpt-dir /tmp/ckpt

``--smoke`` trains the reduced config on local devices; the full config
path expects a real fleet (device count >= mesh size) and otherwise
exits after printing the plan — the dry-run (``repro.launch.dryrun``)
is the no-hardware validation path.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd"])
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    elif len(jax.devices()) < 256:
        print(f"[train] full config {cfg.name} needs a production mesh; "
              f"found {len(jax.devices())} devices. Use --smoke locally "
              f"or repro.launch.dryrun for no-hardware validation.")
        return

    # minicpm's paper schedule is WSD; honor it by default
    schedule = "wsd" if "minicpm" in cfg.name else args.schedule

    bundle = build_model(cfg)
    tc = TrainConfig(
        n_micro=args.n_micro,
        peak_lr=args.lr,
        total_steps=args.steps,
        schedule=schedule,
        adamw=AdamWConfig(),
        compress_grads=args.compress_grads,
    )
    pipeline = TokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size,
        seq_len=args.seq_len,
        global_batch=args.global_batch,
    ))
    trainer = Trainer(
        bundle, tc,
        TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every),
        pipeline,
    )
    result = trainer.run()
    for m in result["metrics"]:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.3f}  {m['seconds']*1e3:.0f} ms")
    print(f"[train] done at step {result['final_step']}; "
          f"stragglers flagged: {len(result['stragglers'])}")


if __name__ == "__main__":
    main()
