import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the
device count at first init); everything below is ordinary code.

For each cell this driver:
  1. builds the model bundle and ShapeDtypeStruct input specs,
  2. jits the train/prefill/decode step with production shardings,
  3. ``.lower().compile()`` on the 16x16 (and optionally 2x16x16) mesh,
  4. prints ``memory_analysis()`` + ``cost_analysis()`` and writes the
     roofline terms to ``experiments/dryrun/<cell>.json``.

Usage:
    python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all --mesh both
"""

import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs.base import (
    SHAPES, all_configs, cell_is_supported, get_config,
)
from repro.dist.cache_sharding import batch_shardings, cache_shardings
from repro.dist.sharding import (
    param_shardings, serve_param_shardings, use_mesh,
)
from repro.launch.mesh import dp_size, make_production_mesh
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.tools.jaxpr_cost import trace_cost
from repro.tools.roofline import analyze, model_flops_for
from repro.train.train_step import (
    TrainConfig, make_train_step, suggest_n_micro,
)

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _opt_dtype(cfg):
    # bf16 optimizer state for >=30B models (DESIGN.md §7 memory budget)
    import jax.numpy as jnp
    return jnp.bfloat16 if cfg.param_count() >= 30e9 else jnp.float32


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               n_micro: int | None = None):
    """Lower + compile one cell; returns (compiled, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_supported(cfg, shape)
    if not ok:
        return None, {"skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    bundle = build_model(cfg)
    specs = bundle.input_specs(shape)
    t0 = time.perf_counter()

    with mesh, use_mesh(mesh):
        p_shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
        p_sh = param_shardings(mesh, p_shapes)
        b_sh = batch_shardings(mesh, specs)

        if shape.kind == "train":
            nm = n_micro or suggest_n_micro(cfg, shape, dp_size(mesh))
            tc = TrainConfig(
                n_micro=nm, adamw=AdamWConfig(state_dtype=_opt_dtype(cfg))
            )
            step_fn = make_train_step(bundle, tc)
            o_shapes = jax.eval_shape(
                lambda p: init_opt_state(p, tc.adamw), p_shapes
            )
            from jax.sharding import NamedSharding, PartitionSpec as P
            o_sh = {
                "mu": p_sh, "nu": p_sh,
                "count": NamedSharding(mesh, P()),
            }
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_sh, o_sh, b_sh),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(p_shapes, o_shapes, specs)
            jcost = trace_cost(step_fn, p_shapes, o_shapes, specs)
            mode = f"train_step(n_micro={nm})"
        else:
            # serving layout: TP-only params when they fit (no per-layer
            # FSDP gathers on the decode path)
            p_sh = serve_param_shardings(mesh, p_shapes, cfg.param_count())
            c_shapes = jax.eval_shape(
                lambda: bundle.init_caches(shape.global_batch,
                                           shape.seq_len)
            )
            c_sh = cache_shardings(mesh, c_shapes, shape.global_batch)
            if shape.kind == "prefill":
                jitted = jax.jit(
                    bundle.prefill,
                    in_shardings=(p_sh, b_sh, c_sh),
                    donate_argnums=(2,),
                )
                lowered = jitted.lower(p_shapes, specs, c_shapes)
                jcost = trace_cost(bundle.prefill, p_shapes, specs, c_shapes)
                mode = "prefill_step"
            else:
                tok = jax.ShapeDtypeStruct(
                    (shape.global_batch, 1), jax.numpy.int32
                )
                pos = jax.ShapeDtypeStruct((), jax.numpy.int32)
                jitted = jax.jit(
                    bundle.decode,
                    in_shardings=(
                        p_sh, batch_shardings(mesh, {"t": tok})["t"],
                        c_sh, None,
                    ),
                    donate_argnums=(2,),
                )
                lowered = jitted.lower(p_shapes, tok, c_shapes, pos)
                jcost = trace_cost(bundle.decode, p_shapes, tok, c_shapes, pos)
                mode = "serve_step(decode)"

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    meta = {
        "jaxpr_costs": jcost,
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": mode,
        "lower_seconds": round(t_lower, 1),
        "compile_seconds": round(t_compile, 1),
    }
    return compiled, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cell = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    try:
        compiled, meta = lower_cell(arch, shape_name, multi_pod)
    except Exception as e:  # a failure here is a bug in the system
        traceback.print_exc()
        return {"cell": cell, "error": f"{type(e).__name__}: {e}"}

    if compiled is None:
        report = {"cell": cell, **meta}
    else:
        n_chips = 512 if multi_pod else 256
        jcost = meta.pop("jaxpr_costs")
        report = analyze(
            compiled, n_chips=n_chips,
            model_flops=model_flops_for(cfg, shape),
            jaxpr_costs=jcost,
        )
        report["jaxpr_global"] = {
            "flops": jcost["flops"], "bytes": jcost["bytes"],
        }
        report.update(meta)
        report["cell"] = cell
        print(f"[dryrun] {cell}: memory_analysis="
              f"{report.get('memory_analysis')}")
        print(f"[dryrun] {cell}: flops/dev={report['flops_per_device']:.3e}"
              f" bytes/dev={report['bytes_per_device']:.3e}"
              f" coll_bytes/dev={report['collectives']['total_bytes']:.3e}")
        print(f"[dryrun] {cell}: terms={report['terms_seconds']}"
              f" dominant={report['dominant']}")

    if out_dir:
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{cell}.json").write_text(json.dumps(report, indent=2))
    return report


def run_quiver_cell(multi_pod: bool,
                    out_dir: pathlib.Path | None = None) -> dict:
    """Lower + compile the paper's own workload on the production mesh:
    1M x 768 sharded QuIVer fan-out search (256 queries, ef=64, k=10).

    The index is sharded over every mesh axis (4096 vectors/chip at 256
    chips); per-chip hot set = 4096 x (192 B sigs + 288 B adjacency)
    ~ 2 MB — HBM-resident with room to spare (DESIGN.md §7)."""
    import jax.numpy as jnp
    from repro.core import bq
    from repro.core.distributed import make_sharded_search
    from repro.tools.jaxpr_cost import trace_cost

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    cell = f"quiver-1m__search_ef64__{'2x16x16' if multi_pod else '16x16'}"
    dim, ef, k, q = 768, 64, 10, 256
    n_per_shard = 1_048_576 // n_chips
    axes = tuple(mesh.axis_names)
    w2 = 2 * bq.n_words(dim)

    t0 = time.perf_counter()
    fn = make_sharded_search(
        mesh, dim=dim, ef=ef, k=k, n_per_shard=n_per_shard, axis=axes
    )
    sig = jax.ShapeDtypeStruct((n_chips, n_per_shard, w2), jnp.uint32)
    adj = jax.ShapeDtypeStruct((n_chips, n_per_shard, 72), jnp.int32)
    med = jax.ShapeDtypeStruct((n_chips,), jnp.int32)
    vec = jax.ShapeDtypeStruct((n_chips, n_per_shard, dim), jnp.float32)
    liv = jax.ShapeDtypeStruct((n_chips, n_per_shard), jnp.bool_)
    # filter-predicate result mask (all-True when serving unfiltered):
    # same shape as the tombstone mask, one per shard
    rvd = jax.ShapeDtypeStruct((n_chips, n_per_shard), jnp.bool_)
    qw = jax.ShapeDtypeStruct((q, w2), jnp.uint32)
    qf = jax.ShapeDtypeStruct((q, dim), jnp.float32)
    try:
        with mesh:
            lowered = jax.jit(fn).lower(sig, adj, med, vec, liv, rvd,
                                        qw, qf)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
            jcost = trace_cost(fn, sig, adj, med, vec, liv, rvd, qw, qf,
                               while_trip_hint=4 * ef + 128)
    except Exception as e:
        traceback.print_exc()
        return {"cell": cell, "error": f"{type(e).__name__}: {e}"}

    report = analyze(
        compiled, n_chips=n_chips,
        # "useful work": Q queries x hops x R neighbour distances x 2D
        # bit-ops-equivalent + rerank GEMV flops
        model_flops=float(q * (4 * ef + 128) * 72 * 2 * dim
                          + q * ef * 2 * dim),
        jaxpr_costs=jcost,
    )
    report.update({
        "cell": cell, "arch": "quiver-1m", "shape": "search_ef64",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": "sharded_search(while_hint=%d)" % (4 * ef + 128),
        "lower_seconds": round(t_lower, 1),
        "compile_seconds": round(t_compile, 1),
    })
    print(f"[dryrun] {cell}: terms={report['terms_seconds']} "
          f"dominant={report['dominant']}")
    if out_dir:
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{cell}.json").write_text(json.dumps(report, indent=2))
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    if args.arch == "quiver-1m":
        meshes = {"pod": [False], "multipod": [True],
                  "both": [False, True]}[args.mesh]
        failures = 0
        for mp in meshes:
            rep = run_quiver_cell(mp, pathlib.Path(args.out))
            if "error" in rep:
                failures += 1
        raise SystemExit(failures)

    archs = sorted(all_configs()) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    out_dir = pathlib.Path(args.out)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rep = run_cell(arch, shape, mp, out_dir)
                if "error" in rep:
                    failures += 1
                    print(f"[dryrun] FAIL {rep['cell']}: {rep['error']}")
                elif "skipped" in rep:
                    print(f"[dryrun] SKIP {rep['cell']}: {rep['skipped']}")
                else:
                    print(f"[dryrun] OK   {rep['cell']} "
                          f"(compile {rep['compile_seconds']}s)")
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
