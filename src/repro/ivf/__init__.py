"""IVF-over-BQ coarse routing (DESIGN.md §13).

Training-free inverted lists in 2-bit Sign-Magnitude space: BQ-medoid
centroids, contiguous list layout, and kernel-dispatched list scans —
the build accelerator (``BuildParams(ivf_candidates=True)``), the
``nav="ivf"`` plan family, and the targeted-scatter shard unit.
"""

from repro.ivf.partition import (
    IVFPartition,
    build_partition,
    default_n_lists,
)
from repro.ivf.search import (
    list_candidates,
    record_routes,
    scan_search,
    top_lists,
)

__all__ = [
    "IVFPartition",
    "build_partition",
    "default_n_lists",
    "list_candidates",
    "record_routes",
    "scan_search",
    "top_lists",
]
