"""IVF list-scan search primitives (DESIGN.md §13).

The jit-composable building blocks of the ``nav="ivf"`` family: scan
the centroid signatures with the batched list-scan kernel, keep the
top-p lists, gather their (disjoint) members from the padded
``list_ids`` view, score them with the registered metric backend, and
keep the best ef — the flat two-stage alternative to graph traversal,
racing it on the same plan/rerank/margin machinery.

These are free functions over traced arrays (``cent_words`` /
``list_ids`` enter as program arguments, exactly like ``adjacency``
does on the graph route) so ``plan.cache`` fuses them into one compiled
program per plan and the construction seeder (``core.vamana``) reuses
``top_lists``/``list_candidates`` inside its own jitted chunk op.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

INF = jnp.float32(3.0e38)

# shards-contacted histogram boundaries: powers of two up to fleet
# sizes far beyond anything the host-driven scatter will see
_SCATTER_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def record_routes(top, shards_contacted=None, *, registry=None):
    """Record per-list routing counters (DESIGN.md §13 observability).

    ``top`` is the (Q, p) probed-list array of one batch;
    ``shards_contacted`` (optional, (Q,)) is how many shards each
    query's targeted scatter touched.  Feeds
    ``quiver_ivf_list_routes_total{list}`` and the
    ``quiver_ivf_scatter_shards`` histogram on ``registry`` (default
    process registry), making skewed list popularity and fan-out width
    visible on the fleet scrape.
    """
    from repro.obs.metrics import get_default_registry

    reg = registry if registry is not None else get_default_registry()
    routes = reg.counter(
        "quiver_ivf_list_routes_total",
        "IVF probes routed to this coarse list",
        labels=("list",),
    )
    counts = np.bincount(np.asarray(top).ravel())
    for lst in np.nonzero(counts)[0]:
        routes.inc(int(counts[lst]), list=int(lst))
    if shards_contacted is not None:
        reg.histogram(
            "quiver_ivf_scatter_shards",
            "shards contacted per query by targeted scatter",
            buckets=_SCATTER_BUCKETS,
        ).observe_many(np.asarray(shards_contacted))


def top_lists(scan, reprs, cent_words, p: int) -> jnp.ndarray:
    """(Q, 2W) query signatures -> (Q, p) nearest-list ids.

    ``scan`` is a bound ``ListScanOps.scan`` (kernel-dispatched); the
    similarity is int32 Table-1, larger = nearer.
    """
    sim = scan(reprs, cent_words)
    _, top = jax.lax.top_k(sim, p)
    return top


def list_candidates(backend, reprs, list_ids, top):
    """Gather + score the members of each query's top-p lists.

    Returns ((Q, p*cap) member ids with -1 padding, (Q, p*cap) float32
    distances, INF on padding).  Lists partition the corpus, so the
    gathered members are disjoint across a query's p lists — no dedup
    stage is needed before top-k.
    """
    q = top.shape[0]
    mem = list_ids[top].reshape(q, -1)
    valid = mem >= 0
    d = backend.dist_many(reprs, jnp.maximum(mem, 0), valid)
    d = jnp.where(valid, d, INF)
    return mem, d


def scan_search(
    backend,
    scan,
    reprs,
    cent_words,
    list_ids,
    *,
    probes: int,
    ef: int,
    result_valid=None,
):
    """Full IVF candidate stage: (Q, 2W) reprs -> ((Q, ef') ids, dists).

    ``ef'`` = min(ef, probes*cap) — a plan cannot ask for more
    candidates than its probed lists hold; short pools surface as -1
    ids / INF dists, which downstream rerank and ``beam_margin``
    already treat as starvation (margin -1 -> escalation widens p).
    ``result_valid`` (optional (N,) bool) is the filtered route's
    predicate mask: non-matching members never surface, mirroring the
    beam's result mask semantics.
    """
    top = top_lists(scan, reprs, cent_words, probes)
    mem, d = list_candidates(backend, reprs, list_ids, top)
    if result_valid is not None:
        d = jnp.where(result_valid[jnp.maximum(mem, 0)], d, INF)
    ef_eff = min(ef, mem.shape[1])
    neg, pos = jax.lax.top_k(-d, ef_eff)
    ids = jnp.take_along_axis(mem, pos, axis=-1)
    dists = -neg
    ids = jnp.where(dists < INF / 2, ids, -1)
    return ids, dists
