"""IVF-over-BQ: k-means-free coarse partition in signature space.

The partition layer of DESIGN.md §13: split the corpus into L ≈ √N
inverted lists whose centroids are *real node signatures* chosen by
BQ medoid sampling — no k-means, no float training pass, keeping the
paper's training-free claim intact end to end:

1. a seeded permutation yields L *seed signatures* — one uniform draw
   per random shard, so seed density follows data density (a mean- or
   medoid-of-shard seed would clump at the corpus centroid: a random
   shard's mean IS the global mean, up to 1/√|shard| noise);
2. a few rounds of *majority-vote refinement* over a node subsample:
   each round assigns the subsample to the current centroids with the
   batched list-scan kernel (``kernels.dispatch.list_scan_ops``),
   then recomputes every list's majority signature — the re-encoded
   mean of its sampled members' decoded ±1/±2 levels, a closed-form
   bitwise majority with no learned parameters, the same construction
   ``core.vamana`` uses for the global entry medoid.  Refinement only
   shapes the centroids, so it runs on ~32·L nodes instead of all N
   (majorities are stable from a few dozen members per list); routing
   quality plateaus after 2-3 rounds and measured on the green
   surrogate corpora it matches a float k-means partition's list
   coverage, i.e. the signature-space partition is at the IVF ceiling
   for the data;
3. one full assignment scan maps every node to its nearest refined
   majority signature — the only O(N·L) pass in the build, which is
   what keeps IVF-assisted construction near-linear;
4. the final layout is contiguous: ``member_ids`` is one (N,)
   permutation, ``offsets`` its (L+1,) prefix — the canonical
   persisted layout — and ``list_ids`` the (L, cap) padded device
   view the fused search programs gather from.  ``cent_words`` keeps
   the majority signatures (they route better than any single member
   can); ``cent_ids`` snaps each list to its nearest *real member*
   via ``linking.shard_medoids`` — the list's medoid, used as entry
   seed and provenance.

Everything downstream (construction seeding, the ``nav="ivf"`` plan
route, targeted scatter) consumes this one object.  Determinism: the
partition is a pure function of (signatures, n_lists, seed, sample).
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.core import bq, linking
from repro.core.metric import MetricArrays, make_backend
from repro.kernels import dispatch

_PREFIX = "ivf_"
_ASSIGN_CHUNK = 8192
# refinement subsample: ~this many members per list feed each round's
# majority vote (the final assignment always scans every node)
_REFINE_PER_LIST = 32
# capacity-bounded assignment keeps this many ranked list choices per
# node before falling back to the globally emptiest list
_BALANCE_PREFS = 8


def default_n_lists(n: int) -> int:
    """≈√N lists (each list ≈ √N members), clamped for tiny corpora."""
    return max(2, min(n, round(math.sqrt(max(n, 1)))))


@dataclasses.dataclass
class IVFPartition:
    """The coarse list structure (hot: ``cent_words`` + ``list_ids``).

    ``member_ids``/``offsets`` are the canonical contiguous layout
    (list l's members are ``member_ids[offsets[l]:offsets[l+1]]``);
    ``list_ids`` is the derived (L, cap) -1-padded device view that the
    fused programs gather with a single ``list_ids[top_p]`` — cap is
    the max list population rounded up to a lane-friendly multiple.
    """

    cent_words: jnp.ndarray          # (L, 2W) uint32 — device-hot
    list_ids: jnp.ndarray            # (L, cap) int32, -1 padded — device-hot
    cent_ids: np.ndarray             # (L,) int32 medoid node ids
    assign: np.ndarray               # (N,) int32 list id per node
    offsets: np.ndarray              # (L+1,) int64 contiguous-layout prefix
    member_ids: np.ndarray           # (N,) int32 contiguous layout
    dim: int
    seed: int = 0

    @property
    def n_lists(self) -> int:
        return int(self.cent_words.shape[0])

    @property
    def cap(self) -> int:
        return int(self.list_ids.shape[1])

    @property
    def default_probes(self) -> int:
        """Serve-time top-p default: ≈L/3 probed lists.

        Flat coarse routing trades scan fraction for recall — on
        corpora without strong coarse cluster structure (the green
        surrogates), list coverage of the true top-k grows roughly
        linearly in p, so the serve default probes a third of the
        lists and leaves escalation (plan ``escalate_mult``) room to
        widen toward the exact-bq2 ceiling at p = L.
        """
        return min(self.n_lists, max(2, -(-self.n_lists // 3)))

    @property
    def build_probes(self) -> int:
        """Construction-time top-p default: ≈4√L probed lists.

        Build candidate pools only need *approximate* locality — the
        alpha-prune keeps diverse survivors and the random long-edge
        mix-in restores reachability — so construction probes
        O(√L) = O(N^(1/4)) lists, a vanishing fraction of L as the
        corpus grows; with the capacity-bounded cap (≈1.5·N/L) the
        per-node pool is O(N^(3/4)) candidates instead of the O(N)
        a whole-graph beam search touches, which is where the
        sub-quadratic build time comes from.  The 4× multiplier is
        empirical: it buys graph quality within a point of the
        beam-seeded build while staying well under its cost.
        """
        return min(self.n_lists,
                   max(2, round(4 * math.sqrt(self.n_lists))))

    def memory_bytes(self) -> int:
        """Hot bytes of the IVF tier (centroid signatures + list
        layout) — what ``memory_breakdown`` reports."""
        return int(
            self.cent_words.size * 4
            + self.list_ids.size * 4
            + self.offsets.size * 8
        )

    # -- persistence (merged into index npz archives) ----------------------

    def to_npz_fields(self, prefix: str = _PREFIX) -> dict:
        return {
            prefix + "cent_words": np.asarray(self.cent_words),
            prefix + "cent_ids": self.cent_ids,
            prefix + "assign": self.assign,
            prefix + "offsets": self.offsets,
            prefix + "member_ids": self.member_ids,
            prefix + "dim": np.int64(self.dim),
            prefix + "seed": np.int64(self.seed),
            prefix + "cap": np.int64(self.cap),
        }

    @classmethod
    def from_npz(cls, z, prefix: str = _PREFIX):
        """Rebuild from an index archive; None when it carries none."""
        if prefix + "cent_words" not in z:
            return None
        assign = z[prefix + "assign"].astype(np.int32)
        offsets = z[prefix + "offsets"].astype(np.int64)
        member_ids = z[prefix + "member_ids"].astype(np.int32)
        return cls(
            cent_words=jnp.asarray(z[prefix + "cent_words"]),
            list_ids=jnp.asarray(_layout_to_list_ids(
                member_ids, offsets, int(z[prefix + "cap"][()])
            )),
            cent_ids=z[prefix + "cent_ids"].astype(np.int32),
            assign=assign,
            offsets=offsets,
            member_ids=member_ids,
            dim=int(z[prefix + "dim"][()]),
            seed=int(z[prefix + "seed"][()]),
        )


def _layout_to_list_ids(member_ids, offsets, cap) -> np.ndarray:
    """Contiguous layout -> (L, cap) padded gather view."""
    n_lists = offsets.shape[0] - 1
    out = np.full((n_lists, cap), -1, dtype=np.int32)
    counts = np.diff(offsets)
    rank = np.arange(member_ids.shape[0]) - np.repeat(offsets[:-1], counts)
    rows = np.repeat(np.arange(n_lists), counts)
    out[rows, rank] = member_ids
    return out


def build_partition(
    sigs: bq.Signature,
    *,
    n_lists: int | None = None,
    seed: int = 0,
    sample: int = 256,
    refine: int = 3,
    balance: float | None = 1.5,
    route: str | None = None,
) -> IVFPartition:
    """Partition ``sigs`` into L inverted lists (see module docstring).

    ``sample`` bounds how many list members feed each majority
    signature (decode cost is O(L·sample·D) per round; medoid
    selection and the final assignment always see every member);
    ``refine`` is the number of majority-vote rounds, each run on a
    subsample so the only O(N·L) pass is the final assignment scan.
    ``balance`` caps every list at ``ceil(balance · N/L)`` members in
    the final assignment (None disables): nodes claim their nearest
    list in confidence order (sim margin between 1st and 2nd choice,
    descending) and spill to their next choice once a list is full.
    Everything downstream pays O(p · cap) per probe, so the padded cap
    — not the mean list size — is the real scan cost; capacity-bounded
    assignment keeps cap within ~``balance``× of the mean instead of
    letting one dense cluster set it.  Deterministic under fixed
    ``seed``.
    """
    n = sigs.words.shape[0]
    n_lists = n_lists or default_n_lists(n)
    n_lists = max(2, min(n_lists, n))
    backend = make_backend("bq2", MetricArrays(sigs=sigs), route=route)
    ops = dispatch.list_scan_ops(sigs.dim, route=route)

    def assign_to(words, cent_words) -> np.ndarray:
        m = words.shape[0]
        out = np.empty((m,), dtype=np.int32)
        for s in range(0, m, _ASSIGN_CHUNK):
            block = words[s:s + _ASSIGN_CHUNK]
            sim = ops.scan(block, cent_words)
            out[s:s + block.shape[0]] = np.asarray(
                jnp.argmax(sim, axis=-1)
            )
        return out

    def assign_capped(words, cent_words, frac: float) -> np.ndarray:
        """Greedy capacity-bounded assignment (see ``balance``)."""
        m = words.shape[0]
        k = min(_BALANCE_PREFS, n_lists)
        pref = np.empty((m, k), dtype=np.int32)
        psim = np.empty((m, k), dtype=np.float32)
        for s in range(0, m, _ASSIGN_CHUNK):
            block = words[s:s + _ASSIGN_CHUNK]
            # host-side top-k: the sim block is tiny (rows x L) and
            # np.argpartition beats compiling a device top_k for it
            sim = np.asarray(ops.scan(block, cent_words))
            part_k = np.argpartition(-sim, k - 1, axis=-1)[:, :k]
            vals = np.take_along_axis(sim, part_k, axis=-1)
            order_k = np.argsort(-vals, axis=-1, kind="stable")
            pref[s:s + block.shape[0]] = np.take_along_axis(
                part_k, order_k, axis=-1
            )
            psim[s:s + block.shape[0]] = np.take_along_axis(
                vals, order_k, axis=-1
            )
        margin = psim[:, 0] - (psim[:, 1] if k > 1 else 0.0)
        order = np.argsort(-margin, kind="stable")
        cap_limit = max(8, -(-int(m * frac) // n_lists))
        counts = np.zeros((n_lists,), dtype=np.int64)
        out = np.empty((m,), dtype=np.int32)
        for i in order:
            for li in pref[i]:
                if counts[li] < cap_limit:
                    out[i] = li
                    counts[li] += 1
                    break
            else:
                # all k preferred lists full: take the emptiest
                li = int(np.argmin(counts))
                out[i] = li
                counts[li] += 1
        return out

    def layout(assign):
        member_ids = np.argsort(assign, kind="stable").astype(np.int32)
        counts = np.bincount(assign, minlength=n_lists)
        offsets = np.zeros((n_lists + 1,), dtype=np.int64)
        np.cumsum(counts, out=offsets[1:])
        cap = max(8, int(-(-int(counts.max()) // 8) * 8))
        return member_ids, counts, offsets, cap

    # 1. density-following seeds: one uniform draw per random shard
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n).astype(np.int32)
    per = -(-n // n_lists)                         # ceil division
    padded = (np.concatenate([perm, perm[:per * n_lists - n]])
              if per * n_lists - n else perm)
    seed_ids = padded.reshape(n_lists, per)[:, 0].copy()

    # 2. majority-vote refinement on a subsample: each round assigns
    # the subsample to the current centroids, then every non-empty
    # list's routing centroid becomes the re-encoded mean of its
    # sampled members' decoded levels — a closed-form bitwise majority
    cent_words = sigs.words[jnp.asarray(seed_ids)]
    r_n = min(n, max(_REFINE_PER_LIST * n_lists, 2048))
    sub_ids = np.sort(perm[:r_n])
    sub_words = sigs.words[jnp.asarray(sub_ids)]
    for _ in range(max(refine, 0)):
        assign_s = assign_to(sub_words, cent_words)
        member_s, counts_s, offsets_s, cap_s = layout(assign_s)
        grid = jnp.asarray(_layout_to_list_ids(
            member_s, offsets_s, cap_s
        ))[:, : min(cap_s, max(8, sample))]
        levels = bq.decode_levels(
            bq.Signature(words=sub_words[jnp.maximum(grid, 0)],
                         dim=sigs.dim)
        )                                          # (L, S', D)
        ok = (grid >= 0)[..., None]
        mean = (
            jnp.where(ok, levels, 0.0).sum(axis=1)
            / jnp.maximum(ok.sum(axis=1), 1)
        )
        majority = backend.encode_queries(mean)
        # empty lists keep their previous signature (stay recoverable)
        cent_words = jnp.where(
            (counts_s > 0)[:, None], majority, cent_words
        )

    # 3. the single full assignment scan + contiguous layout; the
    # capacity bound keeps cap (the per-probe scan cost) near the mean
    if balance is not None:
        assign = assign_capped(sigs.words, cent_words, balance)
    else:
        assign = assign_to(sigs.words, cent_words)
    member_ids, counts, offsets, cap = layout(assign)
    prov = jnp.asarray(_layout_to_list_ids(member_ids, offsets, cap))

    # 4. snap each list to its nearest real member for provenance /
    # entry seeding; routing keeps the majority signatures
    medoids = np.asarray(
        linking.shard_medoids(backend, cent_words, prov)
    ).astype(np.int32)
    cent_ids = np.where(counts > 0, medoids, seed_ids).astype(np.int32)
    list_ids = np.asarray(prov)

    return IVFPartition(
        cent_words=cent_words,
        list_ids=jnp.asarray(list_ids),
        cent_ids=cent_ids,
        assign=assign,
        offsets=offsets,
        member_ids=member_ids,
        dim=sigs.dim,
        seed=seed,
    )
