"""Incremental probe statistics for the streaming lifecycle.

A :class:`ProbeAccumulator` maintains the *exact* per-dimension bit-plane
counts of the live set under insert/delete — O(B·D) work per mutation
batch, never a full-store rescan — so a mutable index always knows its
sign/magnitude entropy without re-probing.  The counts are computed from
the packed signature words themselves (the planes ARE the statistics),
which means the accumulator works on vector-free indexes too and a
from-scratch recompute over the live rows reproduces it exactly:

    acc == ProbeAccumulator.from_words(words[live], dim)

Consolidation is a no-op for the accumulator: deletes already removed
the dead rows' counts, and reclaiming slots only clears storage the
accumulator never counted.

The expensive sampled statistics (cosine spread, BQ agreement) are NOT
maintained incrementally — they are recomputed on demand from a live
sample (``MutableQuIVerIndex.probe_report``), with the entropy fields
taken from this accumulator (exact over the whole live set, not a
sample).
"""

from __future__ import annotations

import numpy as np

from repro.core import bq
from repro.probe.diagnostics import entropy_from_counts


def _plane_bits(words: np.ndarray, dim: int) -> tuple[np.ndarray, np.ndarray]:
    """(B, 2W) packed words -> ((B, D) pos bits, (B, D) strong bits)."""
    words = np.asarray(words, dtype=np.uint32)
    w = words.shape[-1] // 2
    bits = np.unpackbits(
        words.view(np.uint8).reshape(len(words), -1),
        axis=-1, bitorder="little",
    )
    return bits[:, : dim], bits[:, 32 * w: 32 * w + dim]


class ProbeAccumulator:
    """Exact live-set bit-plane counts under insert/delete churn."""

    def __init__(self, dim: int):
        self.dim = int(dim)
        self.n = 0
        self.pos_counts = np.zeros((dim,), dtype=np.int64)
        self.strong_counts = np.zeros((dim,), dtype=np.int64)

    @classmethod
    def from_words(cls, words, dim: int) -> "ProbeAccumulator":
        """From-scratch recompute over a row set (the consistency oracle
        the incremental path is tested against)."""
        out = cls(dim)
        words = np.asarray(words)
        if len(words):
            out.add(words)
        return out

    @classmethod
    def from_signature(cls, sig: bq.Signature) -> "ProbeAccumulator":
        return cls.from_words(np.asarray(sig.words), sig.dim)

    # -- mutation ----------------------------------------------------------

    def add(self, words) -> None:
        """Count a batch of inserted rows' packed words."""
        pos, strong = _plane_bits(words, self.dim)
        self.n += len(pos)
        self.pos_counts += pos.sum(axis=0, dtype=np.int64)
        self.strong_counts += strong.sum(axis=0, dtype=np.int64)

    def remove(self, words) -> None:
        """Un-count a batch of deleted rows' packed words."""
        pos, strong = _plane_bits(words, self.dim)
        self.n -= len(pos)
        self.pos_counts -= pos.sum(axis=0, dtype=np.int64)
        self.strong_counts -= strong.sum(axis=0, dtype=np.int64)
        if self.n < 0:
            raise ValueError("removed more rows than were added")

    # -- statistics --------------------------------------------------------

    @property
    def sign_balance(self) -> np.ndarray:
        """(D,) fraction of positive signs per dimension."""
        return self.pos_counts / max(self.n, 1)

    @property
    def sign_entropy(self) -> float:
        return entropy_from_counts(self.pos_counts, self.n)

    @property
    def strong_entropy(self) -> float:
        return entropy_from_counts(self.strong_counts, self.n)

    def report(self, *, k: int = 10, thresholds=None):
        """Signature-statistics :class:`CompatibilityReport` from the
        exact live counts — the remediation ladder's cheapest re-probe
        (see :func:`repro.probe.diagnostics.report_from_accumulator`)."""
        from repro.probe.diagnostics import report_from_accumulator
        if thresholds is None:
            return report_from_accumulator(self, k=k)
        return report_from_accumulator(self, k=k, thresholds=thresholds)

    def __eq__(self, other) -> bool:
        return (
            isinstance(other, ProbeAccumulator)
            and self.dim == other.dim
            and self.n == other.n
            and np.array_equal(self.pos_counts, other.pos_counts)
            and np.array_equal(self.strong_counts, other.strong_counts)
        )

    def __repr__(self) -> str:
        return (
            f"ProbeAccumulator(n={self.n}, dim={self.dim}, "
            f"sign_entropy={self.sign_entropy:.3f}, "
            f"strong_entropy={self.strong_entropy:.3f})"
        )
