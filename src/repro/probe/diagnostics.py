"""Sample-based, training-free probe statistics (DESIGN.md §10).

Everything here is computed from a corpus slice — no training, no
codebooks, no labels.  The numeric cores are jitted; the host side only
draws the deterministic sample (``np.random.default_rng(seed)``) and
boxes the scalars into a :class:`~repro.probe.report.CompatibilityReport`.

Two entry points:

* :func:`probe_corpus`     — float32 vectors available (build time, the
  common case): full report including the falsifiable BQ-vs-float32
  top-k agreement.
* :func:`probe_signatures` — packed signatures only (vector-free
  indexes): bit-plane statistics, agreement = NaN, verdict capped at
  amber.

The per-dimension entropy math is shared with the streaming
:class:`~repro.probe.incremental.ProbeAccumulator` through
:func:`entropy_from_counts` — one owner for the formula, so the
incremental statistics are bit-for-bit consistent with a from-scratch
recompute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bq
from repro.probe.report import (
    DEFAULT_THRESHOLDS,
    CompatibilityReport,
    Thresholds,
)

DEFAULT_SAMPLE = 1024
DEFAULT_QUERIES = 64
DEFAULT_K = 10
# neighborhood width of the cluster-concentration statistic: the mean
# similarity of each sample row's top-m neighbors stands in for the
# row's coarse (IVF-list-level) cluster
DEFAULT_CLUSTER_M = 16


def _unit(x: jnp.ndarray) -> jnp.ndarray:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def binary_entropy(p: np.ndarray) -> np.ndarray:
    """Elementwise entropy of a Bernoulli(p) bit, in bits (host side)."""
    p = np.clip(np.asarray(p, dtype=np.float64), 1e-12, 1.0 - 1e-12)
    return -(p * np.log2(p) + (1.0 - p) * np.log2(1.0 - p))


def entropy_from_counts(counts: np.ndarray, n: int) -> float:
    """Mean per-dimension bit entropy from set-bit ``counts`` over ``n``
    rows — the one formula both the sampled probe and the incremental
    accumulator use."""
    if n <= 0:
        return 0.0
    return float(binary_entropy(counts / n).mean())


# ---------------------------------------------------------------------------
# jitted numeric cores
# ---------------------------------------------------------------------------


@jax.jit
def _cosine_moments(sample: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Mean/std of off-diagonal pairwise cosine in a unit-vector sample."""
    sims = sample @ sample.T
    s = sample.shape[0]
    off = ~jnp.eye(s, dtype=jnp.bool_)
    count = jnp.float32(s * (s - 1))
    mean = jnp.where(off, sims, 0.0).sum() / count
    var = jnp.where(off, (sims - mean) ** 2, 0.0).sum() / count
    return mean, jnp.sqrt(var)


@jax.jit
def _plane_counts(bits: jnp.ndarray) -> jnp.ndarray:
    """(S, D) bool bit plane -> (D,) set-bit counts."""
    return bits.sum(axis=0).astype(jnp.int32)


@jax.jit
def _sign_corr(bits: jnp.ndarray) -> jnp.ndarray:
    """Mean |Pearson corr| between sign bits across dimension pairs.

    Zero-variance dimensions (constant bits) are excluded from the mean
    — they carry no information, which the entropy statistic already
    reports; counting their undefined correlation as 0 would *dilute*
    the redundancy signal of the informative dims.
    """
    x = bits.astype(jnp.float32)
    s, d = x.shape
    xc = x - x.mean(axis=0)
    std = jnp.sqrt((xc * xc).mean(axis=0))
    ok = std > 1e-6
    denom = jnp.where(ok, std, 1.0)
    z = (xc / denom) * ok
    corr = (z.T @ z) / jnp.float32(s)
    pair = ok[:, None] & ok[None, :] & ~jnp.eye(d, dtype=jnp.bool_)
    total = jnp.maximum(pair.sum(), 1)
    return jnp.where(pair, jnp.abs(corr), 0.0).sum() / total


@functools.partial(jax.jit, static_argnames=("m",))
def _neighbor_mean(sample: jnp.ndarray, *, m: int) -> jnp.ndarray:
    """Mean cosine of each row's top-``m`` neighbors in a unit sample.

    The *raw gap* between this and the overall mean pairwise cosine is
    the cluster-concentration statistic: clustered corpora put a row's
    coarse neighborhood well above the bulk (green surrogate tiers
    measure a gap of 0.21-0.52), structureless ones don't (random
    sphere 0.09, sift-like 0.08).  The gap is deliberately *not*
    normalized by ``cos_std``: the spread itself scales with the
    structure, so a z-score flattens every corpus to ~2.5 and cannot
    discriminate.
    """
    sims = sample @ sample.T
    s = sample.shape[0]
    sims = jnp.where(jnp.eye(s, dtype=jnp.bool_), -jnp.inf, sims)
    return jax.lax.top_k(sims, m)[0].mean()


@functools.partial(jax.jit, static_argnames=("k", "dim"))
def _topk_agreement(
    q_vecs, base_vecs, q_words, base_words, *, k: int, dim: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k overlap of exact-cosine vs symmetric-BQ ranking, plus the
    30th percentile of the per-query normalized k-th-neighbor margin.

    Queries and base rows are disjoint slices of the sample, so there
    is no self-match to exclude; ties inside either ranking resolve by
    index order on both sides (``top_k`` is stable), which makes the
    statistics deterministic.

    The margin percentile calibrates the adaptive-rerank escalation
    threshold (``repro.core.beam.beam_margin`` uses the same formula:
    ``(neutral - d_k) / neutral`` with ``neutral = 4*dim`` for bq2):
    serve-time queries whose margin falls below the sample's 30th
    percentile are in their corpus's own low-margin tail.
    """
    exact = jax.lax.top_k(q_vecs @ base_vecs.T, k)[1]
    d = bq.pairwise_distance(
        bq.Signature(words=q_words, dim=dim),
        bq.Signature(words=base_words, dim=dim),
    )
    neg_topk, quant = jax.lax.top_k(-d, k)
    hits = (exact[:, :, None] == quant[:, None, :]).any(axis=-1)
    # bq.pairwise_distance is -similarity and the beam navigates on the
    # calibrated scale d = 4D - sim, so the beam_margin formula
    # (neutral - d_k) / neutral reduces to sim_k / 4D
    neutral = jnp.float32(4 * dim)
    margin = neg_topk[:, -1].astype(jnp.float32) / neutral
    return hits.mean(), jnp.percentile(margin, 30.0)


# ---------------------------------------------------------------------------
# host drivers
# ---------------------------------------------------------------------------


def _sample_rows(n: int, take: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if take >= n:
        return np.arange(n, dtype=np.int64)
    return rng.choice(n, size=take, replace=False)


def probe_corpus(
    vectors,
    *,
    sample: int = DEFAULT_SAMPLE,
    queries: int = DEFAULT_QUERIES,
    k: int = DEFAULT_K,
    seed: int = 0,
    thresholds: Thresholds = DEFAULT_THRESHOLDS,
) -> CompatibilityReport:
    """Probe a float32 corpus (or slice): the full boundary report.

    ``sample`` rows are drawn without replacement (deterministic in
    ``seed``); the first ``queries`` of them are held out as agreement
    queries against the remaining rows.  Cost is O(sample² · D) — a
    ~1k-row sample probes a million-vector corpus in milliseconds.
    """
    vectors = jnp.asarray(vectors, dtype=jnp.float32)
    if vectors.ndim != 2:
        raise ValueError(f"expected (N, D) vectors, got {vectors.shape}")
    n, dim = vectors.shape
    take = min(sample, n)
    nq = max(1, min(queries, take // 4))
    if take - nq < k:
        raise ValueError(
            f"sample of {take} rows is too small to probe top-{k} "
            f"agreement with {nq} queries"
        )
    rows = _sample_rows(n, take, seed)
    sample_v = _unit(vectors[jnp.asarray(rows)])
    sigs = bq.encode(sample_v)

    cos_mean, cos_std = _cosine_moments(sample_v)
    pos_bits = bq.unpack_bits(sigs.pos, dim)
    strong_bits = bq.unpack_bits(sigs.strong, dim)
    sign_entropy = entropy_from_counts(
        np.asarray(_plane_counts(pos_bits)), take
    )
    strong_entropy = entropy_from_counts(
        np.asarray(_plane_counts(strong_bits)), take
    )
    agreement, margin_p30 = _topk_agreement(
        sample_v[:nq], sample_v[nq:],
        sigs.words[:nq], sigs.words[nq:],
        k=k, dim=dim,
    )
    m = max(1, min(DEFAULT_CLUSTER_M, take - 1))
    cluster = float(_neighbor_mean(sample_v, m=m)) - float(cos_mean)
    return CompatibilityReport(
        n_sampled=int(take),
        n_queries=int(nq),
        k=int(k),
        dim=int(dim),
        seed=int(seed),
        cos_mean=float(cos_mean),
        cos_std=float(cos_std),
        sign_entropy=sign_entropy,
        strong_entropy=strong_entropy,
        inter_bit_corr=float(_sign_corr(pos_bits)),
        bq_agreement=float(agreement),
        margin_p30=float(margin_p30),
        cluster_concentration=cluster,
        thresholds=thresholds,
    )


def probe_signatures(
    words,
    dim: int,
    *,
    sample: int = DEFAULT_SAMPLE,
    k: int = DEFAULT_K,
    seed: int = 0,
    thresholds: Thresholds = DEFAULT_THRESHOLDS,
) -> CompatibilityReport:
    """Probe packed signatures alone (vector-free indexes).

    Without float32 ground truth there is no agreement probe and no
    cosine spread; the report carries the bit-plane statistics, NaN for
    the rest, and its verdict never reaches green.  ``cos_std`` is set
    just above the red threshold so the verdict is decided by the sign
    entropy (the one collapse mode signatures *can* prove).
    """
    words = jnp.asarray(words)
    n = words.shape[0]
    take = min(sample, n)
    if take == 0:
        raise ValueError("cannot probe an empty signature set")
    rows = jnp.asarray(_sample_rows(n, take, seed))
    sigs = bq.Signature(words=words[rows], dim=dim)
    pos_bits = bq.unpack_bits(sigs.pos, dim)
    strong_bits = bq.unpack_bits(sigs.strong, dim)
    return CompatibilityReport(
        n_sampled=int(take),
        n_queries=0,
        k=int(k),
        dim=int(dim),
        seed=int(seed),
        cos_mean=float("nan"),
        cos_std=thresholds.cos_std_red,   # unknown: leave to sign entropy
        sign_entropy=entropy_from_counts(
            np.asarray(_plane_counts(pos_bits)), take
        ),
        strong_entropy=entropy_from_counts(
            np.asarray(_plane_counts(strong_bits)), take
        ),
        inter_bit_corr=float(_sign_corr(pos_bits)),
        bq_agreement=float("nan"),
        thresholds=thresholds,
    )


def report_from_accumulator(
    acc,
    *,
    k: int = DEFAULT_K,
    thresholds: Thresholds = DEFAULT_THRESHOLDS,
) -> CompatibilityReport:
    """Re-probe a live :class:`~repro.probe.incremental.ProbeAccumulator`.

    The cheapest rung of the remediation ladder (DESIGN.md §14): the
    accumulator already holds *exact* bit-plane counts for the live row
    set, so this costs two entropy evaluations — no sampling, no device
    work.  The evidence is signature-statistics only (no cosine
    geometry, no agreement probe), so like :func:`probe_signatures` the
    verdict is capped at amber; ``cos_std`` sits exactly at the red
    threshold so sign entropy alone decides red.
    """
    n = int(acc.n)
    if n <= 0:
        raise ValueError("cannot re-probe an empty accumulator")
    return CompatibilityReport(
        n_sampled=n,
        n_queries=0,
        k=int(k),
        dim=int(acc.dim),
        seed=0,
        cos_mean=float("nan"),
        cos_std=thresholds.cos_std_red,   # unknown: leave to sign entropy
        sign_entropy=float(acc.sign_entropy),
        strong_entropy=float(acc.strong_entropy),
        inter_bit_corr=float("nan"),
        bq_agreement=float("nan"),
        thresholds=thresholds,
    )
