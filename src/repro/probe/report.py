"""CompatibilityReport — the applicability boundary as a runtime object.

The paper's central contribution is the *boundary* (Table 7 / §6):
BQ-native topology is safe on cosine-native contrastive embeddings,
marginal on cosine-native non-contrastive data, and unusable on
Euclidean-native or structureless distributions.  This module turns
that post-hoc observation into a falsifiable, training-free verdict
computed from a corpus sample (``repro.probe.diagnostics``):

* ``sign_entropy``   — mean per-dimension entropy of the sign plane.
  Euclidean-native CV features (SIFT/GIST) are non-negative, so after
  L2-norm every sign bit is constant: entropy ~0 and the paper's
  Finding 1 collapse is detectable *before* building anything.
* ``cos_std``        — spread of pairwise cosine similarity in the
  sample.  Structureless data concentrates at 1/sqrt(D) (concentration
  of measure): there is no neighborhood structure for any quantizer to
  preserve.
* ``bq_agreement``   — mean top-k overlap between exact float32 cosine
  and symmetric 2-bit SM ranking inside the sample: the directly
  falsifiable criterion (if BQ cannot rank a 1k sample, it cannot rank
  the corpus).
* ``strong_entropy`` / ``inter_bit_corr`` / ``cos_mean`` — secondary
  diagnostics reported for inspection (redundant bit planes, hubness).

Calibrated thresholds (measured on the paper-tier surrogate corpora,
see DESIGN.md §10) map the statistics to a green/amber/red verdict;
``repro.probe.policy`` maps the verdict to a navigation policy.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

VERDICTS = ("green", "amber", "red")


@dataclasses.dataclass(frozen=True)
class Thresholds:
    """Verdict calibration (DESIGN.md §10 records the measurements).

    Measured at sample=1024 on the Table-7 surrogate tiers: contrastive
    surrogates score agreement ~0.74-0.78, GloVe-like ~0.66, random
    sphere ~0.42; sign entropy is ~1.0 everywhere except the
    non-negative CV tiers (0.0); cos_std is >= 0.08 on every usable
    tier and <= 0.04 on the structureless/CV tiers.
    """

    sign_entropy_red: float = 0.20   # sign plane ~constant -> collapse
    cos_std_red: float = 0.05        # concentration of measure -> no structure
    agreement_red: float = 0.45      # BQ cannot rank even a small sample
    agreement_green: float = 0.70    # BQ ranking ~matches float32
    # coarse cluster structure: raw gap between the mean top-m neighbor
    # cosine and the overall mean pairwise cosine in the sample.
    # Clustered green tiers measure 0.21-0.52; structureless data
    # (random sphere 0.09, sift-like 0.08) has no gap for an IVF
    # partition to exploit.  Gates the green -> ivf auto-selection.
    cluster_strong: float = 0.15

DEFAULT_THRESHOLDS = Thresholds()

_FLOAT_FIELDS = (
    "cos_mean", "cos_std", "sign_entropy", "strong_entropy",
    "inter_bit_corr", "bq_agreement", "margin_p30",
    "cluster_concentration",
)
_INT_FIELDS = ("n_sampled", "n_queries", "k", "dim", "seed")


@dataclasses.dataclass(frozen=True)
class CompatibilityReport:
    """Training-free compatibility diagnostics for one corpus (slice).

    ``bq_agreement`` is NaN for signature-only probes (no cold float32
    vectors to rank against); the verdict then degrades to the bit-plane
    statistics alone and never reaches green (no falsifiable evidence).
    """

    n_sampled: int            # base sample rows the stats were computed on
    n_queries: int            # held-out query rows for the agreement probe
    k: int                    # top-k depth of the agreement probe
    dim: int
    seed: int
    cos_mean: float           # mean pairwise cosine in the sample
    cos_std: float            # spread of pairwise cosine (structure signal)
    sign_entropy: float       # mean per-dim entropy of the sign plane, bits
    strong_entropy: float     # mean per-dim entropy of the magnitude plane
    inter_bit_corr: float     # mean |corr| between sign bits (redundancy)
    bq_agreement: float       # BQ-vs-float32 top-k overlap; NaN if unknown
    # 30th percentile of the sample's normalized k-th-neighbor BQ score
    # margin (see repro.core.beam.beam_margin): the corpus-calibrated
    # escalation threshold of the adaptive-rerank schedule.
    margin_p30: float = float("nan")
    # mean top-m-neighbor cosine minus the overall mean pairwise cosine
    # in the sample: how much nearer a row's coarse neighborhood is than
    # the bulk.  NaN for signature-only probes (needs cosine geometry).
    # >= thresholds.cluster_strong means the corpus has list-level
    # structure an IVF partition can exploit.
    cluster_concentration: float = float("nan")
    thresholds: Thresholds = DEFAULT_THRESHOLDS

    @property
    def verdict(self) -> str:
        """``green`` (BQ-native safe) / ``amber`` (escalate) / ``red``."""
        t = self.thresholds
        if self.sign_entropy < t.sign_entropy_red:
            return "red"
        if self.cos_std < t.cos_std_red:
            return "red"
        if math.isnan(self.bq_agreement):
            # signature-only probe: no falsifiable ranking evidence, so
            # the best available verdict is amber
            return "amber"
        if self.bq_agreement < t.agreement_red:
            return "red"
        if self.bq_agreement >= t.agreement_green:
            return "green"
        return "amber"

    def summary(self) -> str:
        return (
            f"{self.verdict}: agreement@{self.k}={self.bq_agreement:.3f} "
            f"sign_entropy={self.sign_entropy:.3f} "
            f"cos_std={self.cos_std:.3f} "
            f"(sample={self.n_sampled}, dim={self.dim})"
        )

    # -- persistence (merged into index npz archives) ----------------------

    def to_npz_fields(self, prefix: str = "probe_") -> dict:
        out = {
            prefix + name: np.float64(getattr(self, name))
            for name in _FLOAT_FIELDS
        }
        out.update({
            prefix + name: np.int64(getattr(self, name))
            for name in _INT_FIELDS
        })
        out[prefix + "thresholds"] = np.asarray(
            [getattr(self.thresholds, f.name)
             for f in dataclasses.fields(Thresholds)],
            dtype=np.float64,
        )
        return out

    @classmethod
    def from_npz(cls, z, prefix: str = "probe_"):
        """Rebuild from an index archive; None when it carries no probe."""
        if prefix + "cos_mean" not in z:
            return None
        # archives written before a statistic existed simply omit it:
        # missing floats load as NaN (the "unknown" value every verdict
        # rule already handles), missing thresholds keep their defaults
        kw = {
            name: float(z[prefix + name][()])
            for name in _FLOAT_FIELDS if prefix + name in z
        }
        kw.update(
            {name: int(z[prefix + name][()]) for name in _INT_FIELDS}
        )
        th = z[prefix + "thresholds"]
        names = [f.name for f in dataclasses.fields(Thresholds)]
        kw["thresholds"] = Thresholds(
            **{n: float(v) for n, v in zip(names, th)}
        )
        return cls(**kw)


def merge_reports(reports) -> CompatibilityReport:
    """Fleet-wide report: sample-count-weighted merge of shard reports.

    Means (cosine moments, entropies, correlation, agreement) are
    weighted by each shard's sample size; ``cos_std`` merges through the
    second moment.  NaN agreements (signature-only shards) are excluded
    from the agreement merge — if every shard is NaN, so is the fleet.
    The merged verdict is therefore the verdict of the pooled sample,
    which is what the fan-out search actually serves.
    """
    reports = list(reports)
    if not reports:
        raise ValueError("nothing to merge")
    if len({r.dim for r in reports}) != 1:
        raise ValueError(f"dim mismatch: {[r.dim for r in reports]}")
    if len({r.k for r in reports}) != 1:
        raise ValueError(f"k mismatch: {[r.k for r in reports]}")
    w = np.asarray([r.n_sampled for r in reports], dtype=np.float64)
    if w.sum() <= 0:
        raise ValueError("merge needs at least one non-empty report")
    w = w / w.sum()

    def wmean(name):
        return float(sum(wi * getattr(r, name) for wi, r in zip(w, reports)))

    def nan_wmean(name):
        # weighted mean over the shards that measured the statistic;
        # NaN when none did (signature-only fleets)
        pairs = [
            (wi, getattr(r, name)) for wi, r in zip(w, reports)
            if not math.isnan(getattr(r, name))
        ]
        if not pairs:
            return float("nan")
        tot = sum(wi for wi, _ in pairs)
        return float(sum(wi * v for wi, v in pairs) / max(tot, 1e-12))

    # pooled variance: E[x^2] - E[x]^2 over the weighted mixture
    cos_mean = wmean("cos_mean")
    second = sum(
        wi * (r.cos_std ** 2 + r.cos_mean ** 2)
        for wi, r in zip(w, reports)
    )
    cos_std = float(np.sqrt(max(second - cos_mean ** 2, 0.0)))

    return CompatibilityReport(
        n_sampled=int(sum(r.n_sampled for r in reports)),
        n_queries=int(sum(r.n_queries for r in reports)),
        k=reports[0].k,
        dim=reports[0].dim,
        seed=reports[0].seed,
        cos_mean=cos_mean,
        cos_std=cos_std,
        sign_entropy=wmean("sign_entropy"),
        strong_entropy=wmean("strong_entropy"),
        inter_bit_corr=wmean("inter_bit_corr"),
        bq_agreement=nan_wmean("bq_agreement"),
        # weighted mean approximates the pooled percentile; exact
        # pooling would need the per-shard margin samples themselves
        margin_p30=nan_wmean("margin_p30"),
        cluster_concentration=nan_wmean("cluster_concentration"),
        thresholds=reports[0].thresholds,
    )
