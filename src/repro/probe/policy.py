"""NavPolicy — the auto-selection ladder and ef/rerank schedule.

``select_policy`` maps a :class:`~repro.probe.report.CompatibilityReport`
verdict to a navigation policy on the ladder **bq2 → adc → float32**
(decreasing compression, increasing metric fidelity):

* **green** — BQ-native topology is safe: navigate in ``bq2`` at the
  caller's ef.  The paper's headline configuration.
* **amber** — BQ ranks the sample imperfectly: keep the compact ``bq2``
  hot path but double the beam (rerank pool = beam width, so this *is*
  the rerank-depth schedule) and turn on per-query adaptive escalation
  (``repro.core.beam.beam_margin``): queries whose top-k BQ margins are
  tight re-run with an ``escalate_mult``-times wider pool.
* **red** — BQ-native navigation would collapse (<15% recall in the
  paper's Table 7): route off the BQ rung entirely — ``float32``
  navigation when cold vectors exist, else ``adc`` (decoded-levels
  asymmetric distance, the best signature-only rung) with aggressive
  widening.  Red-zone policies trade throughput for a recall floor;
  the point of the probe is that the caller learns this *before*
  serving garbage.

The policy is a frozen dataclass persisted inside every index archive
(``policy_*`` npz fields) so a loaded index keeps serving exactly the
schedule it was built under.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.probe.report import CompatibilityReport

# the auto-selection ladder, most to least compressed.  "ivf" is the
# coarse-list sibling of the bq2 rung (DESIGN.md §13): same signature
# space and fidelity, flat top-p list scan instead of graph traversal —
# eligible only when the index carries a partition (``have_ivf``).
NAV_LADDER = ("bq2", "ivf", "adc", "float32")


@dataclasses.dataclass(frozen=True)
class NavPolicy:
    """Navigation policy: nav kind + ef/rerank schedule.

    ``ef_scale`` multiplies the caller's ``ef`` before the beam runs
    (the rerank pool is the beam, so this is also the rerank depth).
    ``adaptive`` enables per-query escalation: queries whose top-k
    margin (``beam_margin``) falls below ``escalate_margin`` re-run
    with ``ef * ef_scale * escalate_mult``.
    """

    nav: str                       # rung of NAV_LADDER
    ef_scale: int = 1              # static beam/rerank-depth multiplier
    adaptive: bool = False         # per-query escalation on tight margins
    escalate_margin: float = 0.15  # beam_margin below this escalates
    escalate_mult: int = 4         # escalated-pass ef multiplier
    source: str = "manual"         # "probe" when chosen by auto-selection

    def __post_init__(self):
        if self.nav not in NAV_LADDER:
            raise ValueError(
                f"nav {self.nav!r} not on the ladder {NAV_LADDER}"
            )
        if self.ef_scale < 1 or self.escalate_mult < 1:
            raise ValueError("ef_scale / escalate_mult must be >= 1")

    def describe(self) -> str:
        extra = " +adaptive" if self.adaptive else ""
        return f"{self.nav} x{self.ef_scale}{extra} ({self.source})"

    # -- persistence (merged into index npz archives) ----------------------

    def to_npz_fields(self, prefix: str = "policy_") -> dict:
        return {
            prefix + "nav": np.array(self.nav),
            prefix + "ef_scale": np.int64(self.ef_scale),
            prefix + "adaptive": np.int64(self.adaptive),
            prefix + "escalate_margin": np.float64(self.escalate_margin),
            prefix + "escalate_mult": np.int64(self.escalate_mult),
            prefix + "source": np.array(self.source),
        }

    @classmethod
    def from_npz(cls, z, prefix: str = "policy_"):
        """Rebuild from an index archive; None when it carries none."""
        if prefix + "nav" not in z:
            return None
        return cls(
            nav=str(z[prefix + "nav"]),
            ef_scale=int(z[prefix + "ef_scale"][()]),
            adaptive=bool(z[prefix + "adaptive"][()]),
            escalate_margin=float(z[prefix + "escalate_margin"][()]),
            escalate_mult=int(z[prefix + "escalate_mult"][()]),
            source=str(z[prefix + "source"]),
        )


def resolve_schedule(
    policy: NavPolicy | None,
    nav: str | None,
    ef: int,
    adaptive: bool | None,
) -> tuple[int, bool, NavPolicy]:
    """Resolve a search call's effective (ef, adaptive, schedule).

    The one owner of the policy-application rule every search surface
    shares: an index's auto-selected schedule applies only when the
    caller navigates on the index's own default (``nav is None``) —
    forcing ``nav=`` overrides it; ``adaptive=None`` defers to the
    policy.  The returned schedule always carries usable escalation
    constants (defaults when the index has no policy).
    """
    sched = policy if nav is None else None
    if sched is not None:
        ef = ef * sched.ef_scale
    if adaptive is None:
        adaptive = sched.adaptive if sched is not None else False
    return ef, adaptive, (sched if sched is not None else NavPolicy("bq2"))


def select_policy(
    report: CompatibilityReport, *, have_vectors: bool = True,
    have_ivf: bool = False,
) -> NavPolicy:
    """Map a probe verdict to a rung of the ladder + schedule.

    ``have_vectors=False`` (vector-free index) removes the float32 rung:
    red-zone data then routes to ``adc`` with the widest schedule — the
    honest best-effort, still far better than collapsed ``bq2``.

    ``have_ivf=True`` (the index carries a coarse partition, i.e. it was
    built with ``ivf_candidates``) makes the ``ivf`` family the green
    default *when the probe also measures strong coarse cluster
    structure* (``cluster_concentration >= thresholds.cluster_strong``):
    on clustered green corpora the flat top-p list scan matches graph
    recall at the same signature fidelity with no traversal, and
    escalation widens ``probes`` instead of ef.  A green corpus without
    list-level concentration keeps the graph — its neighborhoods don't
    align with any coarse partition, so list scans would need probes ~L
    to match recall.  Amber/red verdicts never select ivf — a
    quantization-stressed corpus needs the graph's adaptive widening or
    an off-BQ rung, not a coarser candidate stage.
    """
    verdict = report.verdict
    # corpus-calibrated escalation threshold: serve-time queries whose
    # k-th-candidate margin falls below the probe sample's 30th
    # percentile are in their own corpus's low-margin tail
    margin = report.margin_p30
    if not (margin == margin):            # NaN: signature-only probe
        margin = NavPolicy(nav="bq2").escalate_margin
    if verdict == "green":
        # NaN concentration (report predates the statistic, e.g. loaded
        # from an old archive) keeps the pre-gate behavior: a green
        # verdict already implies usable neighborhood structure
        cluster = report.cluster_concentration
        clustered = not (cluster == cluster) \
            or cluster >= report.thresholds.cluster_strong
        if have_ivf and clustered:
            return NavPolicy(nav="ivf", source="probe")
        return NavPolicy(nav="bq2", source="probe")
    if verdict == "amber":
        return NavPolicy(
            nav="bq2", ef_scale=2, adaptive=True,
            escalate_margin=margin, source="probe",
        )
    if have_vectors:
        return NavPolicy(nav="float32", ef_scale=4, source="probe")
    return NavPolicy(
        nav="adc", ef_scale=4, adaptive=True,
        escalate_margin=margin, source="probe",
    )
