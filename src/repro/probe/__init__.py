"""Applicability-boundary probe (DESIGN.md §10).

Training-free compatibility diagnostics, auto metric selection, and the
adaptive-rerank schedule — the paper's Table-7 boundary as a runtime
component:

* :func:`probe_corpus` / :func:`probe_signatures` — jitted, sampled
  statistics -> :class:`CompatibilityReport` (green/amber/red);
* :func:`select_policy` -> :class:`NavPolicy` — the bq2 → adc → float32
  ladder plus ef/rerank-depth schedule behind ``build(nav="auto")``;
* :class:`ProbeAccumulator` — exact live-set bit statistics maintained
  incrementally under streaming churn;
* :func:`merge_reports` — fleet-wide report from per-shard reports.
"""

from repro.probe.diagnostics import (
    DEFAULT_CLUSTER_M,
    DEFAULT_K,
    DEFAULT_QUERIES,
    DEFAULT_SAMPLE,
    binary_entropy,
    entropy_from_counts,
    probe_corpus,
    probe_signatures,
    report_from_accumulator,
)
from repro.probe.incremental import ProbeAccumulator
from repro.probe.policy import (
    NAV_LADDER,
    NavPolicy,
    resolve_schedule,
    select_policy,
)
from repro.probe.report import (
    DEFAULT_THRESHOLDS,
    VERDICTS,
    CompatibilityReport,
    Thresholds,
    merge_reports,
)

__all__ = [
    "CompatibilityReport",
    "DEFAULT_CLUSTER_M",
    "DEFAULT_K",
    "DEFAULT_QUERIES",
    "DEFAULT_SAMPLE",
    "DEFAULT_THRESHOLDS",
    "NAV_LADDER",
    "NavPolicy",
    "ProbeAccumulator",
    "Thresholds",
    "VERDICTS",
    "binary_entropy",
    "entropy_from_counts",
    "merge_reports",
    "probe_corpus",
    "probe_signatures",
    "report_from_accumulator",
    "resolve_schedule",
    "select_policy",
]
