"""Span-based tracing of the query lifecycle.

One request's life through the serve stack is a handful of stages —

    admission -> coalesce -> launch -> finalize [-> escalate | degrade]

(rerank is fused into the compiled plan program, so it is timed inside
launch/finalize rather than as its own span; DESIGN.md §12).  A
:class:`Tracer` hands out integer trace ids at admission, every stage
records a :class:`Span` carrying that id, and finished spans land in

* a bounded ring of recent spans (inspection/debugging — ``spans()``),
* per-stage duration histograms in a :class:`MetricsRegistry`
  (``quiver_stage_seconds{stage=...}``) — the operational signal.

Spans are plain dataclasses, ids are a counter behind a lock, and the
ring is a ``deque(maxlen=...)``: tracing a request costs two clock
reads and one deque append per stage.  No repro.* imports besides the
sibling metrics module.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time

from repro.obs.metrics import MetricsRegistry

STAGES = (
    "admission", "coalesce", "launch", "finalize", "escalate", "degrade",
    "request", "window",
)


@dataclasses.dataclass
class Span:
    name: str
    trace_id: int
    start: float
    end: float | None = None
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def seconds(self) -> float | None:
        return None if self.end is None else self.end - self.start

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "trace_id": self.trace_id,
            "start": self.start,
            "seconds": self.seconds,
            **({"attrs": self.attrs} if self.attrs else {}),
        }


class Tracer:
    """Hands out trace ids and records finished spans.

    ``registry`` (optional) receives per-stage duration histograms; the
    ring keeps the last ``max_spans`` finished spans for inspection.
    ``clock`` is injectable for tests (same convention as QueryEngine).
    """

    def __init__(
        self,
        registry: MetricsRegistry | None = None,
        *,
        max_spans: int = 2048,
        clock=time.monotonic,
    ):
        self.registry = registry
        self.clock = clock
        self._spans = collections.deque(maxlen=max_spans)
        self._next = 0
        self._lock = threading.Lock()
        self._stage_hist = (
            registry.histogram(
                "quiver_stage_seconds",
                "query-lifecycle stage durations",
                labels=("stage",),
            )
            if registry is not None else None
        )

    def new_trace(self) -> int:
        with self._lock:
            self._next += 1
            return self._next

    def record(self, span: Span) -> Span:
        """File a finished span (sets ``end`` if the caller didn't)."""
        if span.end is None:
            span.end = self.clock()
        self._spans.append(span)
        if self._stage_hist is not None:
            self._stage_hist.observe(span.seconds, stage=span.name)
        return span

    @contextlib.contextmanager
    def span(self, name: str, trace_id: int = 0, **attrs):
        """Context-managed stage span; records on exit (even on error,
        so a failing launch still shows up in the stage histogram)."""
        s = Span(name=name, trace_id=trace_id, start=self.clock(),
                 attrs=dict(attrs))
        try:
            yield s
        finally:
            self.record(s)

    def spans(self, trace_id: int | None = None,
              name: str | None = None) -> list[Span]:
        out = list(self._spans)
        if trace_id is not None:
            out = [s for s in out if s.trace_id == trace_id]
        if name is not None:
            out = [s for s in out if s.name == name]
        return out

    def report(self) -> dict:
        """Per-stage {count, total_s, mean_ms} over the span ring."""
        agg: dict[str, list] = {}
        for s in self._spans:
            if s.seconds is None:
                continue
            slot = agg.setdefault(s.name, [0, 0.0])
            slot[0] += 1
            slot[1] += s.seconds
        return {
            name: {
                "count": c,
                "total_s": round(tot, 6),
                "mean_ms": round(tot / c * 1e3, 4),
            }
            for name, (c, tot) in sorted(agg.items())
        }
