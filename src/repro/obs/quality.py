"""Shadow ground-truth sampling: live recall estimation (DESIGN.md §14).

Latency SLOs are observable from the serving path itself; **recall** is
not — the engine never knows the exact answer it should have returned.
The probe layer bounds recall *indirectly* (green/amber/red bands over
bit-plane statistics), but the multi-stage-rerank literature and the
paper's own Table 7 show quality degrades *continuously* under
distribution shift: operators need a number, not a band.

A :class:`ShadowSampler` closes that gap the way production ranking
systems do — by re-answering a deterministic fraction of live traffic
exactly:

* **sampling** is a hash of the query bytes (``crc32(q) % rate == 0``,
  default ~1/256): stateless, deterministic (the same query is always
  in or always out, so replays and A/B runs sample identically), and
  tenant-fair (no tenant can be systematically unsampled).
* **offering** happens at result-scatter time in the engine and only
  copies the sampled rows into a bounded pending queue — O(sampled)
  host work on the serving path, nothing else.
* **draining** runs after the admission window is fully finalized and
  accounted: the pending queries re-run as exact float32 brute force
  (:func:`~repro.core.baselines.flat_search` over the index's cold
  vector tier) and the served-vs-exact recall@k lands in the
  :class:`MetricsRegistry` labelled by tenant, plan nav kind, and
  escalation stage, in a bounded :class:`Ring` window, and — through
  :meth:`TenantLedger.observe_recall` — in the tenant's rolling
  recall-SLO account.

The shadow lane never competes with tenants: shadow queries are not
admitted through the token buckets, never join admission windows, and
their brute-force work happens strictly after every live result of the
window has been delivered and its latency recorded.
"""

from __future__ import annotations

import collections
import zlib

import numpy as np

from repro.core.baselines import flat_search
from repro.obs.metrics import MetricsRegistry, Ring, get_default_registry

DEFAULT_RATE = 256         # ~0.4% of live queries get exact ground truth
DEFAULT_WINDOW = 512       # rolling recall window (Ring size)
RECALL_BUCKETS = (0.1, 0.25, 0.5, 0.7, 0.8, 0.9, 0.95, 0.99, 1.0)
# pad ground-truth batches to these row counts so the brute-force jit
# compiles a handful of shapes, not one per drain size
_GT_BUCKETS = (1, 8, 32, 64)


def shadow_hash(query) -> int:
    """crc32 of the query's float32 bytes — the sampling key."""
    q = np.ascontiguousarray(np.asarray(query, dtype=np.float32))
    return zlib.crc32(q.tobytes())


def should_sample(query, rate: int = DEFAULT_RATE) -> bool:
    """Deterministic membership in the shadow sample: same query bytes,
    same decision, forever — no RNG state to coordinate or replay."""
    if rate <= 1:
        return True
    return shadow_hash(query) % rate == 0


class ShadowSampler:
    """Deterministic shadow sampling + exact recall@k accounting.

    ``index`` is anything with a float32 ``vectors`` tier (the exact
    ground truth is brute force over it).  ``ledger`` (optional) is a
    :class:`~repro.obs.tenant.TenantLedger`: every drained recall
    measurement feeds the tenant's rolling recall-SLO window.  The
    sampler registers itself as ``index.shadow`` so
    ``memory_breakdown()`` can report its host-side bytes.
    """

    def __init__(
        self,
        index,
        *,
        rate: int = DEFAULT_RATE,
        k: int = 10,
        registry: MetricsRegistry | None = None,
        ledger=None,
        window: int = DEFAULT_WINDOW,
        max_pending: int = 4096,
    ):
        if getattr(index, "vectors", None) is None:
            raise ValueError(
                "shadow sampling needs the float32 vector tier for "
                "exact ground truth; this index is vector-free"
            )
        self.index = index
        self.rate = int(rate)
        self.k = int(k)
        self.ledger = ledger
        self.registry = (
            registry if registry is not None else get_default_registry()
        )
        self.seen = 0              # rows offered
        self.sampled = 0           # rows that hashed into the shadow
        self.drained = 0           # rows with ground truth computed
        self.backlog_dropped = 0   # overwritten before drain (bounded q)
        self.pending = collections.deque(maxlen=int(max_pending))
        self.recalls = Ring(int(window))
        self._h_recall = self.registry.histogram(
            "quiver_shadow_recall",
            "shadow-sampled recall@k of served results vs exact",
            labels=("tenant", "nav", "stage"),
            buckets=RECALL_BUCKETS, window=window,
        )
        self._c_sampled = self.registry.counter(
            "quiver_shadow_queries_total",
            "live queries sampled into the shadow lane",
            labels=("tenant",),
        )
        self._c_dropped = self.registry.counter(
            "quiver_shadow_backlog_dropped_total",
            "shadow samples overwritten before ground truth ran",
        )
        index.shadow = self

    # -- hot-path side ------------------------------------------------------

    def offer(self, queries, served_ids, *, tenant: str = "default",
              nav: str = "bq2", stage: str = "base") -> int:
        """Offer one request's served results for shadow sampling.

        Called at result-scatter time; copies only the rows whose bytes
        hash into the sample.  Returns how many rows were enqueued.
        """
        q = np.asarray(queries, dtype=np.float32)
        if q.ndim == 1:
            q = q[None]
        ids = np.asarray(served_ids)
        if ids.ndim == 1:
            ids = ids[None]
        taken = 0
        for row in range(q.shape[0]):
            self.seen += 1
            if not should_sample(q[row], self.rate):
                continue
            if len(self.pending) == self.pending.maxlen:
                self.backlog_dropped += 1
                self._c_dropped.inc()
            self.pending.append((
                q[row].copy(), ids[row, : self.k].copy(),
                tenant, nav, stage,
            ))
            self.sampled += 1
            taken += 1
            self._c_sampled.inc(tenant=tenant)
        return taken

    # -- off-hot-path side --------------------------------------------------

    def drain(self, max_rows: int | None = None) -> list[dict]:
        """Run exact ground truth for the pending shadow queries.

        Brute-force float32 top-k over the index's vector tier, batched
        and bucket-padded (bounded jit shapes).  Each measurement lands
        in the labelled recall histogram, the rolling window, and the
        tenant ledger; the records are returned for callers that want
        the raw stream (benchmarks, tests).
        """
        out: list[dict] = []
        while self.pending and (max_rows is None or len(out) < max_rows):
            take = len(self.pending)
            if max_rows is not None:
                take = min(take, max_rows - len(out))
            take = min(take, _GT_BUCKETS[-1])
            batch = [self.pending.popleft() for _ in range(take)]
            qs = np.stack([b[0] for b in batch])
            pad = next(b for b in _GT_BUCKETS if b >= take)
            if pad > take:
                qs = np.concatenate(
                    [qs, np.zeros((pad - take, qs.shape[1]), qs.dtype)]
                )
            exact_ids, _ = flat_search(
                self.index.vectors, qs, k=self.k,
                query_batch=_GT_BUCKETS[-1],
            )
            for (_, served, tenant, nav, stage), truth in zip(
                batch, exact_ids[:take]
            ):
                hits = len(set(served.tolist()) & set(truth.tolist()))
                recall = hits / self.k
                self.drained += 1
                self.recalls.append(recall)
                self._h_recall.observe(
                    recall, tenant=tenant, nav=nav, stage=stage
                )
                if self.ledger is not None:
                    self.ledger.observe_recall(tenant, recall)
                out.append({
                    "tenant": tenant, "nav": nav, "stage": stage,
                    "recall": recall,
                })
        return out

    # -- reporting ----------------------------------------------------------

    def memory_bytes(self) -> int:
        """Host-side bytes: pending shadow copies + the recall window
        (reported through ``memory_breakdown()`` — see DESIGN.md §14)."""
        pending = sum(
            q.nbytes + ids.nbytes for q, ids, *_ in self.pending
        )
        return int(pending + self.recalls.maxlen * 8)

    def report(self) -> dict:
        return {
            "rate": self.rate,
            "k": self.k,
            "seen": self.seen,
            "sampled": self.sampled,
            "drained": self.drained,
            "pending": len(self.pending),
            "backlog_dropped": self.backlog_dropped,
            "recall_n": len(self.recalls),
            "recall_mean": (
                round(float(self.recalls.array().mean()), 4)
                if len(self.recalls) else None
            ),
            "recall_p50": (
                round(self.recalls.percentile(50), 4)
                if len(self.recalls) else None
            ),
            "recall_p10": (
                round(self.recalls.percentile(10), 4)
                if len(self.recalls) else None
            ),
            "memory_bytes": self.memory_bytes(),
        }
