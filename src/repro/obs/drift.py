"""Probe-drift alarms: the applicability boundary as a live monitor.

``build(nav="auto")`` decides the nav ladder once, from a probe of the
corpus *at build time* (DESIGN.md §10).  Under streaming churn that
verdict rots: a tenant that starts green (contrastive embeddings) and
gradually ingests sign-collapsed rows (SIFT-like CV features) slides
across the paper's boundary while the index keeps navigating in bq2 —
exactly the silent-recall-collapse failure mode the paper's Table 7
warns about.  The :class:`ProbeAccumulator` already maintains the
exact live-set bit-plane entropies under insert/delete, so re-scoring
them against the calibrated :class:`~repro.probe.report.Thresholds` is
free — a :class:`DriftMonitor` does that after every mutation batch and
raises a :class:`DriftAlarm` through the metrics layer whenever the
live corpus crosses a band.

Bands from signature statistics alone (the cheap, every-mutation path):

* ``red``   — ``sign_entropy < thresholds.sign_entropy_red`` (0.2):
  the sign plane is collapsing; BQ navigation is unsafe *now*;
* ``amber`` — entropy under ``amber_scale`` x the red line: drifting
  toward the boundary, re-probe with samples before it is too late;
* ``green`` — the bit planes carry full entropy.

The full sampled verdict (cosine spread, BQ-vs-float32 agreement) is
still authoritative; :meth:`DriftMonitor.check_report` re-scores one
(e.g. from ``MutableQuIVerIndex.probe_report()``) through the same
alarm path at phase boundaries, where the sampled probes are worth
their cost.
"""

from __future__ import annotations

import collections
import dataclasses
import time

from repro.obs.metrics import MetricsRegistry, get_default_registry
from repro.probe.report import DEFAULT_THRESHOLDS, Thresholds

BANDS = ("green", "amber", "red")
_BAND_CODE = {b: i for i, b in enumerate(BANDS)}


@dataclasses.dataclass(frozen=True)
class DriftAlarm:
    """One band-crossing event (worsening only; recoveries are recorded
    as events but never alarm)."""

    tenant: str
    prev_band: str
    band: str
    stat: str                 # which statistic tripped the band
    value: float
    threshold: float
    n_live: int
    unix_ts: float

    def message(self) -> str:
        return (
            f"[drift] tenant={self.tenant} {self.prev_band}->{self.band} "
            f"{self.stat}={self.value:.3f} (threshold {self.threshold:g},"
            f" n_live={self.n_live})"
        )


class DriftMonitor:
    """Re-score incremental probe stats against the calibrated bands.

    ``acc`` is anything with ``sign_entropy`` / ``strong_entropy`` / ``n``
    (a :class:`~repro.probe.incremental.ProbeAccumulator`; a mutable
    index passes its own).  ``min_n`` suppresses banding noise on tiny
    live sets — a two-row corpus has degenerate entropy and no verdict.

    Attach to a mutable index (``index.attach_drift_monitor(...)``) and
    the index calls :meth:`check` after every insert/delete/consolidate
    batch; or drive it manually from any churn loop.
    """

    def __init__(
        self,
        acc,
        *,
        tenant: str = "default",
        thresholds: Thresholds = DEFAULT_THRESHOLDS,
        amber_scale: float = 2.0,
        min_n: int = 64,
        registry: MetricsRegistry | None = None,
        max_events: int = 256,
        clock=time.time,
    ):
        self.acc = acc
        self.tenant = tenant
        self.thresholds = thresholds
        self.amber_scale = float(amber_scale)
        self.min_n = int(min_n)
        self.clock = clock
        self.band = None                  # unknown until first check()
        self.alarms: list[DriftAlarm] = []
        self.events = collections.deque(maxlen=max_events)
        self._subs: list = []
        reg = registry if registry is not None else get_default_registry()
        self._c_alarms = reg.counter(
            "quiver_drift_alarms_total",
            "probe-drift band-crossing alarms",
            labels=("tenant", "band"),
        )
        self._g_entropy = reg.gauge(
            "quiver_drift_sign_entropy",
            "live-set sign-plane entropy (bits)", labels=("tenant",),
        )
        self._g_band = reg.gauge(
            "quiver_drift_band",
            "live-set drift band (0=green 1=amber 2=red)",
            labels=("tenant",),
        )

    def subscribe(self, fn) -> None:
        """Register ``fn(alarm)`` to fire on every raised
        :class:`DriftAlarm` (band worsenings only, same events that land
        in ``self.alarms``) — the hook the closed-loop
        :class:`~repro.obs.remediate.RemediationPolicy` attaches to."""
        self._subs.append(fn)

    def _raise(self, event: DriftAlarm) -> DriftAlarm:
        self.alarms.append(event)
        self._c_alarms.inc(tenant=self.tenant, band=event.band)
        for fn in list(self._subs):
            fn(event)
        return event

    # -- banding -----------------------------------------------------------

    def score(self) -> tuple[str, str, float, float]:
        """(band, tripping stat, value, threshold) from the accumulator's
        exact entropies (signature-only: green here means "bit planes
        healthy", not the full sampled-agreement green)."""
        e = float(self.acc.sign_entropy)
        red = self.thresholds.sign_entropy_red
        if e < red:
            return "red", "sign_entropy", e, red
        if e < self.amber_scale * red:
            return "amber", "sign_entropy", e, self.amber_scale * red
        return "green", "sign_entropy", e, self.amber_scale * red

    def check(self) -> DriftAlarm | None:
        """Re-score; on a band *worsening* raise (return + record) an
        alarm.  Improvements update state silently (logged as events)."""
        if getattr(self.acc, "n", 0) < self.min_n:
            return None
        band, stat, value, threshold = self.score()
        self._g_entropy.set(value, tenant=self.tenant)
        self._g_band.set(_BAND_CODE[band], tenant=self.tenant)
        prev, self.band = self.band, band
        if prev is None:
            # arming the monitor asserts a healthy baseline (the index
            # was built/adopted under an acceptable verdict), so a first
            # scoring that is already amber/red must alarm
            prev = "green"
        if band == prev:
            return None
        event = DriftAlarm(
            tenant=self.tenant, prev_band=prev, band=band, stat=stat,
            value=value, threshold=threshold,
            n_live=int(getattr(self.acc, "n", 0)),
            unix_ts=self.clock(),
        )
        self.events.append(event)
        if _BAND_CODE[band] > _BAND_CODE[prev]:
            return self._raise(event)
        return None

    def check_report(self, report) -> DriftAlarm | None:
        """Score a full sampled :class:`CompatibilityReport` verdict
        through the same alarm path (phase-boundary re-probe: the
        sampled agreement stats catch drift the bit planes cannot)."""
        band = report.verdict
        self._g_band.set(_BAND_CODE[band], tenant=self.tenant)
        prev, self.band = self.band, band
        if prev is None:
            prev = "green"              # same baseline rule as check()
        if band == prev:
            return None
        event = DriftAlarm(
            tenant=self.tenant, prev_band=prev, band=band,
            stat="verdict", value=float(_BAND_CODE[band]),
            threshold=float(_BAND_CODE["amber"]),
            n_live=int(getattr(self.acc, "n", 0)),
            unix_ts=self.clock(),
        )
        self.events.append(event)
        if _BAND_CODE[band] > _BAND_CODE[prev]:
            return self._raise(event)
        return None

    def report(self) -> dict:
        return {
            "tenant": self.tenant,
            "band": self.band,
            "n_live": int(getattr(self.acc, "n", 0)),
            "sign_entropy": float(self.acc.sign_entropy),
            "strong_entropy": float(self.acc.strong_entropy),
            "alarms": len(self.alarms),
            "events": [dataclasses.asdict(e) for e in self.events],
        }
