"""ObsHub — one handle bundling registry + tracer + sinks.

Every instrumented component takes (or builds) a hub: the
:class:`~repro.serve.engine.QueryEngine` records per-tenant metrics and
lifecycle spans into ``hub.registry``/``hub.tracer``; ``hub.emit()``
pushes one snapshot record through every sink.  A default hub writes
into the process-global registry — so beam/filter/stream
instrumentation recorded through ``get_default_registry()`` appears in
the same scrape — with no sinks (pure pull, zero I/O), which is the
test-friendly shape; serving processes build one ``from_env()`` with
whatever the launcher staged.

:class:`PeriodicReporter` is the operational push loop: a daemon thread
emitting ``hub.emit(extra_fn())`` every ``interval`` seconds — this is
what turns ``stats_report``/``trace_report`` from pull-only dicts into
a live telemetry stream (ISSUE 7 satellite).  ``autostart`` wires the
reporter + Prometheus endpoint from the env (``REPRO_OBS_INTERVAL_S``,
``REPRO_METRICS_PORT``).
"""

from __future__ import annotations

import atexit
import os
import threading
import time

from repro.obs.metrics import MetricsRegistry, get_default_registry
from repro.obs.sinks import PrometheusServer, Sink, sinks_from_env
from repro.obs.tracing import Tracer


class ObsHub:
    """Registry + tracer + sinks, bundled."""

    def __init__(
        self,
        registry: MetricsRegistry | None = None,
        tracer: Tracer | None = None,
        sinks: list[Sink] | tuple = (),
    ):
        self.registry = (
            registry if registry is not None else get_default_registry()
        )
        self.tracer = (
            tracer if tracer is not None else Tracer(self.registry)
        )
        self.sinks = list(sinks)
        self._closed = False
        if self.sinks:
            # flush-and-close at interpreter exit: a process torn down
            # without an orderly engine.shutdown() still closes its
            # flight recorder cleanly (close() is idempotent, so the
            # orderly path costs nothing extra)
            atexit.register(self.close)

    @classmethod
    def from_env(cls, env=None) -> "ObsHub":
        """Hub over the global registry with env-staged sinks
        (``launch/serve.py`` sets the variables up front)."""
        return cls(sinks=sinks_from_env(env))

    def emit(self, extra: dict | None = None) -> dict:
        """Snapshot metrics + span aggregates (+ caller extras) and push
        the record through every sink; returns the record either way, so
        a sink-less hub still serves as the pull API."""
        record = {
            "unix_ts": round(time.time(), 3),
            "metrics": self.registry.snapshot(),
            "spans": self.tracer.report(),
        }
        if extra:
            record.update(extra)
        for sink in self.sinks:
            sink.emit(record)
        return record

    def close(self) -> None:
        """Close every sink exactly once (idempotent: engine shutdown
        and the atexit hook may both land here)."""
        if self._closed:
            return
        self._closed = True
        for sink in self.sinks:
            sink.close()


class PeriodicReporter(threading.Thread):
    """Emit ``hub.emit(extra_fn())`` every ``interval`` seconds.

    Daemon thread: dies with the process; ``stop()`` emits one final
    snapshot so short runs always leave at least one record behind.
    """

    def __init__(self, hub: ObsHub, *, interval: float = 5.0,
                 extra_fn=None):
        super().__init__(daemon=True, name="obs-reporter")
        self.hub = hub
        self.interval = float(interval)
        self.extra_fn = extra_fn
        self._stopped = False
        # NB: not named _stop — Thread.join() calls self._stop()
        # internally, and an Event attribute would shadow it
        self._halt = threading.Event()

    def _extra(self) -> dict | None:
        if self.extra_fn is None:
            return None
        try:
            return self.extra_fn()
        except Exception as e:       # keep the loop alive; surface why
            return {"reporter_error": repr(e)}

    def start(self) -> None:
        # registered at start (not construction) so only a *running*
        # loop owes the world a final snapshot; atexit runs LIFO, so
        # this fires before the hub's own sink-close hook — the flush
        # lands in an open flight recorder
        atexit.register(self.stop)
        super().start()

    def run(self) -> None:
        while not self._halt.wait(self.interval):
            self.hub.emit(self._extra())

    def stop(self) -> None:
        """Stop the loop and flush one final snapshot (idempotent:
        engine shutdown and the atexit hook may both call it, and the
        snapshot must not be double-emitted)."""
        if self._stopped:
            return
        self._stopped = True
        self._halt.set()
        if self.is_alive():
            self.join(timeout=2 * self.interval)
        self.hub.emit(self._extra())


def autostart(
    hub: ObsHub, *, extra_fn=None, health_fn=None, env=None
) -> tuple[PeriodicReporter | None, PrometheusServer | None]:
    """Start the push loop / scrape endpoint the env asks for.

    ``REPRO_OBS_INTERVAL_S`` (default 5) paces the reporter — started
    only when the hub has sinks to feed; ``REPRO_METRICS_PORT`` starts
    the Prometheus snapshot endpoint on that port (``health_fn`` —
    typically ``engine.health_verdicts`` — adds its ``GET /healthz``
    verdict route).  Returns whichever were started (callers
    ``stop()``/``close()`` them on shutdown).
    """
    env = os.environ if env is None else env
    reporter = server = None
    if hub.sinks:
        interval = float(env.get("REPRO_OBS_INTERVAL_S", "5"))
        reporter = PeriodicReporter(hub, interval=interval,
                                    extra_fn=extra_fn)
        reporter.start()
    port = env.get("REPRO_METRICS_PORT")
    if port:
        server = PrometheusServer(hub.registry, port=int(port),
                                  health_fn=health_fn)
    return reporter, server
