"""Pluggable emission sinks + Prometheus text exposition.

``stats_report``/``trace_report`` used to be pull-only dicts nothing
consumed in production; a :class:`Sink` is the push side.  Each
``emit(record)`` receives one JSON-serializable snapshot (metrics +
spans + whatever the caller attaches) and ships it somewhere:

* :class:`JsonlSink` — append one JSON line per snapshot to a file (the
  fleet-telemetry flight recorder; trivially greppable/parseable);
* :class:`StdoutSink` — terse human-readable summary to stderr (the
  operator's tail -f);
* :func:`render_prometheus` / :class:`PrometheusServer` — Prometheus
  text-exposition snapshot of a registry, optionally served on an HTTP
  endpoint (``GET /metrics``) for a scraper.  Stdlib ``http.server``
  in a daemon thread: no new dependencies.

``sinks_from_env`` builds the sink list the launcher stages
(``launch/serve.py``): ``REPRO_OBS_JSONL=<path>``,
``REPRO_OBS_STDOUT=1``.
"""

from __future__ import annotations

import http.server
import json
import os
import pathlib
import sys
import threading

from repro.obs.metrics import MetricsRegistry


class Sink:
    """One destination for telemetry snapshots."""

    def emit(self, record: dict) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class JsonlSink(Sink):
    """Append-one-JSON-line-per-snapshot file sink with size rotation.

    A long-running serve emits a snapshot per admission window; without
    a bound the flight recorder eventually fills the disk.  When the
    live file would exceed ``max_bytes`` the sink rolls it logrotate
    style — ``path`` -> ``path.1`` -> ... -> ``path.<keep>``, oldest
    dropped — before writing, so every line lands whole in exactly one
    generation and the newest data is always in ``path`` itself.
    ``max_bytes=0`` (the default) disables rotation.
    """

    def __init__(self, path, *, max_bytes: int = 0, keep: int = 3):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.max_bytes = int(max_bytes)
        self.keep = int(keep)
        self._fh = self.path.open("a")
        self._lock = threading.Lock()
        self._closed = False

    def _rotate(self) -> None:
        self._fh.close()
        oldest = self.path.with_name(f"{self.path.name}.{self.keep}")
        oldest.unlink(missing_ok=True)
        for i in range(self.keep - 1, 0, -1):
            src = self.path.with_name(f"{self.path.name}.{i}")
            if src.exists():
                src.rename(self.path.with_name(f"{self.path.name}.{i + 1}"))
        self.path.rename(self.path.with_name(f"{self.path.name}.1"))
        self._fh = self.path.open("a")

    def emit(self, record: dict) -> None:
        line = json.dumps(record, default=_json_default) + "\n"
        with self._lock:
            if self._closed:
                # a straggler snapshot after close (reporter's atexit
                # flush racing the hub's) is dropped, not a crash
                return
            if (
                self.max_bytes > 0
                and self._fh.tell() > 0
                and self._fh.tell() + len(line) > self.max_bytes
            ):
                self._rotate()
            self._fh.write(line)
            self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._fh.close()


class StdoutSink(Sink):
    """Terse one-line-per-snapshot pretty printer (stderr by default:
    benchmark CSV owns stdout)."""

    def __init__(self, stream=None, prefix: str = "[obs]"):
        self.stream = stream if stream is not None else sys.stderr
        self.prefix = prefix

    def emit(self, record: dict) -> None:
        bits = []
        for key in ("unix_ts", "requests", "done", "dropped", "rejected"):
            if key in record:
                bits.append(f"{key}={record[key]}")
        metrics = record.get("metrics", {})
        for name in sorted(metrics):
            series = metrics[name]
            if isinstance(series, dict) and len(series) <= 4:
                for lbl, v in series.items():
                    tag = f"{name}{{{lbl}}}" if lbl else name
                    if isinstance(v, dict):      # histogram summary
                        bits.append(f"{tag}.count={v.get('count')}")
                    else:
                        bits.append(f"{tag}={v:g}")
        print(f"{self.prefix} " + " ".join(bits), file=self.stream)


def _json_default(o):
    try:
        return float(o)
    except (TypeError, ValueError):
        return str(o)


# -- health verdicts -------------------------------------------------------


_BAND_ORDER = {"green": 0, "amber": 1, "red": 2}


def health_snapshot(health_fn) -> tuple[dict, int]:
    """Evaluate ``health_fn`` into a ``/healthz`` body + HTTP status.

    ``health_fn`` returns ``{component: verdict}`` (e.g. graph / drift
    / recall-SLO bands); the overall verdict is the *worst* band and
    the status is 503 only on red — amber is degraded-but-serving, a
    scraper page not a load-balancer eviction.  A crashing probe is
    itself a red verdict: the endpoint must never take the server down,
    and "health check broken" is not health.
    """
    components: dict = {}
    if health_fn is not None:
        try:
            components = dict(health_fn())
        except Exception as e:
            components = {"health_probe": "red", "error": repr(e)}
    worst = "green"
    for v in components.values():
        if _BAND_ORDER.get(v, 0) > _BAND_ORDER[worst]:
            worst = v
    return (
        {"verdict": worst, "components": components},
        503 if worst == "red" else 200,
    )


# -- Prometheus text exposition -------------------------------------------


def _escape(v) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"')


def _fmt_labels(names, values, extra=()) -> str:
    pairs = [
        f'{n}="{_escape(v)}"' for n, v in (*zip(names, values), *extra)
    ]
    return "{" + ",".join(pairs) + "}" if pairs else ""


def render_prometheus(registry: MetricsRegistry) -> str:
    """Text-exposition-format snapshot of every series in ``registry``
    (counters/gauges verbatim; histograms as cumulative ``_bucket``
    series plus ``_sum``/``_count``, the standard shape)."""
    lines = []
    for m in registry.metrics():
        lines.append(f"# HELP {m.name} {m.help or m.name}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        for key, s in sorted(m.series().items()):
            if m.kind == "histogram":
                cum = 0
                for bound, c in zip(m.buckets, s.counts):
                    cum += int(c)
                    lbl = _fmt_labels(m.label_names, key,
                                      extra=(("le", f"{bound:g}"),))
                    lines.append(f"{m.name}_bucket{lbl} {cum}")
                cum += int(s.counts[-1])
                lbl = _fmt_labels(m.label_names, key,
                                  extra=(("le", "+Inf"),))
                lines.append(f"{m.name}_bucket{lbl} {cum}")
                base = _fmt_labels(m.label_names, key)
                lines.append(f"{m.name}_sum{base} {s.sum:g}")
                lines.append(f"{m.name}_count{base} {s.count}")
            else:
                lbl = _fmt_labels(m.label_names, key)
                lines.append(f"{m.name}{lbl} {s[0]:g}")
    return "\n".join(lines) + "\n"


class PrometheusServer:
    """``GET /metrics`` snapshot endpoint over a registry.

    Stdlib ``ThreadingHTTPServer`` on a daemon thread — a scrape reads
    whatever the registry holds at that instant; nothing blocks the
    serving loop.  ``port=0`` binds an ephemeral port (tests).

    ``health_fn`` (optional) adds a ``GET /healthz`` liveness verdict:
    a JSON body of per-component bands (graph topology, probe drift,
    recall SLO — whatever the caller wires in) with 200 while no
    component reads red and 503 once one does, so a load balancer can
    evict a replica whose graph has structurally collapsed without
    parsing the full metrics exposition.
    """

    def __init__(self, registry: MetricsRegistry, port: int = 0,
                 host: str = "127.0.0.1", health_fn=None):
        self.registry = registry
        self.health_fn = health_fn
        outer = self

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):
                path = self.path.rstrip("/")
                if path == "/healthz":
                    record, status = health_snapshot(outer.health_fn)
                    body = json.dumps(record).encode()
                    self.send_response(status)
                    self.send_header("Content-Type", "application/json")
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                    return
                if path not in ("", "/metrics"):
                    self.send_error(404)
                    return
                body = render_prometheus(outer.registry).encode()
                self.send_response(200)
                self.send_header(
                    "Content-Type", "text/plain; version=0.0.4"
                )
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):        # keep scrapes silent
                pass

        self._httpd = http.server.ThreadingHTTPServer(
            (host, port), Handler
        )
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, daemon=True,
            name=f"prometheus:{self.port}",
        )
        self._thread.start()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()


def sinks_from_env(env=None) -> list[Sink]:
    """Build the sink list from the env the launcher staged:
    ``REPRO_OBS_JSONL`` (file path), ``REPRO_OBS_JSONL_MAX_BYTES`` /
    ``REPRO_OBS_JSONL_KEEP`` (size rotation), ``REPRO_OBS_STDOUT``
    (=1)."""
    env = os.environ if env is None else env
    sinks: list[Sink] = []
    path = env.get("REPRO_OBS_JSONL")
    if path:
        sinks.append(JsonlSink(
            path,
            max_bytes=int(env.get("REPRO_OBS_JSONL_MAX_BYTES", "0")),
            keep=int(env.get("REPRO_OBS_JSONL_KEEP", "3")),
        ))
    if env.get("REPRO_OBS_STDOUT", "0") == "1":
        sinks.append(StdoutSink())
    return sinks
