"""Fleet telemetry: metrics, tracing, tenant SLOs, drift alarms
(DESIGN.md §12).

The observability substrate of the serving stack:

* :mod:`repro.obs.metrics` — labelled counters/gauges/fixed-bucket
  histograms in a :class:`MetricsRegistry`; :class:`Ring` bounded
  windows for SLO percentiles;
* :mod:`repro.obs.tracing` — span-based query-lifecycle tracing
  (admission → coalesce → launch → finalize → escalate/degrade);
* :mod:`repro.obs.sinks` — JSONL / stdout push sinks, Prometheus text
  exposition and the ``/metrics`` snapshot endpoint (plus the
  ``/healthz`` verdict route);
* :mod:`repro.obs.graph` — the graph X-ray: structural health probes
  (degrees, reciprocity, medoid reachability, BQ/f32 edge agreement)
  banded into a calibrated verdict, and the edge-triggered
  :class:`GraphHealthMonitor`;
* :mod:`repro.obs.tenant` — token-bucket admission quotas and
  per-tenant SLO accounting (:class:`TenantLedger`);
* :mod:`repro.obs.drift` — probe-drift alarms: the paper's
  green/amber/red boundary re-scored live under streaming churn;
* :mod:`repro.obs.quality` — shadow ground-truth sampling: exact
  recall@k for a deterministic fraction of live traffic (DESIGN.md §14);
* :mod:`repro.obs.remediate` — the closed loop: drift alarms and
  recall-SLO breaches walk an ordered remediation ladder;
* :mod:`repro.obs.hub` — :class:`ObsHub` bundling the above,
  :class:`PeriodicReporter` push loop, env-driven ``autostart``.
"""

from repro.obs.drift import BANDS, DriftAlarm, DriftMonitor
from repro.obs.graph import (
    DEFAULT_GRAPH_THRESHOLDS,
    GraphHealthAlarm,
    GraphHealthMonitor,
    GraphHealthReport,
    GraphThresholds,
    graph_health_report,
)
from repro.obs.hub import ObsHub, PeriodicReporter, autostart
from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Ring,
    get_default_registry,
    latency_summary,
    reset_default_registry,
)
from repro.obs.quality import (
    DEFAULT_RATE,
    ShadowSampler,
    shadow_hash,
    should_sample,
)
from repro.obs.remediate import ACTIONS, RemediationPolicy
from repro.obs.sinks import (
    JsonlSink,
    PrometheusServer,
    Sink,
    StdoutSink,
    health_snapshot,
    render_prometheus,
    sinks_from_env,
)
from repro.obs.tenant import (
    DEFAULT_TENANT,
    TenantLedger,
    TenantQuota,
    TokenBucket,
)
from repro.obs.tracing import Span, Tracer

__all__ = [
    "ACTIONS",
    "BANDS",
    "Counter",
    "DEFAULT_BUCKETS",
    "DEFAULT_GRAPH_THRESHOLDS",
    "DEFAULT_RATE",
    "DEFAULT_TENANT",
    "DriftAlarm",
    "DriftMonitor",
    "Gauge",
    "GraphHealthAlarm",
    "GraphHealthMonitor",
    "GraphHealthReport",
    "GraphThresholds",
    "Histogram",
    "JsonlSink",
    "MetricsRegistry",
    "ObsHub",
    "PeriodicReporter",
    "PrometheusServer",
    "RemediationPolicy",
    "Ring",
    "ShadowSampler",
    "Sink",
    "Span",
    "StdoutSink",
    "TenantLedger",
    "TenantQuota",
    "TokenBucket",
    "Tracer",
    "autostart",
    "get_default_registry",
    "graph_health_report",
    "health_snapshot",
    "latency_summary",
    "render_prometheus",
    "reset_default_registry",
    "shadow_hash",
    "should_sample",
    "sinks_from_env",
]
