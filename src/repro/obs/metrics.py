"""Labelled metrics primitives: counters, gauges, fixed-bucket histograms.

The serving hot path (``serve/engine.py``, ``plan/cache.py``) records a
handful of numbers per admission window; everything here is shaped so
that recording is allocation-cheap:

* metric instances hold a flat dict keyed by label-*value* tuples —
  recording with the same labels touches one dict slot, no string
  formatting, no per-event objects;
* histograms are **fixed-bucket**: one ``np.searchsorted`` against a
  static boundary array plus an integer bump (cumulative rendering is
  done at scrape/emit time, never on the hot path);
* recent raw observations ride a :class:`Ring` — a bounded numpy ring
  buffer — so window percentiles (p50/p99 over the *last W* events, the
  SLO number) are available without unbounded growth.  The same class
  replaces the append-forever latency list ``EngineStats`` used to keep.

A :class:`MetricsRegistry` is the unit of isolation: one per process for
serving (``get_default_registry``), fresh ones in tests.  Registries
render to plain dicts (``snapshot``) for the JSONL/stdout sinks and to
Prometheus text exposition (``repro.obs.sinks.render_prometheus``).

Import-cycle-free on purpose (stdlib + numpy only): core, filter,
stream, plan and serve all record into it.
"""

from __future__ import annotations

import threading

import numpy as np

# latency-flavored defaults: 100us .. 10s, roughly log-spaced (seconds)
DEFAULT_BUCKETS = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


class Ring:
    """Bounded float ring buffer with window percentiles.

    Appending past capacity overwrites the oldest entry — a
    long-running engine keeps the last ``size`` observations, O(size)
    memory forever, and percentiles are computed over that window.
    """

    __slots__ = ("_buf", "_count", "_head")

    def __init__(self, size: int):
        if size < 1:
            raise ValueError(f"ring size must be >= 1, got {size}")
        self._buf = np.zeros((size,), dtype=np.float64)
        self._count = 0          # total ever appended
        self._head = 0           # next write slot

    @property
    def maxlen(self) -> int:
        return len(self._buf)

    @property
    def total(self) -> int:
        """Observations ever appended (>= len once the ring wraps)."""
        return self._count

    def append(self, value: float) -> None:
        self._buf[self._head] = value
        self._head = (self._head + 1) % len(self._buf)
        self._count += 1

    def extend(self, values) -> None:
        for v in np.asarray(values, dtype=np.float64).ravel():
            self.append(float(v))

    def __len__(self) -> int:
        return min(self._count, len(self._buf))

    def array(self) -> np.ndarray:
        """The window's values (unordered; percentiles don't care)."""
        return self._buf[: len(self)].copy()

    def percentile(self, q) -> float | None:
        if len(self) == 0:
            return None
        return float(np.percentile(self._buf[: len(self)], q))


def latency_summary(ring: Ring, quantiles=(50, 99)) -> dict:
    """``{"p<q>_ms": ...}`` from a Ring of *seconds*.

    The one place window percentiles become report fields — the serve
    engine's ``stats_report`` and the tenant ledger used to each carry
    their own copy of this scale-and-round.  Empty windows report
    ``None`` for every quantile (absence of evidence, not 0ms).
    """
    out = {}
    for q in quantiles:
        v = ring.percentile(q)
        out[f"p{int(q)}_ms"] = None if v is None else round(v * 1e3, 3)
    return out


def _label_key(label_names, labels: dict) -> tuple:
    if set(labels) != set(label_names):
        raise ValueError(
            f"expected labels {label_names}, got {tuple(labels)}"
        )
    return tuple(str(labels[n]) for n in label_names)


class _Metric:
    """Shared bookkeeping: name, help text, declared label names."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", labels: tuple = ()):
        self.name = name
        self.help = help
        self.label_names = tuple(labels)
        self._series: dict = {}
        self._lock = threading.Lock()

    def _slot(self, labels: dict, factory):
        key = _label_key(self.label_names, labels)
        slot = self._series.get(key)
        if slot is None:
            with self._lock:
                slot = self._series.setdefault(key, factory())
        return slot

    def series(self) -> dict:
        """{label-value tuple: raw series state} (rendering input)."""
        return dict(self._series)

    def labelled(self, key: tuple) -> dict:
        return dict(zip(self.label_names, key))


class Counter(_Metric):
    """Monotonic counter; ``inc`` only moves forward."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError("counters only go up")
        slot = self._slot(labels, lambda: [0.0])
        slot[0] += value

    def value(self, **labels) -> float:
        key = _label_key(self.label_names, labels)
        slot = self._series.get(key)
        return slot[0] if slot else 0.0


class Gauge(_Metric):
    """Point-in-time value (set/add)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        slot = self._slot(labels, lambda: [0.0])
        slot[0] = float(value)

    def add(self, value: float, **labels) -> None:
        slot = self._slot(labels, lambda: [0.0])
        slot[0] += value

    def value(self, **labels) -> float:
        key = _label_key(self.label_names, labels)
        slot = self._series.get(key)
        return slot[0] if slot else 0.0


class _HistSeries:
    __slots__ = ("counts", "sum", "count", "ring")

    def __init__(self, n_buckets: int, window: int):
        # one overflow slot past the last boundary (+Inf bucket)
        self.counts = np.zeros((n_buckets + 1,), dtype=np.int64)
        self.sum = 0.0
        self.count = 0
        self.ring = Ring(window) if window else None


class Histogram(_Metric):
    """Fixed-bucket histogram with an optional percentile window.

    ``buckets`` are upper boundaries (ascending); values above the last
    boundary land in the +Inf overflow slot.  ``window`` > 0 additionally
    keeps the last ``window`` raw observations in a :class:`Ring` so
    ``percentile`` reports exact window quantiles (bucket-interpolated
    quantiles are too coarse for SLO p99s at toy scale).
    """

    kind = "histogram"

    def __init__(self, name, help="", labels=(), *,
                 buckets=DEFAULT_BUCKETS, window: int = 1024):
        super().__init__(name, help, labels)
        self.buckets = np.asarray(sorted(buckets), dtype=np.float64)
        if len(self.buckets) == 0:
            raise ValueError("histogram needs at least one bucket")
        self.window = int(window)

    def _mk(self):
        return _HistSeries(len(self.buckets), self.window)

    def observe(self, value: float, **labels) -> None:
        s = self._slot(labels, self._mk)
        s.counts[int(np.searchsorted(self.buckets, value))] += 1
        s.sum += value
        s.count += 1
        if s.ring is not None:
            s.ring.append(value)

    def observe_many(self, values, **labels) -> None:
        """Batch observe (one searchsorted for the whole array)."""
        v = np.asarray(values, dtype=np.float64).ravel()
        if v.size == 0:
            return
        s = self._slot(labels, self._mk)
        idx = np.searchsorted(self.buckets, v)
        np.add.at(s.counts, idx, 1)
        s.sum += float(v.sum())
        s.count += v.size
        if s.ring is not None:
            s.ring.extend(v)

    def percentile(self, q, **labels) -> float | None:
        key = _label_key(self.label_names, labels)
        s = self._series.get(key)
        if s is None or s.ring is None:
            return None
        return s.ring.percentile(q)


class MetricsRegistry:
    """Named metric namespace: get-or-create semantics, one snapshot.

    ``counter``/``gauge``/``histogram`` are idempotent — asking twice
    with the same name returns the same instance (and raises if the
    second ask disagrees on type or labels), so instrumented modules
    never need to coordinate creation order.
    """

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name, help, labels, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, labels, **kw)
                self._metrics[name] = m
                return m
        if not isinstance(m, cls) or m.label_names != tuple(labels):
            raise ValueError(
                f"metric {name!r} already registered as {m.kind} "
                f"with labels {m.label_names}"
            )
        return m

    def counter(self, name, help="", labels=()) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name, help="", labels=()) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name, help="", labels=(), *,
                  buckets=DEFAULT_BUCKETS, window=1024) -> Histogram:
        return self._get(Histogram, name, help, labels,
                         buckets=buckets, window=window)

    def metrics(self) -> list[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    def snapshot(self) -> dict:
        """Flat, JSON-serializable view of every series.

        ``{name: {label_str: value}}`` for counters/gauges and
        ``{name: {label_str: {count, sum, p50, p99}}}`` for histograms
        (label_str is ``"k=v,k=v"``; ``""`` for unlabelled series).
        """
        out = {}
        for m in self.metrics():
            series = {}
            for key, s in m.series().items():
                lbl = ",".join(
                    f"{n}={v}" for n, v in zip(m.label_names, key)
                )
                if m.kind == "histogram":
                    series[lbl] = {
                        "count": int(s.count),
                        "sum": float(s.sum),
                        "p50": s.ring.percentile(50) if s.ring else None,
                        "p99": s.ring.percentile(99) if s.ring else None,
                    }
                else:
                    series[lbl] = float(s[0])
            out[m.name] = series
        return out


_DEFAULT = MetricsRegistry()
_DEFAULT_LOCK = threading.Lock()


def get_default_registry() -> MetricsRegistry:
    """The process-global registry (serving default: every layer's
    instrumentation lands in one scrapeable namespace)."""
    return _DEFAULT


def reset_default_registry() -> MetricsRegistry:
    """Swap in a fresh global registry (test isolation)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        _DEFAULT = MetricsRegistry()
    return _DEFAULT
