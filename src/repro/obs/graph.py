"""Graph X-ray: structural health of the BQ-native topology (DESIGN.md §15).

The paper's central claim is that a 2-bit metric space can *define*
graph topology; §10's probe tests that claim on the corpus
*distribution* before building.  Nothing so far tests it on the built
*graph* — degree collapse, medoid unreachability, or BQ↔float32 edge
disagreement stay invisible until shadow recall (§14) has already
cratered.  This module computes a device-side
:class:`GraphHealthReport` straight from the adjacency arrays:

* **degree structure** — in/out-degree histograms and means over the
  live rows, plus the *saturation* ratio (rows at the full adjacency
  bound: no slack left for reverse edges, the churn-pressure signal);
* **reciprocity** — the fraction of directed edges whose reverse edge
  also exists.  Vamana's reverse-append keeps healthy graphs well
  above a few percent; a near-zero ratio means pruning degenerated the
  graph into directed chains;
* **medoid reachability** — a batched frontier BFS from the medoid
  over the full adjacency (tombstoned rows route, per the navigation
  semantics), reporting unreachable live rows and hop-radius
  percentiles (the descent-length distribution an entry point implies);
* **tombstone density** — dead/allocated on streaming indexes;
* **edge agreement** — the paper's topology question as a live gauge:
  re-rank a sample of adjacency lists in float32 cosine and measure
  the top-k overlap with the BQ ordering that *built* them.  When BQ
  and float32 disagree about which of a node's own edges are closest,
  greedy descent follows the wrong gradient.

All statistics summarize into a calibrated ``health_score`` in [0, 1]
and a green/amber/red ``verdict`` (:class:`GraphThresholds`), persist
through index save/load/freeze (same npz-merge idiom as the §10
probe), and band-cross through :class:`GraphHealthMonitor` into the
§14 remediation ladder (amber → consolidate/replan, red → flag for
rebuild-through-probe).
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bq
from repro.obs.metrics import MetricsRegistry, get_default_registry

BANDS = ("green", "amber", "red")
_BAND_CODE = {b: i for i, b in enumerate(BANDS)}

# degree-histogram bucket upper edges (counts land host-side in the
# report and, when a registry is given, in quiver_graph_*_degree)
DEGREE_BUCKETS = (0, 1, 2, 4, 8, 16, 32, 64, 128)

# reciprocity is an O(N·R²) gather; fold it blockwise so the working
# set stays ~block·R² regardless of N
_RECIP_BLOCK = 512


@dataclasses.dataclass(frozen=True)
class GraphThresholds:
    """Verdict calibration for the structural statistics.

    Measured on the surrogate tiers at N=4000, m=16 (DESIGN.md §15):
    healthy contrastive builds read unreachable ≈ 0, reciprocity
    0.15-0.4, edge agreement 0.75-0.9; sign-collapsed corpora (the
    paper's Finding-1 red zone) build graphs whose sampled edge
    agreement drops under ~0.3 because every BQ distance ties.
    """

    unreachable_amber: float = 0.005  # >0.5% live rows off the medoid tree
    unreachable_red: float = 0.05
    agreement_amber: float = 0.55     # BQ vs f32 disagree on own edges
    agreement_red: float = 0.35
    tombstone_amber: float = 0.25     # consolidation overdue
    tombstone_red: float = 0.60
    reciprocity_amber: float = 0.02   # directed-chain degeneracy
    degree_amber: float = 0.25        # mean out-degree / bound collapse


DEFAULT_GRAPH_THRESHOLDS = GraphThresholds()

_FLOAT_FIELDS = (
    "out_degree_mean", "in_degree_mean", "saturation", "reciprocity",
    "unreachable_frac", "hop_p50", "hop_p99", "hop_max",
    "tombstone_density", "edge_agreement",
)
_INT_FIELDS = (
    "n_live", "n_allocated", "n_unreachable", "n_sampled",
    "degree_bound", "agreement_k", "seed",
)


@dataclasses.dataclass(frozen=True)
class GraphHealthReport:
    """One structural X-ray of a built graph (see module docstring).

    ``edge_agreement`` is NaN when the index has no float32 cold tier
    or no sampled row carries ``2 * agreement_k`` edges; the verdict
    then rests on the purely structural statistics.
    """

    n_live: int               # rows the stats describe
    n_allocated: int          # rows with adjacency state (>= n_live)
    degree_bound: int         # adjacency width (r + reverse slack)
    out_degree_mean: float    # live-row means
    in_degree_mean: float
    saturation: float         # live rows at the full degree bound
    reciprocity: float        # edges whose reverse edge exists
    n_unreachable: int        # live rows the medoid BFS never reached
    unreachable_frac: float
    hop_p50: float            # medoid hop-radius percentiles (reached)
    hop_p99: float
    hop_max: float
    tombstone_density: float  # dead / allocated
    edge_agreement: float     # sampled BQ vs f32 top-k edge overlap
    n_sampled: int            # rows in the agreement sample
    agreement_k: int
    seed: int
    out_degree_hist: tuple = ()   # counts per DEGREE_BUCKETS edge (+inf)
    in_degree_hist: tuple = ()
    thresholds: GraphThresholds = DEFAULT_GRAPH_THRESHOLDS

    # -- calibrated summary -------------------------------------------------

    def _cascade(self) -> tuple[str, str, float, float]:
        """(band, stat, value, threshold) of the worst tripped rule."""
        t = self.thresholds
        reds = (
            ("unreachable_frac", self.unreachable_frac, t.unreachable_red,
             self.unreachable_frac > t.unreachable_red),
            ("edge_agreement", self.edge_agreement, t.agreement_red,
             not math.isnan(self.edge_agreement)
             and self.edge_agreement < t.agreement_red),
            ("tombstone_density", self.tombstone_density, t.tombstone_red,
             self.tombstone_density > t.tombstone_red),
        )
        for stat, value, threshold, hit in reds:
            if hit:
                return "red", stat, value, threshold
        degree_frac = (
            self.out_degree_mean / self.degree_bound
            if self.degree_bound else 1.0
        )
        ambers = (
            ("unreachable_frac", self.unreachable_frac, t.unreachable_amber,
             self.unreachable_frac > t.unreachable_amber),
            ("edge_agreement", self.edge_agreement, t.agreement_amber,
             not math.isnan(self.edge_agreement)
             and self.edge_agreement < t.agreement_amber),
            ("tombstone_density", self.tombstone_density, t.tombstone_amber,
             self.tombstone_density > t.tombstone_amber),
            ("reciprocity", self.reciprocity, t.reciprocity_amber,
             self.reciprocity < t.reciprocity_amber),
            ("out_degree_mean", degree_frac, t.degree_amber,
             degree_frac < t.degree_amber),
        )
        for stat, value, threshold, hit in ambers:
            if hit:
                return "amber", stat, value, threshold
        return "green", "health_score", self.health_score, 1.0

    @property
    def verdict(self) -> str:
        return self._cascade()[0]

    def worst_stat(self) -> tuple[str, float, float]:
        """(stat, value, threshold) behind the current verdict."""
        _, stat, value, threshold = self._cascade()
        return stat, value, threshold

    @property
    def health_score(self) -> float:
        """Numeric summary in [0, 1]: the min of the per-statistic
        scores, each normalized so 1.0 is comfortably healthy and 0.0
        is at (or past) its red line.  A trend signal — the banded
        ``verdict`` is the actionable output."""
        t = self.thresholds

        def clip(x):
            return float(min(max(x, 0.0), 1.0))

        scores = [
            clip(1.0 - self.unreachable_frac / t.unreachable_red),
            clip(1.0 - self.tombstone_density / t.tombstone_red),
            clip(self.reciprocity / t.reciprocity_amber),
        ]
        if self.degree_bound:
            scores.append(clip(
                self.out_degree_mean / self.degree_bound / t.degree_amber
            ))
        if not math.isnan(self.edge_agreement):
            scores.append(clip(
                (self.edge_agreement - t.agreement_red)
                / (t.agreement_amber - t.agreement_red)
            ))
        return min(scores)

    def summary(self) -> str:
        stat, value, threshold = self.worst_stat()
        return (
            f"{self.verdict}: score={self.health_score:.2f} "
            f"{stat}={value:.3f} (threshold {threshold:g}) "
            f"unreachable={self.n_unreachable}/{self.n_live} "
            f"agreement@{self.agreement_k}={self.edge_agreement:.3f} "
            f"tombstones={self.tombstone_density:.2f}"
        )

    def to_dict(self) -> dict:
        out = {f: getattr(self, f) for f in _FLOAT_FIELDS + _INT_FIELDS}
        out["out_degree_hist"] = list(self.out_degree_hist)
        out["in_degree_hist"] = list(self.in_degree_hist)
        out["health_score"] = self.health_score
        out["verdict"] = self.verdict
        return out

    # -- persistence (merged into index npz archives) ----------------------

    def to_npz_fields(self, prefix: str = "graph_") -> dict:
        out = {
            prefix + name: np.float64(getattr(self, name))
            for name in _FLOAT_FIELDS
        }
        out.update({
            prefix + name: np.int64(getattr(self, name))
            for name in _INT_FIELDS
        })
        out[prefix + "out_degree_hist"] = np.asarray(
            self.out_degree_hist, dtype=np.int64)
        out[prefix + "in_degree_hist"] = np.asarray(
            self.in_degree_hist, dtype=np.int64)
        out[prefix + "thresholds"] = np.asarray(
            [getattr(self.thresholds, f.name)
             for f in dataclasses.fields(GraphThresholds)],
            dtype=np.float64,
        )
        return out

    @classmethod
    def from_npz(cls, z, prefix: str = "graph_"):
        """Rebuild from an index archive; None when it carries none."""
        if prefix + "out_degree_mean" not in z:
            return None
        kw = {
            name: float(z[prefix + name][()])
            for name in _FLOAT_FIELDS if prefix + name in z
        }
        kw.update(
            {name: int(z[prefix + name][()]) for name in _INT_FIELDS}
        )
        for name in ("out_degree_hist", "in_degree_hist"):
            if prefix + name in z:
                kw[name] = tuple(int(v) for v in z[prefix + name])
        th = z[prefix + "thresholds"]
        names = [f.name for f in dataclasses.fields(GraphThresholds)]
        kw["thresholds"] = GraphThresholds(
            **{n: float(v) for n, v in zip(names, th)}
        )
        return cls(**kw)


# -- device-side probes -----------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block",))
def _structure_stats(adjacency, allocated, *, block=_RECIP_BLOCK):
    """(out_deg, in_deg, edges, reciprocal_edges) — one fused pass.

    Degrees count edges leaving allocated rows (targets may be
    tombstoned: they still route).  Reciprocity folds blockwise so the
    (block, R, R) back-edge gather bounds the working set.
    """
    n, _ = adjacency.shape
    valid = (adjacency >= 0) & allocated[:, None]
    out_deg = valid.sum(-1, dtype=jnp.int32)
    tgt = jnp.where(valid, adjacency, 0)
    in_deg = jnp.zeros((n,), jnp.int32).at[tgt.ravel()].add(
        valid.ravel().astype(jnp.int32))

    pad = (-n) % block
    rows = jnp.arange(n + pad, dtype=jnp.int32)

    def blk(carry, ids):
        ids_c = jnp.minimum(ids, n - 1)
        a = adjacency[ids_c]
        v = (a >= 0) & allocated[ids_c][:, None] & (ids < n)[:, None]
        t = jnp.where(v, a, 0)
        back = adjacency[t]                       # (B, R, R)
        rec = (back == ids_c[:, None, None]).any(-1) & v
        edges, recip = carry
        return (edges + v.sum(dtype=jnp.int32),
                recip + rec.sum(dtype=jnp.int32)), None

    (edges, recip), _ = jax.lax.scan(
        blk, (jnp.int32(0), jnp.int32(0)), rows.reshape(-1, block))
    return out_deg, in_deg, edges, recip


@functools.partial(jax.jit, static_argnames=("max_hops",))
def _medoid_bfs(adjacency, allocated, medoid, *, max_hops=64):
    """Hop distance from the medoid over the full adjacency, -1 when
    unreached.  A boolean-frontier fixpoint: every round scatters the
    neighbors of all reached rows (O(N·R) per hop, no dynamic shapes)
    until no row turns over or ``max_hops`` is hit."""
    n, _ = adjacency.shape
    dist = jnp.full((n,), -1, jnp.int32).at[medoid].set(0)

    def cond(state):
        _, hop, grew = state
        return grew & (hop < max_hops)

    def body(state):
        dist, hop, _ = state
        reached = dist >= 0
        valid = (adjacency >= 0) & reached[:, None] & allocated[:, None]
        tgt = jnp.where(valid, adjacency, 0)
        nbr = jnp.zeros((n,), jnp.bool_).at[tgt.ravel()].max(valid.ravel())
        new = nbr & ~reached
        return (jnp.where(new, hop + 1, dist), hop + 1, new.any())

    dist, _, _ = jax.lax.while_loop(
        cond, body, (dist, jnp.int32(0), jnp.bool_(True)))
    return dist


@functools.partial(jax.jit, static_argnames=("dim", "k"))
def _edge_agreement(words, vectors, adjacency, sample_ids, *, dim, k):
    """Mean top-k overlap between the BQ and float32-cosine orderings of
    each sampled row's own adjacency list.  Rows are pre-filtered
    host-side to carry >= k live edges, so both top-k sets draw from
    real candidates only."""
    adj_s = adjacency[sample_ids]                  # (S, R)
    valid = adj_s >= 0
    tgt = jnp.where(valid, adj_s, 0)
    d_bq = bq.symmetric_distance(
        bq.Signature(words[sample_ids][:, None, :], dim),
        bq.Signature(words[tgt], dim),
    )                                              # (S, R) int32
    neg = jnp.float32(-jnp.inf)
    score_bq = jnp.where(valid, -d_bq.astype(jnp.float32), neg)
    v = vectors / jnp.maximum(
        jnp.linalg.norm(vectors, axis=-1, keepdims=True), 1e-12)
    sim = jnp.einsum("sd,srd->sr", v[sample_ids], v[tgt])
    score_f32 = jnp.where(valid, sim, neg)
    _, top_b = jax.lax.top_k(score_bq, k)
    _, top_f = jax.lax.top_k(score_f32, k)
    overlap = (top_b[:, :, None] == top_f[:, None, :]).any(-1)
    return overlap.mean(-1).mean()


# -- the report entry point -------------------------------------------------


def graph_health_report(
    adjacency,
    *,
    medoid: int,
    words=None,
    dim: int | None = None,
    vectors=None,
    live=None,
    allocated=None,
    sample: int = 256,
    agreement_k: int = 8,
    max_hops: int = 64,
    seed: int = 0,
    thresholds: GraphThresholds = DEFAULT_GRAPH_THRESHOLDS,
    registry: MetricsRegistry | None = None,
) -> GraphHealthReport:
    """Compute a :class:`GraphHealthReport` from raw index arrays.

    ``live``/``allocated`` default to all-rows (immutable snapshots);
    streaming indexes pass their masks so tombstoned rows route in the
    BFS but never count as unreachable.  ``words`` + ``dim`` +
    ``vectors`` arm the sampled edge-agreement probe (NaN without
    them).  Deterministic for a fixed ``seed``.
    """
    n = int(adjacency.shape[0])
    degree_bound = int(adjacency.shape[1])
    live_h = (np.ones(n, bool) if live is None
              else np.asarray(live, bool).copy())
    alloc_h = (live_h.copy() if allocated is None
               else np.asarray(allocated, bool).copy())
    alloc_d = jnp.asarray(alloc_h)
    n_live = int(live_h.sum())
    n_alloc = int(alloc_h.sum())

    out_deg, in_deg, edges, recip = _structure_stats(adjacency, alloc_d)
    dist = _medoid_bfs(
        adjacency, alloc_d, jnp.int32(medoid), max_hops=max_hops)
    out_deg = np.asarray(out_deg)
    in_deg = np.asarray(in_deg)
    dist = np.asarray(dist)
    edges, recip = int(edges), int(recip)

    live_out = out_deg[live_h]
    live_in = in_deg[live_h]
    reached = (dist >= 0) & live_h
    hops = dist[reached]
    n_unreachable = int(n_live - reached.sum())
    edges_hist = list(DEGREE_BUCKETS) + [np.inf]

    agreement = float("nan")
    sampled_ids = np.zeros(0, np.int64)
    if words is not None and vectors is not None and n_live:
        # a row whose degree is exactly k makes both top-k sets the whole
        # candidate list (overlap trivially 1.0) — require 2k edges so the
        # two orderings have real choices to disagree about
        eligible = np.nonzero(live_h & (out_deg >= 2 * agreement_k))[0]
        if len(eligible):
            rng = np.random.default_rng(seed)
            take = min(int(sample), len(eligible))
            sampled_ids = np.sort(
                rng.choice(eligible, size=take, replace=False))
            agreement = float(_edge_agreement(
                words, vectors, adjacency,
                jnp.asarray(sampled_ids, jnp.int32),
                dim=int(dim), k=int(agreement_k),
            ))

    report = GraphHealthReport(
        n_live=n_live,
        n_allocated=n_alloc,
        degree_bound=degree_bound,
        out_degree_mean=float(live_out.mean()) if n_live else 0.0,
        in_degree_mean=float(live_in.mean()) if n_live else 0.0,
        saturation=(
            float((live_out == degree_bound).mean()) if n_live else 0.0),
        reciprocity=float(recip / edges) if edges else 0.0,
        n_unreachable=n_unreachable,
        unreachable_frac=(n_unreachable / n_live) if n_live else 0.0,
        hop_p50=float(np.percentile(hops, 50)) if len(hops) else 0.0,
        hop_p99=float(np.percentile(hops, 99)) if len(hops) else 0.0,
        hop_max=float(hops.max()) if len(hops) else 0.0,
        tombstone_density=(
            1.0 - n_live / n_alloc if n_alloc else 0.0),
        edge_agreement=agreement,
        n_sampled=len(sampled_ids),
        agreement_k=int(agreement_k),
        seed=int(seed),
        out_degree_hist=tuple(
            int(c) for c in np.histogram(live_out, bins=edges_hist)[0]),
        in_degree_hist=tuple(
            int(c) for c in np.histogram(live_in, bins=edges_hist)[0]),
        thresholds=thresholds,
    )

    reg = registry if registry is not None else get_default_registry()
    for name, vals in (("out", live_out), ("in", live_in)):
        h = reg.histogram(
            f"quiver_graph_{name}_degree",
            f"live-row {name}-degree distribution at last health probe",
            buckets=DEGREE_BUCKETS[1:], window=0,
        )
        h.observe_many(vals)
    return report


# -- the monitor ------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GraphHealthAlarm:
    """One structural band-crossing (worsenings only, like drift)."""

    tenant: str
    prev_band: str
    band: str
    stat: str
    value: float
    threshold: float
    health_score: float
    n_live: int
    unix_ts: float

    def message(self) -> str:
        return (
            f"[graph] tenant={self.tenant} {self.prev_band}->{self.band} "
            f"{self.stat}={self.value:.3f} (threshold {self.threshold:g}, "
            f"score={self.health_score:.2f}, n_live={self.n_live})"
        )


class GraphHealthMonitor:
    """Edge-triggered banding over successive :class:`GraphHealthReport`s.

    The structural twin of :class:`~repro.obs.drift.DriftMonitor`:
    arming asserts a healthy baseline (first check already amber/red
    alarms immediately), band *worsenings* raise a
    :class:`GraphHealthAlarm` through ``subscribe()`` (the hook
    :class:`~repro.obs.remediate.RemediationPolicy.attach_graph` uses)
    and recoveries update state silently.  Gauges track the latest
    score/band plus the score delta between consecutive checks — the
    per-consolidation-cycle health delta.
    """

    def __init__(
        self,
        *,
        tenant: str = "default",
        registry: MetricsRegistry | None = None,
        max_events: int = 256,
        clock=time.time,
    ):
        self.tenant = tenant
        self.clock = clock
        self.band = None                # unknown until first check()
        self.last_report: GraphHealthReport | None = None
        self.last_score: float | None = None
        self.alarms: list[GraphHealthAlarm] = []
        self.events = collections.deque(maxlen=max_events)
        self._subs: list = []
        reg = registry if registry is not None else get_default_registry()
        self._c_alarms = reg.counter(
            "quiver_graph_health_alarms_total",
            "graph-health band-crossing alarms",
            labels=("tenant", "band"),
        )
        self._g_score = reg.gauge(
            "quiver_graph_health_score",
            "latest structural health score [0, 1]", labels=("tenant",),
        )
        self._g_band = reg.gauge(
            "quiver_graph_health_band",
            "latest graph band (0=green 1=amber 2=red)",
            labels=("tenant",),
        )
        self._g_delta = reg.gauge(
            "quiver_graph_health_delta",
            "health-score delta vs the previous check (per cycle)",
            labels=("tenant",),
        )

    def subscribe(self, fn) -> None:
        """Register ``fn(alarm)`` for every raised alarm."""
        self._subs.append(fn)

    def check(self, report: GraphHealthReport) -> GraphHealthAlarm | None:
        """Band a fresh report; raise on a band *worsening* only."""
        band = report.verdict
        score = report.health_score
        self._g_score.set(score, tenant=self.tenant)
        self._g_band.set(_BAND_CODE[band], tenant=self.tenant)
        if self.last_score is not None:
            self._g_delta.set(score - self.last_score, tenant=self.tenant)
        self.last_report, self.last_score = report, score
        prev, self.band = self.band, band
        if prev is None:
            prev = "green"      # arming asserts a healthy baseline
        if band == prev:
            return None
        stat, value, threshold = report.worst_stat()
        event = GraphHealthAlarm(
            tenant=self.tenant, prev_band=prev, band=band, stat=stat,
            value=value, threshold=threshold, health_score=score,
            n_live=report.n_live, unix_ts=self.clock(),
        )
        self.events.append(event)
        if _BAND_CODE[band] > _BAND_CODE[prev]:
            self.alarms.append(event)
            self._c_alarms.inc(tenant=self.tenant, band=band)
            for fn in list(self._subs):
                fn(event)
            return event
        return None

    def report(self) -> dict:
        return {
            "tenant": self.tenant,
            "band": self.band,
            "health_score": self.last_score,
            "alarms": len(self.alarms),
            "events": [dataclasses.asdict(e) for e in self.events],
            "last_report": (
                self.last_report.to_dict() if self.last_report else None
            ),
        }
