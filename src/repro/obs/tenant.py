"""Per-tenant SLO accounting and token-bucket admission quotas.

Multi-tenant serving needs two things the engine could not answer
before: *enforcement* (a tenant may not buy more than its share of the
fleet) and *attribution* (whose requests degraded, whose dropped, whose
p99 blew the SLO).  A :class:`TenantLedger` owns both:

* **quota** — one :class:`TokenBucket` per tenant (``qps`` refill rate,
  ``burst`` capacity, cost = queries in the request).  Buckets are
  independent, so an over-budget tenant exhausts only its own tokens:
  rejecting it cannot starve anyone else — isolation is structural, not
  scheduled.  Tenants without a quota are never rejected.
* **accounting** — per-tenant counters (submitted/admitted/rejected/
  done/dropped/degraded, queries), a latency :class:`Ring` for window
  p50/p99, and the audit trail the multitenant benchmark checks:
  ``quota_violations`` counts admissions that went through on an empty
  bucket, which the ledger's own ``admit`` makes impossible — a nonzero
  value means some path bypassed admission.

The ledger is clock-injected (same convention as ``QueryEngine``) so
quota refill is testable without sleeping.
"""

from __future__ import annotations

import dataclasses
import time

from repro.obs.metrics import MetricsRegistry, Ring, latency_summary

DEFAULT_TENANT = "default"

# per-stat navigation-trace window (hops/evals/descent/... per tenant);
# smaller than the latency window — nav traces are per *query*, not per
# request, and the report only needs a current-behaviour p50
NAV_WINDOW = 512


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Admission budget: sustained ``qps`` with ``burst`` headroom, plus
    an optional quality floor — the rolling shadow-recall p50 the tenant
    was promised (DESIGN.md §14).  Unlike qps, the recall SLO is not
    enforced at admission (a query can't be rejected for future recall);
    breaches are *events* the remediation policy subscribes to."""

    qps: float
    burst: float | None = None       # default: 2 * qps (min 1)
    recall_slo: float | None = None  # rolling recall@k p50 floor

    def capacity(self) -> float:
        if self.burst is not None:
            return float(self.burst)
        return max(2.0 * self.qps, 1.0)


class TokenBucket:
    """Classic token bucket: refills at ``qps``, caps at ``capacity``."""

    __slots__ = ("rate", "capacity", "tokens", "stamp")

    def __init__(self, quota: TenantQuota, now: float):
        self.rate = float(quota.qps)
        self.capacity = quota.capacity()
        self.tokens = self.capacity      # full burst on arrival
        self.stamp = now

    def refill(self, now: float) -> None:
        dt = max(now - self.stamp, 0.0)
        self.tokens = min(self.capacity, self.tokens + dt * self.rate)
        self.stamp = now

    def take(self, cost: float, now: float) -> bool:
        self.refill(now)
        if self.tokens >= cost:
            self.tokens -= cost
            return True
        return False


@dataclasses.dataclass
class TenantStats:
    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    done: int = 0
    dropped: int = 0
    degraded: int = 0
    queries: int = 0                 # admitted queries
    rejected_queries: int = 0
    latencies: Ring = None           # set by the ledger (window-sized)
    recalls: Ring = None             # shadow recall@k window (ledger-set)
    nav: dict = None                 # {stat: Ring} beam nav counters
    recall_breaches: int = 0         # breached-state entries (not samples)
    recall_breached: bool = False    # currently below the recall SLO


class TenantLedger:
    """Quota enforcement + per-tenant serving accounts (see module
    docstring).  One per :class:`~repro.serve.engine.QueryEngine`."""

    def __init__(
        self,
        *,
        registry: MetricsRegistry | None = None,
        latency_window: int = 1024,
        recall_window: int = 256,
        recall_min_samples: int = 16,
        clock=time.monotonic,
    ):
        self.clock = clock
        self.latency_window = int(latency_window)
        self.recall_window = int(recall_window)
        # breach evaluation needs a minimally credible window: a single
        # unlucky shadow sample must not page anyone
        self.recall_min_samples = int(recall_min_samples)
        self._quotas: dict[str, TenantQuota] = {}
        self._buckets: dict[str, TokenBucket] = {}
        self._stats: dict[str, TenantStats] = {}
        self._breach_subs: list = []
        self.quota_violations = 0
        self._reg = registry
        if registry is not None:
            self._c_requests = registry.counter(
                "quiver_tenant_requests_total",
                "requests submitted per tenant and admission outcome",
                labels=("tenant", "outcome"),
            )
            self._c_queries = registry.counter(
                "quiver_tenant_queries_total",
                "admitted queries per tenant", labels=("tenant",),
            )
            self._h_latency = registry.histogram(
                "quiver_tenant_latency_seconds",
                "request latency per tenant", labels=("tenant",),
                window=latency_window,
            )
            self._g_tokens = registry.gauge(
                "quiver_tenant_quota_tokens",
                "remaining admission tokens", labels=("tenant",),
            )
            self._h_recall = registry.histogram(
                "quiver_tenant_recall",
                "shadow-sampled recall@k per tenant", labels=("tenant",),
                buckets=(0.1, 0.25, 0.5, 0.7, 0.8, 0.9, 0.95, 0.99, 1.0),
                window=recall_window,
            )
            self._c_breaches = registry.counter(
                "quiver_recall_slo_breaches_total",
                "recall-SLO breached-state entries", labels=("tenant",),
            )

    # -- quota -------------------------------------------------------------

    def set_quota(self, tenant: str, qps: float,
                  burst: float | None = None,
                  recall_slo: float | None = None) -> TenantQuota:
        q = TenantQuota(qps=qps, burst=burst, recall_slo=recall_slo)
        self._quotas[tenant] = q
        self._buckets[tenant] = TokenBucket(q, self.clock())
        return q

    def quota(self, tenant: str) -> TenantQuota | None:
        return self._quotas.get(tenant)

    def stats(self, tenant: str) -> TenantStats:
        s = self._stats.get(tenant)
        if s is None:
            s = self._stats[tenant] = TenantStats(
                latencies=Ring(self.latency_window),
                recalls=Ring(self.recall_window),
                nav={},
            )
        return s

    def admit(self, tenant: str, n_queries: int,
              now: float | None = None) -> bool:
        """Charge ``n_queries`` against the tenant's bucket; False means
        the request must be rejected (quota exhausted).  Tenants with no
        quota are always admitted."""
        now = self.clock() if now is None else now
        s = self.stats(tenant)
        s.submitted += 1
        bucket = self._buckets.get(tenant)
        ok = True if bucket is None else bucket.take(n_queries, now)
        if ok:
            s.admitted += 1
            s.queries += n_queries
            if bucket is not None and bucket.tokens < 0:
                # structurally unreachable through take(); a nonzero
                # count means an admission path bypassed the bucket
                self.quota_violations += 1
        else:
            s.rejected += 1
            s.rejected_queries += n_queries
        if self._reg is not None:
            self._c_requests.inc(
                tenant=tenant, outcome="admitted" if ok else "rejected"
            )
            if ok:
                self._c_queries.inc(n_queries, tenant=tenant)
            if bucket is not None:
                self._g_tokens.set(bucket.tokens, tenant=tenant)
        return ok

    # -- attribution -------------------------------------------------------

    def observe(self, tenant: str, *, status: str,
                latency: float | None = None,
                degraded: bool = False) -> None:
        """Account one finished request (``done`` | ``dropped``)."""
        s = self.stats(tenant)
        if status == "done":
            s.done += 1
        elif status == "dropped":
            s.dropped += 1
        else:
            raise ValueError(f"unknown terminal status {status!r}")
        if degraded:
            s.degraded += 1
        if latency is not None:
            s.latencies.append(latency)
            if self._reg is not None:
                self._h_latency.observe(latency, tenant=tenant)

    def observe_nav(self, tenant: str, traces: dict) -> None:
        """Account one request's navigation counters: ``traces`` maps a
        stat name (``hops``/``evals``/``descent``/...) to that tenant's
        per-query values from the finalized batch.  Each stat rides its
        own bounded Ring so the report shows *current* navigation
        behaviour — a tenant whose hops p50 climbs while recall still
        holds is walking a degrading graph (DESIGN.md §15)."""
        s = self.stats(tenant)
        for stat, vals in traces.items():
            ring = s.nav.get(stat)
            if ring is None:
                ring = s.nav[stat] = Ring(NAV_WINDOW)
            ring.extend(vals)

    # -- recall SLO --------------------------------------------------------

    def subscribe(self, fn) -> None:
        """Register ``fn(event_dict)`` for recall-SLO breach events.
        Fired once per breached-state *entry* (edge-triggered, like the
        drift monitor's band crossings), not once per bad sample."""
        self._breach_subs.append(fn)

    def recall_breached(self, tenant: str) -> bool:
        return self.stats(tenant).recall_breached

    def observe_recall(self, tenant: str, recall: float) -> bool:
        """Account one shadow-sampled recall@k measurement.

        Appends to the tenant's rolling window, re-evaluates the recall
        SLO over it, and returns whether the tenant is currently in
        breach.  State transitions into breach increment the breach
        counter and notify subscribers; recovery (window p50 back above
        the floor) silently clears the flag so the next degradation
        alarms again.
        """
        s = self.stats(tenant)
        s.recalls.append(float(recall))
        if self._reg is not None:
            self._h_recall.observe(float(recall), tenant=tenant)
        q = self._quotas.get(tenant)
        if q is None or q.recall_slo is None:
            return False
        if len(s.recalls) < self.recall_min_samples:
            return s.recall_breached
        p50 = s.recalls.percentile(50)
        if p50 < q.recall_slo:
            if not s.recall_breached:
                s.recall_breached = True
                s.recall_breaches += 1
                if self._reg is not None:
                    self._c_breaches.inc(tenant=tenant)
                event = {
                    "kind": "recall_slo", "tenant": tenant,
                    "recall_p50": float(p50),
                    "recall_slo": float(q.recall_slo),
                    "window": len(s.recalls),
                }
                for fn in list(self._breach_subs):
                    fn(event)
        else:
            s.recall_breached = False
        return s.recall_breached

    # -- reporting ---------------------------------------------------------

    def tenants(self) -> list[str]:
        return sorted(set(self._stats) | set(self._quotas))

    def report(self) -> dict:
        """Per-tenant SLO account: counters, window percentiles, quota
        state, plus the fleet-wide ``quota_violations`` audit."""
        out = {"quota_violations": self.quota_violations, "tenants": {}}
        for t in self.tenants():
            s = self.stats(t)
            q = self._quotas.get(t)
            lat = s.latencies
            out["tenants"][t] = {
                "submitted": s.submitted,
                "admitted": s.admitted,
                "rejected": s.rejected,
                "done": s.done,
                "dropped": s.dropped,
                "degraded": s.degraded,
                "queries": s.queries,
                "rejected_queries": s.rejected_queries,
                **latency_summary(lat),
                "quota_qps": q.qps if q else None,
                "quota_burst": q.capacity() if q else None,
                "recall_p50": (
                    round(s.recalls.percentile(50), 4)
                    if s.recalls is not None and len(s.recalls) else None
                ),
                "recall_n": (
                    len(s.recalls) if s.recalls is not None else 0
                ),
                "recall_slo": (
                    q.recall_slo if q is not None else None
                ),
                "recall_breaches": s.recall_breaches,
                "recall_breached": s.recall_breached,
                "nav": {
                    stat: {"p50": round(r.percentile(50), 3),
                           "n": len(r)}
                    for stat, r in sorted((s.nav or {}).items())
                    if len(r)
                },
            }
        return out
