"""Closed-loop drift remediation: alarms -> actions (DESIGN.md §14).

The observability stack ends in two *event* streams — probe-drift
alarms (:class:`~repro.obs.drift.DriftMonitor`, bit-plane statistics
crossing the calibrated bands) and recall-SLO breaches
(:class:`~repro.obs.tenant.TenantLedger`, shadow-sampled recall p50
dropping below a tenant's quota).  Both mean the same thing: the nav
schedule chosen at build time is no longer earning its recall.  A
:class:`RemediationPolicy` subscribes to both and walks an ordered
action ladder, cheapest-first, until an action plausibly restores
recall:

1. ``reprobe``      — re-run the probe diagnostics on the *live* corpus
   (the accumulator's exact entropies, or a fresh sampled probe).  A
   drift alarm whose re-probe still reads green is a false alarm:
   resolve, no serving change.
2. ``replan``       — the re-probe's :func:`~repro.probe.select_policy`
   wants a different nav rung: switch the index's default via
   ``replan(nav=...)``, invalidating only the old family's compiled
   plans (every other tenant's executables survive — zero retraces).
3. ``escalate_ef``  — the rung is already right but recall is short:
   double the engine's default ef bucket (capped at ``ef_cap`` x the
   original) — spend compute, keep the schedule.
4. ``flag_red``     — the ladder is exhausted: flag the corpus red and
   route the default to the exact float32 ladder (``adc`` when the
   index is vector-free).  Loud, expensive, and correct — the paper's
   boundary says BQ navigation has no business here.

One trigger advances the ladder by one *plausible* action; repeated
triggers (recall still breaching after a replan) walk further down.
Every action is emitted as a span + a
``quiver_remediation_actions_total{action,trigger}`` counter, so the
closed loop is itself observable.
"""

from __future__ import annotations

import collections
import time

from repro.obs.metrics import MetricsRegistry, get_default_registry

ACTIONS = ("reprobe", "replan", "escalate_ef", "consolidate", "flag_red")


class RemediationPolicy:
    """Subscribe to quality alarms and walk the remediation ladder.

    ``engine`` is a :class:`~repro.serve.engine.QueryEngine` (anything
    with ``.index``, ``.default_ef``, ``.tenants`` and optionally
    ``.obs``); the index is always read through the engine so snapshot
    swaps (``engine.swap_index``) are followed automatically.

    ``auto=True`` (default) acts on every subscribed event the moment
    it fires; ``auto=False`` queues triggers for an operator-paced
    :meth:`check` (benchmarks and cautious fleets).  Both drift alarms
    and SLO breaches are edge-triggered at their source, so auto mode
    sees one trigger per degradation episode, not a flood.

    ``probe_source`` overrides where re-probes come from: a callable
    returning a :class:`CompatibilityReport`, or any object with a
    ``probe_report()`` method (a mutable index, a sharded fleet).  By
    default the policy prefers, in order: the index's own
    ``probe_report()``, its live :class:`ProbeAccumulator`, a fresh
    sampled probe of the cold vectors, and finally a signature-only
    accumulator report.
    """

    def __init__(
        self,
        engine,
        *,
        probe_source=None,
        auto: bool = True,
        ef_cap: float = 8.0,
        probe_sample: int = 1024,
        registry: MetricsRegistry | None = None,
        max_events: int = 256,
        clock=time.time,
    ):
        self.engine = engine
        self.probe_source = probe_source
        self.auto = bool(auto)
        self.ef_cap = float(ef_cap)
        self.probe_sample = int(probe_sample)
        self.clock = clock
        self.base_ef = int(engine.default_ef)
        self.flagged_red = False
        self.last_report = None            # most recent re-probe
        self.triggers = collections.deque(maxlen=max_events)
        self.events = collections.deque(maxlen=max_events)
        self.action_counts = {a: 0 for a in ACTIONS}
        obs = getattr(engine, "obs", None)
        self.tracer = obs.tracer if obs is not None else None
        reg = registry
        if reg is None:
            reg = obs.registry if obs is not None else get_default_registry()
        self._c_actions = reg.counter(
            "quiver_remediation_actions_total",
            "remediation-ladder actions by trigger",
            labels=("action", "trigger"),
        )
        # the ledger's breach events are already wired to the engine
        engine.tenants.subscribe(self._on_breach)

    @property
    def index(self):
        return self.engine.index

    # -- subscriptions -------------------------------------------------------

    def attach(self, monitor) -> "RemediationPolicy":
        """Subscribe to a :class:`DriftMonitor`'s alarms; returns self
        (``policy.attach(m1).attach(m2)`` chains over a fleet)."""
        monitor.subscribe(self._on_drift)
        return self

    def _on_drift(self, alarm) -> None:
        self._trigger({
            "kind": "drift",
            "tenant": alarm.tenant,
            "band": alarm.band,
            "stat": alarm.stat,
            "value": alarm.value,
        })

    def attach_graph(self, monitor) -> "RemediationPolicy":
        """Subscribe to a :class:`~repro.obs.graph.GraphHealthMonitor`'s
        structural band crossings (chainable, like :meth:`attach`).
        Graph triggers walk their own short ladder in :meth:`step`:
        amber is a topology-repair problem (consolidate / replan
        recommendation), not an ef problem — spending beam width on a
        disconnected graph buys nothing."""
        monitor.subscribe(self._on_graph)
        return self

    def _on_graph(self, alarm) -> None:
        self._trigger({
            "kind": "graph_health",
            "tenant": alarm.tenant,
            "band": alarm.band,
            "stat": alarm.stat,
            "value": alarm.value,
        })

    def _on_breach(self, event: dict) -> None:
        self._trigger(dict(event))         # kind == "recall_slo"

    def _trigger(self, trigger: dict) -> None:
        if self.auto:
            self.step(trigger)
        else:
            self.triggers.append(trigger)

    def check(self) -> dict | None:
        """Process queued triggers (``auto=False`` mode).  All pending
        triggers coalesce into **one** ladder step — they describe the
        same degradation episode; acting once and re-observing beats
        racing down the ladder on correlated alarms."""
        if not self.triggers:
            return None
        trigger = self.triggers.popleft()
        self.triggers.clear()
        return self.step(trigger)

    # -- the ladder ----------------------------------------------------------

    def step(self, trigger: dict) -> dict:
        """Advance the ladder one plausible action for ``trigger``;
        returns the event record describing what was done."""
        kind = trigger.get("kind", "manual")
        if self.flagged_red:
            # already at the bottom: nothing cheaper left to try
            return self._emit("flag_red", kind, trigger,
                              note="already red-flagged")
        if kind == "graph_health":
            return self._step_graph(trigger)
        report = self._reprobe()
        self.last_report = report
        verdict = report.verdict if report is not None else "amber"
        if kind == "drift" and verdict == "green":
            # the sampled probe overrules the cheap entropy banding:
            # false alarm, no serving change
            return self._emit("reprobe", kind, trigger,
                              verdict=verdict, note="false alarm")
        self._emit("reprobe", kind, trigger, verdict=verdict)
        target = self._target_policy(report)
        current = self._current_nav()
        if target is not None and target.nav != current:
            self.index.replan(
                nav=target.nav, ef_scale=target.ef_scale,
                adaptive=target.adaptive, source="remediation",
            )
            return self._emit("replan", kind, trigger,
                              nav=f"{current}->{target.nav}")
        cap = int(self.base_ef * self.ef_cap)
        if self.engine.default_ef < cap:
            new_ef = min(2 * self.engine.default_ef, cap)
            old_ef, self.engine.default_ef = self.engine.default_ef, new_ef
            return self._emit("escalate_ef", kind, trigger,
                              ef=f"{old_ef}->{new_ef}")
        self.flagged_red = True
        fallback = "float32" if self.index.vectors is not None else "adc"
        if current != fallback:
            self.index.replan(nav=fallback, source="remediation:red")
        return self._emit("flag_red", kind, trigger, nav=fallback)

    def _step_graph(self, trigger: dict) -> dict:
        """The structural branch of the ladder.  Amber means the
        topology needs *repair*, so the cheapest plausible action is a
        consolidation cycle (splice-and-reprune, slot reclamation) when
        the index is mutable — an immutable snapshot gets a
        consolidate/replan recommendation instead.  Red means the graph
        contradicts its own metric space (mass unreachability, BQ/f32
        edge disagreement): no serve-time knob fixes that, so flag for
        a rebuild through the probe (``build(nav="auto")``)."""
        band = trigger.get("band", "amber")
        if band == "red":
            self.flagged_red = True
            return self._emit("flag_red", "graph_health", trigger,
                              note="rebuild-through-probe")
        idx = self.index
        if hasattr(idx, "consolidate"):
            rep = idx.consolidate()
            return self._emit(
                "consolidate", "graph_health", trigger,
                repaired=int(rep.get("repaired_rows", 0)),
                reclaimed=int(rep.get("reclaimed", 0)),
            )
        return self._emit(
            "consolidate", "graph_health", trigger,
            note="immutable snapshot: consolidate/replan at next swap",
        )

    def resolve(self, note: str = "operator resolve") -> None:
        """Clear the red flag and restore the original ef bucket —
        the operator (or a recovered SLO) declaring the episode over;
        the next trigger walks the ladder from the top again."""
        self.flagged_red = False
        self.engine.default_ef = self.base_ef
        self.events.append({
            "action": "resolve", "trigger": "manual", "note": note,
            "unix_ts": self.clock(),
        })

    # -- internals -----------------------------------------------------------

    def _current_nav(self) -> str:
        idx = self.index
        policy = getattr(idx, "policy", None)
        return policy.nav if policy is not None else idx.metric_kind

    def _target_policy(self, report):
        if report is None:
            return None
        from repro.probe import select_policy
        idx = self.index
        return select_policy(
            report,
            have_vectors=getattr(idx, "vectors", None) is not None,
            have_ivf=getattr(idx, "ivf", None) is not None,
        )

    def _reprobe(self):
        src = self.probe_source
        if callable(src):
            return src()
        if src is not None and hasattr(src, "probe_report"):
            return src.probe_report()
        idx = self.index
        if hasattr(idx, "probe_report"):
            return idx.probe_report()
        acc = getattr(idx, "probe_acc", None)
        if acc is not None and getattr(acc, "n", 0):
            from repro.probe import report_from_accumulator
            return report_from_accumulator(acc)
        if getattr(idx, "vectors", None) is not None:
            from repro.probe import probe_corpus
            return probe_corpus(idx.vectors, sample=self.probe_sample)
        # vector-free immutable index: exact signature statistics are
        # all we have — fold them into an accumulator report
        import numpy as np
        from repro.probe import ProbeAccumulator, report_from_accumulator
        acc = ProbeAccumulator(idx.sigs.dim)
        acc.add(np.asarray(idx.sigs.words))
        return report_from_accumulator(acc)

    def _emit(self, action: str, trigger_kind: str, trigger: dict,
              **detail) -> dict:
        self.action_counts[action] += 1
        self._c_actions.inc(action=action, trigger=trigger_kind)
        event = {
            "action": action, "trigger": trigger_kind,
            "tenant": trigger.get("tenant", "default"),
            **detail, "unix_ts": self.clock(),
        }
        self.events.append(event)
        if self.tracer is not None:
            with self.tracer.span("remediate", 0, action=action,
                                  trigger=trigger_kind, **detail):
                pass
        return event

    def report(self) -> dict:
        return {
            "auto": self.auto,
            "flagged_red": self.flagged_red,
            "base_ef": self.base_ef,
            "default_ef": int(self.engine.default_ef),
            "current_nav": self._current_nav(),
            "pending_triggers": len(self.triggers),
            "actions": dict(self.action_counts),
            "last_verdict": (
                self.last_report.verdict
                if self.last_report is not None else None
            ),
            "events": list(self.events),
        }
