"""Mamba (S6) selective state-space layer — Jamba's recurrent block.

Training/prefill uses ``jax.lax.associative_scan`` over time (parallel
prefix over the diagonal SSM recurrence); decode is the O(1)-state
single-step update.  The causal depthwise conv (width 4) is expressed as
shifted adds, which lowers to cheap pad+slice HLO everywhere.

State for serving: (conv_state (B, d_conv-1, d_inner),
                    ssm_state (B, d_inner, d_state)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import shard
from repro.models.layers import init_linear, linear, silu


def init_mamba(key, d_model: int, *, d_state: int = 16, d_conv: int = 4,
               expand: int = 2, dt_rank: int | None = None,
               dtype=jnp.bfloat16) -> dict:
    d_inner = expand * d_model
    dt_rank = dt_rank or max(16, d_model // 16)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    a = jnp.tile(
        jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :],
        (d_inner, 1),
    )
    return {
        "in_proj": init_linear(ks[0], d_model, 2 * d_inner, dtype=dtype),
        "conv_w": (
            jax.random.normal(ks[1], (d_conv, 1, d_inner), jnp.float32)
            / np.sqrt(d_conv)
        ).astype(dtype),
        "x_proj": init_linear(ks[2], d_inner, dt_rank + 2 * d_state,
                              dtype=dtype),
        "dt_proj": init_linear(ks[3], dt_rank, d_inner, dtype=dtype),
        "dt_bias": jnp.zeros((d_inner,), jnp.float32),
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": init_linear(ks[5], d_inner, d_model, dtype=dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 prev: jnp.ndarray | None = None):
    """Depthwise causal conv via shifted adds.

    x: (B, T, d_inner); w: (width, 1, d_inner).
    prev: (B, width-1, d_inner) carry-in for decode/prefill chunking.
    Returns (y, new_prev) where new_prev holds the last width-1 inputs.
    """
    width = w.shape[0]
    b, t, d = x.shape
    if prev is None:
        prev = jnp.zeros((b, width - 1, d), dtype=x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)            # (B, T+width-1, d)
    y = jnp.zeros((b, t, d), dtype=jnp.float32)
    for i in range(width):
        y = y + xp[:, i:i + t, :].astype(jnp.float32) * w[i, 0][None, None, :]
    new_prev = xp[:, t:, :] if width > 1 else prev
    return y.astype(x.dtype), new_prev


def _ssm_scan(u, dt, a, b_mat, c_mat, d_skip, h0=None):
    """Selective scan.  u,dt: (B,T,di); b,c: (B,T,ds); a: (di,ds)."""
    # discretize
    da = jnp.exp(dt[..., None] * a[None, None])                 # (B,T,di,ds)
    db_u = (dt * u)[..., None] * b_mat[:, :, None, :]           # (B,T,di,ds)
    if h0 is not None:
        # fold the incoming state in as a virtual first step
        da0 = jnp.ones_like(h0)[:, None]                        # (B,1,di,ds)
        da = jnp.concatenate([da0, da], axis=1)
        db_u = jnp.concatenate([h0[:, None], db_u], axis=1)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    a_acc, h = jax.lax.associative_scan(combine, (da, db_u), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    y = jnp.einsum("btds,bts->btd", h, c_mat) + u * d_skip[None, None]
    return y, h[:, -1]                                           # last state


def mamba(
    p: dict,
    x: jnp.ndarray,                       # (B, T, d_model)
    *,
    conv_state: jnp.ndarray | None = None,
    ssm_state: jnp.ndarray | None = None,
    return_state: bool = False,
):
    """Returns y (B,T,d) and, if requested, (conv_state, ssm_state)."""
    d_state = p["a_log"].shape[-1]
    dt_rank = p["x_proj"]["w"].shape[-1] - 2 * d_state

    xz = linear(p["in_proj"], x)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, "dp", None, "tp")

    has_state = conv_state is not None
    xin, new_conv = _causal_conv(xin, p["conv_w"], conv_state)
    xin = silu(xin)

    proj = linear(p["x_proj"], xin)
    dt_in, b_mat, c_mat = jnp.split(
        proj, [dt_rank, dt_rank + d_state], axis=-1
    )
    dt = jax.nn.softplus(
        linear(p["dt_proj"], dt_in).astype(jnp.float32)
        + p["dt_bias"][None, None]
    )
    a = -jnp.exp(p["a_log"])

    y, last_state = _ssm_scan(
        xin.astype(jnp.float32), dt, a,
        b_mat.astype(jnp.float32), c_mat.astype(jnp.float32),
        p["d_skip"],
        h0=ssm_state if has_state else None,
    )
    y = (y.astype(x.dtype) * silu(z))
    y = shard(y, "dp", None, "tp")
    out = linear(p["out_proj"], y)
    if return_state:
        return out, (new_conv, last_state)
    return out


def init_mamba_state(b: int, d_model: int, *, d_state=16, d_conv=4,
                     expand=2, dtype=jnp.bfloat16):
    d_inner = expand * d_model
    return (
        jnp.zeros((b, d_conv - 1, d_inner), dtype=dtype),
        jnp.zeros((b, d_inner, d_state), dtype=jnp.float32),
    )
