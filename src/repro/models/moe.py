"""Mixture-of-Experts with capacity-based sort/gather dispatch.

Dispatch is *gather-based* (argsort by expert + rank-within-group +
capacity drop), not the dense one-hot-matmul formulation: the HLO FLOPs
then reflect only real expert compute, which keeps the roofline's
MODEL_FLOPS/HLO_FLOPs ratio honest (a one-hot dispatch einsum would
double-count dispatch as compute).

Expert weights are stored (E, d, f) and sharded (None, dp, tp) — the
expert count never has to divide a mesh axis (qwen2's 60 experts), while
per-expert compute is TP-sharded on d_ff.  Shared experts (qwen2-moe)
are a dense FFN of width n_shared * d_ff fused into one matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P
from repro.dist.compat import shard_map

from repro.dist.sharding import active_ctx, param_pspecs
from repro.models.layers import silu


def init_moe(
    key,
    d_model: int,
    d_ff: int,
    n_experts: int,
    top_k: int,
    *,
    n_shared_experts: int = 0,
    dtype=jnp.bfloat16,
) -> dict:
    ks = jax.random.split(key, 6)
    scale = 1.0 / np.sqrt(d_model)

    def mat(k, shape, s):
        return (jax.random.normal(k, shape, jnp.float32) * s).astype(dtype)

    p = {
        "router": {
            "w": jax.random.normal(
                ks[0], (d_model, n_experts), jnp.float32
            ) * scale,  # router stays fp32 for routing stability
        },
        "w1": mat(ks[1], (n_experts, d_model, d_ff), scale),
        "w3": mat(ks[2], (n_experts, d_model, d_ff), scale),
        "w2": mat(ks[3], (n_experts, d_ff, d_model), 1.0 / np.sqrt(d_ff)),
    }
    if n_shared_experts:
        ds = n_shared_experts * d_ff
        p["shared"] = {
            "w1": mat(ks[4], (d_model, ds), scale),
            "w3": mat(ks[5], (d_model, ds), scale),
            "w2": mat(jax.random.fold_in(ks[4], 7), (ds, d_model),
                      1.0 / np.sqrt(ds)),
        }
    return p


def _row_moe(flat, p, *, top_k: int, cap: int, norm_topk: bool):
    """Dispatch + expert compute for ONE batch row (T, d).

    Keeping sort/gather/scatter indices *row-local* keeps every dispatch
    op batch-sharded under vmap — a global flat dispatch makes XLA
    all-gather full (tokens x d_model) activations around each sort/
    scatter (measured: 275s collective term on qwen3 train_4k,
    EXPERIMENTS.md §Perf iteration a.1).
    """
    n_tok, d = flat.shape
    e = p["w1"].shape[0]

    # --- routing (fp32) ----------------------------------------------------
    logits = flat.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)     # (T, k)
    if norm_topk:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9
        )

    # --- capacity dispatch, gather-only formulation --------------------------
    # GSPMD replicates scatter operands under vmap (measured +80s on the
    # scatter form, §Perf iteration a.2); two argsorts + gathers + a
    # sum-over-k partition cleanly instead.  No scatter ops anywhere.
    n_pairs = n_tok * top_k
    pairs_e = expert_idx.reshape(-1)                          # (P,)
    pairs_tok = jnp.repeat(jnp.arange(n_tok), top_k)
    pairs_gate = gate_vals.reshape(-1)

    order = jnp.argsort(pairs_e)
    inv_order = jnp.argsort(order)
    e_s = pairs_e[order]
    tok_s = pairs_tok[order]

    idx = jnp.arange(n_pairs)
    boundary = jnp.concatenate([jnp.array([True]), e_s[1:] != e_s[:-1]])
    seg_start = jax.lax.cummax(jnp.where(boundary, idx, 0))
    rank = idx - seg_start                                    # within expert
    keep = rank < cap

    # expert buffer via gather: slot (e, r) reads sorted pair seg[e]+r
    starts = jnp.searchsorted(e_s, jnp.arange(e))             # (E,)
    pos = starts[:, None] + jnp.arange(cap)[None, :]          # (E, cap)
    pos_c = jnp.minimum(pos, n_pairs - 1)
    valid = (pos < n_pairs) & (e_s[pos_c] == jnp.arange(e)[:, None])
    src_tok = jnp.where(valid, tok_s[pos_c], n_tok)           # sentinel row
    flat_pad = jnp.concatenate(
        [flat, jnp.zeros((1, d), dtype=flat.dtype)], axis=0
    )
    buf = flat_pad[src_tok]                                   # (E, cap, d)

    # --- expert compute (TP on d_ff) ----------------------------------------
    h = silu(jnp.einsum("ecd,edf->ecf", buf, p["w1"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["w3"]
    )
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w2"])

    # --- combine: gather back in original pair order -------------------------
    rank_orig = rank[inv_order]
    keep_orig = keep[inv_order]
    slot_orig = jnp.where(
        keep_orig, pairs_e * cap + rank_orig, e * cap
    )
    out_flat = jnp.concatenate(
        [out_buf.reshape(e * cap, d),
         jnp.zeros((1, d), dtype=out_buf.dtype)], axis=0
    )
    y_pairs = out_flat[slot_orig] * pairs_gate[:, None].astype(out_buf.dtype)
    y = y_pairs.reshape(n_tok, top_k, d).sum(axis=1)

    # --- shared experts (dense) ----------------------------------------------
    if "shared" in p:
        sh = p["shared"]
        hs = silu(flat @ sh["w1"]) * (flat @ sh["w3"])
        y = y + (hs @ sh["w2"]).astype(y.dtype)

    # --- aux metrics (gather/segment-free) -----------------------------------
    me = probs.mean(axis=0)                                   # (E,)
    ce = (jax.nn.one_hot(pairs_e, e, dtype=jnp.float32).sum(0)
          / n_pairs)
    aux = {
        "load_balance_loss": e * jnp.sum(me * ce),
        "dropped_fraction": 1.0 - keep.mean(),
    }
    return y.astype(flat.dtype), aux


def _cap_for(tokens: int, e: int, top_k: int, cf: float) -> int:
    cap = int(np.ceil(tokens * top_k / e * cf))
    return max(8, ((cap + 7) // 8) * 8)


def moe(
    p: dict,
    x: jnp.ndarray,                     # (B, T, d)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    norm_topk: bool = True,
    serving: bool = False,
) -> tuple[jnp.ndarray, dict]:
    ctx = active_ctx()
    b, t, d = x.shape
    e = p["w1"].shape[0]

    if ctx is None:
        # single-device path (smoke tests, local runs)
        cap = _cap_for(t, e, top_k, capacity_factor)
        y, aux = jax.vmap(
            functools.partial(_row_moe, p=p, top_k=top_k, cap=cap,
                              norm_topk=norm_topk)
        )(x)
        aux = jax.tree.map(lambda a: a.mean(), aux)
        return y, aux

    # ---- explicit SPMD path (§Perf iteration a.3) --------------------------
    # GSPMD replicates the dispatch gathers/scatters whatever the
    # formulation (measured 272-356s collective on qwen3 train_4k), so
    # the MoE block is a shard_map: dispatch stays device-local on the
    # dp token shard, expert weights are explicitly FSDP-gathered
    # (transpose: reduce-scatter of grads), and the only activation
    # collective is ONE psum of (tokens x d_model) over tp.
    mesh, lmap = ctx
    dp_axes = tuple(lmap.get("dp", ()))
    tp_axes = tuple(lmap.get("tp", ()))
    import math as _math
    dp_size = _math.prod(mesh.shape[a] for a in dp_axes) if dp_axes else 1
    if b % dp_size != 0:
        dp_axes = ()
        dp_size = 1
    local_tokens = (b // dp_size) * t
    cap = _cap_for(local_tokens, e, top_k, capacity_factor)
    # serving layouts replicate the FSDP dim (serve_param_shardings):
    # weights arrive whole, so per-layer dp gathers would be pure waste
    # (measured: jamba decode 3 ms -> 226 ms with gathers, §Perf c.3)
    gather_weights = bool(dp_axes) and not serving

    def local_fn(p_local, x_local):
        # explicit FSDP all-gathers (bf16, amortized over the token block)
        w1, w3, w2 = p_local["w1"], p_local["w3"], p_local["w2"]
        if gather_weights:
            w1 = jax.lax.all_gather(w1, dp_axes, axis=1, tiled=True)
            w3 = jax.lax.all_gather(w3, dp_axes, axis=1, tiled=True)
            w2 = jax.lax.all_gather(w2, dp_axes, axis=2, tiled=True)
        p_full = {"router": p_local["router"], "w1": w1, "w3": w3,
                  "w2": w2}
        if "shared" in p_local:
            sh = p_local["shared"]
            s1, s3, s2 = sh["w1"], sh["w3"], sh["w2"]
            if gather_weights:
                s1 = jax.lax.all_gather(s1, dp_axes, axis=0, tiled=True)
                s3 = jax.lax.all_gather(s3, dp_axes, axis=0, tiled=True)
                s2 = jax.lax.all_gather(s2, dp_axes, axis=1, tiled=True)
            p_full["shared"] = {"w1": s1, "w3": s3, "w2": s2}

        flat = x_local.reshape(-1, x_local.shape[-1])
        y, aux = _row_moe(flat, p_full, top_k=top_k, cap=cap,
                          norm_topk=norm_topk)
        if tp_axes:
            # expert outputs are partial over the d_ff shard
            y = jax.lax.psum(y, tp_axes)
            aux = jax.tree.map(lambda a: jax.lax.pmean(a, tp_axes), aux)
        if dp_axes:
            aux = jax.tree.map(lambda a: jax.lax.pmean(a, dp_axes), aux)
        return y.reshape(x_local.shape), aux

    x_spec = P(dp_axes if len(dp_axes) > 1 else
               (dp_axes[0] if dp_axes else None), None, None)
    # in_specs must match what local_fn expects: weights arrive
    # un-dp-sharded when we skip the gathers (serving / batch==1)
    lmap_eff = {"dp": dp_axes if gather_weights else (),
                "tp": tuple(lmap.get("tp", ()))}
    p_specs = param_pspecs(mesh, p, lmap_eff)
    y, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(p_specs, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(p, x)
    return y, aux
