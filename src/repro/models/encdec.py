"""Whisper-style encoder-decoder backbone (modality frontend stubbed).

The conv stem is a stub: ``input_specs`` feeds precomputed frame
embeddings (B, S_enc, d_model); a linear adapter stands in for the
stem's output projection.  Encoder layers are bidirectional; decoder
layers are causal self-attention + cross-attention over the encoder
output.  Serving caches: per-decoder-layer self KV + precomputed cross
KV (computed once from the encoder output at prefill).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models.layers import (
    embed,
    init_embedding,
    init_linear,
    init_norm,
    linear,
    rmsnorm,
    sinusoidal_positions,
)


class EncDecCaches(NamedTuple):
    self_kv: attn_mod.KVCache        # (L, B, S_dec, K, hd)
    cross_kv: attn_mod.KVCache       # (L, B, S_enc, K, hd)


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_norm(cfg.d_model),
        "attn": attn_mod.init_attention(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
        ),
        "ln2": init_norm(cfg.d_model),
        "mlp": ffn_mod.init_ffn(
            ks[1], cfg.d_model, cfg.d_ff, activation=cfg.activation
        ),
    }


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "ln1": init_norm(cfg.d_model),
        "attn": attn_mod.init_attention(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
        ),
        "ln_x": init_norm(cfg.d_model),
        "xattn": attn_mod.init_cross_attention(
            ks[1], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
        ),
        "ln2": init_norm(cfg.d_model),
        "mlp": ffn_mod.init_ffn(
            ks[2], cfg.d_model, cfg.d_ff, activation=cfg.activation
        ),
    }


def init_encdec(key, cfg) -> dict:
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.n_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_dec_layers)
    enc_layers = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_init_enc_layer(k, cfg) for k in enc_keys],
    )
    dec_layers = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_init_dec_layer(k, cfg) for k in dec_keys],
    )
    return {
        "frontend_adapter": init_linear(ks[2], cfg.d_model, cfg.d_model),
        "embed": init_embedding(ks[3], cfg.padded_vocab, cfg.d_model),
        "enc_layers": enc_layers,
        "enc_norm": init_norm(cfg.d_model),
        "dec_layers": dec_layers,
        "dec_norm": init_norm(cfg.d_model),
        "lm_head": init_linear(ks[4], cfg.d_model, cfg.padded_vocab),
    }


def encode(params, cfg, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, S_enc, d) stub embeddings -> encoder output."""
    b, s, d = frames.shape
    x = linear(params["frontend_adapter"], frames)
    x = x + sinusoidal_positions(s, d)[None].astype(x.dtype)
    x = shard(x, "dp", "tp", None)

    def body(h, layer):
        a, _ = attn_mod.attention_forward(
            layer["attn"], rmsnorm(layer["ln1"], h, cfg.norm_eps),
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim_, causal=False, kv_chunk=cfg.kv_chunk,
        )
        h = h + a
        h = h + ffn_mod.ffn(
            layer["mlp"], rmsnorm(layer["ln2"], h, cfg.norm_eps),
            activation=cfg.activation,
        )
        return shard(h, "dp", "tp", None), None

    body_fn = jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable
    ) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer_apply(layer, h, cfg, *, enc_out=None, cross_kv=None,
                     self_cache=None, cache_pos=None):
    a, new_self = attn_mod.attention_forward(
        layer["attn"], rmsnorm(layer["ln1"], h, cfg.norm_eps),
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim_, causal=True, kv_chunk=cfg.kv_chunk,
        cache=self_cache, cache_pos=cache_pos,
    )
    h = h + a
    if cross_kv is None:
        cross_kv = attn_mod.cross_attention_kv(
            layer["xattn"], enc_out,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
        )
    h = h + attn_mod.cross_attention_forward(
        layer["xattn"], rmsnorm(layer["ln_x"], h, cfg.norm_eps), cross_kv,
        n_heads=cfg.n_heads, head_dim=cfg.head_dim_, kv_chunk=cfg.kv_chunk,
    )
    h = h + ffn_mod.ffn(
        layer["mlp"], rmsnorm(layer["ln2"], h, cfg.norm_eps),
        activation=cfg.activation,
    )
    return shard(h, "dp", "tp", None), new_self


def decode_train(params, cfg, tokens, enc_out) -> jnp.ndarray:
    """Teacher-forced decoder pass -> hidden states (B, S_dec, d)."""
    b, s = tokens.shape
    x = embed(params["embed"], tokens)
    x = x + sinusoidal_positions(s, cfg.d_model)[None].astype(x.dtype)
    x = shard(x, "dp", "tp", None)

    def body(h, layer):
        h, _ = _dec_layer_apply(layer, h, cfg, enc_out=enc_out)
        return h, None

    body_fn = jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable
    ) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    return rmsnorm(params["dec_norm"], x, cfg.norm_eps)


def make_cross_kv(params, cfg, enc_out) -> attn_mod.KVCache:
    """Precompute per-layer cross K/V from encoder output (prefill)."""
    def body(_, layer):
        kv = attn_mod.cross_attention_kv(
            layer["xattn"], enc_out,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
        )
        return None, kv

    _, kvs = jax.lax.scan(body, None, params["dec_layers"])
    return kvs                                    # leading dim L


def decode_with_cache(params, cfg, tokens, caches: EncDecCaches, cache_pos):
    """Prefill (T>1) or single-token decode (T==1) for the decoder."""
    b, t = tokens.shape
    x = embed(params["embed"], tokens)
    table = sinusoidal_positions(caches.self_kv.k.shape[2], cfg.d_model)
    x = x + jax.lax.dynamic_slice_in_dim(
        table, jnp.asarray(cache_pos), t, axis=0
    )[None].astype(x.dtype)

    def body(h, xs):
        layer, self_kv, cross_kv = xs
        h, new_self = _dec_layer_apply(
            layer, h, cfg, cross_kv=cross_kv,
            self_cache=self_kv, cache_pos=cache_pos,
        )
        return h, new_self

    x, new_self = jax.lax.scan(
        body, x, (params["dec_layers"], caches.self_kv, caches.cross_kv)
    )
    x = rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    return x, EncDecCaches(self_kv=new_self, cross_kv=caches.cross_kv)


def init_encdec_caches(cfg, b: int, s_dec: int, s_enc: int,
                       dtype=jnp.bfloat16) -> EncDecCaches:
    l = cfg.n_dec_layers
    mk = lambda s: attn_mod.KVCache(
        k=jnp.zeros((l, b, s, cfg.n_kv_heads, cfg.head_dim_), dtype),
        v=jnp.zeros((l, b, s, cfg.n_kv_heads, cfg.head_dim_), dtype),
    )
    return EncDecCaches(self_kv=mk(s_dec), cross_kv=mk(s_enc))
