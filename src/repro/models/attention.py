"""GQA attention with XLA-portable chunked flash attention + KV cache.

The softmax is computed blockwise over KV chunks with running max /
denominator (FlashAttention recurrence) via ``lax.scan`` — peak memory is
O(Tq * kv_chunk) instead of O(Tq * Tk), which is what lets the 32k
prefill shapes compile on a 16 GB/chip mesh without a custom kernel, and
it lowers identically on CPU (dry-run) and TPU.  On real TPUs a Pallas
flash kernel can be swapped in behind the same signature; the XLA
formulation is the portable default.

Supports: causal masking, sliding-window (Jamba's attention layers at
long context), GQA head grouping, single-token decode against a sharded
KV cache, and cross-attention (Whisper decoder).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.layers import apply_rope, init_linear, linear

NEG_INF = jnp.float32(-1e30)


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, S, K, hd)
    v: jnp.ndarray  # (B, S, K, hd)


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d_model, n_heads * head_dim, dtype=dtype),
        "wk": init_linear(ks[1], d_model, n_kv_heads * head_dim, dtype=dtype),
        "wv": init_linear(ks[2], d_model, n_kv_heads * head_dim, dtype=dtype),
        "wo": init_linear(ks[3], n_heads * head_dim, d_model, dtype=dtype),
    }


def _chunk_count(t: int, chunk: int) -> int:
    return (t + chunk - 1) // chunk


def flash_attention(
    q: jnp.ndarray,            # (B, Tq, H, hd)
    k: jnp.ndarray,            # (B, Tk, K, hd)
    v: jnp.ndarray,            # (B, Tk, K, hd)
    *,
    causal: bool = True,
    q_offset: jnp.ndarray | int = 0,
    kv_valid_len: jnp.ndarray | None = None,
    sliding_window: int = 0,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Blockwise-softmax attention; returns (B, Tq, H, hd)."""
    b, tq, h, hd = q.shape
    tk, kh = k.shape[1], k.shape[2]
    g = h // kh
    if tq <= 8:
        # decode fast path: scores are tiny (Tq x Tk), so one-pass
        # softmax over the full (possibly sequence-sharded) KV — XLA
        # turns this into flash-decoding (local partials + stat psums)
        # instead of gathering KV chunk by chunk through a scan.
        qg = q.reshape(b, tq, kh, g, hd).astype(jnp.float32)
        scores = jnp.einsum(
            "btkgh,bskh->btkgs", qg, k.astype(jnp.float32)
        ) * (hd ** -0.5)
        k_pos = jnp.arange(tk)
        q_pos = jnp.asarray(q_offset) + jnp.arange(tq)
        mask = jnp.ones((tq, tk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if sliding_window:
            mask &= k_pos[None, :] > q_pos[:, None] - sliding_window
        if kv_valid_len is not None:
            mask &= k_pos[None, :] < kv_valid_len
        scores = jnp.where(mask[None, :, None, None, :], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("btkgs,bskh->btkgh", p, v.astype(jnp.float32))
        return out.reshape(b, tq, h, hd).astype(q.dtype)
    if kh < h:
        # GQA grouping (K, G) cannot be head-sharded when K < tp: GSPMD
        # re-layouts every (B,T,K,G,hd) intermediate (measured ~2.7 GB of
        # per-layer gathers on qwen3, §Perf iteration a.4).  MHA-izing the
        # KV (repeat to H heads) keeps one shardable H dim; FLOPs are
        # unchanged, KV repeat is transient.  Decode keeps grouped KV (the
        # cache read is its memory bound; see the tq<=8 fast path).
        k = jnp.repeat(k, h // kh, axis=2)
        v = jnp.repeat(v, h // kh, axis=2)
        kh = h
        g = 1
    kv_chunk = min(kv_chunk, tk)
    n_chunks = _chunk_count(tk, kv_chunk)
    pad = n_chunks * kv_chunk - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = q.reshape(b, tq, kh, g, hd).astype(jnp.float32)
    scale = hd ** -0.5
    q_pos = jnp.asarray(q_offset) + jnp.arange(tq)

    kc = k.reshape(b, n_chunks, kv_chunk, kh, hd)
    vc = v.reshape(b, n_chunks, kv_chunk, kh, hd)
    # scan over kv chunks: carry running (acc, max, denom)
    acc0 = jnp.zeros((b, tq, kh, g, hd), jnp.float32)
    m0 = jnp.full((b, tq, kh, g), NEG_INF)
    d0 = jnp.zeros((b, tq, kh, g), jnp.float32)

    def body(carry, inputs):
        acc, m, d = carry
        kj, vj, j = inputs
        scores = jnp.einsum(
            "btkgh,bckh->btkgc", qg, kj.astype(jnp.float32)
        ) * scale                                        # (B,Tq,K,G,C)
        k_pos = j * kv_chunk + jnp.arange(kv_chunk)
        mask = jnp.ones((tq, kv_chunk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if sliding_window:
            mask &= k_pos[None, :] > q_pos[:, None] - sliding_window
        if kv_valid_len is not None:
            mask &= k_pos[None, :] < kv_valid_len
        mask &= (k_pos < tk)[None, :]
        scores = jnp.where(mask[None, :, None, None, :], scores, NEG_INF)

        m_new = jnp.maximum(m, scores.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        acc = acc * corr[..., None] + jnp.einsum(
            "btkgc,bckh->btkgh", p, vj.astype(jnp.float32)
        )
        d = d * corr + p.sum(axis=-1)
        return (acc, m_new, d), None

    (acc, m, d), _ = jax.lax.scan(
        body,
        (acc0, m0, d0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1),
         jnp.arange(n_chunks)),
    )
    out = acc / jnp.maximum(d[..., None], 1e-30)
    return out.reshape(b, tq, h, hd).astype(q.dtype)


def attention_forward(
    p: dict,
    x: jnp.ndarray,                  # (B, T, d)
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float = 1e4,
    positions: jnp.ndarray | None = None,
    causal: bool = True,
    sliding_window: int = 0,
    kv_chunk: int = 1024,
    cache: KVCache | None = None,
    cache_pos: jnp.ndarray | int | None = None,
) -> tuple[jnp.ndarray, KVCache | None]:
    """Self-attention in train / prefill / decode modes.

    * train:    cache=None                      -> attends within x
    * prefill:  cache=empty, cache_pos=0        -> fills cache[0:T]
    * decode:   cache=filled, cache_pos=t, T==1 -> attends over cache[:t+1]
    """
    b, t, _ = x.shape
    if positions is None:
        base = 0 if cache_pos is None else cache_pos
        positions = jnp.asarray(base) + jnp.arange(t)[None, :]

    q = linear(p["wq"], x).reshape(b, t, n_heads, head_dim)
    k = linear(p["wk"], x).reshape(b, t, n_kv_heads, head_dim)
    v = linear(p["wv"], x).reshape(b, t, n_kv_heads, head_dim)
    q = shard(q, "dp", None, "tp", None)
    k = shard(k, "dp", None, "tp", None)
    v = shard(v, "dp", None, "tp", None)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)

    new_cache = None
    if cache is not None:
        assert cache_pos is not None
        ck = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype),
            (0, jnp.asarray(cache_pos), 0, 0),
        )
        cv = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype),
            (0, jnp.asarray(cache_pos), 0, 0),
        )
        new_cache = KVCache(k=ck, v=cv)
        k_att, v_att = ck, cv
        valid = jnp.asarray(cache_pos) + t
        out = flash_attention(
            q, k_att, v_att,
            causal=True,
            q_offset=cache_pos,
            kv_valid_len=valid,
            sliding_window=sliding_window,
            kv_chunk=kv_chunk,
        )
    else:
        out = flash_attention(
            q, k, v,
            causal=causal,
            sliding_window=sliding_window,
            kv_chunk=kv_chunk,
        )
    out = out.reshape(b, t, n_heads * head_dim)
    return linear(p["wo"], out), new_cache


def init_cross_attention(key, d_model, n_heads, n_kv_heads, head_dim,
                         *, dtype=jnp.bfloat16) -> dict:
    return init_attention(key, d_model, n_heads, n_kv_heads, head_dim,
                          dtype=dtype)


def cross_attention_kv(p: dict, enc_out: jnp.ndarray, *,
                       n_kv_heads: int, head_dim: int) -> KVCache:
    """Precompute encoder-side K/V once per sequence (Whisper decoder)."""
    b, s, _ = enc_out.shape
    k = linear(p["wk"], enc_out).reshape(b, s, n_kv_heads, head_dim)
    v = linear(p["wv"], enc_out).reshape(b, s, n_kv_heads, head_dim)
    return KVCache(k=shard(k, "dp", None, "tp", None),
                   v=shard(v, "dp", None, "tp", None))


def cross_attention_forward(
    p: dict,
    x: jnp.ndarray,
    enc_kv: KVCache,
    *,
    n_heads: int,
    head_dim: int,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    b, t, _ = x.shape
    q = linear(p["wq"], x).reshape(b, t, n_heads, head_dim)
    q = shard(q, "dp", None, "tp", None)
    out = flash_attention(
        q, enc_kv.k, enc_kv.v, causal=False, kv_chunk=kv_chunk
    )
    return linear(p["wo"], out.reshape(b, t, n_heads * head_dim))


def make_kv_cache(b: int, s: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    shape = (b, s, n_kv_heads, head_dim)
    return KVCache(
        k=jnp.zeros(shape, dtype=dtype), v=jnp.zeros(shape, dtype=dtype)
    )
