"""Decoder-only LM assembly over heterogeneous layer stacks.

All ten assigned architectures share this skeleton.  Layers are grouped
by the architecture's *pattern period* P (jamba: 8 = lcm(attn 1:8, MoE
1:2); xlstm: 8 = 7 mLSTM + 1 sLSTM; dense/moe: 1) and the stack is a
``lax.scan`` over n_layers/P groups with a Python loop over the P
heterogeneous positions inside the (rematerialized) group body — HLO
size stays O(P) regardless of depth, which is what keeps 96-layer
340B-parameter configs compilable in seconds.

Serving state (KV caches / SSM states / xLSTM cells) is stored with a
leading group dimension and threaded through the same scan as xs/ys.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import embed, init_embedding, init_linear, \
    init_norm, linear, rmsnorm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg, kind: str, has_moe: bool) -> dict:
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": init_norm(cfg.d_model)}
    if kind == "attn":
        p["attn"] = attn_mod.init_attention(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
        )
    elif kind == "mamba":
        p["mamba"] = mamba_mod.init_mamba(
            ks[0], cfg.d_model, d_state=cfg.d_state, d_conv=cfg.d_conv,
            expand=cfg.ssm_expand,
        )
    elif kind == "mlstm":
        p["cell"] = xlstm_mod.init_mlstm(
            ks[0], cfg.d_model, n_heads=cfg.n_heads,
            expand=cfg.xlstm_expand,
        )
        return p                       # xLSTM blocks carry no separate FFN
    elif kind == "slstm":
        p["cell"] = xlstm_mod.init_slstm(
            ks[0], cfg.d_model, n_heads=cfg.n_heads
        )
        return p
    else:
        raise ValueError(kind)

    p["ln2"] = init_norm(cfg.d_model)
    if has_moe:
        p["moe"] = moe_mod.init_moe(
            ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.top_k,
            n_shared_experts=cfg.n_shared_experts,
        )
    else:
        p["mlp"] = ffn_mod.init_ffn(
            ks[1], cfg.d_model, cfg.d_ff, activation=cfg.activation
        )
    return p


def init_decoder(key, cfg) -> dict:
    period, groups = cfg.pattern()
    ks = jax.random.split(key, 3)
    params: dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.padded_vocab, cfg.d_model),
        "final_norm": init_norm(cfg.d_model),
        "lm_head": init_linear(ks[1], cfg.d_model, cfg.padded_vocab),
    }
    layer_keys = jax.random.split(ks[2], groups * period).reshape(
        groups, period, 2
    )
    stacked = []
    for pos in range(period):
        kind = cfg.layer_kind(pos)
        has_moe = cfg.layer_has_moe(pos)
        per_group = [
            _init_layer(layer_keys[g, pos], cfg, kind, has_moe)
            for g in range(groups)
        ]
        stacked.append(
            jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
        )
    params["layers"] = stacked
    return params


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------


def _residual_shard(x, cfg):
    if cfg.seq_sharded_residual:
        return shard(x, "dp", "tp", None)
    # recurrent mixers: batch-sharded residual, d_model replicated —
    # activation-d x weight-d axis mismatches otherwise force full-size
    # activation all-reduces (measured: 14.7s -> see EXPERIMENTS.md §Perf)
    return shard(x, "dp", None, None)


def _apply_layer(
    p: dict,
    x: jnp.ndarray,
    cfg,
    kind: str,
    has_moe: bool,
    *,
    cache: Any = None,
    cache_pos=None,
    aux_acc=None,
):
    """One residual block. Returns (x, new_cache, aux_acc)."""
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    new_cache = cache
    if kind == "attn":
        sw = cfg.sliding_window
        if cache is not None:
            out, new_cache = attn_mod.attention_forward(
                p["attn"], h,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim_, rope_theta=cfg.rope_theta,
                sliding_window=sw, cache=cache, cache_pos=cache_pos,
                kv_chunk=cfg.kv_chunk,
            )
        else:
            out, _ = attn_mod.attention_forward(
                p["attn"], h,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim_, rope_theta=cfg.rope_theta,
                sliding_window=sw, kv_chunk=cfg.kv_chunk,
            )
    elif kind == "mamba":
        if cache is not None:
            out, new_cache = mamba_mod.mamba(
                p["mamba"], h, conv_state=cache[0], ssm_state=cache[1],
                return_state=True,
            )
        else:
            out = mamba_mod.mamba(p["mamba"], h)
    elif kind == "mlstm":
        if cache is not None:
            out, new_cache = xlstm_mod.mlstm_block(
                p["cell"], h, n_heads=cfg.n_heads, state=cache,
                return_state=True,
            )
        else:
            out = xlstm_mod.mlstm_block(p["cell"], h, n_heads=cfg.n_heads)
        return _residual_shard(x + out, cfg), new_cache, aux_acc
    elif kind == "slstm":
        if cache is not None:
            out, new_cache = xlstm_mod.slstm_block(
                p["cell"], h, n_heads=cfg.n_heads, state=cache,
                return_state=True,
            )
        else:
            out = xlstm_mod.slstm_block(p["cell"], h, n_heads=cfg.n_heads)
        return _residual_shard(x + out, cfg), new_cache, aux_acc
    else:
        raise ValueError(kind)

    x = x + out
    x = _residual_shard(x, cfg)
    h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if has_moe:
        mlp_out, aux = moe_mod.moe(
            p["moe"], h2, top_k=cfg.top_k, serving=cache is not None
        )
        if aux_acc is not None:
            aux_acc = jax.tree.map(
                lambda a, b: a + b, aux_acc,
                {"load_balance_loss": aux["load_balance_loss"],
                 "dropped_fraction": aux["dropped_fraction"]},
            )
    else:
        mlp_out = ffn_mod.ffn(p["mlp"], h2, activation=cfg.activation)
    x = x + mlp_out
    x = _residual_shard(x, cfg)
    return x, new_cache, aux_acc


def _zero_aux():
    return {"load_balance_loss": jnp.float32(0.0),
            "dropped_fraction": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def forward_hidden(params, cfg, x: jnp.ndarray) -> tuple[jnp.ndarray, dict]:
    """Training/scoring forward through the stack. x: (B, S, d)."""
    period, groups = cfg.pattern()
    x = _residual_shard(x, cfg)

    def group_body(carry, group_params):
        h, aux = carry
        for pos in range(period):
            h, _, aux = _apply_layer(
                group_params[pos], h, cfg,
                cfg.layer_kind(pos), cfg.layer_has_moe(pos), aux_acc=aux,
            )
        return (h, aux), None

    body = jax.checkpoint(
        group_body, policy=jax.checkpoint_policies.nothing_saveable
    ) if cfg.remat else group_body

    (x, aux), _ = jax.lax.scan(
        body, (x, _zero_aux()), tuple(params["layers"])
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def logits_from_hidden(params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    logits = linear(params["lm_head"], x).astype(jnp.float32)
    logits = shard(logits, "dp", None, "tp")
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    return logits


def embed_tokens(params, cfg, tokens: jnp.ndarray) -> jnp.ndarray:
    return embed(params["embed"], tokens)


def forward_with_cache(
    params, cfg, x: jnp.ndarray, caches: list, cache_pos
) -> tuple[jnp.ndarray, list]:
    """Prefill (T>1) or decode (T==1) against per-layer caches."""
    period, groups = cfg.pattern()

    def group_body(h, xs):
        group_params, group_caches = xs
        new_caches = []
        for pos in range(period):
            h, nc, _ = _apply_layer(
                group_params[pos], h, cfg,
                cfg.layer_kind(pos), cfg.layer_has_moe(pos),
                cache=group_caches[pos], cache_pos=cache_pos,
            )
            new_caches.append(nc)
        return h, tuple(new_caches)

    x, new_caches = jax.lax.scan(
        group_body, x, (tuple(params["layers"]), tuple(caches))
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, list(new_caches)


def init_caches(cfg, b: int, max_seq: int, dtype=jnp.bfloat16) -> list:
    """Per-pattern-position serving state, leading dim = n_groups."""
    period, groups = cfg.pattern()

    def one(pos):
        kind = cfg.layer_kind(pos)
        if kind == "attn":
            return attn_mod.KVCache(
                k=jnp.zeros(
                    (groups, b, max_seq, cfg.n_kv_heads, cfg.head_dim_),
                    dtype,
                ),
                v=jnp.zeros(
                    (groups, b, max_seq, cfg.n_kv_heads, cfg.head_dim_),
                    dtype,
                ),
            )
        if kind == "mamba":
            conv, ssm = mamba_mod.init_mamba_state(
                b, cfg.d_model, d_state=cfg.d_state, d_conv=cfg.d_conv,
                expand=cfg.ssm_expand,
            )
            return (
                jnp.broadcast_to(conv, (groups, *conv.shape)),
                jnp.broadcast_to(ssm, (groups, *ssm.shape)),
            )
        if kind == "mlstm":
            st = xlstm_mod.init_mlstm_state(
                b, cfg.d_model, n_heads=cfg.n_heads, expand=cfg.xlstm_expand
            )
            return tuple(
                jnp.broadcast_to(s, (groups, *s.shape)) for s in st
            )
        if kind == "slstm":
            st = xlstm_mod.init_slstm_state(b, cfg.d_model,
                                            n_heads=cfg.n_heads)
            return tuple(
                jnp.broadcast_to(s, (groups, *s.shape)) for s in st
            )
        raise ValueError(kind)

    return [one(pos) for pos in range(period)]
