"""Shared building blocks: norms, linears, embeddings, rotary, activations.

Parameters are plain pytrees (nested dicts of jnp arrays); every init
function takes a PRNG key and returns the param subtree.  Compute dtype
is bf16 by default with fp32 accumulation at reductions (norms, softmax,
loss); param dtype is configurable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def init_linear(key, d_in: int, d_out: int, *, dtype=jnp.bfloat16,
                scale: float | None = None) -> dict:
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    return {"w": w.astype(dtype)}


def linear(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"]


def init_norm(d: int, *, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * p["scale"]).astype(x.dtype)


def init_layernorm(d: int, *, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype),
            "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"] + p["bias"]).astype(x.dtype)


def init_embedding(key, vocab: int, d: int, *, dtype=jnp.bfloat16) -> dict:
    w = jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02
    return {"w": w.astype(dtype)}


def embed(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return p["w"][tokens]


# -- rotary -----------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """x: (..., T, H, hd); positions: (..., T) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..,T,hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (..,T,1,hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_positions(t: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings (T, d)."""
    pos = np.arange(t)[:, None]
    i = np.arange(d // 2)[None, :]
    angles = pos / (10000 ** (2 * i / d))
    out = np.concatenate([np.sin(angles), np.cos(angles)], axis=-1)
    return jnp.asarray(out, dtype=jnp.float32)


# -- activations -------------------------------------------------------------


def silu(x):
    return x * jax.nn.sigmoid(x)


def squared_relu(x):
    r = jnp.maximum(x, 0)
    return r * r


ACTIVATIONS = {
    "silu": silu,
    "gelu": jax.nn.gelu,
    "squared_relu": squared_relu,
}
