"""Dense feed-forward variants: SwiGLU (llama-family), squared-ReLU
(nemotron), GELU (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.layers import ACTIVATIONS, init_linear, linear, silu


def init_ffn(key, d_model: int, d_ff: int, *, activation: str = "swiglu",
             dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 3)
    if activation == "swiglu":
        return {
            "w1": init_linear(ks[0], d_model, d_ff, dtype=dtype),   # gate
            "w3": init_linear(ks[1], d_model, d_ff, dtype=dtype),   # up
            "w2": init_linear(ks[2], d_ff, d_model, dtype=dtype),   # down
        }
    return {
        "w1": init_linear(ks[0], d_model, d_ff, dtype=dtype),
        "w2": init_linear(ks[1], d_ff, d_model, dtype=dtype),
    }


def ffn(p: dict, x: jnp.ndarray, *, activation: str = "swiglu") -> jnp.ndarray:
    if activation == "swiglu":
        h = silu(linear(p["w1"], x)) * linear(p["w3"], x)
    else:
        h = ACTIVATIONS[activation](linear(p["w1"], x))
    h = shard(h, "dp", None, "tp")
    return linear(p["w2"], h)
