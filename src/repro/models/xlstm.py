"""xLSTM blocks: chunkwise-parallel mLSTM + sequential sLSTM.

mLSTM (matrix memory, exponential gating) is computed in the chunkwise-
parallel form: within a chunk of L steps the contribution is an
attention-like lower-triangular product with log-space decay weights;
across chunks a ``lax.scan`` carries the (C, n, m) state.  This is the
TPU-native formulation (MXU-friendly L x L and L x d matmuls) of the
paper's recurrence — a sequential reference (``mlstm_sequential``) is
kept for correctness tests.

sLSTM (scalar memory, block-diagonal recurrence) is inherently
sequential (true recurrence through h_{t-1}); it runs as a ``lax.scan``
over time with all input projections hoisted out of the loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import shard
from repro.models.layers import init_linear, linear, silu


# ---------------------------------------------------------------------------
# mLSTM cell
# ---------------------------------------------------------------------------


def init_mlstm(key, d_model: int, *, n_heads: int, expand: float = 2.0,
               dtype=jnp.bfloat16) -> dict:
    d_inner = int(expand * d_model)
    hd = d_inner // n_heads
    ks = jax.random.split(key, 8)

    def block_diag(k):
        # per-head block-diagonal projection (official xLSTM mLSTM layout)
        w = jax.random.normal(k, (n_heads, hd, hd), jnp.float32)
        return (w / np.sqrt(hd)).astype(dtype)

    return {
        "up": init_linear(ks[0], d_model, d_inner, dtype=dtype),
        "gate_proj": init_linear(ks[1], d_model, d_inner, dtype=dtype),
        "wq": block_diag(ks[2]),
        "wk": block_diag(ks[3]),
        "wv": block_diag(ks[4]),
        "w_if": init_linear(ks[5], d_inner, 2 * n_heads, dtype=dtype),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "down": init_linear(ks[7], d_inner, d_model, dtype=dtype),
    }


def _headwise_rmsnorm(x, scale, n_heads, eps=1e-5):
    """GroupNorm-per-head stand-in (B, T, H, hd)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def mlstm_chunkwise(
    q, k, v,            # (B, H, T, dk/dv)
    i_gate, f_gate,     # (B, H, T) pre-activation (log-space via softplus)
    *,
    chunk: int = 64,
    state=None,         # (C (B,H,dk,dv), n (B,H,dk), m (B,H))
):
    """Chunkwise-parallel stabilized mLSTM. Returns (h, state)."""
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    pad = (-t) % chunk
    if pad:
        z = lambda x_, d_: jnp.pad(x_, ((0, 0), (0, 0), (0, pad)) + d_)
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        i_gate = jnp.pad(i_gate, ((0, 0), (0, 0), (0, pad)),
                         constant_values=-1e30)
        f_gate = jnp.pad(f_gate, ((0, 0), (0, 0), (0, pad)))
    tt = t + pad
    nc = tt // chunk
    scale = dk ** -0.5

    def rs(x_, d_):
        return x_.reshape(b, h, nc, chunk, d_).transpose(2, 0, 1, 3, 4)

    qc, kc, vc = rs(q, dk), rs(k, dk), rs(v, dv)
    ic = i_gate.reshape(b, h, nc, chunk).transpose(2, 0, 1, 3)
    # log f via softplus (always-positive forget gate in (0,1) log-space)
    logf = jax.nn.log_sigmoid(
        f_gate.astype(jnp.float32)
    ).reshape(b, h, nc, chunk).transpose(2, 0, 1, 3)

    if state is None:
        c0 = jnp.zeros((b, h, dk, dv), jnp.float32)
        n0 = jnp.zeros((b, h, dk), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state

    def body(carry, xs):
        c, n, m = carry
        qj, kj, vj, ij, fj = xs
        qj32, kj32, vj32 = (
            qj.astype(jnp.float32), kj.astype(jnp.float32),
            vj.astype(jnp.float32),
        )
        ij = ij.astype(jnp.float32)
        cum_f = jnp.cumsum(fj, axis=-1)                       # (B,H,L)
        # log weight of source s at step t: cum_f_t - cum_f_s + i_s
        src = ij - cum_f                                      # (B,H,L)
        run_max = jax.lax.cummax(src, axis=src.ndim - 1)      # (B,H,L)
        m_new = jnp.maximum(cum_f + m[..., None], cum_f + run_max)
        inter_scale = jnp.exp(cum_f + m[..., None] - m_new)   # (B,H,L)
        logw = (
            cum_f[..., :, None] + src[..., None, :] - m_new[..., :, None]
        )                                                     # (B,H,L,L)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(tri[None, None], jnp.exp(logw), 0.0)

        scores = jnp.einsum("bhtd,bhsd->bhts", qj32, kj32) * scale
        intra = jnp.einsum("bhts,bhsv->bhtv", scores * w, vj32)
        inter = jnp.einsum(
            "bhtd,bhdv->bhtv", qj32, c
        ) * scale * inter_scale[..., None]
        num = intra + inter

        n_intra = jnp.einsum("bhts,bhsd->bhtd", w, kj32)
        n_t = n_intra + n[..., None, :] * inter_scale[..., None]
        denom = jnp.abs(
            jnp.einsum("bhtd,bhtd->bht", qj32, n_t) * scale
        )
        denom = jnp.maximum(denom, jnp.exp(-m_new))
        h_out = num / denom[..., None]

        # end-of-chunk state update
        last_scale = jnp.exp(cum_f[..., -1:] + m[..., None] - m_new[..., -1:])
        src_w = jnp.exp(
            cum_f[..., -1:] + src - m_new[..., -1:]
        )                                                     # (B,H,L)
        c_new = (
            c * last_scale[..., None]
            + jnp.einsum("bhs,bhsd,bhsv->bhdv", src_w, kj32, vj32)
        )
        n_new = n * last_scale + jnp.einsum("bhs,bhsd->bhd", src_w, kj32)
        m_out = m_new[..., -1]
        return (c_new, n_new, m_out), h_out

    (c, n, m), hs = jax.lax.scan(body, (c0, n0, m0), (qc, kc, vc, ic, logf))
    h_all = hs.transpose(1, 2, 0, 3, 4).reshape(b, h, tt, dv)[:, :, :t]
    return h_all, (c, n, m)


def mlstm_sequential(q, k, v, i_gate, f_gate, *, state=None):
    """Step-by-step reference recurrence (tests + single-token decode)."""
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    scale = dk ** -0.5
    if state is None:
        c = jnp.zeros((b, h, dk, dv), jnp.float32)
        n = jnp.zeros((b, h, dk), jnp.float32)
        m = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c, n, m = state

    def body(carry, xs):
        c, n, m = carry
        qt, kt, vt, it, ft = xs
        qt, kt, vt = (x.astype(jnp.float32) for x in (qt, kt, vt))
        logf = jax.nn.log_sigmoid(ft.astype(jnp.float32))
        m_new = jnp.maximum(logf + m, it.astype(jnp.float32))
        fp = jnp.exp(logf + m - m_new)
        ip = jnp.exp(it.astype(jnp.float32) - m_new)
        c = c * fp[..., None, None] + ip[..., None, None] * (
            kt[..., :, None] * vt[..., None, :]
        )
        n = n * fp[..., None] + ip[..., None] * kt
        num = jnp.einsum("bhd,bhdv->bhv", qt, c) * scale
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)) * scale,
            jnp.exp(-m_new),
        )
        return (c, n, m_new), num / den[..., None]

    xs = tuple(
        x.transpose(2, 0, 1, 3) for x in (q, k, v)
    ) + tuple(x.transpose(2, 0, 1) for x in (i_gate, f_gate))
    (c, n, m), hs = jax.lax.scan(body, (c, n, m), xs)
    return hs.transpose(1, 2, 0, 3), (c, n, m)


def mlstm_block(
    p: dict,
    x: jnp.ndarray,                 # (B, T, d_model)
    *,
    n_heads: int,
    chunk: int = 64,
    state=None,
    return_state: bool = False,
):
    b, t, _ = x.shape
    inner = linear(p["up"], x)
    gate = linear(p["gate_proj"], x)
    d_inner = inner.shape[-1]
    hd = d_inner // n_heads

    inner_h = inner.reshape(b, t, n_heads, hd)

    def heads(w):
        # block-diagonal per-head projection -> (B, H, T, hd)
        return jnp.einsum("bthd,hde->bhte", inner_h, w)

    q = heads(p["wq"])
    k = heads(p["wk"])
    v = shard(heads(p["wv"]), "dp", None, None, "tp")
    if_gates = linear(p["w_if"], inner).astype(jnp.float32)
    i_gate = if_gates[..., :n_heads].transpose(0, 2, 1)
    f_gate = if_gates[..., n_heads:].transpose(0, 2, 1)

    if t == 1 and state is not None:
        h, new_state = mlstm_sequential(q, k, v, i_gate, f_gate, state=state)
    else:
        h, new_state = mlstm_chunkwise(
            q, k, v, i_gate, f_gate, chunk=min(chunk, max(t, 1)),
            state=state,
        )
    h = shard(h, "dp", None, None, "tp")
    h = h.transpose(0, 2, 1, 3)                    # (B, T, H, hd)
    h = _headwise_rmsnorm(h, p["norm_scale"], n_heads)
    h = h.reshape(b, t, d_inner) * p["norm_scale"][None, None]
    h = shard(h, "dp", None, "tp")
    out = linear(p["down"], (h.astype(x.dtype) * silu(gate)))
    if return_state:
        return out, new_state
    return out


def init_mlstm_state(b, d_model, *, n_heads, expand=2.0):
    d_inner = int(expand * d_model)
    hd = d_inner // n_heads
    return (
        jnp.zeros((b, n_heads, hd, hd), jnp.float32),
        jnp.zeros((b, n_heads, hd), jnp.float32),
        jnp.full((b, n_heads), -1e30, jnp.float32),
    )


# ---------------------------------------------------------------------------
# sLSTM cell
# ---------------------------------------------------------------------------


def init_slstm(key, d_model: int, *, n_heads: int,
               dtype=jnp.bfloat16) -> dict:
    hd = d_model // n_heads
    ks = jax.random.split(key, 4)
    return {
        "w_in": init_linear(ks[0], d_model, 4 * d_model, dtype=dtype),
        "r_kernel": (
            jax.random.normal(ks[1], (4, n_heads, hd, hd), jnp.float32)
            / np.sqrt(hd)
        ).astype(dtype),
        "norm_scale": jnp.ones((d_model,), jnp.float32),
        "w_ff": {
            "w1": init_linear(ks[2], d_model, 2 * d_model, dtype=dtype),
            "w2": init_linear(ks[3], d_model, d_model, dtype=dtype),
        },
    }


def slstm_block(
    p: dict,
    x: jnp.ndarray,                # (B, T, d_model)
    *,
    n_heads: int,
    state=None,                    # (c, n, m, h) each (B, H, hd)
    return_state: bool = False,
):
    b, t, d = x.shape
    hd = d // n_heads
    wx = linear(p["w_in"], x).astype(jnp.float32)     # (B,T,4d)
    wx = wx.reshape(b, t, 4, n_heads, hd)
    wx = shard(wx, "dp", None, None, None, "tp")
    r = p["r_kernel"].astype(jnp.float32)             # (4,H,hd,hd)

    if state is None:
        zeros = jnp.zeros((b, n_heads, hd), jnp.float32)
        state = (zeros, zeros + 1e-6, zeros - 1e30, zeros)
    c0, n0, m0, h0 = state

    def body(carry, xt):
        c, n, m, h = carry
        rec = jnp.einsum("bhd,ghde->gbhe", h, r)       # (4,B,H,hd)
        zt = jnp.tanh(xt[:, 0] + rec[0])
        it = xt[:, 1] + rec[1]
        ft = xt[:, 2] + rec[2]
        ot = jax.nn.sigmoid(xt[:, 3] + rec[3])
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        fp = jnp.exp(logf + m - m_new)
        ip = jnp.exp(it - m_new)
        c = fp * c + ip * zt
        n = fp * n + ip
        h = ot * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, h), h

    (c0, n0, m0, h0), hs = jax.lax.scan(
        body, (c0, n0, m0, h0), wx.transpose(1, 0, 2, 3, 4)
    )
    h_all = hs.transpose(1, 0, 2, 3).reshape(b, t, d)
    h_all = h_all * p["norm_scale"][None, None]
    out = h_all.astype(x.dtype)
    # post-up-projection GeGLU FFN (xLSTM sLSTM block, pf = 4/3-style)
    ff = p["w_ff"]
    g = linear(ff["w1"], out)
    g1, g2 = jnp.split(g, 2, axis=-1)
    out = linear(ff["w2"], silu(g1) * g2)
    if return_state:
        return out, (c0, n0, m0, h0)
    return out


def init_slstm_state(b, d_model, *, n_heads):
    hd = d_model // n_heads
    zeros = jnp.zeros((b, n_heads, hd), jnp.float32)
    return (zeros, zeros + 1e-6, zeros - 1e30, zeros)
