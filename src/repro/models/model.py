"""Model dispatcher: one uniform bundle per architecture.

``build_model(cfg)`` returns a :class:`ModelBundle` exposing

    init(key)                         -> params
    loss(params, batch)               -> (loss, metrics)
    prefill(params, batch, caches)    -> (last_logits, caches)
    decode(params, tokens, caches, t) -> (logits, caches)
    init_caches(b, max_seq)           -> serving state pytree
    input_specs(shape)                -> {name: ShapeDtypeStruct} (global)

Batch layouts (see DESIGN.md §4 frontends-as-stubs):
    decoder LM : tokens (B,S) labels (B,S)
    vlm        : patches (B,P,d) tokens (B,S-P) labels (B,S-P)
    encdec     : frames (B,S,d) tokens (B,S//4) labels (B,S//4)
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec as encdec_mod
from repro.models import transformer as tf


class ModelBundle(NamedTuple):
    cfg: ArchConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    init_caches: Callable
    input_specs: Callable


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  vocab: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Token-mean CE with label mask (labels < 0 ignored), fp32."""
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    loss = ((logz - ll) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, mask.sum()


# ---------------------------------------------------------------------------
# decoder-only (dense / moe / hybrid / ssm / vlm)
# ---------------------------------------------------------------------------


def _decoder_bundle(cfg: ArchConfig) -> ModelBundle:
    is_vlm = cfg.frontend == "patch_stub"
    n_front = cfg.n_frontend_tokens if is_vlm else 0

    def init(key):
        return tf.init_decoder(key, cfg)

    def _embed_batch(params, batch):
        x = tf.embed_tokens(params, cfg, batch["tokens"])
        if is_vlm:
            x = jnp.concatenate(
                [batch["patches"].astype(x.dtype), x], axis=1
            )
        return x

    def loss(params, batch):
        x = _embed_batch(params, batch)
        h, aux = tf.forward_hidden(params, cfg, x)
        if n_front:
            h = h[:, n_front:]
        logits = tf.logits_from_hidden(params, cfg, h)
        ce, n_tok = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        total = ce + 0.01 * aux["load_balance_loss"]
        metrics = {
            "ce": ce, "tokens": n_tok,
            "load_balance_loss": aux["load_balance_loss"],
            "dropped_fraction": aux["dropped_fraction"],
        }
        return total, metrics

    def init_caches(b, max_seq):
        return tf.init_caches(cfg, b, max_seq)

    def prefill(params, batch, caches):
        x = _embed_batch(params, batch)
        h, caches = tf.forward_with_cache(params, cfg, x, caches, 0)
        logits = tf.logits_from_hidden(params, cfg, h[:, -1:])
        return logits[:, 0], caches

    def decode(params, tokens, caches, pos):
        x = tf.embed_tokens(params, cfg, tokens)
        h, caches = tf.forward_with_cache(params, cfg, x, caches, pos)
        logits = tf.logits_from_hidden(params, cfg, h[:, -1:])
        return logits[:, 0], caches

    def input_specs(shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((b, s - n_front), jnp.int32)
        specs = {"tokens": tok}
        if is_vlm:
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, n_front, cfg.d_model), jnp.bfloat16
            )
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct(
                (b, s - n_front), jnp.int32
            )
        if shape.kind == "decode":
            specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        return specs

    return ModelBundle(cfg, init, loss, prefill, decode, init_caches,
                       input_specs)


# ---------------------------------------------------------------------------
# encoder-decoder (whisper)
# ---------------------------------------------------------------------------


def _encdec_bundle(cfg: ArchConfig) -> ModelBundle:
    dec_ratio = 4   # audio frames per text token (training shapes)

    def init(key):
        return encdec_mod.init_encdec(key, cfg)

    def loss(params, batch):
        enc_out = encdec_mod.encode(params, cfg, batch["frames"])
        h = encdec_mod.decode_train(params, cfg, batch["tokens"], enc_out)
        logits = tf.logits_from_hidden(params, cfg, h)
        ce, n_tok = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        return ce, {"ce": ce, "tokens": n_tok,
                    "load_balance_loss": jnp.float32(0.0),
                    "dropped_fraction": jnp.float32(0.0)}

    def init_caches(b, max_seq):
        # self KV sized for the decoder; cross KV sized for the encoder
        return encdec_mod.init_encdec_caches(cfg, b, max_seq, max_seq)

    def prefill(params, batch, caches):
        enc_out = encdec_mod.encode(params, cfg, batch["frames"])
        cross = encdec_mod.make_cross_kv(params, cfg, enc_out)
        caches = encdec_mod.EncDecCaches(
            self_kv=caches.self_kv, cross_kv=cross
        )
        h, caches = encdec_mod.decode_with_cache(
            params, cfg, batch["tokens"], caches, 0
        )
        logits = tf.logits_from_hidden(params, cfg, h[:, -1:])
        return logits[:, 0], caches

    def decode(params, tokens, caches, pos):
        h, caches = encdec_mod.decode_with_cache(
            params, cfg, tokens, caches, pos
        )
        logits = tf.logits_from_hidden(params, cfg, h[:, -1:])
        return logits[:, 0], caches

    def input_specs(shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        specs = {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                           jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((b, s // dec_ratio), jnp.int32),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct(
                (b, s // dec_ratio), jnp.int32
            )
        return specs

    return ModelBundle(cfg, init, loss, prefill, decode, init_caches,
                       input_specs)


def build_model(cfg: ArchConfig) -> ModelBundle:
    if cfg.family == "encdec":
        return _encdec_bundle(cfg)
    return _decoder_bundle(cfg)
