"""Pallas TPU kernel: 1-bit SimHash Hamming distance (QuIVer baseline).

Same tiling strategy as ``bq_distance`` but over a single bit plane —
used by the 1-bit ablation (§2.1 / §5) and as the cheapest navigation
distance in the comparison suite.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hamming_kernel(q_ref, base_ref, out_ref, *, w: int):
    acc = jnp.zeros(out_ref.shape, dtype=jnp.int32)
    for i in range(w):
        x = q_ref[:, i][:, None] ^ base_ref[:, i][None, :]
        acc += jax.lax.population_count(x).astype(jnp.int32)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_q", "block_n", "interpret"))
def hamming_distance_pallas(
    q_words: jnp.ndarray,
    base_words: jnp.ndarray,
    *,
    block_q: int = 8,
    block_n: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(Q, W) x (N, W) uint32 sign planes -> (Q, N) int32 Hamming.

    ``interpret=None`` resolves by platform: compiled Mosaic on TPU,
    interpreter elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q, w = q_words.shape
    n = base_words.shape[0]
    assert q % block_q == 0 and n % block_n == 0

    return pl.pallas_call(
        functools.partial(_hamming_kernel, w=w),
        grid=(q // block_q, n // block_n),
        in_specs=[
            pl.BlockSpec((block_q, w), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.int32),
        interpret=interpret,
    )(q_words, base_words)
