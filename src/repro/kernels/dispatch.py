"""Kernel dispatch: one owner for every BQ distance evaluation.

Every metric backend in ``repro.core.metric`` obtains its distance
primitives here, *once, at construction time*.  The route is decided by
the accelerator platform (overridable for tests):

* ``pallas`` — the Mosaic-compiled Pallas kernels in this package
  (``interpret=False``); chosen automatically on TPU.
* ``ref``    — the pure-jnp oracle in ``repro.core.bq``; chosen on
  CPU/GPU, where Pallas-TPU kernels would fall back to the (slow)
  interpreter.

Callers never touch ``bq.symmetric_similarity_words`` directly — the
registered backend over this module is the single owner of the BQ2
distance (enforced by a grep test in ``tests/test_metric_layer.py``).

Two primitive shapes cover all callers:

* ``dist_rows``: one query (or a batch of queries, broadcast over
  leading dims) against *gathered* rows — the beam-search hot path,
  ``(..., 2W) x (..., K, 2W) -> (..., K)``.
* ``pairwise``: all-pairs within a candidate pool — the alpha-prune
  path, ``(..., C, 2W) -> (..., C, C)``.

Both return **int32 similarities** (Table-1 weighted sums for BQ2,
negated Hamming for BQ1); the backend applies its own non-negative
distance calibration on top.
"""

from __future__ import annotations

import os
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bq


class MetricOps(NamedTuple):
    """Distance primitives bound to one route at backend construction."""

    dist_rows: Callable  # (..., 2W) x (..., K, 2W) -> (..., K) int32 sim
    pairwise: Callable   # (..., C, 2W) -> (..., C, C) int32 sim
    route: str           # "pallas" | "ref" (introspection / tests)


class ListScanOps(NamedTuple):
    """IVF coarse-routing primitive bound to one route (DESIGN.md §13)."""

    scan: Callable       # (Q, 2W) x (L, 2W) -> (Q, L) int32 sim
    route: str           # "pallas" | "ref"


def resolve_route(route: str | None = None) -> str:
    """Pick the kernel route once; ``QUIVER_DISPATCH`` overrides auto.

    auto: Pallas on TPU (compiled Mosaic), jnp reference elsewhere —
    interpret-mode Pallas is a debugger, not a hot path.
    """
    route = route or os.environ.get("QUIVER_DISPATCH", "auto")
    if route == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if route not in ("pallas", "ref"):
        raise ValueError(
            f"unknown dispatch route {route!r}; expected pallas|ref|auto"
        )
    return route


# ---------------------------------------------------------------------------
# BQ2 — symmetric 2-bit Sign-Magnitude similarity
# ---------------------------------------------------------------------------


def _bq2_sim_ref(q_words, rows, mask, w):
    """Broadcasting jnp reference: (..., 2W) x (..., K, 2W) -> (..., K)."""
    qp = q_words[..., None, :w]
    qs = q_words[..., None, w:]
    return bq.symmetric_similarity_words(
        qp, qs, rows[..., :w], rows[..., w:], mask
    )


def _bq2_pairwise_ref(rows, mask, w):
    a = rows[..., :, None, :]
    b = rows[..., None, :, :]
    return bq.symmetric_similarity_words(
        a[..., :w], a[..., w:], b[..., :w], b[..., w:], mask
    )


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - size)
    return jnp.pad(x, widths)


def _flatten_leading(fn):
    """Lift a (2-D q, 2-D rows) kernel call over arbitrary leading dims."""

    def wrapped(q_words, rows):
        lead = rows.shape[:-2]
        k, ww2 = rows.shape[-2], rows.shape[-1]
        q2 = jnp.broadcast_to(q_words, (*lead, ww2)).reshape(-1, ww2)
        r2 = rows.reshape(-1, k, ww2)
        out = jax.vmap(fn)(q2[:, None, :], r2)      # (B, 1, K)
        return out.reshape(*lead, k)

    return wrapped


def bq2_ops(dim: int, route: str | None = None) -> MetricOps:
    """Bind the symmetric 2-bit SM similarity primitives for ``dim``."""
    from repro.kernels.bq_distance import bq_distance_pallas

    route = resolve_route(route)
    mask = bq.valid_mask(dim)
    w = bq.n_words(dim)

    if route == "ref":
        return MetricOps(
            dist_rows=lambda q, rows: _bq2_sim_ref(q, rows, mask, w),
            pairwise=lambda rows: _bq2_pairwise_ref(rows, mask, w),
            route=route,
        )

    block_q, block_n = 8, 128

    def kernel_qn(q2, r2):
        """(Q, 2W) x (N, 2W) -> (Q, N) similarity via the Pallas kernel."""
        qp = _pad_to(q2, 0, block_q)
        rp = _pad_to(r2, 0, block_n)
        d = bq_distance_pallas(
            qp, rp, mask, dim=dim, block_q=block_q, block_n=block_n,
        )
        return -d[: q2.shape[0], : r2.shape[0]]     # kernel emits -sim

    def pairwise(rows):
        lead = rows.shape[:-2]
        c, ww2 = rows.shape[-2], rows.shape[-1]
        r2 = rows.reshape(-1, c, ww2)
        out = jax.vmap(lambda r: kernel_qn(r, r))(r2)
        return out.reshape(*lead, c, c)

    return MetricOps(
        dist_rows=_flatten_leading(kernel_qn),
        pairwise=pairwise,
        route=route,
    )


# ---------------------------------------------------------------------------
# IVF centroid list scan — batched top-p coarse routing (DESIGN.md §13)
# ---------------------------------------------------------------------------


def list_scan_ops(dim: int, route: str | None = None) -> ListScanOps:
    """Bind the batched centroid-scan primitive for ``dim``.

    The scan scores a query block against *every* list centroid
    signature at once — (Q, 2W) x (L, 2W) -> (Q, L) int32 Table-1
    similarity — so a ``lax.top_k`` over the result is the top-p list
    routing decision of the IVF layer.  Same ``QUIVER_DISPATCH``
    switch as the metric ops: compiled Mosaic kernel on TPU
    (centroids VMEM-resident across the whole grid,
    ``repro.kernels.list_scan``), jnp reference elsewhere.
    """
    from repro.kernels.list_scan import list_scan_pallas

    route = resolve_route(route)
    mask = bq.valid_mask(dim)
    w = bq.n_words(dim)

    if route == "ref":
        return ListScanOps(
            scan=lambda q, cents: _bq2_sim_ref(q, cents, mask, w),
            route=route,
        )

    block_q, block_l = 8, 128

    def scan(q_words, cent_words):
        qp = _pad_to(q_words, 0, block_q)
        cp = _pad_to(cent_words, 0, block_l)
        sim = list_scan_pallas(qp, cp, mask, dim=dim, block_q=block_q)
        return sim[: q_words.shape[0], : cent_words.shape[0]]

    return ListScanOps(scan=scan, route=route)


# ---------------------------------------------------------------------------
# BQ1 — 1-bit SimHash Hamming (sign plane only)
# ---------------------------------------------------------------------------


def _ham_rows_ref(q_words, rows):
    x = q_words[..., None, :] ^ rows
    return -jax.lax.population_count(x).astype(jnp.int32).sum(axis=-1)


def _ham_pairwise_ref(rows):
    x = rows[..., :, None, :] ^ rows[..., None, :, :]
    return -jax.lax.population_count(x).astype(jnp.int32).sum(axis=-1)


def bq1_ops(dim: int, route: str | None = None) -> MetricOps:
    """Bind the 1-bit Hamming primitives (as negated-distance similarity)."""
    from repro.kernels.hamming import hamming_distance_pallas

    route = resolve_route(route)

    if route == "ref":
        return MetricOps(
            dist_rows=_ham_rows_ref,
            pairwise=_ham_pairwise_ref,
            route=route,
        )

    block_q, block_n = 8, 128

    def kernel_qn(q2, r2):
        qp = _pad_to(q2, 0, block_q)
        rp = _pad_to(r2, 0, block_n)
        d = hamming_distance_pallas(
            qp, rp, block_q=block_q, block_n=block_n,
        )
        return -d[: q2.shape[0], : r2.shape[0]]

    def pairwise(rows):
        lead = rows.shape[:-2]
        c, ww = rows.shape[-2], rows.shape[-1]
        r2 = rows.reshape(-1, c, ww)
        out = jax.vmap(lambda r: kernel_qn(r, r))(r2)
        return out.reshape(*lead, c, c)

    return MetricOps(
        dist_rows=_flatten_leading(kernel_qn),
        pairwise=pairwise,
        route=route,
    )
