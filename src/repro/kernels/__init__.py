"""Accelerator kernels for the QuIVer hot path.

* ``bq_distance`` / ``hamming`` / ``binarize`` — Pallas TPU kernels for
  the paper's compute hot-spots (compiled Mosaic on TPU, interpreter
  fallback elsewhere).
* ``ops``      — jit'd shape-padding wrappers around the raw kernels.
* ``ref``      — pure-jnp oracles with identical calling conventions.
* ``dispatch`` — the routing layer every metric backend binds against:
  one owner for every BQ distance evaluation (DESIGN.md §2).
"""

from repro.kernels import dispatch  # noqa: F401
from repro.kernels.dispatch import (  # noqa: F401
    MetricOps,
    bq1_ops,
    bq2_ops,
    resolve_route,
)
