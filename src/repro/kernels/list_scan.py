"""Pallas TPU kernel: batched IVF centroid list-scan in BQ space.

The coarse routing primitive of the IVF-over-BQ layer (DESIGN.md §13):
score a block of queries against *every* list centroid signature and
let the caller keep the top-p lists.  Same Table-1 weighted similarity
as ``repro.kernels.bq_distance``, different tiling: the centroid set is
small (L ≈ √N signatures, a few hundred KB even at fleet scale), so the
whole (L, 2W) centroid matrix stays VMEM-resident across the grid and
only the query blocks stream HBM→VMEM — one grid dimension, not two.
Each base-signature word is read once per query *block* rather than
once per query, which is what makes the scan cheap enough to sit in
front of every search and every construction chunk.

Emits raw int32 similarities (larger = nearer), matching the
``MetricOps`` convention of ``repro.kernels.dispatch``; the top-p
selection itself is a ``lax.top_k`` over the (Q, L) tile — L is tiny,
so selection is never the bottleneck and staying out of the kernel
keeps Mosaic layouts on the native (8, 128) tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _list_scan_kernel(mask_ref, q_ref, cent_ref, out_ref, *, w: int):
    """One (block_q, L) similarity tile.

    q_ref:    (block_q, 2W) uint32 — [pos | strong] query signature words
    cent_ref: (L, 2W)       uint32 — the full centroid matrix (resident)
    mask_ref: (1, W)        uint32 valid-bit mask
    out_ref:  (block_q, L)  int32
    """
    sim = jnp.zeros(out_ref.shape, dtype=jnp.int32)
    for i in range(w):
        qp = q_ref[:, i][:, None]            # (bq, 1)
        qs = q_ref[:, w + i][:, None]
        cp = cent_ref[:, i][None, :]         # (1, L)
        cs = cent_ref[:, w + i][None, :]
        m = mask_ref[0, i]

        diff = qp ^ cp                       # pad bits are 0 in both planes
        same = (~diff) & m
        both_strong = qs & cs
        one_strong = qs ^ cs
        both_weak = (~(qs | cs)) & m

        def pc(v):
            return jax.lax.population_count(v).astype(jnp.int32)

        sim += (
            4 * pc(same & both_strong)
            + 2 * pc(same & one_strong)
            + pc(same & both_weak)
            - 4 * pc(diff & both_strong)
            - 2 * pc(diff & one_strong)
            - pc(diff & both_weak)
        )
    out_ref[...] = sim


@functools.partial(
    jax.jit, static_argnames=("dim", "block_q", "interpret")
)
def list_scan_pallas(
    q_words: jnp.ndarray,
    cent_words: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    dim: int,
    block_q: int = 8,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(Q, 2W) queries x (L, 2W) centroids -> (Q, L) int32 similarity.

    Q % block_q == 0 and L % 128 == 0 (pad with zero signatures; a zero
    pad column scores the orthogonal-pair similarity and never wins a
    top-p race against a real centroid for in-distribution queries —
    callers slice pads off anyway).  ``interpret=None`` resolves by
    platform: compiled Mosaic on TPU, interpreter elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q, ww2 = q_words.shape
    el = cent_words.shape[0]
    w = ww2 // 2
    assert q % block_q == 0 and el % 128 == 0, (q, el, block_q)

    grid = (q // block_q,)
    return pl.pallas_call(
        functools.partial(_list_scan_kernel, w=w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w), lambda i: (0, 0)),
            pl.BlockSpec((block_q, ww2), lambda i: (i, 0)),
            pl.BlockSpec((el, ww2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, el), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, el), jnp.int32),
        interpret=interpret,
    )(mask.reshape(1, -1), q_words, cent_words)
