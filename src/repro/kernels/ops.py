"""Jit'd public wrappers around the Pallas kernels.

These handle shape padding (block divisibility), interpret-mode selection
(Pallas executes in Python on CPU; compiled Mosaic on TPU), and the
packed-word bookkeeping, so callers deal only in logical shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bq
from repro.kernels.binarize import binarize_pallas
from repro.kernels.bq_distance import bq_distance_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.hamming import hamming_distance_pallas


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pad_rows(x: jnp.ndarray, mult: int) -> tuple[jnp.ndarray, int]:
    n = x.shape[0]
    target = ((n + mult - 1) // mult) * mult
    if target != n:
        pad = jnp.zeros((target - n, *x.shape[1:]), dtype=x.dtype)
        x = jnp.concatenate([x, pad], axis=0)
    return x, n


def bq_distance(
    q_words: jnp.ndarray,
    base_words: jnp.ndarray,
    dim: int,
    *,
    block_q: int = 8,
    block_n: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Symmetric 2-bit SM distances, (Q, 2W) x (N, 2W) -> (Q, N) int32."""
    interpret = _auto_interpret(interpret)
    mask = bq.valid_mask(dim)
    qp, q = _pad_rows(q_words, block_q)
    bp, n = _pad_rows(base_words, block_n)
    out = bq_distance_pallas(
        qp, bp, mask, dim=dim, block_q=block_q, block_n=block_n,
        interpret=interpret,
    )
    return out[:q, :n]


def hamming_distance(
    q_words: jnp.ndarray,
    base_words: jnp.ndarray,
    *,
    block_q: int = 8,
    block_n: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """1-bit Hamming distances over sign planes, (Q, W) x (N, W) -> (Q, N)."""
    interpret = _auto_interpret(interpret)
    qp, q = _pad_rows(q_words, block_q)
    bp, n = _pad_rows(base_words, block_n)
    out = hamming_distance_pallas(
        qp, bp, block_q=block_q, block_n=block_n, interpret=interpret
    )
    return out[:q, :n]


def binarize(
    x: jnp.ndarray,
    *,
    block_n: int = 256,
    interpret: bool | None = None,
) -> bq.Signature:
    """(N, D) float32 -> packed Signature via the fused Pallas pass."""
    interpret = _auto_interpret(interpret)
    n, d = x.shape
    d_pad = bq.n_words(d) * bq.WORD_BITS
    if d_pad != d:
        x = jnp.concatenate(
            [x, jnp.zeros((n, d_pad - d), dtype=x.dtype)], axis=-1
        )
    xp, n0 = _pad_rows(x, block_n)
    words = binarize_pallas(
        xp, true_dim=d, block_n=block_n, interpret=interpret
    )
    return bq.Signature(words=words[:n0], dim=d)


def flash_attention_tpu(
    q: jnp.ndarray,            # (B, T, H, hd) — GQA already MHA-ized
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Pallas flash attention behind the model-layer layout."""
    interpret = _auto_interpret(interpret)
    b, t, h, hd = q.shape
    tk = k.shape[1]

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], hd)

    pad_q = (-t) % block_q
    pad_kv = (-tk) % block_kv
    qf, kf, vf = fold(q), fold(k), fold(v)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        kf = jnp.pad(kf, ((0, 0), (0, pad_kv), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_kv), (0, 0)))
    out = flash_attention_pallas(
        qf, kf, vf, block_q=block_q, block_kv=block_kv,
        causal=causal, interpret=interpret, kv_len=tk,
    )[:, :t]
    return out.reshape(b, h, t, hd).transpose(0, 2, 1, 3)
