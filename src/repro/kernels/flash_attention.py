"""Pallas TPU kernel: blockwise-softmax (flash) attention.

The LM-side perf-critical layer (prefill cells are memory-bound on
attention score traffic — EXPERIMENTS.md §Roofline). Grid is
(batch*heads, q-blocks); each cell streams KV in ``block_kv`` slices
with the online max/denominator recurrence, so VMEM holds one
(block_q x hd) query tile + one (block_kv x hd) KV tile + the running
accumulator. MXU does the two matmuls per tile; the mask is computed
from iota on the VPU.

Caller contract (see ``ops.flash_attention_tpu``): GQA is MHA-ized
before the kernel (matches the train-path layout decision, DESIGN.md
§7.5); layouts are (B*H, T, hd) with hd a multiple of 128 preferred.
Validated against ``ref.flash_attention_ref`` in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_kv: int,
                  causal: bool, scale: float, kv_len: int):
    """One (1, block_q, hd) output tile; streams KV in block_kv slices."""
    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    bq = q.shape[0]
    tk = k_ref.shape[1]
    n_kv = tk // block_kv
    q_block = pl.program_id(1)
    q_pos = q_block * bq + jax.lax.iota(jnp.int32, bq)

    def body(j, carry):
        acc, m, d = carry
        k = jax.lax.dynamic_slice_in_dim(
            k_ref[0], j * block_kv, block_kv, axis=0
        ).astype(jnp.float32)                          # (bkv, hd)
        v = jax.lax.dynamic_slice_in_dim(
            v_ref[0], j * block_kv, block_kv, axis=0
        ).astype(jnp.float32)
        s = q @ k.T                                    # (bq, bkv)
        k_pos = j * block_kv + jax.lax.iota(jnp.int32, block_kv)
        mask = k_pos[None, :] < kv_len                 # padded KV rows
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        acc = acc * corr[:, None] + p @ v
        d = d * corr + p.sum(axis=-1)
        return acc, m_new, d

    hd = q.shape[-1]
    acc0 = jnp.zeros((bq, hd), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    d0 = jnp.zeros((bq,), jnp.float32)
    acc, m, d = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, d0))
    o_ref[0] = (acc / jnp.maximum(d, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_kv", "causal", "interpret",
                     "kv_len"),
)
def flash_attention_pallas(
    q: jnp.ndarray,       # (BH, Tq, hd)
    k: jnp.ndarray,       # (BH, Tk, hd)
    v: jnp.ndarray,       # (BH, Tk, hd)
    *,
    block_q: int = 128,
    block_kv: int = 128,
    causal: bool = True,
    interpret: bool = True,
    kv_len: int | None = None,
) -> jnp.ndarray:
    bh, tq, hd = q.shape
    tk = k.shape[1]
    assert tq % block_q == 0 and tk % block_kv == 0, (tq, tk)
    scale = hd ** -0.5
    kv_len = kv_len if kv_len is not None else tk

    return pl.pallas_call(
        functools.partial(
            _flash_kernel, block_kv=block_kv, causal=causal, scale=scale,
            kv_len=kv_len,
        ),
        grid=(bh, tq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, tk, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, tk, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
