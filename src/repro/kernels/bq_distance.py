"""Pallas TPU kernel: symmetric 2-bit Sign-Magnitude BQ distance.

TPU adaptation of QuIVer's AVX-512 VPOPCNTDQ hot loop (§3.1): the packed
signature matrix is tiled HBM->VMEM in (block_q x 2W) / (block_n x 2W)
tiles; the six Table-1 category terms are evaluated with bitwise ops +
``lax.population_count`` on the VPU and accumulated into an int32
(block_q x block_n) distance tile.

The word loop is unrolled statically (W <= 48 for D <= 1536); every
intermediate is a 2-D (block_q, block_n) uint32/int32 tile, which keeps
Mosaic layouts on the native (8, 128) register tiling.  The kernel is
HBM-bandwidth bound by design — each base word is read once per query
block — mirroring the memory-bound character of the paper's CPU loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bq_distance_kernel(mask_ref, q_ref, base_ref, out_ref, *, w: int):
    """One (block_q, block_n) output tile.

    q_ref:    (block_q, 2W) uint32 — [pos | strong] words
    base_ref: (block_n, 2W) uint32
    mask_ref: (1, W)        uint32 valid-bit mask
    out_ref:  (block_q, block_n) int32
    """
    sim = jnp.zeros(out_ref.shape, dtype=jnp.int32)
    for i in range(w):
        qp = q_ref[:, i][:, None]          # (bq, 1)
        qs = q_ref[:, w + i][:, None]
        bp = base_ref[:, i][None, :]       # (1, bn)
        bs = base_ref[:, w + i][None, :]
        m = mask_ref[0, i]

        diff = qp ^ bp                      # pad bits are 0 in both planes
        same = (~diff) & m
        both_strong = qs & bs
        one_strong = qs ^ bs
        both_weak = (~(qs | bs)) & m

        def pc(v):
            return jax.lax.population_count(v).astype(jnp.int32)

        sim += (
            4 * pc(same & both_strong)
            + 2 * pc(same & one_strong)
            + pc(same & both_weak)
            - 4 * pc(diff & both_strong)
            - 2 * pc(diff & one_strong)
            - pc(diff & both_weak)
        )
    out_ref[...] = -sim


@functools.partial(
    jax.jit, static_argnames=("dim", "block_q", "block_n", "interpret")
)
def bq_distance_pallas(
    q_words: jnp.ndarray,
    base_words: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    dim: int,
    block_q: int = 8,
    block_n: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(Q, 2W) x (N, 2W) -> (Q, N) int32. Q % block_q == N % block_n == 0.

    ``interpret=None`` resolves by platform: compiled Mosaic on TPU,
    interpreter elsewhere (correctness-only fallback).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q, ww2 = q_words.shape
    n = base_words.shape[0]
    w = ww2 // 2
    assert q % block_q == 0 and n % block_n == 0, (q, n, block_q, block_n)

    grid = (q // block_q, n // block_n)
    return pl.pallas_call(
        functools.partial(_bq_distance_kernel, w=w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w), lambda i, j: (0, 0)),
            pl.BlockSpec((block_q, ww2), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, ww2), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.int32),
        interpret=interpret,
    )(mask.reshape(1, -1), q_words, base_words)
