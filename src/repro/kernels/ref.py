"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function has the exact calling convention of the
corresponding kernel wrapper in ``ops.py`` and is used by the test suite
as ground truth (``assert_allclose`` / exact integer equality).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import bq


def bq_distance_ref(
    q_words: jnp.ndarray, base_words: jnp.ndarray, dim: int
) -> jnp.ndarray:
    """(Q, 2W) x (N, 2W) packed signatures -> (Q, N) int32 distances."""
    q = bq.Signature(words=q_words, dim=dim)
    b = bq.Signature(words=base_words, dim=dim)
    return bq.pairwise_distance(q, b)


def hamming_distance_ref(
    q_words: jnp.ndarray, base_words: jnp.ndarray, dim: int
) -> jnp.ndarray:
    """1-bit plane Hamming distance, (Q, W) x (N, W) -> (Q, N) int32."""
    x = q_words[:, None, :] ^ base_words[None, :, :]
    import jax

    return jax.lax.population_count(x).astype(jnp.int32).sum(axis=-1)


def binarize_ref(x: jnp.ndarray) -> jnp.ndarray:
    """(N, D) float32 -> (N, 2W) packed uint32 2-bit SM signatures."""
    return bq.encode(x).words


def flash_attention_ref(q, k, v, *, causal=True):
    """(BH, Tq, hd) x (BH, Tk, hd) -> (BH, Tq, hd), naive softmax."""
    import jax

    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        tq, tk = q.shape[1], k.shape[1]
        mask = jnp.arange(tk)[None, :] <= jnp.arange(tq)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
