"""Pallas TPU kernel: float32 -> packed 2-bit Sign-Magnitude signatures.

Stage-0 bulk pre-installation (QuIVer §4.1) as a single fused pass:
per-row threshold tau = mean|x|, sign/magnitude bit planes, and bit
packing into uint32 words, one (block_n, D) VMEM tile at a time.  The
float vector is read exactly once from HBM; only D/4 bytes per vector are
written back (12:1 compression happens on-chip).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bq import WORD_BITS


def _binarize_kernel(x_ref, out_ref, *, true_dim: int, w: int):
    """x_ref: (block_n, D_pad) float32; out_ref: (block_n, 2W) uint32."""
    x = x_ref[...]
    absx = jnp.abs(x)
    # Padding columns are zero, so sum is over the true dims only.
    tau = absx.sum(axis=-1, keepdims=True) / jnp.float32(true_dim)
    pos = (x > 0).astype(jnp.uint32)
    strong = (absx > tau).astype(jnp.uint32)

    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))

    def pack(bits):
        g = bits.reshape(bits.shape[0], w, WORD_BITS)
        return (g * weights).sum(axis=-1).astype(jnp.uint32)

    out_ref[:, :w] = pack(pos)
    out_ref[:, w:] = pack(strong)


@functools.partial(
    jax.jit, static_argnames=("true_dim", "block_n", "interpret")
)
def binarize_pallas(
    x_padded: jnp.ndarray,
    *,
    true_dim: int,
    block_n: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """(N, D_pad) float32 (D_pad % 32 == 0, zero-padded) -> (N, 2W) uint32."""
    n, d_pad = x_padded.shape
    assert d_pad % WORD_BITS == 0 and n % block_n == 0, (n, d_pad)
    w = d_pad // WORD_BITS

    return pl.pallas_call(
        functools.partial(_binarize_kernel, true_dim=true_dim, w=w),
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n, d_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_n, 2 * w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 2 * w), jnp.uint32),
        interpret=interpret,
    )(x_padded)
